# DGL-KE reproduction — build/test/verify entry points.

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS_DIR ?= artifacts

.PHONY: build test fmt fmt-check lint analyze loom miri tsan check artifacts bench bench-smoke bench-prefetch bench-cache bench-dist bench-kernels bench-serve bench-obs trace clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt

fmt-check:
	$(CARGO) fmt --check

# Repo-specific static analysis (narrowing casts in byte math, the
# unsafe budget, unwrap bans in kvstore/serve/prefetch, the
# Relaxed-ordering allowlist). Config: unsafe-budget.toml +
# relaxed-allowlist.toml.
lint:
	$(CARGO) run -p xtask -- lint

# Syntax-aware static analysis (lexer + crate-local call graph):
# lock-order/deadlock vs lock-order.toml, blocking-under-lock,
# Release/Acquire pairing vs ordering-pairs.toml, ledger-billing
# completeness over the KV access sites, and the metrics-registry
# ratchet vs metrics-registry.toml. See docs/STATIC_ANALYSIS.md.
analyze:
	$(CARGO) run -p xtask -- analyze

# Loom-style model checking: reruns rust/tests/loom_tests.rs with the
# util::sync shim's seeded schedule perturbation (48 interleavings per
# test by default; LOOM_MAX_ITERS=n to change).
loom:
	RUSTFLAGS="--cfg loom" $(CARGO) test --test loom_tests

# Miri over the race-free unit-test subset (needs a nightly toolchain
# with the miri component). Hogwild tests are excluded by the filter:
# the intentional RacyCell race is UB by the letter of the model and is
# policed by quarantine instead (docs/CONCURRENCY.md).
miri:
	MIRIFLAGS=-Zmiri-disable-isolation $(CARGO) +nightly miri test --lib \
	    util:: kvstore::protocol store::racy store::dense train::batch

# ThreadSanitizer over the concurrency unit tests (nightly + build-std).
# Known benign reports are suppressed via tsan-suppressions.txt, which
# names ONLY the quarantined store::racy Hogwild cell.
tsan:
	RUSTFLAGS="-Zsanitizer=thread --cfg tsan" \
	TSAN_OPTIONS="suppressions=$(CURDIR)/tsan-suppressions.txt" \
	$(CARGO) +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu --lib \
	    store:: train::sync kvstore:: util::

# Tier-1 verification: what CI runs.
check: build test fmt-check lint analyze

# AOT-compile the JAX/Pallas train+eval artifacts (writes
# $(ARTIFACTS_DIR)/manifest.json + HLO text files). Requires jax.
# abspath keeps ARTIFACTS_DIR overrides (relative or absolute) correct
# despite the cd into python/.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out $(abspath $(ARTIFACTS_DIR))

# Storage-layer gather/scatter microbenchmark (dense vs sharded vs mmap);
# small enough for CI, writes the BENCH_storage.json artifact.
bench-smoke:
	QUICK=1 $(CARGO) bench --bench bench_storage

# Prefetch-pipeline on/off step-time comparison per storage backend;
# writes BENCH_prefetch.json (expected: mmap >= 1.2x, dense ~ wash).
bench-prefetch:
	QUICK=1 $(CARGO) bench --bench bench_prefetch

# Hot-row cache gather/update latency (mmap: cache off / cold / warm);
# writes BENCH_cache.json (expected: warm gather beats uncached mmap).
bench-cache:
	QUICK=1 $(CARGO) bench --bench bench_cache

# Distributed comms: sync vs pipelined vs pipelined+prefetch KVStore
# client on random vs METIS partitions; writes BENCH_dist.json (expected:
# pipelined+prefetch cuts per-batch time vs sync on the random partition).
bench-dist:
	QUICK=1 $(CARGO) bench --bench bench_dist

# Fused-vs-scalar score/grad kernel throughput per model x dim; writes
# BENCH_kernels.json (expected: fused score >= 2x for Dot/SqDiff at dim
# 400; parity itself is asserted by kernel_parity_tests).
bench-kernels:
	QUICK=1 $(CARGO) bench --bench bench_kernels

# Serving latency/throughput: snapshot cold-open + first batch vs warm
# steady state, per kernel backend; writes BENCH_serve.json (p50/p95
# batch latency, QPS — see docs/SERVING.md).
bench-serve:
	QUICK=1 $(CARGO) bench --bench bench_serve

# Observability overhead: disabled/enabled span cost, counter bumps,
# and the same tiny run with obs off vs on; writes BENCH_obs.json
# (asserts the disabled span path stays under a generous 1 us ceiling —
# the contract is "free when off", docs/OBSERVABILITY.md).
bench-obs:
	QUICK=1 $(CARGO) bench --bench bench_obs

# Tracing smoke: a tiny traced run, then schema + span-nesting
# validation of the emitted Chrome trace via `dglke trace-check`.
trace:
	$(CARGO) run --release --bin dglke -- train --dataset tiny --workers 1 \
	    --batches 40 --log-every 10 --prefetch \
	    --trace-path /tmp/dglke-trace-smoke.json
	$(CARGO) run --release --bin dglke -- trace-check /tmp/dglke-trace-smoke.json

# Paper-figure benches (skip gracefully without artifacts). QUICK=1 shrinks.
bench:
	$(CARGO) build --release --benches
	for b in fig3_neg_sampling fig4_optimizations fig5_multigpu fig6_manycore \
	         fig7_distributed fig8_pbg fig9_graphvite; do \
	    $(CARGO) bench --bench $$b || exit 1; \
	done

clean:
	$(CARGO) clean
