# DGL-KE reproduction — build/test/verify entry points.

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS_DIR ?= artifacts

.PHONY: build test fmt fmt-check check artifacts bench bench-smoke bench-prefetch bench-cache bench-dist clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt

fmt-check:
	$(CARGO) fmt --check

# Tier-1 verification: what CI runs.
check: build test fmt-check

# AOT-compile the JAX/Pallas train+eval artifacts (writes
# $(ARTIFACTS_DIR)/manifest.json + HLO text files). Requires jax.
# abspath keeps ARTIFACTS_DIR overrides (relative or absolute) correct
# despite the cd into python/.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out $(abspath $(ARTIFACTS_DIR))

# Storage-layer gather/scatter microbenchmark (dense vs sharded vs mmap);
# small enough for CI, writes the BENCH_storage.json artifact.
bench-smoke:
	QUICK=1 $(CARGO) bench --bench bench_storage

# Prefetch-pipeline on/off step-time comparison per storage backend;
# writes BENCH_prefetch.json (expected: mmap >= 1.2x, dense ~ wash).
bench-prefetch:
	QUICK=1 $(CARGO) bench --bench bench_prefetch

# Hot-row cache gather/update latency (mmap: cache off / cold / warm);
# writes BENCH_cache.json (expected: warm gather beats uncached mmap).
bench-cache:
	QUICK=1 $(CARGO) bench --bench bench_cache

# Distributed comms: sync vs pipelined vs pipelined+prefetch KVStore
# client on random vs METIS partitions; writes BENCH_dist.json (expected:
# pipelined+prefetch cuts per-batch time vs sync on the random partition).
bench-dist:
	QUICK=1 $(CARGO) bench --bench bench_dist

# Paper-figure benches (skip gracefully without artifacts). QUICK=1 shrinks.
bench:
	$(CARGO) build --release --benches
	for b in fig3_neg_sampling fig4_optimizations fig5_multigpu fig6_manycore \
	         fig7_distributed fig8_pbg fig9_graphvite; do \
	    $(CARGO) bench --bench $$b || exit 1; \
	done

clean:
	$(CARGO) clean
