//! Hot-row cache microbenchmark: gather and update latency on the mmap
//! backend with the cache off / cold / warm. Writes `BENCH_cache.json`
//! (`make bench-cache`) so the cache's win is tracked run-over-run.
//!
//! Expectation: a *warm* cache turns per-row `pread`/`pwrite` syscalls
//! into user-space copies, so warm gather must beat uncached mmap gather
//! (the acceptance bar); the *cold* pass prices the fill/evict overhead
//! — it stays in the same ballpark as uncached because each miss is one
//! backing-store read plus bookkeeping.
//!
//! QUICK=1 shrinks the table and pass count for smoke runs.

use dglke::store::{CachedStore, EmbeddingStore, MmapStore};
use dglke::util::json::Json;
use dglke::util::rng::Rng;
use std::collections::BTreeMap;
use std::time::Instant;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Time one pass over `batches`, reporting ms per batch.
fn time_pass(batches: &[Vec<u64>], mut f: impl FnMut(&[u64])) -> f64 {
    let t = Instant::now();
    for b in batches {
        f(b);
    }
    t.elapsed().as_secs_f64() * 1000.0 / batches.len().max(1) as f64
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QUICK").is_ok();
    let rows: usize = if quick { 50_000 } else { 200_000 };
    let dim: usize = 64;
    let n_ids: usize = 2048;
    let iters = if quick { 16 } else { 64 };
    // hot working set sized well under the cache; the cold stream spans
    // the whole table so it misses (and evicts) continuously
    let hot_rows: usize = 4096;
    let capacity_rows: usize = 8192;

    let mut rng = Rng::seed_from_u64(11);
    let hot_ids: Vec<u64> =
        rng.sample_distinct(rows, hot_rows).into_iter().map(|x| x as u64).collect();
    let hot_batches: Vec<Vec<u64>> = (0..iters)
        .map(|_| (0..n_ids).map(|_| hot_ids[rng.gen_index(hot_rows)]).collect())
        .collect();
    let cold_batches: Vec<Vec<u64>> = (0..iters)
        .map(|_| (0..n_ids).map(|_| rng.gen_index(rows) as u64).collect())
        .collect();

    let tmp = |tag: &str| {
        std::env::temp_dir().join(format!("dglke-bench-cache-{tag}-{}.f32", std::process::id()))
    };
    let mut out = vec![0f32; n_ids * dim];

    println!(
        "cache bench: rows={rows} dim={dim} batch_ids={n_ids} iters={iters} \
         hot_rows={hot_rows} capacity_rows={capacity_rows}"
    );

    // uncached mmap baseline: one untimed pass warms the OS page cache,
    // so the comparison is pread-from-page-cache vs user-space hit
    let plain = MmapStore::create_ephemeral(&tmp("plain"), rows, dim)?;
    time_pass(&hot_batches, |b| {
        plain.gather(b, &mut out);
    });
    let gather_off_ms = time_pass(&hot_batches, |b| {
        plain.gather(b, &mut out);
    });
    let update_off_ms = time_pass(&hot_batches, |b| {
        for &id in b {
            plain.update_row(id as usize, &mut |row| row[0] += 0.25);
        }
    });

    // cold: a fresh cache fed the full-table stream — every batch is
    // dominated by misses and evictions
    let cold = CachedStore::with_capacity_rows(
        Box::new(MmapStore::create_ephemeral(&tmp("cold"), rows, dim)?),
        capacity_rows,
    );
    let gather_cold_ms = time_pass(&cold_batches, |b| {
        cold.gather(b, &mut out);
    });

    // warm: working set resident after one untimed pass
    let warm = CachedStore::with_capacity_rows(
        Box::new(MmapStore::create_ephemeral(&tmp("warm"), rows, dim)?),
        capacity_rows,
    );
    time_pass(&hot_batches, |b| {
        warm.gather(b, &mut out);
    });
    let gather_warm_ms = time_pass(&hot_batches, |b| {
        warm.gather(b, &mut out);
    });
    let update_warm_ms = time_pass(&hot_batches, |b| {
        for &id in b {
            warm.update_row(id as usize, &mut |row| row[0] += 0.25);
        }
    });
    let stats = warm.cache_stats().expect("cached store reports stats");

    let gather_speedup = gather_off_ms / gather_warm_ms.max(1e-9);
    let update_speedup = update_off_ms / update_warm_ms.max(1e-9);
    println!(
        "  gather  off {gather_off_ms:8.3} ms   cold {gather_cold_ms:8.3} ms   \
         warm {gather_warm_ms:8.3} ms   warm speedup {gather_speedup:5.2}x"
    );
    println!(
        "  update  off {update_off_ms:8.3} ms   warm {update_warm_ms:8.3} ms   \
         warm speedup {update_speedup:5.2}x"
    );

    let report = obj(vec![
        ("rows", Json::Num(rows as f64)),
        ("dim", Json::Num(dim as f64)),
        ("batch_ids", Json::Num(n_ids as f64)),
        ("iters", Json::Num(iters as f64)),
        ("hot_rows", Json::Num(hot_rows as f64)),
        ("capacity_rows", Json::Num(capacity_rows as f64)),
        (
            "gather_ms",
            obj(vec![
                ("mmap_uncached", Json::Num(gather_off_ms)),
                ("cache_cold", Json::Num(gather_cold_ms)),
                ("cache_warm", Json::Num(gather_warm_ms)),
            ]),
        ),
        (
            "update_ms",
            obj(vec![
                ("mmap_uncached", Json::Num(update_off_ms)),
                ("cache_warm", Json::Num(update_warm_ms)),
            ]),
        ),
        ("warm_gather_speedup", Json::Num(gather_speedup)),
        ("warm_update_speedup", Json::Num(update_speedup)),
        (
            "warm_cache",
            obj(vec![
                ("hits", Json::Num(stats.hits as f64)),
                ("misses", Json::Num(stats.misses as f64)),
                ("evictions", Json::Num(stats.evictions as f64)),
                ("write_backs", Json::Num(stats.write_backs as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_cache.json", report.to_string())?;
    println!("[wrote BENCH_cache.json]");
    Ok(())
}
