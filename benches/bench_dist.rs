//! Distributed comms benchmark: per-batch critical-path time and remote
//! traffic for the sync round-trip client vs the pipelined client vs
//! pipelined + distributed prefetch, on random (remote-heavy) and METIS
//! (locality-optimized) partitions. Writes `BENCH_dist.json`
//! (`make bench-dist`).
//!
//! Expectation: on the random partition, where a large share of every
//! batch's pulls cross TCP, pipelined+prefetch comms cut the per-batch
//! critical-path time vs the sync client — the pull wave fans out to all
//! servers at once, pushes stop blocking the trainer, and the prefetch
//! helper moves the whole pull off the critical path. On METIS most
//! traffic is a shared-memory memcpy, so the gap narrows.
//!
//! QUICK=1 shrinks the batch count for smoke runs.

use dglke::dist::{run_distributed, DistConfig, DistStats, PartitionStrategy};
use dglke::kg::Dataset;
use dglke::models::step::StepShape;
use dglke::runtime::BackendKind;
use dglke::util::json::Json;
use std::collections::BTreeMap;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn run_once(
    dataset: &Dataset,
    partition: PartitionStrategy,
    pipelined: bool,
    prefetch: bool,
    batches: usize,
    shape: StepShape,
) -> anyhow::Result<DistStats> {
    let cfg = DistConfig {
        backend: BackendKind::Native,
        shape: Some(shape),
        machines: 2,
        trainers_per_machine: 1,
        servers_per_machine: 1,
        partition,
        batches_per_trainer: batches,
        lr: 0.1,
        log_every: batches.max(1),
        pipelined,
        inflight: 8,
        prefetch,
        prefetch_depth: 2,
        seed: 7,
        ..Default::default()
    };
    let (stats, mut cluster) = run_distributed(dataset, None, &cfg)?;
    cluster.shutdown();
    Ok(stats)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QUICK").is_ok();
    let dataset = Dataset::load("fb15k-syn", 3)?;
    let shape = StepShape { batch: 256, chunks: 32, neg_k: 16, dim: 32 };
    let batches = if quick { 40 } else { 150 };

    println!(
        "dist comms bench: dataset={} entities={} shape=(b={} nc={} k={} d={}) \
         2 machines x 1 trainer, {} batches/trainer",
        dataset.name,
        dataset.n_entities(),
        shape.batch,
        shape.chunks,
        shape.neg_k,
        shape.dim,
        batches
    );

    let modes: [(&str, bool, bool); 3] =
        [("sync", false, false), ("pipelined", true, false), ("pipelined_prefetch", true, true)];
    let mut partitions = BTreeMap::new();
    for strategy in [PartitionStrategy::Random, PartitionStrategy::Metis] {
        let mut sync_ms = 0.0;
        let mut mode_objs = BTreeMap::new();
        for (name, pipelined, prefetch) in modes {
            let stats = run_once(&dataset, strategy, pipelined, prefetch, batches, shape)?;
            let batch_ms = stats.wall_secs * 1000.0 / batches as f64;
            if name == "sync" {
                sync_ms = batch_ms;
            }
            let speedup = if batch_ms > 0.0 { sync_ms / batch_ms } else { 0.0 };
            println!(
                "  {:7} {name:18} batch {batch_ms:7.3} ms  speedup {speedup:5.2}x  \
                 remote {:7.2} MB ({:5.2} MB overlapped)  locality {:.3}",
                strategy.name(),
                stats.remote_bytes as f64 / 1e6,
                stats.remote_overlapped_bytes as f64 / 1e6,
                stats.locality,
            );
            mode_objs.insert(
                name.to_string(),
                obj(vec![
                    ("batch_ms", Json::Num(batch_ms)),
                    ("speedup_vs_sync", Json::Num(speedup)),
                    ("remote_mb", Json::Num(stats.remote_bytes as f64 / 1e6)),
                    (
                        "remote_overlapped_mb",
                        Json::Num(stats.remote_overlapped_bytes as f64 / 1e6),
                    ),
                    (
                        "remote_critical_mb",
                        Json::Num(
                            stats.remote_bytes.saturating_sub(stats.remote_overlapped_bytes)
                                as f64
                                / 1e6,
                        ),
                    ),
                    ("local_mb", Json::Num(stats.local_bytes as f64 / 1e6)),
                    ("remote_requests", Json::Num(stats.remote_requests as f64)),
                    ("locality", Json::Num(stats.locality)),
                ]),
            );
        }
        partitions.insert(strategy.name().to_string(), Json::Obj(mode_objs));
    }

    let report = obj(vec![
        ("dataset", Json::Str(dataset.name.clone())),
        ("entities", Json::Num(dataset.n_entities() as f64)),
        ("machines", Json::Num(2.0)),
        ("trainers_per_machine", Json::Num(1.0)),
        ("batch", Json::Num(shape.batch as f64)),
        ("neg_k", Json::Num(shape.neg_k as f64)),
        ("dim", Json::Num(shape.dim as f64)),
        ("batches", Json::Num(batches as f64)),
        ("inflight", Json::Num(8.0)),
        ("partitions", Json::Obj(partitions)),
    ]);
    std::fs::write("BENCH_dist.json", report.to_string())?;
    println!("[wrote BENCH_dist.json]");
    Ok(())
}
