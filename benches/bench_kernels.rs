//! Fused-vs-scalar kernel throughput: pairwise score (forward) and
//! gradient (backward) GF/s per model × kernel backend × dim. Writes
//! `BENCH_kernels.json` (`make bench-kernels`) so the fused kernels' win
//! is tracked run-over-run.
//!
//! Expectation: the candidate-tiled fused forward keeps eight score
//! chains in registers and the transposed tile L1-resident, so the Dot
//! and SqDiff forwards should clear 2x over the reference triple loop at
//! production dims (the acceptance bar at dim 400); L1/L2 gain less
//! (abs/sqrt bound) and backward gains least (axpy is already
//! stride-1). Parity is not re-checked here — that is
//! `rust/tests/kernel_parity_tests.rs`'s job — but a cheap assert keeps
//! the bench honest about computing the same thing.
//!
//! QUICK=1 shrinks the shapes and pass count for smoke runs.

use dglke::models::ops;
use dglke::models::{KernelBackend, KernelScratch, ModelKind, PairwiseOp};
use dglke::util::json::Json;
use dglke::util::rng::Rng;
use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::Instant;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Median-ish timing: run `iters` passes, take the best (benches on
/// shared CI boxes see scheduling noise in one direction only).
fn best_secs(iters: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

/// FLOPs of one m×k×d pairwise forward: Dot is mul+add per element;
/// the diff-based ops add a subtract (and the |.|/sqrt is amortized).
fn fwd_flops(op: PairwiseOp, m: usize, k: usize, d: usize) -> f64 {
    let per = match op {
        PairwiseOp::Dot => 2.0,
        _ => 3.0,
    };
    per * (m * k * d) as f64
}

/// Backward moves ~2 mul + 2 add per element across both grads.
fn bwd_flops(m: usize, k: usize, d: usize) -> f64 {
    4.0 * (m * k * d) as f64
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QUICK").is_ok();
    // one training chunk's worth of scoring: m o-rows vs k candidates
    let (m, k) = if quick { (16, 256) } else { (64, 1024) };
    let iters = if quick { 5 } else { 20 };
    let dims: &[usize] = &[100, 400];
    // the four distinct pairwise ops, labeled by a representative model
    let cases: &[(ModelKind, PairwiseOp)] = &[
        (ModelKind::DistMult, PairwiseOp::Dot),
        (ModelKind::RotatE, PairwiseOp::SqDiff),
        (ModelKind::TransEL2, PairwiseOp::L2),
        (ModelKind::TransEL1, PairwiseOp::L1),
    ];

    println!("kernel bench: m={m} k={k} dims={dims:?} iters={iters}");
    let mut model_entries: Vec<(&str, Json)> = vec![];

    for &(kind, op) in cases {
        let mut dim_entries: Vec<(String, Json)> = vec![];
        for &d in dims {
            let mut rng = Rng::seed_from_u64(0xBE);
            let o: Vec<f32> = (0..m * d).map(|_| rng.gen_normal()).collect();
            let n: Vec<f32> = (0..k * d).map(|_| rng.gen_normal()).collect();
            let g: Vec<f32> = (0..m * k).map(|_| rng.gen_normal()).collect();
            let mut scores = vec![0f32; m * k];
            let mut d_o = vec![0f32; m * d];
            let mut d_n = vec![0f32; k * d];
            let mut scratch = KernelScratch::default();

            let mut fwd_gfs = BTreeMap::new();
            let mut bwd_gfs = BTreeMap::new();
            let mut check = [0f32; 2];
            for (bi, kb) in KernelBackend::ALL.iter().enumerate() {
                // untimed warmup also primes the scratch allocations
                kb.forward(op, &o, &n, d, &mut scores, &mut scratch);
                let secs = best_secs(iters, || {
                    kb.forward(op, &o, &n, d, black_box(&mut scores), &mut scratch);
                });
                check[bi] = scores[m * k - 1];
                fwd_gfs.insert(kb.name(), fwd_flops(op, m, k, d) / secs / 1e9);

                let secs = best_secs(iters, || {
                    d_o.iter_mut().for_each(|x| *x = 0.0);
                    d_n.iter_mut().for_each(|x| *x = 0.0);
                    kb.backward(op, &o, &n, d, &scores, &g, black_box(&mut d_o), &mut d_n);
                });
                bwd_gfs.insert(kb.name(), bwd_flops(m, k, d) / secs / 1e9);
            }
            assert_eq!(
                check[0].to_bits(),
                check[1].to_bits(),
                "{kind:?} d={d}: fused diverged from scalar — run kernel_parity_tests"
            );

            let score_speedup = fwd_gfs["fused"] / fwd_gfs["scalar"].max(1e-12);
            let grad_speedup = bwd_gfs["fused"] / bwd_gfs["scalar"].max(1e-12);
            println!(
                "  {:<10} d={d:<4} score {:6.2} -> {:6.2} GF/s ({score_speedup:4.2}x)   \
                 grad {:6.2} -> {:6.2} GF/s ({grad_speedup:4.2}x)",
                kind.name(),
                fwd_gfs["scalar"],
                fwd_gfs["fused"],
                bwd_gfs["scalar"],
                bwd_gfs["fused"],
            );
            dim_entries.push((
                format!("dim{d}"),
                obj(vec![
                    (
                        "score_gflops",
                        obj(vec![
                            ("scalar", Json::Num(fwd_gfs["scalar"])),
                            ("fused", Json::Num(fwd_gfs["fused"])),
                        ]),
                    ),
                    (
                        "grad_gflops",
                        obj(vec![
                            ("scalar", Json::Num(bwd_gfs["scalar"])),
                            ("fused", Json::Num(bwd_gfs["fused"])),
                        ]),
                    ),
                    ("score_speedup", Json::Num(score_speedup)),
                    ("grad_speedup", Json::Num(grad_speedup)),
                ]),
            ));
        }
        let mut dm = BTreeMap::new();
        for (key, v) in dim_entries {
            dm.insert(key, v);
        }
        model_entries.push((kind.name(), Json::Obj(dm)));
    }

    // keep the reference loops honest too: one diag pass, so a perf PR
    // that accidentally slows the positive-score path shows up in the blob
    let d = dims[dims.len() - 1];
    let mut rng = Rng::seed_from_u64(0xD0);
    let o: Vec<f32> = (0..m * d).map(|_| rng.gen_normal()).collect();
    let n: Vec<f32> = (0..m * d).map(|_| rng.gen_normal()).collect();
    let mut diag = vec![0f32; m];
    let diag_secs = best_secs(iters, || {
        ops::diag_forward(PairwiseOp::L2, &o, &n, d, black_box(&mut diag));
    });

    let report = obj(vec![
        ("m", Json::Num(m as f64)),
        ("k", Json::Num(k as f64)),
        ("iters", Json::Num(iters as f64)),
        ("quick", Json::Bool(quick)),
        ("models", {
            let mut mm = BTreeMap::new();
            for (kname, v) in model_entries {
                mm.insert(kname.to_string(), v);
            }
            Json::Obj(mm)
        }),
        ("diag_l2_gflops", Json::Num(3.0 * (m * d) as f64 / diag_secs / 1e9)),
    ]);
    std::fs::write("BENCH_kernels.json", report.to_string())?;
    println!("[wrote BENCH_kernels.json]");
    Ok(())
}
