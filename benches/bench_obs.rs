//! Observability overhead benchmark: what a span costs when tracing is
//! off (the price every hot loop pays, permanently), when it is on, and
//! what a registry counter bump costs — plus the end-to-end check, the
//! same tiny training run with obs off vs fully on. Writes
//! `BENCH_obs.json` (`make bench-obs`) so the disabled-path cost is
//! tracked run-over-run next to a host-class block.
//!
//! Expectation: the disabled path is one Relaxed load and a branch —
//! single-digit nanoseconds. The bench asserts only a very generous
//! ceiling (1 µs) so it never flakes on a loaded CI host; the number in
//! the JSON is the real signal.
//!
//! QUICK=1 shrinks iteration counts for smoke runs.

use dglke::api::{ObsSpec, ParallelMode, RunSpec, Session};
use dglke::models::step::StepShape;
use dglke::models::ModelKind;
use dglke::obs::trace::{span, start, SpanId};
use dglke::runtime::BackendKind;
use dglke::util::json::Json;
use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::Instant;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn tiny_spec(obs: ObsSpec, trace_dir: &std::path::Path) -> RunSpec {
    let mut obs = obs;
    if obs.trace {
        obs.trace_path =
            Some(trace_dir.join("bench-trace.json").to_string_lossy().into_owned());
    }
    RunSpec {
        dataset: "tiny".into(),
        model: ModelKind::TransEL2,
        backend: BackendKind::Native,
        mode: ParallelMode::Single { workers: 1, gpu: false },
        batches: 200,
        lr: 0.25,
        log_every: 50,
        async_update: false,
        shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
        seed: 5,
        obs,
        ..Default::default()
    }
}

fn train_ms(spec: RunSpec) -> anyhow::Result<f64> {
    let mut session = Session::from_spec(spec)?;
    let t = Instant::now();
    session.train()?;
    Ok(t.elapsed().as_secs_f64() * 1000.0)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QUICK").is_ok();
    let span_iters: u64 = if quick { 1_000_000 } else { 10_000_000 };
    // enabled spans land in the per-thread buffer (capacity 1<<16
    // events, 2 per span): stay under it so nothing is dropped
    let enabled_iters: u64 = 20_000;

    let dir = std::env::temp_dir().join(format!("dglke-bench-obs-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    println!("obs bench: span_iters={span_iters} enabled_iters={enabled_iters} quick={quick}");

    // disabled path: tracing off, every span() is a Relaxed load + branch
    let t = Instant::now();
    for _ in 0..span_iters {
        black_box(span(black_box(SpanId::Compute)));
    }
    let disabled_span_ns = t.elapsed().as_secs_f64() * 1e9 / span_iters as f64;
    println!("  span, tracing off   {disabled_span_ns:9.2} ns/op");
    anyhow::ensure!(
        disabled_span_ns < 1_000.0,
        "disabled span path costs {disabled_span_ns:.1} ns — the 'free when off' \
         contract (docs/OBSERVABILITY.md) is broken"
    );

    // enabled path: two timestamped buffer pushes per span
    let guard = start();
    let t = Instant::now();
    for _ in 0..enabled_iters {
        black_box(span(black_box(SpanId::Compute)));
    }
    let enabled_span_ns = t.elapsed().as_secs_f64() * 1e9 / enabled_iters as f64;
    let data = guard.finish();
    println!("  span, tracing on    {enabled_span_ns:9.2} ns/op ({} events)", 2 * enabled_iters);

    // serialization cost, while we hold a buffer worth of real events
    let t = Instant::now();
    let json = data.to_chrome_json();
    let export_ms = t.elapsed().as_secs_f64() * 1000.0;
    println!("  chrome export       {export_ms:9.3} ms ({} bytes)", json.len());

    // registry counter bump: one Relaxed fetch_add
    let counter = dglke::obs::metrics::global().counter("bench.obs.add");
    let t = Instant::now();
    for i in 0..span_iters {
        counter.add(black_box(i & 1));
    }
    let counter_add_ns = t.elapsed().as_secs_f64() * 1e9 / span_iters as f64;
    println!("  counter.add         {counter_add_ns:9.2} ns/op");

    // end to end: identical tiny run, obs fully off vs trace+metrics on
    let off_ms = train_ms(tiny_spec(ObsSpec::default(), &dir))?;
    let on_ms = train_ms(tiny_spec(
        ObsSpec { trace: true, trace_path: None, metrics: true },
        &dir,
    ))?;
    let overhead_pct = (on_ms - off_ms) / off_ms.max(1e-9) * 100.0;
    println!("  train obs off       {off_ms:9.3} ms");
    println!("  train obs on        {on_ms:9.3} ms  ({overhead_pct:+.1}%)");

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
    let report = obj(vec![
        ("span_iters", Json::Num(span_iters as f64)),
        ("enabled_iters", Json::Num(enabled_iters as f64)),
        ("disabled_span_ns", Json::Num(disabled_span_ns)),
        ("enabled_span_ns", Json::Num(enabled_span_ns)),
        ("chrome_export_ms", Json::Num(export_ms)),
        ("counter_add_ns", Json::Num(counter_add_ns)),
        (
            "train",
            obj(vec![
                ("batches", Json::Num(200.0)),
                ("off_ms", Json::Num(off_ms)),
                ("on_ms", Json::Num(on_ms)),
                ("overhead_pct", Json::Num(overhead_pct)),
            ]),
        ),
        (
            "host",
            obj(vec![
                ("cores", Json::Num(cores as f64)),
                ("arch", Json::Str(std::env::consts::ARCH.to_string())),
                ("os", Json::Str(std::env::consts::OS.to_string())),
            ]),
        ),
    ]);
    std::fs::write("BENCH_obs.json", report.to_string())?;
    println!("[wrote BENCH_obs.json]");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
