//! Prefetch-pipeline step-time benchmark: prefetch on vs off, per
//! storage backend, same spec otherwise. Writes `BENCH_prefetch.json`
//! (`make bench-prefetch`) so the pipeline's win is tracked run-over-run.
//!
//! Expectation (and what CI smoke asserts eyeballs-on): the mmap backend
//! — where gather is positioned file I/O and visibly on the critical
//! path — should see a clear speedup (>= 1.2x) from overlapping
//! sample+gather with compute; dense in-memory gathers are cheap, so
//! prefetch there is roughly a wash (it only hides the sample cost).
//!
//! QUICK=1 shrinks the table and batch count for smoke runs.

use dglke::kg::Dataset;
use dglke::models::step::StepShape;
use dglke::store::StoreConfig;
use dglke::train::worker::ModelState;
use dglke::train::{run_training, TrainConfig};
use dglke::util::json::Json;
use std::collections::BTreeMap;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn step_ms(
    dataset: &Dataset,
    shape: StepShape,
    storage: &StoreConfig,
    batches: usize,
    prefetch: bool,
) -> anyhow::Result<f64> {
    let cfg = TrainConfig {
        shape: Some(shape),
        n_workers: 1,
        batches_per_worker: batches,
        // sync updates: the honest comparison — the only overlap source
        // is the prefetch pipeline itself, and results stay byte-identical
        async_update: false,
        prefetch,
        log_every: batches.max(1),
        ..Default::default()
    };
    let state = ModelState::init_with_storage(
        dataset, cfg.model, shape.dim, cfg.lr, cfg.init_scale, 7, storage,
    )?;
    // warm one short run so page cache / allocator state is comparable
    let warm = TrainConfig { batches_per_worker: (batches / 10).max(1), ..cfg.clone() };
    run_training(dataset, &state, None, &warm)?;
    let stats = run_training(dataset, &state, None, &cfg)?;
    Ok(stats.wall_secs * 1000.0 / batches as f64)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QUICK").is_ok();
    // the entity table must dwarf the per-batch row set: the pipeline
    // re-gathers (patches) prefetched rows its own updates dirtied, so a
    // small table would put most of the gather right back on the
    // critical path. ~2.8k rows/step over 50k (quick) / 100k entities
    // keeps the patch fraction around 5-10%.
    let dataset = Dataset::load(if quick { "freebase-syn:0.5" } else { "freebase-syn:1.0" }, 3)?;
    // small chunks tilt the step toward gather (the phase prefetch
    // hides): 64 chunks × 16 negatives = 2k negative rows per batch
    let shape = StepShape { batch: 256, chunks: 64, neg_k: 16, dim: 64 };
    let batches = if quick { 80 } else { 200 };

    let tmp = std::env::temp_dir().join(format!("dglke-bench-prefetch-{}", std::process::id()));
    let configs = [
        ("dense", StoreConfig::dense()),
        ("sharded", StoreConfig::sharded(8)),
        ("mmap", StoreConfig::mmap(tmp.to_string_lossy().into_owned())),
    ];

    println!(
        "prefetch bench: dataset={} entities={} shape=(b={} nc={} k={} d={}) batches={}",
        dataset.name,
        dataset.n_entities(),
        shape.batch,
        shape.chunks,
        shape.neg_k,
        shape.dim,
        batches
    );
    let mut backends = BTreeMap::new();
    for (name, storage) in configs {
        let storage = storage.resolved()?;
        let off_ms = step_ms(&dataset, shape, &storage, batches, false)?;
        let on_ms = step_ms(&dataset, shape, &storage, batches, true)?;
        let speedup = off_ms / on_ms;
        println!(
            "  {name:8} step off {off_ms:8.3} ms   on {on_ms:8.3} ms   speedup {speedup:5.2}x"
        );
        backends.insert(
            name.to_string(),
            obj(vec![
                ("prefetch_off_step_ms", Json::Num(off_ms)),
                ("prefetch_on_step_ms", Json::Num(on_ms)),
                ("speedup", Json::Num(speedup)),
            ]),
        );
    }

    let report = obj(vec![
        ("dataset", Json::Str(dataset.name.clone())),
        ("entities", Json::Num(dataset.n_entities() as f64)),
        ("batch", Json::Num(shape.batch as f64)),
        ("neg_k", Json::Num(shape.neg_k as f64)),
        ("dim", Json::Num(shape.dim as f64)),
        ("batches", Json::Num(batches as f64)),
        ("depth", Json::Num(2.0)),
        ("backends", Json::Obj(backends)),
    ]);
    std::fs::write("BENCH_prefetch.json", report.to_string())?;
    println!("[wrote BENCH_prefetch.json]");
    std::fs::remove_dir_all(&tmp).ok();
    Ok(())
}
