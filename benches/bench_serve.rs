//! Serving latency/throughput benchmark: snapshot cold-open plus first
//! batch vs warm steady state through the worker pool, per kernel
//! backend. Writes `BENCH_serve.json` (`make bench-serve`) so request
//! latency (p50/p95/p99 per batch) and QPS are tracked run-over-run,
//! alongside the serve pool's own `obs::metrics` histograms
//! (`ServeHandle::latencies`) and a host-class block (core count,
//! arch/os) so numbers from different machines aren't compared blindly.
//!
//! Expectation: cold open is dominated by manifest validation + mmap
//! setup and stays in single-digit milliseconds regardless of table size
//! (zero-copy — no table read happens until the first query); warm fused
//! serving beats warm scalar serving because candidate rows stream
//! store→tile once instead of being staged through a gather buffer.
//!
//! QUICK=1 shrinks the table and pass count for smoke runs.

use dglke::kg::vocab::Vocab;
use dglke::models::{KernelBackend, ModelKind};
use dglke::serve::{
    vocab_hash, CheckpointManifest, Query, ServeConfig, ServeHandle, ServeScratch, Snapshot,
    SnapshotOptions, TableInfo, FORMAT_VERSION,
};
use dglke::util::bytes::f32_as_bytes;
use dglke::util::json::Json;
use dglke::util::rng::Rng;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Write one table file in checkpoint framing: [u64 n_values][LE f32...].
fn write_table(path: &Path, rows: usize, dim: usize, rng: &mut Rng) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(&((rows * dim) as u64).to_le_bytes())?;
    let mut row = vec![0f32; dim];
    for _ in 0..rows {
        for v in row.iter_mut() {
            *v = rng.gen_f32() - 0.5;
        }
        w.write_all(f32_as_bytes(&row))?;
    }
    w.flush()?;
    Ok(())
}

/// Fabricate a format-2 checkpoint directly (no training run): the bench
/// prices serving, not SGD.
fn make_checkpoint(dir: &Path, n: usize, m: usize, dim: usize) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut rng = Rng::seed_from_u64(17);
    write_table(&dir.join("entities.f32"), n, dim, &mut rng)?;
    write_table(&dir.join("relations.f32"), m, dim, &mut rng)?;
    let manifest = CheckpointManifest {
        format_version: FORMAT_VERSION,
        model: ModelKind::TransEL2,
        dataset: "bench-synth".to_string(),
        dim,
        rel_dim: dim,
        n_entities: n,
        n_relations: m,
        seed: 17,
        entity_vocab_hash: vocab_hash(&Vocab::synthetic("e", n)),
        relation_vocab_hash: vocab_hash(&Vocab::synthetic("r", m)),
        entities: TableInfo::single("entities.f32", n, dim),
        relations: TableInfo::single("relations.f32", m, dim),
    };
    manifest.save(dir)
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * p).round() as usize;
    sorted_ms[idx]
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QUICK").is_ok();
    let n_entities: usize = if quick { 20_000 } else { 100_000 };
    let n_relations: usize = 200;
    let dim: usize = if quick { 32 } else { 64 };
    let batches: usize = if quick { 32 } else { 128 };
    let batch_queries: usize = if quick { 64 } else { 256 };
    let threads: usize = 4;
    let topk: usize = 10;

    let dir =
        std::env::temp_dir().join(format!("dglke-bench-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    make_checkpoint(&dir, n_entities, n_relations, dim)?;

    let mut rng = Rng::seed_from_u64(23);
    let traffic: Vec<Vec<Query>> = (0..batches)
        .map(|_| {
            (0..batch_queries)
                .map(|i| {
                    let e = rng.gen_index(n_entities) as u64;
                    let r = rng.gen_index(n_relations) as u64;
                    if i % 2 == 0 {
                        Query::tail(e, r)
                    } else {
                        Query::head(e, r)
                    }
                })
                .collect()
        })
        .collect();

    println!(
        "serve bench: entities={n_entities} relations={n_relations} dim={dim} \
         batches={batches}x{batch_queries} threads={threads} topk={topk}"
    );

    // cold: open (manifest validation + mmap, no table read) then the
    // first batch, which faults the touched pages in
    let t = Instant::now();
    let cold = Snapshot::open(&dir)?;
    let open_ms = t.elapsed().as_secs_f64() * 1000.0;
    let mut scratch = ServeScratch::default();
    let t = Instant::now();
    let first = cold.query_batch(&traffic[0], topk, &mut scratch)?;
    let first_batch_ms = t.elapsed().as_secs_f64() * 1000.0;
    anyhow::ensure!(first.len() == batch_queries, "cold batch answered");
    drop(cold);
    println!("  cold    open {open_ms:8.3} ms   first batch {first_batch_ms:8.3} ms");

    let mut kernel_reports = Vec::new();
    for kernels in [KernelBackend::Scalar, KernelBackend::Fused] {
        let snap = Snapshot::open_with(&dir, &SnapshotOptions { cache_mb: None, kernels })?;
        let handle = ServeHandle::start(
            snap,
            &ServeConfig { threads, batch: batch_queries, topk },
        );
        // one untimed pass warms the page cache and worker scratch
        for b in traffic.iter().take(4.min(batches)) {
            handle.submit(b, topk)?;
        }
        let mut lat_ms: Vec<f64> = Vec::with_capacity(batches);
        let t_all = Instant::now();
        for b in &traffic {
            let t = Instant::now();
            let got = handle.submit(b, topk)?;
            lat_ms.push(t.elapsed().as_secs_f64() * 1000.0);
            debug_assert_eq!(got.len(), batch_queries);
        }
        let wall_s = t_all.elapsed().as_secs_f64();
        let qps = (batches * batch_queries) as f64 / wall_s.max(1e-9);
        lat_ms.sort_by(|a, b| a.total_cmp(b));
        let p50 = percentile(&lat_ms, 0.50);
        let p95 = percentile(&lat_ms, 0.95);
        let p99 = percentile(&lat_ms, 0.99);
        let name = match kernels {
            KernelBackend::Scalar => "scalar",
            _ => "fused",
        };
        println!(
            "  {name:6}  batch p50 {p50:8.3} ms   p95 {p95:8.3} ms   p99 {p99:8.3} ms \
             {qps:10.0} qps"
        );
        // the handle's own log-2 histograms (serve.*_ns): bucket-upper-
        // bound percentiles, so coarser than the sorted-sample figures
        // above but directly comparable to `--metrics-out` snapshots
        let lats = handle.latencies();
        let histo = |h: &dglke::obs::metrics::HistogramSnapshot| {
            obj(vec![
                ("count", Json::Num(h.count as f64)),
                ("p50_ns", Json::Num(h.percentile(0.50))),
                ("p95_ns", Json::Num(h.percentile(0.95))),
                ("p99_ns", Json::Num(h.percentile(0.99))),
                ("mean_ns", Json::Num(h.mean())),
            ])
        };
        kernel_reports.push((
            name,
            obj(vec![
                ("batch_p50_ms", Json::Num(p50)),
                ("batch_p95_ms", Json::Num(p95)),
                ("batch_p99_ms", Json::Num(p99)),
                ("qps", Json::Num(qps)),
                ("queue", histo(&lats.queue_ns)),
                ("score", histo(&lats.score_ns)),
                ("batch", histo(&lats.batch_ns)),
                ("query", histo(&lats.query_ns)),
            ]),
        ));
        handle.shutdown();
    }

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
    let report = obj(vec![
        ("entities", Json::Num(n_entities as f64)),
        ("relations", Json::Num(n_relations as f64)),
        ("dim", Json::Num(dim as f64)),
        ("batches", Json::Num(batches as f64)),
        ("batch_queries", Json::Num(batch_queries as f64)),
        ("threads", Json::Num(threads as f64)),
        ("topk", Json::Num(topk as f64)),
        ("checkpoint_seed", Json::Num(17.0)),
        ("traffic_seed", Json::Num(23.0)),
        (
            "host",
            obj(vec![
                ("cores", Json::Num(cores as f64)),
                ("arch", Json::Str(std::env::consts::ARCH.to_string())),
                ("os", Json::Str(std::env::consts::OS.to_string())),
            ]),
        ),
        (
            "cold",
            obj(vec![
                ("open_ms", Json::Num(open_ms)),
                ("first_batch_ms", Json::Num(first_batch_ms)),
            ]),
        ),
        ("warm", Json::Obj(kernel_reports.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
    ]);
    std::fs::write("BENCH_serve.json", report.to_string())?;
    println!("[wrote BENCH_serve.json]");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
