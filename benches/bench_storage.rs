//! Storage-backend microbenchmark: gather and scatter-update (AdaGrad)
//! latency for the dense / sharded / mmap [`EmbeddingStore`] backends on
//! the same table shape and id distribution. Writes `BENCH_storage.json`
//! so the perf trajectory of the storage layer is tracked run-over-run
//! (`make bench-smoke`).
//!
//! QUICK=1 shrinks the table for smoke runs.

use dglke::store::{EmbeddingStore, SparseAdagrad, StoreConfig};
use dglke::util::json::Json;
use dglke::util::rng::Rng;
use std::collections::BTreeMap;
use std::time::Instant;

fn time_ms(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warm
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() * 1000.0 / iters as f64
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QUICK").is_ok();
    let rows: usize = if quick { 20_000 } else { 200_000 };
    let dim: usize = 64;
    let n_ids: usize = 2048;
    let iters = if quick { 8 } else { 32 };

    let mut rng = Rng::seed_from_u64(7);
    // unique ids: the trainers pre-accumulate duplicates before the
    // optimizer, so the hot path sees unique rows
    let ids: Vec<u64> =
        rng.sample_distinct(rows, n_ids).into_iter().map(|x| x as u64).collect();
    let grads: Vec<f32> = (0..n_ids * dim).map(|_| rng.gen_normal() * 0.01).collect();

    let tmp = std::env::temp_dir().join(format!("dglke-bench-storage-{}", std::process::id()));
    let configs = [
        ("dense", StoreConfig::dense()),
        ("sharded", StoreConfig::sharded(8)),
        ("mmap", StoreConfig::mmap(tmp.to_string_lossy().into_owned())),
    ];

    println!("storage microbench: rows={rows} dim={dim} batch_ids={n_ids} iters={iters}");
    let mut backends = BTreeMap::new();
    for (name, cfg) in configs {
        let cfg = cfg.resolved()?;
        let table = cfg.uniform(&format!("bench_{name}"), rows, dim, 0.4, 1)?;
        let opt = SparseAdagrad::with_storage(&cfg, &format!("bench_{name}.opt"), rows, 0.05)?;
        let mut out = vec![0f32; n_ids * dim];

        let gather_ms = time_ms(iters, || table.gather(&ids, &mut out));
        let update_ms = time_ms(iters, || opt.apply(&*table, &ids, &grads));
        println!("  {name:8} gather {gather_ms:9.3} ms   adagrad update {update_ms:9.3} ms");

        backends.insert(
            name.to_string(),
            obj(vec![
                ("gather_ms", Json::Num(gather_ms)),
                ("update_ms", Json::Num(update_ms)),
                ("resident_bytes", Json::Num(table.resident_bytes() as f64)),
            ]),
        );
    }

    let report = obj(vec![
        ("rows", Json::Num(rows as f64)),
        ("dim", Json::Num(dim as f64)),
        ("batch_ids", Json::Num(n_ids as f64)),
        ("iters", Json::Num(iters as f64)),
        ("backends", Json::Obj(backends)),
    ]);
    std::fs::write("BENCH_storage.json", report.to_string())?;
    println!("[wrote BENCH_storage.json]");
    std::fs::remove_dir_all(&tmp).ok();
    Ok(())
}
