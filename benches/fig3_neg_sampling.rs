//! Fig 3: effect of joint negative sampling (TransE on FB15k).
//!
//! Paper: joint sampling gives ~4× on 1 GPU (tensor-op efficiency) and
//! ~40× on 8 GPUs (data movement). Here: identical sampling work per
//! positive (k=64 per corruption side), chunked GEMM-form scoring
//! (`fig3_joint`, cs=64) vs independent per-positive negatives lowered
//! with naive broadcasting (`fig3_naive`, cs=1).

use dglke::benchkit::*;
use dglke::kg::Dataset;
use dglke::models::ModelKind;

fn main() -> anyhow::Result<()> {
    let _manifest = load_manifest_or_exit();
    let dataset = std::sync::Arc::new(Dataset::load("fb15k-syn", 0)?);
    println!("Fig 3: joint vs naive negative sampling — transe_l2, fb15k-syn");
    println!("{:>12} {:>8} {:>16} {:>16}", "sampling", "workers", "step (ms, sim)", "h2d MB/step");

    let mut rows = Vec::new();
    for workers in [1usize, 8] {
        let mut joint_ms = 0.0;
        for (name, tag, batches) in
            [("joint", "fig3_joint", bench_batches(30)), ("naive", "fig3_naive", bench_batches(6))]
        {
            let (stats, ms) = timed_run(
                &dataset,
                ModelKind::TransEL2,
                tag,
                workers,
                batches,
                true,
                |_| {},
            )?;
            let h2d_mb = stats.h2d_bytes as f64 / 1e6 / stats.total_batches as f64;
            println!("{name:>12} {workers:>8} {ms:>16.1} {h2d_mb:>16.2}");
            if name == "joint" {
                joint_ms = ms;
            } else {
                println!(
                    "             -> joint speedup at {workers} worker(s): {:.1}x  (paper: ~4x @1GPU, ~40x @8GPU)",
                    ms / joint_ms
                );
            }
            rows.push(format!("{name},{workers},{ms:.2},{h2d_mb:.3}"));
        }
    }
    write_results_csv("fig3", "sampling,workers,step_ms,h2d_mb_per_step", &rows);
    Ok(())
}
