//! Fig 4: speedup of the multi-GPU optimizations — sync vs async entity
//! updates (§3.5) vs async + relation partitioning (§3.4).
//!
//! Paper: async gives ~40% on Freebase; rel_part adds >10% for embedding
//! models and much more for TransR.
//!
//! GPU-step model (documented in EXPERIMENTS.md §Testbed): this testbed's
//! XLA-CPU step is ~100× slower than the paper's V100 on the same batch,
//! which would drown the update/transfer effects Fig 4 is about. We
//! therefore reconstruct the simulated per-batch GPU step from *measured*
//! components:
//!
//!   compute_gpu  = measured XLA step / CAL      (CAL=100 calibrates one
//!                  simulated V100 to DGL-KE's reported ~1M triplets/s)
//!   transfer     = ledgered critical-path bytes / 12 GB/s (PCIe 3.0 x16)
//!   update_cpu   = measured CPU-side sparse-AdaGrad + grad-split time
//!
//!   sync:             step = compute_gpu + transfer + update_cpu
//!   async (§3.5):     step = max(compute_gpu, update_cpu) + transfer
//!   async+rel_part:   same, relations pinned on-GPU (no relation bytes)

use dglke::benchkit::*;
use dglke::kg::Dataset;
use dglke::models::ModelKind;

const CAL: f64 = 100.0; // CPU→V100 compute calibration
const PCIE_GBPS: f64 = 12.0;

struct Components {
    compute_ms: f64,
    update_ms: f64,
    transfer_ms: f64,
}

fn components(
    dataset: &std::sync::Arc<Dataset>,
    model: ModelKind,
    rel_part: bool,
    batches: usize,
) -> anyhow::Result<Components> {
    // one measured run per configuration; phases are aggregated thread-CPU
    // seconds across workers
    let (stats, _) = timed_run(dataset, model, "default", 2, batches, true, |spec| {
        spec.async_update = false; // measure the update cost explicitly
        spec.relation_partition = rel_part;
    })?;
    let per_batch = |phase: &str| -> f64 {
        stats
            .phases
            .iter()
            .find(|(p, _)| p == phase)
            .map(|(_, s)| s * 1000.0 / stats.total_batches as f64)
            .unwrap_or(0.0)
    };
    let transfer_bytes = (stats.h2d_bytes + stats.d2h_bytes) as f64 / stats.total_batches as f64;
    Ok(Components {
        compute_ms: per_batch("compute") / CAL,
        update_ms: per_batch("update") + per_batch("gather"),
        transfer_ms: transfer_bytes / (PCIE_GBPS * 1e9) * 1000.0,
    })
}

fn main() -> anyhow::Result<()> {
    let _manifest = load_manifest_or_exit();
    println!("Fig 4: simulated V100 per-batch step time (model in bench header)");
    println!(
        "{:>10} {:>18} {:>9} {:>9} {:>9} {:>16}",
        "model", "dataset", "sync ms", "async ms", "+relpart", "speedup vs sync"
    );
    let mut rows = Vec::new();
    for (ds_name, batches) in [("fb15k-syn", 12), ("freebase-syn:0.02", 12)] {
        let dataset = std::sync::Arc::new(Dataset::load(ds_name, 0)?);
        for model in [
            ModelKind::TransEL2,
            ModelKind::DistMult,
            ModelKind::ComplEx,
            ModelKind::RotatE,
            ModelKind::TransR,
        ] {
            let b = bench_batches(batches);
            let dense_rel = components(&dataset, model, false, b)?;
            let pinned_rel = components(&dataset, model, true, b)?;

            let sync = dense_rel.compute_ms + dense_rel.transfer_ms + dense_rel.update_ms;
            let async_ = dense_rel.compute_ms.max(dense_rel.update_ms) + dense_rel.transfer_ms;
            let relp = pinned_rel.compute_ms.max(pinned_rel.update_ms) + pinned_rel.transfer_ms;
            println!(
                "{:>10} {:>18} {:>9.2} {:>9.2} {:>9.2} {:>7.2}x /{:>5.2}x",
                model.name(),
                ds_name,
                sync,
                async_,
                relp,
                sync / async_,
                sync / relp
            );
            rows.push(format!(
                "{},{ds_name},{sync:.3},{async_:.3},{relp:.3}",
                model.name()
            ));
        }
    }
    write_results_csv("fig4", "model,dataset,sync_ms,async_ms,async_relpart_ms", &rows);
    Ok(())
}
