//! Fig 5: multi-GPU scaling (1/2/4/8 simulated GPUs, plus 16 workers on
//! 8 GPUs for the Freebase-style dataset).
//!
//! Paper: near-linear scaling; 16 processes on 8 GPUs is fastest on
//! Freebase.

use dglke::benchkit::*;
use dglke::kg::Dataset;
use dglke::models::ModelKind;

fn main() -> anyhow::Result<()> {
    let _manifest = load_manifest_or_exit();
    println!("Fig 5: multi-GPU scaling (simulated parallel clock)");
    println!("{:>14} {:>10} {:>8} {:>14} {:>10}", "dataset", "model", "workers", "triplets/s", "speedup");
    let mut rows = Vec::new();
    for (ds_name, model) in
        [("fb15k-syn", ModelKind::TransEL2), ("freebase-syn:0.02", ModelKind::TransEL2)]
    {
        let dataset = std::sync::Arc::new(Dataset::load(ds_name, 0)?);
        let mut base = 0.0f64;
        for workers in [1usize, 2, 4, 8, 16] {
            let (stats, _) = timed_run(
                &dataset,
                model,
                "default",
                workers,
                bench_batches(24),
                true,
                |_| {},
            )?;
            let tps = stats.triplets_per_sec;
            if workers == 1 {
                base = tps;
            }
            println!(
                "{:>14} {:>10} {:>8} {:>14.0} {:>9.2}x",
                ds_name,
                model.name(),
                workers,
                tps,
                tps / base
            );
            rows.push(format!("{ds_name},{},{workers},{tps:.0},{:.3}", model.name(), tps / base));
        }
    }
    write_results_csv("fig5", "dataset,model,workers,triplets_per_sec,speedup", &rows);
    Ok(())
}
