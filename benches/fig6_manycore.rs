//! Fig 6: many-core CPU scaling (paper: 48-core r5dn, near-linear).
//!
//! CPU mode: no transfer ledger; embeddings live in shared memory and
//! workers are trainer threads. The simulated-parallel clock (max worker
//! thread-CPU busy time + sync) stands in for multi-core wall-clock on
//! this 1-core testbed — see EXPERIMENTS.md §Testbed.

use dglke::benchkit::*;
use dglke::kg::Dataset;
use dglke::models::ModelKind;

fn main() -> anyhow::Result<()> {
    let _manifest = load_manifest_or_exit();
    println!("Fig 6: many-core CPU scaling");
    println!("{:>14} {:>10} {:>8} {:>14} {:>10}", "dataset", "model", "threads", "triplets/s", "speedup");
    let mut rows = Vec::new();
    for (ds_name, model) in
        [("fb15k-syn", ModelKind::TransEL2), ("fb15k-syn", ModelKind::DistMult)]
    {
        let dataset = std::sync::Arc::new(Dataset::load(ds_name, 0)?);
        let mut base = 0.0f64;
        for threads in [1usize, 2, 4, 8, 16, 32, 48] {
            let (stats, _) = timed_run(
                &dataset,
                model,
                "default",
                threads,
                bench_batches(16),
                false,
                |spec| spec.sync_interval = 8, // the paper's periodic sync
            )?;
            let tps = stats.triplets_per_sec;
            if threads == 1 {
                base = tps;
            }
            println!(
                "{:>14} {:>10} {:>8} {:>14.0} {:>9.2}x",
                ds_name,
                model.name(),
                threads,
                tps,
                tps / base
            );
            rows.push(format!("{ds_name},{},{threads},{tps:.0},{:.3}", model.name(), tps / base));
        }
    }
    write_results_csv("fig6", "dataset,model,threads,triplets_per_sec,speedup", &rows);
    Ok(())
}
