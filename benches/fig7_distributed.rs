//! Fig 7: distributed training — single machine vs 4-machine cluster with
//! random vs METIS partitioning.
//!
//! Paper: METIS ≈3.5× faster than single machine and ~20% faster than
//! random partitioning (communication-bound). We report real wall-clock
//! (TCP loopback) plus the remote-traffic ledger — the quantity METIS
//! minimizes. Both arms run through the `api::Session`.

use dglke::api::{ParallelMode, Session};
use dglke::benchkit::*;
use dglke::dist::PartitionStrategy;
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let _manifest = load_manifest_or_exit();
    let dataset = Arc::new(Dataset::load("freebase-syn:0.02", 0)?);
    println!("Fig 7: distributed training on {}", dataset.summary());
    let model = ModelKind::TransEL2;
    let batches = bench_batches(16);
    let mut rows = Vec::new();

    // single machine baseline (8 workers, shared memory)
    let (stats, _) = timed_run(&dataset, model, "default", 8, batches, false, |_| {})?;
    println!(
        "{:>22} wall {:>8.2}s  sim-parallel {:>8.2}s  remote 0 MB",
        "single-machine", stats.wall_secs, stats.sim_parallel_secs
    );
    rows.push(format!("single,{:.3},{:.3},0,1.0", stats.wall_secs, stats.sim_parallel_secs));

    for strategy in [PartitionStrategy::Random, PartitionStrategy::Metis] {
        let mut spec = bench_spec(&dataset, model, "default", 8, batches, false);
        spec.mode = ParallelMode::Distributed {
            machines: 4,
            trainers: 2,
            servers: 2,
            partition: strategy,
            local_negatives: true,
        };
        let mut session = Session::with_dataset(spec, dataset.clone())?;
        let report = session.train()?;
        println!(
            "{:>22} wall {:>8.2}s  locality {:.3}  remote {:>8.1} MB  ({} reqs)",
            format!("4-machine {}", strategy.name()),
            report.wall_secs,
            report.locality,
            report.remote_bytes as f64 / 1e6,
            report.remote_requests
        );
        rows.push(format!(
            "{},{:.3},{:.3},{:.1},{:.3}",
            strategy.name(),
            report.wall_secs,
            report.wall_secs,
            report.remote_bytes as f64 / 1e6,
            report.locality
        ));
    }
    write_results_csv("fig7", "config,wall_secs,sim_secs,remote_mb,locality", &rows);
    Ok(())
}
