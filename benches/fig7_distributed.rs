//! Fig 7: distributed training — single machine vs 4-machine cluster with
//! random vs METIS partitioning.
//!
//! Paper: METIS ≈3.5× faster than single machine and ~20% faster than
//! random partitioning (communication-bound). We report real wall-clock
//! (TCP loopback) plus the remote-traffic ledger — the quantity METIS
//! minimizes.

use dglke::benchkit::*;
use dglke::dist::{run_distributed, DistConfig, PartitionStrategy};
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use dglke::runtime::BackendKind;

fn main() -> anyhow::Result<()> {
    let manifest = load_manifest_or_exit();
    let dataset = Dataset::load("freebase-syn:0.02", 0)?;
    println!("Fig 7: distributed training on {}", dataset.summary());
    let model = ModelKind::TransEL2;
    let batches = bench_batches(16);
    let mut rows = Vec::new();

    // single machine baseline (8 workers, shared memory)
    let (stats, _) = timed_run(&dataset, &manifest, model, "default", 8, batches, false, |_| {})?;
    println!(
        "{:>22} wall {:>8.2}s  sim-parallel {:>8.2}s  remote 0 MB",
        "single-machine", stats.wall_secs, stats.sim_parallel_secs
    );
    rows.push(format!("single,{:.3},{:.3},0,1.0", stats.wall_secs, stats.sim_parallel_secs));

    for (name, strategy) in
        [("random", PartitionStrategy::Random), ("metis", PartitionStrategy::Metis)]
    {
        let cfg = DistConfig {
            model,
            backend: BackendKind::Xla,
            artifact_tag: "default".into(),
            machines: 4,
            trainers_per_machine: 2,
            servers_per_machine: 2,
            partition: strategy,
            local_negatives: true,
            batches_per_trainer: batches,
            lr: 0.25,
            ..Default::default()
        };
        let (stats, mut cluster) = run_distributed(&dataset, Some(&manifest), &cfg)?;
        cluster.shutdown();
        println!(
            "{:>22} wall {:>8.2}s  locality {:.3}  remote {:>8.1} MB  ({} reqs)",
            format!("4-machine {name}"),
            stats.wall_secs,
            stats.locality,
            stats.remote_bytes as f64 / 1e6,
            stats.remote_requests
        );
        rows.push(format!(
            "{name},{:.3},{:.3},{:.1},{:.3}",
            stats.wall_secs,
            stats.wall_secs,
            stats.remote_bytes as f64 / 1e6,
            stats.locality
        ));
    }
    write_results_csv("fig7", "config,wall_secs,sim_secs,remote_mb,locality", &rows);
    Ok(())
}
