//! Fig 8: DGL-KE vs PyTorch-BigGraph-style baseline on the Freebase-style
//! dataset (paper: DGL-KE ≈2× faster).
//!
//! The PBG baseline pays its dense-relation-weight cost (a full
//! read-modify-write pass over the relation table per batch) and its
//! random 2D block schedule; everything else is shared code. The DGL-KE
//! arm runs through the `api::Session`.

use dglke::baselines::{run_pbg, PbgConfig};
use dglke::benchkit::*;
use dglke::kg::Dataset;
use dglke::models::step::StepShape;
use dglke::models::ModelKind;
use dglke::runtime::BackendKind;
use dglke::train::worker::ModelState;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let manifest = load_manifest_or_exit();
    let dataset = Arc::new(Dataset::load("freebase-syn:0.02", 0)?);
    println!("Fig 8: DGL-KE vs PBG-style on {}", dataset.summary());
    println!("{:>10} {:>12} {:>12} {:>10}", "model", "dglke s", "pbg s", "speedup");
    let mut rows = Vec::new();
    for model in [ModelKind::TransEL2, ModelKind::DistMult, ModelKind::ComplEx] {
        let batches = bench_batches(16);
        let (dgl_stats, _) = timed_run(&dataset, model, "default", 2, batches, false, |_| {})?;

        let art = manifest.find_train(model.name(), "logistic", "default")?;
        let pbg_cfg = PbgConfig {
            model,
            backend: BackendKind::Xla,
            artifact_tag: "default".into(),
            shape: Some(StepShape {
                batch: art.batch,
                chunks: art.chunks,
                neg_k: art.neg_k,
                dim: art.dim,
            }),
            n_workers: 2,
            buckets: 4,
            batches_per_worker: batches,
            lr: 0.25,
            ..Default::default()
        };
        let state = ModelState::init_with(&dataset, model, art.dim, 0.1, 0.37, 0);
        let pbg_stats = run_pbg(&dataset, &state, Some(&manifest), &pbg_cfg)?;
        // compare total busy work under the same clock: wall on this
        // single-core box is proportional to total compute for both
        println!(
            "{:>10} {:>12.2} {:>12.2} {:>9.2}x",
            model.name(),
            dgl_stats.wall_secs,
            pbg_stats.wall_secs,
            pbg_stats.wall_secs / dgl_stats.wall_secs
        );
        rows.push(format!(
            "{},{:.3},{:.3}",
            model.name(),
            dgl_stats.wall_secs,
            pbg_stats.wall_secs
        ));
    }
    write_results_csv("fig8", "model,dglke_secs,pbg_secs", &rows);
    Ok(())
}
