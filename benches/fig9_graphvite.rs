//! Fig 9/10: DGL-KE vs GraphVite-style baseline on FB15k- and WN18-style
//! datasets (paper: DGL-KE ≈5× faster to the same accuracy, because
//! episodic training converges much slower).
//!
//! Protocol here: identical total batch budget; report wall time AND the
//! final filtered MRR — DGL-KE should match/beat MRR in the same or less
//! time, while GraphVite pays episode copies and staleness. The DGL-KE arm
//! runs through the `api::Session` (eval requested in the spec).

use dglke::api::{EvalProtocolSpec, EvalSpec};
use dglke::baselines::{run_graphvite, GraphViteConfig};
use dglke::benchkit::*;
use dglke::eval::{evaluate, EvalConfig};
use dglke::kg::Dataset;
use dglke::models::step::StepShape;
use dglke::models::ModelKind;
use dglke::runtime::BackendKind;
use dglke::train::worker::ModelState;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let manifest = load_manifest_or_exit();
    println!("Fig 9/10: DGL-KE vs GraphVite-style (equal batch budget)");
    println!(
        "{:>12} {:>10} {:>10} {:>8} {:>10} {:>8}",
        "dataset", "model", "system", "time s", "MRR", "Hit@10"
    );
    let mut rows = Vec::new();
    let eval_cfg = EvalConfig { max_triplets: 200, n_threads: 4, ..Default::default() };
    for ds_name in ["fb15k-syn", "wn18-syn"] {
        let dataset = Arc::new(Dataset::load(ds_name, 0)?);
        for model in [ModelKind::TransEL2, ModelKind::DistMult] {
            let batches = bench_batches(60);
            let art = manifest.find_train(model.name(), "logistic", "default")?;

            // DGL-KE through the session API (spec-requested eval)
            let (report, _) = timed_run(&dataset, model, "default", 1, batches, false, |spec| {
                spec.eval = Some(EvalSpec {
                    protocol: EvalProtocolSpec::FullFiltered,
                    max_triplets: 200,
                    n_threads: 4,
                });
            })?;
            let m = report.metrics.expect("eval requested in spec");
            let dgl_time = report.wall_secs;
            println!(
                "{ds_name:>12} {:>10} {:>10} {:>8.1} {:>10.3} {:>8.3}",
                model.name(),
                "dglke",
                dgl_time,
                m.mrr,
                m.hit10
            );
            rows.push(format!(
                "{ds_name},{},dglke,{dgl_time:.2},{:.4},{:.4}",
                model.name(),
                m.mrr,
                m.hit10
            ));

            // GraphVite-style
            let gv_cfg = GraphViteConfig {
                model,
                backend: BackendKind::Xla,
                artifact_tag: "default".into(),
                shape: Some(StepShape {
                    batch: art.batch,
                    chunks: art.chunks,
                    neg_k: art.neg_k,
                    dim: art.dim,
                }),
                n_workers: 1,
                episode_entities: 4096,
                episode_batches: 30,
                total_batches_per_worker: batches,
                lr: 0.25,
                ..Default::default()
            };
            let gv_state = ModelState::init_with(&dataset, model, art.dim, 0.1, 0.37, 0);
            let t = std::time::Instant::now();
            run_graphvite(&dataset, &gv_state, Some(&manifest), &gv_cfg)?;
            let gv_time = t.elapsed().as_secs_f64();
            let gm = evaluate(
                model,
                &gv_state.entities,
                &gv_state.relations,
                &dataset,
                &dataset.test,
                &eval_cfg,
            );
            println!(
                "{ds_name:>12} {:>10} {:>10} {:>8.1} {:>10.3} {:>8.3}",
                model.name(),
                "graphvite",
                gv_time,
                gm.mrr,
                gm.hit10
            );
            rows.push(format!(
                "{ds_name},{},graphvite,{gv_time:.2},{:.4},{:.4}",
                model.name(),
                gm.mrr,
                gm.hit10
            ));
        }
    }
    write_results_csv("fig9_10", "dataset,model,system,time_secs,mrr,hit10", &rows);
    Ok(())
}
