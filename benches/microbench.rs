//! Microbenchmarks of the L3 hot-path components, used by the §Perf pass:
//! artifact execution, gather, grad split/accumulate, sampler, optimizer,
//! and KVStore pull/push (local + TCP).

use dglke::benchkit::load_manifest_or_exit;
use dglke::kg::Dataset;
use dglke::models::step::StepInputs;
use dglke::models::ModelKind;
use dglke::runtime::{TrainExecutor, XlaRuntime};
use dglke::sampler::{NegativeConfig, NegativeSampler, PositiveSampler};
use dglke::store::{DenseStore, SparseAdagrad};
use dglke::train::batch::{split_grads, BatchBuffers};
use std::time::Instant;

fn time_ms(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warm
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() * 1000.0 / iters as f64
}

fn main() -> anyhow::Result<()> {
    let manifest = load_manifest_or_exit();
    let dataset = Dataset::load("fb15k-syn", 0)?;
    let model = ModelKind::TransEL2;
    let art = manifest.find_train(model.name(), "logistic", "default")?;
    let rt = XlaRuntime::cpu()?;
    let exe = TrainExecutor::new(&rt, art)?;
    let shape = exe.shape;
    let rel_dim = exe.rel_dim;

    let entities = DenseStore::uniform(dataset.n_entities(), shape.dim, 0.4, 1);
    let relations = DenseStore::uniform(dataset.n_relations(), rel_dim, 0.4, 2);
    let ent_opt = SparseAdagrad::new(dataset.n_entities(), 0.1);

    let mut pos = PositiveSampler::over_all(&dataset.train, 3);
    let mut neg = NegativeSampler::new(
        NegativeConfig { k: shape.neg_k, chunk_size: shape.chunk_size(), ..Default::default() },
        dataset.n_entities(),
        4,
    );
    let mut idx = Vec::new();
    pos.next_batch(shape.batch, &mut idx);
    let batch = neg.assemble(&dataset.train, &idx);
    let mut buf = BatchBuffers::new(&shape, rel_dim);
    buf.gather(&batch, &entities, &relations);
    let grads = exe.step(&buf.inputs())?;
    let (ent_g, _) = split_grads(&batch, &grads, shape.dim, rel_dim);

    println!("microbench (default transe_l2 shape: b={} nc={} k={} d={})",
        shape.batch, shape.chunks, shape.neg_k, shape.dim);
    let ms = time_ms(8, || {
        pos.next_batch(shape.batch, &mut idx);
        let _ = neg.assemble(&dataset.train, &idx);
    });
    println!("  sample+assemble      {ms:9.3} ms");
    let ms = time_ms(8, || {
        buf.gather(&batch, &entities, &relations);
    });
    println!("  gather               {ms:9.3} ms");
    let ms = time_ms(8, || {
        let inp = StepInputs {
            h: &buf.h,
            r: &buf.r,
            t: &buf.t,
            neg_h: &buf.neg_h,
            neg_t: &buf.neg_t,
        };
        exe.step(&inp).unwrap();
    });
    println!("  xla train step       {ms:9.3} ms");
    let ms = time_ms(8, || {
        let _ = split_grads(&batch, &grads, shape.dim, rel_dim);
    });
    println!("  grad split+accum     {ms:9.3} ms");
    let ms = time_ms(8, || {
        ent_opt.apply(&entities, &ent_g.ids, &ent_g.rows);
    });
    println!("  adagrad apply        {ms:9.3} ms");

    // KVStore round trips
    let entity_machine: Vec<u32> = (0..dataset.n_entities()).map(|i| (i % 2) as u32).collect();
    let cluster = dglke::kvstore::KvCluster::start(
        &entity_machine,
        dataset.n_relations(),
        2,
        1,
        shape.dim,
        rel_dim,
        0.1,
        0.4,
        9,
    )?;
    let mut client = cluster.client(0)?;
    let ids: Vec<u64> = (0..1024u64).collect();
    let mut out = vec![0f32; 1024 * shape.dim];
    let ms = time_ms(8, || {
        client.pull(dglke::kvstore::TableId::Entities, &ids, shape.dim, &mut out).unwrap();
    });
    println!("  kv pull 1024 rows    {ms:9.3} ms (half local, half TCP)");
    let ms = time_ms(8, || {
        client.push(dglke::kvstore::TableId::Entities, &ids, shape.dim, &out).unwrap();
    });
    println!("  kv push 1024 rows    {ms:9.3} ms");
    Ok(())
}
