//! Distributed training demo (paper §6.3): a 4-machine KVStore cluster
//! (servers reachable via shared memory locally and TCP remotely),
//! comparing METIS vs random graph partitioning on communication volume
//! and accuracy — all through the typed session API.
//!
//!     make artifacts && cargo run --release --example distributed_cluster

use dglke::api::{EvalProtocolSpec, EvalSpec, ParallelMode, RunSpec, Session};
use dglke::dist::PartitionStrategy;
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use dglke::runtime::{artifacts, BackendKind};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    if !artifacts::available() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let dataset = Arc::new(Dataset::load("freebase-syn:0.05", 3)?);
    println!("dataset: {}", dataset.summary());

    for strategy in [PartitionStrategy::Random, PartitionStrategy::Metis] {
        println!("\n=== 4 machines x 2 trainers, {} partitioning ===", strategy.name());
        let spec = RunSpec {
            dataset: dataset.name.clone(),
            model: ModelKind::DistMult,
            backend: BackendKind::Xla,
            mode: ParallelMode::Distributed {
                machines: 4,
                trainers: 2,
                servers: 2,
                partition: strategy,
                local_negatives: true,
            },
            batches: 25,
            lr: 0.3,
            eval: Some(EvalSpec {
                protocol: EvalProtocolSpec::Sampled { uniform: 500, degree: 500 },
                max_triplets: 150,
                n_threads: 4,
            }),
            seed: 3,
            ..Default::default()
        };
        let mut session = Session::with_dataset(spec, dataset.clone())?;
        let report = session.train()?;
        println!(
            "locality {:.3} | local {:.1}MB | remote {:.1}MB over TCP ({} requests) | wall {:.1}s",
            report.locality,
            report.local_bytes as f64 / 1e6,
            report.remote_bytes as f64 / 1e6,
            report.remote_requests,
            report.wall_secs
        );
        if let Some(m) = &report.metrics {
            println!("accuracy: {}", m.row());
        }
    }
    Ok(())
}
