//! Distributed training demo (paper §6.3): a 4-machine KVStore cluster
//! (servers reachable via shared memory locally and TCP remotely),
//! comparing METIS vs random graph partitioning on communication volume
//! and accuracy.
//!
//!     make artifacts && cargo run --release --example distributed_cluster

use dglke::dist::{run_distributed, DistConfig, PartitionStrategy};
use dglke::eval::{evaluate, EvalConfig, EvalProtocol};
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use dglke::runtime::{artifacts, BackendKind, Manifest};

fn main() -> anyhow::Result<()> {
    if !artifacts::available() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let manifest = Manifest::load(&artifacts::default_dir())?;
    let dataset = Dataset::load("freebase-syn:0.05", 3)?;
    println!("dataset: {}", dataset.summary());

    let model = ModelKind::DistMult;
    for strategy in [PartitionStrategy::Random, PartitionStrategy::Metis] {
        let name = match strategy {
            PartitionStrategy::Random => "random",
            PartitionStrategy::Metis => "METIS",
        };
        println!("\n=== 4 machines x 2 trainers, {} partitioning ===", name);
        let cfg = DistConfig {
            model,
            backend: BackendKind::Xla,
            artifact_tag: "default".into(),
            machines: 4,
            trainers_per_machine: 2,
            servers_per_machine: 2,
            partition: strategy,
            local_negatives: true,
            batches_per_trainer: 25,
            lr: 0.3,
            seed: 3,
            ..Default::default()
        };
        let (stats, mut cluster) = run_distributed(&dataset, Some(&manifest), &cfg)?;
        println!(
            "locality {:.3} | local {:.1}MB | remote {:.1}MB over TCP ({} requests) | wall {:.1}s",
            stats.locality,
            stats.local_bytes as f64 / 1e6,
            stats.remote_bytes as f64 / 1e6,
            stats.remote_requests,
            stats.wall_secs
        );

        let ents = cluster.dump_entities(dataset.n_entities(), 128);
        let rels = cluster.dump_relations(dataset.n_relations(), 128);
        cluster.shutdown();
        let m = evaluate(
            model,
            &ents,
            &rels,
            &dataset,
            &dataset.test,
            &EvalConfig {
                protocol: EvalProtocol::Sampled { uniform: 500, degree: 500 },
                max_triplets: 150,
                n_threads: 4,
                seed: 3,
            },
        );
        println!("accuracy: {}", m.row());
    }
    Ok(())
}
