//! End-to-end driver (DESIGN.md deliverable): train a ~100M-parameter
//! KGE model on a Freebase-shaped synthetic graph for a few hundred
//! steps through the full three-layer stack, logging the loss curve and
//! throughput, then evaluate with the paper's sampled protocol.
//!
//! 100M parameters ≈ 780k entities × d=128 (+ relations). The run is
//! recorded in EXPERIMENTS.md §End-to-end. The custom generated dataset is
//! attached to a `Session` via `Session::with_dataset`.
//!
//!     make artifacts && cargo run --release --example freebase_e2e

use dglke::api::{EvalProtocolSpec, EvalSpec, ParallelMode, RunSpec, Session};
use dglke::kg::generator::GeneratorConfig;
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use dglke::runtime::{artifacts, BackendKind};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    if !artifacts::available() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }

    // Freebase-shaped synthetic graph sized for ~100M parameters at d=128.
    let steps: usize = std::env::var("E2E_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    let gen = GeneratorConfig {
        n_entities: 780_000,
        n_relations: 8_000,
        n_edges: 2_000_000,
        relation_zipf: 1.0,
        ..GeneratorConfig::freebase_syn(1.0, 7)
    };
    println!("generating freebase-shaped KG ({} entities, {} edges)...", gen.n_entities, gen.n_edges);
    let t = std::time::Instant::now();
    let dataset = Arc::new(Dataset::synthetic("freebase-e2e", &gen, 7));
    println!("generated in {:.1}s: {}", t.elapsed().as_secs_f64(), dataset.summary());

    let workers = 4;
    let spec = RunSpec {
        dataset: dataset.name.clone(),
        model: ModelKind::TransEL2,
        backend: BackendKind::Xla,
        mode: ParallelMode::Single { workers, gpu: true },
        batches: steps / workers,
        lr: 0.3,
        neg_degree_frac: 0.5,
        sync_interval: 50,
        log_every: 10,
        eval: Some(EvalSpec {
            protocol: EvalProtocolSpec::Sampled { uniform: 1000, degree: 1000 },
            max_triplets: 200,
            n_threads: 4,
        }),
        seed: 7,
        ..Default::default()
    };
    let mut session = Session::with_dataset(spec, dataset.clone())?;
    println!(
        "model: {} — {:.1}M parameters ({} entities x d={} + {} relations)",
        session.spec().model.name(),
        session.n_params() as f64 / 1e6,
        dataset.n_entities(),
        session.dim(),
        dataset.n_relations()
    );
    assert!(session.n_params() >= 100_000_000, "e2e run must exercise >=100M params");

    println!("training {} steps on {} workers (async updates, rel-part, degree negatives)...", steps, workers);
    let report = session.train()?;
    println!("loss curve:");
    for (step, loss) in &report.loss_curve {
        println!("  step {step:5}  loss {loss:.4}");
    }
    println!(
        "done: {} batches, wall {:.1}s, sim-parallel {:.1}s, {:.0} triplets/s",
        report.total_batches, report.wall_secs, report.sim_parallel_secs, report.triplets_per_sec
    );
    println!(
        "transfers: h2d {:.0}MB, d2h {:.0}MB, overlapped {:.0}MB",
        report.h2d_bytes as f64 / 1e6,
        report.d2h_bytes as f64 / 1e6,
        report.overlapped_bytes as f64 / 1e6
    );
    if let Some(m) = &report.metrics {
        println!("result (paper protocol 2: 1000 uniform + 1000 degree-based): {}", m.row());
    }
    Ok(())
}
