//! End-to-end driver (DESIGN.md deliverable): train a ~100M-parameter
//! KGE model on a Freebase-shaped synthetic graph for a few hundred
//! steps through the full three-layer stack, logging the loss curve and
//! throughput, then evaluate with the paper's sampled protocol.
//!
//! 100M parameters ≈ 780k entities × d=128 (+ relations). The run is
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example freebase_e2e

use dglke::eval::{evaluate, EvalConfig, EvalProtocol};
use dglke::kg::generator::GeneratorConfig;
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use dglke::runtime::{artifacts, BackendKind, Manifest};
use dglke::train::worker::ModelState;
use dglke::train::{run_training, Hardware, TrainConfig};

fn main() -> anyhow::Result<()> {
    if !artifacts::available() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let manifest = Manifest::load(&artifacts::default_dir())?;

    // Freebase-shaped synthetic graph sized for ~100M parameters at d=128.
    let steps: usize = std::env::var("E2E_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    let gen = GeneratorConfig {
        n_entities: 780_000,
        n_relations: 8_000,
        n_edges: 2_000_000,
        relation_zipf: 1.0,
        ..GeneratorConfig::freebase_syn(1.0, 7)
    };
    println!("generating freebase-shaped KG ({} entities, {} edges)...", gen.n_entities, gen.n_edges);
    let t = std::time::Instant::now();
    let dataset = Dataset::synthetic("freebase-e2e", &gen, 7);
    println!("generated in {:.1}s: {}", t.elapsed().as_secs_f64(), dataset.summary());

    let model = ModelKind::TransEL2;
    let workers = 4;
    let cfg = TrainConfig {
        model,
        backend: BackendKind::Xla,
        artifact_tag: "default".into(),
        n_workers: workers,
        batches_per_worker: steps / workers,
        lr: 0.3,
        neg_degree_frac: 0.5,
        hardware: Hardware::Gpu { pcie_gbps: 12.0 },
        sync_interval: 50,
        log_every: 10,
        seed: 7,
        ..Default::default()
    };
    let state = ModelState::init(&dataset, model, 128, &cfg);
    println!(
        "model: {} — {:.1}M parameters ({} entities x d=128 + {} relations)",
        model.name(),
        state.n_params() as f64 / 1e6,
        dataset.n_entities(),
        dataset.n_relations()
    );
    assert!(state.n_params() >= 100_000_000, "e2e run must exercise >=100M params");

    println!("training {} steps on {} workers (async updates, rel-part, degree negatives)...", steps, workers);
    let stats = run_training(&dataset, &state, Some(&manifest), &cfg)?;
    println!("loss curve:");
    for (step, loss) in &stats.loss_curve {
        println!("  step {step:5}  loss {loss:.4}");
    }
    println!(
        "done: {} batches, wall {:.1}s, sim-parallel {:.1}s, {:.0} triplets/s",
        stats.total_batches, stats.wall_secs, stats.sim_parallel_secs, stats.triplets_per_sec
    );
    println!(
        "transfers: h2d {:.0}MB, d2h {:.0}MB, overlapped {:.0}MB",
        stats.h2d_bytes as f64 / 1e6,
        stats.d2h_bytes as f64 / 1e6,
        stats.overlapped_bytes as f64 / 1e6
    );

    println!("evaluating (paper protocol 2: 1000 uniform + 1000 degree-based negatives)...");
    let m = evaluate(
        model,
        &state.entities,
        &state.relations,
        &dataset,
        &dataset.test,
        &EvalConfig {
            protocol: EvalProtocol::Sampled { uniform: 1000, degree: 1000 },
            max_triplets: 200,
            n_threads: 4,
            seed: 7,
        },
    );
    println!("result: {}", m.row());
    Ok(())
}
