//! Walkthrough of the paper's §3.3–§3.5 multi-GPU optimizations on a
//! small dataset: joint negative sampling, async gradient overlap, and
//! relation partitioning — printing per-optimization step times and
//! transfer volumes (simulated 8-GPU mode).
//!
//!     make artifacts && cargo run --release --example multi_gpu_optimizations

use dglke::benchkit::timed_run;
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use dglke::runtime::{artifacts, Manifest};

fn main() -> anyhow::Result<()> {
    if !artifacts::available() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let manifest = Manifest::load(&artifacts::default_dir())?;
    let dataset = Dataset::load("fb15k-syn", 1)?;
    println!("dataset: {}", dataset.summary());
    let model = ModelKind::TransEL2;

    println!("\n1) joint vs naive negative sampling (Fig 3, 8 sim-GPUs):");
    for (name, tag, batches) in [("joint", "fig3_joint", 12usize), ("naive", "fig3_naive", 4)] {
        let (stats, ms) = timed_run(&dataset, &manifest, model, tag, 8, batches, true, |_| {})?;
        println!(
            "   {name:6} {ms:8.1} ms/step, {:.1} MB h2d per step",
            stats.h2d_bytes as f64 / 1e6 / stats.total_batches as f64
        );
    }

    println!("\n2) async gradient overlap + relation partitioning (Fig 4):");
    for (name, async_up, rel_part) in
        [("sync", false, false), ("async", true, false), ("async+rel_part", true, true)]
    {
        let (stats, ms) = timed_run(&dataset, &manifest, model, "default", 8, 10, true, |cfg| {
            cfg.async_update = async_up;
            cfg.relation_partition = rel_part;
        })?;
        println!(
            "   {name:16} {ms:8.1} ms/step  (critical-path transfer {:.1} MB, overlapped {:.1} MB)",
            (stats.h2d_bytes + stats.d2h_bytes) as f64 / 1e6,
            stats.overlapped_bytes as f64 / 1e6
        );
    }
    println!("\nsee benches/fig*_*.rs for the full figure reproductions");
    Ok(())
}
