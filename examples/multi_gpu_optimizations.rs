//! Walkthrough of the paper's §3.3–§3.5 multi-GPU optimizations on a
//! small dataset: joint negative sampling, async gradient overlap, and
//! relation partitioning — printing per-optimization step times and
//! transfer volumes (simulated 8-GPU mode).
//!
//!     make artifacts && cargo run --release --example multi_gpu_optimizations

use dglke::benchkit::{load_manifest_or_exit, timed_run};
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let _manifest = load_manifest_or_exit();
    let dataset = Arc::new(Dataset::load("fb15k-syn", 1)?);
    println!("dataset: {}", dataset.summary());
    let model = ModelKind::TransEL2;

    println!("\n1) joint vs naive negative sampling (Fig 3, 8 sim-GPUs):");
    for (name, tag, batches) in [("joint", "fig3_joint", 12usize), ("naive", "fig3_naive", 4)] {
        let (report, ms) = timed_run(&dataset, model, tag, 8, batches, true, |_| {})?;
        println!(
            "   {name:6} {ms:8.1} ms/step, {:.1} MB h2d per step",
            report.h2d_bytes as f64 / 1e6 / report.total_batches as f64
        );
    }

    println!("\n2) async gradient overlap + relation partitioning (Fig 4):");
    for (name, async_up, rel_part) in
        [("sync", false, false), ("async", true, false), ("async+rel_part", true, true)]
    {
        let (report, ms) = timed_run(&dataset, model, "default", 8, 10, true, |spec| {
            spec.async_update = async_up;
            spec.relation_partition = rel_part;
        })?;
        println!(
            "   {name:16} {ms:8.1} ms/step  (critical-path transfer {:.1} MB, overlapped {:.1} MB)",
            (report.h2d_bytes + report.d2h_bytes) as f64 / 1e6,
            report.overlapped_bytes as f64 / 1e6
        );
    }
    println!("\nsee benches/fig*_*.rs for the full figure reproductions");
    Ok(())
}
