//! Quickstart: train a TransE model on a small synthetic KG through the
//! typed session API, then evaluate link prediction and export the
//! embeddings.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks the full stack: `RunSpec` → `Session` → dataset → sampler →
//! gather → PJRT-compiled artifact (Pallas/JAX lowered to HLO) → sparse
//! AdaGrad → filtered link-prediction evaluation → `Report` JSON.
//!
//! A native-backend variant of this run (same dataset/model/schedule, no
//! artifacts needed) is described declaratively by
//! `examples/specs/quickstart.json`:
//!
//!     dglke train --config examples/specs/quickstart.json

use dglke::api::{EvalProtocolSpec, EvalSpec, Session};
use dglke::models::ModelKind;
use dglke::runtime::{artifacts, BackendKind};

fn main() -> anyhow::Result<()> {
    if !artifacts::available() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }

    // a small FB15k-shaped synthetic KG (see kg::generator for why the
    // synthetic stand-in is learnable)
    let mut session = Session::builder()
        .dataset("fb15k-syn")
        .model(ModelKind::TransEL2)
        .backend(BackendKind::Xla)
        .workers(2)
        .batches(250) // ~1 epoch per worker
        .lr(0.3)
        .sync_interval(100)
        .log_every(25)
        .eval(EvalSpec {
            protocol: EvalProtocolSpec::FullFiltered,
            max_triplets: 500,
            n_threads: 4,
        })
        .seed(42)
        .build()?;

    println!("dataset: {}", session.dataset().summary());
    println!(
        "training {} ({:.1}M parameters)...",
        session.spec().model.name(),
        session.n_params() as f64 / 1e6
    );

    let report = session.train()?;
    println!(
        "trained {} batches in {:.1}s ({:.0} triplets/s)",
        report.total_batches, report.wall_secs, report.triplets_per_sec
    );
    for (step, loss) in &report.loss_curve {
        println!("  step {step:5}  loss {loss:.4}");
    }
    if let Some(m) = &report.metrics {
        println!("result (filtered ranking protocol): {}", m.row());
    }

    // the whole run — spec, stats, metrics — as one JSON document
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/quickstart_report.json", report.to_json_string())?;
    println!("[wrote results/quickstart_report.json]");

    // export embeddings for downstream serving, and prove they round-trip
    let ckpt = std::path::Path::new("results/quickstart_ckpt");
    session.export_embeddings(ckpt)?;
    session.load_checkpoint(ckpt)?;
    println!("[exported + reloaded {}]", ckpt.display());
    Ok(())
}
