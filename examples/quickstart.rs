//! Quickstart: train a TransE model on a small synthetic KG with the
//! production (AOT XLA) path, then evaluate link prediction.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks the full stack: dataset → sampler → gather → PJRT-compiled
//! artifact (Pallas/JAX lowered to HLO) → sparse AdaGrad → filtered
//! link-prediction evaluation.

use dglke::eval::{evaluate, EvalConfig};
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use dglke::runtime::{artifacts, BackendKind, Manifest};
use dglke::train::worker::ModelState;
use dglke::train::{run_training, TrainConfig};

fn main() -> anyhow::Result<()> {
    if !artifacts::available() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let manifest = Manifest::load(&artifacts::default_dir())?;

    // a small FB15k-shaped synthetic KG (see kg::generator for why the
    // synthetic stand-in is learnable)
    let dataset = Dataset::load("fb15k-syn", 42)?;
    println!("dataset: {}", dataset.summary());

    let model = ModelKind::TransEL2;
    let cfg = TrainConfig {
        model,
        backend: BackendKind::Xla,
        artifact_tag: "default".into(),
        n_workers: 2,
        batches_per_worker: 250, // ~1 epoch
        lr: 0.3,
        sync_interval: 100,
        log_every: 25,
        seed: 42,
        ..Default::default()
    };
    let state = ModelState::init(&dataset, model, 128, &cfg);
    println!("training {} ({:.1}M parameters)...", model.name(), state.n_params() as f64 / 1e6);
    let stats = run_training(&dataset, &state, Some(&manifest), &cfg)?;
    println!(
        "trained {} batches in {:.1}s ({:.0} triplets/s)",
        stats.total_batches, stats.wall_secs, stats.triplets_per_sec
    );
    for (step, loss) in &stats.loss_curve {
        println!("  step {step:5}  loss {loss:.4}");
    }

    println!("evaluating (filtered ranking protocol)...");
    let m = evaluate(
        model,
        &state.entities,
        &state.relations,
        &dataset,
        &dataset.test,
        &EvalConfig { max_triplets: 300, n_threads: 4, ..Default::default() },
    );
    println!("result: {}", m.row());
    Ok(())
}
