"""Build-time JAX/Pallas layer of dglke-rs. Never imported at runtime."""
