"""AOT lowering: JAX/Pallas model graphs → HLO *text* artifacts + manifest.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Python never runs at training time — the Rust coordinator loads these
files through PJRT and owns the hot path.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as M
from . import shapes as S


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train(model: str, loss: str, shape: S.TrainShape, adv_temp, kernels="pallas"):
    step = M.make_train_step(model, loss, shape.chunks, adv_temp=adv_temp, kernels=kernels)
    args = M.example_train_args(model, shape)
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]
    return jax.jit(step).lower(*specs)


def lower_eval(model: str, side: str, shape: S.EvalShape):
    fn = M.make_eval_score(model, side)
    args = M.example_eval_args(model, shape)
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]
    return jax.jit(fn).lower(*specs)


def emit(out_dir: str, key: str, hlo: str) -> str:
    fname = f"{key}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(hlo)
    return fname


def build_manifest(out_dir: str, models, losses, include_tiny=True, adv_temp=None):
    entries = []
    for model in models:
        for loss in losses:
            shapes = [("default", S.default_train_shape(model))]
            if include_tiny:
                shapes.append(("tiny", S.tiny_train_shape(model)))
            if model == "transe_l2" and loss == "logistic":
                # Fig 3 pair: identical work per positive, chunked vs
                # independent negatives (chunk size 1 = naive sampling)
                shapes.append(("fig3_joint", S.TrainShape(batch=1024, chunks=16, neg_k=64, dim=128)))
                shapes.append(("fig3_naive", S.TrainShape(batch=1024, chunks=1024, neg_k=64, dim=128)))
            for tag, shape in shapes:
                key = shape.key(model, loss)
                # the naive-sampling baseline is lowered with naive jnp
                # broadcast scoring (no chunked GEMM kernels)
                kernels = "ref" if tag == "fig3_naive" else "pallas"
                print(f"lowering {key} ...", flush=True)
                hlo = to_hlo_text(lower_train(model, loss, shape, adv_temp, kernels=kernels))
                fname = emit(out_dir, key, hlo)
                entries.append(
                    {
                        "key": key,
                        "file": fname,
                        "kind": "train",
                        "model": model,
                        "loss": loss,
                        "tag": tag,
                        "batch": shape.batch,
                        "chunks": shape.chunks,
                        "neg_k": shape.neg_k,
                        "dim": shape.dim,
                        "rel_dim": S.rel_dim(model, shape.dim),
                        "adv_temp": adv_temp,
                        "sha256": hashlib.sha256(hlo.encode()).hexdigest()[:16],
                    }
                )
        for side in ("tail", "head"):
            shapes = [("default", S.default_eval_shape(model))]
            if include_tiny:
                shapes.append(("tiny", S.tiny_eval_shape(model)))
            for tag, shape in shapes:
                key = shape.key(model, side)
                print(f"lowering {key} ...", flush=True)
                hlo = to_hlo_text(lower_eval(model, side, shape))
                fname = emit(out_dir, key, hlo)
                entries.append(
                    {
                        "key": key,
                        "file": fname,
                        "kind": f"eval_{side}",
                        "model": model,
                        "tag": tag,
                        "m": shape.m,
                        "cands": shape.cands,
                        "dim": shape.dim,
                        "rel_dim": S.rel_dim(model, shape.dim),
                        "sha256": hashlib.sha256(hlo.encode()).hexdigest()[:16],
                    }
                )
    return entries


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument(
        "--models",
        default=",".join(S.MODELS),
        help="comma-separated subset of models to lower",
    )
    p.add_argument("--losses", default="logistic", help="logistic,margin")
    p.add_argument("--no-tiny", action="store_true", help="skip tiny test shapes")
    p.add_argument("--adv-temp", type=float, default=None)
    args = p.parse_args()

    models = [m.strip() for m in args.models.split(",") if m.strip()]
    for m in models:
        if m not in S.MODELS:
            print(f"unknown model {m!r}; known: {S.MODELS}", file=sys.stderr)
            return 1
    losses = [l.strip() for l in args.losses.split(",") if l.strip()]

    os.makedirs(args.out, exist_ok=True)
    entries = build_manifest(
        args.out, models, losses, include_tiny=not args.no_tiny, adv_temp=args.adv_temp
    )
    manifest = {
        "version": 1,
        "jax_version": jax.__version__,
        "artifacts": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts to {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
