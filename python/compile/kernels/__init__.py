"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from . import pairwise, ref  # noqa: F401
