"""Layer-1 Pallas kernels: grouped pairwise scoring.

The paper's §3.3 insight is that joint negative sampling turns negative
scoring into a *generalized matrix multiplication*: a chunk of ``cs``
positives shares ``k`` negatives, so the score block is a ``[cs, k]``
contraction over the embedding dimension ``d``. On GPU the authors hand
this to cuBLAS; on TPU the same contraction is exactly one MXU systolic
pass per ``[CS_T, d] x [d, K_T]`` tile pair.

Kernels:

* :func:`bmm` — batched matmul ``[nc, m, kk] x [nc, kk, n] -> [nc, m, n]``.
  This single kernel carries the Dot/SqDiff/L2 score families *and* their
  backward passes (both VJPs of a matmul are matmuls).
* :func:`pairwise_l1` (+ backward kernels) — TransE-L1 has no GEMM form
  (sum of absolute differences); its kernel streams ``d``-strips through
  VMEM and accumulates ``|o - n|`` tiles, the TPU analogue of the paper's
  fused elementwise path.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that
the Rust runtime executes. The BlockSpec structure is still the real TPU
schedule; DESIGN.md §Perf carries the VMEM/MXU analysis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: sized for TPU VMEM (see DESIGN.md §Perf). For small inputs
# the tile clamps to the full extent. TILE_N=256 measured 19% faster than
# 128 on the CPU-PJRT path (fewer interpret-mode grid steps) and still fits
# VMEM on TPU — see EXPERIMENTS.md §Perf.
TILE_M = 128
TILE_N = 256


def _tile(extent: int, tile: int) -> int:
    """Largest divisor-tile <= tile (extents here are powers of two)."""
    t = min(extent, tile)
    while extent % t != 0:
        t -= 1
    return t


# ---------------------------------------------------------------------------
# bmm: batched matmul
# ---------------------------------------------------------------------------


def _bmm_kernel(a_ref, b_ref, o_ref):
    # a_ref: [1, TM, kk], b_ref: [1, kk, TN] resident in VMEM; one MXU
    # contraction per grid step.
    a = a_ref[0]
    b = b_ref[0]
    o_ref[0] = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def bmm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched matmul via Pallas: [nc, m, kk] x [nc, kk, n] -> [nc, m, n]."""
    nc, m, kk = a.shape
    nc2, kk2, n = b.shape
    assert nc == nc2 and kk == kk2, (a.shape, b.shape)
    tm = _tile(m, TILE_M)
    tn = _tile(n, TILE_N)
    grid = (nc, m // tm, n // tn)
    return pl.pallas_call(
        _bmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, kk), lambda c, i, j: (c, i, 0)),
            pl.BlockSpec((1, kk, tn), lambda c, i, j: (c, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, tm, tn), lambda c, i, j: (c, i, j)),
        out_shape=jax.ShapeDtypeStruct((nc, m, n), jnp.float32),
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# pairwise L1: scores[c, i, j] = -sum_d |o[c,i,d] - n[c,j,d]|
# ---------------------------------------------------------------------------


def _l1_kernel(o_ref, n_ref, s_ref):
    o = o_ref[0]  # [TM, d]
    n = n_ref[0]  # [TN, d]
    diff = jnp.abs(o[:, None, :] - n[None, :, :])  # [TM, TN, d] in VMEM
    s_ref[0] = -jnp.sum(diff, axis=-1)


def pairwise_l1_fwd(o: jax.Array, n: jax.Array) -> jax.Array:
    """[nc, cs, d], [nc, k, d] -> [nc, cs, k] of -Σ|o - n|."""
    nc, cs, d = o.shape
    nc2, k, d2 = n.shape
    assert nc == nc2 and d == d2
    # smaller tiles than bmm: the |o-n| intermediate is TM*TN*d floats
    tm = _tile(cs, 32)
    tn = _tile(k, 64)
    grid = (nc, cs // tm, k // tn)
    return pl.pallas_call(
        _l1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d), lambda c, i, j: (c, i, 0)),
            pl.BlockSpec((1, tn, d), lambda c, i, j: (c, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tm, tn), lambda c, i, j: (c, i, j)),
        out_shape=jax.ShapeDtypeStruct((nc, cs, k), jnp.float32),
        interpret=True,
    )(o, n)


def _l1_bwd_do_kernel(o_ref, n_ref, g_ref, do_ref):
    # do[c,i,d] = -Σ_j g[c,i,j] · sign(o[c,i,d] - n[c,j,d])
    o = o_ref[0]  # [TM, d]
    n = n_ref[0]  # [k, d]
    g = g_ref[0]  # [TM, k]
    sign = jnp.sign(o[:, None, :] - n[None, :, :])  # [TM, k, d]
    do_ref[0] = -jnp.einsum("ij,ijd->id", g, sign)


def _l1_bwd_dn_kernel(o_ref, n_ref, g_ref, dn_ref):
    # dn[c,j,d] = Σ_i g[c,i,j] · sign(o[c,i,d] - n[c,j,d])
    o = o_ref[0]  # [cs, d]
    n = n_ref[0]  # [TN, d]
    g = g_ref[0]  # [cs, TN]
    sign = jnp.sign(o[:, None, :] - n[None, :, :])  # [cs, TN, d]
    dn_ref[0] = jnp.einsum("ij,ijd->jd", g, sign)


def pairwise_l1_bwd(o, n, g):
    nc, cs, d = o.shape
    k = n.shape[1]
    tm = _tile(cs, 32)
    do = pl.pallas_call(
        _l1_bwd_do_kernel,
        grid=(nc, cs // tm),
        in_specs=[
            pl.BlockSpec((1, tm, d), lambda c, i: (c, i, 0)),
            pl.BlockSpec((1, k, d), lambda c, i: (c, 0, 0)),
            pl.BlockSpec((1, tm, k), lambda c, i: (c, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tm, d), lambda c, i: (c, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, cs, d), jnp.float32),
        interpret=True,
    )(o, n, g)
    tn = _tile(k, 64)
    dn = pl.pallas_call(
        _l1_bwd_dn_kernel,
        grid=(nc, k // tn),
        in_specs=[
            pl.BlockSpec((1, cs, d), lambda c, j: (c, 0, 0)),
            pl.BlockSpec((1, tn, d), lambda c, j: (c, j, 0)),
            pl.BlockSpec((1, cs, tn), lambda c, j: (c, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, tn, d), lambda c, j: (c, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, k, d), jnp.float32),
        interpret=True,
    )(o, n, g)
    return do, dn


# ---------------------------------------------------------------------------
# Differentiable pairwise ops built on the kernels
# ---------------------------------------------------------------------------

L2_EPS = 1e-12  # must match rust models::L2_EPS


@jax.custom_vjp
def pairwise_dot(o, n):
    """[nc,cs,d] x [nc,k,d] -> [nc,cs,k] of o·n (MXU kernel fwd + bwd)."""
    return bmm(o, jnp.swapaxes(n, 1, 2))


def _dot_fwd(o, n):
    return pairwise_dot(o, n), (o, n)


def _dot_bwd(res, g):
    o, n = res
    do = bmm(g, n)  # [nc,cs,k] x [nc,k,d]
    dn = bmm(jnp.swapaxes(g, 1, 2), o)  # [nc,k,cs] x [nc,cs,d]
    return do, dn


pairwise_dot.defvjp(_dot_fwd, _dot_bwd)


def _sq_norms(x):
    return jnp.sum(x * x, axis=-1)


@jax.custom_vjp
def pairwise_sqdiff(o, n):
    """-(‖o‖² - 2 o·n + ‖n‖²) via the bmm kernel (quadratic expansion)."""
    cross = bmm(o, jnp.swapaxes(n, 1, 2))
    return -(_sq_norms(o)[:, :, None] - 2.0 * cross + _sq_norms(n)[:, None, :])


def _sqdiff_fwd(o, n):
    return pairwise_sqdiff(o, n), (o, n)


def _sqdiff_bwd(res, g):
    o, n = res
    # df/do = -2(o - n): do_i = -2(o_i Σ_j g_ij - Σ_j g_ij n_j)
    row = jnp.sum(g, axis=2)  # [nc, cs]
    col = jnp.sum(g, axis=1)  # [nc, k]
    do = -2.0 * (o * row[:, :, None] - bmm(g, n))
    dn = 2.0 * (bmm(jnp.swapaxes(g, 1, 2), o) - n * col[:, :, None])
    return do, dn


pairwise_sqdiff.defvjp(_sqdiff_fwd, _sqdiff_bwd)


@jax.custom_vjp
def pairwise_l2(o, n):
    """-sqrt(‖o-n‖² + eps), matching rust PairwiseOp::L2."""
    sq = -pairwise_sqdiff(o, n)
    return -jnp.sqrt(sq + L2_EPS)


def _l2_fwd(o, n):
    f = pairwise_l2(o, n)
    return f, (o, n, f)


def _l2_bwd(res, g):
    o, n, f = res
    # df/do = (o-n)/f (f negative) → with w = g / (-f):
    w = g / (-f)
    row = jnp.sum(w, axis=2)
    col = jnp.sum(w, axis=1)
    # df/do = -(o-n)/L ⇒ do_i = -Σ_j w_ij (o_i - n_j)
    # df/dn = +(o-n)/L ⇒ dn_j = +Σ_i w_ij (o_i - n_j)
    do = -(o * row[:, :, None] - bmm(w, n))
    dn = bmm(jnp.swapaxes(w, 1, 2), o) - n * col[:, :, None]
    return do, dn


pairwise_l2.defvjp(_l2_fwd, _l2_bwd)


@jax.custom_vjp
def pairwise_l1(o, n):
    """-Σ|o - n| via the dedicated L1 kernels."""
    return pairwise_l1_fwd(o, n)


def _l1_fwd(o, n):
    return pairwise_l1_fwd(o, n), (o, n)


def _l1_bwd(res, g):
    o, n = res
    return pairwise_l1_bwd(o, n, g)


pairwise_l1.defvjp(_l1_fwd, _l1_bwd)


PAIRWISE = {
    "dot": pairwise_dot,
    "sqdiff": pairwise_sqdiff,
    "l2": pairwise_l2,
    "l1": pairwise_l1,
}
