"""Pure-jnp oracles for the Pallas pairwise kernels.

These are the correctness reference: ``python/tests/test_kernels.py``
sweeps shapes with hypothesis and asserts the Pallas path matches these
to float tolerance, for values *and* gradients.
"""

import jax.numpy as jnp

L2_EPS = 1e-12


def ref_dot(o, n):
    return jnp.einsum("bid,bjd->bij", o, n)


def ref_sqdiff(o, n):
    diff = o[:, :, None, :] - n[:, None, :, :]
    return -jnp.sum(diff * diff, axis=-1)


def ref_l2(o, n):
    return -jnp.sqrt(-ref_sqdiff(o, n) + L2_EPS)


def ref_l1(o, n):
    diff = o[:, :, None, :] - n[:, None, :, :]
    return -jnp.sum(jnp.abs(diff), axis=-1)


REF = {
    "dot": ref_dot,
    "sqdiff": ref_sqdiff,
    "l2": ref_l2,
    "l1": ref_l1,
}
