"""Layer-2: JAX score functions, losses and train-step graphs for the KGE
model zoo (paper Table 1), built on the Layer-1 Pallas pairwise kernels.

This module mirrors ``rust/src/models/`` bit-for-bit in math and memory
layout (see the decomposition notes there):

* every model is (o-builder, optional negative projection, pairwise op);
* relation rows: TransR = ``[r_vec(d) | M(d·d) row-major]``, RESCAL =
  ``M(d·d) row-major``, RotatE = phases ``θ[d/2]``, ComplEx = first half
  real / second half imaginary;
* the loss is logistic (default) or pairwise margin, with optional
  self-adversarial negative weighting (stop-gradient softmax).

``train_step`` is ``jax.value_and_grad`` over the *gathered* embeddings —
gather/scatter and AdaGrad live in the Rust coordinator, matching the
paper's step (2)/(4) split.
"""

import jax
import jax.numpy as jnp

from .kernels.pairwise import PAIRWISE
from .shapes import rel_dim

L2_EPS = 1e-12

PAIRWISE_OP = {
    "transe_l1": "l1",
    "transe_l2": "l2",
    "distmult": "dot",
    "complex": "dot",
    "rescal": "dot",
    "rotate": "sqdiff",
    "transr": "sqdiff",
}


def _split_complex(x):
    d = x.shape[-1]
    return x[..., : d // 2], x[..., d // 2 :]


def build_o(model: str, side: str, e, r):
    """o-builder: ``side='tail'`` consumes heads, ``side='head'`` consumes
    tails. e: [..., d]; r: [..., rd]. Returns [..., d]."""
    if model in ("transe_l1", "transe_l2"):
        return e + r if side == "tail" else e - r
    if model == "distmult":
        return e * r
    if model == "complex":
        er, ei = _split_complex(e)
        rr, ri = _split_complex(r)
        if side == "tail":
            return jnp.concatenate([er * rr - ei * ri, er * ri + ei * rr], axis=-1)
        # head: w = (rr·tr + ri·ti, rr·ti − ri·tr)
        return jnp.concatenate([rr * er + ri * ei, rr * ei - ri * er], axis=-1)
    if model == "rotate":
        hr, hi = _split_complex(e)
        cos, sin = jnp.cos(r), jnp.sin(r)
        if side == "tail":
            return jnp.concatenate([hr * cos - hi * sin, hr * sin + hi * cos], axis=-1)
        # head: o' = t ∘ e^{-iθ}
        return jnp.concatenate([hr * cos + hi * sin, hi * cos - hr * sin], axis=-1)
    if model == "rescal":
        d = e.shape[-1]
        m = r.reshape(r.shape[:-1] + (d, d))
        if side == "tail":
            return jnp.einsum("...a,...ab->...b", e, m)  # Mᵀh
        return jnp.einsum("...ab,...b->...a", m, e)  # Mt
    if model == "transr":
        d = e.shape[-1]
        rv = r[..., :d]
        m = r[..., d:].reshape(r.shape[:-1] + (d, d))
        if side == "tail":
            return jnp.einsum("...ab,...b->...a", m, e) + rv  # Mh + rv
        return jnp.einsum("...ab,...b->...a", m, e) - rv  # Mt - rv
    raise ValueError(model)


def transr_project(r, n, d):
    """Project negatives [nc,k,d] through each positive's M: returns
    [nc,cs,k,d]. r: [nc,cs,rd]."""
    m = r[..., d:].reshape(r.shape[:-1] + (d, d))  # [nc,cs,d,d]
    return jnp.einsum("zcab,zkb->zcka", m, n)


def _sq(x):
    return jnp.sum(x * x, axis=-1)


def _pairwise_4d(op: str, o, n4):
    """Pairwise op between o [nc,cs,d] and per-row candidates n4
    [nc,cs,k,d] (TransR projected negatives). Plain jnp — the 4-D shape
    has no shared-candidate GEMM structure."""
    diff = o[:, :, None, :] - n4
    if op == "sqdiff":
        return -jnp.sum(diff * diff, axis=-1)
    if op == "l2":
        return -jnp.sqrt(jnp.sum(diff * diff, axis=-1) + L2_EPS)
    if op == "l1":
        return -jnp.sum(jnp.abs(diff), axis=-1)
    if op == "dot":
        return jnp.einsum("zcd,zckd->zck", o, n4)
    raise ValueError(op)


def _diag_pairwise(op: str, o, n):
    """scores[i] = op(o_i, n_i); o, n: [..., d]."""
    if op == "dot":
        return jnp.sum(o * n, axis=-1)
    diff = o - n
    if op == "sqdiff":
        return -_sq(diff)
    if op == "l2":
        return -jnp.sqrt(_sq(diff) + L2_EPS)
    if op == "l1":
        return -jnp.sum(jnp.abs(diff), axis=-1)
    raise ValueError(op)


def batch_scores(model: str, h, r, t, neg_h, neg_t, chunks: int, kernels: str = "pallas"):
    """Forward scores of one mini-batch.

    h/r/t: [b, ·]; neg_h/neg_t: [nc, k, d]. Returns (pos [b],
    neg [b, 2k]) with tail-corruption scores first, then head-corruption —
    the same layout as rust `models::step`.

    kernels="pallas" routes pairwise scoring through the Layer-1 kernels
    (the paper's GEMM formulation); kernels="ref" uses naive jnp
    broadcasting — the baseline a naive implementation would write, used
    by the Fig 3 "naive sampling" artifact.
    """
    b, d = h.shape
    k = neg_t.shape[1]
    cs = b // chunks
    op = PAIRWISE_OP[model]

    hc = h.reshape(chunks, cs, d)
    tc = t.reshape(chunks, cs, d)
    rc = r.reshape(chunks, cs, r.shape[-1])

    o_tail = build_o(model, "tail", hc, rc)  # [nc,cs,d]
    o_head = build_o(model, "head", tc, rc)

    if model == "transr":
        # positives: project each t_i through its own M
        m = rc[..., d:].reshape(chunks, cs, d, d)
        t_proj = jnp.einsum("zcab,zcb->zca", m, tc)
        pos = _diag_pairwise(op, o_tail, t_proj).reshape(b)
        # negatives: project the chunk candidates per positive row
        nt4 = transr_project(rc, neg_t, d)  # [nc,cs,k,d]
        nh4 = transr_project(rc, neg_h, d)
        neg_tail = _pairwise_4d(op, o_tail, nt4)  # [nc,cs,k]
        neg_head = _pairwise_4d(op, o_head, nh4)
    else:
        pos = _diag_pairwise(op, o_tail, tc).reshape(b)
        if kernels == "pallas":
            pair = PAIRWISE[op]
        else:
            from .kernels.ref import REF

            pair = REF[op]
        neg_tail = pair(o_tail, neg_t)  # [nc,cs,k]
        neg_head = pair(o_head, neg_h)

    neg = jnp.concatenate(
        [neg_tail.reshape(b, k), neg_head.reshape(b, k)], axis=1
    )  # [b, 2k]
    return pos, neg


def loss_fn(loss: str, pos, neg, gamma: float = 1.0, adv_temp: float | None = None):
    """Loss matching rust `models::loss::loss_and_grad`."""
    b, k2 = neg.shape
    if adv_temp is not None:
        w = jax.nn.softmax(neg * adv_temp, axis=-1)
        w = jax.lax.stop_gradient(w)
    else:
        w = jnp.full_like(neg, 1.0 / k2)
    if loss == "logistic":
        pos_term = jnp.mean(jax.nn.softplus(-pos))
        neg_term = jnp.mean(jnp.sum(w * jax.nn.softplus(neg), axis=-1))
        return pos_term + neg_term
    if loss == "margin":
        viol = jnp.maximum(0.0, gamma - pos[:, None] + neg)
        return jnp.mean(jnp.sum(w * viol, axis=-1))
    raise ValueError(loss)


def make_train_step(
    model: str,
    loss: str,
    chunks: int,
    adv_temp: float | None = None,
    kernels: str = "pallas",
):
    """Returns f(h, r, t, neg_h, neg_t) -> (loss, d_h, d_r, d_t, d_negh,
    d_negt) — the train artifact body."""

    def objective(h, r, t, neg_h, neg_t):
        pos, neg = batch_scores(model, h, r, t, neg_h, neg_t, chunks, kernels=kernels)
        return loss_fn(loss, pos, neg, adv_temp=adv_temp)

    grad_fn = jax.value_and_grad(objective, argnums=(0, 1, 2, 3, 4))

    def step(h, r, t, neg_h, neg_t):
        value, grads = grad_fn(h, r, t, neg_h, neg_t)
        return (value,) + grads

    return step


def make_eval_score(model: str, side: str):
    """Returns f(e, r, cand) -> (scores [m, c],).

    side='tail': e = heads, candidates are tails.
    side='head': e = tails, candidates are heads.
    """
    op = PAIRWISE_OP[model]

    def score(e, r, cand):
        m, d = e.shape
        o = build_o(model, side, e[None], r[None])[0]  # [m, d]
        if model == "transr":
            mm = r[:, d:].reshape(m, d, d)
            pc = jnp.einsum("mab,cb->mca", mm, cand)  # [m, c, d]
            diff = o[:, None, :] - pc
            return (-jnp.sum(diff * diff, axis=-1),)
        pair = PAIRWISE[op]
        return (pair(o[None], cand[None])[0],)

    return score


def example_train_args(model: str, shape, rng_seed: int = 0):
    """Random example args with the artifact's exact shapes/dtypes."""
    import numpy as np

    rng = np.random.default_rng(rng_seed)
    b, nc, k, d = shape.batch, shape.chunks, shape.neg_k, shape.dim
    rd = rel_dim(model, d)

    def arr(*s):
        return jnp.asarray(rng.standard_normal(s, dtype=np.float32) * 0.5)

    return (arr(b, d), arr(b, rd), arr(b, d), arr(nc, k, d), arr(nc, k, d))


def example_eval_args(model: str, shape, rng_seed: int = 0):
    import numpy as np

    rng = np.random.default_rng(rng_seed)
    m, c, d = shape.m, shape.cands, shape.dim
    rd = rel_dim(model, d)

    def arr(*s):
        return jnp.asarray(rng.standard_normal(s, dtype=np.float32) * 0.5)

    return (arr(m, d), arr(m, rd), arr(c, d))
