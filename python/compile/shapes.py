"""Static shape configurations for the AOT artifacts.

The Rust coordinator pads/assembles batches to exactly these shapes (XLA
artifacts are shape-monomorphic). Keys must match
``rust/src/runtime/artifacts.rs``.
"""

from dataclasses import dataclass

MODELS = [
    "transe_l1",
    "transe_l2",
    "distmult",
    "complex",
    "rescal",
    "rotate",
    "transr",
]

# relation-row width per model (must match ModelKind::rel_dim)
def rel_dim(model: str, d: int) -> int:
    if model in ("transe_l1", "transe_l2", "distmult", "complex"):
        return d
    if model == "rotate":
        return d // 2
    if model == "rescal":
        return d * d
    if model == "transr":
        return d + d * d
    raise ValueError(model)


@dataclass(frozen=True)
class TrainShape:
    batch: int
    chunks: int
    neg_k: int
    dim: int

    @property
    def chunk_size(self) -> int:
        assert self.batch % self.chunks == 0
        return self.batch // self.chunks

    def key(self, model: str, loss: str) -> str:
        return (
            f"{model}_train_{loss}_b{self.batch}_c{self.chunk_size}"
            f"_k{self.neg_k}_d{self.dim}"
        )


@dataclass(frozen=True)
class EvalShape:
    m: int  # positives scored at once
    cands: int  # candidate entities per call
    dim: int

    def key(self, model: str, side: str) -> str:
        return f"{model}_eval_{side}_m{self.m}_cand{self.cands}_d{self.dim}"


def default_train_shape(model: str) -> TrainShape:
    """Production shapes. TransR/RESCAL are d× heavier (paper §2), so they
    get smaller batches, mirroring how the paper runs them."""
    if model == "transr":
        return TrainShape(batch=256, chunks=8, neg_k=64, dim=32)
    if model == "rescal":
        return TrainShape(batch=512, chunks=8, neg_k=128, dim=64)
    return TrainShape(batch=1024, chunks=16, neg_k=256, dim=128)


def default_eval_shape(model: str) -> EvalShape:
    if model == "transr":
        return EvalShape(m=64, cands=1024, dim=32)
    if model == "rescal":
        return EvalShape(m=64, cands=2048, dim=64)
    return EvalShape(m=64, cands=2048, dim=128)


def tiny_train_shape(model: str) -> TrainShape:
    return TrainShape(batch=32, chunks=4, neg_k=16, dim=16)


def tiny_eval_shape(model: str) -> EvalShape:
    return EvalShape(m=8, cands=64, dim=16)
