"""AOT pipeline: HLO text round-trips through XLA and evaluates to the
same numbers as the jitted jax function."""

import json
import os

import jax
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M, shapes as S


def run_hlo_text(hlo: str, args):
    """Compile HLO text with the local CPU client and execute — the same
    path the rust runtime takes (via the xla crate)."""
    client = xc._xla.get_local_backend("cpu") if hasattr(xc._xla, "get_local_backend") else None
    if client is None:
        import jax.extend.backend as jb

        client = jb.get_backend("cpu")
    comp = xc._xla.hlo_module_from_text(hlo) if hasattr(xc._xla, "hlo_module_from_text") else None
    if comp is None:
        pytest.skip("no hlo text parser in this jaxlib")
    exe = client.compile_and_load(
        xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto()).as_serialized_hlo_module_proto()
        if False
        else xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto())
    )
    outs = exe.execute([np.asarray(a) for a in args])
    return outs


@pytest.mark.parametrize("model", ["transe_l2", "distmult", "rotate"])
def test_hlo_text_roundtrip_values(model, tmp_path):
    shape = S.tiny_train_shape(model)
    lowered = aot.lower_train(model, "logistic", shape, None)
    hlo = aot.to_hlo_text(lowered)
    assert "ENTRY" in hlo  # sanity: parseable HLO text

    args = M.example_train_args(model, shape)
    want = jax.jit(M.make_train_step(model, "logistic", shape.chunks))(*args)

    try:
        outs = run_hlo_text(hlo, args)
    except Exception as e:  # jaxlib version without text loader: skip
        pytest.skip(f"in-python HLO execution unavailable: {e}")
    got = [np.asarray(o) for o in outs[0]] if isinstance(outs[0], (list, tuple)) else outs
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, np.asarray(w), rtol=1e-4, atol=1e-5)


def test_manifest_written(tmp_path):
    out = tmp_path / "artifacts"
    out.mkdir()
    entries = aot.build_manifest(str(out), ["distmult"], ["logistic"], include_tiny=True)
    manifest_files = {e["file"] for e in entries}
    for f in manifest_files:
        assert (out / f).exists()
    # keys unique
    keys = [e["key"] for e in entries]
    assert len(keys) == len(set(keys))
    # train + 2 eval sides, default + tiny each
    kinds = sorted(e["kind"] for e in entries)
    assert kinds == ["eval_head", "eval_head", "eval_tail", "eval_tail", "train", "train"]


def test_manifest_shapes_consistent(tmp_path):
    out = tmp_path / "a"
    out.mkdir()
    entries = aot.build_manifest(str(out), ["rotate"], ["logistic"], include_tiny=False)
    train = [e for e in entries if e["kind"] == "train"][0]
    assert train["rel_dim"] == train["dim"] // 2
    assert train["batch"] % train["chunks"] == 0
