"""Pallas kernels vs pure-jnp oracle: values and gradients, swept over
shapes and distributions (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pairwise as pw
from compile.kernels import ref

OPS = ["dot", "sqdiff", "l2", "l1"]


def rand(key, shape, scale):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


@pytest.mark.parametrize("op", OPS)
def test_values_match_ref(op):
    o = rand(0, (3, 16, 24), 1.0)
    n = rand(1, (3, 40, 24), 1.0)
    np.testing.assert_allclose(
        pw.PAIRWISE[op](o, n), ref.REF[op](o, n), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("op", OPS)
def test_grads_match_ref(op):
    o = rand(2, (2, 8, 16), 1.0)
    n = rand(3, (2, 12, 16), 1.0)
    g = rand(4, (2, 8, 12), 1.0)

    def mine(o, n):
        return jnp.sum(pw.PAIRWISE[op](o, n) * g)

    def theirs(o, n):
        return jnp.sum(ref.REF[op](o, n) * g)

    g1 = jax.grad(mine, argnums=(0, 1))(o, n)
    g2 = jax.grad(theirs, argnums=(0, 1))(o, n)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    nc=st.sampled_from([1, 2, 4]),
    cs=st.sampled_from([1, 4, 8, 32]),
    k=st.sampled_from([1, 8, 64]),
    d=st.sampled_from([2, 8, 16, 128]),
    op=st.sampled_from(OPS),
    scale=st.sampled_from([1e-3, 1.0, 100.0]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_shape_sweep(nc, cs, k, d, op, scale, seed):
    o = rand(seed, (nc, cs, d), scale)
    n = rand(seed + 1, (nc, k, d), scale)
    got = pw.PAIRWISE[op](o, n)
    want = ref.REF[op](o, n)
    assert got.shape == (nc, cs, k)
    tol = 1e-4 * max(scale * scale * d, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=tol)


@settings(max_examples=10, deadline=None)
@given(
    cs=st.sampled_from([4, 16]),
    k=st.sampled_from([8, 32]),
    d=st.sampled_from([8, 64]),
    op=st.sampled_from(OPS),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_grad_sweep(cs, k, d, op, seed):
    o = rand(seed, (2, cs, d), 1.0)
    n = rand(seed + 1, (2, k, d), 1.0)
    g = rand(seed + 2, (2, cs, k), 1.0)
    g1 = jax.grad(lambda o, n: jnp.sum(pw.PAIRWISE[op](o, n) * g), argnums=(0, 1))(o, n)
    g2 = jax.grad(lambda o, n: jnp.sum(ref.REF[op](o, n) * g), argnums=(0, 1))(o, n)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-3, atol=1e-3)


def test_bmm_matches_einsum():
    a = rand(5, (3, 16, 8), 1.0)
    b = rand(6, (3, 8, 24), 1.0)
    np.testing.assert_allclose(
        pw.bmm(a, b), jnp.einsum("zmk,zkn->zmn", a, b), rtol=1e-5, atol=1e-5
    )


def test_l2_eps_matches_rust():
    # The constant must equal rust models::L2_EPS for bit-compatible
    # numerics across backends.
    assert pw.L2_EPS == 1e-12
    assert ref.L2_EPS == 1e-12


def test_l1_at_kink_is_finite():
    # identical rows: |o-n| = 0 everywhere; gradient must be finite (sign(0)=0)
    o = jnp.ones((1, 4, 8))
    n = jnp.ones((1, 4, 8))
    g = jnp.ones((1, 4, 4))
    do, dn = jax.grad(lambda o, n: jnp.sum(pw.pairwise_l1(o, n) * g), argnums=(0, 1))(o, n)
    assert np.isfinite(np.asarray(do)).all()
    assert np.isfinite(np.asarray(dn)).all()


def test_l2_at_zero_distance_is_finite():
    o = jnp.ones((1, 2, 4))
    n = jnp.ones((1, 2, 4))
    f = pw.pairwise_l2(o, n)
    assert np.isfinite(np.asarray(f)).all()
    do = jax.grad(lambda o: jnp.sum(pw.pairwise_l2(o, n)))(o)
    assert np.isfinite(np.asarray(do)).all()
