"""L2 model layer: score decompositions vs textbook formulas, loss math,
train-step gradients, eval scoring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import shapes as S

MODELS = S.MODELS


def direct_score(model, h, r, t):
    """Textbook per-triplet score, paper Table 1 (same as the rust test
    oracle in models/builders.rs)."""
    d = h.shape[-1]
    dc = d // 2
    if model == "transe_l1":
        return -jnp.sum(jnp.abs(h + r - t), -1)
    if model == "transe_l2":
        return -jnp.sqrt(jnp.sum((h + r - t) ** 2, -1) + M.L2_EPS)
    if model == "distmult":
        return jnp.sum(h * r * t, -1)
    if model == "complex":
        hr, hi = h[..., :dc], h[..., dc:]
        rr, ri = r[..., :dc], r[..., dc:]
        tr, ti = t[..., :dc], t[..., dc:]
        return jnp.sum((hr * rr - hi * ri) * tr + (hr * ri + hi * rr) * ti, -1)
    if model == "rotate":
        hr, hi = h[..., :dc], h[..., dc:]
        cos, sin = jnp.cos(r), jnp.sin(r)
        orr = hr * cos - hi * sin
        oi = hr * sin + hi * cos
        return -jnp.sum((orr - t[..., :dc]) ** 2 + (oi - t[..., dc:]) ** 2, -1)
    if model == "rescal":
        m = r.reshape(r.shape[:-1] + (d, d))
        return jnp.einsum("...a,...ab,...b->...", h, m, t)
    if model == "transr":
        rv, m = r[..., :d], r[..., d:].reshape(r.shape[:-1] + (d, d))
        proj = jnp.einsum("...ab,...b->...a", m, h - t) + rv
        return -jnp.sum(proj**2, -1)
    raise ValueError(model)


def rand_inputs(model, b=8, nc=2, k=4, d=8, seed=0):
    rng = np.random.default_rng(seed)
    rd = S.rel_dim(model, d)
    mk = lambda *s: jnp.asarray(rng.standard_normal(s, dtype=np.float32) * 0.5)
    return mk(b, d), mk(b, rd), mk(b, d), mk(nc, k, d), mk(nc, k, d)


@pytest.mark.parametrize("model", MODELS)
def test_positive_scores_match_direct(model):
    h, r, t, nh, nt = rand_inputs(model)
    pos, _ = M.batch_scores(model, h, r, t, nh, nt, chunks=2)
    np.testing.assert_allclose(pos, direct_score(model, h, r, t), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("model", MODELS)
def test_negative_scores_match_direct(model):
    b, nc, k, d = 8, 2, 4, 8
    h, r, t, nh, nt = rand_inputs(model, b, nc, k, d)
    _, neg = M.batch_scores(model, h, r, t, nh, nt, chunks=nc)
    cs = b // nc
    for i in range(b):
        c = i // cs
        for j in range(k):
            # tail corruption: replace t_i with nt[c, j]
            want = direct_score(model, h[i], r[i], nt[c, j])
            np.testing.assert_allclose(neg[i, j], want, rtol=1e-3, atol=1e-4)
            # head corruption: replace h_i with nh[c, j]
            want = direct_score(model, nh[c, j], r[i], t[i])
            np.testing.assert_allclose(neg[i, k + j], want, rtol=1e-3, atol=1e-4)


def test_logistic_loss_matches_manual():
    pos = jnp.array([2.0, -1.0])
    neg = jnp.array([[0.5, -0.5], [1.0, 0.0]])
    got = M.loss_fn("logistic", pos, neg)
    sp = lambda x: np.log1p(np.exp(x))
    want = np.mean([sp(-2.0), sp(1.0)]) + np.mean(
        [0.5 * (sp(0.5) + sp(-0.5)), 0.5 * (sp(1.0) + sp(0.0))]
    )
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_margin_loss_matches_manual():
    pos = jnp.array([1.0])
    neg = jnp.array([[0.5, -3.0]])
    got = M.loss_fn("margin", pos, neg, gamma=1.0)
    # pairs: max(0, 1 - 1 + 0.5) = 0.5 ; max(0, 1 - 1 - 3) = 0; mean w=1/2
    np.testing.assert_allclose(got, 0.25, rtol=1e-6)


def test_adversarial_weights_prefer_hard_negatives():
    pos = jnp.array([0.0])
    easy = jnp.array([[-10.0, 5.0]])
    l_adv = M.loss_fn("logistic", pos, easy, adv_temp=1.0)
    l_uni = M.loss_fn("logistic", pos, easy)
    # adversarial concentrates weight on the hard (high-score) negative
    assert l_adv > l_uni


@pytest.mark.parametrize("model", MODELS)
def test_train_step_runs_and_shapes(model):
    shape = S.tiny_train_shape(model)
    step = M.make_train_step(model, "logistic", shape.chunks)
    args = M.example_train_args(model, shape)
    out = jax.jit(step)(*args)
    loss, dh, dr, dt, dnh, dnt = out
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    for g, a in zip((dh, dr, dt, dnh, dnt), args):
        assert g.shape == a.shape
        assert np.isfinite(np.asarray(g)).all()


@pytest.mark.parametrize("model", ["transe_l2", "distmult", "rotate", "transr"])
def test_train_step_gradient_descends(model):
    shape = S.tiny_train_shape(model)
    step = jax.jit(M.make_train_step(model, "logistic", shape.chunks))
    args = list(M.example_train_args(model, shape))
    first = float(step(*args)[0])
    for _ in range(60):
        out = step(*args)
        for i in range(5):
            args[i] = args[i] - 0.5 * out[1 + i]
    last = float(step(*args)[0])
    assert last < first * 0.8, f"{model}: {first} -> {last}"


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("side", ["tail", "head"])
def test_eval_scores_match_direct(model, side):
    rng = np.random.default_rng(7)
    m, c, d = 4, 6, 8
    rd = S.rel_dim(model, d)
    mk = lambda *s: jnp.asarray(rng.standard_normal(s, dtype=np.float32) * 0.5)
    e, r, cand = mk(m, d), mk(m, rd), mk(c, d)
    (scores,) = M.make_eval_score(model, side)(e, r, cand)
    assert scores.shape == (m, c)
    for i in range(m):
        for j in range(c):
            if side == "tail":
                want = direct_score(model, e[i], r[i], cand[j])
            else:
                want = direct_score(model, cand[j], r[i], e[i])
            np.testing.assert_allclose(scores[i, j], want, rtol=1e-3, atol=1e-4)
