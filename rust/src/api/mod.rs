//! The typed run API: one declarative [`RunSpec`] + one validating
//! [`Session`] drive every mode of the system — many-core CPU, simulated
//! multi-GPU, and distributed — replacing the per-entry-point wiring of
//! `TrainConfig` / `DistConfig` / `EvalConfig` that the CLI, repro drivers,
//! examples, and benches used to duplicate.
//!
//! * [`RunSpec`] — dataset, model, loss, backend, parallelism mode,
//!   hyperparameters, eval protocol, seed. Serializes to/parses from JSON
//!   (see [`spec`] for the schema); `dglke train --config run.json` and
//!   `--dump-config` round-trip through it.
//! * [`Session`] — `Session::from_spec(spec)?` or
//!   `Session::builder().dataset("fb15k-syn").workers(8).build()?`;
//!   internalizes manifest loading, shape resolution (including the
//!   documented [`DEFAULT_NATIVE_SHAPE`] fallback), and state init.
//! * [`Report`] — unified result (train stats + eval metrics +
//!   traffic/locality counters), JSON-serializable, produced by one code
//!   path for all hardware modes.
//! * [`Session::export_embeddings`] / [`Session::load_checkpoint`] — model
//!   persistence for downstream serving.

pub mod report;
pub mod session;
pub mod spec;

pub use report::Report;
pub use session::{load_default_manifest, resolve_shape, ResolvedShape, Session, SessionBuilder};
pub use spec::{
    CommSpec, EvalProtocolSpec, EvalSpec, LossSpec, ObsSpec, ParallelMode, PipelineSpec,
    RunSpec, ServeSpec, DEFAULT_NATIVE_SHAPE,
};
