//! [`Report`]: the unified result of a [`crate::api::Session`] run — train
//! stats, optional eval metrics, and traffic/locality counters — with a
//! JSON form so benchmarks and experiment trajectories are produced by one
//! code path regardless of hardware mode.

use crate::dist::DistStats;
use crate::eval::Metrics;
use crate::train::TrainStats;
use crate::util::json::Json;
use std::collections::BTreeMap;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Unified run report. Single-machine runs leave the traffic/locality
/// fields at zero; distributed runs leave the transfer-ledger fields at
/// zero. `final_loss` is the mean of the last 10 logged losses.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// "single" | "distributed"
    pub mode: String,
    pub total_batches: u64,
    pub wall_secs: f64,
    /// simulated parallel wall-clock (see `TrainStats::sim_parallel_secs`);
    /// equals `wall_secs` for distributed runs
    pub sim_parallel_secs: f64,
    pub triplets_per_sec: f64,
    pub final_loss: f32,
    pub loss_curve: Vec<(u64, f32)>,
    /// per-phase busy seconds (single-machine runs)
    pub phases: Vec<(String, f64)>,
    // simulated PCIe ledger (single-machine GPU mode)
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub overlapped_bytes: u64,
    // hot-row cache counters (mmap storage with a cache budget; all zero
    // otherwise)
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_write_backs: u64,
    // KVStore ledger (distributed mode)
    pub locality: f64,
    pub local_bytes: u64,
    pub remote_bytes: u64,
    pub remote_requests: u64,
    /// remote bytes moved off the trainers' critical path (prefetch-helper
    /// pulls, fire-and-forget pushes); critical-path remote traffic is
    /// `remote_bytes - remote_overlapped_bytes`
    pub remote_overlapped_bytes: u64,
    /// eval metrics, when the spec requested evaluation
    pub metrics: Option<Metrics>,
    /// `obs::metrics` registry snapshot, when the spec set `obs.metrics`
    /// (see `docs/OBSERVABILITY.md`); `Snapshot::from_json` inverts the
    /// serialized form exactly
    pub obs_metrics: Option<crate::obs::metrics::Snapshot>,
    /// the spec that produced this report (provenance), in JSON form
    pub spec: Option<Json>,
}

impl Report {
    pub fn from_train(stats: &TrainStats) -> Report {
        Report {
            mode: "single".into(),
            total_batches: stats.total_batches,
            wall_secs: stats.wall_secs,
            sim_parallel_secs: stats.sim_parallel_secs,
            triplets_per_sec: stats.triplets_per_sec,
            final_loss: stats.mean_loss_tail,
            loss_curve: stats.loss_curve.clone(),
            phases: stats.phases.clone(),
            h2d_bytes: stats.h2d_bytes,
            d2h_bytes: stats.d2h_bytes,
            overlapped_bytes: stats.overlapped_bytes,
            cache_hits: stats.cache.hits,
            cache_misses: stats.cache.misses,
            cache_evictions: stats.cache.evictions,
            cache_write_backs: stats.cache.write_backs,
            ..Default::default()
        }
    }

    pub fn from_dist(stats: &DistStats) -> Report {
        Report {
            mode: "distributed".into(),
            total_batches: stats.total_batches,
            wall_secs: stats.wall_secs,
            sim_parallel_secs: stats.wall_secs,
            triplets_per_sec: stats.triplets_per_sec,
            final_loss: stats.mean_loss_tail,
            loss_curve: stats.loss_curve.clone(),
            locality: stats.locality,
            local_bytes: stats.local_bytes,
            remote_bytes: stats.remote_bytes,
            remote_requests: stats.remote_requests,
            remote_overlapped_bytes: stats.remote_overlapped_bytes,
            ..Default::default()
        }
    }

    pub fn to_json(&self) -> Json {
        let metrics = match &self.metrics {
            None => Json::Null,
            Some(m) => obj(vec![
                ("hit1", Json::Num(m.hit1)),
                ("hit3", Json::Num(m.hit3)),
                ("hit10", Json::Num(m.hit10)),
                ("mr", Json::Num(m.mr)),
                ("mrr", Json::Num(m.mrr)),
                ("n", Json::Num(m.n as f64)),
            ]),
        };
        obj(vec![
            ("mode", Json::Str(self.mode.clone())),
            ("total_batches", Json::Num(self.total_batches as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("sim_parallel_secs", Json::Num(self.sim_parallel_secs)),
            ("triplets_per_sec", Json::Num(self.triplets_per_sec)),
            ("final_loss", Json::Num(self.final_loss as f64)),
            (
                "loss_curve",
                Json::Arr(
                    self.loss_curve
                        .iter()
                        .map(|&(s, l)| Json::Arr(vec![Json::Num(s as f64), Json::Num(l as f64)]))
                        .collect(),
                ),
            ),
            (
                "phases",
                obj(self
                    .phases
                    .iter()
                    .map(|(p, s)| (p.as_str(), Json::Num(*s)))
                    .collect()),
            ),
            ("h2d_bytes", Json::Num(self.h2d_bytes as f64)),
            ("d2h_bytes", Json::Num(self.d2h_bytes as f64)),
            ("overlapped_bytes", Json::Num(self.overlapped_bytes as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_misses", Json::Num(self.cache_misses as f64)),
            ("cache_evictions", Json::Num(self.cache_evictions as f64)),
            ("cache_write_backs", Json::Num(self.cache_write_backs as f64)),
            ("locality", Json::Num(self.locality)),
            ("local_bytes", Json::Num(self.local_bytes as f64)),
            ("remote_bytes", Json::Num(self.remote_bytes as f64)),
            ("remote_requests", Json::Num(self.remote_requests as f64)),
            ("remote_overlapped_bytes", Json::Num(self.remote_overlapped_bytes as f64)),
            ("metrics", metrics),
            (
                "obs_metrics",
                self.obs_metrics.as_ref().map(|s| s.to_json()).unwrap_or(Json::Null),
            ),
            ("spec", self.spec.clone().unwrap_or(Json::Null)),
        ])
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Human-readable multi-line summary (what the CLI prints).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "done: {} batches, wall {:.1}s, sim-parallel {:.1}s, {:.0} triplets/s, final loss {:.4}",
            self.total_batches,
            self.wall_secs,
            self.sim_parallel_secs,
            self.triplets_per_sec,
            self.final_loss
        );
        for (p, secs) in &self.phases {
            s.push_str(&format!("\n  phase {p}: {secs:.2}s"));
        }
        if self.h2d_bytes + self.d2h_bytes + self.overlapped_bytes > 0 {
            s.push_str(&format!(
                "\n  transfers: h2d {:.1}MB d2h {:.1}MB overlapped {:.1}MB",
                self.h2d_bytes as f64 / 1e6,
                self.d2h_bytes as f64 / 1e6,
                self.overlapped_bytes as f64 / 1e6
            ));
        }
        if self.cache_hits + self.cache_misses > 0 {
            s.push_str(&format!(
                "\n  row cache: {} hits / {} misses ({:.1}% hit), {} evictions, {} write-backs",
                self.cache_hits,
                self.cache_misses,
                100.0 * self.cache_hits as f64
                    / (self.cache_hits + self.cache_misses).max(1) as f64,
                self.cache_evictions,
                self.cache_write_backs
            ));
        }
        if self.mode == "distributed" {
            s.push_str(&format!(
                "\n  locality {:.3}; traffic local {:.1}MB remote {:.1}MB \
                 ({:.1}MB overlapped, {:.1}MB critical, {} remote reqs)",
                self.locality,
                self.local_bytes as f64 / 1e6,
                self.remote_bytes as f64 / 1e6,
                self.remote_overlapped_bytes as f64 / 1e6,
                self.remote_bytes.saturating_sub(self.remote_overlapped_bytes) as f64 / 1e6,
                self.remote_requests
            ));
        }
        if let Some(m) = &self.metrics {
            s.push_str(&format!("\n  eval ({} ranks, both sides): {}", m.n, m.row()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_serializes_and_parses() {
        let mut r = Report::from_train(&TrainStats {
            wall_secs: 1.5,
            sim_parallel_secs: 0.7,
            total_batches: 60,
            triplets_per_sec: 1234.0,
            mean_loss_tail: 0.25,
            loss_curve: vec![(0, 0.9), (50, 0.3)],
            phases: vec![("compute".into(), 0.4)],
            cache: crate::store::CacheStats {
                hits: 90,
                misses: 10,
                evictions: 3,
                write_backs: 5,
            },
            ..Default::default()
        });
        r.metrics = Some(Metrics { hit10: 0.5, mrr: 0.25, n: 10, ..Default::default() });
        let j = Json::parse(&r.to_json_string()).unwrap();
        assert_eq!(j.get("total_batches").unwrap().as_usize(), Some(60));
        assert_eq!(j.get("mode").unwrap().as_str(), Some("single"));
        assert_eq!(j.get("cache_hits").unwrap().as_usize(), Some(90));
        assert_eq!(j.get("cache_misses").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("cache_evictions").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("cache_write_backs").unwrap().as_usize(), Some(5));
        assert!(r.summary().contains("row cache: 90 hits"));
        assert_eq!(j.get("metrics").unwrap().get("n").unwrap().as_usize(), Some(10));
        let curve = j.get("loss_curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), 2);
        assert!(r.summary().contains("60 batches"));
    }

    #[test]
    fn obs_metrics_snapshot_round_trips_through_report() {
        use crate::obs::metrics::{HistogramSnapshot, Snapshot};
        let mut snap = Snapshot::default();
        snap.counters.insert("store.cache.hits".into(), 90);
        snap.gauges.insert("store.cache.resident_rows".into(), 12);
        snap.histograms.insert(
            "serve.query_ns".into(),
            HistogramSnapshot { count: 3, sum: 900, buckets: vec![(9, 3)] },
        );
        let mut r = Report::default();
        r.obs_metrics = Some(snap.clone());
        let j = Json::parse(&r.to_json_string()).unwrap();
        let back = Snapshot::from_json(j.get("obs_metrics").unwrap()).unwrap();
        assert_eq!(back, snap);
        // absent → null, not a missing key
        let r = Report::default();
        let j = Json::parse(&r.to_json_string()).unwrap();
        assert_eq!(j.get("obs_metrics"), Some(&Json::Null));
    }

    #[test]
    fn dist_report_surfaces_net_ledger() {
        let r = Report::from_dist(&DistStats {
            wall_secs: 2.0,
            total_batches: 80,
            locality: 0.75,
            local_bytes: 4_000_000,
            remote_bytes: 2_000_000,
            remote_requests: 160,
            remote_overlapped_bytes: 1_500_000,
            ..Default::default()
        });
        let j = Json::parse(&r.to_json_string()).unwrap();
        assert_eq!(j.get("mode").unwrap().as_str(), Some("distributed"));
        assert_eq!(j.get("local_bytes").unwrap().as_usize(), Some(4_000_000));
        assert_eq!(j.get("remote_bytes").unwrap().as_usize(), Some(2_000_000));
        assert_eq!(j.get("remote_requests").unwrap().as_usize(), Some(160));
        assert_eq!(j.get("remote_overlapped_bytes").unwrap().as_usize(), Some(1_500_000));
        let s = r.summary();
        assert!(s.contains("remote 2.0MB"), "{s}");
        assert!(s.contains("1.5MB overlapped"), "{s}");
        assert!(s.contains("0.5MB critical"), "{s}");
        assert!(s.contains("160 remote reqs"), "{s}");
    }
}
