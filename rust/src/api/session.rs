//! [`Session`]: a validated, ready-to-run training/evaluation session built
//! from a [`RunSpec`]. Internalizes everything the entry points used to
//! hand-wire: manifest loading, step-shape resolution, model-state init,
//! mode dispatch (single-machine vs distributed), post-train evaluation,
//! and embedding export/import.

use super::report::Report;
use super::spec::{ParallelMode, RunSpec, DEFAULT_NATIVE_SHAPE};
use crate::dist::{run_distributed, DistConfig};
use crate::eval::{evaluate, Metrics};
use crate::kg::Dataset;
use crate::models::step::StepShape;
use crate::runtime::{artifacts, BackendKind, Manifest};
use crate::serve::manifest::{
    read_chunk_into, vocab_hash, CheckpointManifest, ChunkInfo, TableInfo, FORMAT_VERSION,
    TABLE_HEADER_BYTES,
};
use crate::store::{EmbeddingStore, StoreBackendKind};
use crate::train::worker::ModelState;
use crate::train::{run_training, Hardware, TrainConfig};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Load the default artifact manifest if artifacts have been built.
pub fn load_default_manifest() -> Result<Option<Manifest>> {
    if artifacts::available() {
        Ok(Some(Manifest::load(&artifacts::default_dir())?))
    } else {
        Ok(None)
    }
}

/// The step shape a spec resolves to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResolvedShape {
    /// the shape every train step will actually use
    pub step: StepShape,
    /// explicit shape to hand the native backend (`None` when a compiled
    /// XLA artifact owns the shape)
    pub native_override: Option<StepShape>,
}

/// Resolve the step shape for a spec: the spec's own shape wins (native
/// backend); otherwise the artifact manifest; otherwise — native backend
/// only — the documented [`DEFAULT_NATIVE_SHAPE`], with a log line (the old
/// CLI buried this fallback as an unlogged literal).
pub fn resolve_shape(manifest: Option<&Manifest>, spec: &RunSpec) -> Result<ResolvedShape> {
    let loss_name = spec.loss.to_cfg().kind.name();
    let art =
        manifest.and_then(|m| m.find_train(spec.model.name(), loss_name, &spec.artifact_tag).ok());
    match spec.backend {
        BackendKind::Native => {
            if let Some(s) = spec.shape {
                return Ok(ResolvedShape { step: s, native_override: Some(s) });
            }
            match art {
                Some(a) => {
                    let s =
                        StepShape { batch: a.batch, chunks: a.chunks, neg_k: a.neg_k, dim: a.dim };
                    Ok(ResolvedShape { step: s, native_override: Some(s) })
                }
                None => {
                    let s = DEFAULT_NATIVE_SHAPE;
                    // log the fallback once per process, not once per
                    // session (repro tables build many sessions)
                    static LOGGED: std::sync::Once = std::sync::Once::new();
                    LOGGED.call_once(|| {
                        println!(
                            "[spec] no artifacts built — native runs without an explicit shape \
                             use the default batch={} chunks={} neg_k={} dim={} \
                             (set RunSpec.shape to override)",
                            s.batch, s.chunks, s.neg_k, s.dim
                        );
                    });
                    Ok(ResolvedShape { step: s, native_override: Some(s) })
                }
            }
        }
        BackendKind::Xla => match art {
            // compiled artifacts carry their own shape; the spec's shape
            // field is not consulted
            Some(a) => Ok(ResolvedShape {
                step: StepShape { batch: a.batch, chunks: a.chunks, neg_k: a.neg_k, dim: a.dim },
                native_override: None,
            }),
            None => bail!(
                "no artifacts for model {} tag {} — run `make artifacts` or use the native backend",
                spec.model.name(),
                spec.artifact_tag
            ),
        },
    }
}

/// A validated run: dataset loaded, shapes resolved, model state
/// initialized. Construct with [`Session::from_spec`] or
/// [`Session::builder`], then call [`Session::train`] /
/// [`Session::evaluate`] / [`Session::export_embeddings`].
pub struct Session {
    spec: RunSpec,
    dataset: Arc<Dataset>,
    manifest: Option<Manifest>,
    shape: ResolvedShape,
    state: ModelState,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Build a session from a spec: validates the spec, loads the dataset
    /// (erroring on unknown presets/directories), loads the artifact
    /// manifest when present, resolves the step shape, and initializes the
    /// embedding tables.
    pub fn from_spec(spec: RunSpec) -> Result<Session> {
        spec.validate()?;
        let dataset = Arc::new(
            Dataset::load(&spec.dataset, spec.seed)
                .with_context(|| format!("loading dataset {:?}", spec.dataset))?,
        );
        Self::with_dataset(spec, dataset)
    }

    /// Like [`Session::from_spec`] but reusing an already-loaded dataset
    /// (synthetic datasets are expensive to regenerate; benches share one
    /// `Arc<Dataset>` across many sessions).
    pub fn with_dataset(spec: RunSpec, dataset: Arc<Dataset>) -> Result<Session> {
        spec.validate()?;
        let manifest = load_default_manifest()?;
        let shape = resolve_shape(manifest.as_ref(), &spec)?;
        let dim = shape.step.dim;
        anyhow::ensure!(
            spec.model.validate_dim(dim),
            "model {} requires an even dim, got {}",
            spec.model.name(),
            dim
        );
        // in-memory budget: dense/sharded tables (embeddings + optimizer
        // state) must fit. Single-machine mmap runs keep their rows on
        // disk, but they are *not* exempt wholesale — their resident set
        // is the hot-row cache, so what must fit under the budget is the
        // cache allowance (cache_mb, defaulting to budget_mb itself).
        // Distributed runs materialize dense tables on the in-process
        // KVStore servers regardless of the declared backend.
        if let Some(mb) = spec.storage.budget_mb {
            let rel_dim = spec.model.rel_dim(dim);
            let need = ((dataset.n_entities() * (dim + 1) + dataset.n_relations() * (rel_dim + 1))
                * 4) as u64;
            let budget = (mb * (1u64 << 20) as f64) as u64;
            let on_disk = spec.storage.backend == StoreBackendKind::Mmap
                && matches!(spec.mode, ParallelMode::Single { .. });
            if on_disk {
                let cache = spec.storage.cache_total_bytes().unwrap_or(0);
                anyhow::ensure!(
                    cache <= budget,
                    "storage.cache_mb ({} MiB) exceeds storage.budget_mb ({mb} MiB) — the \
                     hot-row cache is the resident set of an mmap run, so it must fit the budget",
                    spec.storage.cache_mb.unwrap_or(mb)
                );
            } else {
                anyhow::ensure!(
                    need <= budget,
                    "embedding tables need {need} bytes but storage.budget_mb is {mb} MiB — \
                     use {{\"storage\": {{\"backend\": \"mmap\"}}}} in a single-machine run for \
                     larger-than-RAM tables (distributed servers hold dense shards in memory)",
                );
            }
        }
        let state = match spec.mode {
            // distributed runs initialize per-shard on the KVStore servers
            // (id-derived RNG) and dump into this state after training, so
            // the random init here would be dead work
            ParallelMode::Distributed { .. } => {
                ModelState::placeholder(&dataset, spec.model, dim, spec.lr)
            }
            ParallelMode::Single { .. } => ModelState::init_with_storage(
                &dataset,
                spec.model,
                dim,
                spec.lr,
                spec.init_scale,
                spec.seed,
                &spec.storage,
            )?,
        };
        Ok(Session { spec, dataset, manifest, shape, state })
    }

    pub fn spec(&self) -> &RunSpec {
        &self.spec
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    pub fn dim(&self) -> usize {
        self.shape.step.dim
    }

    /// The step shape every mini-batch will use.
    pub fn step_shape(&self) -> StepShape {
        self.shape.step
    }

    /// Mini-batch size of one step under the resolved shape.
    pub fn batch_size(&self) -> usize {
        self.shape.step.batch
    }

    /// Number of model parameters.
    pub fn n_params(&self) -> usize {
        self.state.n_params()
    }

    fn train_config(&self, workers: usize, gpu: bool) -> TrainConfig {
        TrainConfig {
            model: self.spec.model,
            loss: self.spec.loss.to_cfg(),
            backend: self.spec.backend,
            artifact_tag: self.spec.artifact_tag.clone(),
            shape: self.shape.native_override,
            n_workers: workers,
            batches_per_worker: self.spec.batches,
            lr: self.spec.lr,
            init_scale: self.spec.init_scale,
            neg_degree_frac: self.spec.neg_degree_frac,
            async_update: self.spec.async_update,
            prefetch: self.spec.pipeline.prefetch,
            prefetch_depth: self.spec.pipeline.depth,
            relation_partition: self.spec.relation_partition,
            sync_interval: self.spec.sync_interval,
            hardware: if gpu { Hardware::Gpu { pcie_gbps: 12.0 } } else { Hardware::Cpu },
            seed: self.spec.seed,
            log_every: self.spec.log_every,
            kernels: self.spec.kernels,
        }
    }

    /// Run training under the spec's parallelism mode; when the spec
    /// requests evaluation, it is run afterwards and embedded in the
    /// [`Report`]. Trained embeddings are left in the session state (for
    /// distributed runs they are dumped out of the KVStore cluster), so
    /// [`Session::evaluate`] and [`Session::export_embeddings`] see them.
    pub fn train(&mut self) -> Result<Report> {
        // claim the process-wide trace collector before any worker can
        // emit a span; finish (and write the file) after every worker
        // has joined, which run_training/run_distributed guarantee
        let trace_guard = if self.spec.obs.trace { Some(crate::obs::trace::start()) } else { None };
        let mut report = match self.spec.mode {
            ParallelMode::Single { workers, gpu } => {
                let cfg = self.train_config(workers, gpu);
                let stats = run_training(&self.dataset, &self.state, self.manifest.as_ref(), &cfg)?;
                Report::from_train(&stats)
            }
            ParallelMode::Distributed { machines, trainers, servers, partition, local_negatives } => {
                let cfg = DistConfig {
                    model: self.spec.model,
                    loss: self.spec.loss.to_cfg(),
                    backend: self.spec.backend,
                    artifact_tag: self.spec.artifact_tag.clone(),
                    shape: self.shape.native_override,
                    machines,
                    trainers_per_machine: trainers,
                    servers_per_machine: servers,
                    partition,
                    local_negatives,
                    batches_per_trainer: self.spec.batches,
                    lr: self.spec.lr,
                    init_scale: self.spec.init_scale,
                    neg_degree_frac: self.spec.neg_degree_frac,
                    seed: self.spec.seed,
                    log_every: self.spec.log_every,
                    storage: self.spec.storage.clone(),
                    pipelined: self.spec.comm.pipelined,
                    inflight: self.spec.comm.inflight,
                    prefetch: self.spec.pipeline.prefetch,
                    prefetch_depth: self.spec.pipeline.depth,
                    kernels: self.spec.kernels,
                };
                let (stats, mut cluster) =
                    run_distributed(&self.dataset, self.manifest.as_ref(), &cfg)?;
                // materialize the trained embeddings into the session state
                let ents = cluster.dump_entities(self.dataset.n_entities(), self.dim());
                let rels = cluster.dump_relations(self.dataset.n_relations(), self.state.rel_dim);
                cluster.shutdown();
                self.state.entities = ents;
                self.state.relations = rels;
                Report::from_dist(&stats)
            }
        };
        if let Some(guard) = trace_guard {
            let data = guard.finish();
            if data.dropped > 0 {
                println!("[obs] trace buffers overflowed: {} events dropped", data.dropped);
            }
            let path = self.spec.obs.trace_path.as_deref().unwrap_or("trace.json");
            std::fs::write(path, data.to_chrome_json())
                .with_context(|| format!("writing trace to {path}"))?;
            println!(
                "[obs] wrote {} trace events to {path} (open in Perfetto / chrome://tracing)",
                data.event_count()
            );
        }
        if self.spec.obs.metrics {
            report.obs_metrics = Some(crate::obs::metrics::global().snapshot());
        }
        if self.spec.eval.is_some() {
            report.metrics = Some(self.evaluate()?);
        }
        report.spec = Some(self.spec.to_json());
        Ok(report)
    }

    /// Evaluate link prediction of the current embeddings on the test
    /// split, under the spec's eval protocol (or the default protocol when
    /// the spec has none). Note: a distributed session holds placeholder
    /// (zero) embeddings until [`Session::train`] dumps the cluster state.
    pub fn evaluate(&self) -> Result<Metrics> {
        let eval_spec = self.spec.eval.clone().unwrap_or_default();
        let mut cfg = eval_spec.to_cfg(self.spec.seed);
        cfg.kernels = self.spec.kernels;
        Ok(evaluate(
            self.spec.model,
            &self.state.entities,
            &self.state.relations,
            &self.dataset,
            &self.dataset.test,
            &cfg,
        ))
    }

    /// The format-2 manifest describing this session's tables under the
    /// given chunk layout (see `serve::manifest`): model, dims, counts,
    /// and order-sensitive vocab hashes, so a [`crate::serve::Snapshot`]
    /// can refuse a checkpoint from a different dataset build.
    fn build_manifest(&self, entities: TableInfo, relations: TableInfo) -> CheckpointManifest {
        CheckpointManifest {
            format_version: FORMAT_VERSION,
            model: self.spec.model,
            dataset: self.spec.dataset.clone(),
            dim: self.dim(),
            rel_dim: self.state.rel_dim,
            n_entities: self.dataset.n_entities(),
            n_relations: self.dataset.n_relations(),
            seed: self.spec.seed,
            entity_vocab_hash: vocab_hash(&self.dataset.entities),
            relation_vocab_hash: vocab_hash(&self.dataset.relations),
            entities,
            relations,
        }
    }

    /// Export the embedding tables to `dir` as a versioned checkpoint:
    /// `manifest.json` (format 2: model, dims, vocab hashes, chunk list —
    /// what `serve::Snapshot` opens), `checkpoint.json` (legacy format-1
    /// metadata, kept so pre-manifest readers still work), and
    /// `entities.f32` / `relations.f32` (length-prefixed little-endian
    /// f32 rows — byte-identical to the legacy layout). Rows are
    /// *streamed* through a bounded buffer
    /// ([`EmbeddingStore::export_rows`]) — no full-table clone, so
    /// checkpointing an mmap-backed table never allocates table-sized
    /// memory.
    pub fn export_embeddings(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let meta = {
            let mut m = std::collections::BTreeMap::new();
            m.insert("version".to_string(), Json::Num(1.0));
            m.insert("dataset".to_string(), Json::Str(self.spec.dataset.clone()));
            m.insert("model".to_string(), Json::Str(self.spec.model.name().to_string()));
            m.insert("dim".to_string(), Json::Num(self.dim() as f64));
            m.insert("rel_dim".to_string(), Json::Num(self.state.rel_dim as f64));
            m.insert("n_entities".to_string(), Json::Num(self.dataset.n_entities() as f64));
            m.insert("n_relations".to_string(), Json::Num(self.dataset.n_relations() as f64));
            m.insert("seed".to_string(), Json::Num(self.spec.seed as f64));
            Json::Obj(m)
        };
        std::fs::write(dir.join("checkpoint.json"), meta.to_string())?;
        for (file, table) in
            [("entities.f32", &self.state.entities), ("relations.f32", &self.state.relations)]
        {
            let path = dir.join(file);
            let f = std::fs::File::create(&path)
                .with_context(|| format!("creating {}", path.display()))?;
            let mut w = std::io::BufWriter::new(f);
            use std::io::Write;
            // same framing as util::bytes::Writer::f32_slice
            w.write_all(&(table.n_params() as u64).to_le_bytes())?;
            table.export_rows(&mut w)?;
            w.flush()?;
        }
        let manifest = self.build_manifest(
            TableInfo::single("entities.f32", self.state.entities.rows(), self.dim()),
            TableInfo::single("relations.f32", self.state.relations.rows(), self.state.rel_dim),
        );
        manifest.save(dir)
    }

    /// Like [`Session::export_embeddings`] but splitting each table into
    /// chunk files of at most `chunk_rows` rows (`entities.00000.f32`,
    /// `entities.00001.f32`, …). Chunked checkpoints are manifest-only —
    /// no `checkpoint.json` is written, because legacy readers cannot
    /// reassemble chunks. Useful when a single table file would exceed a
    /// filesystem or transfer size limit.
    pub fn export_embeddings_chunked(&self, dir: &Path, chunk_rows: usize) -> Result<()> {
        anyhow::ensure!(chunk_rows >= 1, "chunk_rows must be >= 1");
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let mut infos = Vec::new();
        for (stem, table) in
            [("entities", &self.state.entities), ("relations", &self.state.relations)]
        {
            let rows = table.rows();
            let dim = table.dim();
            let mut chunks = Vec::new();
            let mut first = 0usize;
            let mut index = 0usize;
            let mut row_buf = vec![0f32; dim];
            while first < rows || (rows == 0 && index == 0) {
                let take = chunk_rows.min(rows - first.min(rows));
                let file = format!("{stem}.{index:05}.f32");
                let path = dir.join(&file);
                let f = std::fs::File::create(&path)
                    .with_context(|| format!("creating {}", path.display()))?;
                let mut w = std::io::BufWriter::new(f);
                use std::io::Write;
                w.write_all(&((take * dim) as u64).to_le_bytes())?;
                for i in first..first + take {
                    table.read_row(i, &mut row_buf);
                    w.write_all(crate::util::bytes::f32_as_bytes(&row_buf))?;
                }
                w.flush()?;
                chunks.push(ChunkInfo { file, rows: take });
                first += take;
                index += 1;
                if rows == 0 {
                    break;
                }
            }
            infos.push(TableInfo { rows, dim, chunks });
        }
        let relations = infos.pop().ok_or_else(|| anyhow!("missing relations table info"))?;
        let entities = infos.pop().ok_or_else(|| anyhow!("missing entities table info"))?;
        self.build_manifest(entities, relations).save(dir)
    }

    /// Load a checkpoint previously written by [`Session::export_embeddings`]
    /// (or its chunked variant) into this session's embedding tables. The
    /// checkpoint must match the session's model, dims, table sizes, and —
    /// for format-2 checkpoints — vocabulary hashes. Optimizer state is
    /// reset. All validation (format version, metadata consistency, file
    /// sizes, chunk headers) happens *before* any table row is mutated, so
    /// a rejected checkpoint leaves the session state untouched.
    pub fn load_checkpoint(&mut self, dir: &Path) -> Result<()> {
        if dir.join("manifest.json").exists() {
            self.load_checkpoint_v2(dir)
        } else {
            self.load_checkpoint_legacy(dir)
        }
    }

    /// Format-2 path: `manifest.json` + chunk files.
    fn load_checkpoint_v2(&mut self, dir: &Path) -> Result<()> {
        let manifest = CheckpointManifest::load(dir)?;
        manifest
            .validate()
            .with_context(|| format!("inconsistent manifest in {}", dir.display()))?;
        anyhow::ensure!(
            manifest.model == self.spec.model,
            "checkpoint model {:?} does not match session model {:?}",
            manifest.model.name(),
            self.spec.model.name()
        );
        anyhow::ensure!(
            manifest.dim == self.dim(),
            "checkpoint dim {} does not match session dim {}",
            manifest.dim,
            self.dim()
        );
        anyhow::ensure!(
            manifest.rel_dim == self.state.rel_dim,
            "checkpoint rel_dim {} does not match session rel_dim {}",
            manifest.rel_dim,
            self.state.rel_dim
        );
        anyhow::ensure!(
            manifest.n_entities == self.dataset.n_entities(),
            "checkpoint has {} entities, dataset has {}",
            manifest.n_entities,
            self.dataset.n_entities()
        );
        anyhow::ensure!(
            manifest.n_relations == self.dataset.n_relations(),
            "checkpoint has {} relations, dataset has {}",
            manifest.n_relations,
            self.dataset.n_relations()
        );
        anyhow::ensure!(
            manifest.entity_vocab_hash == vocab_hash(&self.dataset.entities),
            "checkpoint entity vocabulary does not match this dataset build \
             (hash {} vs {}) — ids would be silently remapped",
            manifest.entity_vocab_hash,
            vocab_hash(&self.dataset.entities)
        );
        anyhow::ensure!(
            manifest.relation_vocab_hash == vocab_hash(&self.dataset.relations),
            "checkpoint relation vocabulary does not match this dataset build"
        );
        // every chunk file's existence, exact size, and header — before
        // the first set_rows
        manifest.validate_files(dir)?;
        for (table_info, table) in [
            (&manifest.entities, &self.state.entities),
            (&manifest.relations, &self.state.relations),
        ] {
            let mut first = 0usize;
            for chunk in &table_info.chunks {
                read_chunk_into(&dir.join(&chunk.file), first, chunk.rows, table_info.dim, table.as_ref())?;
                first += chunk.rows;
            }
        }
        Ok(())
    }

    /// Legacy format-1 path: `checkpoint.json` + single-file tables. The
    /// `version` field is required and must be exactly 1 — earlier builds
    /// trusted whatever `checkpoint.json` said and would happily stream a
    /// future-format or truncated file into the tables.
    fn load_checkpoint_legacy(&mut self, dir: &Path) -> Result<()> {
        let meta_path = dir.join("checkpoint.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let meta = Json::parse(&text).map_err(|e| anyhow!("bad checkpoint.json: {e}"))?;
        let version = meta.get("version").and_then(Json::as_f64);
        anyhow::ensure!(
            version == Some(1.0),
            "checkpoint.json declares format version {} (this build reads legacy version 1, \
             or format {FORMAT_VERSION} via manifest.json)",
            version.map(|v| v.to_string()).unwrap_or_else(|| "<missing>".to_string())
        );
        let meta_usize = |k: &str| -> Result<usize> {
            meta.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("checkpoint missing {k}"))
        };
        let model = meta.get("model").and_then(Json::as_str).unwrap_or("");
        anyhow::ensure!(
            model == self.spec.model.name(),
            "checkpoint model {model:?} does not match session model {:?}",
            self.spec.model.name()
        );
        anyhow::ensure!(meta_usize("dim")? == self.dim(), "checkpoint dim mismatch");
        anyhow::ensure!(
            meta_usize("rel_dim")? == self.state.rel_dim,
            "checkpoint rel_dim mismatch"
        );
        anyhow::ensure!(
            meta_usize("n_entities")? == self.dataset.n_entities(),
            "checkpoint has {} entities, dataset has {}",
            meta_usize("n_entities")?,
            self.dataset.n_entities()
        );
        anyhow::ensure!(
            meta_usize("n_relations")? == self.dataset.n_relations(),
            "checkpoint relation count mismatch"
        );
        // validate both files' exact on-disk size before mutating either
        // table — a truncated entities.f32 must not leave relations
        // half-loaded (or vice versa)
        for (file, table) in
            [("entities.f32", &self.state.entities), ("relations.f32", &self.state.relations)]
        {
            let path = dir.join(file);
            let need = TABLE_HEADER_BYTES + table.n_params() as u64 * 4;
            let len = std::fs::metadata(&path)
                .with_context(|| format!("reading {}", path.display()))?
                .len();
            anyhow::ensure!(
                len == need,
                "{}: file is {len} bytes, table needs {need} (truncated checkpoint?)",
                path.display()
            );
        }
        for (file, table) in
            [("entities.f32", &self.state.entities), ("relations.f32", &self.state.relations)]
        {
            // stream rows through a bounded buffer — symmetric with
            // export_embeddings, so loading never allocates table-sized
            // memory either
            let path = dir.join(file);
            let f = std::fs::File::open(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let mut rd = std::io::BufReader::new(f);
            use std::io::Read;
            let mut len8 = [0u8; 8];
            rd.read_exact(&mut len8)
                .with_context(|| format!("decoding {}", path.display()))?;
            // lint:allow(narrowing-cast) — guarded: the ensure! below
            // rejects any header that does not exactly match the table
            let n_values = u64::from_le_bytes(len8) as usize;
            anyhow::ensure!(
                n_values == table.n_params(),
                "{file}: expected {} values, found {}",
                table.n_params(),
                n_values
            );
            let dim = table.dim();
            let rows = table.rows();
            if rows == 0 || dim == 0 {
                continue;
            }
            let chunk_rows = crate::store::chunk_rows_for(dim, rows);
            let mut buf = vec![0f32; chunk_rows * dim];
            let mut row = 0;
            while row < rows {
                let take = chunk_rows.min(rows - row);
                let n_values = take * dim;
                // decode straight into the reused f32 buffer (LE hosts)
                let bytes = crate::util::bytes::f32_as_bytes_mut(&mut buf[..n_values]);
                rd.read_exact(bytes)
                    .with_context(|| format!("decoding {}", path.display()))?;
                table.set_rows(row, &buf[..n_values]);
                row += take;
            }
        }
        Ok(())
    }
}

/// Fluent construction of a [`RunSpec`] + [`Session`].
///
/// ```no_run
/// # use dglke::api::Session;
/// # use dglke::models::ModelKind;
/// # fn main() -> anyhow::Result<()> {
/// let mut session = Session::builder()
///     .dataset("fb15k-syn")
///     .model(ModelKind::RotatE)
///     .workers(8)
///     .batches(250)
///     .build()?;
/// let report = session.train()?;
/// println!("{}", report.summary());
/// # Ok(())
/// # }
/// ```
#[derive(Default, Clone, Debug)]
pub struct SessionBuilder {
    spec: RunSpec,
}

impl SessionBuilder {
    pub fn dataset(mut self, name: impl Into<String>) -> Self {
        self.spec.dataset = name.into();
        self
    }

    pub fn model(mut self, model: crate::models::ModelKind) -> Self {
        self.spec.model = model;
        self
    }

    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.spec.backend = backend;
        self
    }

    pub fn artifact_tag(mut self, tag: impl Into<String>) -> Self {
        self.spec.artifact_tag = tag.into();
        self
    }

    /// Single-machine mode with `workers` trainer threads (CPU).
    pub fn workers(mut self, workers: usize) -> Self {
        let gpu = matches!(self.spec.mode, ParallelMode::Single { gpu: true, .. });
        self.spec.mode = ParallelMode::Single { workers, gpu };
        self
    }

    /// Single-machine mode with simulated GPUs (PCIe transfer accounting).
    pub fn gpu(mut self, gpu: bool) -> Self {
        let workers = match self.spec.mode {
            ParallelMode::Single { workers, .. } => workers,
            _ => 1,
        };
        self.spec.mode = ParallelMode::Single { workers, gpu };
        self
    }

    /// Distributed mode over the KVStore cluster.
    pub fn distributed(mut self, machines: usize, trainers: usize, servers: usize) -> Self {
        self.spec.mode = ParallelMode::Distributed {
            machines,
            trainers,
            servers,
            partition: crate::dist::PartitionStrategy::Metis,
            local_negatives: true,
        };
        self
    }

    pub fn partition(mut self, strategy: crate::dist::PartitionStrategy) -> Self {
        if let ParallelMode::Distributed { ref mut partition, .. } = self.spec.mode {
            *partition = strategy;
        }
        self
    }

    pub fn local_negatives(mut self, on: bool) -> Self {
        if let ParallelMode::Distributed { ref mut local_negatives, .. } = self.spec.mode {
            *local_negatives = on;
        }
        self
    }

    pub fn batches(mut self, batches: usize) -> Self {
        self.spec.batches = batches;
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.spec.lr = lr;
        self
    }

    pub fn init_scale(mut self, s: f32) -> Self {
        self.spec.init_scale = s;
        self
    }

    pub fn margin(mut self, margin: f32) -> Self {
        self.spec.loss.margin = Some(margin);
        self
    }

    pub fn adv_temp(mut self, t: f32) -> Self {
        self.spec.loss.adv_temp = Some(t);
        self
    }

    pub fn neg_degree_frac(mut self, f: f64) -> Self {
        self.spec.neg_degree_frac = f;
        self
    }

    pub fn async_update(mut self, on: bool) -> Self {
        self.spec.async_update = on;
        self
    }

    /// Overlap next-batch sample+gather with compute (§3.5). Helps when
    /// gather latency is visible — mmap/sharded storage on one machine,
    /// and *especially* distributed trainers, whose gather is a KVStore
    /// network pull; a wash on dense in-memory tables.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.spec.pipeline.prefetch = on;
        self
    }

    /// Prefetch buffers in flight (>= 2; also the staleness bound).
    pub fn prefetch_depth(mut self, depth: usize) -> Self {
        self.spec.pipeline.depth = depth;
        self
    }

    /// Use the async/pipelined KVStore client in distributed mode (§3.6):
    /// concurrent pull fan-out across servers, pipelined tagged frames,
    /// fire-and-forget pushes behind a drain barrier.
    pub fn comm_pipelined(mut self, on: bool) -> Self {
        self.spec.comm.pipelined = on;
        self
    }

    /// In-flight frames per remote KVStore connection (>= 1).
    pub fn comm_inflight(mut self, inflight: usize) -> Self {
        self.spec.comm.inflight = inflight;
        self
    }

    /// Score/grad kernel backend (`Scalar` reference loops or `Fused`
    /// cache-tiled kernels); results are bit-identical either way.
    pub fn kernels(mut self, kernels: crate::models::KernelBackend) -> Self {
        self.spec.kernels = kernels;
        self
    }

    pub fn relation_partition(mut self, on: bool) -> Self {
        self.spec.relation_partition = on;
        self
    }

    pub fn sync_interval(mut self, n: usize) -> Self {
        self.spec.sync_interval = n;
        self
    }

    pub fn log_every(mut self, n: usize) -> Self {
        self.spec.log_every = n;
        self
    }

    pub fn shape(mut self, shape: StepShape) -> Self {
        self.spec.shape = Some(shape);
        self
    }

    pub fn eval(mut self, eval: super::spec::EvalSpec) -> Self {
        self.spec.eval = Some(eval);
        self
    }

    /// Embedding-storage backend (dense / sharded / mmap).
    pub fn storage(mut self, storage: crate::store::StoreConfig) -> Self {
        self.spec.storage = storage;
        self
    }

    /// Worker threads for the `dglke serve` request loop.
    pub fn serve_threads(mut self, threads: usize) -> Self {
        self.spec.serve.threads = threads;
        self
    }

    /// Max queries handed to one serve worker as one job.
    pub fn serve_batch(mut self, batch: usize) -> Self {
        self.spec.serve.batch = batch;
        self
    }

    /// Default top-k depth for served queries.
    pub fn serve_topk(mut self, topk: usize) -> Self {
        self.spec.serve.topk = topk;
        self
    }

    /// Record tracing spans during `train()` and write Chrome trace-event
    /// JSON (to `obs.trace_path`, default `trace.json`) when it finishes.
    pub fn trace(mut self, on: bool) -> Self {
        self.spec.obs.trace = on;
        self
    }

    /// Where the trace JSON is written (implies nothing unless `trace`
    /// is also set).
    pub fn trace_path(mut self, path: impl Into<String>) -> Self {
        self.spec.obs.trace_path = Some(path.into());
        self
    }

    /// Attach an `obs::metrics` registry snapshot to the train `Report`.
    pub fn obs_metrics(mut self, on: bool) -> Self {
        self.spec.obs.metrics = on;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seed = seed;
        self
    }

    /// The spec assembled so far (e.g. to serialize instead of running).
    pub fn into_spec(self) -> RunSpec {
        self.spec
    }

    pub fn build(self) -> Result<Session> {
        Session::from_spec(self.spec)
    }
}
