//! [`RunSpec`]: the single declarative, JSON-serializable description of a
//! training/evaluation run, shared by the CLI, the repro drivers, the
//! examples, and the benches.
//!
//! # JSON schema
//!
//! ```json
//! {
//!   "dataset": "fb15k-syn",                  // preset name or TSV directory
//!   "model": "transe_l2",                    // Table-1 model name
//!   "loss": {"kind": "logistic"},            // or {"kind": "margin", "margin": 1.5}
//!                                            // optional "adv_temp": 1.0
//!   "backend": "native",                     // "native" | "xla"
//!   "artifact_tag": "default",               // AOT shape family
//!   "mode": {"kind": "single",               // one machine, N workers
//!            "workers": 2, "gpu": false},
//!        // or {"kind": "distributed", "machines": 4, "trainers": 2,
//!        //     "servers": 2, "partition": "metis", "local_negatives": true}
//!   "batches": 200,                          // per worker / per trainer
//!   "lr": 0.25,
//!   "init_scale": 0.37,
//!   "neg_degree_frac": 0.0,                  // §3.3 degree-based negatives
//!   "async_update": true,                    // §3.5 (single-machine only)
//!   "pipeline": {"prefetch": false,          // §3.5 overlap next-batch
//!                "depth": 2},                //   sample+gather (single) or
//!                                            //   sample+pull (distributed)
//!                                            //   with compute; depth =
//!                                            //   buffers in flight (>= 2)
//!   "comm": {"pipelined": false,             // §3.6 async KVStore client:
//!            "inflight": 8},                 //   concurrent pull fan-out,
//!                                            //   pipelined frames, fire-and-
//!                                            //   forget pushes (distributed)
//!   "kernels": "scalar",                     // "scalar" | "fused" score/grad
//!                                            //   kernels (bit-identical; see
//!                                            //   docs/KERNELS.md)
//!   "relation_partition": true,              // §3.4 (single-machine only)
//!   "sync_interval": 500,                    // §3.6 barrier period
//!   "log_every": 50,
//!   "shape": null,                           // or {"batch":256,"chunks":8,
//!                                            //     "neg_k":64,"dim":64}
//!   "eval": null,                            // or {"protocol":"full_filtered",
//!                                            //     "max_triplets":500,"n_threads":4}
//!                                            // or {"protocol":"sampled",
//!                                            //     "uniform":1000,"degree":1000,...}
//!   "storage": {"backend": "dense",          // "dense" | "sharded" | "mmap"
//!               "shards": 8,                 // sharded backend only
//!               "dir": null,                 // mmap backing dir (null = temp)
//!               "budget_mb": null,           // in-memory budget; tables over
//!                                            // it must use the mmap backend
//!               "cache_mb": null},           // mmap hot-row cache size
//!                                            // (default: budget_mb; must
//!                                            // not exceed it)
//!   "serve": {"threads": 2,                  // `dglke serve` request loop:
//!             "batch": 64,                   //   worker threads, queries per
//!             "topk": 10},                   //   dispatched job, default k
//!                                            //   (see docs/SERVING.md)
//!   "obs": {"trace": false,                  // span tracing → Chrome trace
//!           "trace_path": null,              //   JSON (null = trace.json)
//!           "metrics": false},               // registry snapshot in Report
//!                                            //   (see docs/OBSERVABILITY.md)
//!   "seed": 0
//! }
//! ```
//!
//! Every field has a default; a spec file only needs the fields it changes.
//! `RunSpec::from_json` round-trips `RunSpec::to_json` exactly.

use crate::dist::PartitionStrategy;
use crate::models::step::StepShape;
use crate::models::{KernelBackend, LossCfg, LossKind, ModelKind};
use crate::runtime::BackendKind;
use crate::store::{StoreBackendKind, StoreConfig};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// The silent `resolve_shape` fallback of the old CLI, promoted to an
/// explicit, documented default: the step shape used by the native backend
/// when neither the spec nor the artifact manifest provides one.
pub const DEFAULT_NATIVE_SHAPE: StepShape =
    StepShape { batch: 256, chunks: 8, neg_k: 64, dim: 64 };

/// Loss configuration in spec form (margin implies the hinge loss).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct LossSpec {
    /// `Some(γ)` selects the pairwise hinge loss; `None` the logistic loss.
    pub margin: Option<f32>,
    /// self-adversarial temperature α (RotatE-style)
    pub adv_temp: Option<f32>,
}

impl LossSpec {
    pub fn to_cfg(&self) -> LossCfg {
        LossCfg {
            kind: self.margin.map(LossKind::Margin).unwrap_or(LossKind::Logistic),
            adv_temp: self.adv_temp,
        }
    }
}

/// Prefetch-pipeline configuration (§3.5): run sample+gather for batch
/// N+1 on a helper thread while batch N computes. Off by default — it
/// pays off when gather latency is visible (mmap / sharded storage) and
/// is a wash on dense in-memory tables. With synchronous updates and a
/// single worker the pipeline is byte-identical to the sequential loop
/// (prefetched rows dirtied by an update are patched before compute);
/// otherwise staleness is bounded by `depth` batches, the same Hogwild
/// contract as `async_update`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PipelineSpec {
    pub prefetch: bool,
    /// buffers in flight (>= 2; 2 = classic double buffering)
    pub depth: usize,
}

impl Default for PipelineSpec {
    fn default() -> Self {
        PipelineSpec { prefetch: false, depth: 2 }
    }
}

/// Distributed KVStore comms configuration (§3.6). `pipelined` swaps the
/// synchronous per-round-trip client for the async one: per-server I/O
/// worker threads fan a batch's pull out to all owning servers
/// concurrently, up to `inflight` request-tagged frames ride each
/// connection, and gradient pushes are fire-and-forget behind a drain
/// barrier at epoch/run end. Single-trainer runs are byte-identical
/// either way (per-connection frame ordering); see
/// `rust/tests/dist_comm_tests.rs`. Ignored in single-machine mode.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommSpec {
    pub pipelined: bool,
    /// in-flight frames per remote connection (>= 1)
    pub inflight: usize,
}

impl Default for CommSpec {
    fn default() -> Self {
        CommSpec { pipelined: false, inflight: 8 }
    }
}

/// Serving request-loop configuration for `dglke serve`: the shape of the
/// [`crate::serve::ServeHandle`] worker pool answering top-k queries
/// against a checkpoint snapshot. Ignored by training/eval runs; see
/// `docs/SERVING.md`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeSpec {
    /// worker threads answering queries
    pub threads: usize,
    /// max queries handed to one worker as one job
    pub batch: usize,
    /// default top-k depth when the caller doesn't pass one
    pub topk: usize,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec { threads: 2, batch: 64, topk: 10 }
    }
}

/// Observability configuration (`obs::trace` spans + `obs::metrics`
/// registry snapshots). Both default to off; either way training output
/// is byte-identical — spans and metrics observe, they never steer (the
/// equivalence matrix in `rust/tests/obs_tests.rs` enforces this). See
/// `docs/OBSERVABILITY.md`.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ObsSpec {
    /// record begin/end spans and write Chrome trace-event JSON at the
    /// end of the run
    pub trace: bool,
    /// where the trace JSON goes (`None` = `trace.json` in the cwd);
    /// only meaningful with `trace: true`
    pub trace_path: Option<String>,
    /// attach a metrics-registry snapshot to the run's `Report`
    pub metrics: bool,
}

/// Hardware/parallelism mode of a run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ParallelMode {
    /// One machine: `workers` trainer threads over shared memory, optionally
    /// billing a simulated PCIe link per worker (`gpu`).
    Single { workers: usize, gpu: bool },
    /// `machines × trainers` trainer threads over the KVStore cluster.
    Distributed {
        machines: usize,
        trainers: usize,
        servers: usize,
        partition: PartitionStrategy,
        local_negatives: bool,
    },
}

impl Default for ParallelMode {
    fn default() -> Self {
        ParallelMode::Single { workers: 1, gpu: false }
    }
}

/// Evaluation protocol in spec form.
#[derive(Clone, Debug, PartialEq)]
pub enum EvalProtocolSpec {
    /// rank against all corrupted candidates, filtered (paper protocol 1)
    FullFiltered,
    /// rank against sampled negatives, unfiltered (paper protocol 2)
    Sampled { uniform: usize, degree: usize },
}

#[derive(Clone, Debug, PartialEq)]
pub struct EvalSpec {
    pub protocol: EvalProtocolSpec,
    /// evaluate at most this many test triplets (0 = all)
    pub max_triplets: usize,
    pub n_threads: usize,
}

impl Default for EvalSpec {
    fn default() -> Self {
        EvalSpec { protocol: EvalProtocolSpec::FullFiltered, max_triplets: 500, n_threads: 4 }
    }
}

impl EvalSpec {
    pub fn to_cfg(&self, seed: u64) -> crate::eval::EvalConfig {
        crate::eval::EvalConfig {
            protocol: match self.protocol {
                EvalProtocolSpec::FullFiltered => crate::eval::EvalProtocol::FullFiltered,
                EvalProtocolSpec::Sampled { uniform, degree } => {
                    crate::eval::EvalProtocol::Sampled { uniform, degree }
                }
            },
            max_triplets: self.max_triplets,
            n_threads: self.n_threads,
            seed,
            // the session layer overrides this from `RunSpec.kernels`
            kernels: KernelBackend::Scalar,
        }
    }
}

/// A complete, declarative description of one run. See the module docs for
/// the JSON schema.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    pub dataset: String,
    pub model: ModelKind,
    pub loss: LossSpec,
    pub backend: BackendKind,
    pub artifact_tag: String,
    pub mode: ParallelMode,
    /// batches per worker (single) / per trainer (distributed)
    pub batches: usize,
    pub lr: f32,
    pub init_scale: f32,
    pub neg_degree_frac: f64,
    pub async_update: bool,
    /// async prefetch pipeline: overlap next-batch sample+gather (single
    /// machine) or sample+KVStore-pull (distributed) with compute
    pub pipeline: PipelineSpec,
    /// distributed KVStore comms (async/pipelined client); ignored in
    /// single-machine mode
    pub comm: CommSpec,
    /// score/grad kernel backend (`scalar` reference loops or `fused`
    /// cache-tiled kernels); bit-identical results either way — see
    /// `docs/KERNELS.md` and `rust/tests/kernel_parity_tests.rs`
    pub kernels: KernelBackend,
    pub relation_partition: bool,
    pub sync_interval: usize,
    pub log_every: usize,
    /// explicit step shape; `None` = resolve from artifacts, falling back to
    /// [`DEFAULT_NATIVE_SHAPE`] on the native backend
    pub shape: Option<StepShape>,
    /// evaluation to run after training (`None` = skip)
    pub eval: Option<EvalSpec>,
    /// embedding-storage backend (dense / sharded / mmap) and its knobs
    pub storage: StoreConfig,
    /// `dglke serve` request-loop shape; ignored by training/eval
    pub serve: ServeSpec,
    /// tracing spans + metrics snapshot (both off by default; never
    /// affect training output)
    pub obs: ObsSpec,
    /// limited to 2^53 so the JSON round-trip (f64 numbers) is exact;
    /// `validate()` rejects larger seeds
    pub seed: u64,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            dataset: "fb15k-syn".into(),
            model: ModelKind::TransEL2,
            loss: LossSpec::default(),
            backend: BackendKind::Native,
            artifact_tag: "default".into(),
            mode: ParallelMode::default(),
            batches: 200,
            lr: 0.3,
            init_scale: 0.37,
            neg_degree_frac: 0.0,
            async_update: true,
            pipeline: PipelineSpec::default(),
            comm: CommSpec::default(),
            kernels: KernelBackend::Scalar,
            relation_partition: true,
            sync_interval: 500,
            log_every: 50,
            shape: None,
            eval: None,
            storage: StoreConfig::default(),
            serve: ServeSpec::default(),
            obs: ObsSpec::default(),
            seed: 0,
        }
    }
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn shape_to_json(s: &StepShape) -> Json {
    obj(vec![
        ("batch", Json::Num(s.batch as f64)),
        ("chunks", Json::Num(s.chunks as f64)),
        ("neg_k", Json::Num(s.neg_k as f64)),
        ("dim", Json::Num(s.dim as f64)),
    ])
}

fn opt_num(j: &Json, key: &str) -> Option<f64> {
    match j.get(key) {
        Some(Json::Null) | None => None,
        Some(v) => v.as_f64(),
    }
}

fn req_usize(j: &Json, key: &str, what: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("{what}: missing or non-numeric field {key:?}"))
}

fn get_usize(j: &Json, key: &str, default: usize) -> Result<usize> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_usize().ok_or_else(|| anyhow!("field {key:?} must be a number")),
    }
}

fn get_f64(j: &Json, key: &str, default: f64) -> Result<f64> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| anyhow!("field {key:?} must be a number")),
    }
}

fn get_bool(j: &Json, key: &str, default: bool) -> Result<bool> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        Some(_) => bail!("field {key:?} must be a boolean"),
    }
}

fn get_str(j: &Json, key: &str, default: &str) -> Result<String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default.to_string()),
        Some(Json::Str(s)) => Ok(s.clone()),
        Some(_) => bail!("field {key:?} must be a string"),
    }
}

impl RunSpec {
    /// Serialize to the documented JSON form. `from_json` inverts this
    /// exactly (`parse(to_json(s)) == s`).
    pub fn to_json(&self) -> Json {
        let loss = {
            let mut entries = vec![(
                "kind",
                Json::Str(if self.loss.margin.is_some() { "margin" } else { "logistic" }.into()),
            )];
            if let Some(m) = self.loss.margin {
                entries.push(("margin", Json::Num(m as f64)));
            }
            if let Some(a) = self.loss.adv_temp {
                entries.push(("adv_temp", Json::Num(a as f64)));
            }
            obj(entries)
        };
        let mode = match &self.mode {
            ParallelMode::Single { workers, gpu } => obj(vec![
                ("kind", Json::Str("single".into())),
                ("workers", Json::Num(*workers as f64)),
                ("gpu", Json::Bool(*gpu)),
            ]),
            ParallelMode::Distributed { machines, trainers, servers, partition, local_negatives } => {
                obj(vec![
                    ("kind", Json::Str("distributed".into())),
                    ("machines", Json::Num(*machines as f64)),
                    ("trainers", Json::Num(*trainers as f64)),
                    ("servers", Json::Num(*servers as f64)),
                    ("partition", Json::Str(partition.name().into())),
                    ("local_negatives", Json::Bool(*local_negatives)),
                ])
            }
        };
        let eval = match &self.eval {
            None => Json::Null,
            Some(e) => {
                let mut entries = match e.protocol {
                    EvalProtocolSpec::FullFiltered => {
                        vec![("protocol", Json::Str("full_filtered".into()))]
                    }
                    EvalProtocolSpec::Sampled { uniform, degree } => vec![
                        ("protocol", Json::Str("sampled".into())),
                        ("uniform", Json::Num(uniform as f64)),
                        ("degree", Json::Num(degree as f64)),
                    ],
                };
                entries.push(("max_triplets", Json::Num(e.max_triplets as f64)));
                entries.push(("n_threads", Json::Num(e.n_threads as f64)));
                obj(entries)
            }
        };
        let storage = obj(vec![
            ("backend", Json::Str(self.storage.backend.name().into())),
            ("shards", Json::Num(self.storage.shards as f64)),
            (
                "dir",
                self.storage.dir.as_ref().map(|d| Json::Str(d.clone())).unwrap_or(Json::Null),
            ),
            ("budget_mb", self.storage.budget_mb.map(Json::Num).unwrap_or(Json::Null)),
            ("cache_mb", self.storage.cache_mb.map(Json::Num).unwrap_or(Json::Null)),
        ]);
        obj(vec![
            ("dataset", Json::Str(self.dataset.clone())),
            ("model", Json::Str(self.model.name().into())),
            ("loss", loss),
            (
                "backend",
                Json::Str(match self.backend {
                    BackendKind::Xla => "xla".into(),
                    BackendKind::Native => "native".into(),
                }),
            ),
            ("artifact_tag", Json::Str(self.artifact_tag.clone())),
            ("mode", mode),
            ("batches", Json::Num(self.batches as f64)),
            ("lr", Json::Num(self.lr as f64)),
            ("init_scale", Json::Num(self.init_scale as f64)),
            ("neg_degree_frac", Json::Num(self.neg_degree_frac)),
            ("async_update", Json::Bool(self.async_update)),
            (
                "pipeline",
                obj(vec![
                    ("prefetch", Json::Bool(self.pipeline.prefetch)),
                    ("depth", Json::Num(self.pipeline.depth as f64)),
                ]),
            ),
            (
                "comm",
                obj(vec![
                    ("pipelined", Json::Bool(self.comm.pipelined)),
                    ("inflight", Json::Num(self.comm.inflight as f64)),
                ]),
            ),
            ("kernels", Json::Str(self.kernels.name().into())),
            ("relation_partition", Json::Bool(self.relation_partition)),
            ("sync_interval", Json::Num(self.sync_interval as f64)),
            ("log_every", Json::Num(self.log_every as f64)),
            ("shape", self.shape.as_ref().map(shape_to_json).unwrap_or(Json::Null)),
            ("eval", eval),
            ("storage", storage),
            (
                "serve",
                obj(vec![
                    ("threads", Json::Num(self.serve.threads as f64)),
                    ("batch", Json::Num(self.serve.batch as f64)),
                    ("topk", Json::Num(self.serve.topk as f64)),
                ]),
            ),
            (
                "obs",
                obj(vec![
                    ("trace", Json::Bool(self.obs.trace)),
                    (
                        "trace_path",
                        self.obs
                            .trace_path
                            .as_ref()
                            .map(|p| Json::Str(p.clone()))
                            .unwrap_or(Json::Null),
                    ),
                    ("metrics", Json::Bool(self.obs.metrics)),
                ]),
            ),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse the documented JSON form. Missing fields take their
    /// [`RunSpec::default`] values; unknown enum values are errors.
    pub fn from_json(j: &Json) -> Result<RunSpec> {
        let d = RunSpec::default();
        let model_name = get_str(j, "model", d.model.name())?;
        let model = ModelKind::parse(&model_name)
            .ok_or_else(|| anyhow!("unknown model {model_name:?}"))?;
        let backend_name = get_str(j, "backend", "native")?;
        let backend = BackendKind::parse(&backend_name)
            .ok_or_else(|| anyhow!("unknown backend {backend_name:?}"))?;
        let kernels_name = get_str(j, "kernels", d.kernels.name())?;
        let kernels = KernelBackend::parse(&kernels_name)
            .ok_or_else(|| anyhow!("unknown kernels backend {kernels_name:?}"))?;

        let loss = match j.get("loss") {
            None | Some(Json::Null) => LossSpec::default(),
            Some(l) => {
                let margin = opt_num(l, "margin").map(|v| v as f32);
                let adv_temp = opt_num(l, "adv_temp").map(|v| v as f32);
                // a bare "margin" implies the hinge loss, matching LossSpec
                let default_kind = if margin.is_some() { "margin" } else { "logistic" };
                let kind = get_str(l, "kind", default_kind)?;
                match kind.as_str() {
                    "logistic" => {
                        anyhow::ensure!(
                            margin.is_none(),
                            "loss.margin is set but loss.kind is \"logistic\" — \
                             use kind \"margin\" or drop the margin field"
                        );
                        LossSpec { margin: None, adv_temp }
                    }
                    "margin" => LossSpec { margin: Some(margin.unwrap_or(1.0)), adv_temp },
                    other => bail!("unknown loss kind {other:?}"),
                }
            }
        };

        let mode = match j.get("mode") {
            None | Some(Json::Null) => ParallelMode::default(),
            Some(m) => match get_str(m, "kind", "single")?.as_str() {
                "single" => ParallelMode::Single {
                    workers: get_usize(m, "workers", 1)?,
                    gpu: get_bool(m, "gpu", false)?,
                },
                "distributed" => {
                    let part_name = get_str(m, "partition", "metis")?;
                    ParallelMode::Distributed {
                        machines: get_usize(m, "machines", 4)?,
                        trainers: get_usize(m, "trainers", 2)?,
                        servers: get_usize(m, "servers", 2)?,
                        partition: PartitionStrategy::parse(&part_name)
                            .ok_or_else(|| anyhow!("unknown partition {part_name:?}"))?,
                        local_negatives: get_bool(m, "local_negatives", true)?,
                    }
                }
                other => bail!("unknown mode kind {other:?}"),
            },
        };

        let shape = match j.get("shape") {
            None | Some(Json::Null) => None,
            Some(s) => Some(StepShape {
                batch: req_usize(s, "batch", "shape")?,
                chunks: req_usize(s, "chunks", "shape")?,
                neg_k: req_usize(s, "neg_k", "shape")?,
                dim: req_usize(s, "dim", "shape")?,
            }),
        };

        let eval = match j.get("eval") {
            None | Some(Json::Null) => None,
            Some(e) => {
                let protocol = match get_str(e, "protocol", "full_filtered")?.as_str() {
                    "full_filtered" => EvalProtocolSpec::FullFiltered,
                    "sampled" => EvalProtocolSpec::Sampled {
                        uniform: get_usize(e, "uniform", 1000)?,
                        degree: get_usize(e, "degree", 1000)?,
                    },
                    other => bail!("unknown eval protocol {other:?}"),
                };
                Some(EvalSpec {
                    protocol,
                    max_triplets: get_usize(e, "max_triplets", 500)?,
                    n_threads: get_usize(e, "n_threads", 4)?,
                })
            }
        };

        let pipeline = match j.get("pipeline") {
            None | Some(Json::Null) => PipelineSpec::default(),
            Some(p) => PipelineSpec {
                prefetch: get_bool(p, "prefetch", PipelineSpec::default().prefetch)?,
                depth: get_usize(p, "depth", PipelineSpec::default().depth)?,
            },
        };

        let comm = match j.get("comm") {
            None | Some(Json::Null) => CommSpec::default(),
            Some(c) => CommSpec {
                pipelined: get_bool(c, "pipelined", CommSpec::default().pipelined)?,
                inflight: get_usize(c, "inflight", CommSpec::default().inflight)?,
            },
        };

        let serve = match j.get("serve") {
            None | Some(Json::Null) => ServeSpec::default(),
            Some(s) => ServeSpec {
                threads: get_usize(s, "threads", ServeSpec::default().threads)?,
                batch: get_usize(s, "batch", ServeSpec::default().batch)?,
                topk: get_usize(s, "topk", ServeSpec::default().topk)?,
            },
        };

        let obs = match j.get("obs") {
            None | Some(Json::Null) => ObsSpec::default(),
            Some(o) => {
                let trace_path = match o.get("trace_path") {
                    None | Some(Json::Null) => None,
                    Some(Json::Str(p)) => Some(p.clone()),
                    Some(_) => bail!("field \"obs.trace_path\" must be a string"),
                };
                ObsSpec {
                    trace: get_bool(o, "trace", false)?,
                    trace_path,
                    metrics: get_bool(o, "metrics", false)?,
                }
            }
        };

        let storage = match j.get("storage") {
            None | Some(Json::Null) => StoreConfig::default(),
            Some(s) => {
                let backend_name = get_str(s, "backend", "dense")?;
                let backend = StoreBackendKind::parse(&backend_name)
                    .ok_or_else(|| anyhow!("unknown storage backend {backend_name:?}"))?;
                let dir = match s.get("dir") {
                    None | Some(Json::Null) => None,
                    Some(Json::Str(d)) => Some(d.clone()),
                    Some(_) => bail!("field \"storage.dir\" must be a string"),
                };
                let budget_mb = match s.get("budget_mb") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(v.as_f64().ok_or_else(|| {
                        anyhow!("field \"storage.budget_mb\" must be a number")
                    })?),
                };
                let cache_mb = match s.get("cache_mb") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(v.as_f64().ok_or_else(|| {
                        anyhow!("field \"storage.cache_mb\" must be a number")
                    })?),
                };
                StoreConfig {
                    backend,
                    shards: get_usize(s, "shards", StoreConfig::default().shards)?,
                    dir,
                    budget_mb,
                    cache_mb,
                }
            }
        };

        Ok(RunSpec {
            dataset: get_str(j, "dataset", &d.dataset)?,
            model,
            loss,
            backend,
            artifact_tag: get_str(j, "artifact_tag", &d.artifact_tag)?,
            mode,
            batches: get_usize(j, "batches", d.batches)?,
            lr: get_f64(j, "lr", d.lr as f64)? as f32,
            init_scale: get_f64(j, "init_scale", d.init_scale as f64)? as f32,
            neg_degree_frac: get_f64(j, "neg_degree_frac", d.neg_degree_frac)?,
            async_update: get_bool(j, "async_update", d.async_update)?,
            pipeline,
            comm,
            kernels,
            relation_partition: get_bool(j, "relation_partition", d.relation_partition)?,
            sync_interval: get_usize(j, "sync_interval", d.sync_interval)?,
            log_every: get_usize(j, "log_every", d.log_every)?,
            shape,
            eval,
            storage,
            serve,
            obs,
            seed: get_usize(j, "seed", d.seed as usize)? as u64,
        })
    }

    pub fn from_json_str(s: &str) -> Result<RunSpec> {
        let j = Json::parse(s).map_err(|e| anyhow!("spec is not valid JSON: {e}"))?;
        Self::from_json(&j)
    }

    /// Structural validation (cheap; no dataset/artifact access).
    pub fn validate(&self) -> Result<()> {
        match &self.mode {
            ParallelMode::Single { workers, .. } => {
                anyhow::ensure!(*workers >= 1, "mode.workers must be >= 1");
            }
            ParallelMode::Distributed { machines, trainers, servers, .. } => {
                anyhow::ensure!(*machines >= 1, "mode.machines must be >= 1");
                anyhow::ensure!(*trainers >= 1, "mode.trainers must be >= 1");
                anyhow::ensure!(*servers >= 1, "mode.servers must be >= 1");
            }
        }
        anyhow::ensure!(self.batches >= 1, "batches must be >= 1");
        anyhow::ensure!(self.lr > 0.0, "lr must be positive");
        if let Some(s) = &self.shape {
            anyhow::ensure!(
                s.batch > 0 && s.chunks > 0 && s.neg_k > 0 && s.dim > 0,
                "shape fields must be positive"
            );
            anyhow::ensure!(
                s.batch % s.chunks == 0,
                "shape.batch ({}) must be divisible by shape.chunks ({})",
                s.batch,
                s.chunks
            );
            anyhow::ensure!(
                self.model.validate_dim(s.dim),
                "model {} requires an even dim, got {}",
                self.model.name(),
                s.dim
            );
        }
        anyhow::ensure!(self.sync_interval >= 1, "sync_interval must be >= 1");
        anyhow::ensure!(
            (2..=16).contains(&self.pipeline.depth),
            "pipeline.depth must be in [2, 16] (double buffering needs 2 buffers; \
             more than 16 only grows staleness), got {}",
            self.pipeline.depth
        );
        anyhow::ensure!(
            (1..=64).contains(&self.comm.inflight),
            "comm.inflight must be in [1, 64] (frames in flight per connection; \
             more than 64 only grows memory and ack latency), got {}",
            self.comm.inflight
        );
        self.storage.validate()?;
        anyhow::ensure!(
            (1..=256).contains(&self.serve.threads),
            "serve.threads must be in [1, 256], got {}",
            self.serve.threads
        );
        anyhow::ensure!(
            (1..=65536).contains(&self.serve.batch),
            "serve.batch must be in [1, 65536], got {}",
            self.serve.batch
        );
        anyhow::ensure!(self.serve.topk >= 1, "serve.topk must be >= 1");
        anyhow::ensure!(
            self.obs.trace_path.is_none() || self.obs.trace,
            "obs.trace_path is set but obs.trace is false — enable tracing \
             or drop the path"
        );
        anyhow::ensure!(
            self.seed <= (1u64 << 53),
            "seed {} exceeds 2^53 and would not survive the JSON round-trip",
            self.seed
        );
        Ok(())
    }

    /// Number of trainer threads this spec launches.
    pub fn n_workers(&self) -> usize {
        match &self.mode {
            ParallelMode::Single { workers, .. } => *workers,
            ParallelMode::Distributed { machines, trainers, .. } => machines * trainers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_round_trips() {
        let spec = RunSpec::default();
        let s = spec.to_json_string();
        let back = RunSpec::from_json_str(&s).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn full_spec_round_trips() {
        let spec = RunSpec {
            dataset: "wn18-syn".into(),
            model: ModelKind::RotatE,
            loss: LossSpec { margin: Some(6.0), adv_temp: Some(0.5) },
            backend: BackendKind::Xla,
            artifact_tag: "tiny".into(),
            mode: ParallelMode::Distributed {
                machines: 4,
                trainers: 2,
                servers: 2,
                partition: PartitionStrategy::Random,
                local_negatives: false,
            },
            batches: 77,
            lr: 0.125,
            init_scale: 0.5,
            neg_degree_frac: 0.25,
            async_update: false,
            pipeline: PipelineSpec { prefetch: true, depth: 3 },
            comm: CommSpec { pipelined: true, inflight: 16 },
            kernels: KernelBackend::Fused,
            relation_partition: false,
            sync_interval: 64,
            log_every: 5,
            shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
            eval: Some(EvalSpec {
                protocol: EvalProtocolSpec::Sampled { uniform: 100, degree: 50 },
                max_triplets: 40,
                n_threads: 2,
            }),
            storage: StoreConfig {
                backend: StoreBackendKind::Mmap,
                shards: 4,
                dir: Some("/tmp/dglke-tables".into()),
                budget_mb: Some(512.5),
                cache_mb: Some(128.25),
            },
            serve: ServeSpec { threads: 4, batch: 32, topk: 100 },
            obs: ObsSpec {
                trace: true,
                trace_path: Some("/tmp/dglke-trace.json".into()),
                metrics: true,
            },
            seed: 99,
        };
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn storage_spec_parses_and_defaults() {
        let spec = RunSpec::from_json_str(r#"{"storage": {"backend": "sharded", "shards": 16}}"#)
            .unwrap();
        assert_eq!(spec.storage.backend, StoreBackendKind::Sharded);
        assert_eq!(spec.storage.shards, 16);
        assert_eq!(spec.storage.dir, None);
        // absent → dense default
        let spec = RunSpec::from_json_str("{}").unwrap();
        assert_eq!(spec.storage, StoreConfig::default());
        // unknown backend rejected
        assert!(RunSpec::from_json_str(r#"{"storage": {"backend": "ssd"}}"#).is_err());
        // wrong-typed budget rejected, not silently dropped
        assert!(RunSpec::from_json_str(r#"{"storage": {"budget_mb": "256"}}"#).is_err());
        // cache_mb parses, round-trips, and rejects wrong types
        let spec = RunSpec::from_json_str(
            r#"{"storage": {"backend": "mmap", "budget_mb": 64, "cache_mb": 16.5}}"#,
        )
        .unwrap();
        assert_eq!(spec.storage.cache_mb, Some(16.5));
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(spec, back);
        assert!(RunSpec::from_json_str(r#"{"storage": {"cache_mb": "big"}}"#).is_err());
        // negative cache rejected by validation
        let mut spec = RunSpec::default();
        spec.storage.cache_mb = Some(-1.0);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn pipeline_spec_parses_and_validates() {
        // absent → off, depth 2
        let spec = RunSpec::from_json_str("{}").unwrap();
        assert_eq!(spec.pipeline, PipelineSpec::default());
        assert!(!spec.pipeline.prefetch);
        // partial object fills defaults
        let spec = RunSpec::from_json_str(r#"{"pipeline": {"prefetch": true}}"#).unwrap();
        assert_eq!(spec.pipeline, PipelineSpec { prefetch: true, depth: 2 });
        // explicit depth round-trips
        let spec = RunSpec::from_json_str(r#"{"pipeline": {"prefetch": true, "depth": 4}}"#)
            .unwrap();
        assert_eq!(spec.pipeline.depth, 4);
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(spec, back);
        // wrong types rejected
        assert!(RunSpec::from_json_str(r#"{"pipeline": {"prefetch": "yes"}}"#).is_err());
        assert!(RunSpec::from_json_str(r#"{"pipeline": {"depth": "two"}}"#).is_err());
        // depth bounds enforced by validate
        let mut spec = RunSpec::default();
        spec.pipeline.depth = 1;
        assert!(spec.validate().is_err(), "depth 1 cannot double-buffer");
        spec.pipeline.depth = 17;
        assert!(spec.validate().is_err(), "depth 17 exceeds the staleness cap");
        spec.pipeline.depth = 2;
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn comm_spec_parses_and_validates() {
        // absent → sync client, inflight 8
        let spec = RunSpec::from_json_str("{}").unwrap();
        assert_eq!(spec.comm, CommSpec::default());
        assert!(!spec.comm.pipelined);
        // partial object fills defaults
        let spec = RunSpec::from_json_str(r#"{"comm": {"pipelined": true}}"#).unwrap();
        assert_eq!(spec.comm, CommSpec { pipelined: true, inflight: 8 });
        // explicit inflight round-trips
        let spec =
            RunSpec::from_json_str(r#"{"comm": {"pipelined": true, "inflight": 4}}"#).unwrap();
        assert_eq!(spec.comm.inflight, 4);
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(spec, back);
        // wrong types rejected
        assert!(RunSpec::from_json_str(r#"{"comm": {"pipelined": "yes"}}"#).is_err());
        assert!(RunSpec::from_json_str(r#"{"comm": {"inflight": "deep"}}"#).is_err());
        // inflight bounds enforced by validate
        let mut spec = RunSpec::default();
        spec.comm.inflight = 0;
        assert!(spec.validate().is_err(), "a zero window cannot make progress");
        spec.comm.inflight = 65;
        assert!(spec.validate().is_err(), "inflight past the cap");
        spec.comm.inflight = 1;
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn serve_spec_parses_and_validates() {
        // absent → 2 threads, batch 64, topk 10
        let spec = RunSpec::from_json_str("{}").unwrap();
        assert_eq!(spec.serve, ServeSpec::default());
        // partial object fills defaults
        let spec = RunSpec::from_json_str(r#"{"serve": {"threads": 8}}"#).unwrap();
        assert_eq!(spec.serve, ServeSpec { threads: 8, batch: 64, topk: 10 });
        // explicit values round-trip
        let spec =
            RunSpec::from_json_str(r#"{"serve": {"threads": 3, "batch": 7, "topk": 1}}"#).unwrap();
        assert_eq!(spec.serve, ServeSpec { threads: 3, batch: 7, topk: 1 });
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(spec, back);
        // wrong types rejected
        assert!(RunSpec::from_json_str(r#"{"serve": {"threads": "many"}}"#).is_err());
        assert!(RunSpec::from_json_str(r#"{"serve": {"topk": true}}"#).is_err());
        // bounds enforced by validate
        let mut spec = RunSpec::default();
        spec.serve.threads = 0;
        assert!(spec.validate().is_err(), "a threadless pool cannot serve");
        spec.serve.threads = 257;
        assert!(spec.validate().is_err(), "threads past the cap");
        spec.serve.threads = 1;
        spec.serve.batch = 0;
        assert!(spec.validate().is_err(), "empty jobs make no progress");
        spec.serve.batch = 1;
        spec.serve.topk = 0;
        assert!(spec.validate().is_err(), "top-0 answers nothing");
        spec.serve.topk = 1;
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn obs_spec_parses_and_validates() {
        // absent → everything off
        let spec = RunSpec::from_json_str("{}").unwrap();
        assert_eq!(spec.obs, ObsSpec::default());
        assert!(!spec.obs.trace && !spec.obs.metrics);
        // partial object fills defaults
        let spec = RunSpec::from_json_str(r#"{"obs": {"trace": true}}"#).unwrap();
        assert_eq!(spec.obs, ObsSpec { trace: true, trace_path: None, metrics: false });
        assert!(spec.validate().is_ok());
        // explicit path round-trips
        let spec = RunSpec::from_json_str(
            r#"{"obs": {"trace": true, "trace_path": "out/t.json", "metrics": true}}"#,
        )
        .unwrap();
        assert_eq!(spec.obs.trace_path.as_deref(), Some("out/t.json"));
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(spec, back);
        // wrong types rejected
        assert!(RunSpec::from_json_str(r#"{"obs": {"trace": "on"}}"#).is_err());
        assert!(RunSpec::from_json_str(r#"{"obs": {"trace_path": 7}}"#).is_err());
        // a path without tracing is a config mistake, not a silent no-op
        let mut spec = RunSpec::default();
        spec.obs.trace_path = Some("t.json".into());
        assert!(spec.validate().is_err(), "trace_path without trace");
        spec.obs.trace = true;
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn kernels_spec_parses_and_round_trips() {
        // absent → scalar reference
        let spec = RunSpec::from_json_str("{}").unwrap();
        assert_eq!(spec.kernels, KernelBackend::Scalar);
        // explicit fused round-trips
        let spec = RunSpec::from_json_str(r#"{"kernels": "fused"}"#).unwrap();
        assert_eq!(spec.kernels, KernelBackend::Fused);
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(spec, back);
        // case-insensitive, like the other enums
        let spec = RunSpec::from_json_str(r#"{"kernels": "FUSED"}"#).unwrap();
        assert_eq!(spec.kernels, KernelBackend::Fused);
        // wrong type rejected
        assert!(RunSpec::from_json_str(r#"{"kernels": 8}"#).is_err());
    }

    #[test]
    fn sparse_spec_uses_defaults() {
        let spec = RunSpec::from_json_str(r#"{"dataset": "tiny", "batches": 7}"#).unwrap();
        assert_eq!(spec.dataset, "tiny");
        assert_eq!(spec.batches, 7);
        assert_eq!(spec.model, ModelKind::TransEL2);
        assert_eq!(spec.mode, ParallelMode::Single { workers: 1, gpu: false });
    }

    #[test]
    fn unknown_enum_values_rejected() {
        assert!(RunSpec::from_json_str(r#"{"model": "gpt"}"#).is_err());
        assert!(RunSpec::from_json_str(r#"{"backend": "cuda"}"#).is_err());
        assert!(RunSpec::from_json_str(r#"{"loss": {"kind": "hinge2"}}"#).is_err());
        assert!(RunSpec::from_json_str(r#"{"mode": {"kind": "tpu-pod"}}"#).is_err());
        assert!(RunSpec::from_json_str(r#"{"kernels": "avx999"}"#).is_err());
        assert!(
            RunSpec::from_json_str(r#"{"mode": {"kind":"distributed","partition":"spectral"}}"#)
                .is_err()
        );
    }

    #[test]
    fn validation_catches_bad_specs() {
        let mut spec = RunSpec::default();
        spec.mode = ParallelMode::Single { workers: 0, gpu: false };
        assert!(spec.validate().is_err());

        let mut spec = RunSpec::default();
        spec.batches = 0;
        assert!(spec.validate().is_err());

        let mut spec = RunSpec::default();
        spec.model = ModelKind::RotatE;
        spec.shape = Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 15 });
        assert!(spec.validate().is_err(), "rotate needs even dim");

        let mut spec = RunSpec::default();
        spec.shape = Some(StepShape { batch: 30, chunks: 4, neg_k: 8, dim: 16 });
        assert!(spec.validate().is_err(), "batch must divide by chunks");

        let mut spec = RunSpec::default();
        spec.storage.shards = 0;
        assert!(spec.validate().is_err(), "zero shards");

        let mut spec = RunSpec::default();
        spec.storage.budget_mb = Some(-1.0);
        assert!(spec.validate().is_err(), "negative budget");
    }
}
