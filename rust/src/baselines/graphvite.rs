//! GraphVite-style baseline trainer (paper §4, Fig 9/10).
//!
//! GraphVite's multi-GPU strategy: construct a *subgraph episode* (a
//! subset of entities and the triplets among them), move the episode's
//! embeddings to GPU memory once, run many mini-batches entirely inside
//! the episode, then write the embeddings back. This minimizes CPU↔GPU
//! transfer at the cost of **staleness**: during an episode a worker
//! neither sees other workers' updates nor touches entities outside its
//! subgraph — which is exactly why the paper observes GraphVite needs
//! thousands of epochs where DGL-KE needs < 100.
//!
//! Episode embeddings live in a private copy (the "GPU buffer"); the
//! transfer ledger bills the copy-in/copy-out.

use crate::kg::Dataset;
use crate::models::step::{StepInputs, StepShape};
use crate::models::{LossCfg, ModelKind};
use crate::runtime::{BackendKind, Manifest, TrainBackend};
use crate::store::{DenseStore, EmbeddingStore, SparseAdagrad};
use crate::train::device::TransferLedger;
use crate::train::worker::ModelState;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct GraphViteConfig {
    pub model: ModelKind,
    pub loss: LossCfg,
    pub backend: BackendKind,
    pub artifact_tag: String,
    pub shape: Option<StepShape>,
    pub n_workers: usize,
    /// entities per episode subgraph
    pub episode_entities: usize,
    /// batches run inside one episode before writing back
    pub episode_batches: usize,
    pub total_batches_per_worker: usize,
    pub lr: f32,
    pub init_scale: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for GraphViteConfig {
    fn default() -> Self {
        GraphViteConfig {
            model: ModelKind::TransEL2,
            loss: LossCfg::default(),
            backend: BackendKind::Native,
            artifact_tag: "default".into(),
            shape: None,
            n_workers: 1,
            episode_entities: 4096,
            episode_batches: 50,
            total_batches_per_worker: 200,
            lr: 0.1,
            init_scale: 0.37,
            seed: 0,
            log_every: 50,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct GraphViteStats {
    pub wall_secs: f64,
    pub total_batches: u64,
    pub triplets_per_sec: f64,
    pub loss_curve: Vec<(u64, f32)>,
    pub episodes: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

/// Run GraphVite-style episodic training; embeddings end in `state`.
pub fn run_graphvite(
    dataset: &Dataset,
    state: &ModelState,
    manifest: Option<&Manifest>,
    cfg: &GraphViteConfig,
) -> Result<GraphViteStats> {
    let ledger = TransferLedger::new();
    let episodes_counter = crate::obs::metrics::global().counter("baseline.graphvite.episodes");
    let timer = Timer::new();

    let outs: Vec<Result<Vec<(u64, f32)>>> =
        crate::util::threadpool::scoped_map(cfg.n_workers, |w| {
            let backend = TrainBackend::create(
                cfg.backend,
                cfg.model,
                cfg.loss,
                manifest,
                &cfg.artifact_tag,
                cfg.shape,
            )?;
            let shape = backend.shape();
            let rel_dim = backend.rel_dim();
            let mut rng = Rng::seed_from_u64(cfg.seed ^ (w as u64 * 7919 + 3));
            let mut losses = Vec::new();
            let mut step = 0u64;

            // adjacency for episode construction
            let csr = crate::kg::Csr::build(&dataset.train, true);

            while step < cfg.total_batches_per_worker as u64 {
                // --- build episode subgraph: random entity subset ---
                let n_sub = cfg.episode_entities.min(dataset.n_entities());
                let sub: Vec<usize> = rng.sample_distinct(dataset.n_entities(), n_sub);
                let in_sub: std::collections::HashMap<u32, u32> = sub
                    .iter()
                    .enumerate()
                    .map(|(local, &global)| (global as u32, local as u32))
                    .collect();
                // triplets fully inside the subgraph
                let mut episode_triplets: Vec<(u32, u32, u32)> = Vec::new();
                for &h in &sub {
                    if let (Some(&lh), true) = (in_sub.get(&(h as u32)), true) {
                        for (t, r) in csr.edges(h as u32) {
                            if let Some(&lt) = in_sub.get(&t) {
                                episode_triplets.push((lh, r, lt));
                            }
                        }
                    }
                }
                if episode_triplets.len() < shape.batch {
                    continue; // too sparse; resample
                }
                episodes_counter.inc();

                // --- copy-in: episode embeddings to the "GPU buffer" ---
                let mut ent_buf = vec![0f32; shape.dim];
                let local_ents = DenseStore::zeros(n_sub, shape.dim);
                for (local, &global) in sub.iter().enumerate() {
                    state.entities.read_row(global, &mut ent_buf);
                    local_ents.set_row(local, &ent_buf);
                }
                let mut rel_buf = vec![0f32; rel_dim];
                let local_rels = DenseStore::zeros(dataset.n_relations(), rel_dim);
                for r in 0..dataset.n_relations() {
                    state.relations.read_row(r, &mut rel_buf);
                    local_rels.set_row(r, &rel_buf);
                }
                let local_ent_opt = SparseAdagrad::new(n_sub, cfg.lr);
                let local_rel_opt = SparseAdagrad::new(dataset.n_relations(), cfg.lr);
                ledger.add_h2d(((n_sub * shape.dim + dataset.n_relations() * rel_dim) * 4) as u64);

                // --- episode batches: stale, local-only ---
                let mut h_ids = vec![0u64; shape.batch];
                let mut r_ids = vec![0u64; shape.batch];
                let mut t_ids = vec![0u64; shape.batch];
                let nk = shape.chunks * shape.neg_k;
                let mut nh_ids = vec![0u64; nk];
                let mut nt_ids = vec![0u64; nk];
                let mut bufs = crate::train::batch::BatchBuffers::new(&shape, rel_dim);
                for _ in 0..cfg.episode_batches {
                    if step >= cfg.total_batches_per_worker as u64 {
                        break;
                    }
                    for i in 0..shape.batch {
                        let (h, r, t) =
                            episode_triplets[rng.gen_index(episode_triplets.len())];
                        h_ids[i] = h as u64;
                        r_ids[i] = r as u64;
                        t_ids[i] = t as u64;
                    }
                    for j in 0..nk {
                        nh_ids[j] = rng.gen_index(n_sub) as u64;
                        nt_ids[j] = rng.gen_index(n_sub) as u64;
                    }
                    local_ents.gather(&h_ids, &mut bufs.h);
                    local_rels.gather(&r_ids, &mut bufs.r);
                    local_ents.gather(&t_ids, &mut bufs.t);
                    local_ents.gather(&nh_ids, &mut bufs.neg_h);
                    local_ents.gather(&nt_ids, &mut bufs.neg_t);
                    let grads = backend.step(&StepInputs {
                        h: &bufs.h,
                        r: &bufs.r,
                        t: &bufs.t,
                        neg_h: &bufs.neg_h,
                        neg_t: &bufs.neg_t,
                    })?;
                    if w == 0 && step % cfg.log_every as u64 == 0 {
                        losses.push((step, grads.loss));
                    }
                    // local sparse updates
                    let batch = crate::sampler::Batch {
                        heads: h_ids.clone(),
                        rels: r_ids.clone(),
                        tails: t_ids.clone(),
                        neg_heads: nh_ids.clone(),
                        neg_tails: nt_ids.clone(),
                        chunks: shape.chunks,
                        neg_k: shape.neg_k,
                    };
                    let (ent_g, rel_g) =
                        crate::train::batch::split_grads(&batch, &grads, shape.dim, rel_dim);
                    local_ent_opt.apply_unique(&local_ents, &ent_g.ids, &ent_g.rows);
                    local_rel_opt.apply_unique(&local_rels, &rel_g.ids, &rel_g.rows);
                    step += 1;
                }

                // --- copy-out: write the episode's embeddings back ---
                for (local, &global) in sub.iter().enumerate() {
                    state.entities.set_row(global, local_ents.row(local));
                }
                for r in 0..dataset.n_relations() {
                    state.relations.set_row(r, local_rels.row(r));
                }
                ledger.add_d2h(((n_sub * shape.dim + dataset.n_relations() * rel_dim) * 4) as u64);
            }
            Ok(losses)
        });
    let wall = timer.elapsed_secs();

    let mut losses = Vec::new();
    for o in outs {
        let l = o?;
        if l.len() > losses.len() {
            losses = l;
        }
    }
    let b = cfg.shape.map(|s| s.batch).unwrap_or(0) as u64;
    let total = (cfg.n_workers * cfg.total_batches_per_worker) as u64;
    Ok(GraphViteStats {
        wall_secs: wall,
        total_batches: total,
        triplets_per_sec: (total * b) as f64 / wall.max(1e-9),
        loss_curve: losses,
        episodes: episodes_counter.get(),
        h2d_bytes: ledger.h2d.get(),
        d2h_bytes: ledger.d2h.get(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::TrainConfig;

    fn shape() -> StepShape {
        StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }
    }

    #[test]
    fn graphvite_trains_within_episodes() {
        let dataset = Dataset::load("tiny", 41).unwrap();
        let cfg = GraphViteConfig {
            shape: Some(shape()),
            episode_entities: 150,
            episode_batches: 20,
            total_batches_per_worker: 60,
            lr: 0.25,
            log_every: 5,
            ..Default::default()
        };
        let state = ModelState::init(&dataset, cfg.model, 16, &TrainConfig::default());
        let stats = run_graphvite(&dataset, &state, None, &cfg).unwrap();
        assert!(stats.episodes >= 3);
        assert!(stats.h2d_bytes > 0 && stats.d2h_bytes > 0);
        let first = stats.loss_curve.first().unwrap().1;
        let last = stats.loss_curve.last().unwrap().1;
        assert!(last < first, "{first} -> {last}");
    }

    /// The paper's convergence claim: for the same number of batches,
    /// episodic (stale) training reaches worse eval accuracy than DGL-KE's
    /// globally-shared training.
    #[test]
    fn staleness_hurts_convergence_vs_dglke() {
        let dataset = Dataset::load("tiny", 42).unwrap();
        let n_batches = 400;

        let gv_cfg = GraphViteConfig {
            shape: Some(shape()),
            episode_entities: 60, // small episodes → strong staleness
            episode_batches: 100,
            total_batches_per_worker: n_batches,
            lr: 0.25,
            ..Default::default()
        };
        let gv_state = ModelState::init(&dataset, gv_cfg.model, 16, &TrainConfig::default());
        run_graphvite(&dataset, &gv_state, None, &gv_cfg).unwrap();

        let dgl_cfg = TrainConfig {
            shape: Some(shape()),
            n_workers: 1,
            batches_per_worker: n_batches,
            lr: 0.25,
            ..Default::default()
        };
        let dgl_state = ModelState::init(&dataset, dgl_cfg.model, 16, &dgl_cfg);
        crate::train::run_training(&dataset, &dgl_state, None, &dgl_cfg).unwrap();

        let eval_cfg = crate::eval::EvalConfig { max_triplets: 50, n_threads: 2, ..Default::default() };
        let gv = crate::eval::evaluate(
            gv_cfg.model,
            &gv_state.entities,
            &gv_state.relations,
            &dataset,
            &dataset.test,
            &eval_cfg,
        );
        let dgl = crate::eval::evaluate(
            dgl_cfg.model,
            &dgl_state.entities,
            &dgl_state.relations,
            &dataset,
            &dataset.test,
            &eval_cfg,
        );
        assert!(
            dgl.mrr > gv.mrr,
            "dglke mrr={} should beat stale graphvite mrr={}",
            dgl.mrr,
            gv.mrr
        );
    }
}
