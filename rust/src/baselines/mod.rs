//! Reimplementations of the systems the paper compares against (§4, §6.4)
//! on our substrate, isolating exactly the design choices the paper
//! credits for its speedups:
//!
//! * [`pbg`] — PyTorch-BigGraph-style: random 2D block schedule + dense
//!   relation weights (Fig 8);
//! * [`graphvite`] — GraphVite-style: episodic subgraph training with
//!   stale embeddings (Fig 9/10);
//! * naive negative sampling (Fig 3) is a sampler/artifact configuration:
//!   chunk_size = 1 (`NegativeConfig`), exercised by the Fig 3 bench.

pub mod graphvite;
pub mod pbg;

pub use graphvite::{run_graphvite, GraphViteConfig, GraphViteStats};
pub use pbg::{run_pbg, PbgConfig, PbgStats};
