//! PyTorch-BigGraph-style baseline trainer (paper §4, Fig 8).
//!
//! Reproduces the two PBG design choices the paper blames for its slower
//! training, on our substrate:
//!
//! 1. **Random 2D block partitioning** — entities are hashed into `P`
//!    buckets; edges into `P×P` blocks by (head-bucket, tail-bucket);
//!    workers process disjoint blocks per round (no two concurrent blocks
//!    share a bucket row/column);
//! 2. **Dense relation weights** — relations are model weights, not
//!    sparse embeddings: every batch pays a read-modify-write pass over
//!    the *entire* relation table (PBG's dense optimizer), even though a
//!    batch only touches a handful of relations.
//!
//! Everything else (score functions, optimizer math, negative sampling)
//! is shared with the main trainer so the comparison isolates exactly
//! these two choices.

use crate::kg::Dataset;
use crate::models::step::StepShape;
use crate::models::{LossCfg, ModelKind};
use crate::runtime::{BackendKind, Manifest, TrainBackend};
use crate::sampler::{NegativeConfig, NegativeSampler, PositiveSampler};
use crate::store::EmbeddingStore;
use crate::train::batch::{split_grads, BatchBuffers};
use crate::train::worker::ModelState;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use anyhow::Result;
use std::sync::{Arc, Mutex};

#[derive(Clone, Debug)]
pub struct PbgConfig {
    pub model: ModelKind,
    pub loss: LossCfg,
    pub backend: BackendKind,
    pub artifact_tag: String,
    pub shape: Option<StepShape>,
    pub n_workers: usize,
    /// entity buckets per dimension (P); PBG uses P ≥ 2·workers
    pub buckets: usize,
    pub batches_per_worker: usize,
    pub lr: f32,
    pub init_scale: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PbgConfig {
    fn default() -> Self {
        PbgConfig {
            model: ModelKind::TransEL2,
            loss: LossCfg::default(),
            backend: BackendKind::Native,
            artifact_tag: "default".into(),
            shape: None,
            n_workers: 2,
            buckets: 4,
            batches_per_worker: 100,
            lr: 0.1,
            init_scale: 0.37,
            seed: 0,
            log_every: 50,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct PbgStats {
    pub wall_secs: f64,
    pub total_batches: u64,
    pub triplets_per_sec: f64,
    pub loss_curve: Vec<(u64, f32)>,
    /// relation rows touched per batch (== n_relations: the dense cost)
    pub rel_rows_per_batch: u64,
}

/// Dense AdaGrad state over the full relation table (PBG treats relation
/// parameters as dense model weights). The accumulator sits behind a
/// plain `Mutex`: the full-table walk below dwarfs the lock cost, and the
/// PBG baseline's conflict-free block schedule rarely contends — no
/// reason for Hogwild aliasing off the hot path.
struct DenseRelOptimizer {
    state: Mutex<Vec<f32>>,
    lr: f32,
}

impl DenseRelOptimizer {
    fn new(rows: usize, lr: f32) -> Self {
        DenseRelOptimizer { state: Mutex::new(vec![0f32; rows]), lr }
    }

    /// Full-table pass: every row is read and written (grad rows for the
    /// batch's relations, zero-grad elsewhere — but PBG's dense optimizer
    /// walks the whole tensor regardless).
    #[allow(clippy::erasing_op)]
    fn apply_dense(&self, table: &dyn EmbeddingStore, sparse_ids: &[u64], sparse_rows: &[f32]) {
        let dim = table.dim();
        let mut state = match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        // index sparse grads
        let mut grad_of = std::collections::HashMap::with_capacity(sparse_ids.len());
        for (j, &id) in sparse_ids.iter().enumerate() {
            grad_of.insert(id as usize, j);
        }
        for row_id in 0..table.rows() {
            match grad_of.get(&row_id) {
                Some(&j) => {
                    let g = &sparse_rows[j * dim..(j + 1) * dim];
                    let mut sum_sq = 0f32;
                    for &x in g {
                        sum_sq += x * x;
                    }
                    state[row_id] += sum_sq / dim as f32;
                    let scale = self.lr / (state[row_id] + 1e-10).sqrt();
                    table.update_row(row_id, &mut |row| {
                        for (x, &gx) in row.iter_mut().zip(g) {
                            *x -= scale * gx;
                        }
                    });
                }
                None => {
                    // zero grad: dense optimizer still reads+writes the row
                    let scale = self.lr / (state[row_id] + 1e-10).sqrt();
                    table.update_row(row_id, &mut |row| {
                        for x in row.iter_mut() {
                            *x -= scale * 0.0;
                        }
                    });
                }
            }
        }
    }
}

/// 2D block schedule: round-robin Latin-square so concurrent workers never
/// share a bucket row or column (PBG's conflict-free schedule).
fn block_of_round(round: usize, worker: usize, buckets: usize) -> (usize, usize) {
    let row = (worker + round) % buckets;
    let col = (worker + round + round / buckets) % buckets;
    (row, col)
}

/// Run PBG-style training. Embeddings end up in `state`.
pub fn run_pbg(
    dataset: &Dataset,
    state: &ModelState,
    manifest: Option<&Manifest>,
    cfg: &PbgConfig,
) -> Result<PbgStats> {
    assert!(cfg.buckets >= cfg.n_workers, "PBG needs buckets >= workers");
    // entity buckets (random hash — PBG's partitioning is uniform random)
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x9B9);
    let bucket_of: Vec<u8> =
        (0..dataset.n_entities()).map(|_| rng.gen_index(cfg.buckets) as u8).collect();
    // edge blocks
    let mut blocks: Vec<Vec<u32>> = vec![Vec::new(); cfg.buckets * cfg.buckets];
    for i in 0..dataset.train.len() {
        let bh = bucket_of[dataset.train.heads[i] as usize] as usize;
        let bt = bucket_of[dataset.train.tails[i] as usize] as usize;
        blocks[bh * cfg.buckets + bt].push(i as u32);
    }
    let blocks: Vec<Arc<Vec<u32>>> = blocks.into_iter().map(Arc::new).collect();
    let rel_opt = DenseRelOptimizer::new(dataset.n_relations(), cfg.lr);

    let timer = Timer::new();
    let outs: Vec<Result<Vec<(u64, f32)>>> =
        crate::util::threadpool::scoped_map(cfg.n_workers, |w| {
            let backend = TrainBackend::create(
                cfg.backend,
                cfg.model,
                cfg.loss,
                manifest,
                &cfg.artifact_tag,
                cfg.shape,
            )?;
            let shape = backend.shape();
            let rel_dim = backend.rel_dim();
            let mut buf = BatchBuffers::new(&shape, rel_dim);
            let mut neg = NegativeSampler::new(
                NegativeConfig {
                    k: shape.neg_k,
                    chunk_size: shape.chunk_size(),
                    degree_frac: 0.0,
                    local_pool: None,
                },
                dataset.n_entities(),
                cfg.seed ^ (w as u64 + 0xB0),
            );
            let mut losses = Vec::new();
            let mut idx = Vec::with_capacity(shape.batch);
            let mut step = 0u64;
            let mut round = 0usize;
            'outer: loop {
                // pick this worker's block for the round (conflict-free)
                let (bh, bt) = block_of_round(round, w, cfg.buckets);
                round += 1;
                let block = &blocks[bh * cfg.buckets + bt];
                if block.len() < shape.batch {
                    continue; // sparse block: skip (PBG merges small blocks)
                }
                let mut pos =
                    PositiveSampler::over_indices((**block).clone(), cfg.seed ^ step ^ w as u64);
                // PBG trains a block for a while before switching
                let batches_this_block =
                    ((block.len() / shape.batch).max(1)).min(cfg.batches_per_worker / 4 + 1);
                for _ in 0..batches_this_block {
                    pos.next_batch(shape.batch, &mut idx);
                    let batch = neg.assemble(&dataset.train, &idx);
                    buf.gather(&batch, &state.entities, &state.relations);
                    let grads = backend.step(&buf.inputs())?;
                    if w == 0 && step % cfg.log_every as u64 == 0 {
                        losses.push((step, grads.loss));
                    }
                    let (ent_g, rel_g) =
                        split_grads(&batch, &grads, shape.dim, rel_dim);
                    state.ent_opt.apply_unique(&state.entities, &ent_g.ids, &ent_g.rows);
                    // THE PBG COST: dense pass over the whole relation table
                    rel_opt.apply_dense(&state.relations, &rel_g.ids, &rel_g.rows);
                    step += 1;
                    if step >= cfg.batches_per_worker as u64 {
                        break 'outer;
                    }
                }
            }
            Ok(losses)
        });
    let wall = timer.elapsed_secs();

    let mut losses = Vec::new();
    for o in outs {
        let l = o?;
        if l.len() > losses.len() {
            losses = l;
        }
    }
    let shape = cfg.shape.expect("pbg needs explicit shape for stats").batch as u64;
    let total = (cfg.n_workers * cfg.batches_per_worker) as u64;
    Ok(PbgStats {
        wall_secs: wall,
        total_batches: total,
        triplets_per_sec: (total * shape) as f64 / wall.max(1e-9),
        loss_curve: losses,
        rel_rows_per_batch: dataset.n_relations() as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::{run_training, TrainConfig};

    fn shape() -> StepShape {
        StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }
    }

    #[test]
    fn schedule_is_conflict_free() {
        for buckets in [2usize, 4, 8] {
            for round in 0..20 {
                let mut rows = std::collections::HashSet::new();
                let mut cols = std::collections::HashSet::new();
                for w in 0..buckets {
                    let (r, c) = block_of_round(round, w, buckets);
                    assert!(rows.insert(r), "row conflict round={round}");
                    assert!(cols.insert(c), "col conflict round={round}");
                }
            }
        }
    }

    #[test]
    fn pbg_trains() {
        let dataset = Dataset::load("tiny", 31).unwrap();
        let cfg = PbgConfig {
            shape: Some(shape()),
            n_workers: 2,
            buckets: 2,
            batches_per_worker: 40,
            lr: 0.25,
            log_every: 5,
            ..Default::default()
        };
        let state = ModelState::init(
            &dataset,
            cfg.model,
            16,
            &TrainConfig { lr: cfg.lr, ..Default::default() },
        );
        let stats = run_pbg(&dataset, &state, None, &cfg).unwrap();
        let first = stats.loss_curve.first().unwrap().1;
        let last = stats.loss_curve.last().unwrap().1;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn pbg_slower_than_dglke_with_many_relations() {
        // the dense-relation cost should make PBG visibly slower per batch
        // on a relation-heavy graph
        let cfg_gen = crate::kg::generator::GeneratorConfig {
            n_relations: 2000,
            ..crate::kg::generator::GeneratorConfig::tiny(32)
        };
        let kg = crate::kg::generator::generate(&cfg_gen);
        let (train, valid, test) = crate::kg::generator::split(&kg.store, 0.05, 0.05, 1);
        let dataset = Dataset {
            name: "relheavy".into(),
            entities: crate::kg::vocab::Vocab::synthetic("e", train.n_entities()),
            relations: crate::kg::vocab::Vocab::synthetic("r", train.n_relations()),
            train,
            valid,
            test,
        };
        let n_batches = 30;

        let pbg_cfg = PbgConfig {
            shape: Some(shape()),
            n_workers: 1,
            buckets: 1,
            batches_per_worker: n_batches,
            ..Default::default()
        };
        let state1 = ModelState::init(&dataset, pbg_cfg.model, 16, &TrainConfig::default());
        let pbg = run_pbg(&dataset, &state1, None, &pbg_cfg).unwrap();

        let dgl_cfg = TrainConfig {
            shape: Some(shape()),
            n_workers: 1,
            batches_per_worker: n_batches,
            async_update: false,
            ..Default::default()
        };
        let state2 = ModelState::init(&dataset, dgl_cfg.model, 16, &dgl_cfg);
        let dgl = run_training(&dataset, &state2, None, &dgl_cfg).unwrap();

        assert!(
            pbg.wall_secs > dgl.wall_secs,
            "pbg={} dglke={}",
            pbg.wall_secs,
            dgl.wall_secs
        );
    }
}
