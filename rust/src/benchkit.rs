//! Shared helpers for the paper-figure benches (`benches/*.rs`,
//! `harness = false`), built on the [`crate::api`] session so benches run
//! the same code path as the CLI and the repro drivers.
//!
//! Testbed note (also in EXPERIMENTS.md): this machine exposes ONE CPU
//! core, so concurrent workers time-share. Timing benches therefore
//! report the **simulated parallel clock**: max per-worker thread-CPU
//! busy time + modeled PCIe/network transfer (see
//! `train::device::TransferLedger` and `util::cputime`). Single-worker
//! numbers are additionally reported as real wall-clock.

use crate::api::{ParallelMode, Report, RunSpec, Session};
use crate::kg::Dataset;
use crate::models::ModelKind;
use crate::runtime::{artifacts, BackendKind, Manifest};
use anyhow::Result;
use std::sync::Arc;

/// Batches per worker for benches; QUICK=1 shrinks runs ~4×.
pub fn bench_batches(default: usize) -> usize {
    if std::env::var("QUICK").is_ok() {
        (default / 4).max(2)
    } else {
        default
    }
}

pub fn load_manifest_or_exit() -> Manifest {
    if !artifacts::available() {
        eprintln!("benches need AOT artifacts — run `make artifacts` first");
        std::process::exit(0); // treat as skipped, not failed
    }
    Manifest::load(&artifacts::default_dir()).expect("manifest parse")
}

/// The spec the timing benches start from; `mutate` in [`timed_run`]
/// adjusts it per measurement.
pub fn bench_spec(
    dataset: &Dataset,
    model: ModelKind,
    tag: &str,
    workers: usize,
    batches_per_worker: usize,
    gpu: bool,
) -> RunSpec {
    RunSpec {
        dataset: dataset.name.clone(),
        model,
        backend: BackendKind::Xla,
        artifact_tag: tag.to_string(),
        mode: ParallelMode::Single { workers, gpu },
        batches: batches_per_worker,
        lr: 0.25,
        sync_interval: usize::MAX, // benches measure steady-state steps
        log_every: usize::MAX,
        ..Default::default()
    }
}

/// One timed training run through the session API; returns
/// (report, per-batch sim-parallel ms). The dataset `Arc` is shared so
/// repeated measurements don't regenerate the synthetic graph.
pub fn timed_run(
    dataset: &Arc<Dataset>,
    model: ModelKind,
    tag: &str,
    workers: usize,
    batches_per_worker: usize,
    gpu: bool,
    mutate: impl FnOnce(&mut RunSpec),
) -> Result<(Report, f64)> {
    let mut spec = bench_spec(dataset, model, tag, workers, batches_per_worker, gpu);
    mutate(&mut spec);
    let mut session = Session::with_dataset(spec, dataset.clone())?;
    let report = session.train()?;
    let per_batch_ms = report.sim_parallel_secs * 1000.0 / batches_per_worker as f64;
    Ok((report, per_batch_ms))
}

/// Append rows to results/<name>.csv (creating header if new).
pub fn write_results_csv(name: &str, header: &str, rows: &[String]) {
    std::fs::create_dir_all("results").ok();
    let path = format!("results/{name}.csv");
    let fresh = !std::path::Path::new(&path).exists();
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path).unwrap();
    use std::io::Write;
    if fresh {
        writeln!(f, "{header}").unwrap();
    }
    for r in rows {
        writeln!(f, "{r}").unwrap();
    }
    println!("[appended {} rows to {path}]", rows.len());
}
