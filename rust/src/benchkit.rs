//! Shared helpers for the paper-figure benches (`benches/*.rs`,
//! `harness = false`).
//!
//! Testbed note (also in EXPERIMENTS.md): this machine exposes ONE CPU
//! core, so concurrent workers time-share. Timing benches therefore
//! report the **simulated parallel clock**: max per-worker thread-CPU
//! busy time + modeled PCIe/network transfer (see
//! `train::device::TransferLedger` and `util::cputime`). Single-worker
//! numbers are additionally reported as real wall-clock.

use crate::kg::Dataset;
use crate::models::ModelKind;
use crate::runtime::{artifacts, BackendKind, Manifest};
use crate::train::worker::ModelState;
use crate::train::{run_training, Hardware, TrainConfig, TrainStats};
use anyhow::Result;

/// Batches per worker for benches; QUICK=1 shrinks runs ~4×.
pub fn bench_batches(default: usize) -> usize {
    if std::env::var("QUICK").is_ok() {
        (default / 4).max(2)
    } else {
        default
    }
}

pub fn load_manifest_or_exit() -> Manifest {
    if !artifacts::available() {
        eprintln!("benches need AOT artifacts — run `make artifacts` first");
        std::process::exit(0); // treat as skipped, not failed
    }
    Manifest::load(&artifacts::default_dir()).expect("manifest parse")
}

/// One timed training run; returns (stats, per-batch sim-parallel ms).
#[allow(clippy::too_many_arguments)]
pub fn timed_run(
    dataset: &Dataset,
    manifest: &Manifest,
    model: ModelKind,
    tag: &str,
    workers: usize,
    batches_per_worker: usize,
    gpu: bool,
    mutate: impl FnOnce(&mut TrainConfig),
) -> Result<(TrainStats, f64)> {
    let art = manifest.find_train(model.name(), "logistic", tag)?;
    let mut cfg = TrainConfig {
        model,
        backend: BackendKind::Xla,
        artifact_tag: tag.to_string(),
        n_workers: workers,
        batches_per_worker,
        lr: 0.25,
        sync_interval: usize::MAX, // benches measure steady-state steps
        hardware: if gpu { Hardware::Gpu { pcie_gbps: 12.0 } } else { Hardware::Cpu },
        log_every: usize::MAX,
        ..Default::default()
    };
    mutate(&mut cfg);
    let state = ModelState::init(dataset, model, art.dim, &cfg);
    let stats = run_training(dataset, &state, Some(manifest), &cfg)?;
    let per_batch_ms = stats.sim_parallel_secs * 1000.0 / batches_per_worker as f64;
    Ok((stats, per_batch_ms))
}

/// Append rows to results/<name>.csv (creating header if new).
pub fn write_results_csv(name: &str, header: &str, rows: &[String]) {
    std::fs::create_dir_all("results").ok();
    let path = format!("results/{name}.csv");
    let fresh = !std::path::Path::new(&path).exists();
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path).unwrap();
    use std::io::Write;
    if fresh {
        writeln!(f, "{header}").unwrap();
    }
    for r in rows {
        writeln!(f, "{r}").unwrap();
    }
    println!("[appended {} rows to {path}]", rows.len());
}
