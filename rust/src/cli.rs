//! Minimal CLI argument parser (no clap in the vendored dep set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Unknown keys are rejected at `finish()` so typos fail loudly.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

pub struct Args {
    named: HashMap<String, String>,
    flags: std::collections::HashSet<String>,
    positional: Vec<String>,
    consumed: std::collections::HashSet<String>,
}

/// Can `s` be the *value* of the preceding `--key`? Anything not starting
/// with a dash qualifies, and so does a negative number (`--margin -1.5`,
/// `--shift -2`, `--eps -1e-6`) — a dash followed by digits must not turn
/// the preceding key into a boolean flag.
fn is_value_token(s: &str) -> bool {
    !s.starts_with('-') || s.parse::<f64>().is_ok()
}

impl Args {
    /// Parse raw args (without the program name).
    pub fn parse(raw: &[String]) -> Result<Args> {
        let mut named = HashMap::new();
        let mut flags = std::collections::HashSet::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    named.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && is_value_token(&raw[i + 1]) {
                    named.insert(key.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(key.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { named, flags, positional, consumed: Default::default() })
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.named.get(key).cloned()
    }

    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    pub fn parse_or<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| anyhow!("--{key}: cannot parse {v:?}")),
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.consumed.insert(key.to_string());
        self.flags.contains(key)
            || self.named.get(key).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    /// Error on unconsumed --keys (catches typos).
    pub fn finish(self) -> Result<()> {
        let unknown: Vec<&String> = self
            .named
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(*k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown arguments: {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn named_and_positional() {
        let mut a = Args::parse(&raw("train --model transe --workers 4 --verbose")).unwrap();
        assert_eq!(a.positional(), &["train"]);
        assert_eq!(a.get("model").as_deref(), Some("transe"));
        assert_eq!(a.parse_or("workers", 1usize).unwrap(), 4);
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let mut a = Args::parse(&raw("--lr=0.5 --tag=x")).unwrap();
        assert_eq!(a.parse_or("lr", 0.0f32).unwrap(), 0.5);
        assert_eq!(a.get_or("tag", "y"), "x");
        a.finish().unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        let mut a = Args::parse(&raw("--known 1 --typo 2")).unwrap();
        let _ = a.get("known");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_parse_errors() {
        let mut a = Args::parse(&raw("--workers abc")).unwrap();
        assert!(a.parse_or("workers", 1usize).is_err());
    }

    #[test]
    fn negative_numeric_values() {
        // regression: `--margin -1.5` must bind -1.5 to margin, not turn
        // --margin into a boolean flag
        let mut a = Args::parse(&raw("train --margin -1.5 --shift -2 --eps -1e-6")).unwrap();
        assert_eq!(a.parse_or("margin", 0.0f32).unwrap(), -1.5);
        assert_eq!(a.parse_or("shift", 0i64).unwrap(), -2);
        assert_eq!(a.parse_or("eps", 0.0f64).unwrap(), -1e-6);
        a.finish().unwrap();
        // equals syntax too
        let mut b = Args::parse(&raw("--margin=-1.5")).unwrap();
        assert_eq!(b.parse_or("margin", 0.0f32).unwrap(), -1.5);
        b.finish().unwrap();
    }

    #[test]
    fn flag_followed_by_flag_stays_flag() {
        let mut a = Args::parse(&raw("--gpu --margin -1.5")).unwrap();
        assert!(a.flag("gpu"));
        assert_eq!(a.parse_or("margin", 0.0f32).unwrap(), -1.5);
        a.finish().unwrap();
        // a non-numeric dash token is not a value
        let mut b = Args::parse(&raw("--eval --model transe")).unwrap();
        assert!(b.flag("eval"));
        assert_eq!(b.get("model").as_deref(), Some("transe"));
        b.finish().unwrap();
    }
}
