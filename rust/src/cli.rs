//! Minimal CLI argument parser (no clap in the vendored dep set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Unknown keys are rejected at `finish()` so typos fail loudly.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

pub struct Args {
    named: HashMap<String, String>,
    flags: std::collections::HashSet<String>,
    positional: Vec<String>,
    consumed: std::collections::HashSet<String>,
}

impl Args {
    /// Parse raw args (without the program name).
    pub fn parse(raw: &[String]) -> Result<Args> {
        let mut named = HashMap::new();
        let mut flags = std::collections::HashSet::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    named.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    named.insert(key.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(key.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { named, flags, positional, consumed: Default::default() })
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.named.get(key).cloned()
    }

    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    pub fn parse_or<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| anyhow!("--{key}: cannot parse {v:?}")),
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.consumed.insert(key.to_string());
        self.flags.contains(key)
            || self.named.get(key).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    /// Error on unconsumed --keys (catches typos).
    pub fn finish(self) -> Result<()> {
        let unknown: Vec<&String> = self
            .named
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(*k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown arguments: {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn named_and_positional() {
        let mut a = Args::parse(&raw("train --model transe --workers 4 --verbose")).unwrap();
        assert_eq!(a.positional(), &["train"]);
        assert_eq!(a.get("model").as_deref(), Some("transe"));
        assert_eq!(a.parse_or("workers", 1usize).unwrap(), 4);
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let mut a = Args::parse(&raw("--lr=0.5 --tag=x")).unwrap();
        assert_eq!(a.parse_or("lr", 0.0f32).unwrap(), 0.5);
        assert_eq!(a.get_or("tag", "y"), "x");
        a.finish().unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        let mut a = Args::parse(&raw("--known 1 --typo 2")).unwrap();
        let _ = a.get("known");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_parse_errors() {
        let mut a = Args::parse(&raw("--workers abc")).unwrap();
        assert!(a.parse_or("workers", 1usize).is_err());
    }
}
