//! Distributed training (paper §3.2, §3.6, §6.3): trainers on `machines`
//! simulated machines pull/push embeddings through the in-process
//! [`crate::kvstore`] cluster (shared memory locally, TCP remotely).
//!
//! The paper's distributed recipe, reproduced here:
//!
//! 1. **Graph partitioning** (§3.2): entities are placed on machines by a
//!    METIS-style min-cut (or randomly, the §6.3 baseline); each machine's
//!    trainers sample positives only from triplets whose head lives there.
//! 2. **KVStore** (§3.6): every machine runs `servers_per_machine` servers;
//!    embeddings shard across them (relations reshuffled by hash to avoid
//!    long-tail hot spots). Same-machine access is a memcpy; cross-machine
//!    access is TCP, counted by the [`crate::kvstore::NetLedger`].
//! 3. **Local negative sampling** (§3.3): negatives are drawn from the
//!    machine's own entity pool, so negative gathers add no remote traffic.
//! 4. Server-side sparse AdaGrad: trainers push raw gradients; the owning
//!    server applies the optimizer (communication/optimizer overlap).

use crate::kg::Dataset;
use crate::kvstore::comm::{patch_batch, pull_batch, CommHandle, DistPrefetcher};
use crate::kvstore::{KvCluster, TableId};
use crate::models::step::StepShape;
use crate::models::{LossCfg, ModelKind};
use crate::partition::{GraphPartition, MetisConfig};
use crate::runtime::{BackendKind, Manifest, TrainBackend};
use crate::sampler::{NegativeConfig, NegativeSampler, PositiveSampler};
use crate::store::SparseGrads;
use crate::train::batch::{split_grads, BatchBuffers};
use crate::util::timer::Timer;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::Arc;

/// How entities (and with them, triplets) are placed on machines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Uniform random placement — the paper's §6.3 baseline.
    Random,
    /// METIS-style min-cut placement (maximizes triplet locality).
    Metis,
}

impl PartitionStrategy {
    pub fn parse(s: &str) -> Option<PartitionStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(PartitionStrategy::Random),
            "metis" => Some(PartitionStrategy::Metis),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionStrategy::Random => "random",
            PartitionStrategy::Metis => "metis",
        }
    }
}

#[derive(Clone, Debug)]
pub struct DistConfig {
    pub model: ModelKind,
    pub loss: LossCfg,
    pub backend: BackendKind,
    /// artifact shape family ("default" / "tiny"); ignored for native
    pub artifact_tag: String,
    /// explicit shape (required for the native backend)
    pub shape: Option<StepShape>,
    pub machines: usize,
    pub trainers_per_machine: usize,
    pub servers_per_machine: usize,
    pub partition: PartitionStrategy,
    /// draw uniform negatives from the machine-local entity pool (§3.3)
    pub local_negatives: bool,
    pub batches_per_trainer: usize,
    pub lr: f32,
    pub init_scale: f32,
    /// fraction of negatives drawn in-batch ∝ degree (§3.3)
    pub neg_degree_frac: f64,
    pub seed: u64,
    /// record loss every this many batches (trainer 0 only)
    pub log_every: usize,
    /// storage backend for the per-server embedding shards
    pub storage: crate::store::StoreConfig,
    /// use the async KVStore client (§3.6 overlap): per-server I/O worker
    /// threads, concurrent pull fan-out, pipelined tagged frames, and
    /// fire-and-forget pushes behind a drain barrier
    pub pipelined: bool,
    /// in-flight frames per remote connection for the async client
    pub inflight: usize,
    /// pull batch N+1 through a helper thread while batch N computes —
    /// the PR-3 prefetch pipeline extended to the network gather
    pub prefetch: bool,
    /// prefetch buffers in flight (>= 2; also the staleness bound)
    pub prefetch_depth: usize,
    /// score/grad kernel backend for the native trainer step
    /// (bit-identical results either way)
    pub kernels: crate::models::KernelBackend,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            model: ModelKind::TransEL2,
            loss: LossCfg::default(),
            backend: BackendKind::Native,
            artifact_tag: "default".into(),
            shape: None,
            machines: 4,
            trainers_per_machine: 2,
            servers_per_machine: 2,
            partition: PartitionStrategy::Metis,
            local_negatives: true,
            batches_per_trainer: 100,
            lr: 0.1,
            init_scale: 0.37,
            neg_degree_frac: 0.0,
            seed: 0,
            log_every: 50,
            storage: crate::store::StoreConfig::default(),
            pipelined: false,
            inflight: 8,
            prefetch: false,
            prefetch_depth: 2,
            kernels: crate::models::KernelBackend::Scalar,
        }
    }
}

/// Aggregate statistics of one distributed run.
#[derive(Clone, Debug, Default)]
pub struct DistStats {
    pub wall_secs: f64,
    pub total_batches: u64,
    pub triplets_per_sec: f64,
    /// fraction of triplet endpoints local to their machine (§3.2)
    pub locality: f64,
    /// bytes served through the same-machine fast path
    pub local_bytes: u64,
    /// bytes that crossed TCP
    pub remote_bytes: u64,
    pub remote_requests: u64,
    /// remote bytes moved off the trainers' critical path (prefetch-helper
    /// pulls, fire-and-forget pushes); critical-path remote traffic is
    /// `remote_bytes - remote_overlapped_bytes`
    pub remote_overlapped_bytes: u64,
    pub loss_curve: Vec<(u64, f32)>,
    pub mean_loss_tail: f32,
}

/// Resolve (explicit native shape, dim, rel_dim) for a distributed run —
/// the same contract as [`TrainBackend::create`], evaluated up front so the
/// KVStore shards can be sized before trainers start.
fn resolve_dims(
    cfg: &DistConfig,
    manifest: Option<&Manifest>,
) -> Result<(Option<StepShape>, usize, usize)> {
    match cfg.backend {
        BackendKind::Native => {
            let shape = match cfg.shape {
                Some(s) => s,
                None => bail!("native distributed backend needs an explicit shape"),
            };
            Ok((Some(shape), shape.dim, cfg.model.rel_dim(shape.dim)))
        }
        BackendKind::Xla => {
            let m = match manifest {
                Some(m) => m,
                None => bail!("XLA distributed backend needs a manifest"),
            };
            let art = m.find_train(cfg.model.name(), cfg.loss.kind.name(), &cfg.artifact_tag)?;
            Ok((None, art.dim, art.rel_dim))
        }
    }
}

/// Per-trainer result of [`run_trainer`].
pub struct TrainerOut {
    pub losses: Vec<(u64, f32)>,
    pub batches: u64,
}

/// Run distributed training. Returns stats plus the still-running cluster so
/// the caller can [`KvCluster::dump_entities`] for evaluation; call
/// [`KvCluster::shutdown`] when done.
pub fn run_distributed(
    dataset: &Dataset,
    manifest: Option<&Manifest>,
    cfg: &DistConfig,
) -> Result<(DistStats, KvCluster)> {
    anyhow::ensure!(cfg.machines >= 1, "machines must be >= 1");
    anyhow::ensure!(cfg.trainers_per_machine >= 1, "trainers_per_machine must be >= 1");
    anyhow::ensure!(cfg.servers_per_machine >= 1, "servers_per_machine must be >= 1");
    anyhow::ensure!(cfg.inflight >= 1, "inflight must be >= 1");

    let partition = match cfg.partition {
        PartitionStrategy::Metis => {
            GraphPartition::metis(&dataset.train, cfg.machines, &MetisConfig::default())
        }
        PartitionStrategy::Random => {
            GraphPartition::random(&dataset.train, cfg.machines, cfg.seed)
        }
    };
    let locality = partition.locality(&dataset.train);

    let (shape_override, dim, rel_dim) = resolve_dims(cfg, manifest)?;
    let cluster = KvCluster::start_with_storage(
        &partition.entity_part,
        dataset.n_relations(),
        cfg.machines,
        cfg.servers_per_machine,
        dim,
        rel_dim,
        cfg.lr,
        cfg.init_scale,
        cfg.seed,
        &cfg.storage,
    )?;

    // Per-machine positive index sets and local negative pools, shared
    // read-only across that machine's trainers.
    let mut machine_triplets: Vec<Arc<Vec<usize>>> = Vec::with_capacity(cfg.machines);
    let mut machine_pools: Vec<Option<Arc<Vec<u32>>>> = Vec::with_capacity(cfg.machines);
    for m in 0..cfg.machines {
        let mut idx = partition.triplets_of(m as u32);
        if idx.is_empty() {
            // degenerate partition (tiny graph, many machines): fall back to
            // the full triplet set so the trainer has work
            idx = (0..dataset.train.len()).collect();
        }
        machine_triplets.push(Arc::new(idx));
        let pool = if cfg.local_negatives {
            let p = cluster.placement.entities_of_machine(m);
            (!p.is_empty()).then(|| Arc::new(p))
        } else {
            None
        };
        machine_pools.push(pool);
    }

    let n_trainers = cfg.machines * cfg.trainers_per_machine;
    let timer = Timer::new();
    let outs: Vec<Result<TrainerOut>> = crate::util::threadpool::scoped_map(n_trainers, |t| {
        let machine = t / cfg.trainers_per_machine;
        let lane = t % cfg.trainers_per_machine;
        run_trainer(
            dataset,
            manifest,
            cfg,
            &cluster,
            machine,
            lane,
            &machine_triplets[machine],
            machine_pools[machine].clone(),
            t,
        )
    });
    let wall = timer.elapsed_secs();

    let mut losses = Vec::new();
    let mut batches = 0u64;
    let mut batch_size = 0usize;
    for out in outs {
        let out = out?;
        batches += out.batches;
        if out.losses.len() > losses.len() {
            losses = out.losses;
        }
    }
    if let Some(s) = shape_override {
        batch_size = s.batch;
    } else if let Some(m) = manifest {
        if let Ok(art) = m.find_train(cfg.model.name(), cfg.loss.kind.name(), &cfg.artifact_tag) {
            batch_size = art.batch;
        }
    }
    let tail: Vec<f32> = losses.iter().rev().take(10).map(|&(_, l)| l).collect();
    let stats = DistStats {
        wall_secs: wall,
        total_batches: batches,
        triplets_per_sec: (batches * batch_size as u64) as f64 / wall.max(1e-9),
        locality,
        local_bytes: cluster.ledger.local(),
        remote_bytes: cluster.ledger.remote(),
        remote_requests: cluster.ledger.remote_requests.get(),
        remote_overlapped_bytes: cluster.ledger.overlapped(),
        mean_loss_tail: if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().sum::<f32>() / tail.len() as f32
        },
        loss_curve: losses,
    };
    Ok((stats, cluster))
}

/// Build a trainer's KVStore handle under the config's comm mode.
fn make_comm(
    cluster: &KvCluster,
    machine: usize,
    cfg: &DistConfig,
    overlap_pulls: bool,
) -> Result<Box<dyn CommHandle>> {
    if cfg.pipelined {
        Ok(Box::new(cluster.async_client(machine, cfg.inflight, overlap_pulls)?))
    } else {
        let mut client = cluster.client(machine)?;
        client.set_overlap_pulls(overlap_pulls);
        Ok(Box::new(client))
    }
}

/// Drive one trainer over an existing cluster. Public because the
/// async↔sync equivalence tests need a *single* trainer against a
/// multi-machine cluster — a shape `DistConfig` cannot express (its
/// trainer count is per machine). `run_distributed` calls this once per
/// trainer thread. Ends with a [`CommHandle::drain`] barrier, so no
/// gradient is left in flight when it returns.
#[allow(clippy::too_many_arguments)]
pub fn run_trainer(
    dataset: &Dataset,
    manifest: Option<&Manifest>,
    cfg: &DistConfig,
    cluster: &KvCluster,
    machine: usize,
    lane: usize,
    machine_idx: &[usize],
    local_pool: Option<Arc<Vec<u32>>>,
    trainer_id: usize,
) -> Result<TrainerOut> {
    let (shape_override, _, rel_dim) = resolve_dims(cfg, manifest)?;
    // backend per trainer thread (the PJRT client is !Send)
    let backend = TrainBackend::create_with_kernels(
        cfg.backend,
        cfg.model,
        cfg.loss,
        manifest,
        &cfg.artifact_tag,
        shape_override,
        cfg.kernels,
    )?;
    let shape = backend.shape();
    let mut comm = make_comm(cluster, machine, cfg, false)?;

    // strided split of the machine's triplets among its trainer lanes
    let mut my_idx: Vec<u32> = machine_idx
        .iter()
        .enumerate()
        .filter(|&(j, _)| j % cfg.trainers_per_machine == lane)
        .map(|(_, &i)| i as u32)
        .collect();
    if my_idx.is_empty() {
        my_idx = machine_idx.iter().map(|&i| i as u32).collect();
    }
    let pos = PositiveSampler::over_indices(my_idx, cfg.seed ^ (trainer_id as u64 + 1));
    let neg = NegativeSampler::new(
        NegativeConfig {
            k: shape.neg_k,
            chunk_size: shape.chunk_size(),
            degree_frac: cfg.neg_degree_frac,
            local_pool,
        },
        dataset.n_entities(),
        cfg.seed ^ (0xD157 + trainer_id as u64),
    );

    let out = if cfg.prefetch {
        run_trainer_pipelined(
            dataset, cfg, cluster, &backend, shape, rel_dim, machine, &mut *comm, pos, neg,
            trainer_id,
        )?
    } else {
        run_trainer_plain(dataset, cfg, &backend, shape, rel_dim, &mut *comm, pos, neg, trainer_id)?
    };

    // run-end barrier: every fire-and-forget push must be applied before
    // the caller dumps/evaluates the cluster
    comm.drain()?;
    Ok(out)
}

/// The sequential trainer loop: sample → pull → compute → push, all on
/// this thread. Under the async client the pull is still a concurrent
/// wave across servers and the pushes are fire-and-forget.
#[allow(clippy::too_many_arguments)]
fn run_trainer_plain(
    dataset: &Dataset,
    cfg: &DistConfig,
    backend: &TrainBackend,
    shape: StepShape,
    rel_dim: usize,
    comm: &mut dyn CommHandle,
    mut pos: PositiveSampler,
    mut neg: NegativeSampler,
    trainer_id: usize,
) -> Result<TrainerOut> {
    let mut buf = BatchBuffers::new(&shape, rel_dim);
    let mut idx_buf: Vec<u32> = Vec::with_capacity(shape.batch);
    let mut losses = Vec::new();

    for step in 0..cfg.batches_per_trainer as u64 {
        // (1) sample positives + joint negatives
        pos.next_batch(shape.batch, &mut idx_buf);
        let batch = neg.assemble(&dataset.train, &idx_buf);

        // (2) pull embeddings through the KVStore, one fan-out wave
        pull_batch(comm, &batch, &mut buf, shape.dim, rel_dim)?;

        // (3) fwd/bwd
        let grads = backend.step(&buf.inputs())?;
        if trainer_id == 0 && step % cfg.log_every.max(1) as u64 == 0 {
            losses.push((step, grads.loss));
        }

        // (4) push sparse gradients; the owning server applies AdaGrad
        let (ent_g, rel_g): (SparseGrads, SparseGrads) =
            split_grads(&batch, &grads, shape.dim, rel_dim);
        comm.push(TableId::Entities, &ent_g.ids, shape.dim, &ent_g.rows)?;
        comm.push(TableId::Relations, &rel_g.ids, rel_dim, &rel_g.rows)?;
    }

    Ok(TrainerOut { losses, batches: cfg.batches_per_trainer as u64 })
}

/// Unique ids one step pushed — the pipelined loop keeps a window of
/// these so it can repair prefetched pulls that raced those pushes.
struct PushedIds {
    step: u64,
    ents: std::collections::HashSet<u64>,
    rels: std::collections::HashSet<u64>,
}

/// Advance the applied-push stamp past every step whose pushes have been
/// acked (applied server-side). The prefetch helper reads `applied` to
/// stamp its pulls: a stamp `S` must prove all pushes of steps `< S` were
/// visible to the pull, which is exactly what the per-connection mark
/// test guarantees (a global completed count would not — a fast link's
/// completions could stand in for a lagging link's un-acked push).
fn advance_applied(
    marks: &mut VecDeque<(u64, Vec<u64>)>,
    comm: &dyn CommHandle,
    // lint:allow(metrics-registry) — applied stamp (Release/Acquire), not a stat
    applied: &crate::util::sync::atomic::AtomicU64,
) {
    while let Some((step, mark)) = marks.front() {
        if comm.pushes_complete(mark) {
            // Release: pairs with the helper's Acquire load when stamping a
            // pull — a helper that reads stamp `S` also observes everything
            // the acks of steps `< S` made visible (docs/CONCURRENCY.md).
            applied.store(step + 1, crate::util::sync::atomic::Ordering::Release);
            marks.pop_front();
        } else {
            break;
        }
    }
}

/// The two-stage distributed pipeline: a helper thread (with its own
/// KVStore handle) samples and pulls batch N+1 while this thread computes
/// batch N, mirroring `train::worker::run_pipelined` with the gather
/// replaced by a network pull. Rows this trainer pushed at or after a
/// batch's stamp are re-pulled on the trainer's *own* handle (ordered
/// after its pushes per connection) before compute — which keeps a
/// 1-trainer run byte-identical to the sequential loop; with several
/// trainers, staleness is bounded by the pipeline depth, the same Hogwild
/// contract as single-machine async updates.
#[allow(clippy::too_many_arguments)]
fn run_trainer_pipelined(
    dataset: &Dataset,
    cfg: &DistConfig,
    cluster: &KvCluster,
    backend: &TrainBackend,
    shape: StepShape,
    rel_dim: usize,
    machine: usize,
    comm: &mut dyn CommHandle,
    pos: PositiveSampler,
    neg: NegativeSampler,
    trainer_id: usize,
) -> Result<TrainerOut> {
    let helper_comm = make_comm(cluster, machine, cfg, true)?;
    let depth = cfg.prefetch_depth.max(2);
    // lint:allow(metrics-registry) — applied stamp (Release/Acquire), not a stat
    let applied = Arc::new(crate::util::sync::atomic::AtomicU64::new(0));
    let mut losses = Vec::new();
    std::thread::scope(|s| -> Result<()> {
        let mut pf = DistPrefetcher::spawn_scoped(
            s,
            pos,
            neg,
            &dataset.train,
            helper_comm,
            shape,
            rel_dim,
            depth,
            applied.clone(),
        )?;
        // ids pushed per recent step, newest at the back; pruned as the
        // stamp advances (stamps are monotone), so it always covers
        // exactly the steps a live prefetched pull can have missed
        let mut pushed: VecDeque<PushedIds> = VecDeque::new();
        // (step, per-link push mark after that step) awaiting acks
        let mut marks: VecDeque<(u64, Vec<u64>)> = VecDeque::new();
        let mut ent_dirty: std::collections::HashSet<u64> = std::collections::HashSet::new();
        let mut rel_dirty: std::collections::HashSet<u64> = std::collections::HashSet::new();
        for step in 0..cfg.batches_per_trainer as u64 {
            // fold in acks that arrived while we were computing
            advance_applied(&mut marks, &*comm, &applied);

            // (1)+(2) arrive prefetched; blocking here is the pipeline stall
            let mut pb = pf.recv()?;

            // (2b) re-pull rows pushed at or after the pull's stamp
            pushed.retain(|p| p.step >= pb.gathered_at);
            ent_dirty.clear();
            rel_dirty.clear();
            for p in &pushed {
                ent_dirty.extend(p.ents.iter().copied());
                rel_dirty.extend(p.rels.iter().copied());
            }
            patch_batch(comm, &pb.batch, &mut pb.buf, shape.dim, rel_dim, &ent_dirty, &rel_dirty)?;

            // (3) fwd/bwd
            let grads = backend.step(&pb.buf.inputs())?;
            if trainer_id == 0 && step % cfg.log_every.max(1) as u64 == 0 {
                losses.push((step, grads.loss));
            }

            // (4) push sparse gradients
            let (ent_g, rel_g): (SparseGrads, SparseGrads) =
                split_grads(&pb.batch, &grads, shape.dim, rel_dim);
            comm.push(TableId::Entities, &ent_g.ids, shape.dim, &ent_g.rows)?;
            comm.push(TableId::Relations, &rel_g.ids, rel_dim, &rel_g.rows)?;
            marks.push_back((step, comm.push_mark()));
            // synchronous clients complete pushes inline — advance now so
            // the helper's next stamp is as fresh as possible
            advance_applied(&mut marks, &*comm, &applied);
            pushed.push_back(PushedIds {
                step,
                ents: ent_g.ids.into_iter().collect(),
                rels: rel_g.ids.into_iter().collect(),
            });
            pf.recycle(pb);
        }
        pf.finish()?;
        Ok(())
    })?;
    Ok(TrainerOut { losses, batches: cfg.batches_per_trainer as u64 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::EmbeddingStore;

    fn tiny_cfg() -> DistConfig {
        DistConfig {
            backend: BackendKind::Native,
            shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
            machines: 2,
            trainers_per_machine: 2,
            servers_per_machine: 1,
            batches_per_trainer: 20,
            lr: 0.25,
            log_every: 5,
            ..Default::default()
        }
    }

    #[test]
    fn distributed_training_runs_and_learns() {
        let dataset = Dataset::load("tiny", 11).unwrap();
        let cfg = tiny_cfg();
        let (stats, mut cluster) = run_distributed(&dataset, None, &cfg).unwrap();
        cluster.shutdown();
        assert_eq!(stats.total_batches, 2 * 2 * 20);
        assert!(stats.locality > 0.0 && stats.locality <= 1.0);
        let first = stats.loss_curve.first().unwrap().1;
        assert!(stats.mean_loss_tail < first, "{} -> {}", first, stats.mean_loss_tail);
    }

    #[test]
    fn metis_moves_fewer_remote_bytes_than_random() {
        let dataset = Dataset::load("tiny", 12).unwrap();
        let run = |strategy: PartitionStrategy| {
            let cfg = DistConfig { partition: strategy, ..tiny_cfg() };
            let (stats, mut cluster) = run_distributed(&dataset, None, &cfg).unwrap();
            cluster.shutdown();
            stats
        };
        let metis = run(PartitionStrategy::Metis);
        let random = run(PartitionStrategy::Random);
        assert!(metis.locality > random.locality);
        assert!(
            metis.remote_bytes < random.remote_bytes,
            "metis {} vs random {}",
            metis.remote_bytes,
            random.remote_bytes
        );
    }

    #[test]
    fn pipelined_comm_trains_and_bills_overlap() {
        let dataset = Dataset::load("tiny", 14).unwrap();
        let cfg = DistConfig { pipelined: true, inflight: 4, ..tiny_cfg() };
        let (stats, mut cluster) = run_distributed(&dataset, None, &cfg).unwrap();
        cluster.shutdown();
        assert_eq!(stats.total_batches, 2 * 2 * 20);
        let first = stats.loss_curve.first().unwrap().1;
        assert!(stats.mean_loss_tail < first, "{} -> {}", first, stats.mean_loss_tail);
        // fire-and-forget pushes are off the critical path
        assert!(stats.remote_overlapped_bytes > 0);
        assert!(stats.remote_overlapped_bytes <= stats.remote_bytes);
    }

    #[test]
    fn distributed_prefetch_trains() {
        let dataset = Dataset::load("tiny", 15).unwrap();
        let cfg = DistConfig { pipelined: true, prefetch: true, prefetch_depth: 2, ..tiny_cfg() };
        let (stats, mut cluster) = run_distributed(&dataset, None, &cfg).unwrap();
        cluster.shutdown();
        assert_eq!(stats.total_batches, 2 * 2 * 20);
        let first = stats.loss_curve.first().unwrap().1;
        assert!(stats.mean_loss_tail < first, "{} -> {}", first, stats.mean_loss_tail);
        // helper pulls + async pushes both overlap
        assert!(stats.remote_overlapped_bytes > 0);
    }

    #[test]
    fn sync_client_bills_no_overlap() {
        let dataset = Dataset::load("tiny", 16).unwrap();
        let cfg = DistConfig { batches_per_trainer: 5, ..tiny_cfg() };
        let (stats, mut cluster) = run_distributed(&dataset, None, &cfg).unwrap();
        cluster.shutdown();
        assert!(stats.remote_bytes > 0);
        assert_eq!(stats.remote_overlapped_bytes, 0);
    }

    #[test]
    fn dump_matches_server_shards() {
        let dataset = Dataset::load("tiny", 13).unwrap();
        let cfg = DistConfig { batches_per_trainer: 2, ..tiny_cfg() };
        let (_, mut cluster) = run_distributed(&dataset, None, &cfg).unwrap();
        let dim = 16;
        let ents = cluster.dump_entities(dataset.n_entities(), dim);
        // row 0 equals the owning shard's slot
        let s = cluster.placement.ent_server[0] as usize;
        let slot = cluster.placement.ent_slot[0] as usize;
        assert_eq!(ents.row_vec(0), cluster.states[s].ents.row_vec(slot));
        cluster.shutdown();
    }
}
