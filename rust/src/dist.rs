//! Distributed training (paper §3.2, §3.6, §6.3): trainers on `machines`
//! simulated machines pull/push embeddings through the in-process
//! [`crate::kvstore`] cluster (shared memory locally, TCP remotely).
//!
//! The paper's distributed recipe, reproduced here:
//!
//! 1. **Graph partitioning** (§3.2): entities are placed on machines by a
//!    METIS-style min-cut (or randomly, the §6.3 baseline); each machine's
//!    trainers sample positives only from triplets whose head lives there.
//! 2. **KVStore** (§3.6): every machine runs `servers_per_machine` servers;
//!    embeddings shard across them (relations reshuffled by hash to avoid
//!    long-tail hot spots). Same-machine access is a memcpy; cross-machine
//!    access is TCP, counted by the [`crate::kvstore::NetLedger`].
//! 3. **Local negative sampling** (§3.3): negatives are drawn from the
//!    machine's own entity pool, so negative gathers add no remote traffic.
//! 4. Server-side sparse AdaGrad: trainers push raw gradients; the owning
//!    server applies the optimizer (communication/optimizer overlap).

use crate::kg::Dataset;
use crate::kvstore::{KvCluster, TableId};
use crate::models::step::StepShape;
use crate::models::{LossCfg, ModelKind};
use crate::partition::{GraphPartition, MetisConfig};
use crate::runtime::{BackendKind, Manifest, TrainBackend};
use crate::sampler::{NegativeConfig, NegativeSampler, PositiveSampler};
use crate::store::SparseGrads;
use crate::train::batch::{split_grads, BatchBuffers};
use crate::util::timer::Timer;
use anyhow::{bail, Result};
use std::sync::Arc;

/// How entities (and with them, triplets) are placed on machines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Uniform random placement — the paper's §6.3 baseline.
    Random,
    /// METIS-style min-cut placement (maximizes triplet locality).
    Metis,
}

impl PartitionStrategy {
    pub fn parse(s: &str) -> Option<PartitionStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(PartitionStrategy::Random),
            "metis" => Some(PartitionStrategy::Metis),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionStrategy::Random => "random",
            PartitionStrategy::Metis => "metis",
        }
    }
}

#[derive(Clone, Debug)]
pub struct DistConfig {
    pub model: ModelKind,
    pub loss: LossCfg,
    pub backend: BackendKind,
    /// artifact shape family ("default" / "tiny"); ignored for native
    pub artifact_tag: String,
    /// explicit shape (required for the native backend)
    pub shape: Option<StepShape>,
    pub machines: usize,
    pub trainers_per_machine: usize,
    pub servers_per_machine: usize,
    pub partition: PartitionStrategy,
    /// draw uniform negatives from the machine-local entity pool (§3.3)
    pub local_negatives: bool,
    pub batches_per_trainer: usize,
    pub lr: f32,
    pub init_scale: f32,
    /// fraction of negatives drawn in-batch ∝ degree (§3.3)
    pub neg_degree_frac: f64,
    pub seed: u64,
    /// record loss every this many batches (trainer 0 only)
    pub log_every: usize,
    /// storage backend for the per-server embedding shards
    pub storage: crate::store::StoreConfig,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            model: ModelKind::TransEL2,
            loss: LossCfg::default(),
            backend: BackendKind::Native,
            artifact_tag: "default".into(),
            shape: None,
            machines: 4,
            trainers_per_machine: 2,
            servers_per_machine: 2,
            partition: PartitionStrategy::Metis,
            local_negatives: true,
            batches_per_trainer: 100,
            lr: 0.1,
            init_scale: 0.37,
            neg_degree_frac: 0.0,
            seed: 0,
            log_every: 50,
            storage: crate::store::StoreConfig::default(),
        }
    }
}

/// Aggregate statistics of one distributed run.
#[derive(Clone, Debug, Default)]
pub struct DistStats {
    pub wall_secs: f64,
    pub total_batches: u64,
    pub triplets_per_sec: f64,
    /// fraction of triplet endpoints local to their machine (§3.2)
    pub locality: f64,
    /// bytes served through the same-machine fast path
    pub local_bytes: u64,
    /// bytes that crossed TCP
    pub remote_bytes: u64,
    pub remote_requests: u64,
    pub loss_curve: Vec<(u64, f32)>,
    pub mean_loss_tail: f32,
}

/// Resolve (explicit native shape, dim, rel_dim) for a distributed run —
/// the same contract as [`TrainBackend::create`], evaluated up front so the
/// KVStore shards can be sized before trainers start.
fn resolve_dims(
    cfg: &DistConfig,
    manifest: Option<&Manifest>,
) -> Result<(Option<StepShape>, usize, usize)> {
    match cfg.backend {
        BackendKind::Native => {
            let shape = match cfg.shape {
                Some(s) => s,
                None => bail!("native distributed backend needs an explicit shape"),
            };
            Ok((Some(shape), shape.dim, cfg.model.rel_dim(shape.dim)))
        }
        BackendKind::Xla => {
            let m = match manifest {
                Some(m) => m,
                None => bail!("XLA distributed backend needs a manifest"),
            };
            let art = m.find_train(cfg.model.name(), cfg.loss.kind.name(), &cfg.artifact_tag)?;
            Ok((None, art.dim, art.rel_dim))
        }
    }
}

struct TrainerOut {
    losses: Vec<(u64, f32)>,
    batches: u64,
}

/// Run distributed training. Returns stats plus the still-running cluster so
/// the caller can [`KvCluster::dump_entities`] for evaluation; call
/// [`KvCluster::shutdown`] when done.
pub fn run_distributed(
    dataset: &Dataset,
    manifest: Option<&Manifest>,
    cfg: &DistConfig,
) -> Result<(DistStats, KvCluster)> {
    anyhow::ensure!(cfg.machines >= 1, "machines must be >= 1");
    anyhow::ensure!(cfg.trainers_per_machine >= 1, "trainers_per_machine must be >= 1");
    anyhow::ensure!(cfg.servers_per_machine >= 1, "servers_per_machine must be >= 1");

    let partition = match cfg.partition {
        PartitionStrategy::Metis => {
            GraphPartition::metis(&dataset.train, cfg.machines, &MetisConfig::default())
        }
        PartitionStrategy::Random => {
            GraphPartition::random(&dataset.train, cfg.machines, cfg.seed)
        }
    };
    let locality = partition.locality(&dataset.train);

    let (shape_override, dim, rel_dim) = resolve_dims(cfg, manifest)?;
    let cluster = KvCluster::start_with_storage(
        &partition.entity_part,
        dataset.n_relations(),
        cfg.machines,
        cfg.servers_per_machine,
        dim,
        rel_dim,
        cfg.lr,
        cfg.init_scale,
        cfg.seed,
        &cfg.storage,
    )?;

    // Per-machine positive index sets and local negative pools, shared
    // read-only across that machine's trainers.
    let mut machine_triplets: Vec<Arc<Vec<usize>>> = Vec::with_capacity(cfg.machines);
    let mut machine_pools: Vec<Option<Arc<Vec<u32>>>> = Vec::with_capacity(cfg.machines);
    for m in 0..cfg.machines {
        let mut idx = partition.triplets_of(m as u32);
        if idx.is_empty() {
            // degenerate partition (tiny graph, many machines): fall back to
            // the full triplet set so the trainer has work
            idx = (0..dataset.train.len()).collect();
        }
        machine_triplets.push(Arc::new(idx));
        let pool = if cfg.local_negatives {
            let p = cluster.placement.entities_of_machine(m);
            (!p.is_empty()).then(|| Arc::new(p))
        } else {
            None
        };
        machine_pools.push(pool);
    }

    let n_trainers = cfg.machines * cfg.trainers_per_machine;
    let timer = Timer::new();
    let outs: Vec<Result<TrainerOut>> = crate::util::threadpool::scoped_map(n_trainers, |t| {
        let machine = t / cfg.trainers_per_machine;
        let lane = t % cfg.trainers_per_machine;
        trainer_loop(
            dataset,
            manifest,
            cfg,
            &cluster,
            shape_override,
            rel_dim,
            machine,
            lane,
            &machine_triplets[machine],
            machine_pools[machine].clone(),
            t,
        )
    });
    let wall = timer.elapsed_secs();

    let mut losses = Vec::new();
    let mut batches = 0u64;
    let mut batch_size = 0usize;
    for out in outs {
        let out = out?;
        batches += out.batches;
        if out.losses.len() > losses.len() {
            losses = out.losses;
        }
    }
    if let Some(s) = shape_override {
        batch_size = s.batch;
    } else if let Some(m) = manifest {
        if let Ok(art) = m.find_train(cfg.model.name(), cfg.loss.kind.name(), &cfg.artifact_tag) {
            batch_size = art.batch;
        }
    }
    let tail: Vec<f32> = losses.iter().rev().take(10).map(|&(_, l)| l).collect();
    let stats = DistStats {
        wall_secs: wall,
        total_batches: batches,
        triplets_per_sec: (batches * batch_size as u64) as f64 / wall.max(1e-9),
        locality,
        local_bytes: cluster.ledger.local(),
        remote_bytes: cluster.ledger.remote(),
        remote_requests: cluster
            .ledger
            .remote_requests
            .load(std::sync::atomic::Ordering::Relaxed),
        mean_loss_tail: if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().sum::<f32>() / tail.len() as f32
        },
        loss_curve: losses,
    };
    Ok((stats, cluster))
}

#[allow(clippy::too_many_arguments)]
fn trainer_loop(
    dataset: &Dataset,
    manifest: Option<&Manifest>,
    cfg: &DistConfig,
    cluster: &KvCluster,
    shape_override: Option<StepShape>,
    rel_dim: usize,
    machine: usize,
    lane: usize,
    machine_idx: &[usize],
    local_pool: Option<Arc<Vec<u32>>>,
    trainer_id: usize,
) -> Result<TrainerOut> {
    // backend per trainer thread (the PJRT client is !Send)
    let backend = TrainBackend::create(
        cfg.backend,
        cfg.model,
        cfg.loss,
        manifest,
        &cfg.artifact_tag,
        shape_override,
    )?;
    let shape = backend.shape();
    let mut client = cluster.client(machine)?;

    // strided split of the machine's triplets among its trainer lanes
    let mut my_idx: Vec<u32> = machine_idx
        .iter()
        .enumerate()
        .filter(|&(j, _)| j % cfg.trainers_per_machine == lane)
        .map(|(_, &i)| i as u32)
        .collect();
    if my_idx.is_empty() {
        my_idx = machine_idx.iter().map(|&i| i as u32).collect();
    }
    let mut pos = PositiveSampler::over_indices(my_idx, cfg.seed ^ (trainer_id as u64 + 1));
    let mut neg = NegativeSampler::new(
        NegativeConfig {
            k: shape.neg_k,
            chunk_size: shape.chunk_size(),
            degree_frac: cfg.neg_degree_frac,
            local_pool,
        },
        dataset.n_entities(),
        cfg.seed ^ (0xD157 + trainer_id as u64),
    );

    let mut buf = BatchBuffers::new(&shape, rel_dim);
    let mut idx_buf: Vec<u32> = Vec::with_capacity(shape.batch);
    let mut losses = Vec::new();

    for step in 0..cfg.batches_per_trainer as u64 {
        // (1) sample positives + joint negatives
        pos.next_batch(shape.batch, &mut idx_buf);
        let batch = neg.assemble(&dataset.train, &idx_buf);

        // (2) pull embeddings through the KVStore
        client.pull(TableId::Entities, &batch.heads, shape.dim, &mut buf.h)?;
        client.pull(TableId::Relations, &batch.rels, rel_dim, &mut buf.r)?;
        client.pull(TableId::Entities, &batch.tails, shape.dim, &mut buf.t)?;
        client.pull(TableId::Entities, &batch.neg_heads, shape.dim, &mut buf.neg_h)?;
        client.pull(TableId::Entities, &batch.neg_tails, shape.dim, &mut buf.neg_t)?;

        // (3) fwd/bwd
        let grads = backend.step(&buf.inputs())?;
        if trainer_id == 0 && step % cfg.log_every.max(1) as u64 == 0 {
            losses.push((step, grads.loss));
        }

        // (4) push sparse gradients; the owning server applies AdaGrad
        let (ent_g, rel_g): (SparseGrads, SparseGrads) =
            split_grads(&batch, &grads, shape.dim, rel_dim);
        client.push(TableId::Entities, &ent_g.ids, shape.dim, &ent_g.rows)?;
        client.push(TableId::Relations, &rel_g.ids, rel_dim, &rel_g.rows)?;
    }

    Ok(TrainerOut { losses, batches: cfg.batches_per_trainer as u64 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::EmbeddingStore;

    fn tiny_cfg() -> DistConfig {
        DistConfig {
            backend: BackendKind::Native,
            shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 8, dim: 16 }),
            machines: 2,
            trainers_per_machine: 2,
            servers_per_machine: 1,
            batches_per_trainer: 20,
            lr: 0.25,
            log_every: 5,
            ..Default::default()
        }
    }

    #[test]
    fn distributed_training_runs_and_learns() {
        let dataset = Dataset::load("tiny", 11).unwrap();
        let cfg = tiny_cfg();
        let (stats, mut cluster) = run_distributed(&dataset, None, &cfg).unwrap();
        cluster.shutdown();
        assert_eq!(stats.total_batches, 2 * 2 * 20);
        assert!(stats.locality > 0.0 && stats.locality <= 1.0);
        let first = stats.loss_curve.first().unwrap().1;
        assert!(stats.mean_loss_tail < first, "{} -> {}", first, stats.mean_loss_tail);
    }

    #[test]
    fn metis_moves_fewer_remote_bytes_than_random() {
        let dataset = Dataset::load("tiny", 12).unwrap();
        let run = |strategy: PartitionStrategy| {
            let cfg = DistConfig { partition: strategy, ..tiny_cfg() };
            let (stats, mut cluster) = run_distributed(&dataset, None, &cfg).unwrap();
            cluster.shutdown();
            stats
        };
        let metis = run(PartitionStrategy::Metis);
        let random = run(PartitionStrategy::Random);
        assert!(metis.locality > random.locality);
        assert!(
            metis.remote_bytes < random.remote_bytes,
            "metis {} vs random {}",
            metis.remote_bytes,
            random.remote_bytes
        );
    }

    #[test]
    fn dump_matches_server_shards() {
        let dataset = Dataset::load("tiny", 13).unwrap();
        let cfg = DistConfig { batches_per_trainer: 2, ..tiny_cfg() };
        let (_, mut cluster) = run_distributed(&dataset, None, &cfg).unwrap();
        let dim = 16;
        let ents = cluster.dump_entities(dataset.n_entities(), dim);
        // row 0 equals the owning shard's slot
        let s = cluster.placement.ent_server[0] as usize;
        let slot = cluster.placement.ent_slot[0] as usize;
        assert_eq!(ents.row_vec(0), cluster.states[s].ents.row_vec(slot));
        cluster.shutdown();
    }
}
