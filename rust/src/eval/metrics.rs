//! Link-prediction metrics (paper §5.3): Hit@k, Mean Rank, MRR.

/// Accumulates ranks of positive triplets.
#[derive(Clone, Debug, Default)]
pub struct RankAccumulator {
    ranks: Vec<f64>,
}

impl RankAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rank: f64) {
        debug_assert!(rank >= 1.0);
        self.ranks.push(rank);
    }

    pub fn merge(&mut self, other: RankAccumulator) {
        self.ranks.extend(other.ranks);
    }

    pub fn len(&self) -> usize {
        self.ranks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }

    pub fn metrics(&self) -> Metrics {
        let q = self.ranks.len().max(1) as f64;
        let mut m = Metrics::default();
        for &r in &self.ranks {
            if r <= 1.0 {
                m.hit1 += 1.0;
            }
            if r <= 3.0 {
                m.hit3 += 1.0;
            }
            if r <= 10.0 {
                m.hit10 += 1.0;
            }
            m.mr += r;
            m.mrr += 1.0 / r;
        }
        m.hit1 /= q;
        m.hit3 /= q;
        m.hit10 /= q;
        m.mr /= q;
        m.mrr /= q;
        m.n = self.ranks.len();
        m
    }
}

/// Total order over candidate scores: descending score, ascending index on
/// ties. This is the reference ranking the offline evaluator implies and the
/// serving path must reproduce — `util::topk::top_k_indices(scores, k)` is
/// defined to equal `full_ranking(scores)[..k]`, and the serve parity suite
/// (`rust/tests/serve_tests.rs`) holds both to it bit-for-bit.
pub fn full_ranking(scores: &[f32]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    order
}

/// The five numbers every accuracy table in the paper reports.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Metrics {
    pub hit1: f64,
    pub hit3: f64,
    pub hit10: f64,
    pub mr: f64,
    pub mrr: f64,
    pub n: usize,
}

impl Metrics {
    /// Paper-style table row.
    pub fn row(&self) -> String {
        format!(
            "Hit@10 {:.3}  Hit@3 {:.3}  Hit@1 {:.3}  MR {:.2}  MRR {:.3}",
            self.hit10, self.hit3, self.hit1, self.mr, self.mrr
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_math() {
        let mut acc = RankAccumulator::new();
        for r in [1.0, 2.0, 10.0, 100.0] {
            acc.push(r);
        }
        let m = acc.metrics();
        assert_eq!(m.hit1, 0.25);
        assert_eq!(m.hit3, 0.5);
        assert_eq!(m.hit10, 0.75);
        assert_eq!(m.mr, 28.25);
        assert!((m.mrr - (1.0 + 0.5 + 0.1 + 0.01) / 4.0).abs() < 1e-12);
        assert_eq!(m.n, 4);
    }

    #[test]
    fn merge_accumulators() {
        let mut a = RankAccumulator::new();
        a.push(1.0);
        let mut b = RankAccumulator::new();
        b.push(3.0);
        a.merge(b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.metrics().hit3, 1.0);
    }

    #[test]
    fn full_ranking_orders_desc_with_index_tiebreak() {
        let scores = [0.5f32, 2.0, 0.5, -1.0, 2.0];
        assert_eq!(full_ranking(&scores), vec![1, 4, 0, 2, 3]);
        assert_eq!(full_ranking(&[]), Vec::<usize>::new());
        // agrees with util::topk on every prefix
        for k in 0..=scores.len() {
            assert_eq!(
                crate::util::topk::top_k_indices(&scores, k),
                full_ranking(&scores)[..k].to_vec()
            );
        }
    }

    #[test]
    fn perfect_model() {
        let mut acc = RankAccumulator::new();
        for _ in 0..10 {
            acc.push(1.0);
        }
        let m = acc.metrics();
        assert_eq!(m.mrr, 1.0);
        assert_eq!(m.mr, 1.0);
        assert_eq!(m.hit1, 1.0);
    }
}
