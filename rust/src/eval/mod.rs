//! Link-prediction evaluation (paper §5.3), both protocols:
//!
//! * **Protocol 1** (FB15k/WN18): rank each test triplet against *all*
//!   corrupted candidates, filtering corruptions that exist anywhere in
//!   the dataset;
//! * **Protocol 2** (Freebase): rank against 2000 sampled negatives —
//!   1000 uniform + 1000 degree-proportional — without filtering.
//!
//! Evaluation is read-only and parallelized over test triplets. Scoring
//! goes through the native model mirror (bit-identical to the artifacts,
//! see `rust/tests/xla_vs_native.rs`), blocked over candidate chunks.

pub mod metrics;

pub use metrics::{full_ranking, Metrics, RankAccumulator};

use crate::kg::{Dataset, TripletSet, TripletStore};
use crate::models::kernels::zeroed;
use crate::models::{EvalScratch, EvalSide, KernelBackend, LossCfg, ModelKind, NativeModel};
use crate::store::EmbeddingStore;
use crate::train::batch::stream_gather_scores;
use crate::util::alias::AliasTable;
use crate::util::rng::Rng;
use crate::util::topk::rank_of;

#[derive(Clone, Debug)]
pub enum EvalProtocol {
    /// full candidate set, filtered (paper protocol 1)
    FullFiltered,
    /// `uniform` + `degree` sampled negatives, unfiltered (protocol 2)
    Sampled { uniform: usize, degree: usize },
}

#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub protocol: EvalProtocol,
    /// evaluate at most this many test triplets (0 = all)
    pub max_triplets: usize,
    pub n_threads: usize,
    pub seed: u64,
    /// Pairwise kernel backend. `Fused` additionally streams candidate
    /// rows store→kernel-tile instead of staging `[4096, d]` blocks
    /// (non-projecting models). Metrics are bit-identical either way —
    /// the kernel parity contract, `docs/KERNELS.md`.
    pub kernels: KernelBackend,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            protocol: EvalProtocol::FullFiltered,
            max_triplets: 2000,
            n_threads: 8,
            seed: 7,
            kernels: KernelBackend::Scalar,
        }
    }
}

/// Evaluate link prediction of trained embeddings on `test`. Reads the
/// tables only through the [`EmbeddingStore`] trait, so any backend
/// (dense / sharded / mmap) evaluates identically.
pub fn evaluate(
    model: ModelKind,
    entities: &dyn EmbeddingStore,
    relations: &dyn EmbeddingStore,
    dataset: &Dataset,
    test: &TripletStore,
    cfg: &EvalConfig,
) -> Metrics {
    let dim = entities.dim();
    let native = NativeModel::new(model, dim, LossCfg::default());
    let n_entities = dataset.n_entities();

    // which test triplets to evaluate
    let mut idx: Vec<usize> = (0..test.len()).collect();
    if cfg.max_triplets > 0 && idx.len() > cfg.max_triplets {
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xE7A1);
        rng.shuffle(&mut idx);
        idx.truncate(cfg.max_triplets);
    }

    // protocol-specific context
    let filter = match cfg.protocol {
        EvalProtocol::FullFiltered => {
            Some(TripletSet::from_stores([&dataset.train, &dataset.valid, &dataset.test]))
        }
        EvalProtocol::Sampled { .. } => None,
    };
    let degree_table = match cfg.protocol {
        EvalProtocol::Sampled { degree, .. } if degree > 0 => {
            let deg = dataset.train.entity_degrees();
            Some(AliasTable::new(&deg.iter().map(|&d| d as f64 + 0.5).collect::<Vec<_>>()))
        }
        _ => None,
    };

    // Fused + non-projecting: stream candidate rows store→tile instead of
    // staging `[BLOCK, d]` gathers (TransR must stage — candidates are
    // re-projected per positive, so the rows have to be materialized).
    let op = model.pairwise_op();
    let fused_stream = cfg.kernels == KernelBackend::Fused && !model.projects_negatives();

    let n_threads = cfg.n_threads.max(1);
    let ranges = crate::util::threadpool::split_ranges(idx.len(), n_threads);
    let accs = crate::util::threadpool::scoped_map(n_threads, |w| {
        let mut acc = RankAccumulator::new();
        let mut rng = Rng::seed_from_u64(cfg.seed ^ (w as u64 + 0x5EED));
        let mut cand_buf: Vec<f32> = Vec::new();
        let mut score_buf: Vec<f32> = Vec::new();
        let mut id_buf: Vec<u64> = Vec::new();
        let mut h_emb = vec![0f32; dim];
        let mut t_emb = vec![0f32; dim];
        let mut r_emb = vec![0f32; relations.dim()];
        // per-thread arena: query rows, TransR projection buffer, and
        // kernel tiles all persist across triplets and scoring blocks
        let mut scratch = EvalScratch::default();
        for &ti in &idx[ranges[w].clone()] {
            let t = test.get(ti);
            entities.read_row(t.head as usize, &mut h_emb);
            entities.read_row(t.tail as usize, &mut t_emb);
            relations.read_row(t.rel as usize, &mut r_emb);
            let pos_score = native.score_one(&h_emb, &r_emb, &t_emb);

            for side in [EvalSide::Tail, EvalSide::Head] {
                // candidate entity ids for this corruption side
                let cand_ids: Vec<u32> = match &cfg.protocol {
                    EvalProtocol::FullFiltered => {
                        let filter = filter.as_ref().unwrap();
                        (0..n_entities as u32)
                            .filter(|&c| {
                                let (ch, ct) = match side {
                                    EvalSide::Tail => (t.head, c),
                                    EvalSide::Head => (c, t.tail),
                                };
                                // skip the positive itself and any true triplet
                                !(ch == t.head && ct == t.tail)
                                    && !filter.contains(ch, t.rel, ct)
                            })
                            .collect()
                    }
                    EvalProtocol::Sampled { uniform, degree } => {
                        let mut ids = Vec::with_capacity(uniform + degree);
                        for _ in 0..*uniform {
                            ids.push(rng.gen_index(n_entities) as u32);
                        }
                        if let Some(table) = &degree_table {
                            for _ in 0..*degree {
                                ids.push(table.sample(&mut rng) as u32);
                            }
                        }
                        ids
                    }
                };
                // blocked scoring
                let (kept, kept_r) = match side {
                    EvalSide::Tail => (&h_emb, &r_emb),
                    EvalSide::Head => (&t_emb, &r_emb),
                };
                let mut ranks_scores: Vec<f32> = Vec::with_capacity(cand_ids.len());
                const BLOCK: usize = 4096;
                if fused_stream {
                    // build the o = g(e, r) query row once per side, then
                    // stream candidates through the fused gather→score path
                    let q = zeroed(&mut scratch.query, dim);
                    native.build_query(side, kept, kept_r, q);
                    for block in cand_ids.chunks(BLOCK) {
                        id_buf.clear();
                        id_buf.extend(block.iter().map(|&c| c as u64));
                        score_buf.resize(block.len(), 0.0);
                        stream_gather_scores(
                            op,
                            q,
                            entities,
                            &id_buf,
                            dim,
                            &mut score_buf,
                            &mut scratch.kernel,
                        );
                        ranks_scores.extend_from_slice(&score_buf);
                    }
                } else {
                    for block in cand_ids.chunks(BLOCK) {
                        id_buf.clear();
                        id_buf.extend(block.iter().map(|&c| c as u64));
                        cand_buf.resize(block.len() * dim, 0.0);
                        entities.gather(&id_buf, &mut cand_buf);
                        score_buf.resize(block.len(), 0.0);
                        native.eval_scores_with(
                            side,
                            kept,
                            kept_r,
                            &cand_buf,
                            &mut score_buf,
                            cfg.kernels,
                            &mut scratch,
                        );
                        ranks_scores.extend_from_slice(&score_buf);
                    }
                }
                acc.push(rank_of(pos_score, &ranks_scores));
            }
        }
        acc
    });

    let mut total = RankAccumulator::new();
    for a in accs {
        total.merge(a);
    }
    total.metrics()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::step::StepShape;
    use crate::runtime::BackendKind;
    use crate::train::worker::ModelState;
    use crate::train::{run_training, TrainConfig};

    fn train_tiny(batches: usize) -> (Dataset, ModelState) {
        let dataset = Dataset::load("tiny", 21).unwrap();
        let cfg = TrainConfig {
            model: ModelKind::TransEL2,
            backend: BackendKind::Native,
            shape: Some(StepShape { batch: 64, chunks: 8, neg_k: 16, dim: 16 }),
            n_workers: 2,
            batches_per_worker: batches,
            lr: 0.25,
            sync_interval: 50,
            ..Default::default()
        };
        let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
        run_training(&dataset, &state, None, &cfg).unwrap();
        (dataset, state)
    }

    #[test]
    fn trained_model_beats_random_full_protocol() {
        let (dataset, state) = train_tiny(300);
        let cfg = EvalConfig { max_triplets: 60, n_threads: 4, ..Default::default() };
        let trained = evaluate(
            ModelKind::TransEL2,
            &state.entities,
            &state.relations,
            &dataset,
            &dataset.test,
            &cfg,
        );
        // random embeddings baseline
        let rand_state = ModelState::init(
            &dataset,
            ModelKind::TransEL2,
            16,
            &TrainConfig { seed: 999, ..Default::default() },
        );
        let random = evaluate(
            ModelKind::TransEL2,
            &rand_state.entities,
            &rand_state.relations,
            &dataset,
            &dataset.test,
            &cfg,
        );
        assert!(
            trained.mrr > 2.0 * random.mrr,
            "trained mrr={} random mrr={}",
            trained.mrr,
            random.mrr
        );
        assert!(trained.mr < random.mr);
    }

    #[test]
    fn sampled_protocol_runs() {
        let (dataset, state) = train_tiny(100);
        let cfg = EvalConfig {
            protocol: EvalProtocol::Sampled { uniform: 50, degree: 50 },
            max_triplets: 40,
            n_threads: 2,
            seed: 3,
            ..Default::default()
        };
        let m = evaluate(
            ModelKind::TransEL2,
            &state.entities,
            &state.relations,
            &dataset,
            &dataset.test,
            &cfg,
        );
        assert_eq!(m.n, 80); // both sides
        assert!(m.mrr > 0.0 && m.mrr <= 1.0);
        assert!(m.mr >= 1.0 && m.mr <= 101.0);
    }

    /// Fused kernels (including the streaming gather→score path) must
    /// produce bit-identical eval metrics — same ranks, same MRR bits.
    #[test]
    fn fused_eval_is_bit_identical() {
        let (dataset, state) = train_tiny(100);
        let base = EvalConfig { max_triplets: 40, n_threads: 2, ..Default::default() };
        let fused_cfg = EvalConfig { kernels: KernelBackend::Fused, ..base.clone() };
        for cfg_pair in [
            (base.clone(), fused_cfg.clone()),
            // sampled protocol exercises partial last blocks too
            (
                EvalConfig {
                    protocol: EvalProtocol::Sampled { uniform: 37, degree: 13 },
                    ..base.clone()
                },
                EvalConfig {
                    protocol: EvalProtocol::Sampled { uniform: 37, degree: 13 },
                    kernels: KernelBackend::Fused,
                    ..base.clone()
                },
            ),
        ] {
            let scalar = evaluate(
                ModelKind::TransEL2,
                &state.entities,
                &state.relations,
                &dataset,
                &dataset.test,
                &cfg_pair.0,
            );
            let fused = evaluate(
                ModelKind::TransEL2,
                &state.entities,
                &state.relations,
                &dataset,
                &dataset.test,
                &cfg_pair.1,
            );
            assert_eq!(scalar.n, fused.n);
            assert_eq!(scalar.mrr.to_bits(), fused.mrr.to_bits());
            assert_eq!(scalar.mr.to_bits(), fused.mr.to_bits());
            assert_eq!(scalar.hit1.to_bits(), fused.hit1.to_bits());
            assert_eq!(scalar.hit10.to_bits(), fused.hit10.to_bits());
        }
    }

    #[test]
    fn filtered_rank_never_worse_than_raw() {
        let (dataset, state) = train_tiny(100);
        let filtered = evaluate(
            ModelKind::TransEL2,
            &state.entities,
            &state.relations,
            &dataset,
            &dataset.test,
            &EvalConfig { max_triplets: 30, n_threads: 2, ..Default::default() },
        );
        // raw = sampled protocol over the whole entity set without filter
        let raw = evaluate(
            ModelKind::TransEL2,
            &state.entities,
            &state.relations,
            &dataset,
            &dataset.test,
            &EvalConfig {
                protocol: EvalProtocol::Sampled { uniform: 200, degree: 0 },
                max_triplets: 30,
                n_threads: 2,
                seed: 7,
                ..Default::default()
            },
        );
        // not a strict theorem at these sizes, but filtered MRR should not
        // be dramatically lower than raw on the same model
        assert!(filtered.mrr > raw.mrr * 0.3);
    }
}
