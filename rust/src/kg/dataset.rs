//! Dataset assembly: named presets, TSV I/O, and train/valid/test splits.
//!
//! `Dataset::load` accepts either a preset name (`fb15k-syn`, `wn18-syn`,
//! `freebase-syn[:scale]`, `tiny`) or a directory containing
//! `train.tsv` / `valid.tsv` / `test.tsv` with `head<TAB>rel<TAB>tail`
//! rows (the OpenKE / DGL-KE file layout), so real datasets drop in
//! unchanged when available.

use super::generator::{generate, split, GeneratorConfig};
use super::triplets::{Triplet, TripletStore};
use super::vocab::Vocab;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, Write};
use std::path::Path;

#[derive(Debug)]
pub struct Dataset {
    pub name: String,
    pub train: TripletStore,
    pub valid: TripletStore,
    pub test: TripletStore,
    pub entities: Vocab,
    pub relations: Vocab,
}

impl Dataset {
    pub fn n_entities(&self) -> usize {
        self.train.n_entities()
    }

    pub fn n_relations(&self) -> usize {
        self.train.n_relations()
    }

    /// Load a preset synthetic dataset or a TSV directory.
    pub fn load(spec: &str, seed: u64) -> Result<Dataset> {
        let (name, cfg) = match spec {
            "fb15k-syn" => (spec, Some(GeneratorConfig::fb15k_syn(seed))),
            "wn18-syn" => (spec, Some(GeneratorConfig::wn18_syn(seed))),
            "tiny" => (spec, Some(GeneratorConfig::tiny(seed))),
            s if s.starts_with("freebase-syn") => {
                let scale = s
                    .strip_prefix("freebase-syn")
                    .and_then(|r| r.strip_prefix(':'))
                    .map(|v| v.parse::<f64>())
                    .transpose()
                    .context("bad freebase-syn scale")?
                    .unwrap_or(1.0);
                (s, Some(GeneratorConfig::freebase_syn(scale, seed)))
            }
            _ => (spec, None),
        };
        match cfg {
            Some(cfg) => Ok(Self::synthetic(name, &cfg, seed)),
            None => Self::from_tsv_dir(Path::new(spec)),
        }
    }

    /// Generate a synthetic dataset with a 90/5/5 split (the paper's
    /// Freebase protocol; FB15k/WN18 official splits are similar scale).
    pub fn synthetic(name: &str, cfg: &GeneratorConfig, seed: u64) -> Dataset {
        let g = generate(cfg);
        let (train, valid, test) = split(&g.store, 0.05, 0.05, seed);
        Dataset {
            name: name.to_string(),
            entities: Vocab::synthetic("e", train.n_entities()),
            relations: Vocab::synthetic("r", train.n_relations()),
            train,
            valid,
            test,
        }
    }

    /// Read OpenKE-style TSV directory: train.tsv / valid.tsv / test.tsv.
    pub fn from_tsv_dir(dir: &Path) -> Result<Dataset> {
        if !dir.is_dir() {
            bail!(
                "dataset '{}' is neither a preset (fb15k-syn, wn18-syn, freebase-syn[:scale], \
                 tiny) nor a directory",
                dir.display()
            );
        }
        let mut entities = Vocab::new();
        let mut relations = Vocab::new();
        let mut raw: Vec<Vec<(u32, u32, u32)>> = Vec::new();
        for f in ["train.tsv", "valid.tsv", "test.tsv"] {
            let path = dir.join(f);
            let file = std::fs::File::open(&path)
                .with_context(|| format!("open {}", path.display()))?;
            let mut triples = Vec::new();
            for (ln, line) in std::io::BufReader::new(file).lines().enumerate() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let mut it = line.split('\t');
                let (h, r, t) = match (it.next(), it.next(), it.next()) {
                    (Some(h), Some(r), Some(t)) => (h, r, t),
                    _ => bail!("{}:{}: expected 3 tab-separated fields", path.display(), ln + 1),
                };
                triples.push((entities.intern(h), relations.intern(r), entities.intern(t)));
            }
            raw.push(triples);
        }
        let ne = entities.len();
        let nr = relations.len();
        let mk = |v: &[(u32, u32, u32)]| {
            let trip: Vec<Triplet> =
                v.iter().map(|&(h, r, t)| Triplet { head: h, rel: r, tail: t }).collect();
            TripletStore::from_triplets(ne, nr, &trip)
        };
        Ok(Dataset {
            name: dir.display().to_string(),
            train: mk(&raw[0]),
            valid: mk(&raw[1]),
            test: mk(&raw[2]),
            entities,
            relations,
        })
    }

    /// Write the dataset out as a TSV directory (for external tools and
    /// for caching expensive synthetic generations).
    pub fn save_tsv_dir(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (f, store) in
            [("train.tsv", &self.train), ("valid.tsv", &self.valid), ("test.tsv", &self.test)]
        {
            let mut w = std::io::BufWriter::new(std::fs::File::create(dir.join(f))?);
            for t in store.iter() {
                writeln!(
                    w,
                    "{}\t{}\t{}",
                    self.entities.name(t.head).unwrap(),
                    self.relations.name(t.rel).unwrap(),
                    self.entities.name(t.tail).unwrap()
                )?;
            }
        }
        Ok(())
    }

    pub fn summary(&self) -> String {
        format!(
            "{}: {} entities, {} relations, {} train / {} valid / {} test triplets",
            self.name,
            self.n_entities(),
            self.n_relations(),
            self.train.len(),
            self.valid.len(),
            self.test.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_tiny() {
        let d = Dataset::load("tiny", 1).unwrap();
        assert!(d.train.len() > d.valid.len());
        assert_eq!(d.n_entities(), 200);
    }

    #[test]
    fn unknown_spec_errors() {
        assert!(Dataset::load("/nonexistent/zzz", 1).is_err());
    }

    #[test]
    fn tsv_roundtrip() {
        let d = Dataset::load("tiny", 2).unwrap();
        let dir = std::env::temp_dir().join(format!("dglke_test_tsv_{}", std::process::id()));
        d.save_tsv_dir(&dir).unwrap();
        let d2 = Dataset::from_tsv_dir(&dir).unwrap();
        assert_eq!(d2.train.len(), d.train.len());
        assert_eq!(d2.test.len(), d.test.len());
        assert_eq!(d2.n_entities(), d.n_entities());
        // spot-check a triplet survives the round trip
        let t = d.train.get(0);
        let t2 = d2.train.get(0);
        assert_eq!(d.entities.name(t.head), d2.entities.name(t2.head));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn freebase_scale_parse() {
        let d = Dataset::load("freebase-syn:0.01", 1).unwrap();
        assert_eq!(d.n_entities(), 1000);
    }
}
