//! Synthetic knowledge-graph generator (dataset substitution, DESIGN.md).
//!
//! We cannot download FB15k / WN18 / Freebase in this environment, so we
//! generate *learnable* stand-ins from a latent ground-truth ("teacher")
//! model. The generator reproduces the dataset properties the paper's
//! optimizations depend on:
//!
//! * **learnability** — edges are chosen to score highly under a teacher
//!   TransE model over low-dimensional latent vectors, so a student KGE
//!   model can reach high Hit@k/MRR and accuracy-affecting optimizations
//!   (degree-based negatives, staleness, partition restrictions) move the
//!   metrics in the same direction they do on real data;
//! * **long-tail relation frequencies** — Zipf-distributed, like
//!   Freebase's 14.8k relations (drives relation partitioning, §3.4, and
//!   KVStore reshuffling, §3.6);
//! * **skewed entity degrees** — Zipf head selection (drives degree-based
//!   negative sampling, §3.3);
//! * **community structure** — entities belong to latent communities and
//!   edges are mostly intra-community, so a min-cut partitioner finds the
//!   diagonal-block structure of paper Fig. 2 (drives §3.2/§6.3).

use super::triplets::{Triplet, TripletStore};
use crate::util::alias::AliasTable;
use crate::util::rng::Rng;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    pub n_entities: usize,
    pub n_relations: usize,
    pub n_edges: usize,
    /// Latent teacher dimension (small; controls how "clean" the KG is).
    pub latent_dim: usize,
    /// Zipf exponent for relation frequencies (~1.0 for Freebase-like).
    pub relation_zipf: f64,
    /// Zipf exponent for head-entity popularity.
    pub entity_zipf: f64,
    /// Number of candidate tails scored per edge (higher = cleaner KG).
    pub candidates: usize,
    /// Number of latent communities (0 = ceil(sqrt(n_entities))).
    pub n_communities: usize,
    /// Probability an edge stays inside its head's community.
    pub p_intra: f64,
    /// Fraction of pure-noise edges (uniform random tails).
    pub noise: f64,
    pub seed: u64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            n_entities: 10_000,
            n_relations: 100,
            n_edges: 100_000,
            latent_dim: 16,
            relation_zipf: 1.0,
            entity_zipf: 0.7,
            candidates: 24,
            n_communities: 0,
            p_intra: 0.85,
            noise: 0.05,
            seed: 0,
        }
    }
}

impl GeneratorConfig {
    /// FB15k-shaped: 15k entities, 1.3k relations, ~500k edges.
    pub fn fb15k_syn(seed: u64) -> Self {
        GeneratorConfig {
            n_entities: 14_951,
            n_relations: 1_345,
            n_edges: 500_000,
            seed,
            ..Default::default()
        }
    }

    /// WN18-shaped: 41k entities, 18 relations, ~150k edges.
    pub fn wn18_syn(seed: u64) -> Self {
        GeneratorConfig {
            n_entities: 40_943,
            n_relations: 18,
            n_edges: 151_000,
            relation_zipf: 0.6,
            seed,
            ..Default::default()
        }
    }

    /// Freebase-shaped, scaled by `scale` (scale=1.0 → 100k entities,
    /// 14.8k relations long-tail, 1M edges; the paper's real Freebase is
    /// 86M/338M which does not fit this testbed's time budget).
    pub fn freebase_syn(scale: f64, seed: u64) -> Self {
        GeneratorConfig {
            n_entities: ((100_000.0 * scale) as usize).max(1000),
            n_relations: ((14_824.0 * scale.sqrt()) as usize).clamp(100, 14_824),
            n_edges: ((1_000_000.0 * scale) as usize).max(10_000),
            seed,
            ..Default::default()
        }
    }

    /// Tiny graph for unit tests.
    pub fn tiny(seed: u64) -> Self {
        GeneratorConfig {
            n_entities: 200,
            n_relations: 8,
            n_edges: 2_000,
            candidates: 6,
            seed,
            ..Default::default()
        }
    }
}

/// Output: the KG plus the teacher latents (kept for diagnostics/tests).
pub struct GeneratedKg {
    pub store: TripletStore,
    pub communities: Vec<u32>,
    pub n_communities: usize,
}

fn zipf_weights(n: usize, exponent: f64) -> Vec<f64> {
    (1..=n).map(|i| 1.0 / (i as f64).powf(exponent)).collect()
}

/// Generate a synthetic KG. Deterministic for a given config.
pub fn generate(cfg: &GeneratorConfig) -> GeneratedKg {
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xD61E_5EED);
    let n = cfg.n_entities;
    let m = cfg.latent_dim;
    let n_comm = if cfg.n_communities == 0 {
        ((n as f64).sqrt().ceil() as usize).max(1)
    } else {
        cfg.n_communities
    };

    // Teacher latents. Entities of the same community share a centroid so
    // intra-community edges are also semantically coherent.
    let mut centroids = vec![0f32; n_comm * m];
    for v in centroids.iter_mut() {
        *v = rng.gen_normal();
    }
    let mut communities = vec![0u32; n];
    let mut ent = vec![0f32; n * m];
    for e in 0..n {
        let c = rng.gen_index(n_comm);
        communities[e] = c as u32;
        for d in 0..m {
            ent[e * m + d] = centroids[c * m + d] + 0.5 * rng.gen_normal();
        }
    }
    let mut rel = vec![0f32; cfg.n_relations * m];
    for v in rel.iter_mut() {
        *v = 0.7 * rng.gen_normal();
    }

    // Entities grouped by community for intra-community tail candidates.
    let mut by_comm: Vec<Vec<u32>> = vec![Vec::new(); n_comm];
    for e in 0..n {
        by_comm[communities[e] as usize].push(e as u32);
    }

    // Popularity / frequency distributions. Identity permutation for
    // relations (relation 0 is the most frequent — tests rely on the
    // monotone shape, the ids are synthetic anyway).
    let rel_table = AliasTable::new(&zipf_weights(cfg.n_relations, cfg.relation_zipf));
    let head_table = AliasTable::new(&zipf_weights(n, cfg.entity_zipf));

    let mut seen = std::collections::HashSet::with_capacity(cfg.n_edges * 2);
    let mut store = TripletStore::new(n, cfg.n_relations);
    let score = |h: usize, r: usize, t: usize, ent: &[f32], rel: &[f32]| -> f32 {
        // teacher TransE-L2: -(||z_h + z_r - z_t||^2)
        let mut s = 0f32;
        for d in 0..m {
            let diff = ent[h * m + d] + rel[r * m + d] - ent[t * m + d];
            s += diff * diff;
        }
        -s
    };

    let mut attempts = 0usize;
    let max_attempts = cfg.n_edges * 20;
    while store.len() < cfg.n_edges && attempts < max_attempts {
        attempts += 1;
        let h = head_table.sample(&mut rng);
        let r = rel_table.sample(&mut rng);
        let t = if rng.gen_f64() < cfg.noise {
            // pure-noise edge
            rng.gen_index(n)
        } else {
            // pick the best-scoring of `candidates` tails, mostly from the
            // head's community
            let comm = &by_comm[communities[h] as usize];
            let mut best_t = usize::MAX;
            let mut best_s = f32::NEG_INFINITY;
            for _ in 0..cfg.candidates {
                let cand = if !comm.is_empty() && rng.gen_f64() < cfg.p_intra {
                    comm[rng.gen_index(comm.len())] as usize
                } else {
                    rng.gen_index(n)
                };
                let s = score(h, r, cand, &ent, &rel);
                if s > best_s {
                    best_s = s;
                    best_t = cand;
                }
            }
            best_t
        };
        if t == h {
            continue;
        }
        if seen.insert((h as u32, r as u32, t as u32)) {
            store.push(Triplet { head: h as u32, rel: r as u32, tail: t as u32 });
        }
    }

    GeneratedKg { store, communities, n_communities: n_comm }
}

/// Split a store into train/valid/test by fraction (e.g. 0.90/0.05/0.05,
/// the paper's Freebase split). Deterministic shuffle by seed.
pub fn split(
    store: &TripletStore,
    valid_frac: f64,
    test_frac: f64,
    seed: u64,
) -> (TripletStore, TripletStore, TripletStore) {
    let mut idx: Vec<usize> = (0..store.len()).collect();
    let mut rng = Rng::seed_from_u64(seed ^ 0x5917);
    rng.shuffle(&mut idx);
    let n_valid = (store.len() as f64 * valid_frac) as usize;
    let n_test = (store.len() as f64 * test_frac) as usize;
    let valid = store.select(&idx[..n_valid]);
    let test = store.select(&idx[n_valid..n_valid + n_test]);
    let train = store.select(&idx[n_valid + n_test..]);
    (train, valid, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_size() {
        let g = generate(&GeneratorConfig::tiny(1));
        assert!(g.store.len() >= 1_800, "got {}", g.store.len());
        assert_eq!(g.store.n_entities(), 200);
    }

    #[test]
    fn deterministic() {
        let a = generate(&GeneratorConfig::tiny(7));
        let b = generate(&GeneratorConfig::tiny(7));
        assert_eq!(a.store.heads, b.store.heads);
        assert_eq!(a.store.tails, b.store.tails);
        assert_eq!(a.store.rels, b.store.rels);
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(&GeneratorConfig::tiny(1));
        let b = generate(&GeneratorConfig::tiny(2));
        assert_ne!(a.store.heads, b.store.heads);
    }

    #[test]
    fn no_self_loops_or_dups() {
        let g = generate(&GeneratorConfig::tiny(3));
        let mut seen = std::collections::HashSet::new();
        for t in g.store.iter() {
            assert_ne!(t.head, t.tail);
            assert!(seen.insert((t.head, t.rel, t.tail)));
        }
    }

    #[test]
    fn relation_frequencies_long_tailed() {
        let g = generate(&GeneratorConfig::tiny(4));
        let counts = g.store.relation_counts();
        // Zipf with identity permutation: relation 0 should be much more
        // frequent than the median relation.
        let mut sorted = counts.clone();
        sorted.sort_unstable();
        // tiny only has 8 relations, so the tail is shallow — require the
        // head to be at least ~2.5× the median.
        assert!(2 * counts[0] >= 5 * sorted[sorted.len() / 2].max(1), "{counts:?}");
    }

    #[test]
    fn community_locality() {
        let g = generate(&GeneratorConfig::tiny(5));
        let intra = g
            .store
            .iter()
            .filter(|t| g.communities[t.head as usize] == g.communities[t.tail as usize])
            .count();
        // p_intra = 0.85 with candidate selection should keep well over
        // half the edges intra-community.
        assert!(intra * 2 > g.store.len(), "intra={} of {}", intra, g.store.len());
    }

    #[test]
    fn split_fractions() {
        let g = generate(&GeneratorConfig::tiny(6));
        let (train, valid, test) = split(&g.store, 0.05, 0.05, 9);
        assert_eq!(train.len() + valid.len() + test.len(), g.store.len());
        assert!((valid.len() as f64 / g.store.len() as f64 - 0.05).abs() < 0.01);
        // no overlap
        let set = crate::kg::triplets::TripletSet::from_stores([&train]);
        for t in test.iter() {
            assert!(!set.contains(t.head, t.rel, t.tail));
        }
    }
}
