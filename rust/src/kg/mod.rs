//! Knowledge-graph data layer: triplet stores, vocabularies, synthetic
//! dataset generation, and dataset I/O.

pub mod dataset;
pub mod generator;
pub mod triplets;
pub mod vocab;

pub use dataset::Dataset;
pub use triplets::{Csr, Triplet, TripletSet, TripletStore};
