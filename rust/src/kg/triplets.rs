//! Triplet storage with CSR adjacency indexes.
//!
//! A knowledge graph is a list of `(head, relation, tail)` triplets over
//! dense entity/relation id spaces (paper §2). We keep the raw triplet
//! arrays (struct-of-arrays, cache friendly for batch sampling) plus CSR
//! indexes by head and by tail for degree queries, filtered evaluation,
//! and the partitioners.

/// A single (head, relation, tail) edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Triplet {
    pub head: u32,
    pub rel: u32,
    pub tail: u32,
}

/// Struct-of-arrays triplet store.
#[derive(Clone, Debug, Default)]
pub struct TripletStore {
    pub heads: Vec<u32>,
    pub rels: Vec<u32>,
    pub tails: Vec<u32>,
    n_entities: usize,
    n_relations: usize,
}

impl TripletStore {
    pub fn new(n_entities: usize, n_relations: usize) -> Self {
        TripletStore { heads: vec![], rels: vec![], tails: vec![], n_entities, n_relations }
    }

    pub fn from_triplets(n_entities: usize, n_relations: usize, triplets: &[Triplet]) -> Self {
        let mut s = Self::new(n_entities, n_relations);
        s.heads.reserve(triplets.len());
        s.rels.reserve(triplets.len());
        s.tails.reserve(triplets.len());
        for t in triplets {
            s.push(*t);
        }
        s
    }

    pub fn push(&mut self, t: Triplet) {
        debug_assert!((t.head as usize) < self.n_entities, "head out of range");
        debug_assert!((t.tail as usize) < self.n_entities, "tail out of range");
        debug_assert!((t.rel as usize) < self.n_relations, "rel out of range");
        self.heads.push(t.head);
        self.rels.push(t.rel);
        self.tails.push(t.tail);
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heads.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> Triplet {
        Triplet { head: self.heads[i], rel: self.rels[i], tail: self.tails[i] }
    }

    pub fn n_entities(&self) -> usize {
        self.n_entities
    }

    pub fn n_relations(&self) -> usize {
        self.n_relations
    }

    pub fn iter(&self) -> impl Iterator<Item = Triplet> + '_ {
        (0..self.len()).map(move |i| self.get(i))
    }

    /// Total degree (in + out) per entity.
    pub fn entity_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.n_entities];
        for &h in &self.heads {
            deg[h as usize] += 1;
        }
        for &t in &self.tails {
            deg[t as usize] += 1;
        }
        deg
    }

    /// Triplet count per relation (the paper's relation frequency, §3.4).
    pub fn relation_counts(&self) -> Vec<u64> {
        let mut cnt = vec![0u64; self.n_relations];
        for &r in &self.rels {
            cnt[r as usize] += 1;
        }
        cnt
    }

    /// Select a subset of triplet indices into a new store.
    pub fn select(&self, idx: &[usize]) -> TripletStore {
        let mut s = TripletStore::new(self.n_entities, self.n_relations);
        for &i in idx {
            s.push(self.get(i));
        }
        s
    }
}

/// CSR adjacency over a triplet store: for each key entity, the list of
/// (other entity, relation) pairs. Built by counting sort — O(E).
#[derive(Clone, Debug)]
pub struct Csr {
    pub offsets: Vec<u64>,
    /// neighbor entity ids, aligned with `rels`
    pub neighbors: Vec<u32>,
    pub rels: Vec<u32>,
}

impl Csr {
    /// Build keyed by head (out-edges) if `by_head`, else keyed by tail.
    pub fn build(store: &TripletStore, by_head: bool) -> Csr {
        let n = store.n_entities();
        let (keys, others) = if by_head {
            (&store.heads, &store.tails)
        } else {
            (&store.tails, &store.heads)
        };
        let mut offsets = vec![0u64; n + 1];
        for &k in keys {
            offsets[k as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let mut cursor = offsets.clone();
        let mut neighbors = vec![0u32; keys.len()];
        let mut rels = vec![0u32; keys.len()];
        for i in 0..keys.len() {
            let k = keys[i] as usize;
            let pos = cursor[k] as usize;
            neighbors[pos] = others[i];
            rels[pos] = store.rels[i];
            cursor[k] += 1;
        }
        Csr { offsets, neighbors, rels }
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// (neighbor, relation) pairs incident to `v`.
    pub fn edges(&self, v: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        (lo..hi).map(move |i| (self.neighbors[i], self.rels[i]))
    }
}

/// Hash set of all triplets — used by the filtered evaluation protocol to
/// drop corrupted triplets that exist in the dataset (paper §5.3).
#[derive(Debug, Default)]
pub struct TripletSet {
    set: std::collections::HashSet<(u32, u32, u32)>,
}

impl TripletSet {
    pub fn from_stores<'a>(stores: impl IntoIterator<Item = &'a TripletStore>) -> Self {
        let mut set = std::collections::HashSet::new();
        for s in stores {
            for t in s.iter() {
                set.insert((t.head, t.rel, t.tail));
            }
        }
        TripletSet { set }
    }

    #[inline]
    pub fn contains(&self, h: u32, r: u32, t: u32) -> bool {
        self.set.contains(&(h, r, t))
    }

    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> TripletStore {
        // 4 entities, 2 relations
        let t = [(0, 0, 1), (0, 1, 2), (1, 0, 2), (3, 1, 0), (2, 0, 3)];
        let trip: Vec<Triplet> =
            t.iter().map(|&(h, r, t)| Triplet { head: h, rel: r, tail: t }).collect();
        TripletStore::from_triplets(4, 2, &trip)
    }

    #[test]
    fn degrees() {
        let s = toy();
        assert_eq!(s.entity_degrees(), vec![3, 2, 3, 2]);
        assert_eq!(s.relation_counts(), vec![3, 2]);
    }

    #[test]
    fn csr_by_head() {
        let s = toy();
        let csr = Csr::build(&s, true);
        assert_eq!(csr.degree(0), 2);
        let e: Vec<_> = csr.edges(0).collect();
        assert!(e.contains(&(1, 0)) && e.contains(&(2, 1)));
        assert_eq!(csr.degree(2), 1);
    }

    #[test]
    fn csr_by_tail() {
        let s = toy();
        let csr = Csr::build(&s, false);
        assert_eq!(csr.degree(2), 2);
        let e: Vec<_> = csr.edges(0).collect();
        assert_eq!(e, vec![(3, 1)]);
    }

    #[test]
    fn csr_total_edges_preserved() {
        let s = toy();
        for by_head in [true, false] {
            let csr = Csr::build(&s, by_head);
            let total: usize = (0..4).map(|v| csr.degree(v)).sum();
            assert_eq!(total, s.len());
        }
    }

    #[test]
    fn triplet_set_membership() {
        let s = toy();
        let set = TripletSet::from_stores([&s]);
        assert!(set.contains(0, 0, 1));
        assert!(!set.contains(1, 1, 0));
        assert_eq!(set.len(), 5);
    }

    #[test]
    fn select_subset() {
        let s = toy();
        let sub = s.select(&[0, 2]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.get(1), Triplet { head: 1, rel: 0, tail: 2 });
    }
}
