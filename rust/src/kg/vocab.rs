//! Entity / relation vocabularies: string name ↔ dense id mapping.
//!
//! Real KG files (FB15k TSV etc.) name entities with opaque strings
//! (`/m/027rn`); training works on dense u32 ids. `Vocab` builds the
//! mapping on first sight, preserving insertion order for reproducibility.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Vocab {
    name_to_id: HashMap<String, u32>,
    id_to_name: Vec<String>,
}

impl Vocab {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get the id for `name`, inserting it if unseen.
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.name_to_id.get(name) {
            return id;
        }
        let id = self.id_to_name.len() as u32;
        self.name_to_id.insert(name.to_string(), id);
        self.id_to_name.push(name.to_string());
        id
    }

    pub fn get(&self, name: &str) -> Option<u32> {
        self.name_to_id.get(name).copied()
    }

    pub fn name(&self, id: u32) -> Option<&str> {
        self.id_to_name.get(id as usize).map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.id_to_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id_to_name.is_empty()
    }

    /// Synthetic vocab with ids as names ("e0", "e1", ...), used by the
    /// generator presets.
    pub fn synthetic(prefix: &str, n: usize) -> Self {
        let mut v = Vocab::new();
        for i in 0..n {
            v.intern(&format!("{prefix}{i}"));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut v = Vocab::new();
        let a = v.intern("/m/x");
        let b = v.intern("/m/y");
        assert_eq!(v.intern("/m/x"), a);
        assert_eq!(a, 0);
        assert_eq!(b, 1);
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn lookup_both_ways() {
        let mut v = Vocab::new();
        let id = v.intern("hello");
        assert_eq!(v.get("hello"), Some(id));
        assert_eq!(v.name(id), Some("hello"));
        assert_eq!(v.get("missing"), None);
        assert_eq!(v.name(99), None);
    }

    #[test]
    fn synthetic_sizes() {
        let v = Vocab::synthetic("e", 10);
        assert_eq!(v.len(), 10);
        assert_eq!(v.get("e7"), Some(7));
    }
}
