//! KVStore client: batched pull/push with the same-machine shared-memory
//! fast path and a remote-traffic ledger.
//!
//! One client per trainer thread. Ids are deduplicated before hitting the
//! wire (DGL-KE pulls each unique embedding once per batch), grouped by
//! owning server, fetched (local servers by direct memcpy, remote servers
//! over TCP), then scattered into the caller's batch buffers.

use super::placement::Placement;
use super::protocol::*;
use super::server::ServerState;
use crate::obs::metrics::{global, Counter};
use crate::util::bytes::Reader;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::Arc;

/// Remote/local traffic counters shared across a run's clients.
///
/// `remote_bytes` is every byte that crossed TCP; `overlapped_bytes` is
/// the subset moved *off the trainer's critical path* — prefetch-helper
/// pulls running under the previous batch's compute, and fire-and-forget
/// pushes drained by the async client's I/O threads. The critical-path
/// remote traffic of a run is `remote_bytes - overlapped_bytes`. Each
/// counter is a private `obs::metrics` cell registered under `kv.net.*`,
/// so the per-run totals read here also show up — summed across
/// ledgers — in metrics snapshots.
#[derive(Debug)]
pub struct NetLedger {
    pub local_bytes: Counter,
    pub remote_bytes: Counter,
    pub remote_requests: Counter,
    pub overlapped_bytes: Counter,
}

impl Default for NetLedger {
    fn default() -> Self {
        Self::new()
    }
}

impl NetLedger {
    pub fn new() -> Self {
        NetLedger {
            local_bytes: global().counter("kv.net.local_bytes"),
            remote_bytes: global().counter("kv.net.remote_bytes"),
            remote_requests: global().counter("kv.net.remote_requests"),
            overlapped_bytes: global().counter("kv.net.overlapped_bytes"),
        }
    }

    pub fn local(&self) -> u64 {
        self.local_bytes.get()
    }

    pub fn remote(&self) -> u64 {
        self.remote_bytes.get()
    }

    pub fn overlapped(&self) -> u64 {
        self.overlapped_bytes.get()
    }
}

enum Link {
    /// same machine: direct shared-memory access
    Local(Arc<ServerState>),
    /// different machine: TCP connection
    Remote(TcpStream),
}

/// Per-trainer KVStore client homed on one machine.
pub struct KvClient {
    pub machine: usize,
    placement: Arc<Placement>,
    links: Vec<Link>,
    ledger: Arc<NetLedger>,
    /// scratch: per-server slot lists
    pull_slots: Vec<Vec<u64>>,
    pull_back: Vec<Vec<usize>>, // positions into the unique-id list
    /// bill remote pull traffic as overlapped (set on prefetch-helper
    /// clients, whose pulls run under the trainer's compute)
    overlap_pulls: bool,
}

impl KvClient {
    /// Connect a client on `machine`. `states[s]`/`addrs[s]` describe
    /// server `s`; same-machine servers are linked through shared memory.
    pub fn connect(
        machine: usize,
        placement: Arc<Placement>,
        states: &[Arc<ServerState>],
        addrs: &[std::net::SocketAddr],
        ledger: Arc<NetLedger>,
    ) -> Result<KvClient> {
        let n = placement.n_servers();
        anyhow::ensure!(states.len() == n && addrs.len() == n);
        let mut links = Vec::with_capacity(n);
        for s in 0..n {
            if placement.machine_of_server(s) == machine {
                links.push(Link::Local(states[s].clone()));
            } else {
                let stream = TcpStream::connect(addrs[s])?;
                stream.set_nodelay(true)?;
                links.push(Link::Remote(stream));
            }
        }
        Ok(KvClient {
            machine,
            placement,
            links,
            ledger,
            pull_slots: vec![Vec::new(); n],
            pull_back: vec![Vec::new(); n],
            overlap_pulls: false,
        })
    }

    /// Bill this client's remote pull traffic as overlapped — for clients
    /// owned by a prefetch helper, whose pulls run off the critical path.
    pub fn set_overlap_pulls(&mut self, on: bool) {
        self.overlap_pulls = on;
    }

    fn server_and_slot(&self, table: TableId, id: u64) -> (usize, u64) {
        self.placement.server_and_slot(table, id)
    }

    /// Pull rows for (possibly duplicated) `ids` into `out[ids.len(), dim]`.
    pub fn pull(&mut self, table: TableId, ids: &[u64], dim: usize, out: &mut [f32]) -> Result<()> {
        debug_assert_eq!(out.len(), ids.len() * dim);
        // dedup
        let mut unique: Vec<u64> = Vec::with_capacity(ids.len());
        let mut index: HashMap<u64, usize> = HashMap::with_capacity(ids.len());
        for &id in ids {
            index.entry(id).or_insert_with(|| {
                unique.push(id);
                unique.len() - 1
            });
        }
        // group by server
        for s in 0..self.links.len() {
            self.pull_slots[s].clear();
            self.pull_back[s].clear();
        }
        for (u, &id) in unique.iter().enumerate() {
            let (s, slot) = self.server_and_slot(table, id);
            self.pull_slots[s].push(slot);
            self.pull_back[s].push(u);
        }
        // fetch per server into the unique-row buffer
        let mut rows = vec![0f32; unique.len() * dim];
        for s in 0..self.links.len() {
            if self.pull_slots[s].is_empty() {
                continue;
            }
            let slots = std::mem::take(&mut self.pull_slots[s]);
            let nbytes = (slots.len() * dim * 4 + slots.len() * 8) as u64;
            match &mut self.links[s] {
                Link::Local(state) => {
                    self.ledger.local_bytes.add(nbytes);
                    let mut tmp = vec![0f32; slots.len() * dim];
                    state.pull_local(table, &slots, &mut tmp);
                    for (j, &u) in self.pull_back[s].iter().enumerate() {
                        rows[u * dim..(u + 1) * dim].copy_from_slice(&tmp[j * dim..(j + 1) * dim]);
                    }
                }
                Link::Remote(stream) => {
                    self.ledger.remote_bytes.add(nbytes);
                    self.ledger.remote_requests.inc();
                    if self.overlap_pulls {
                        self.ledger.overlapped_bytes.add(nbytes);
                    }
                    write_frame(stream, OP_PULL, &encode_pull(table, &slots))?;
                    let (op, payload) = read_frame(stream)?;
                    if op != OP_OK {
                        bail!("server error on pull");
                    }
                    let tmp = Reader::new(&payload).f32_vec()?;
                    anyhow::ensure!(tmp.len() == slots.len() * dim, "bad pull response size");
                    for (j, &u) in self.pull_back[s].iter().enumerate() {
                        rows[u * dim..(u + 1) * dim].copy_from_slice(&tmp[j * dim..(j + 1) * dim]);
                    }
                }
            }
            self.pull_slots[s] = slots;
        }
        // scatter to caller layout
        for (j, &id) in ids.iter().enumerate() {
            let u = index[&id];
            out[j * dim..(j + 1) * dim].copy_from_slice(&rows[u * dim..(u + 1) * dim]);
        }
        Ok(())
    }

    /// Push (already accumulated) gradient rows; the owning server applies
    /// AdaGrad.
    pub fn push(&mut self, table: TableId, ids: &[u64], dim: usize, rows: &[f32]) -> Result<()> {
        debug_assert_eq!(rows.len(), ids.len() * dim);
        let n = self.links.len();
        let mut slots: Vec<Vec<u64>> = vec![Vec::new(); n];
        let mut data: Vec<Vec<f32>> = vec![Vec::new(); n];
        for (j, &id) in ids.iter().enumerate() {
            let (s, slot) = self.server_and_slot(table, id);
            slots[s].push(slot);
            data[s].extend_from_slice(&rows[j * dim..(j + 1) * dim]);
        }
        for s in 0..n {
            if slots[s].is_empty() {
                continue;
            }
            let nbytes = (data[s].len() * 4 + slots[s].len() * 8) as u64;
            match &mut self.links[s] {
                Link::Local(state) => {
                    self.ledger.local_bytes.add(nbytes);
                    state.push_local(table, &slots[s], &data[s]);
                }
                Link::Remote(stream) => {
                    self.ledger.remote_bytes.add(nbytes);
                    self.ledger.remote_requests.inc();
                    write_frame(stream, OP_PUSH, &encode_push(table, &slots[s], &data[s]))?;
                    let (op, _) = read_frame(stream)?;
                    if op != OP_OK {
                        bail!("server error on push");
                    }
                }
            }
        }
        Ok(())
    }
}

impl Drop for KvClient {
    fn drop(&mut self) {
        for link in &mut self.links {
            if let Link::Remote(stream) = link {
                let _ = write_frame(stream, OP_STOP, &[]);
                let _ = read_frame(stream);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::server::KvServer;
    use crate::store::EmbeddingStore;

    /// 2 machines × 1 server, 8 entities striped, 4 relations.
    fn cluster() -> (Vec<KvServer>, Arc<Placement>, Vec<Arc<ServerState>>, Vec<std::net::SocketAddr>) {
        let entity_machine: Vec<u32> = (0..8).map(|i| (i % 2) as u32).collect();
        let placement = Arc::new(Placement::build(&entity_machine, 4, 2, 1, 3));
        let mut servers = Vec::new();
        let mut states = Vec::new();
        let mut addrs = Vec::new();
        for s in 0..2 {
            let state = Arc::new(ServerState::init(
                &placement.ent_ids_of_server[s],
                &placement.rel_ids_of_server[s],
                4,
                4,
                0.5,
                0.1,
                99,
            ));
            let server = KvServer::start(state.clone()).unwrap();
            addrs.push(server.addr);
            states.push(state);
            servers.push(server);
        }
        (servers, placement, states, addrs)
    }

    #[test]
    fn pull_mixed_local_remote() {
        let (_servers, placement, states, addrs) = cluster();
        let ledger = Arc::new(NetLedger::new());
        let mut client =
            KvClient::connect(0, placement.clone(), &states, &addrs, ledger.clone()).unwrap();
        // ids 0..8 span both machines; 3 duplicated
        let ids = [0u64, 1, 2, 3, 3, 7];
        let mut out = vec![0f32; ids.len() * 4];
        client.pull(TableId::Entities, &ids, 4, &mut out).unwrap();
        // duplicates identical
        assert_eq!(&out[3 * 4..4 * 4], &out[4 * 4..5 * 4]);
        // values match server state directly
        let (s, slot) = (placement.ent_server[7] as usize, placement.ent_slot[7] as usize);
        assert_eq!(&out[5 * 4..6 * 4], states[s].ents.row_vec(slot).as_slice());
        assert!(ledger.local() > 0, "machine-0 ids should use fast path");
        assert!(ledger.remote() > 0, "machine-1 ids should use TCP");
    }

    #[test]
    fn push_updates_remote_rows() {
        let (_servers, placement, states, addrs) = cluster();
        let ledger = Arc::new(NetLedger::new());
        let mut client =
            KvClient::connect(0, placement.clone(), &states, &addrs, ledger).unwrap();
        // entity 1 lives on machine 1 (remote from machine 0)
        let (s, slot) = (placement.ent_server[1] as usize, placement.ent_slot[1] as usize);
        let before = states[s].ents.row_vec(slot);
        client.push(TableId::Entities, &[1], 4, &[1.0, 1.0, 1.0, 1.0]).unwrap();
        assert_ne!(states[s].ents.row_vec(slot), before);
    }

    #[test]
    fn relations_pull_roundtrip() {
        let (_servers, placement, states, addrs) = cluster();
        let ledger = Arc::new(NetLedger::new());
        let mut client = KvClient::connect(1, placement.clone(), &states, &addrs, ledger).unwrap();
        let ids = [0u64, 1, 2, 3];
        let mut out = vec![0f32; 4 * 4];
        client.pull(TableId::Relations, &ids, 4, &mut out).unwrap();
        for (j, &id) in ids.iter().enumerate() {
            let (s, slot) =
                (placement.rel_server[id as usize] as usize, placement.rel_slot[id as usize] as usize);
            assert_eq!(&out[j * 4..(j + 1) * 4], states[s].rels.row_vec(slot).as_slice(), "rel {id}");
        }
    }

    #[test]
    fn dedup_reduces_wire_bytes() {
        let (_servers, placement, states, addrs) = cluster();
        let l1 = Arc::new(NetLedger::new());
        let mut c1 = KvClient::connect(0, placement.clone(), &states, &addrs, l1.clone()).unwrap();
        let many_dups = vec![1u64; 64];
        let mut out = vec![0f32; 64 * 4];
        c1.pull(TableId::Entities, &many_dups, 4, &mut out).unwrap();
        // only ONE unique row crosses the wire
        assert_eq!(l1.remote(), (4 * 4 + 8) as u64);
    }
}
