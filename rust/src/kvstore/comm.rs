//! Asynchronous, pipelined KVStore communication (paper §3.6; PBG's
//! background parameter exchange).
//!
//! The synchronous [`KvClient`] serializes every remote operation into a
//! blocking TCP round trip: a batch's five pull sections hit each owning
//! server one at a time, and every gradient push stalls the trainer until
//! the server acks. This module is the asynchronous counterpart:
//!
//! * [`CommHandle`] — the trait both clients implement, so the
//!   distributed trainer loop is written once against pulls, pushes, a
//!   [`CommHandle::drain`] barrier, and push-progress marks;
//! * [`AsyncKvClient`] — per-server I/O worker threads (a writer/reader
//!   pair per remote connection) behind request-tagged frames
//!   (`OP_TPULL`/`OP_TPUSH`/`OP_TOK`). A pull wave fans out to all owning
//!   servers before collecting any response; up to `inflight` frames ride
//!   each connection concurrently; pushes are fire-and-forget under that
//!   bounded window, with `drain()` as the explicit epoch/run-end barrier
//!   guaranteeing no gradient is left in flight;
//! * [`DistPrefetcher`] — the distributed extension of the PR-3 prefetch
//!   pipeline ([`crate::train::prefetch`]): a helper thread owning cloned
//!   sampler cursors and its *own* comm handle pulls batch N+1's rows
//!   while the trainer computes batch N, stamping each batch with the
//!   trainer's applied-push counter so dirtied rows can be re-pulled
//!   (patched) before compute.
//!
//! # Ordering and exactness
//!
//! Per remote server, one client owns one connection and its writer
//! thread writes frames in submission order; the server applies them in
//! frame order. A pull submitted after a push on the same handle is
//! therefore always answered with the pushed state — which is what makes
//! a *single-trainer* run under the async client byte-identical to the
//! sequential client, and what makes patch re-pulls (issued on the
//! trainer's own handle, after its pushes) exact. The prefetch helper
//! pulls on a separate handle and may race the trainer's pushes; its
//! batches carry an applied-push stamp, and the trainer re-pulls every
//! row it pushed at or after that stamp. `applied` only advances past a
//! step once that step's pushes are *acked* (applied server-side), so a
//! stamp `S` proves the helper's pull observed all pushes of steps `< S`.
//! See `rust/tests/dist_comm_tests.rs` for the equivalence matrix.

use super::client::{KvClient, NetLedger};
use super::placement::Placement;
use super::protocol::*;
use super::server::ServerState;
use super::window::{InflightWindow, PopOutcome};
use crate::kg::TripletStore;
use crate::models::step::StepShape;
use crate::obs::trace::{span, SpanId};
use crate::sampler::{Batch, NegativeSampler, PositiveSampler};
use crate::train::batch::BatchBuffers;
use crate::util::bytes::Reader;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::mpsc::{sync_channel, Receiver, SyncSender};
use anyhow::{anyhow, Result};
use std::collections::{HashMap, HashSet};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread::{JoinHandle, Scope, ScopedJoinHandle};

/// One pull request of a wave: gather rows of `ids` (duplicates allowed)
/// into `out[ids.len(), dim]`.
pub struct PullReq<'a> {
    pub table: TableId,
    pub ids: &'a [u64],
    pub dim: usize,
    pub out: &'a mut [f32],
}

/// What a distributed trainer needs from its KVStore client — implemented
/// by the synchronous [`KvClient`] and the pipelined [`AsyncKvClient`],
/// so `dist::run_trainer` is written once.
pub trait CommHandle: Send {
    /// Pull rows for (possibly duplicated) `ids` into `out[ids.len(), dim]`.
    fn pull(&mut self, table: TableId, ids: &[u64], dim: usize, out: &mut [f32]) -> Result<()>;

    /// Issue several pulls as one wave. The async client dispatches every
    /// request to every owning server before collecting any response
    /// (cross-server fan-out + per-connection pipelining); the sync
    /// client runs them in order.
    fn pull_all(&mut self, reqs: &mut [PullReq<'_>]) -> Result<()>;

    /// Push (already accumulated) gradient rows; the owning server
    /// applies AdaGrad. The async client returns as soon as the frames
    /// are queued (bounded by its in-flight window).
    fn push(&mut self, table: TableId, ids: &[u64], dim: usize, rows: &[f32]) -> Result<()>;

    /// Block until every previously submitted push has been applied and
    /// acked server-side. The epoch/run-end barrier: after `drain()`, no
    /// gradient is in flight.
    fn drain(&mut self) -> Result<()>;

    /// Opaque completion mark: the per-connection submitted-push counts
    /// as of this call. Hand it back to [`CommHandle::pushes_complete`]
    /// to ask whether everything submitted before the mark has been
    /// applied server-side.
    fn push_mark(&self) -> Vec<u64>;

    /// True once every push submitted before `mark` has been acked
    /// (applied server-side). Acks are FIFO *per connection*, so the
    /// comparison is per-connection counts — a single global completed
    /// count would be unsound: a fast link's completions could mask a
    /// lagging link's un-acked push. The pipelined trainer uses this to
    /// advance the applied-push stamp the prefetch helper reads.
    fn pushes_complete(&self, mark: &[u64]) -> bool;
}

impl CommHandle for KvClient {
    fn pull(&mut self, table: TableId, ids: &[u64], dim: usize, out: &mut [f32]) -> Result<()> {
        KvClient::pull(self, table, ids, dim, out)
    }

    fn pull_all(&mut self, reqs: &mut [PullReq<'_>]) -> Result<()> {
        for r in reqs {
            KvClient::pull(self, r.table, r.ids, r.dim, r.out)?;
        }
        Ok(())
    }

    fn push(&mut self, table: TableId, ids: &[u64], dim: usize, rows: &[f32]) -> Result<()> {
        debug_assert_eq!(rows.len(), ids.len() * dim);
        KvClient::push(self, table, ids, dim, rows)
    }

    fn drain(&mut self) -> Result<()> {
        Ok(()) // every push already completed synchronously
    }

    fn push_mark(&self) -> Vec<u64> {
        Vec::new() // nothing is ever in flight
    }

    fn pushes_complete(&self, _mark: &[u64]) -> bool {
        true
    }
}

/// A request handed to a remote link's writer thread.
enum Req {
    Pull { table: TableId, slots: Vec<u64>, reply: SyncSender<PullResp> },
    Push { table: TableId, slots: Vec<u64>, rows: Vec<f32> },
    Drain { ack: SyncSender<()> },
}

/// Pull responses cross a channel; errors travel as strings (the vendored
/// anyhow error is Send, but a plain string keeps the worker side free of
/// error-chain plumbing).
type PullResp = std::result::Result<Vec<f32>, String>;

/// A written-but-unanswered frame in a link's [`InflightWindow`].
enum Pending {
    Pull { tag: u32, reply: SyncSender<PullResp> },
    Push { tag: u32 },
    /// barrier marker: everything queued before it has been answered
    Drain { ack: SyncSender<()> },
    /// final marker: the writer sent OP_STOP; read the ack and exit
    Stop,
}

/// Deliver a link failure to whoever waits on a pending entry. Pulls get
/// an explicit error; for drains, dropping the ack sender makes the
/// waiting `drain()`'s recv fail.
fn deliver_failure(p: Pending) {
    if let Pending::Pull { reply, .. } = p {
        let _ = reply.send(Err("kvstore connection failed".into()));
    }
}

/// Fail the window and deliver the failure to every drained entry.
fn fail_link(win: &InflightWindow<Pending>) {
    for p in win.fail() {
        deliver_failure(p);
    }
}

/// One remote server connection: writer + reader thread pair.
struct RemoteLink {
    req_tx: Option<SyncSender<Req>>,
    writer: Option<JoinHandle<()>>,
    reader: Option<JoinHandle<()>>,
}

impl RemoteLink {
    fn send(&self, req: Req) -> Result<()> {
        self.req_tx
            .as_ref()
            .ok_or_else(|| anyhow!("kvstore link already shut down"))?
            .send(req)
            .map_err(|_| anyhow!("kvstore I/O worker terminated"))
    }
}

enum AsyncLink {
    /// same machine: direct shared-memory access (as in [`KvClient`])
    Local(Arc<ServerState>),
    Remote(RemoteLink),
}

/// Pipelined, fan-out KVStore client: one writer/reader thread pair per
/// remote server, request-tagged frames, a bounded in-flight window per
/// connection, fire-and-forget pushes, and an explicit [`drain`] barrier.
///
/// [`drain`]: CommHandle::drain
pub struct AsyncKvClient {
    pub machine: usize,
    placement: Arc<Placement>,
    links: Vec<AsyncLink>,
    ledger: Arc<NetLedger>,
    /// bill this client's remote *pull* traffic as overlapped — set for
    /// the prefetch helper, whose pulls run under the trainer's compute
    overlap_pulls: bool,
    /// pushes applied inline on local shards (complete by construction)
    local_pushes: u64,
    /// per-link push ops submitted (remote links only; local stay 0)
    submitted_per_link: Vec<u64>,
    /// per-link push acks, incremented by that link's reader thread; acks
    /// are FIFO per connection, which is what makes per-link counts a
    /// sound completion test (see [`CommHandle::pushes_complete`])
    // lint:allow(metrics-registry) — flow-control cell (Release/Acquire
    // ack protocol), not a stat; audited under `acked-per-link` pairing
    acked_per_link: Vec<Arc<AtomicU64>>,
}

impl AsyncKvClient {
    /// Connect a pipelined client on `machine`; `inflight` bounds the
    /// written-but-unanswered frames per remote connection (>= 1).
    pub fn connect(
        machine: usize,
        placement: Arc<Placement>,
        states: &[Arc<ServerState>],
        addrs: &[std::net::SocketAddr],
        ledger: Arc<NetLedger>,
        inflight: usize,
        overlap_pulls: bool,
    ) -> Result<AsyncKvClient> {
        let n = placement.n_servers();
        anyhow::ensure!(states.len() == n && addrs.len() == n);
        let inflight = inflight.max(1);
        let mut acked_per_link = Vec::with_capacity(n);
        let mut links = Vec::with_capacity(n);
        for s in 0..n {
            // lint:allow(metrics-registry) — ack flow-control cell, see field doc
            acked_per_link.push(Arc::new(AtomicU64::new(0)));
            if placement.machine_of_server(s) == machine {
                links.push(AsyncLink::Local(states[s].clone()));
                continue;
            }
            let wr = TcpStream::connect(addrs[s])?;
            wr.set_nodelay(true)?;
            let rd = wr.try_clone()?;
            let win = Arc::new(InflightWindow::<Pending>::new(inflight));
            let (req_tx, req_rx) = sync_channel::<Req>(inflight);
            let w_win = win.clone();
            let writer = std::thread::Builder::new()
                .name(format!("dglke-kv-wr{s}"))
                .spawn(move || writer_loop(wr, req_rx, w_win))?;
            let r_acked = acked_per_link[s].clone();
            let reader = std::thread::Builder::new()
                .name(format!("dglke-kv-rd{s}"))
                .spawn(move || reader_loop(rd, win, r_acked))?;
            links.push(AsyncLink::Remote(RemoteLink {
                req_tx: Some(req_tx),
                writer: Some(writer),
                reader: Some(reader),
            }));
        }
        Ok(AsyncKvClient {
            machine,
            placement,
            links,
            ledger,
            overlap_pulls,
            local_pushes: 0,
            submitted_per_link: vec![0; n],
            acked_per_link,
        })
    }

    /// `(submitted, completed)` push-op totals across all links —
    /// diagnostics and the drain-barrier assertions; the stamp gating
    /// uses the per-link [`CommHandle::push_mark`] instead (a global
    /// count cannot say *which* pushes completed).
    pub fn push_marks(&self) -> (u64, u64) {
        let submitted = self.local_pushes + self.submitted_per_link.iter().sum::<u64>();
        let acked = self.local_pushes
            + self.acked_per_link.iter().map(|a| a.load(Ordering::Acquire)).sum::<u64>();
        (submitted, acked)
    }
}

/// Scatter/collection bookkeeping of one in-flight pull wave entry.
struct WavePart {
    back: Vec<usize>, // positions into the unique-row buffer
    n_slots: usize,
    rx: Receiver<PullResp>,
}

struct Wave {
    index: HashMap<u64, usize>,
    rows: Vec<f32>,
    parts: Vec<WavePart>,
}

impl CommHandle for AsyncKvClient {
    fn pull(&mut self, table: TableId, ids: &[u64], dim: usize, out: &mut [f32]) -> Result<()> {
        let mut reqs = [PullReq { table, ids, dim, out }];
        self.pull_all(&mut reqs)
    }

    /// Two phases: dispatch every remote request of every wave entry
    /// (local shards are served inline — a memcpy), then collect. All
    /// servers work their requests concurrently while this thread blocks
    /// on the first response.
    fn pull_all(&mut self, reqs: &mut [PullReq<'_>]) -> Result<()> {
        let _wave_span = span(SpanId::KvPullWave);
        let n = self.links.len();
        let mut waves: Vec<Wave> = Vec::with_capacity(reqs.len());
        for req in reqs.iter_mut() {
            debug_assert_eq!(req.out.len(), req.ids.len() * req.dim);
            // dedup: each unique row crosses the wire once per wave entry
            let mut unique: Vec<u64> = Vec::with_capacity(req.ids.len());
            let mut index: HashMap<u64, usize> = HashMap::with_capacity(req.ids.len());
            for &id in req.ids {
                index.entry(id).or_insert_with(|| {
                    unique.push(id);
                    unique.len() - 1
                });
            }
            // group by owning server
            let mut slots: Vec<Vec<u64>> = vec![Vec::new(); n];
            let mut back: Vec<Vec<usize>> = vec![Vec::new(); n];
            for (u, &id) in unique.iter().enumerate() {
                let (s, slot) = self.placement.server_and_slot(req.table, id);
                slots[s].push(slot);
                back[s].push(u);
            }
            let mut rows = vec![0f32; unique.len() * req.dim];
            let mut parts = Vec::new();
            for s in 0..n {
                if slots[s].is_empty() {
                    continue;
                }
                let nbytes = (slots[s].len() * req.dim * 4 + slots[s].len() * 8) as u64;
                match &self.links[s] {
                    AsyncLink::Local(state) => {
                        self.ledger.local_bytes.add(nbytes);
                        let mut tmp = vec![0f32; slots[s].len() * req.dim];
                        state.pull_local(req.table, &slots[s], &mut tmp);
                        for (j, &u) in back[s].iter().enumerate() {
                            rows[u * req.dim..(u + 1) * req.dim]
                                .copy_from_slice(&tmp[j * req.dim..(j + 1) * req.dim]);
                        }
                    }
                    AsyncLink::Remote(link) => {
                        self.ledger.remote_bytes.add(nbytes);
                        self.ledger.remote_requests.inc();
                        if self.overlap_pulls {
                            self.ledger.overlapped_bytes.add(nbytes);
                        }
                        let (tx, rx) = sync_channel(1);
                        let n_slots = slots[s].len();
                        link.send(Req::Pull {
                            table: req.table,
                            slots: std::mem::take(&mut slots[s]),
                            reply: tx,
                        })?;
                        parts.push(WavePart { back: std::mem::take(&mut back[s]), n_slots, rx });
                    }
                }
            }
            waves.push(Wave { index, rows, parts });
        }
        // collect responses and scatter to caller layout
        for (req, wave) in reqs.iter_mut().zip(waves.iter_mut()) {
            for part in wave.parts.drain(..) {
                let rows_part = part
                    .rx
                    .recv()
                    .map_err(|_| anyhow!("kvstore connection lost during pull"))?
                    .map_err(|e| anyhow!("server pull failed: {e}"))?;
                anyhow::ensure!(
                    rows_part.len() == part.n_slots * req.dim,
                    "bad pull response size: {} values for {} slots of dim {}",
                    rows_part.len(),
                    part.n_slots,
                    req.dim
                );
                for (j, &u) in part.back.iter().enumerate() {
                    wave.rows[u * req.dim..(u + 1) * req.dim]
                        .copy_from_slice(&rows_part[j * req.dim..(j + 1) * req.dim]);
                }
            }
            for (j, &id) in req.ids.iter().enumerate() {
                let u = wave.index[&id];
                req.out[j * req.dim..(j + 1) * req.dim]
                    .copy_from_slice(&wave.rows[u * req.dim..(u + 1) * req.dim]);
            }
        }
        Ok(())
    }

    /// Fire-and-forget under the bounded in-flight window: remote frames
    /// are queued to the owning link's writer and acked in the
    /// background; local shards apply inline. Returns once queued —
    /// [`CommHandle::drain`] is the completion barrier.
    fn push(&mut self, table: TableId, ids: &[u64], dim: usize, rows: &[f32]) -> Result<()> {
        let _push_span = span(SpanId::KvPush);
        debug_assert_eq!(rows.len(), ids.len() * dim);
        let n = self.links.len();
        let mut slots: Vec<Vec<u64>> = vec![Vec::new(); n];
        let mut data: Vec<Vec<f32>> = vec![Vec::new(); n];
        for (j, &id) in ids.iter().enumerate() {
            let (s, slot) = self.placement.server_and_slot(table, id);
            slots[s].push(slot);
            data[s].extend_from_slice(&rows[j * dim..(j + 1) * dim]);
        }
        for s in 0..n {
            if slots[s].is_empty() {
                continue;
            }
            let nbytes = (data[s].len() * 4 + slots[s].len() * 8) as u64;
            match &self.links[s] {
                AsyncLink::Local(state) => {
                    self.ledger.local_bytes.add(nbytes);
                    state.push_local(table, &slots[s], &data[s]);
                    self.local_pushes += 1;
                }
                AsyncLink::Remote(link) => {
                    self.ledger.remote_bytes.add(nbytes);
                    self.ledger.remote_requests.inc();
                    // a queued push is off the critical path: its wire time
                    // overlaps the trainer's next sample/pull/compute
                    self.ledger.overlapped_bytes.add(nbytes);
                    self.submitted_per_link[s] += 1;
                    link.send(Req::Push {
                        table,
                        slots: std::mem::take(&mut slots[s]),
                        rows: std::mem::take(&mut data[s]),
                    })?;
                }
            }
        }
        Ok(())
    }

    fn drain(&mut self) -> Result<()> {
        let _drain_span = span(SpanId::KvDrain);
        // fan the barrier out, then wait — links drain concurrently
        let mut acks = Vec::new();
        for link in &self.links {
            if let AsyncLink::Remote(link) = link {
                let (tx, rx) = sync_channel(1);
                link.send(Req::Drain { ack: tx })?;
                acks.push(rx);
            }
        }
        for rx in acks {
            rx.recv().map_err(|_| anyhow!("kvstore connection lost during drain"))?;
        }
        Ok(())
    }

    fn push_mark(&self) -> Vec<u64> {
        self.submitted_per_link.clone()
    }

    fn pushes_complete(&self, mark: &[u64]) -> bool {
        mark.iter()
            .zip(&self.acked_per_link)
            .all(|(&m, acked)| acked.load(Ordering::Acquire) >= m)
    }
}

impl Drop for AsyncKvClient {
    fn drop(&mut self) {
        for link in &mut self.links {
            if let AsyncLink::Remote(l) = link {
                // closing the request channel makes the writer finish the
                // queued work, send OP_STOP, and close the pending queue;
                // the reader answers everything outstanding and exits
                l.req_tx.take();
                if let Some(h) = l.writer.take() {
                    let _ = h.join();
                }
                if let Some(h) = l.reader.take() {
                    let _ = h.join();
                }
            }
        }
    }
}

/// Writer half of a remote link: turns queued requests into tagged wire
/// frames, in submission order, under the bounded pending window. The
/// pending entry is queued *before* the frame is written so the reader
/// can never see an unmatched response.
fn writer_loop(mut wr: TcpStream, rx: Receiver<Req>, win: Arc<InflightWindow<Pending>>) {
    let mut next_tag: u32 = 0;
    let mut tag = || {
        let t = next_tag;
        next_tag = next_tag.wrapping_add(1);
        t
    };
    while let Ok(req) = rx.recv() {
        let ok = match req {
            Req::Pull { table, slots, reply } => {
                let t = tag();
                match win.enqueue(Pending::Pull { tag: t, reply }) {
                    Ok(()) => write_frame(
                        &mut wr,
                        OP_TPULL,
                        &prepend_tag(t, &encode_pull(table, &slots)),
                    )
                    .is_ok(),
                    Err(p) => {
                        deliver_failure(p);
                        false
                    }
                }
            }
            Req::Push { table, slots, rows } => {
                let t = tag();
                match win.enqueue(Pending::Push { tag: t }) {
                    Ok(()) => write_frame(
                        &mut wr,
                        OP_TPUSH,
                        &prepend_tag(t, &encode_push(table, &slots, &rows)),
                    )
                    .is_ok(),
                    Err(p) => {
                        deliver_failure(p);
                        false
                    }
                }
            }
            Req::Drain { ack } => win.enqueue(Pending::Drain { ack }).is_ok(),
        };
        if !ok {
            // a failed write leaves the peer's response stream broken: tear
            // the socket down so the (possibly blocked) reader errors out
            fail_link(&win);
            let _ = wr.shutdown(std::net::Shutdown::Both);
            return;
        }
    }
    // client hung up: say goodbye, then close the window
    if win.enqueue(Pending::Stop).is_ok() {
        let _ = write_frame(&mut wr, OP_STOP, &[]);
    }
    win.close();
}

/// Reader half of a remote link: consumes responses independently of
/// writer progress (no write/read deadlock however deep the pipeline),
/// matching each against the front of the pending window and verifying
/// its echoed tag.
// lint:allow(metrics-registry) — ack flow-control cell, see acked_per_link
fn reader_loop(mut rd: TcpStream, win: Arc<InflightWindow<Pending>>, acked: Arc<AtomicU64>) {
    loop {
        let p = match win.pop() {
            PopOutcome::Entry(p) => p,
            PopOutcome::Closed | PopOutcome::Failed => return,
        };
        match p {
            Pending::Drain { ack } => {
                // everything queued before the marker has been answered
                let _ = ack.send(());
            }
            Pending::Stop => {
                let _ = read_frame(&mut rd); // the server's STOP ack
                return;
            }
            Pending::Pull { tag, reply } => match read_tagged_ok(&mut rd, tag) {
                Ok(inner) => {
                    let rows = Reader::new(&inner).f32_vec().map_err(|e| e.to_string());
                    let _ = reply.send(rows);
                }
                Err(e) => {
                    let _ = reply.send(Err(e));
                    fail_link(&win);
                    return;
                }
            },
            Pending::Push { tag } => match read_tagged_ok(&mut rd, tag) {
                Ok(_) => {
                    // Release: pairs with the Acquire in pushes_complete /
                    // push_marks — an observer that sees ack count >= mark
                    // also sees the server-side effects of those pushes
                    // (see docs/CONCURRENCY.md §acked_per_link).
                    acked.fetch_add(1, Ordering::Release);
                }
                Err(_) => {
                    fail_link(&win);
                    return;
                }
            },
        }
    }
}

fn read_tagged_ok(rd: &mut TcpStream, want_tag: u32) -> std::result::Result<Vec<u8>, String> {
    let (op, payload) = read_frame(rd).map_err(|e| e.to_string())?;
    if op != OP_TOK {
        return Err(format!("server error (op {op:#x})"));
    }
    let (tag, inner) = split_tag(&payload).map_err(|e| e.to_string())?;
    if tag != want_tag {
        return Err(format!("response tag {tag} does not match expected {want_tag}"));
    }
    Ok(inner.to_vec())
}

/// Pull all five sections of a batch through `comm` as one wave (the
/// distributed analogue of [`BatchBuffers::gather`]).
pub fn pull_batch(
    comm: &mut dyn CommHandle,
    batch: &Batch,
    buf: &mut BatchBuffers,
    dim: usize,
    rel_dim: usize,
) -> Result<()> {
    let BatchBuffers { h, r, t, neg_h, neg_t } = buf;
    let rels = PullReq {
        table: TableId::Relations,
        ids: &batch.rels,
        dim: rel_dim,
        out: r.as_mut_slice(),
    };
    let mut reqs = [
        PullReq { table: TableId::Entities, ids: &batch.heads, dim, out: h.as_mut_slice() },
        rels,
        PullReq { table: TableId::Entities, ids: &batch.tails, dim, out: t.as_mut_slice() },
        PullReq { table: TableId::Entities, ids: &batch.neg_heads, dim, out: neg_h.as_mut_slice() },
        PullReq { table: TableId::Entities, ids: &batch.neg_tails, dim, out: neg_t.as_mut_slice() },
    ];
    comm.pull_all(&mut reqs)
}

/// Re-pull the rows of `batch` whose ids appear in the dirty sets — the
/// ids this trainer pushed since the prefetched pull's stamp — and patch
/// them into `buf` (the distributed analogue of
/// [`BatchBuffers::patch_rows`]). Issued on the *trainer's* handle, after
/// its pushes, so per-server frame ordering guarantees the re-pulled rows
/// reflect every applied update. The re-pull sits on the critical path
/// and is billed by the pull itself (a trainer handle never overlaps).
pub fn patch_batch(
    comm: &mut dyn CommHandle,
    batch: &Batch,
    buf: &mut BatchBuffers,
    dim: usize,
    rel_dim: usize,
    ent_dirty: &HashSet<u64>,
    rel_dirty: &HashSet<u64>,
) -> Result<()> {
    if ent_dirty.is_empty() && rel_dirty.is_empty() {
        return Ok(());
    }
    struct Sect<'a> {
        table: TableId,
        d: usize,
        pos: Vec<usize>,
        ids: Vec<u64>,
        out: &'a mut Vec<f32>,
    }
    let mut work: Vec<Sect<'_>> = Vec::with_capacity(5);
    {
        let BatchBuffers { h, r, t, neg_h, neg_t } = buf;
        let sections: [(&[u64], &mut Vec<f32>, &HashSet<u64>, usize, TableId); 5] = [
            (&batch.heads, h, ent_dirty, dim, TableId::Entities),
            (&batch.tails, t, ent_dirty, dim, TableId::Entities),
            (&batch.neg_heads, neg_h, ent_dirty, dim, TableId::Entities),
            (&batch.neg_tails, neg_t, ent_dirty, dim, TableId::Entities),
            (&batch.rels, r, rel_dirty, rel_dim, TableId::Relations),
        ];
        for (ids, out, dirty, d, table) in sections {
            let mut pos = Vec::new();
            let mut sel = Vec::new();
            for (j, &id) in ids.iter().enumerate() {
                if dirty.contains(&id) {
                    pos.push(j);
                    sel.push(id);
                }
            }
            if !sel.is_empty() {
                work.push(Sect { table, d, pos, ids: sel, out });
            }
        }
    }
    if work.is_empty() {
        return Ok(());
    }
    let mut tmps: Vec<Vec<f32>> =
        work.iter().map(|s| vec![0f32; s.ids.len() * s.d]).collect();
    {
        let mut reqs: Vec<PullReq<'_>> = work
            .iter()
            .zip(tmps.iter_mut())
            .map(|(s, tmp)| PullReq {
                table: s.table,
                ids: &s.ids,
                dim: s.d,
                out: tmp.as_mut_slice(),
            })
            .collect();
        comm.pull_all(&mut reqs)?;
    }
    for (s, tmp) in work.iter_mut().zip(tmps.iter()) {
        for (k, &j) in s.pos.iter().enumerate() {
            s.out[j * s.d..(j + 1) * s.d].copy_from_slice(&tmp[k * s.d..(k + 1) * s.d]);
        }
    }
    Ok(())
}

/// A sampled batch with its pulled embeddings, produced by
/// [`DistPrefetcher`] one step ahead of compute.
pub struct DistBatch {
    pub batch: Batch,
    pub buf: BatchBuffers,
    /// the trainer's applied-push counter observed *before* the pull
    /// began: rows pushed at or after this step may be stale and must be
    /// patched ([`patch_batch`])
    pub gathered_at: u64,
}

/// Distributed prefetch pipeline: a helper thread owning cloned sampler
/// cursors and its own comm handle runs sample(N+1) + pull(N+1) while the
/// trainer computes step N — the PR-3 [`crate::train::prefetch`] pipeline
/// with the gather replaced by a KVStore pull wave, where the overlap
/// matters even more (the gather is network I/O, not a memcpy).
pub struct DistPrefetcher<'scope> {
    out_rx: Receiver<std::result::Result<DistBatch, String>>,
    free_tx: SyncSender<BatchBuffers>,
    handle: Option<ScopedJoinHandle<'scope, ()>>,
}

impl<'scope> DistPrefetcher<'scope> {
    /// Spawn the helper inside `scope`, taking ownership of the sampler
    /// cursors and `comm` (the helper's own connections — its pulls must
    /// not serialize behind the trainer's traffic). `depth` buffers
    /// circulate (>= 2, double buffering); `applied` is the trainer's
    /// acked-push step counter used to stamp pulls for patching.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_scoped<'env>(
        scope: &'scope Scope<'scope, 'env>,
        mut pos: PositiveSampler,
        mut neg: NegativeSampler,
        triplets: &'env TripletStore,
        mut comm: Box<dyn CommHandle>,
        shape: StepShape,
        rel_dim: usize,
        depth: usize,
        // lint:allow(metrics-registry) — applied stamp (Release/Acquire), not a stat
        applied: Arc<AtomicU64>,
    ) -> Result<DistPrefetcher<'scope>> {
        let depth = depth.max(2);
        let (out_tx, out_rx) = sync_channel::<std::result::Result<DistBatch, String>>(depth);
        let (free_tx, free_rx) = sync_channel::<BatchBuffers>(depth);
        for _ in 0..depth {
            // the channel was just created with capacity `depth`: a send
            // can only fail if the receiver was dropped, which it wasn't
            free_tx
                .send(BatchBuffers::new(&shape, rel_dim))
                .map_err(|_| anyhow!("dist prefetch buffer pool channel closed during seeding"))?;
        }
        let handle = std::thread::Builder::new()
            .name("dglke-dist-prefetch".into())
            .spawn_scoped(scope, move || {
                let mut idx_buf: Vec<u32> = Vec::with_capacity(shape.batch);
                while let Ok(mut buf) = free_rx.recv() {
                    let gathered_at = applied.load(Ordering::Acquire);
                    pos.next_batch(shape.batch, &mut idx_buf);
                    let batch = neg.assemble(triplets, &idx_buf);
                    match pull_batch(&mut *comm, &batch, &mut buf, shape.dim, rel_dim) {
                        Ok(()) => {
                            if out_tx.send(Ok(DistBatch { batch, buf, gathered_at })).is_err() {
                                break; // trainer finished
                            }
                        }
                        Err(e) => {
                            let _ = out_tx.send(Err(e.to_string()));
                            break;
                        }
                    }
                }
            })
            .map_err(|e| anyhow!("spawning dist prefetch thread: {e}"))?;
        Ok(DistPrefetcher { out_rx, free_tx, handle: Some(handle) })
    }

    /// Receive the next prefetched batch. Blocking here is the pipeline
    /// stall; pull errors on the helper surface here.
    pub fn recv(&mut self) -> Result<DistBatch> {
        self.out_rx
            .recv()
            .map_err(|_| anyhow!("dist prefetch thread terminated unexpectedly"))?
            .map_err(|e| anyhow!("prefetch pull failed: {e}"))
    }

    /// Return a consumed batch's buffers to the pool.
    pub fn recycle(&self, b: DistBatch) {
        let _ = self.free_tx.send(b.buf);
    }

    /// Stop the helper thread (its comm handle drops with it).
    pub fn finish(mut self) -> Result<()> {
        let handle =
            self.handle.take().ok_or_else(|| anyhow!("dist prefetcher already finished"))?;
        drop(self); // closes out_rx + free_tx: the helper's send/recv fails
        handle.join().map_err(|_| anyhow!("dist prefetch thread panicked"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::server::KvServer;
    use crate::store::EmbeddingStore;

    /// 2 machines × 1 server, 10 entities striped, 4 relations, dim 4.
    fn cluster() -> (Vec<KvServer>, Arc<Placement>, Vec<Arc<ServerState>>, Vec<std::net::SocketAddr>)
    {
        let entity_machine: Vec<u32> = (0..10).map(|i| (i % 2) as u32).collect();
        let placement = Arc::new(Placement::build(&entity_machine, 4, 2, 1, 3));
        let mut servers = Vec::new();
        let mut states = Vec::new();
        let mut addrs = Vec::new();
        for s in 0..2 {
            let state = Arc::new(ServerState::init(
                &placement.ent_ids_of_server[s],
                &placement.rel_ids_of_server[s],
                4,
                4,
                0.5,
                0.1,
                99,
            ));
            let server = KvServer::start(state.clone()).unwrap();
            addrs.push(server.addr);
            states.push(state);
            servers.push(server);
        }
        (servers, placement, states, addrs)
    }

    fn async_client(
        placement: &Arc<Placement>,
        states: &[Arc<ServerState>],
        addrs: &[std::net::SocketAddr],
        ledger: Arc<NetLedger>,
        inflight: usize,
        overlap: bool,
    ) -> AsyncKvClient {
        AsyncKvClient::connect(0, placement.clone(), states, addrs, ledger, inflight, overlap)
            .unwrap()
    }

    #[test]
    fn async_pull_matches_sync_pull() {
        let (_servers, placement, states, addrs) = cluster();
        let sync_ledger = Arc::new(NetLedger::new());
        let async_ledger = Arc::new(NetLedger::new());
        let mut sync_c =
            KvClient::connect(0, placement.clone(), &states, &addrs, sync_ledger.clone()).unwrap();
        let mut async_c = async_client(&placement, &states, &addrs, async_ledger.clone(), 4, false);
        let ids = [0u64, 3, 3, 7, 2, 9, 1];
        let mut a = vec![0f32; ids.len() * 4];
        let mut b = vec![0f32; ids.len() * 4];
        sync_c.pull(TableId::Entities, &ids, 4, &mut a).unwrap();
        CommHandle::pull(&mut async_c, TableId::Entities, &ids, 4, &mut b).unwrap();
        assert_eq!(a, b);
        // identical byte accounting on both paths
        assert_eq!(sync_ledger.remote(), async_ledger.remote());
        assert_eq!(sync_ledger.local(), async_ledger.local());
        assert_eq!(async_ledger.overlapped(), 0, "critical-path client bills no overlap");
    }

    #[test]
    fn pull_wave_fans_out_and_pipelines() {
        let (_servers, placement, states, addrs) = cluster();
        let ledger = Arc::new(NetLedger::new());
        let mut c = async_client(&placement, &states, &addrs, ledger, 2, false);
        // many more waves than the in-flight window, values verified
        // against the server shards directly
        for round in 0..30u64 {
            let ids: Vec<u64> = (0..10).map(|i| (i + round) % 10).collect();
            let rel_ids: Vec<u64> = (0..4).collect();
            let mut ents = vec![0f32; ids.len() * 4];
            let mut rels = vec![0f32; rel_ids.len() * 4];
            {
                let mut reqs = [
                    PullReq { table: TableId::Entities, ids: &ids, dim: 4, out: &mut ents[..] },
                    PullReq {
                        table: TableId::Relations,
                        ids: &rel_ids,
                        dim: 4,
                        out: &mut rels[..],
                    },
                ];
                c.pull_all(&mut reqs).unwrap();
            }
            for (j, &id) in ids.iter().enumerate() {
                let (s, slot) = placement.server_and_slot(TableId::Entities, id);
                assert_eq!(
                    &ents[j * 4..(j + 1) * 4],
                    states[s].ents.row_vec(slot as usize).as_slice()
                );
            }
            for (j, &id) in rel_ids.iter().enumerate() {
                let (s, slot) = placement.server_and_slot(TableId::Relations, id);
                assert_eq!(
                    &rels[j * 4..(j + 1) * 4],
                    states[s].rels.row_vec(slot as usize).as_slice()
                );
            }
        }
    }

    #[test]
    fn fire_and_forget_push_lands_after_drain() {
        let (_servers, placement, states, addrs) = cluster();
        let ledger = Arc::new(NetLedger::new());
        let mut c = async_client(&placement, &states, &addrs, ledger, 4, false);
        // entity 1 is remote from machine 0
        let (s, slot) = placement.server_and_slot(TableId::Entities, 1);
        let before = states[s].ents.row_vec(slot as usize);
        for _ in 0..20 {
            CommHandle::push(&mut c, TableId::Entities, &[1], 4, &[0.1, 0.1, 0.1, 0.1]).unwrap();
        }
        c.drain().unwrap();
        let (submitted, completed) = c.push_marks();
        assert_eq!(submitted, 20);
        assert_eq!(completed, 20, "drain must wait for every ack");
        assert_ne!(states[s].ents.row_vec(slot as usize), before);
    }

    #[test]
    fn per_link_marks_gate_on_remote_acks() {
        let (_servers, placement, states, addrs) = cluster();
        let ledger = Arc::new(NetLedger::new());
        let mut c = async_client(&placement, &states, &addrs, ledger, 4, false);
        let m0 = c.push_mark();
        assert!(c.pushes_complete(&m0), "nothing in flight: the empty mark is complete");
        // one remote (entity 1) and one local (entity 0) push; the local
        // completes inline, and must not be able to stand in for the
        // remote ack — the mark is per link, not a fungible total
        CommHandle::push(&mut c, TableId::Entities, &[1], 4, &[0.2; 4]).unwrap();
        let m1 = c.push_mark();
        CommHandle::push(&mut c, TableId::Entities, &[0], 4, &[0.2; 4]).unwrap();
        let (s_remote, _) = placement.server_and_slot(TableId::Entities, 1);
        assert_eq!(m1[s_remote], 1, "mark records the remote link's submitted count");
        c.drain().unwrap();
        assert!(c.pushes_complete(&m1), "after drain every mark is complete");
        assert!(c.pushes_complete(&c.push_mark()));
        let (submitted, acked) = c.push_marks();
        assert_eq!(submitted, 2, "one remote op (entity 1) + one local op (entity 0)");
        assert_eq!(submitted, acked);
    }

    #[test]
    fn push_then_pull_on_same_handle_sees_update() {
        // per-connection frame ordering: a pull submitted after a push is
        // answered with the pushed state, without any drain in between
        let (_servers, placement, states, addrs) = cluster();
        let ledger = Arc::new(NetLedger::new());
        let mut c = async_client(&placement, &states, &addrs, ledger, 4, false);
        let mut before = vec![0f32; 4];
        CommHandle::pull(&mut c, TableId::Entities, &[1], 4, &mut before).unwrap();
        CommHandle::push(&mut c, TableId::Entities, &[1], 4, &[1.0, 1.0, 1.0, 1.0]).unwrap();
        let mut after = vec![0f32; 4];
        CommHandle::pull(&mut c, TableId::Entities, &[1], 4, &mut after).unwrap();
        assert_ne!(after, before);
        let (s, slot) = placement.server_and_slot(TableId::Entities, 1);
        assert_eq!(after, states[s].ents.row_vec(slot as usize));
    }

    #[test]
    fn overlap_client_bills_overlapped_pulls() {
        let (_servers, placement, states, addrs) = cluster();
        let ledger = Arc::new(NetLedger::new());
        let mut c = async_client(&placement, &states, &addrs, ledger.clone(), 4, true);
        let ids: Vec<u64> = (0..10).collect();
        let mut out = vec![0f32; 10 * 4];
        CommHandle::pull(&mut c, TableId::Entities, &ids, 4, &mut out).unwrap();
        assert!(ledger.overlapped() > 0);
        assert_eq!(ledger.overlapped(), ledger.remote(), "all remote pulls were overlapped");
        assert!(ledger.local() > 0, "local shard still served inline");
    }
}
