//! Distributed key-value store for embeddings (paper §3.6).
//!
//! * multiple servers per machine (parallel KVStore computation);
//! * relation reshuffling across servers (long-tail hot-spot avoidance);
//! * same-machine shared-memory fast path, cross-machine TCP;
//! * server-side sparse AdaGrad (gradient communication overlapped with
//!   local optimizer work);
//! * a [`NetLedger`] counting local vs remote traffic — the quantity the
//!   METIS partitioning of §3.2 minimizes — split into critical-path and
//!   overlapped bytes;
//! * [`comm`] — the asynchronous client: per-server I/O worker threads,
//!   request-tagged pipelined frames, fire-and-forget pushes behind a
//!   [`comm::CommHandle::drain`] barrier, and the distributed prefetch
//!   pipeline.

pub mod client;
pub mod comm;
pub mod placement;
pub mod protocol;
pub mod server;
pub mod window;

pub use client::{KvClient, NetLedger};
pub use comm::{AsyncKvClient, CommHandle, DistPrefetcher, PullReq};
pub use window::{InflightWindow, PopOutcome};
pub use placement::Placement;
pub use protocol::TableId;
pub use server::{KvServer, ServerState};

use crate::store::{DenseStore, EmbeddingStore};
use anyhow::Result;
use std::sync::Arc;

/// A full in-process cluster: machines × servers_per_machine KvServers.
pub struct KvCluster {
    pub placement: Arc<Placement>,
    pub states: Vec<Arc<ServerState>>,
    pub addrs: Vec<std::net::SocketAddr>,
    servers: Vec<KvServer>,
    pub ledger: Arc<NetLedger>,
}

impl KvCluster {
    /// Boot servers for the given entity→machine assignment (dense shards).
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        entity_machine: &[u32],
        n_relations: usize,
        machines: usize,
        servers_per_machine: usize,
        dim: usize,
        rel_dim: usize,
        lr: f32,
        init_scale: f32,
        seed: u64,
    ) -> Result<KvCluster> {
        Self::start_with_storage(
            entity_machine,
            n_relations,
            machines,
            servers_per_machine,
            dim,
            rel_dim,
            lr,
            init_scale,
            seed,
            &crate::store::StoreConfig::dense(),
        )
    }

    /// Boot servers with shard tables on an explicit storage backend —
    /// each server hosts one partition of the global table on dense,
    /// sharded, or file-backed (mmap) storage.
    #[allow(clippy::too_many_arguments)]
    pub fn start_with_storage(
        entity_machine: &[u32],
        n_relations: usize,
        machines: usize,
        servers_per_machine: usize,
        dim: usize,
        rel_dim: usize,
        lr: f32,
        init_scale: f32,
        seed: u64,
        storage: &crate::store::StoreConfig,
    ) -> Result<KvCluster> {
        let placement = Arc::new(Placement::build(
            entity_machine,
            n_relations,
            machines,
            servers_per_machine,
            seed,
        ));
        let mut states = Vec::new();
        let mut addrs = Vec::new();
        let mut servers = Vec::new();
        for s in 0..placement.n_servers() {
            let state = Arc::new(ServerState::init_with_storage(
                &format!("server{s}"),
                &placement.ent_ids_of_server[s],
                &placement.rel_ids_of_server[s],
                dim,
                rel_dim,
                lr,
                init_scale,
                seed,
                storage,
            )?);
            let server = KvServer::start(state.clone())?;
            addrs.push(server.addr);
            states.push(state);
            servers.push(server);
        }
        Ok(KvCluster { placement, states, addrs, servers, ledger: Arc::new(NetLedger::new()) })
    }

    /// New client homed on `machine`.
    pub fn client(&self, machine: usize) -> Result<KvClient> {
        KvClient::connect(
            machine,
            self.placement.clone(),
            &self.states,
            &self.addrs,
            self.ledger.clone(),
        )
    }

    /// New pipelined/async client homed on `machine`. `inflight` bounds
    /// the unanswered frames per remote connection; `overlap_pulls` bills
    /// the client's remote pull traffic as overlapped (set for prefetch
    /// helpers, whose pulls run under the trainer's compute).
    pub fn async_client(
        &self,
        machine: usize,
        inflight: usize,
        overlap_pulls: bool,
    ) -> Result<AsyncKvClient> {
        AsyncKvClient::connect(
            machine,
            self.placement.clone(),
            &self.states,
            &self.addrs,
            self.ledger.clone(),
            inflight,
            overlap_pulls,
        )
    }

    /// Snapshot all entity embeddings into a dense table (for evaluation).
    pub fn dump_entities(&self, n_entities: usize, dim: usize) -> Arc<dyn EmbeddingStore> {
        let table = DenseStore::zeros(n_entities, dim);
        let mut buf = vec![0f32; dim];
        for s in 0..self.placement.n_servers() {
            // lint:allow(ledger-billing) — shared-memory snapshot for
            // eval/export after training; the ledger audits train traffic
            for (slot, &id) in self.placement.ent_ids_of_server[s].iter().enumerate() {
                self.states[s].ents.read_row(slot, &mut buf);
                table.set_row(id as usize, &buf);
            }
        }
        Arc::new(table)
    }

    /// Snapshot all relation embeddings.
    pub fn dump_relations(&self, n_relations: usize, rel_dim: usize) -> Arc<dyn EmbeddingStore> {
        let table = DenseStore::zeros(n_relations, rel_dim);
        let mut buf = vec![0f32; rel_dim];
        for s in 0..self.placement.n_servers() {
            // lint:allow(ledger-billing) — shared-memory snapshot for
            // eval/export after training; the ledger audits train traffic
            for (slot, &id) in self.placement.rel_ids_of_server[s].iter().enumerate() {
                self.states[s].rels.read_row(slot, &mut buf);
                table.set_row(id as usize, &buf);
            }
        }
        Arc::new(table)
    }

    pub fn shutdown(&mut self) {
        for s in &mut self.servers {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_boot_and_dump() {
        let entity_machine: Vec<u32> = (0..20).map(|i| (i % 2) as u32).collect();
        let cluster = KvCluster::start(&entity_machine, 6, 2, 2, 4, 4, 0.1, 0.2, 5).unwrap();
        let ents = cluster.dump_entities(20, 4);
        // init is id-derived: independent single-table init must match
        let state = ServerState::init(&[7], &[], 4, 4, 0.1, 0.2, 5);
        assert_eq!(ents.row_vec(7), state.ents.row_vec(0));
        let rels = cluster.dump_relations(6, 4);
        assert_eq!(rels.rows(), 6);
    }

    #[test]
    fn client_pull_matches_dump() {
        let entity_machine: Vec<u32> = (0..12).map(|i| (i % 3) as u32).collect();
        let cluster = KvCluster::start(&entity_machine, 4, 3, 1, 4, 4, 0.1, 0.2, 9).unwrap();
        let dump = cluster.dump_entities(12, 4);
        let mut client = cluster.client(1).unwrap();
        let ids: Vec<u64> = (0..12).collect();
        let mut out = vec![0f32; 12 * 4];
        client.pull(TableId::Entities, &ids, 4, &mut out).unwrap();
        for i in 0..12 {
            assert_eq!(&out[i * 4..(i + 1) * 4], dump.row_vec(i).as_slice());
        }
    }
}
