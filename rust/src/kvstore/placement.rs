//! Placement: which server owns each embedding row, and at which slot.
//!
//! * **Entities** follow the graph partition: a METIS partition's entities
//!   live on its machine's servers (paper §3.2 "co-locate the embeddings
//!   of the entities with the triplets in the diagonal block"), spread
//!   across that machine's servers by hash.
//! * **Relations** are *reshuffled* across all servers by hash (paper
//!   §3.6: long-tail relation frequencies would otherwise make the server
//!   holding the head relations a hot spot).

use crate::util::rng::splitmix64;

#[derive(Clone, Debug)]
pub struct Placement {
    pub machines: usize,
    pub servers_per_machine: usize,
    /// entity id → global server index
    pub ent_server: Vec<u32>,
    /// entity id → slot within its server
    pub ent_slot: Vec<u32>,
    /// relation id → global server index
    pub rel_server: Vec<u32>,
    /// relation id → slot within its server
    pub rel_slot: Vec<u32>,
    /// per-server (entity_count, relation_count)
    pub server_sizes: Vec<(usize, usize)>,
    /// per-server list of entity ids in slot order (for init/dump)
    pub ent_ids_of_server: Vec<Vec<u64>>,
    pub rel_ids_of_server: Vec<Vec<u64>>,
}

fn hash_to(seed: u64, id: u64, buckets: usize) -> usize {
    let mut s = seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    (splitmix64(&mut s) % buckets as u64) as usize
}

impl Placement {
    /// `entity_machine[id]` assigns entities to machines (from the graph
    /// partition; use a uniform hash assignment when training without
    /// METIS).
    pub fn build(
        entity_machine: &[u32],
        n_relations: usize,
        machines: usize,
        servers_per_machine: usize,
        seed: u64,
    ) -> Placement {
        let n_servers = machines * servers_per_machine;
        let n_entities = entity_machine.len();
        let mut ent_server = vec![0u32; n_entities];
        let mut ent_slot = vec![0u32; n_entities];
        let mut rel_server = vec![0u32; n_relations];
        let mut rel_slot = vec![0u32; n_relations];
        let mut server_sizes = vec![(0usize, 0usize); n_servers];
        let mut ent_ids_of_server: Vec<Vec<u64>> = vec![Vec::new(); n_servers];
        let mut rel_ids_of_server: Vec<Vec<u64>> = vec![Vec::new(); n_servers];

        for (id, &m) in entity_machine.iter().enumerate() {
            debug_assert!((m as usize) < machines);
            let local = hash_to(seed ^ 0xE17, id as u64, servers_per_machine);
            let s = m as usize * servers_per_machine + local;
            ent_server[id] = s as u32;
            ent_slot[id] = server_sizes[s].0 as u32;
            server_sizes[s].0 += 1;
            ent_ids_of_server[s].push(id as u64);
        }
        // relations: reshuffled across ALL servers
        for id in 0..n_relations {
            let s = hash_to(seed ^ 0x4e1, id as u64, n_servers);
            rel_server[id] = s as u32;
            rel_slot[id] = server_sizes[s].1 as u32;
            server_sizes[s].1 += 1;
            rel_ids_of_server[s].push(id as u64);
        }
        Placement {
            machines,
            servers_per_machine,
            ent_server,
            ent_slot,
            rel_server,
            rel_slot,
            server_sizes,
            ent_ids_of_server,
            rel_ids_of_server,
        }
    }

    pub fn n_servers(&self) -> usize {
        self.machines * self.servers_per_machine
    }

    pub fn machine_of_server(&self, server: usize) -> usize {
        server / self.servers_per_machine
    }

    /// Owning server and within-server slot of one embedding row.
    pub fn server_and_slot(&self, table: crate::kvstore::TableId, id: u64) -> (usize, u64) {
        match table {
            crate::kvstore::TableId::Entities => {
                (self.ent_server[id as usize] as usize, self.ent_slot[id as usize] as u64)
            }
            crate::kvstore::TableId::Relations => {
                (self.rel_server[id as usize] as usize, self.rel_slot[id as usize] as u64)
            }
        }
    }

    /// Entities resident on `machine` (the local negative-sampling pool).
    pub fn entities_of_machine(&self, machine: usize) -> Vec<u32> {
        self.ent_server
            .iter()
            .enumerate()
            .filter(|&(_, &s)| self.machine_of_server(s as usize) == machine)
            .map(|(id, _)| id as u32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_placement() -> Placement {
        let entity_machine: Vec<u32> = (0..100).map(|i| (i % 4) as u32).collect();
        Placement::build(&entity_machine, 50, 4, 2, 7)
    }

    #[test]
    fn entities_land_on_their_machine() {
        let p = toy_placement();
        for id in 0..100usize {
            let s = p.ent_server[id] as usize;
            assert_eq!(p.machine_of_server(s), id % 4);
        }
    }

    #[test]
    fn slots_dense_per_server() {
        let p = toy_placement();
        for s in 0..p.n_servers() {
            let ids = &p.ent_ids_of_server[s];
            assert_eq!(ids.len(), p.server_sizes[s].0);
            for (slot, &id) in ids.iter().enumerate() {
                assert_eq!(p.ent_slot[id as usize] as usize, slot);
                assert_eq!(p.ent_server[id as usize] as usize, s);
            }
        }
    }

    #[test]
    fn relations_spread_across_servers() {
        let p = toy_placement();
        let used: std::collections::HashSet<u32> = p.rel_server.iter().copied().collect();
        // 50 relations over 8 servers should hit most servers
        assert!(used.len() >= 6, "{used:?}");
    }

    #[test]
    fn machine_pools_partition_entities() {
        let p = toy_placement();
        let total: usize = (0..4).map(|m| p.entities_of_machine(m).len()).sum();
        assert_eq!(total, 100);
        assert_eq!(p.entities_of_machine(0), (0..100u32).step_by(4).collect::<Vec<_>>());
    }
}
