//! KVStore wire protocol: length-prefixed frames over TCP.
//!
//! Frame = `[u32 len][u8 opcode][payload]`. Payloads use the codecs in
//! `util::bytes`. The protocol is deliberately tiny: PULL gathers rows,
//! PUSH applies gradients server-side (the server owns the optimizer,
//! like DGL-KE's KVStore), PING measures round trips, STOP shuts a
//! connection down.
//!
//! The pipelined client (`kvstore::comm`) uses the *tagged* variants
//! TPULL/TPUSH: their payload starts with a `u32` request tag that the
//! server echoes back in its TOK response, so a connection can carry many
//! in-flight frames and the reader can match (and verify) each response
//! against the request window without waiting for round trips.

use crate::util::bytes::{Reader, Writer};
use anyhow::{bail, Result};
use std::io::{Read, Write};

pub const OP_PULL: u8 = 1;
pub const OP_PUSH: u8 = 2;
pub const OP_PING: u8 = 3;
pub const OP_STOP: u8 = 4;
/// Tagged pull: payload = `[u32 tag][pull payload]`, answered by OP_TOK.
pub const OP_TPULL: u8 = 5;
/// Tagged push: payload = `[u32 tag][push payload]`, answered by OP_TOK.
pub const OP_TPUSH: u8 = 6;
pub const OP_OK: u8 = 0x80;
/// Tagged OK: payload = `[u32 tag][response payload]`.
pub const OP_TOK: u8 = 0x81;
pub const OP_ERR: u8 = 0xFF;

/// Table selector within a server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableId {
    Entities = 0,
    Relations = 1,
}

impl TableId {
    pub fn from_u8(v: u8) -> Result<TableId> {
        match v {
            0 => Ok(TableId::Entities),
            1 => Ok(TableId::Relations),
            _ => bail!("bad table id {v}"),
        }
    }
}

/// Write one frame.
pub fn write_frame(stream: &mut impl Write, opcode: u8, payload: &[u8]) -> Result<()> {
    let len = (payload.len() + 1) as u32;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(&[opcode])?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame; returns (opcode, payload). Caps frames at 1 GiB.
///
/// The opcode byte is read separately from the length-prefixed body so
/// the payload lands directly at offset 0 of its buffer (a former
/// `buf.remove(0)` here memmoved every payload byte — O(len) per frame).
pub fn read_frame(stream: &mut impl Read) -> Result<(u8, Vec<u8>)> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    // lint:allow(narrowing-cast) — u32 → usize cannot truncate on the
    // supported (>= 32-bit) targets, and the bound check below caps it
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 || len > (1 << 30) {
        bail!("bad frame length {len}");
    }
    let mut op = [0u8; 1];
    stream.read_exact(&mut op)?;
    let mut buf = vec![0u8; len - 1];
    stream.read_exact(&mut buf)?;
    Ok((op[0], buf))
}

/// Prefix `inner` with a little-endian request tag (TPULL/TPUSH payloads).
pub fn prepend_tag(tag: u32, inner: &[u8]) -> Vec<u8> {
    let mut v = Vec::with_capacity(4 + inner.len());
    v.extend_from_slice(&tag.to_le_bytes());
    v.extend_from_slice(inner);
    v
}

/// Split a tagged payload into (tag, inner payload).
pub fn split_tag(payload: &[u8]) -> Result<(u32, &[u8])> {
    if payload.len() < 4 {
        bail!("tagged frame too short ({} bytes)", payload.len());
    }
    let mut tag = [0u8; 4];
    tag.copy_from_slice(&payload[..4]); // length checked above
    Ok((u32::from_le_bytes(tag), &payload[4..]))
}

/// PULL request: (table, slots).
pub fn encode_pull(table: TableId, slots: &[u64]) -> Vec<u8> {
    let mut w = Writer::with_capacity(9 + slots.len() * 8);
    w.u8(table as u8);
    w.u64_slice(slots);
    w.buf
}

pub fn decode_pull(payload: &[u8]) -> Result<(TableId, Vec<u64>)> {
    let mut r = Reader::new(payload);
    let table = TableId::from_u8(r.u8()?)?;
    Ok((table, r.u64_vec()?))
}

/// PUSH request: (table, slots, grad rows).
pub fn encode_push(table: TableId, slots: &[u64], rows: &[f32]) -> Vec<u8> {
    let mut w = Writer::with_capacity(17 + slots.len() * 8 + rows.len() * 4);
    w.u8(table as u8);
    w.u64_slice(slots);
    w.f32_slice(rows);
    w.buf
}

pub fn decode_push(payload: &[u8]) -> Result<(TableId, Vec<u64>, Vec<f32>)> {
    let mut r = Reader::new(payload);
    let table = TableId::from_u8(r.u8()?)?;
    let slots = r.u64_vec()?;
    let rows = r.f32_vec()?;
    Ok((table, slots, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_PULL, b"hello").unwrap();
        let (op, payload) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(op, OP_PULL);
        assert_eq!(payload, b"hello");
    }

    #[test]
    fn pull_roundtrip() {
        let enc = encode_pull(TableId::Relations, &[3, 1, 4]);
        let (t, slots) = decode_pull(&enc).unwrap();
        assert_eq!(t, TableId::Relations);
        assert_eq!(slots, vec![3, 1, 4]);
    }

    #[test]
    fn push_roundtrip() {
        let enc = encode_push(TableId::Entities, &[7], &[1.0, -2.0]);
        let (t, slots, rows) = decode_push(&enc).unwrap();
        assert_eq!(t, TableId::Entities);
        assert_eq!(slots, vec![7]);
        assert_eq!(rows, vec![1.0, -2.0]);
    }

    #[test]
    fn empty_payload_frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_STOP, &[]).unwrap();
        let (op, payload) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(op, OP_STOP);
        assert!(payload.is_empty());
    }

    #[test]
    fn tagged_payload_roundtrip() {
        let inner = encode_pull(TableId::Entities, &[9, 2, 6]);
        let tagged = prepend_tag(0xDEAD_BEEF, &inner);
        let (tag, rest) = split_tag(&tagged).unwrap();
        assert_eq!(tag, 0xDEAD_BEEF);
        assert_eq!(rest, inner.as_slice());
        let (t, slots) = decode_pull(rest).unwrap();
        assert_eq!(t, TableId::Entities);
        assert_eq!(slots, vec![9, 2, 6]);
    }

    #[test]
    fn short_tagged_payload_rejected() {
        assert!(split_tag(&[1, 2]).is_err());
        assert!(split_tag(&[]).is_err());
        // exactly a tag, empty inner payload, is fine
        let (tag, rest) = split_tag(&7u32.to_le_bytes()).unwrap();
        assert_eq!(tag, 7);
        assert!(rest.is_empty());
    }

    #[test]
    fn oversize_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_frame_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_PUSH, b"abcdef").unwrap();
        buf.truncate(buf.len() - 2);
        assert!(read_frame(&mut buf.as_slice()).is_err());
    }
}
