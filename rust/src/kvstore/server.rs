//! KVStore server: owns embedding shards and applies sparse AdaGrad on
//! push (paper §3.6 — the KVStore does the optimizer work, overlapping
//! gradient communication with local gradient computation).
//!
//! Each server is reachable two ways:
//! * **shared memory** — same-machine trainers call [`ServerState`]
//!   methods directly through an `Arc` (the paper's same-machine
//!   optimization);
//! * **TCP** — remote trainers connect to the server's loopback port and
//!   speak the frame protocol; one service thread per connection.

use super::protocol::*;
use crate::obs::metrics::{global, Counter};
use crate::store::{EmbeddingStore, SparseAdagrad, StoreConfig};
use anyhow::Result;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// In-memory state of one server (shared-memory fast path operates on
/// this directly). The shard tables sit behind [`EmbeddingStore`], so a
/// server shard can be hosted on any backend (dense by default; sharded /
/// mmap via [`ServerState::init_with_storage`]) — each server *is* one
/// explicit partition of the global table.
pub struct ServerState {
    pub ents: Arc<dyn EmbeddingStore>,
    pub rels: Arc<dyn EmbeddingStore>,
    pub ent_opt: SparseAdagrad,
    pub rel_opt: SparseAdagrad,
    /// ops served (pulls, pushes) — diagnostics; registry cells under
    /// `kv.server.*`, read per-shard via `.get()`
    pub pulls: Counter,
    pub pushes: Counter,
}

impl ServerState {
    /// Initialize shard tables on the default dense backend.
    pub fn init(
        ent_ids: &[u64],
        rel_ids: &[u64],
        dim: usize,
        rel_dim: usize,
        lr: f32,
        init_scale: f32,
        seed: u64,
    ) -> ServerState {
        Self::init_with_storage(
            "server",
            ent_ids,
            rel_ids,
            dim,
            rel_dim,
            lr,
            init_scale,
            seed,
            &StoreConfig::dense(),
        )
        // lint:allow(unwrap-ban) — startup path; the dense backend's init
        // is infallible (no files, no allocation beyond Vec), so a panic
        // here means a programming error, not an I/O condition to handle
        .expect("dense server shard init cannot fail")
    }

    /// Initialize shard tables on an explicit storage backend. Row init is
    /// derived from the *global* id, so embeddings are identical regardless
    /// of placement — single-node and distributed runs start from the same
    /// model. `label` names the shard's backing files (the cluster passes
    /// `server{s}`), so servers of one cluster can share a pinned mmap dir
    /// with deterministic filenames; concurrent *clusters* must pin
    /// distinct dirs.
    #[allow(clippy::too_many_arguments)]
    pub fn init_with_storage(
        label: &str,
        ent_ids: &[u64],
        rel_ids: &[u64],
        dim: usize,
        rel_dim: usize,
        lr: f32,
        init_scale: f32,
        seed: u64,
        storage: &StoreConfig,
    ) -> Result<ServerState> {
        let storage = storage.resolved()?;
        let ents = storage.zeros(&format!("{label}.entities"), ent_ids.len(), dim)?;
        let mut buf = vec![0f32; dim];
        for (slot, &id) in ent_ids.iter().enumerate() {
            let mut rng = crate::util::rng::Rng::seed_from_u64(seed ^ (id.wrapping_mul(2) + 1));
            for v in buf.iter_mut() {
                *v = rng.gen_uniform(-init_scale, init_scale);
            }
            ents.set_row(slot, &buf);
        }
        let rels = storage.zeros(&format!("{label}.relations"), rel_ids.len(), rel_dim)?;
        let mut buf = vec![0f32; rel_dim];
        for (slot, &id) in rel_ids.iter().enumerate() {
            let mut rng =
                crate::util::rng::Rng::seed_from_u64(seed ^ (id.wrapping_mul(2) + 0x10001));
            for v in buf.iter_mut() {
                *v = rng.gen_uniform(-init_scale, init_scale);
            }
            rels.set_row(slot, &buf);
        }
        Ok(ServerState {
            ent_opt: SparseAdagrad::with_storage(
                &storage,
                &format!("{label}.entities.opt"),
                ent_ids.len(),
                lr,
            )?,
            rel_opt: SparseAdagrad::with_storage(
                &storage,
                &format!("{label}.relations.opt"),
                rel_ids.len(),
                lr,
            )?,
            ents,
            rels,
            pulls: global().counter("kv.server.pulls"),
            pushes: global().counter("kv.server.pushes"),
        })
    }

    fn table(&self, t: TableId) -> &dyn EmbeddingStore {
        match t {
            TableId::Entities => self.ents.as_ref(),
            TableId::Relations => self.rels.as_ref(),
        }
    }

    /// Shared-memory pull: copy rows at `slots` into `out`.
    pub fn pull_local(&self, t: TableId, slots: &[u64], out: &mut [f32]) {
        self.pulls.inc();
        self.table(t).gather(slots, out);
    }

    /// Shared-memory push: apply AdaGrad to rows at `slots`.
    pub fn push_local(&self, t: TableId, slots: &[u64], rows: &[f32]) {
        self.pushes.inc();
        match t {
            TableId::Entities => self.ent_opt.apply(self.ents.as_ref(), slots, rows),
            TableId::Relations => self.rel_opt.apply(self.rels.as_ref(), slots, rows),
        }
    }
}

/// A running TCP server around a [`ServerState`].
pub struct KvServer {
    pub state: Arc<ServerState>,
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl KvServer {
    /// Bind an ephemeral loopback port and start serving.
    pub fn start(state: Arc<ServerState>) -> Result<KvServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_state = state.clone();
        let accept_stop = stop.clone();
        let accept_handle = std::thread::Builder::new()
            .name("dglke-kv-accept".into())
            .spawn(move || {
                // accept loop; connection threads detach and exit on STOP /
                // socket close
                for conn in listener.incoming() {
                    // Relaxed: the stop flag is a pure shutdown signal — no
                    // data is published through it; the self-connect poke in
                    // shutdown() guarantees one more accept() wakeup after
                    // the store (docs/CONCURRENCY.md, "Relaxed allowlist")
                    if accept_stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let st = accept_state.clone();
                            std::thread::Builder::new()
                                .name("dglke-kv-conn".into())
                                .spawn(move || {
                                    let _ = serve_connection(stream, &st);
                                })
                                // lint:allow(unwrap-ban) — thread-spawn
                                // failure (OOM-level) inside the detached
                                // accept loop has no channel back to the
                                // caller; a loud panic beats a server that
                                // silently stops accepting
                                .expect("spawn conn thread");
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(KvServer { state, addr, stop, accept_handle: Some(accept_handle) })
    }

    /// Stop accepting (open connections finish on their own STOP frames).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for KvServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_connection(mut stream: TcpStream, state: &ServerState) -> Result<()> {
    stream.set_nodelay(true)?;
    loop {
        let (op, payload) = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        match op {
            OP_PULL => {
                let (t, slots) = decode_pull(&payload)?;
                let dim = match t {
                    TableId::Entities => state.ents.dim(),
                    TableId::Relations => state.rels.dim(),
                };
                let mut rows = vec![0f32; slots.len() * dim];
                state.pull_local(t, &slots, &mut rows);
                let mut w = crate::util::bytes::Writer::with_capacity(rows.len() * 4 + 8);
                w.f32_slice(&rows);
                write_frame(&mut stream, OP_OK, &w.buf)?;
            }
            OP_PUSH => {
                let (t, slots, rows) = decode_push(&payload)?;
                state.push_local(t, &slots, &rows);
                write_frame(&mut stream, OP_OK, &[])?;
            }
            // tagged variants (pipelined client): echo the request tag in
            // the response so many frames can be in flight per connection
            OP_TPULL => {
                let (tag, inner) = split_tag(&payload)?;
                let (t, slots) = decode_pull(inner)?;
                let dim = match t {
                    TableId::Entities => state.ents.dim(),
                    TableId::Relations => state.rels.dim(),
                };
                let mut rows = vec![0f32; slots.len() * dim];
                state.pull_local(t, &slots, &mut rows);
                let mut w = crate::util::bytes::Writer::with_capacity(rows.len() * 4 + 12);
                w.u32(tag);
                w.f32_slice(&rows);
                write_frame(&mut stream, OP_TOK, &w.buf)?;
            }
            OP_TPUSH => {
                let (tag, inner) = split_tag(&payload)?;
                let (t, slots, rows) = decode_push(inner)?;
                state.push_local(t, &slots, &rows);
                write_frame(&mut stream, OP_TOK, &tag.to_le_bytes())?;
            }
            OP_PING => {
                write_frame(&mut stream, OP_OK, &payload)?;
            }
            OP_STOP => {
                write_frame(&mut stream, OP_OK, &[])?;
                return Ok(());
            }
            _ => {
                write_frame(&mut stream, OP_ERR, b"bad opcode")?;
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_server() -> KvServer {
        let state = ServerState::init(&[10, 20, 30], &[5], 4, 2, 0.5, 0.1, 42);
        KvServer::start(Arc::new(state)).unwrap()
    }

    #[test]
    fn init_is_placement_independent() {
        let a = ServerState::init(&[10, 20], &[], 4, 2, 0.5, 0.1, 42);
        let b = ServerState::init(&[20, 10], &[], 4, 2, 0.5, 0.1, 42);
        assert_eq!(a.ents.row_vec(0), b.ents.row_vec(1)); // id 10
        assert_eq!(a.ents.row_vec(1), b.ents.row_vec(0)); // id 20
    }

    #[test]
    fn tcp_pull_push_roundtrip() {
        let server = toy_server();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream.set_nodelay(true).unwrap();

        // pull slot 1 (entity id 20)
        write_frame(&mut stream, OP_PULL, &encode_pull(TableId::Entities, &[1])).unwrap();
        let (op, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(op, OP_OK);
        let rows = crate::util::bytes::Reader::new(&payload).f32_vec().unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows, server.state.ents.row_vec(1));

        // push a gradient and observe the row move
        let before = server.state.ents.row_vec(1);
        write_frame(
            &mut stream,
            OP_PUSH,
            &encode_push(TableId::Entities, &[1], &[1.0, 1.0, 1.0, 1.0]),
        )
        .unwrap();
        let (op, _) = read_frame(&mut stream).unwrap();
        assert_eq!(op, OP_OK);
        assert_ne!(server.state.ents.row_vec(1), before);

        write_frame(&mut stream, OP_STOP, &[]).unwrap();
        let (op, _) = read_frame(&mut stream).unwrap();
        assert_eq!(op, OP_OK);
    }

    #[test]
    fn concurrent_clients() {
        let server = toy_server();
        crate::util::threadpool::scoped_map(4, |_| {
            let mut stream = TcpStream::connect(server.addr).unwrap();
            for _ in 0..20 {
                write_frame(&mut stream, OP_PULL, &encode_pull(TableId::Entities, &[0, 2]))
                    .unwrap();
                let (op, payload) = read_frame(&mut stream).unwrap();
                assert_eq!(op, OP_OK);
                assert_eq!(payload.len(), 8 + 8 * 4);
            }
            write_frame(&mut stream, OP_STOP, &[]).unwrap();
            let _ = read_frame(&mut stream);
        });
        assert!(server.state.pulls.get() >= 80);
    }

    #[test]
    fn tagged_frames_pipeline_on_one_connection() {
        let server = toy_server();
        // the push (tag 8) follows the pulls on the wire, so every pull
        // must be answered with the pre-push table contents
        let expect: Vec<Vec<f32>> = (0..3).map(|i| server.state.ents.row_vec(i)).collect();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream.set_nodelay(true).unwrap();
        // write a burst of tagged requests before reading any response
        for tag in 0..8u32 {
            let inner = encode_pull(TableId::Entities, &[(tag % 3) as u64]);
            write_frame(&mut stream, OP_TPULL, &prepend_tag(tag, &inner)).unwrap();
        }
        let inner = encode_push(TableId::Entities, &[0], &[0.5, 0.5, 0.5, 0.5]);
        write_frame(&mut stream, OP_TPUSH, &prepend_tag(8, &inner)).unwrap();
        // responses come back in order, each echoing its tag
        for tag in 0..8u32 {
            let (op, payload) = read_frame(&mut stream).unwrap();
            assert_eq!(op, OP_TOK);
            let (rtag, rest) = split_tag(&payload).unwrap();
            assert_eq!(rtag, tag);
            let rows = crate::util::bytes::Reader::new(rest).f32_vec().unwrap();
            assert_eq!(rows, expect[(tag % 3) as usize]);
        }
        let (op, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(op, OP_TOK);
        assert_eq!(split_tag(&payload).unwrap().0, 8);
        // the acked push must have been applied
        assert_ne!(server.state.ents.row_vec(0), expect[0]);
        write_frame(&mut stream, OP_STOP, &[]).unwrap();
        let _ = read_frame(&mut stream);
    }

    #[test]
    fn ping_echoes() {
        let server = toy_server();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        write_frame(&mut stream, OP_PING, b"xyz").unwrap();
        let (op, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(op, OP_OK);
        assert_eq!(payload, b"xyz");
    }
}
