//! Bounded in-flight request window of one remote KVStore link.
//!
//! Extracted from `comm.rs` so the invariants can be model-checked
//! without a TCP socket in the loop (`rust/tests/loom_tests.rs`): the
//! window is the *entire* synchronization between a link's writer thread
//! (enqueues a pending entry per written frame, bounded at `capacity`)
//! and its reader thread (pops the front entry per response frame).
//!
//! Invariants (cataloged in docs/CONCURRENCY.md, verified under loom):
//!
//! * **FIFO matching** — entries pop in enqueue order, which is frame
//!   submission order; the reader can therefore match each response to
//!   the front entry and verify its echoed tag.
//! * **Drain sees every prior push** — a barrier entry enqueued after N
//!   pushes is popped only after those N entries, so acking it proves
//!   every prior frame was answered.
//! * **No deadlock at a full window** — `enqueue` blocks on `space`,
//!   which every pop signals; `fail` wakes both sides.
//! * **Failure delivery** — after `fail()`, every blocked or future
//!   `enqueue` returns its entry to the caller (who delivers the error
//!   to any waiting reply channel) and `pop` reports `Failed`; nothing
//!   blocks forever on a dead link.
//!
//! Lock order: the single internal mutex is the only lock held; callers
//! never hold it (entries are returned by value), so the window cannot
//! participate in a lock cycle.

use crate::util::sync::{Condvar, Mutex, MutexGuard};
use std::collections::VecDeque;

struct WindowState<T> {
    q: VecDeque<T>,
    /// producer hung up; consumers exit once the queue empties
    closed: bool,
    /// I/O failed; both sides bail out
    failed: bool,
}

/// Outcome of [`InflightWindow::pop`].
pub enum PopOutcome<T> {
    Entry(T),
    /// closed and fully drained
    Closed,
    /// the link failed; the failing side already drained the queue
    Failed,
}

/// Bounded FIFO window shared by a link's writer (pushes back) and reader
/// (pops front). See the module docs for the invariants.
pub struct InflightWindow<T> {
    state: Mutex<WindowState<T>>,
    nonempty: Condvar,
    space: Condvar,
    capacity: usize,
}

impl<T> InflightWindow<T> {
    /// A window admitting at most `capacity` (>= 1) in-flight entries.
    pub fn new(capacity: usize) -> Self {
        InflightWindow {
            state: Mutex::new(WindowState { q: VecDeque::new(), closed: false, failed: false }),
            nonempty: Condvar::new(),
            space: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Poison-tolerant lock: a panicking peer thread must not turn every
    /// subsequent window op into a panic of its own — the I/O loops
    /// degrade to the `failed` path instead (no `.unwrap()` in
    /// helper-thread code, enforced by `xtask lint`).
    fn lock_state(&self) -> MutexGuard<'_, WindowState<T>> {
        match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Append an entry, blocking while the window is full. Returns the
    /// entry back when the link has failed, so the caller can deliver the
    /// failure to whoever waits on it.
    pub fn enqueue(&self, entry: T) -> Result<(), T> {
        let mut st = self.lock_state();
        while st.q.len() >= self.capacity && !st.failed {
            st = match self.space.wait(st) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        if st.failed {
            return Err(entry);
        }
        st.q.push_back(entry);
        drop(st);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Pop the oldest entry, blocking while the window is empty and
    /// neither closed nor failed.
    pub fn pop(&self) -> PopOutcome<T> {
        let mut st = self.lock_state();
        loop {
            if st.failed {
                return PopOutcome::Failed;
            }
            if let Some(p) = st.q.pop_front() {
                drop(st);
                self.space.notify_one();
                return PopOutcome::Entry(p);
            }
            if st.closed {
                return PopOutcome::Closed;
            }
            st = match self.nonempty.wait(st) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    /// Mark the link failed, wake everything blocked on it, and hand the
    /// still-queued entries to the caller for failure delivery.
    pub fn fail(&self) -> Vec<T> {
        let mut st = self.lock_state();
        st.failed = true;
        let drained: Vec<T> = st.q.drain(..).collect();
        drop(st);
        self.nonempty.notify_all();
        self.space.notify_all();
        drained
    }

    /// Producer hang-up: consumers drain the remaining entries, then see
    /// [`PopOutcome::Closed`].
    pub fn close(&self) {
        let mut st = self.lock_state();
        st.closed = true;
        drop(st);
        self.nonempty.notify_all();
    }

    pub fn is_failed(&self) -> bool {
        self.lock_state().failed
    }

    pub fn len(&self) -> usize {
        self.lock_state().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_fifo_order() {
        let w = InflightWindow::new(8);
        for i in 0..5 {
            w.enqueue(i).map_err(|_| "failed").unwrap();
        }
        for i in 0..5 {
            match w.pop() {
                PopOutcome::Entry(v) => assert_eq!(v, i),
                _ => panic!("expected entry {i}"),
            }
        }
        w.close();
        assert!(matches!(w.pop(), PopOutcome::Closed));
    }

    #[test]
    fn full_window_blocks_until_pop() {
        let w = InflightWindow::new(2);
        w.enqueue(0u32).map_err(|_| "failed").unwrap();
        w.enqueue(1).map_err(|_| "failed").unwrap();
        std::thread::scope(|s| {
            s.spawn(|| {
                // blocks until the consumer below makes space
                w.enqueue(2).map_err(|_| "failed").unwrap();
                w.close();
            });
            let mut seen = Vec::new();
            loop {
                match w.pop() {
                    PopOutcome::Entry(v) => seen.push(v),
                    PopOutcome::Closed => break,
                    PopOutcome::Failed => panic!("window failed"),
                }
            }
            assert_eq!(seen, vec![0, 1, 2]);
        });
        assert!(w.is_empty());
        assert_eq!(w.capacity(), 2);
    }

    #[test]
    fn fail_drains_and_rejects() {
        let w = InflightWindow::new(4);
        w.enqueue("a").map_err(|_| "failed").unwrap();
        w.enqueue("b").map_err(|_| "failed").unwrap();
        let drained = w.fail();
        assert_eq!(drained, vec!["a", "b"]);
        assert!(w.is_failed());
        assert_eq!(w.enqueue("c"), Err("c"));
        assert!(matches!(w.pop(), PopOutcome::Failed));
    }

    #[test]
    fn fail_releases_blocked_producer() {
        let w = InflightWindow::new(1);
        w.enqueue(0u8).map_err(|_| "failed").unwrap();
        std::thread::scope(|s| {
            let h = s.spawn(|| w.enqueue(1)); // blocks: window full
            // give the producer a moment to block, then fail the link
            std::thread::sleep(std::time::Duration::from_millis(20));
            let drained = w.fail();
            assert_eq!(drained, vec![0]);
            assert_eq!(h.join().unwrap(), Err(1), "blocked enqueue must get its entry back");
        });
    }
}
