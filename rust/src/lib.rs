//! # dglke-rs
//!
//! Reproduction of **DGL-KE: Training Knowledge Graph Embeddings at Scale**
//! (Zheng et al., SIGIR 2020) as a three-layer Rust + JAX + Pallas system.
//!
//! ## Entry point: the [`api`] session
//!
//! Every mode of the system — many-core CPU, simulated multi-GPU, and
//! distributed over the KVStore cluster — is driven by one typed API:
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use dglke::api::Session;
//! use dglke::models::ModelKind;
//!
//! let mut session = Session::builder()
//!     .dataset("fb15k-syn")          // preset or TSV directory
//!     .model(ModelKind::RotatE)
//!     .workers(8)                    // or .distributed(4, 2, 2)
//!     .batches(250)
//!     .seed(42)
//!     .build()?;                     // validates; loads data; resolves shapes
//! let report = session.train()?;     // -> api::Report (JSON-serializable)
//! let metrics = session.evaluate()?; // -> eval::Metrics
//! session.export_embeddings(std::path::Path::new("ckpt"))?;
//! # Ok(())
//! # }
//! ```
//!
//! A [`api::RunSpec`] is the serializable form of the same thing: the CLI's
//! `dglke train --config run.json` and `--dump-config` round-trip through
//! it (schema in [`api::spec`]), so every benchmark and repro table is a
//! spec file away from being reproduced.
//!
//! ## Layers
//!
//! * Layer 3 (this crate): the paper's coordination contribution — graph &
//!   relation partitioning, joint/degree-based/local negative sampling,
//!   pluggable hogwild embedding storage ([`store::EmbeddingStore`]:
//!   dense / sharded / file-backed mmap for larger-than-RAM tables, with
//!   a budget-bounded hot-row cache [`store::CachedStore`]) +
//!   sparse Adagrad, async gradient updaters, distributed KVStore,
//!   multi-worker / many-core / distributed trainers, evaluation, and the
//!   PBG/GraphVite baselines.
//! * Layer 2 (`python/compile/model.py`): JAX fwd/bwd of the KGE models,
//!   AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! * Layer 1 (`python/compile/kernels/`): Pallas pairwise-score kernels —
//!   the paper's §3.3 "negative scoring as generalized matmul".
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for measured-vs-paper results.

pub mod api;
pub mod baselines;
pub mod benchkit;
pub mod cli;
pub mod dist;
pub mod eval;
pub mod kg;
pub mod kvstore;
pub mod obs;
pub mod partition;
pub mod repro;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod store;
pub mod train;
pub mod models;
pub mod util;
