//! # dglke-rs
//!
//! Reproduction of **DGL-KE: Training Knowledge Graph Embeddings at Scale**
//! (Zheng et al., SIGIR 2020) as a three-layer Rust + JAX + Pallas system.
//!
//! * Layer 3 (this crate): the paper's coordination contribution — graph &
//!   relation partitioning, joint/degree-based/local negative sampling,
//!   hogwild embedding store + sparse Adagrad, async gradient updaters,
//!   distributed KVStore, multi-worker / many-core / distributed trainers,
//!   evaluation, and the PBG/GraphVite baselines.
//! * Layer 2 (`python/compile/model.py`): JAX fwd/bwd of the KGE models,
//!   AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! * Layer 1 (`python/compile/kernels/`): Pallas pairwise-score kernels —
//!   the paper's §3.3 "negative scoring as generalized matmul".
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for measured-vs-paper results.

pub mod baselines;
pub mod benchkit;
pub mod cli;
pub mod dist;
pub mod eval;
pub mod kg;
pub mod kvstore;
pub mod partition;
pub mod repro;
pub mod runtime;
pub mod sampler;
pub mod store;
pub mod train;
pub mod models;
pub mod util;
