//! dglke — launcher CLI for the DGL-KE reproduction.
//!
//! Subcommands:
//!   train       single-machine training (many-core CPU or simulated
//!               multi-GPU), optional evaluation
//!   dist-train  distributed training over the in-process KVStore cluster
//!   partition   inspect METIS vs random partition quality
//!   gen-data    materialize a synthetic dataset as TSV
//!   eval-only   evaluate random-init embeddings (sanity floor)
//!   serve       answer top-k link-prediction queries from a checkpoint
//!               (versioned snapshot + threaded request loop)
//!   repro       regenerate the paper's accuracy tables (table4..table9)
//!   trace-check validate a Chrome-trace JSON written by --trace
//!
//! `train` and `dist-train` are thin flag→`RunSpec` translators over the
//! library's `api::Session`: `--config run.json` loads a spec file (any
//! explicit flags override it), `--dump-config` prints the effective spec
//! as JSON without running, and `--report out.json` writes the run's
//! `Report` JSON. Every flag has a default; unknown flags error out.

use anyhow::{anyhow, bail, Context, Result};
use dglke::api::{EvalProtocolSpec, EvalSpec, ParallelMode, RunSpec, Session};
use dglke::cli::Args;
use dglke::dist::PartitionStrategy;
use dglke::kg::Dataset;
use dglke::models::ModelKind;
use dglke::partition::{GraphPartition, MetisConfig};
use dglke::runtime::BackendKind;

const USAGE: &str = "usage: dglke <train|dist-train|partition|gen-data|eval-only|serve|export|repro|trace-check> [--flags]
  common: --dataset fb15k-syn|wn18-syn|freebase-syn[:scale]|tiny|<tsv-dir>
          --model transe_l1|transe_l2|distmult|complex|rescal|rotate|transr
          --backend native|xla (default native) --tag default|tiny --seed N
          --kernels scalar|fused (native score/grad kernels; bit-identical)
          --config spec.json (flags override) --dump-config --report out.json
          --storage dense|sharded|mmap --shards N --storage-dir DIR
          --budget-mb F (tables over the budget must use mmap)
          --cache-mb F (mmap hot-row cache size; default budget-mb)
          --trace (record spans; write Chrome trace JSON after the run)
          --trace-path FILE (implies --trace; default trace.json)
          --metrics-out FILE (write the obs::metrics snapshot as JSON;
          implies attaching it to the run report)
  train:  --workers N --batches N(per worker) --lr F --gpu (simulate GPUs)
          --margin F --adv-temp F --degree-frac F --no-async --no-rel-part
          --prefetch (overlap next-batch sample+gather with compute)
          --prefetch-depth N (buffers in flight, >= 2)
          --sync-interval N --log-every N --eval --sampled-eval
          --export DIR (write a versioned checkpoint after training)
  dist-train: --machines N --trainers N --servers N --random-partition
          --no-local-negatives --batches N --eval
          --pipelined-comm (async KVStore client: concurrent pull fan-out,
          pipelined frames, fire-and-forget pushes + drain barrier)
          --inflight N (frames in flight per connection, default 8)
          --prefetch / --prefetch-depth N (pull batch N+1 during compute)
  partition: --machines N
  gen-data: --out DIR
  eval-only: --dim N
  serve:  --checkpoint DIR (required; written by train --export DIR)
          --threads N --batch N --topk K (overlay spec.serve)
          --kernels scalar|fused --cache-mb F (snapshot hot-row cache)
          --queries N (seeded demo queries to answer, default 256)
          --report out.json (latency/QPS summary)
  export: --checkpoint DIR (required) --tsv (entities.tsv/relations.tsv,
          lossless: f32 Display round-trips the stored bits)
          --out DIR (default: the checkpoint dir)
  repro:  --exp table4..table9|all --scale F --out DIR
  trace-check: dglke trace-check FILE (schema + span-nesting validation)";

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&raw)?;
    let cmd = args.positional().first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "train" => cmd_run(args, false),
        "dist-train" => cmd_run(args, true),
        "partition" => cmd_partition(args),
        "gen-data" => cmd_gen_data(args),
        "eval-only" => cmd_eval_only(args),
        "serve" => cmd_serve(args),
        "export" => cmd_export(args),
        "repro" => cmd_repro(args),
        "trace-check" => cmd_trace_check(args),
        _ => {
            if args.flag("help") || cmd.is_empty() {
                println!("{USAGE}");
                Ok(())
            } else {
                bail!("unknown command {cmd:?}\n{USAGE}")
            }
        }
    }
}

/// Load `--config` (if given) and overlay any explicitly-passed flags onto
/// the spec. Shared by `train` and `dist-train`.
fn spec_from_flags(args: &mut Args, dist: bool) -> Result<RunSpec> {
    let mut spec = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading spec file {path}"))?;
            RunSpec::from_json_str(&text).with_context(|| format!("parsing spec file {path}"))?
        }
        None => RunSpec::default(),
    };

    if dist && !matches!(spec.mode, ParallelMode::Distributed { .. }) {
        spec.mode = ParallelMode::Distributed {
            machines: 4,
            trainers: 2,
            servers: 2,
            partition: PartitionStrategy::Metis,
            local_negatives: true,
        };
        // only replace values still at their RunSpec defaults — a --config
        // file's explicit dataset/batches must survive the mode install
        let defaults = RunSpec::default();
        if spec.dataset == defaults.dataset {
            spec.dataset = "freebase-syn:0.02".into();
        }
        if spec.batches == defaults.batches {
            spec.batches = 100;
        }
    }

    if let Some(v) = args.get("dataset") {
        spec.dataset = v;
    }
    if let Some(v) = args.get("model") {
        spec.model = ModelKind::parse(&v).with_context(|| format!("unknown model {v}"))?;
    }
    if let Some(v) = args.get("backend") {
        spec.backend = BackendKind::parse(&v).with_context(|| format!("unknown backend {v}"))?;
    }
    if let Some(v) = args.get("tag") {
        spec.artifact_tag = v;
    }
    if let Some(v) = args.get("kernels") {
        spec.kernels = dglke::models::KernelBackend::parse(&v)
            .with_context(|| format!("unknown kernels backend {v}"))?;
    }
    spec.seed = args.parse_or("seed", spec.seed)?;
    spec.batches = args.parse_or("batches", spec.batches)?;
    spec.lr = args.parse_or("lr", spec.lr)?;
    if let Some(v) = args.get("margin") {
        spec.loss.margin = Some(v.parse().with_context(|| format!("bad --margin {v}"))?);
    }
    if let Some(v) = args.get("adv-temp") {
        spec.loss.adv_temp = Some(v.parse().with_context(|| format!("bad --adv-temp {v}"))?);
    }
    spec.neg_degree_frac = args.parse_or("degree-frac", spec.neg_degree_frac)?;
    if args.flag("no-async") {
        spec.async_update = false;
    }
    if args.flag("prefetch") {
        spec.pipeline.prefetch = true;
    }
    spec.pipeline.depth = args.parse_or("prefetch-depth", spec.pipeline.depth)?;
    if args.flag("pipelined-comm") {
        spec.comm.pipelined = true;
    }
    spec.comm.inflight = args.parse_or("inflight", spec.comm.inflight)?;
    if args.flag("no-rel-part") {
        spec.relation_partition = false;
    }
    spec.sync_interval = args.parse_or("sync-interval", spec.sync_interval)?;
    spec.log_every = args.parse_or("log-every", spec.log_every)?;
    if let Some(v) = args.get("storage") {
        spec.storage.backend = dglke::store::StoreBackendKind::parse(&v)
            .with_context(|| format!("unknown storage backend {v}"))?;
    }
    spec.storage.shards = args.parse_or("shards", spec.storage.shards)?;
    if let Some(v) = args.get("storage-dir") {
        spec.storage.dir = Some(v);
    }
    if let Some(v) = args.get("budget-mb") {
        spec.storage.budget_mb =
            Some(v.parse().with_context(|| format!("bad --budget-mb {v}"))?);
    }
    if let Some(v) = args.get("cache-mb") {
        spec.storage.cache_mb = Some(v.parse().with_context(|| format!("bad --cache-mb {v}"))?);
    }
    if args.flag("trace") {
        spec.obs.trace = true;
    }
    if let Some(v) = args.get("trace-path") {
        spec.obs.trace = true;
        spec.obs.trace_path = Some(v);
    }

    if dist {
        let (mut machines, mut trainers, mut servers, mut partition, mut local_negatives) =
            match spec.mode {
                ParallelMode::Distributed { machines, trainers, servers, partition, local_negatives } => {
                    (machines, trainers, servers, partition, local_negatives)
                }
                _ => unreachable!("dist mode installed above"),
            };
        machines = args.parse_or("machines", machines)?;
        trainers = args.parse_or("trainers", trainers)?;
        servers = args.parse_or("servers", servers)?;
        if args.flag("random-partition") {
            partition = PartitionStrategy::Random;
        }
        if args.flag("no-local-negatives") {
            local_negatives = false;
        }
        spec.mode =
            ParallelMode::Distributed { machines, trainers, servers, partition, local_negatives };
    } else if let ParallelMode::Single { workers, gpu } = spec.mode {
        let workers = args.parse_or("workers", workers)?;
        let gpu = gpu || args.flag("gpu");
        spec.mode = ParallelMode::Single { workers, gpu };
    } else if args.get("workers").is_some() || args.flag("gpu") {
        // `train --config dist.json` runs the distributed spec as-is;
        // silently ignoring explicit single-mode flags would be a trap
        bail!("--workers/--gpu have no effect with a distributed --config; use dist-train flags");
    }

    if args.flag("eval") || args.flag("sampled-eval") {
        let protocol = if args.flag("sampled-eval") {
            EvalProtocolSpec::Sampled { uniform: 1000, degree: 1000 }
        } else {
            EvalProtocolSpec::FullFiltered
        };
        spec.eval = Some(EvalSpec { protocol, max_triplets: 500, n_threads: 4 });
    }
    Ok(spec)
}

/// `train` and `dist-train`: flag→spec translation + `Session` run.
fn cmd_run(mut args: Args, dist: bool) -> Result<()> {
    let mut spec = spec_from_flags(&mut args, dist)?;
    let dump = args.flag("dump-config");
    let report_path = args.get("report");
    let export_dir = args.get("export");
    let metrics_out = args.get("metrics-out");
    if metrics_out.is_some() {
        spec.obs.metrics = true;
    }
    args.finish()?;

    if dump {
        println!("{}", spec.to_json_string());
        return Ok(());
    }

    let mut session = Session::from_spec(spec)?;
    println!("{}", session.dataset().summary());
    match session.spec().mode {
        ParallelMode::Single { workers, .. } => println!(
            "training {} ({} params) on {} workers, backend {:?}",
            session.spec().model.name(),
            session.n_params(),
            workers,
            session.spec().backend
        ),
        ParallelMode::Distributed { machines, trainers, partition, .. } => println!(
            "distributed training on {machines} machines x {trainers} trainers ({} partition)",
            partition.name()
        ),
    }
    let report = session.train()?;
    println!("{}", report.summary());
    if let Some(path) = report_path {
        std::fs::write(&path, report.to_json_string())
            .with_context(|| format!("writing report {path}"))?;
        println!("[wrote {path}]");
    }
    if let Some(path) = metrics_out {
        let snap = report
            .obs_metrics
            .clone()
            .unwrap_or_else(|| dglke::obs::metrics::global().snapshot());
        std::fs::write(&path, snap.to_json().to_string())
            .with_context(|| format!("writing metrics snapshot {path}"))?;
        println!("[wrote {path}]");
    }
    if let Some(dir) = export_dir {
        session.export_embeddings(std::path::Path::new(&dir))?;
        println!("[exported checkpoint to {dir} — serve it with: dglke serve --checkpoint {dir}]");
    }
    Ok(())
}

fn cmd_partition(mut args: Args) -> Result<()> {
    let dataset_name = args.get_or("dataset", "fb15k-syn");
    let seed = args.parse_or("seed", 0u64)?;
    let machines = args.parse_or("machines", 4usize)?;
    args.finish()?;
    let dataset = Dataset::load(&dataset_name, seed)?;
    println!("{}", dataset.summary());
    let t = std::time::Instant::now();
    let metis = GraphPartition::metis(&dataset.train, machines, &MetisConfig::default());
    let metis_time = t.elapsed();
    let random = GraphPartition::random(&dataset.train, machines, seed);
    println!(
        "METIS : locality {:.3} (computed in {:.2}s), entity sizes {:?}",
        metis.locality(&dataset.train),
        metis_time.as_secs_f64(),
        metis.entity_sizes()
    );
    println!(
        "random: locality {:.3}, entity sizes {:?}",
        random.locality(&dataset.train),
        random.entity_sizes()
    );
    Ok(())
}

fn cmd_gen_data(mut args: Args) -> Result<()> {
    let dataset_name = args.get_or("dataset", "fb15k-syn");
    let seed = args.parse_or("seed", 0u64)?;
    let out = args.get_or("out", "data/generated");
    args.finish()?;
    let dataset = Dataset::load(&dataset_name, seed)?;
    dataset.save_tsv_dir(std::path::Path::new(&out))?;
    println!("{} -> {out}", dataset.summary());
    Ok(())
}

fn cmd_eval_only(mut args: Args) -> Result<()> {
    let mut spec = RunSpec {
        dataset: "tiny".into(),
        backend: BackendKind::Native,
        eval: Some(EvalSpec::default()),
        ..Default::default()
    };
    if let Some(v) = args.get("dataset") {
        spec.dataset = v;
    }
    if let Some(v) = args.get("model") {
        spec.model = ModelKind::parse(&v).with_context(|| format!("unknown model {v}"))?;
    }
    spec.seed = args.parse_or("seed", spec.seed)?;
    let dim = args.parse_or("dim", 64usize)?;
    spec.shape = Some(dglke::models::step::StepShape {
        dim,
        ..dglke::api::DEFAULT_NATIVE_SHAPE
    });
    args.finish()?;

    let session = Session::from_spec(spec)?;
    println!(
        "random-embedding floor for {} on {}:",
        session.spec().model.name(),
        session.dataset().name
    );
    let m = session.evaluate()?;
    println!("eval ({} ranks, both sides): {}", m.n, m.row());
    Ok(())
}

/// `serve`: open a checkpoint as a read-only snapshot, spin up the
/// threaded request loop, and answer a seeded batch of demo queries,
/// reporting latency and throughput. The serving building blocks
/// (`serve::Snapshot`, `serve::ServeHandle`) are library API; this
/// command is their operational smoke test.
fn cmd_serve(mut args: Args) -> Result<()> {
    use dglke::serve::{Query, ServeConfig, ServeHandle, Snapshot, SnapshotOptions};

    let ckpt = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("serve requires --checkpoint DIR\n{USAGE}"))?;
    let mut spec = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading spec file {path}"))?;
            RunSpec::from_json_str(&text).with_context(|| format!("parsing spec file {path}"))?
        }
        None => RunSpec::default(),
    };
    spec.serve.threads = args.parse_or("threads", spec.serve.threads)?;
    spec.serve.batch = args.parse_or("batch", spec.serve.batch)?;
    spec.serve.topk = args.parse_or("topk", spec.serve.topk)?;
    if let Some(v) = args.get("kernels") {
        spec.kernels = dglke::models::KernelBackend::parse(&v)
            .with_context(|| format!("unknown kernels backend {v}"))?;
    }
    let cache_mb = match args.get("cache-mb") {
        Some(v) => Some(v.parse().with_context(|| format!("bad --cache-mb {v}"))?),
        None => spec.storage.cache_mb,
    };
    let n_queries = args.parse_or("queries", 256usize)?;
    let report_path = args.get("report");
    args.finish()?;
    spec.validate()?;

    let opts = SnapshotOptions { cache_mb, kernels: spec.kernels };
    let t_open = std::time::Instant::now();
    let snapshot = Snapshot::open_with(std::path::Path::new(&ckpt), &opts)?;
    let open_ms = t_open.elapsed().as_secs_f64() * 1e3;
    let (n_e, n_r) = (snapshot.n_entities() as u64, snapshot.n_relations() as u64);
    println!(
        "serving {} checkpoint {} ({} entities x dim {}, {} relations; opened in {:.1} ms)",
        snapshot.manifest().model.name(),
        ckpt,
        n_e,
        snapshot.dim(),
        n_r,
        open_ms
    );
    let cfg = ServeConfig {
        threads: spec.serve.threads,
        batch: spec.serve.batch,
        topk: spec.serve.topk,
    };
    let handle = ServeHandle::start(snapshot, &cfg);

    // seeded demo traffic: splitmix-style id stream, alternating sides
    let mut state = spec.seed.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut next = move || {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        state >> 11
    };
    let queries: Vec<Query> = (0..n_queries)
        .map(|i| {
            let (e, r) = (next() % n_e.max(1), next() % n_r.max(1));
            if i % 2 == 0 {
                Query::tail(e, r)
            } else {
                Query::head(e, r)
            }
        })
        .collect();

    let mut lat_ms: Vec<f64> = Vec::new();
    let t0 = std::time::Instant::now();
    for chunk in queries.chunks(cfg.batch.max(1)) {
        let t = std::time::Instant::now();
        let answers = handle.submit(chunk, cfg.topk)?;
        anyhow::ensure!(answers.len() == chunk.len(), "short reply from serve pool");
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let total_s = t0.elapsed().as_secs_f64();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let pct = |p: f64| -> f64 {
        if lat_ms.is_empty() {
            return 0.0;
        }
        let idx = ((lat_ms.len() as f64 - 1.0) * p).round() as usize;
        lat_ms[idx.min(lat_ms.len() - 1)]
    };
    let qps = if total_s > 0.0 { n_queries as f64 / total_s } else { 0.0 };
    println!(
        "answered {} queries (top-{}) on {} threads in {:.3}s: {:.0} QPS, \
         batch latency p50 {:.2} ms / p95 {:.2} ms / p99 {:.2} ms",
        handle.served(),
        cfg.topk,
        cfg.threads,
        total_s,
        qps,
        pct(0.50),
        pct(0.95),
        pct(0.99)
    );
    // the handle's obs::metrics histograms: per-job queue/score and
    // whole-submit latency (log-2 buckets, so ~2x resolution)
    let lats = handle.latencies();
    let us = |ns: f64| ns / 1e3;
    println!(
        "histograms (us): queue p50 {:.0} p99 {:.0} | score p50 {:.0} p99 {:.0} | \
         query p50 {:.0} p99 {:.0}",
        us(lats.queue_ns.percentile(0.50)),
        us(lats.queue_ns.percentile(0.99)),
        us(lats.score_ns.percentile(0.50)),
        us(lats.score_ns.percentile(0.99)),
        us(lats.query_ns.percentile(0.50)),
        us(lats.query_ns.percentile(0.99))
    );
    if let Some(path) = report_path {
        let mut m = std::collections::BTreeMap::new();
        let num = |v: f64| dglke::util::json::Json::Num(v);
        m.insert("queries".to_string(), num(n_queries as f64));
        m.insert("topk".to_string(), num(cfg.topk as f64));
        m.insert("threads".to_string(), num(cfg.threads as f64));
        m.insert("open_ms".to_string(), num(open_ms));
        m.insert("qps".to_string(), num(qps));
        m.insert("batch_p50_ms".to_string(), num(pct(0.50)));
        m.insert("batch_p95_ms".to_string(), num(pct(0.95)));
        m.insert("batch_p99_ms".to_string(), num(pct(0.99)));
        for (name, h) in [
            ("queue", &lats.queue_ns),
            ("score", &lats.score_ns),
            ("batch", &lats.batch_ns),
            ("query", &lats.query_ns),
        ] {
            m.insert(format!("{name}_p50_ns"), num(h.percentile(0.50)));
            m.insert(format!("{name}_p95_ns"), num(h.percentile(0.95)));
            m.insert(format!("{name}_p99_ns"), num(h.percentile(0.99)));
        }
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
        m.insert("host_cores".to_string(), num(cores as f64));
        m.insert(
            "host_arch".to_string(),
            dglke::util::json::Json::Str(std::env::consts::ARCH.to_string()),
        );
        std::fs::write(&path, dglke::util::json::Json::Obj(m).to_string())
            .with_context(|| format!("writing report {path}"))?;
        println!("[wrote {path}]");
    }
    handle.shutdown();
    Ok(())
}

/// `dglke export --checkpoint DIR --tsv [--out DIR]`: convert a
/// format-2 checkpoint to TSV. `serve::export_tsv` is the library API;
/// this command is its operational wrapper.
fn cmd_export(mut args: Args) -> Result<()> {
    use dglke::serve::{export_tsv, Snapshot};

    let ckpt = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("export requires --checkpoint DIR\n{USAGE}"))?;
    let tsv = args.flag("tsv");
    let out = args.get("out").unwrap_or_else(|| ckpt.clone());
    args.finish()?;
    if !tsv {
        bail!("export: no format selected; pass --tsv\n{USAGE}");
    }
    let snapshot = Snapshot::open(std::path::Path::new(&ckpt))?;
    println!(
        "exporting {} checkpoint {} ({} entities x dim {}, {} relations)",
        snapshot.manifest().model.name(),
        ckpt,
        snapshot.n_entities(),
        snapshot.dim(),
        snapshot.n_relations()
    );
    let (e_path, r_path) = export_tsv(&snapshot, std::path::Path::new(&out))?;
    println!("[wrote {} and {}]", e_path.display(), r_path.display());
    Ok(())
}

/// `dglke trace-check FILE`: schema + per-thread span-nesting validation
/// of a Chrome-trace JSON written by `--trace` (library API:
/// `obs::trace::validate_chrome_trace`). Exits non-zero on an invalid
/// trace, so `make trace` can gate on it.
fn cmd_trace_check(mut args: Args) -> Result<()> {
    let file = args
        .positional()
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow!("trace-check requires a trace FILE\n{USAGE}"))?;
    args.finish()?;
    let text =
        std::fs::read_to_string(&file).with_context(|| format!("reading trace {file}"))?;
    let check = dglke::obs::trace::validate_chrome_trace(&text)
        .map_err(|e| anyhow!("{file}: invalid trace: {e}"))?;
    println!(
        "{file}: valid Chrome trace — {} events, {} threads, {} complete spans",
        check.events,
        check.threads,
        check.intervals.len()
    );
    Ok(())
}

fn cmd_repro(mut args: Args) -> Result<()> {
    let exp = args.get_or("exp", "all");
    let backend_name = args.get_or("backend", "xla");
    let opts = dglke::repro::ReproOpts {
        scale: args.parse_or("scale", 1.0f64)?,
        backend: BackendKind::parse(&backend_name)
            .with_context(|| format!("unknown backend {backend_name}"))?,
        out_dir: args.get_or("out", "results").into(),
        seed: args.parse_or("seed", 0u64)?,
    };
    args.finish()?;
    dglke::repro::run(&exp, &opts)
}
