//! dglke — launcher CLI for the DGL-KE reproduction.
//!
//! Subcommands:
//!   train       single-machine training (many-core CPU or simulated
//!               multi-GPU), optional evaluation
//!   dist-train  distributed training over the in-process KVStore cluster
//!   partition   inspect METIS vs random partition quality
//!   gen-data    materialize a synthetic dataset as TSV
//!   eval-only   evaluate random-init embeddings (sanity floor)
//!   repro       regenerate the paper's accuracy tables (table4..table9)
//!
//! Every flag has a default; unknown flags error out.

use anyhow::{bail, Context, Result};
use dglke::cli::Args;
use dglke::dist::{run_distributed, DistConfig, PartitionStrategy};
use dglke::eval::{evaluate, EvalConfig, EvalProtocol};
use dglke::kg::Dataset;
use dglke::models::{LossCfg, LossKind, ModelKind};
use dglke::partition::{GraphPartition, MetisConfig};
use dglke::runtime::{artifacts, BackendKind, Manifest};
use dglke::train::worker::ModelState;
use dglke::train::{run_training, Hardware, TrainConfig};

const USAGE: &str = "usage: dglke <train|dist-train|partition|gen-data|eval-only|repro> [--flags]
  common: --dataset fb15k-syn|wn18-syn|freebase-syn[:scale]|tiny|<tsv-dir>
          --model transe_l1|transe_l2|distmult|complex|rescal|rotate|transr
          --backend xla|native --tag default|tiny --seed N
  train:  --workers N --batches N(per worker) --lr F --gpu (simulate GPUs)
          --degree-frac F --no-async --no-rel-part --sync-interval N --eval
  dist-train: --machines N --trainers N --servers N --random-partition
          --no-local-negatives --batches N --eval
  partition: --machines N
  gen-data: --out DIR
  repro:  --exp table4..table9|all --scale F --out DIR";

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&raw)?;
    let cmd = args.positional().first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "train" => cmd_train(args),
        "dist-train" => cmd_dist(args),
        "partition" => cmd_partition(args),
        "gen-data" => cmd_gen_data(args),
        "eval-only" => cmd_eval_only(args),
        "repro" => cmd_repro(args),
        _ => {
            if args.flag("help") || cmd.is_empty() {
                println!("{USAGE}");
                Ok(())
            } else {
                bail!("unknown command {cmd:?}\n{USAGE}")
            }
        }
    }
}

fn parse_model(args: &mut Args) -> Result<ModelKind> {
    let name = args.get_or("model", "transe_l2");
    ModelKind::parse(&name).with_context(|| format!("unknown model {name}"))
}

fn parse_backend(args: &mut Args) -> Result<BackendKind> {
    let name = args.get_or("backend", "xla");
    BackendKind::parse(&name).with_context(|| format!("unknown backend {name}"))
}

fn load_manifest() -> Result<Option<Manifest>> {
    if artifacts::available() {
        Ok(Some(Manifest::load(&artifacts::default_dir())?))
    } else {
        Ok(None)
    }
}

fn resolve_shape(
    manifest: Option<&Manifest>,
    backend: BackendKind,
    model: ModelKind,
    tag: &str,
) -> Result<(Option<dglke::models::step::StepShape>, usize)> {
    // returns (explicit shape for native, dim)
    match manifest.and_then(|m| m.find_train(model.name(), "logistic", tag).ok()) {
        Some(a) => {
            let s = dglke::models::step::StepShape {
                batch: a.batch,
                chunks: a.chunks,
                neg_k: a.neg_k,
                dim: a.dim,
            };
            Ok(((backend == BackendKind::Native).then_some(s), a.dim))
        }
        None if backend == BackendKind::Native => {
            let s = dglke::models::step::StepShape { batch: 256, chunks: 8, neg_k: 64, dim: 64 };
            Ok((Some(s), 64))
        }
        None => bail!("no artifacts for model {} tag {tag} — run `make artifacts`", model.name()),
    }
}

fn run_eval(model: ModelKind, state: &ModelState, dataset: &Dataset, sampled: bool, seed: u64) {
    let cfg = EvalConfig {
        protocol: if sampled {
            EvalProtocol::Sampled { uniform: 1000, degree: 1000 }
        } else {
            EvalProtocol::FullFiltered
        },
        max_triplets: 500,
        n_threads: 4,
        seed,
    };
    let m = evaluate(model, &state.entities, &state.relations, dataset, &dataset.test, &cfg);
    println!("eval ({} test triplets, both sides): {}", m.n / 2, m.row());
}

fn cmd_train(mut args: Args) -> Result<()> {
    let dataset_name = args.get_or("dataset", "fb15k-syn");
    let seed = args.parse_or("seed", 0u64)?;
    let model = parse_model(&mut args)?;
    let backend = parse_backend(&mut args)?;
    let tag = args.get_or("tag", "default");
    let workers = args.parse_or("workers", 1usize)?;
    let batches = args.parse_or("batches", 200usize)?;
    let lr = args.parse_or("lr", 0.3f32)?;
    let margin: Option<f32> = args.get("margin").map(|v| v.parse()).transpose()?;
    let adv_temp: Option<f32> = args.get("adv-temp").map(|v| v.parse()).transpose()?;
    let gpu = args.flag("gpu");
    let degree_frac = args.parse_or("degree-frac", 0.0f64)?;
    let no_async = args.flag("no-async");
    let no_rel_part = args.flag("no-rel-part");
    let sync_interval = args.parse_or("sync-interval", 500usize)?;
    let do_eval = args.flag("eval");
    let sampled_eval = args.flag("sampled-eval");
    args.finish()?;

    let dataset = Dataset::load(&dataset_name, seed)?;
    println!("{}", dataset.summary());
    let manifest = load_manifest()?;
    let (shape, dim) = resolve_shape(manifest.as_ref(), backend, model, &tag)?;
    let cfg = TrainConfig {
        model,
        loss: LossCfg {
            kind: margin.map(LossKind::Margin).unwrap_or(LossKind::Logistic),
            adv_temp,
        },
        backend,
        artifact_tag: tag,
        shape,
        n_workers: workers,
        batches_per_worker: batches,
        lr,
        neg_degree_frac: degree_frac,
        async_update: !no_async,
        relation_partition: !no_rel_part,
        sync_interval,
        hardware: if gpu { Hardware::Gpu { pcie_gbps: 12.0 } } else { Hardware::Cpu },
        seed,
        ..Default::default()
    };
    let state = ModelState::init(&dataset, model, dim, &cfg);
    println!(
        "training {} ({} params) on {} workers, backend {:?}",
        model.name(),
        state.n_params(),
        workers,
        backend
    );
    let stats = run_training(&dataset, &state, manifest.as_ref(), &cfg)?;
    println!(
        "done: {} batches, wall {:.1}s, sim-parallel {:.1}s, {:.0} triplets/s, final loss {:.4}",
        stats.total_batches,
        stats.wall_secs,
        stats.sim_parallel_secs,
        stats.triplets_per_sec,
        stats.mean_loss_tail
    );
    for (p, s) in &stats.phases {
        println!("  phase {p}: {s:.2}s");
    }
    if gpu {
        println!(
            "  transfers: h2d {:.1}MB d2h {:.1}MB overlapped {:.1}MB",
            stats.h2d_bytes as f64 / 1e6,
            stats.d2h_bytes as f64 / 1e6,
            stats.overlapped_bytes as f64 / 1e6
        );
    }
    if do_eval {
        run_eval(model, &state, &dataset, sampled_eval, seed);
    }
    Ok(())
}

fn cmd_dist(mut args: Args) -> Result<()> {
    let dataset_name = args.get_or("dataset", "freebase-syn:0.02");
    let seed = args.parse_or("seed", 0u64)?;
    let model = parse_model(&mut args)?;
    let backend = parse_backend(&mut args)?;
    let tag = args.get_or("tag", "default");
    let machines = args.parse_or("machines", 4usize)?;
    let trainers = args.parse_or("trainers", 2usize)?;
    let servers = args.parse_or("servers", 2usize)?;
    let batches = args.parse_or("batches", 100usize)?;
    let lr = args.parse_or("lr", 0.3f32)?;
    let random_part = args.flag("random-partition");
    let no_local_neg = args.flag("no-local-negatives");
    let do_eval = args.flag("eval");
    args.finish()?;

    let dataset = Dataset::load(&dataset_name, seed)?;
    println!("{}", dataset.summary());
    let manifest = load_manifest()?;
    let (shape, dim) = resolve_shape(manifest.as_ref(), backend, model, &tag)?;
    let cfg = DistConfig {
        model,
        backend,
        artifact_tag: tag,
        shape,
        machines,
        trainers_per_machine: trainers,
        servers_per_machine: servers,
        partition: if random_part { PartitionStrategy::Random } else { PartitionStrategy::Metis },
        local_negatives: !no_local_neg,
        batches_per_trainer: batches,
        lr,
        seed,
        ..Default::default()
    };
    println!(
        "distributed training on {machines} machines x {trainers} trainers ({} partition)",
        if random_part { "random" } else { "METIS" }
    );
    let (stats, mut cluster) = run_distributed(&dataset, manifest.as_ref(), &cfg)?;
    println!(
        "done: {} batches, wall {:.1}s, {:.0} triplets/s",
        stats.total_batches, stats.wall_secs, stats.triplets_per_sec
    );
    println!(
        "  locality {:.3}; traffic local {:.1}MB remote {:.1}MB ({} remote reqs)",
        stats.locality,
        stats.local_bytes as f64 / 1e6,
        stats.remote_bytes as f64 / 1e6,
        stats.remote_requests
    );
    if do_eval {
        let rel_dim = model.rel_dim(dim);
        let ents = cluster.dump_entities(dataset.n_entities(), dim);
        let rels = cluster.dump_relations(dataset.n_relations(), rel_dim);
        let state = ModelState {
            entities: std::sync::Arc::new(ents),
            relations: std::sync::Arc::new(rels),
            ent_opt: std::sync::Arc::new(dglke::store::SparseAdagrad::new(1, lr)),
            rel_opt: std::sync::Arc::new(dglke::store::SparseAdagrad::new(1, lr)),
            dim,
            rel_dim,
        };
        run_eval(model, &state, &dataset, true, seed);
    }
    cluster.shutdown();
    Ok(())
}

fn cmd_partition(mut args: Args) -> Result<()> {
    let dataset_name = args.get_or("dataset", "fb15k-syn");
    let seed = args.parse_or("seed", 0u64)?;
    let machines = args.parse_or("machines", 4usize)?;
    args.finish()?;
    let dataset = Dataset::load(&dataset_name, seed)?;
    println!("{}", dataset.summary());
    let t = std::time::Instant::now();
    let metis = GraphPartition::metis(&dataset.train, machines, &MetisConfig::default());
    let metis_time = t.elapsed();
    let random = GraphPartition::random(&dataset.train, machines, seed);
    println!(
        "METIS : locality {:.3} (computed in {:.2}s), entity sizes {:?}",
        metis.locality(&dataset.train),
        metis_time.as_secs_f64(),
        metis.entity_sizes()
    );
    println!(
        "random: locality {:.3}, entity sizes {:?}",
        random.locality(&dataset.train),
        random.entity_sizes()
    );
    Ok(())
}

fn cmd_gen_data(mut args: Args) -> Result<()> {
    let dataset_name = args.get_or("dataset", "fb15k-syn");
    let seed = args.parse_or("seed", 0u64)?;
    let out = args.get_or("out", "data/generated");
    args.finish()?;
    let dataset = Dataset::load(&dataset_name, seed)?;
    dataset.save_tsv_dir(std::path::Path::new(&out))?;
    println!("{} -> {out}", dataset.summary());
    Ok(())
}

fn cmd_eval_only(mut args: Args) -> Result<()> {
    let dataset_name = args.get_or("dataset", "tiny");
    let seed = args.parse_or("seed", 0u64)?;
    let model = parse_model(&mut args)?;
    let dim = args.parse_or("dim", 64usize)?;
    args.finish()?;
    let dataset = Dataset::load(&dataset_name, seed)?;
    let cfg = TrainConfig { seed, ..Default::default() };
    let state = ModelState::init(&dataset, model, dim, &cfg);
    println!("random-embedding floor for {} on {}:", model.name(), dataset.name);
    run_eval(model, &state, &dataset, false, seed);
    Ok(())
}

fn cmd_repro(mut args: Args) -> Result<()> {
    let exp = args.get_or("exp", "all");
    let opts = dglke::repro::ReproOpts {
        scale: args.parse_or("scale", 1.0f64)?,
        backend: parse_backend(&mut args)?,
        out_dir: args.get_or("out", "results").into(),
        seed: args.parse_or("seed", 0u64)?,
    };
    args.finish()?;
    dglke::repro::run(&exp, &opts)
}
