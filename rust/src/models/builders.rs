//! Per-model "o-builders" (forward + backward) and negative projection.
//!
//! Tail-corruption form: `o = g(h, r)` such that the triplet score is
//! `pairwise(o, t)`; head-corruption form: `o' = g'(t, r)` such that the
//! score is `pairwise(h, o')`. See `models::ModelKind` for the per-model
//! decomposition and the derivations in DESIGN.md.

use super::ModelKind;

/// Which entity the o-builder consumes: the positive head (tail-corruption
/// side) or the positive tail (head-corruption side).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// corrupt tails: o = g(h, r), candidates are tails
    Tail,
    /// corrupt heads: o' = g'(t, r), candidates are heads
    Head,
}

/// Build o rows for a batch: `e[m,d]` is the kept entity (head for
/// Side::Tail, tail for Side::Head), `r[m,rd]` the relation rows.
/// Writes `o[m,d]`.
pub fn build_o(kind: ModelKind, side: Side, e: &[f32], r: &[f32], d: usize, o: &mut [f32]) {
    let m = e.len() / d;
    let rd = kind.rel_dim(d);
    debug_assert_eq!(r.len(), m * rd);
    debug_assert_eq!(o.len(), m * d);
    let dc = d / 2;
    match (kind, side) {
        (ModelKind::TransEL1 | ModelKind::TransEL2, Side::Tail) => {
            // o = h + r
            for i in 0..m * d {
                o[i] = e[i] + r[i];
            }
        }
        (ModelKind::TransEL1 | ModelKind::TransEL2, Side::Head) => {
            // score(h') = -||h' + r - t|| = -||h' - (t - r)|| → o' = t - r
            for i in 0..m * d {
                o[i] = e[i] - r[i];
            }
        }
        (ModelKind::DistMult, _) => {
            // o = h∘r (symmetric in h/t)
            for i in 0..m * d {
                o[i] = e[i] * r[i];
            }
        }
        (ModelKind::ComplEx, Side::Tail) => {
            // o = h·r (complex product); f = Re(o · conj(t)) = o_r·t_r + o_i·t_i
            for i in 0..m {
                for x in 0..dc {
                    let hr = e[i * d + x];
                    let hi = e[i * d + dc + x];
                    let rr = r[i * d + x];
                    let ri = r[i * d + dc + x];
                    o[i * d + x] = hr * rr - hi * ri;
                    o[i * d + dc + x] = hr * ri + hi * rr;
                }
            }
        }
        (ModelKind::ComplEx, Side::Head) => {
            // f(h') = h'_r·w_r + h'_i·w_i with w = (r_r t_r + r_i t_i,
            //                                       r_r t_i − r_i t_r)
            for i in 0..m {
                for x in 0..dc {
                    let tr = e[i * d + x];
                    let ti = e[i * d + dc + x];
                    let rr = r[i * d + x];
                    let ri = r[i * d + dc + x];
                    o[i * d + x] = rr * tr + ri * ti;
                    o[i * d + dc + x] = rr * ti - ri * tr;
                }
            }
        }
        (ModelKind::RotatE, Side::Tail) => {
            // o = h ∘ e^{iθ}; r rows hold θ[d/2]
            for i in 0..m {
                for x in 0..dc {
                    let hr = e[i * d + x];
                    let hi = e[i * d + dc + x];
                    let (sin, cos) = r[i * dc + x].sin_cos();
                    o[i * d + x] = hr * cos - hi * sin;
                    o[i * d + dc + x] = hr * sin + hi * cos;
                }
            }
        }
        (ModelKind::RotatE, Side::Head) => {
            // ||h'∘r − t|| = ||h' − t∘conj(r)|| → o' = t ∘ e^{−iθ}
            for i in 0..m {
                for x in 0..dc {
                    let tr = e[i * d + x];
                    let ti = e[i * d + dc + x];
                    let (sin, cos) = r[i * dc + x].sin_cos();
                    o[i * d + x] = tr * cos + ti * sin;
                    o[i * d + dc + x] = ti * cos - tr * sin;
                }
            }
        }
        (ModelKind::Rescal, Side::Tail) => {
            // f = hᵀ M t → o = Mᵀ h; r row is M (row-major d×d)
            for i in 0..m {
                let mm = &r[i * rd..(i + 1) * rd];
                let h = &e[i * d..(i + 1) * d];
                let oi = &mut o[i * d..(i + 1) * d];
                oi.fill(0.0);
                for a in 0..d {
                    let ha = h[a];
                    for b in 0..d {
                        oi[b] += ha * mm[a * d + b];
                    }
                }
            }
        }
        (ModelKind::Rescal, Side::Head) => {
            // o' = M t
            for i in 0..m {
                let mm = &r[i * rd..(i + 1) * rd];
                let t = &e[i * d..(i + 1) * d];
                let oi = &mut o[i * d..(i + 1) * d];
                for a in 0..d {
                    let mut s = 0f32;
                    for b in 0..d {
                        s += mm[a * d + b] * t[b];
                    }
                    oi[a] = s;
                }
            }
        }
        (ModelKind::TransR, Side::Tail) => {
            // r row = [r_vec(d) | M(d×d)]; o = M h + r_vec
            for i in 0..m {
                let rv = &r[i * rd..i * rd + d];
                let mm = &r[i * rd + d..(i + 1) * rd];
                let h = &e[i * d..(i + 1) * d];
                let oi = &mut o[i * d..(i + 1) * d];
                for a in 0..d {
                    let mut s = rv[a];
                    for b in 0..d {
                        s += mm[a * d + b] * h[b];
                    }
                    oi[a] = s;
                }
            }
        }
        (ModelKind::TransR, Side::Head) => {
            // score(h') = -||M h' + r - M t||² = -||M h' - (M t - r)||²
            for i in 0..m {
                let rv = &r[i * rd..i * rd + d];
                let mm = &r[i * rd + d..(i + 1) * rd];
                let t = &e[i * d..(i + 1) * d];
                let oi = &mut o[i * d..(i + 1) * d];
                for a in 0..d {
                    let mut s = -rv[a];
                    for b in 0..d {
                        s += mm[a * d + b] * t[b];
                    }
                    oi[a] = s;
                }
            }
        }
    }
}

/// VJP of `build_o`: given `d_o[m,d]`, accumulate into `d_e[m,d]` and
/// `d_r[m,rd]`.
pub fn build_o_backward(
    kind: ModelKind,
    side: Side,
    e: &[f32],
    r: &[f32],
    d: usize,
    d_o: &[f32],
    d_e: &mut [f32],
    d_r: &mut [f32],
) {
    let m = e.len() / d;
    let rd = kind.rel_dim(d);
    let dc = d / 2;
    match (kind, side) {
        (ModelKind::TransEL1 | ModelKind::TransEL2, Side::Tail) => {
            for i in 0..m * d {
                d_e[i] += d_o[i];
                d_r[i] += d_o[i];
            }
        }
        (ModelKind::TransEL1 | ModelKind::TransEL2, Side::Head) => {
            for i in 0..m * d {
                d_e[i] += d_o[i];
                d_r[i] -= d_o[i];
            }
        }
        (ModelKind::DistMult, _) => {
            for i in 0..m * d {
                d_e[i] += d_o[i] * r[i];
                d_r[i] += d_o[i] * e[i];
            }
        }
        (ModelKind::ComplEx, Side::Tail) => {
            for i in 0..m {
                for x in 0..dc {
                    let (hr, hi) = (e[i * d + x], e[i * d + dc + x]);
                    let (rr, ri) = (r[i * d + x], r[i * d + dc + x]);
                    let (gr, gi) = (d_o[i * d + x], d_o[i * d + dc + x]);
                    // o_r = hr rr − hi ri ; o_i = hr ri + hi rr
                    d_e[i * d + x] += gr * rr + gi * ri;
                    d_e[i * d + dc + x] += -gr * ri + gi * rr;
                    d_r[i * d + x] += gr * hr + gi * hi;
                    d_r[i * d + dc + x] += -gr * hi + gi * hr;
                }
            }
        }
        (ModelKind::ComplEx, Side::Head) => {
            for i in 0..m {
                for x in 0..dc {
                    let (tr, ti) = (e[i * d + x], e[i * d + dc + x]);
                    let (rr, ri) = (r[i * d + x], r[i * d + dc + x]);
                    let (gr, gi) = (d_o[i * d + x], d_o[i * d + dc + x]);
                    // o_r = rr tr + ri ti ; o_i = rr ti − ri tr
                    d_e[i * d + x] += gr * rr - gi * ri;
                    d_e[i * d + dc + x] += gr * ri + gi * rr;
                    d_r[i * d + x] += gr * tr + gi * ti;
                    d_r[i * d + dc + x] += gr * ti - gi * tr;
                }
            }
        }
        (ModelKind::RotatE, Side::Tail) => {
            for i in 0..m {
                for x in 0..dc {
                    let (hr, hi) = (e[i * d + x], e[i * d + dc + x]);
                    let (sin, cos) = r[i * dc + x].sin_cos();
                    let (gr, gi) = (d_o[i * d + x], d_o[i * d + dc + x]);
                    // o_r = hr c − hi s ; o_i = hr s + hi c
                    d_e[i * d + x] += gr * cos + gi * sin;
                    d_e[i * d + dc + x] += -gr * sin + gi * cos;
                    // dθ: do_r/dθ = −hr s − hi c ; do_i/dθ = hr c − hi s
                    d_r[i * dc + x] += gr * (-hr * sin - hi * cos) + gi * (hr * cos - hi * sin);
                }
            }
        }
        (ModelKind::RotatE, Side::Head) => {
            for i in 0..m {
                for x in 0..dc {
                    let (tr, ti) = (e[i * d + x], e[i * d + dc + x]);
                    let (sin, cos) = r[i * dc + x].sin_cos();
                    let (gr, gi) = (d_o[i * d + x], d_o[i * d + dc + x]);
                    // o_r = tr c + ti s ; o_i = ti c − tr s
                    d_e[i * d + x] += gr * cos - gi * sin;
                    d_e[i * d + dc + x] += gr * sin + gi * cos;
                    d_r[i * dc + x] += gr * (-tr * sin + ti * cos) + gi * (-ti * sin - tr * cos);
                }
            }
        }
        (ModelKind::Rescal, Side::Tail) => {
            // o = Mᵀh: d_h_a += Σ_b g_b M_ab ; d_M_ab += h_a g_b
            for i in 0..m {
                let mm = &r[i * rd..(i + 1) * rd];
                let h = &e[i * d..(i + 1) * d];
                let g = &d_o[i * d..(i + 1) * d];
                let dh = &mut d_e[i * d..(i + 1) * d];
                for a in 0..d {
                    let mut s = 0f32;
                    for b in 0..d {
                        s += g[b] * mm[a * d + b];
                    }
                    dh[a] += s;
                }
                let dm = &mut d_r[i * rd..(i + 1) * rd];
                for a in 0..d {
                    let ha = h[a];
                    for b in 0..d {
                        dm[a * d + b] += ha * g[b];
                    }
                }
            }
        }
        (ModelKind::Rescal, Side::Head) => {
            // o' = M t: d_t_b += Σ_a g_a M_ab ; d_M_ab += g_a t_b
            for i in 0..m {
                let mm = &r[i * rd..(i + 1) * rd];
                let t = &e[i * d..(i + 1) * d];
                let g = &d_o[i * d..(i + 1) * d];
                let dt = &mut d_e[i * d..(i + 1) * d];
                for b in 0..d {
                    let mut s = 0f32;
                    for a in 0..d {
                        s += g[a] * mm[a * d + b];
                    }
                    dt[b] += s;
                }
                let dm = &mut d_r[i * rd..(i + 1) * rd];
                for a in 0..d {
                    let ga = g[a];
                    for b in 0..d {
                        dm[a * d + b] += ga * t[b];
                    }
                }
            }
        }
        (ModelKind::TransR, Side::Tail) => {
            // o = M h + rv
            for i in 0..m {
                let mm = &r[i * rd + d..(i + 1) * rd];
                let h = &e[i * d..(i + 1) * d];
                let g = &d_o[i * d..(i + 1) * d];
                let dh = &mut d_e[i * d..(i + 1) * d];
                for b in 0..d {
                    let mut s = 0f32;
                    for a in 0..d {
                        s += g[a] * mm[a * d + b];
                    }
                    dh[b] += s;
                }
                let (drv, dm) = d_r[i * rd..(i + 1) * rd].split_at_mut(d);
                for a in 0..d {
                    drv[a] += g[a];
                    let ga = g[a];
                    for b in 0..d {
                        dm[a * d + b] += ga * h[b];
                    }
                }
            }
        }
        (ModelKind::TransR, Side::Head) => {
            // o' = M t − rv
            for i in 0..m {
                let mm = &r[i * rd + d..(i + 1) * rd];
                let t = &e[i * d..(i + 1) * d];
                let g = &d_o[i * d..(i + 1) * d];
                let dt = &mut d_e[i * d..(i + 1) * d];
                for b in 0..d {
                    let mut s = 0f32;
                    for a in 0..d {
                        s += g[a] * mm[a * d + b];
                    }
                    dt[b] += s;
                }
                let (drv, dm) = d_r[i * rd..(i + 1) * rd].split_at_mut(d);
                for a in 0..d {
                    drv[a] -= g[a];
                    let ga = g[a];
                    for b in 0..d {
                        dm[a * d + b] += ga * t[b];
                    }
                }
            }
        }
    }
}

/// TransR negative projection: project candidate rows `n[k,d]` through the
/// i-th positive's matrix M (from `r` row i). Writes `out[k,d]`.
pub fn project_negs(kind: ModelKind, r_row: &[f32], n: &[f32], d: usize, out: &mut [f32]) {
    debug_assert!(kind.projects_negatives());
    let rd = kind.rel_dim(d);
    debug_assert_eq!(r_row.len(), rd);
    let mm = &r_row[d..]; // skip r_vec
    let k = n.len() / d;
    for j in 0..k {
        let nj = &n[j * d..(j + 1) * d];
        let oj = &mut out[j * d..(j + 1) * d];
        for a in 0..d {
            let mut s = 0f32;
            for b in 0..d {
                s += mm[a * d + b] * nj[b];
            }
            oj[a] = s;
        }
    }
}

/// VJP of `project_negs`: accumulate into `d_n[k,d]` and `d_r_row[rd]`
/// (matrix part only).
pub fn project_negs_backward(
    kind: ModelKind,
    r_row: &[f32],
    n: &[f32],
    d: usize,
    d_out: &[f32],
    d_n: &mut [f32],
    d_r_row: &mut [f32],
) {
    debug_assert!(kind.projects_negatives());
    let mm = &r_row[d..];
    let k = n.len() / d;
    let dm = &mut d_r_row[d..];
    for j in 0..k {
        let nj = &n[j * d..(j + 1) * d];
        let gj = &d_out[j * d..(j + 1) * d];
        let dnj = &mut d_n[j * d..(j + 1) * d];
        for b in 0..d {
            let mut s = 0f32;
            for a in 0..d {
                s += gj[a] * mm[a * d + b];
            }
            dnj[b] += s;
        }
        for a in 0..d {
            let ga = gj[a];
            for b in 0..d {
                dm[a * d + b] += ga * nj[b];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ops::{diag_forward, pairwise_forward};
    use crate::util::rng::Rng;

    /// Direct (textbook) score of a single triplet per paper Table 1.
    pub fn direct_score(kind: ModelKind, h: &[f32], r: &[f32], t: &[f32], d: usize) -> f32 {
        let dc = d / 2;
        match kind {
            ModelKind::TransEL1 => {
                -(0..d).map(|x| (h[x] + r[x] - t[x]).abs()).sum::<f32>()
            }
            ModelKind::TransEL2 => {
                let s: f32 = (0..d).map(|x| (h[x] + r[x] - t[x]).powi(2)).sum();
                -(s + crate::models::L2_EPS).sqrt()
            }
            ModelKind::DistMult => (0..d).map(|x| h[x] * r[x] * t[x]).sum(),
            ModelKind::ComplEx => (0..dc)
                .map(|x| {
                    let (hr, hi) = (h[x], h[dc + x]);
                    let (rr, ri) = (r[x], r[dc + x]);
                    let (tr, ti) = (t[x], t[dc + x]);
                    (hr * rr - hi * ri) * tr + (hr * ri + hi * rr) * ti
                })
                .sum(),
            ModelKind::RotatE => -(0..dc)
                .map(|x| {
                    let (sin, cos) = r[x].sin_cos();
                    let or = h[x] * cos - h[dc + x] * sin;
                    let oi = h[x] * sin + h[dc + x] * cos;
                    (or - t[x]).powi(2) + (oi - t[dc + x]).powi(2)
                })
                .sum::<f32>(),
            ModelKind::Rescal => {
                let mut s = 0f32;
                for a in 0..d {
                    for b in 0..d {
                        s += h[a] * r[a * d + b] * t[b];
                    }
                }
                s
            }
            ModelKind::TransR => {
                let rv = &r[..d];
                let mm = &r[d..];
                let mut s = 0f32;
                for a in 0..d {
                    let mut proj = rv[a];
                    for b in 0..d {
                        proj += mm[a * d + b] * (h[b] - t[b]);
                    }
                    s += proj * proj;
                }
                -s
            }
        }
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gen_normal() * 0.5).collect()
    }

    /// Both side decompositions must reproduce the direct triplet score.
    #[test]
    fn decomposition_matches_direct_score() {
        let d = 8;
        let m = 5;
        let mut rng = Rng::seed_from_u64(31);
        for kind in ModelKind::ALL {
            let rd = kind.rel_dim(d);
            let h = rand_vec(&mut rng, m * d);
            let r = rand_vec(&mut rng, m * rd);
            let t = rand_vec(&mut rng, m * d);
            let op = kind.pairwise_op();

            // tail side: score = pairwise(o, proj(t))
            let mut o = vec![0f32; m * d];
            build_o(kind, Side::Tail, &h, &r, d, &mut o);
            let mut tail_scores = vec![0f32; m];
            if kind.projects_negatives() {
                for i in 0..m {
                    let mut pt = vec![0f32; d];
                    project_negs(kind, &r[i * rd..(i + 1) * rd], &t[i * d..(i + 1) * d], d, &mut pt);
                    let mut s = vec![0f32; 1];
                    pairwise_forward(op, &o[i * d..(i + 1) * d], &pt, d, &mut s);
                    tail_scores[i] = s[0];
                }
            } else {
                diag_forward(op, &o, &t, d, &mut tail_scores);
            }

            // head side: score = pairwise(proj(h), o')
            let mut o2 = vec![0f32; m * d];
            build_o(kind, Side::Head, &t, &r, d, &mut o2);
            let mut head_scores = vec![0f32; m];
            if kind.projects_negatives() {
                for i in 0..m {
                    let mut ph = vec![0f32; d];
                    project_negs(kind, &r[i * rd..(i + 1) * rd], &h[i * d..(i + 1) * d], d, &mut ph);
                    let mut s = vec![0f32; 1];
                    pairwise_forward(op, &ph, &o2[i * d..(i + 1) * d], d, &mut s);
                    head_scores[i] = s[0];
                }
            } else {
                // note argument order: pairwise(h, o')
                diag_forward(op, &h, &o2, d, &mut head_scores);
            }

            for i in 0..m {
                let direct = direct_score(
                    kind,
                    &h[i * d..(i + 1) * d],
                    &r[i * rd..(i + 1) * rd],
                    &t[i * d..(i + 1) * d],
                    d,
                );
                assert!(
                    (tail_scores[i] - direct).abs() < 1e-4,
                    "{kind:?} tail: {} vs {direct}",
                    tail_scores[i]
                );
                assert!(
                    (head_scores[i] - direct).abs() < 1e-4,
                    "{kind:?} head: {} vs {direct}",
                    head_scores[i]
                );
            }
        }
    }

    /// Finite-difference check of build_o_backward for every model/side.
    #[test]
    fn builder_gradients() {
        let d = 6;
        let m = 2;
        let mut rng = Rng::seed_from_u64(77);
        for kind in ModelKind::ALL {
            let d_use = if kind.validate_dim(d) { d } else { d + 1 };
            let rd = kind.rel_dim(d_use);
            let e = rand_vec(&mut rng, m * d_use);
            let r = rand_vec(&mut rng, m * rd);
            let g = rand_vec(&mut rng, m * d_use);
            for side in [Side::Tail, Side::Head] {
                let mut d_e = vec![0f32; m * d_use];
                let mut d_r = vec![0f32; m * rd];
                build_o_backward(kind, side, &e, &r, d_use, &g, &mut d_e, &mut d_r);

                let loss = |e: &[f32], r: &[f32]| -> f64 {
                    let mut o = vec![0f32; m * d_use];
                    build_o(kind, side, e, r, d_use, &mut o);
                    o.iter().zip(&g).map(|(&a, &b)| a as f64 * b as f64).sum()
                };
                let eps = 1e-3f32;
                for idx in (0..m * d_use).step_by(3) {
                    let mut ep = e.clone();
                    ep[idx] += eps;
                    let mut em = e.clone();
                    em[idx] -= eps;
                    let fd = (loss(&ep, &r) - loss(&em, &r)) / (2.0 * eps as f64);
                    assert!(
                        (fd - d_e[idx] as f64).abs() < 3e-2,
                        "{kind:?}/{side:?} d_e[{idx}] fd={fd} got={}",
                        d_e[idx]
                    );
                }
                for idx in (0..m * rd).step_by(7) {
                    let mut rp = r.clone();
                    rp[idx] += eps;
                    let mut rm = r.clone();
                    rm[idx] -= eps;
                    let fd = (loss(&e, &rp) - loss(&e, &rm)) / (2.0 * eps as f64);
                    assert!(
                        (fd - d_r[idx] as f64).abs() < 3e-2,
                        "{kind:?}/{side:?} d_r[{idx}] fd={fd} got={}",
                        d_r[idx]
                    );
                }
            }
        }
    }

    #[test]
    fn projection_gradients() {
        let d = 5;
        let k = 3;
        let kind = ModelKind::TransR;
        let rd = kind.rel_dim(d);
        let mut rng = Rng::seed_from_u64(99);
        let r_row = rand_vec(&mut rng, rd);
        let n = rand_vec(&mut rng, k * d);
        let g = rand_vec(&mut rng, k * d);
        let mut d_n = vec![0f32; k * d];
        let mut d_r = vec![0f32; rd];
        project_negs_backward(kind, &r_row, &n, d, &g, &mut d_n, &mut d_r);
        let loss = |r_row: &[f32], n: &[f32]| -> f64 {
            let mut out = vec![0f32; k * d];
            project_negs(kind, r_row, n, d, &mut out);
            out.iter().zip(&g).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let eps = 1e-3f32;
        for idx in 0..k * d {
            let mut np = n.clone();
            np[idx] += eps;
            let mut nm = n.clone();
            nm[idx] -= eps;
            let fd = (loss(&r_row, &np) - loss(&r_row, &nm)) / (2.0 * eps as f64);
            assert!((fd - d_n[idx] as f64).abs() < 2e-2);
        }
        for idx in 0..rd {
            let mut rp = r_row.clone();
            rp[idx] += eps;
            let mut rm = r_row.clone();
            rm[idx] -= eps;
            let fd = (loss(&rp, &n) - loss(&rm, &n)) / (2.0 * eps as f64);
            assert!((fd - d_r[idx] as f64).abs() < 2e-2, "d_r[{idx}]");
        }
    }
}
