//! Blocked, cache-tiled score/grad microkernels + per-worker scratch arenas.
//!
//! This module is the *fused* half of the kernel contract documented in
//! `docs/KERNELS.md`. The naive triple loops in [`super::ops`] stay the
//! reference implementation; everything here is an optimization that must
//! stay **bit-exact** against them (asserted by
//! `rust/tests/kernel_parity_tests.rs` with the ULP comparator in
//! [`crate::util::ulp`]).
//!
//! # How bit-exactness survives vectorization
//!
//! The scalar reference reduces over the embedding dim `x = 0..d`
//! *sequentially* for each `(i, j)` pair. A classic SIMD dot product
//! splits that reduction across lanes and combines partial sums — a
//! different association, hence different rounding. The fused kernels
//! instead vectorize across **candidates**: a tile of [`LANES`] `n`-rows
//! is transposed into an `[d, LANES]` scratch tile (`nt[x][l] = n[j0+l][x]`,
//! L1-resident: `d * LANES * 4` bytes ≤ 16 KiB up to d = 512), and the
//! inner loop
//!
//! ```text
//! for x in 0..d { for l in 0..LANES { acc[l] += o[i][x] * nt[x][l] } }
//! ```
//!
//! performs, per lane, exactly the scalar reduction in exactly the scalar
//! order — eight independent score chains advancing in lockstep, which
//! LLVM maps onto one vector mul + one vector add per `x` (no `mul_add`:
//! a fused multiply-add rounds once where the reference rounds twice).
//! The transpose is amortized over all `m` rows of `o`, which stream
//! row-major through the tile (the `o` block for a training chunk is
//! L2-resident).
//!
//! Backward has no reductions over `d` — every `(i, j)` pair contributes
//! an element-wise AXPY into `d_o[i]` and `d_n[j]` — so it vectorizes
//! over `x` directly with [`LANES`]-wide blocked loops; bit-exactness
//! only requires keeping the reference's ascending `(i, j)` accumulation
//! order and per-element expression shapes (see the `*_axpy2` helpers).
//!
//! The gather→score entry point ([`gather_scores`]) streams candidate
//! rows from an [`EmbeddingStore`] through the transposed tiles
//! [`LANES`] ids at a time, so eval candidate scoring never stages a
//! block-sized `[4096, d]` buffer.

use super::ops;
use super::{PairwiseOp, L1_SIGN_AT_ZERO, L2_EPS};
use crate::store::EmbeddingStore;

/// SIMD lane width the kernels block for: eight f32s = one AVX2 register
/// (two NEON registers). Fixed rather than runtime-detected so results
/// are identical across hosts.
pub const LANES: usize = 8;

/// Which pairwise kernel implementation scores and differentiates
/// batches: the scalar reference loops in [`super::ops`], or the blocked
/// [`LANES`]-wide fused kernels in this module. Selected by
/// `RunSpec.kernels` / `--kernels`; results are bit-identical either way
/// (that is the contract, not an accident — see `docs/KERNELS.md`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelBackend {
    /// Reference triple loops (`models::ops`). The default.
    #[default]
    Scalar,
    /// Blocked candidate-tiled kernels + fused gather→score streaming.
    Fused,
}

impl KernelBackend {
    pub const ALL: [KernelBackend; 2] = [KernelBackend::Scalar, KernelBackend::Fused];

    pub fn parse(s: &str) -> Option<KernelBackend> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelBackend::Scalar),
            "fused" => Some(KernelBackend::Fused),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Fused => "fused",
        }
    }

    /// `scores[i*k + j] = op(o_i, n_j)` — dispatched pairwise forward.
    pub fn forward(
        &self,
        op: PairwiseOp,
        o: &[f32],
        n: &[f32],
        d: usize,
        scores: &mut [f32],
        scratch: &mut KernelScratch,
    ) {
        match self {
            KernelBackend::Scalar => ops::pairwise_forward(op, o, n, d, scores),
            KernelBackend::Fused => forward_fused(op, o, n, d, scores, &mut scratch.tile),
        }
    }

    /// Dispatched pairwise VJP (accumulates into `d_o`/`d_n`).
    pub fn backward(
        &self,
        op: PairwiseOp,
        o: &[f32],
        n: &[f32],
        d: usize,
        scores: &[f32],
        d_scores: &[f32],
        d_o: &mut [f32],
        d_n: &mut [f32],
    ) {
        match self {
            KernelBackend::Scalar => {
                ops::pairwise_backward(op, o, n, d, scores, d_scores, d_o, d_n)
            }
            KernelBackend::Fused => backward_fused(op, o, n, d, scores, d_scores, d_o, d_n),
        }
    }

    /// Dispatched diagonal forward (`scores[i] = op(o_i, n_i)`).
    pub fn diag_forward(&self, op: PairwiseOp, o: &[f32], n: &[f32], d: usize, scores: &mut [f32]) {
        match self {
            KernelBackend::Scalar => ops::diag_forward(op, o, n, d, scores),
            KernelBackend::Fused => diag_forward_fused(op, o, n, d, scores),
        }
    }

    /// Dispatched diagonal VJP.
    #[allow(clippy::too_many_arguments)]
    pub fn diag_backward(
        &self,
        op: PairwiseOp,
        o: &[f32],
        n: &[f32],
        d: usize,
        scores: &[f32],
        d_scores: &[f32],
        d_o: &mut [f32],
        d_n: &mut [f32],
    ) {
        match self {
            KernelBackend::Scalar => {
                ops::diag_backward(op, o, n, d, scores, d_scores, d_o, d_n)
            }
            KernelBackend::Fused => {
                let m = o.len() / d;
                for i in 0..m {
                    backward_fused(
                        op,
                        &o[i * d..(i + 1) * d],
                        &n[i * d..(i + 1) * d],
                        d,
                        &scores[i..i + 1],
                        &d_scores[i..i + 1],
                        &mut d_o[i * d..(i + 1) * d],
                        &mut d_n[i * d..(i + 1) * d],
                    );
                }
            }
        }
    }
}

/// Tile-sized scratch owned by a worker/eval thread so the hot loops
/// never allocate: the `[d, LANES]` transposed candidate tile plus the
/// [`LANES`]-row landing buffer used by [`gather_scores`]. Allocations
/// persist across calls; `Default::default()` is an empty arena.
#[derive(Default)]
pub struct KernelScratch {
    /// Transposed candidate tile, `d * LANES` f32s.
    tile: Vec<f32>,
    /// Row-major landing pad for streamed gathers, `LANES * d` f32s.
    rows: Vec<f32>,
}

/// Checkout a zeroed `f32` scratch slice of length `n`, reusing the
/// vector's allocation across steps (`clear` + `resize` re-zeroes the
/// prefix without freeing capacity). The zeroing keeps reused buffers
/// indistinguishable from the `vec![0f32; n]` they replace, which is what
/// makes scratch reuse bit-exact.
pub(crate) fn zeroed(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    buf.clear();
    buf.resize(n, 0.0);
    &mut buf[..]
}

/// Per-worker scratch arena for `NativeModel::train_step_with` — every
/// `vec![0f32; ..]` the step used to allocate per call lives here
/// instead, checked out zeroed via [`zeroed`]. One arena per worker
/// thread (never shared; `TrainBackend` keeps it in a `RefCell`).
#[derive(Default)]
pub struct StepScratch {
    pub kernel: KernelScratch,
    pub(crate) o_tail: Vec<f32>,
    pub(crate) o_head: Vec<f32>,
    pub(crate) proj_t: Vec<f32>,
    pub(crate) pos: Vec<f32>,
    pub(crate) neg_scores: Vec<f32>,
    pub(crate) proj_negs_t: Vec<f32>,
    pub(crate) proj_negs_h: Vec<f32>,
    pub(crate) row_k: Vec<f32>,
    pub(crate) chunk_s: Vec<f32>,
    pub(crate) d_pos: Vec<f32>,
    pub(crate) d_neg: Vec<f32>,
    pub(crate) d_o_tail: Vec<f32>,
    pub(crate) d_o_head: Vec<f32>,
    pub(crate) d_t_eff: Vec<f32>,
    pub(crate) d_pt: Vec<f32>,
    pub(crate) d_ph: Vec<f32>,
    pub(crate) st: Vec<f32>,
    pub(crate) gt: Vec<f32>,
    pub(crate) sh: Vec<f32>,
    pub(crate) gh: Vec<f32>,
}

/// Per-thread scratch arena for `NativeModel::eval_scores_with` and the
/// eval candidate loop: the `o` query rows, the TransR projected-candidate
/// buffer (reused across *calls*, not just across `i` — the satellite fix
/// for the per-call `vec![0f32; c * d]`), and the kernel tiles.
#[derive(Default)]
pub struct EvalScratch {
    pub kernel: KernelScratch,
    pub(crate) o: Vec<f32>,
    pub(crate) pc: Vec<f32>,
    pub(crate) query: Vec<f32>,
}

// ---------------------------------------------------------------------------
// Fused forward
// ---------------------------------------------------------------------------

/// Per-`x` tile kernel bodies: eight independent scalar chains in
/// lockstep. `nt` is the transposed tile (`d * LANES`), `oi` one `o` row.
#[inline]
fn tile_dot(oi: &[f32], nt: &[f32]) -> [f32; LANES] {
    let mut acc = [0f32; LANES];
    for (&ox, row) in oi.iter().zip(nt.chunks_exact(LANES)) {
        for (a, &v) in acc.iter_mut().zip(row) {
            *a += ox * v;
        }
    }
    acc
}

#[inline]
fn tile_sqdiff(oi: &[f32], nt: &[f32]) -> [f32; LANES] {
    let mut acc = [0f32; LANES];
    for (&ox, row) in oi.iter().zip(nt.chunks_exact(LANES)) {
        for (a, &v) in acc.iter_mut().zip(row) {
            let diff = ox - v;
            *a += diff * diff;
        }
    }
    acc
}

#[inline]
fn tile_l1(oi: &[f32], nt: &[f32]) -> [f32; LANES] {
    let mut acc = [0f32; LANES];
    for (&ox, row) in oi.iter().zip(nt.chunks_exact(LANES)) {
        for (a, &v) in acc.iter_mut().zip(row) {
            *a += (ox - v).abs();
        }
    }
    acc
}

/// Fused pairwise forward: candidate-tiled, bit-exact vs
/// [`ops::pairwise_forward`]. `tile` is the reusable transpose scratch.
fn forward_fused(
    op: PairwiseOp,
    o: &[f32],
    n: &[f32],
    d: usize,
    scores: &mut [f32],
    tile: &mut Vec<f32>,
) {
    let m = o.len() / d;
    let k = n.len() / d;
    debug_assert_eq!(scores.len(), m * k);
    if m == 0 || k == 0 {
        return;
    }
    tile.clear();
    tile.resize(d * LANES, 0.0);
    let nt = &mut tile[..];
    let mut j0 = 0;
    while j0 < k {
        let jw = LANES.min(k - j0);
        // Transpose the candidate tile: nt[x*LANES + l] = n[(j0+l)*d + x].
        // Pad lanes are zero — they compute garbage scores that are never
        // written out (finite inputs keep the padding finite).
        for (x, trow) in nt.chunks_exact_mut(LANES).enumerate() {
            for (l, t) in trow.iter_mut().enumerate() {
                *t = if l < jw { n[(j0 + l) * d + x] } else { 0.0 };
            }
        }
        for i in 0..m {
            let oi = &o[i * d..(i + 1) * d];
            let acc = match op {
                PairwiseOp::Dot => tile_dot(oi, nt),
                PairwiseOp::SqDiff | PairwiseOp::L2 => tile_sqdiff(oi, nt),
                PairwiseOp::L1 => tile_l1(oi, nt),
            };
            let out = &mut scores[i * k + j0..i * k + j0 + jw];
            match op {
                PairwiseOp::Dot => {
                    for (s, &a) in out.iter_mut().zip(&acc[..jw]) {
                        *s = a;
                    }
                }
                PairwiseOp::SqDiff | PairwiseOp::L1 => {
                    for (s, &a) in out.iter_mut().zip(&acc[..jw]) {
                        *s = -a;
                    }
                }
                PairwiseOp::L2 => {
                    for (s, &a) in out.iter_mut().zip(&acc[..jw]) {
                        *s = -(a + L2_EPS).sqrt();
                    }
                }
            }
        }
        j0 += LANES;
    }
}

/// Fused diagonal forward: same sequential per-row reduction as the
/// scalar reference (lane-splitting a single row would change rounding),
/// but without the per-row `vec![0f32; 1]` the reference allocates.
fn diag_forward_fused(op: PairwiseOp, o: &[f32], n: &[f32], d: usize, scores: &mut [f32]) {
    let m = o.len() / d;
    debug_assert_eq!(scores.len(), m);
    for (i, s) in scores.iter_mut().enumerate() {
        let oi = &o[i * d..(i + 1) * d];
        let ni = &n[i * d..(i + 1) * d];
        *s = match op {
            PairwiseOp::Dot => {
                let mut acc = 0f32;
                for (&a, &b) in oi.iter().zip(ni) {
                    acc += a * b;
                }
                acc
            }
            PairwiseOp::SqDiff => {
                let mut acc = 0f32;
                for (&a, &b) in oi.iter().zip(ni) {
                    let diff = a - b;
                    acc += diff * diff;
                }
                -acc
            }
            PairwiseOp::L2 => {
                let mut acc = 0f32;
                for (&a, &b) in oi.iter().zip(ni) {
                    let diff = a - b;
                    acc += diff * diff;
                }
                -(acc + L2_EPS).sqrt()
            }
            PairwiseOp::L1 => {
                let mut acc = 0f32;
                for (&a, &b) in oi.iter().zip(ni) {
                    acc += (a - b).abs();
                }
                -acc
            }
        };
    }
}

// ---------------------------------------------------------------------------
// Fused backward
// ---------------------------------------------------------------------------

/// `dst[x] += a * src[x]` — LANES-blocked main body + scalar tail.
/// Element-wise, so lane-blocking cannot change rounding.
#[inline]
fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut sc = src.chunks_exact(LANES);
    for (dv, sv) in (&mut dc).zip(&mut sc) {
        for (x, &s) in dv.iter_mut().zip(sv) {
            *x += a * s;
        }
    }
    for (x, &s) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
        *x += a * s;
    }
}

/// `diff = o[x] - n[x]; d_o[x] += go*diff; d_n[x] += gn*diff` — the
/// SqDiff VJP row update. `go`/`gn` are the pre-multiplied upstream
/// factors (`(-2.0*g)`, `(2.0*g)`), matching the reference's
/// left-associated `-2.0 * g * diff` exactly.
#[inline]
fn diff_axpy2(d_o: &mut [f32], d_n: &mut [f32], o: &[f32], n: &[f32], go: f32, gn: f32) {
    let mut doc = d_o.chunks_exact_mut(LANES);
    let mut dnc = d_n.chunks_exact_mut(LANES);
    let mut oc = o.chunks_exact(LANES);
    let mut nc = n.chunks_exact(LANES);
    for (((dov, dnv), ov), nv) in (&mut doc).zip(&mut dnc).zip(&mut oc).zip(&mut nc) {
        for (((dox, dnx), &ox), &nx) in
            dov.iter_mut().zip(dnv.iter_mut()).zip(ov).zip(nv)
        {
            let diff = ox - nx;
            *dox += go * diff;
            *dnx += gn * diff;
        }
    }
    for (((dox, dnx), &ox), &nx) in doc
        .into_remainder()
        .iter_mut()
        .zip(dnc.into_remainder().iter_mut())
        .zip(oc.remainder())
        .zip(nc.remainder())
    {
        let diff = ox - nx;
        *dox += go * diff;
        *dnx += gn * diff;
    }
}

/// L2 VJP row update: `t = (g*diff)*inv; d_o[x] += -t; d_n[x] += t`.
/// Bit-identical to the reference's `((-g)*diff)*inv` / `(g*diff)*inv`
/// because IEEE-754 negation is exact.
#[inline]
fn l2_axpy2(d_o: &mut [f32], d_n: &mut [f32], o: &[f32], n: &[f32], g: f32, inv: f32) {
    let mut doc = d_o.chunks_exact_mut(LANES);
    let mut dnc = d_n.chunks_exact_mut(LANES);
    let mut oc = o.chunks_exact(LANES);
    let mut nc = n.chunks_exact(LANES);
    for (((dov, dnv), ov), nv) in (&mut doc).zip(&mut dnc).zip(&mut oc).zip(&mut nc) {
        for (((dox, dnx), &ox), &nx) in
            dov.iter_mut().zip(dnv.iter_mut()).zip(ov).zip(nv)
        {
            let t = (g * (ox - nx)) * inv;
            *dox += -t;
            *dnx += t;
        }
    }
    for (((dox, dnx), &ox), &nx) in doc
        .into_remainder()
        .iter_mut()
        .zip(dnc.into_remainder().iter_mut())
        .zip(oc.remainder())
        .zip(nc.remainder())
    {
        let t = (g * (ox - nx)) * inv;
        *dox += -t;
        *dnx += t;
    }
}

/// L1 VJP row update: subgradient `sign(diff)` with
/// [`L1_SIGN_AT_ZERO`] at ties — the same documented constant the scalar
/// reference uses, so the two paths cannot disagree at kinks.
#[inline]
fn l1_axpy2(d_o: &mut [f32], d_n: &mut [f32], o: &[f32], n: &[f32], gm: f32, gp: f32) {
    let mut doc = d_o.chunks_exact_mut(LANES);
    let mut dnc = d_n.chunks_exact_mut(LANES);
    let mut oc = o.chunks_exact(LANES);
    let mut nc = n.chunks_exact(LANES);
    for (((dov, dnv), ov), nv) in (&mut doc).zip(&mut dnc).zip(&mut oc).zip(&mut nc) {
        for (((dox, dnx), &ox), &nx) in
            dov.iter_mut().zip(dnv.iter_mut()).zip(ov).zip(nv)
        {
            let s = if ox == nx { L1_SIGN_AT_ZERO } else { (ox - nx).signum() };
            *dox += gm * s;
            *dnx += gp * s;
        }
    }
    for (((dox, dnx), &ox), &nx) in doc
        .into_remainder()
        .iter_mut()
        .zip(dnc.into_remainder().iter_mut())
        .zip(oc.remainder())
        .zip(nc.remainder())
    {
        let s = if ox == nx { L1_SIGN_AT_ZERO } else { (ox - nx).signum() };
        *dox += gm * s;
        *dnx += gp * s;
    }
}

/// Fused pairwise VJP: same ascending `(i, j)` accumulation order as
/// [`ops::pairwise_backward`], with the per-row element updates blocked
/// into [`LANES`]-wide chunks.
#[allow(clippy::too_many_arguments)]
fn backward_fused(
    op: PairwiseOp,
    o: &[f32],
    n: &[f32],
    d: usize,
    scores: &[f32],
    d_scores: &[f32],
    d_o: &mut [f32],
    d_n: &mut [f32],
) {
    let m = o.len() / d;
    let k = n.len() / d;
    debug_assert_eq!(d_scores.len(), m * k);
    for i in 0..m {
        let oi = &o[i * d..(i + 1) * d];
        for j in 0..k {
            let g = d_scores[i * k + j];
            if g == 0.0 {
                continue;
            }
            let nj = &n[j * d..(j + 1) * d];
            // Split borrows: d_o row i and d_n row j never alias (separate
            // output buffers), so reborrow per pair.
            let do_row = &mut d_o[i * d..(i + 1) * d];
            let dn_row = &mut d_n[j * d..(j + 1) * d];
            match op {
                PairwiseOp::Dot => {
                    axpy(do_row, nj, g);
                    axpy(dn_row, oi, g);
                }
                PairwiseOp::SqDiff => {
                    diff_axpy2(do_row, dn_row, oi, nj, -2.0 * g, 2.0 * g);
                }
                PairwiseOp::L2 => {
                    let norm = -scores[i * k + j]; // = sqrt(S+eps) > 0
                    let inv = 1.0 / norm;
                    l2_axpy2(do_row, dn_row, oi, nj, g, inv);
                }
                PairwiseOp::L1 => {
                    l1_axpy2(do_row, dn_row, oi, nj, -g, g);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fused gather→score
// ---------------------------------------------------------------------------

/// Stream candidate rows from `store` straight through kernel tiles,
/// scoring each against the single query row `o` (`o.len() == d`) —
/// the fused gather→score path used by eval candidate scoring. Rows land
/// [`LANES`] at a time in a tile-sized buffer instead of a full
/// block-sized staging buffer. Returns `(values moved, values hit)`
/// exactly as a staged [`EmbeddingStore::gather_hits`] over `ids` would,
/// so transfer-ledger accounting is identical between the paths.
///
/// Scores are bit-identical to `gather` + [`ops::pairwise_forward`]:
/// the same rows flow through [`forward_fused`], which bit-matches the
/// scalar reference.
pub fn gather_scores(
    op: PairwiseOp,
    o: &[f32],
    store: &dyn EmbeddingStore,
    ids: &[u64],
    d: usize,
    scores: &mut [f32],
    scratch: &mut KernelScratch,
) -> (u64, u64) {
    debug_assert_eq!(o.len(), d);
    debug_assert_eq!(scores.len(), ids.len());
    let KernelScratch { tile, rows } = scratch;
    rows.clear();
    rows.resize(LANES * d, 0.0);
    let mut values = 0u64;
    let mut hits = 0u64;
    for (tid, stile) in ids.chunks(LANES).zip(scores.chunks_mut(LANES)) {
        let rbuf = &mut rows[..tid.len() * d];
        let (v, h) = store.gather_hits(tid, rbuf);
        values += v;
        hits += h;
        forward_fused(op, o, rbuf, d, stile, tile);
    }
    (values, hits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::DenseStore;
    use crate::util::rng::Rng;
    use crate::util::ulp::max_ulp_distance;

    const OPS: [PairwiseOp; 4] =
        [PairwiseOp::Dot, PairwiseOp::SqDiff, PairwiseOp::L2, PairwiseOp::L1];

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gen_normal()).collect()
    }

    #[test]
    fn fused_forward_bit_matches_scalar() {
        let mut rng = Rng::seed_from_u64(7);
        for op in OPS {
            for &(m, k, d) in &[(3usize, 10usize, 5usize), (1, 8, 16), (4, 9, 17), (2, 1, 1)] {
                let o = randvec(&mut rng, m * d);
                let n = randvec(&mut rng, k * d);
                let mut want = vec![0f32; m * k];
                ops::pairwise_forward(op, &o, &n, d, &mut want);
                let mut got = vec![0f32; m * k];
                let mut scratch = KernelScratch::default();
                KernelBackend::Fused.forward(op, &o, &n, d, &mut got, &mut scratch);
                assert_eq!(max_ulp_distance(&want, &got), 0, "{op:?} m={m} k={k} d={d}");
            }
        }
    }

    #[test]
    fn fused_backward_bit_matches_scalar() {
        let mut rng = Rng::seed_from_u64(11);
        for op in OPS {
            let (m, k, d) = (3usize, 7usize, 13usize);
            let o = randvec(&mut rng, m * d);
            let n = randvec(&mut rng, k * d);
            let mut scores = vec![0f32; m * k];
            ops::pairwise_forward(op, &o, &n, d, &mut scores);
            let mut g = randvec(&mut rng, m * k);
            g[2] = 0.0; // exercise the g == 0 skip
            let (mut do_a, mut dn_a) = (vec![0f32; m * d], vec![0f32; k * d]);
            ops::pairwise_backward(op, &o, &n, d, &scores, &g, &mut do_a, &mut dn_a);
            let (mut do_b, mut dn_b) = (vec![0f32; m * d], vec![0f32; k * d]);
            KernelBackend::Fused
                .backward(op, &o, &n, d, &scores, &g, &mut do_b, &mut dn_b);
            assert_eq!(max_ulp_distance(&do_a, &do_b), 0, "{op:?} d_o");
            assert_eq!(max_ulp_distance(&dn_a, &dn_b), 0, "{op:?} d_n");
        }
    }

    #[test]
    fn fused_diag_bit_matches_scalar() {
        let mut rng = Rng::seed_from_u64(13);
        for op in OPS {
            let (m, d) = (5usize, 9usize);
            let o = randvec(&mut rng, m * d);
            let n = randvec(&mut rng, m * d);
            let mut want = vec![0f32; m];
            ops::diag_forward(op, &o, &n, d, &mut want);
            let mut got = vec![0f32; m];
            KernelBackend::Fused.diag_forward(op, &o, &n, d, &mut got);
            assert_eq!(max_ulp_distance(&want, &got), 0, "{op:?} diag fwd");

            let g = randvec(&mut rng, m);
            let (mut do_a, mut dn_a) = (vec![0f32; m * d], vec![0f32; m * d]);
            ops::diag_backward(op, &o, &n, d, &want, &g, &mut do_a, &mut dn_a);
            let (mut do_b, mut dn_b) = (vec![0f32; m * d], vec![0f32; m * d]);
            KernelBackend::Fused
                .diag_backward(op, &o, &n, d, &want, &g, &mut do_b, &mut dn_b);
            assert_eq!(max_ulp_distance(&do_a, &do_b), 0, "{op:?} diag d_o");
            assert_eq!(max_ulp_distance(&dn_a, &dn_b), 0, "{op:?} diag d_n");
        }
    }

    #[test]
    fn scratch_reuse_does_not_change_results() {
        let mut rng = Rng::seed_from_u64(17);
        let mut scratch = KernelScratch::default();
        // Bigger shape first so the second call reuses a larger buffer.
        for &(m, k, d) in &[(4usize, 20usize, 32usize), (2, 3, 5)] {
            let o = randvec(&mut rng, m * d);
            let n = randvec(&mut rng, k * d);
            let mut want = vec![0f32; m * k];
            ops::pairwise_forward(PairwiseOp::Dot, &o, &n, d, &mut want);
            let mut got = vec![0f32; m * k];
            KernelBackend::Fused.forward(PairwiseOp::Dot, &o, &n, d, &mut got, &mut scratch);
            assert_eq!(want, got);
        }
    }

    #[test]
    fn gather_scores_matches_staged_gather() {
        let d = 6;
        let store = DenseStore::uniform(30, d, 1.0, 42);
        let ids: Vec<u64> = vec![3, 0, 29, 7, 7, 15, 1, 22, 9, 4, 28]; // 11 ids: full tile + tail
        let mut rng = Rng::seed_from_u64(23);
        let o = randvec(&mut rng, d);
        for op in OPS {
            // staged reference: gather the whole block, then scalar-score it
            let mut staged = vec![0f32; ids.len() * d];
            store.gather(&ids, &mut staged);
            let mut want = vec![0f32; ids.len()];
            ops::pairwise_forward(op, &o, &staged, d, &mut want);

            let mut got = vec![0f32; ids.len()];
            let mut scratch = KernelScratch::default();
            let (values, hits) =
                gather_scores(op, &o, &store, &ids, d, &mut got, &mut scratch);
            assert_eq!(max_ulp_distance(&want, &got), 0, "{op:?} streamed vs staged");
            assert_eq!(values, (ids.len() * d) as u64);
            assert_eq!(hits, 0); // DenseStore has no cache in front
        }
    }

    #[test]
    fn empty_inputs_are_noops() {
        let mut scratch = KernelScratch::default();
        let mut scores: Vec<f32> = vec![];
        let two = [1.0f32, 2.0];
        KernelBackend::Fused.forward(PairwiseOp::Dot, &[], &two, 2, &mut scores, &mut scratch);
        KernelBackend::Fused.forward(PairwiseOp::L2, &two, &[], 2, &mut scores, &mut scratch);
        let (mut d_o, mut d_n) = (vec![0f32; 2], vec![0f32; 0]);
        KernelBackend::Fused
            .backward(PairwiseOp::L1, &[1.0, 2.0], &[], 2, &[], &[], &mut d_o, &mut d_n);
        assert_eq!(d_o, vec![0.0, 0.0]);
    }

    #[test]
    fn parse_roundtrip() {
        for kb in KernelBackend::ALL {
            assert_eq!(KernelBackend::parse(kb.name()), Some(kb));
        }
        assert_eq!(KernelBackend::parse("FUSED"), Some(KernelBackend::Fused));
        assert_eq!(KernelBackend::parse("avx999"), None);
    }
}
