//! Loss functions over positive/negative scores (paper §2) and their
//! gradients w.r.t. the scores.
//!
//! * `Logistic` — log(1 + exp(−y·f)), y=+1 positives / −1 negatives;
//! * `Margin`   — pairwise hinge max(0, γ − f⁺ + f⁻).
//!
//! Optional self-adversarial negative weighting (RotatE paper; DGL-KE's
//! `-adv` flag): negatives are weighted by softmax(α·f⁻) treated as a
//! constant (stop-gradient), per chunk-row.

/// Loss family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LossKind {
    Logistic,
    /// Pairwise hinge with the given margin γ.
    Margin(f32),
}

impl LossKind {
    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Logistic => "logistic",
            LossKind::Margin(_) => "margin",
        }
    }
}

/// Loss configuration: family + optional adversarial temperature.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LossCfg {
    pub kind: LossKind,
    /// Self-adversarial temperature α (None = uniform negative weights).
    pub adv_temp: Option<f32>,
}

impl Default for LossCfg {
    fn default() -> Self {
        LossCfg { kind: LossKind::Logistic, adv_temp: None }
    }
}

#[inline]
fn softplus(x: f32) -> f32 {
    // numerically stable log(1+e^x)
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Adversarial weights per row of `k` negatives: softmax(α f) (detached).
/// Writes into `w` (len = scores.len()); rows of length k.
fn adv_weights(scores: &[f32], k: usize, alpha: f32, w: &mut [f32]) {
    for row in 0..scores.len() / k {
        let s = &scores[row * k..(row + 1) * k];
        let wr = &mut w[row * k..(row + 1) * k];
        let mx = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for j in 0..k {
            wr[j] = ((s[j] - mx) * alpha).exp();
            z += wr[j];
        }
        for j in 0..k {
            wr[j] /= z;
        }
    }
}

/// Compute loss value and gradients w.r.t. the scores.
///
/// `pos[b]` — positive scores; `neg[b*k]` — negative scores laid out so
/// that negatives `i*k..(i+1)*k` belong to positive `i` (joint sampling
/// replicates the chunk's shared negatives per positive row).
///
/// Returns loss; writes `d_pos[b]`, `d_neg[b*k]`.
pub fn loss_and_grad(
    cfg: &LossCfg,
    pos: &[f32],
    neg: &[f32],
    k: usize,
    d_pos: &mut [f32],
    d_neg: &mut [f32],
) -> f32 {
    let b = pos.len();
    debug_assert_eq!(neg.len(), b * k);
    debug_assert_eq!(d_pos.len(), b);
    debug_assert_eq!(d_neg.len(), b * k);

    // negative weights: uniform 1/k per row, or adversarial softmax
    let mut w = vec![1.0f32 / k as f32; neg.len()];
    if let Some(alpha) = cfg.adv_temp {
        adv_weights(neg, k, alpha, &mut w);
    }

    match cfg.kind {
        LossKind::Logistic => {
            // L = (1/b)Σ softplus(−f⁺) + (1/b)Σ_i Σ_j w_ij softplus(f⁻_ij)
            let inv_b = 1.0 / b as f32;
            let mut loss = 0f32;
            for i in 0..b {
                loss += softplus(-pos[i]) * inv_b;
                d_pos[i] = -sigmoid(-pos[i]) * inv_b;
            }
            for i in 0..b {
                for j in 0..k {
                    let idx = i * k + j;
                    loss += w[idx] * softplus(neg[idx]) * inv_b;
                    d_neg[idx] = w[idx] * sigmoid(neg[idx]) * inv_b;
                }
            }
            loss
        }
        LossKind::Margin(gamma) => {
            // L = (1/b)Σ_i Σ_j w_ij max(0, γ − f⁺_i + f⁻_ij)
            let inv_b = 1.0 / b as f32;
            let mut loss = 0f32;
            d_pos.fill(0.0);
            for i in 0..b {
                for j in 0..k {
                    let idx = i * k + j;
                    let v = gamma - pos[i] + neg[idx];
                    if v > 0.0 {
                        loss += w[idx] * v * inv_b;
                        d_pos[i] -= w[idx] * inv_b;
                        d_neg[idx] = w[idx] * inv_b;
                    } else {
                        d_neg[idx] = 0.0;
                    }
                }
            }
            loss
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fd_check(cfg: LossCfg) {
        let b = 4;
        let k = 3;
        let mut rng = Rng::seed_from_u64(3);
        let pos: Vec<f32> = (0..b).map(|_| rng.gen_normal()).collect();
        let neg: Vec<f32> = (0..b * k).map(|_| rng.gen_normal()).collect();
        let mut dp = vec![0f32; b];
        let mut dn = vec![0f32; b * k];
        loss_and_grad(&cfg, &pos, &neg, k, &mut dp, &mut dn);

        let f = |pos: &[f32], neg: &[f32]| -> f64 {
            let mut a = vec![0f32; b];
            let mut c = vec![0f32; b * k];
            loss_and_grad(&cfg, pos, neg, k, &mut a, &mut c) as f64
        };
        let eps = 1e-3f32;
        for i in 0..b {
            let mut pp = pos.clone();
            pp[i] += eps;
            let mut pm = pos.clone();
            pm[i] -= eps;
            let fd = (f(&pp, &neg) - f(&pm, &neg)) / (2.0 * eps as f64);
            assert!((fd - dp[i] as f64).abs() < 1e-2, "{cfg:?} d_pos[{i}] fd={fd} got={}", dp[i]);
        }
        // adversarial weights are stop-gradient, so only check the
        // non-adversarial configs against finite differences of d_neg.
        if cfg.adv_temp.is_none() {
            for i in 0..b * k {
                let mut np = neg.clone();
                np[i] += eps;
                let mut nm = neg.clone();
                nm[i] -= eps;
                let fd = (f(&pos, &np) - f(&pos, &nm)) / (2.0 * eps as f64);
                assert!((fd - dn[i] as f64).abs() < 1e-2, "{cfg:?} d_neg[{i}]");
            }
        }
    }

    #[test]
    fn logistic_grads() {
        fd_check(LossCfg { kind: LossKind::Logistic, adv_temp: None });
    }

    #[test]
    fn margin_grads() {
        fd_check(LossCfg { kind: LossKind::Margin(1.0), adv_temp: None });
    }

    #[test]
    fn adversarial_pos_grads() {
        fd_check(LossCfg { kind: LossKind::Logistic, adv_temp: Some(1.0) });
    }

    #[test]
    fn adv_weights_sum_to_one() {
        let scores = [0.5f32, -1.0, 2.0, 0.0, 0.0, 0.0];
        let mut w = vec![0f32; 6];
        adv_weights(&scores, 3, 1.0, &mut w);
        for row in 0..2 {
            let s: f32 = w[row * 3..(row + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        // higher score → higher weight
        assert!(w[2] > w[0] && w[0] > w[1]);
        // uniform row → uniform weights
        assert!((w[3] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn perfect_scores_low_loss() {
        let cfg = LossCfg::default();
        let pos = [20.0f32; 4];
        let neg = [-20.0f32; 8];
        let mut dp = vec![0f32; 4];
        let mut dn = vec![0f32; 8];
        let l = loss_and_grad(&cfg, &pos, &neg, 2, &mut dp, &mut dn);
        assert!(l < 1e-6);
    }
}
