//! KGE model zoo (paper Table 1) — native Rust implementation.
//!
//! Every score function in the paper decomposes, per the paper's §3.3
//! trick, into
//!
//! 1. an **o-builder**: `o = g(h, r)` (tail-corruption form) or
//!    `o' = g'(t, r)` (head-corruption form), computed once per positive;
//! 2. an optional **negative projection** (TransR only: negatives must be
//!    multiplied by the per-positive projection matrix `M_r`);
//! 3. a generic **pairwise op** between `o` rows and candidate rows:
//!    `Dot` (DistMult/ComplEx/RESCAL), `SqDiff` = −‖o−n‖² (RotatE/TransR),
//!    `L2` = −‖o−n‖ (TransE-L2) or `L1` = −Σ|o−n| (TransE-L1).
//!
//! The JAX/Pallas layer (`python/compile/`) implements the *same*
//! decomposition, with the pairwise op as the Pallas kernel; this module
//! is the bit-level reference the artifacts are tested against, the CPU
//! fallback backend, and the scorer used by pure-coordinator benches.

pub mod builders;
pub mod kernels;
pub mod loss;
pub mod ops;
pub mod step;

pub use kernels::{EvalScratch, KernelBackend, KernelScratch, StepScratch};
pub use loss::{LossKind, LossCfg};
pub use step::{EvalSide, NativeModel, StepGrads, StepInputs};

pub const L2_EPS: f32 = 1e-12;

/// Subgradient of `|x|` at `x == 0`, used by the L1 backward pass.
/// Pinned to `0.0` (jax's `sign` convention) and shared by the scalar
/// reference and the fused kernels so the two paths cannot disagree at
/// kinks; `rust/tests/kernel_parity_tests.rs` pins the choice.
pub const L1_SIGN_AT_ZERO: f32 = 0.0;

/// The seven score functions of paper Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    TransEL1,
    TransEL2,
    TransR,
    DistMult,
    ComplEx,
    Rescal,
    RotatE,
}

/// Generic pairwise score between an `o` row and a candidate row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairwiseOp {
    /// f = o · n
    Dot,
    /// f = −‖o − n‖²
    SqDiff,
    /// f = −sqrt(‖o − n‖² + eps)
    L2,
    /// f = −Σ|o − n|
    L1,
}

impl ModelKind {
    pub const ALL: [ModelKind; 7] = [
        ModelKind::TransEL1,
        ModelKind::TransEL2,
        ModelKind::TransR,
        ModelKind::DistMult,
        ModelKind::ComplEx,
        ModelKind::Rescal,
        ModelKind::RotatE,
    ];

    pub fn parse(s: &str) -> Option<ModelKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "transe" | "transe_l2" => ModelKind::TransEL2,
            "transe_l1" => ModelKind::TransEL1,
            "transr" => ModelKind::TransR,
            "distmult" => ModelKind::DistMult,
            "complex" => ModelKind::ComplEx,
            "rescal" => ModelKind::Rescal,
            "rotate" => ModelKind::RotatE,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::TransEL1 => "transe_l1",
            ModelKind::TransEL2 => "transe_l2",
            ModelKind::TransR => "transr",
            ModelKind::DistMult => "distmult",
            ModelKind::ComplEx => "complex",
            ModelKind::Rescal => "rescal",
            ModelKind::RotatE => "rotate",
        }
    }

    /// Width of one relation-embedding row for entity dim `d`.
    /// TransR appends the d×d projection matrix to the d-dim translation
    /// vector; RESCAL's relation *is* the d×d matrix; RotatE stores d/2
    /// rotation phases.
    pub fn rel_dim(&self, d: usize) -> usize {
        match self {
            ModelKind::TransEL1 | ModelKind::TransEL2 | ModelKind::DistMult => d,
            ModelKind::ComplEx => d,
            ModelKind::RotatE => d / 2,
            ModelKind::Rescal => d * d,
            ModelKind::TransR => d + d * d,
        }
    }

    /// Entity dims must be even for the complex-valued models.
    pub fn validate_dim(&self, d: usize) -> bool {
        match self {
            ModelKind::ComplEx | ModelKind::RotatE => d % 2 == 0 && d >= 2,
            _ => d >= 1,
        }
    }

    pub fn pairwise_op(&self) -> PairwiseOp {
        match self {
            ModelKind::DistMult | ModelKind::ComplEx | ModelKind::Rescal => PairwiseOp::Dot,
            ModelKind::RotatE | ModelKind::TransR => PairwiseOp::SqDiff,
            ModelKind::TransEL2 => PairwiseOp::L2,
            ModelKind::TransEL1 => PairwiseOp::L1,
        }
    }

    /// Whether negatives must be projected through the per-positive
    /// relation matrix before the pairwise op (TransR only). This is the
    /// paper's §3.4 observation that TransR moves O(b·d²) of relation
    /// state per batch.
    pub fn projects_negatives(&self) -> bool {
        matches!(self, ModelKind::TransR)
    }

    /// Relative per-triplet FLOP weight (used by benches to normalize).
    pub fn flops_weight(&self, d: usize) -> f64 {
        match self {
            ModelKind::Rescal | ModelKind::TransR => d as f64, // extra matvec
            _ => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in ModelKind::ALL {
            assert_eq!(ModelKind::parse(m.name()), Some(m));
        }
        assert_eq!(ModelKind::parse("TransE"), Some(ModelKind::TransEL2));
        assert_eq!(ModelKind::parse("nope"), None);
    }

    #[test]
    fn rel_dims() {
        assert_eq!(ModelKind::TransEL2.rel_dim(8), 8);
        assert_eq!(ModelKind::RotatE.rel_dim(8), 4);
        assert_eq!(ModelKind::Rescal.rel_dim(8), 64);
        assert_eq!(ModelKind::TransR.rel_dim(8), 72);
    }

    #[test]
    fn dim_validation() {
        assert!(ModelKind::ComplEx.validate_dim(8));
        assert!(!ModelKind::ComplEx.validate_dim(7));
        assert!(ModelKind::TransEL1.validate_dim(7));
    }
}
