//! Generic pairwise score ops (forward + backward).
//!
//! `pairwise(op, o[m,d], n[k,d]) -> scores[m,k]` and its VJP. The `Dot`
//! and `SqDiff` paths are GEMM-shaped — these are exactly what the L1
//! Pallas kernel computes on the accelerator; the native versions here are
//! written as blocked loops that LLVM auto-vectorizes.

use super::PairwiseOp;
use super::L2_EPS;

/// scores[i*k + j] = op(o_i, n_j). `scores` must have len m*k.
pub fn pairwise_forward(op: PairwiseOp, o: &[f32], n: &[f32], d: usize, scores: &mut [f32]) {
    let m = o.len() / d;
    let k = n.len() / d;
    debug_assert_eq!(scores.len(), m * k);
    match op {
        PairwiseOp::Dot => {
            for i in 0..m {
                let oi = &o[i * d..(i + 1) * d];
                for j in 0..k {
                    let nj = &n[j * d..(j + 1) * d];
                    let mut s = 0f32;
                    for x in 0..d {
                        s += oi[x] * nj[x];
                    }
                    scores[i * k + j] = s;
                }
            }
        }
        PairwiseOp::SqDiff => {
            for i in 0..m {
                let oi = &o[i * d..(i + 1) * d];
                for j in 0..k {
                    let nj = &n[j * d..(j + 1) * d];
                    let mut s = 0f32;
                    for x in 0..d {
                        let diff = oi[x] - nj[x];
                        s += diff * diff;
                    }
                    scores[i * k + j] = -s;
                }
            }
        }
        PairwiseOp::L2 => {
            for i in 0..m {
                let oi = &o[i * d..(i + 1) * d];
                for j in 0..k {
                    let nj = &n[j * d..(j + 1) * d];
                    let mut s = 0f32;
                    for x in 0..d {
                        let diff = oi[x] - nj[x];
                        s += diff * diff;
                    }
                    scores[i * k + j] = -(s + L2_EPS).sqrt();
                }
            }
        }
        PairwiseOp::L1 => {
            for i in 0..m {
                let oi = &o[i * d..(i + 1) * d];
                for j in 0..k {
                    let nj = &n[j * d..(j + 1) * d];
                    let mut s = 0f32;
                    for x in 0..d {
                        s += (oi[x] - nj[x]).abs();
                    }
                    scores[i * k + j] = -s;
                }
            }
        }
    }
}

/// VJP of `pairwise_forward`: given upstream `d_scores[m,k]`, accumulate
/// into `d_o[m,d]` and `d_n[k,d]`. `scores` is the forward output (needed
/// by the L2 path to recover the norm).
pub fn pairwise_backward(
    op: PairwiseOp,
    o: &[f32],
    n: &[f32],
    d: usize,
    scores: &[f32],
    d_scores: &[f32],
    d_o: &mut [f32],
    d_n: &mut [f32],
) {
    let m = o.len() / d;
    let k = n.len() / d;
    debug_assert_eq!(d_scores.len(), m * k);
    match op {
        PairwiseOp::Dot => {
            // d_o_i += Σ_j g_ij n_j ; d_n_j += Σ_i g_ij o_i
            for i in 0..m {
                for j in 0..k {
                    let g = d_scores[i * k + j];
                    if g == 0.0 {
                        continue;
                    }
                    for x in 0..d {
                        d_o[i * d + x] += g * n[j * d + x];
                        d_n[j * d + x] += g * o[i * d + x];
                    }
                }
            }
        }
        PairwiseOp::SqDiff => {
            // f = -Σ(o-n)²: df/do = -2(o-n), df/dn = 2(o-n)
            for i in 0..m {
                for j in 0..k {
                    let g = d_scores[i * k + j];
                    if g == 0.0 {
                        continue;
                    }
                    for x in 0..d {
                        let diff = o[i * d + x] - n[j * d + x];
                        d_o[i * d + x] += -2.0 * g * diff;
                        d_n[j * d + x] += 2.0 * g * diff;
                    }
                }
            }
        }
        PairwiseOp::L2 => {
            // f = -sqrt(S+eps): df/do = -(o-n)/sqrt(S+eps) = (o-n)/f
            for i in 0..m {
                for j in 0..k {
                    let g = d_scores[i * k + j];
                    if g == 0.0 {
                        continue;
                    }
                    let norm = -scores[i * k + j]; // = sqrt(S+eps) > 0
                    let inv = 1.0 / norm;
                    for x in 0..d {
                        let diff = o[i * d + x] - n[j * d + x];
                        d_o[i * d + x] += -g * diff * inv;
                        d_n[j * d + x] += g * diff * inv;
                    }
                }
            }
        }
        PairwiseOp::L1 => {
            for i in 0..m {
                for j in 0..k {
                    let g = d_scores[i * k + j];
                    if g == 0.0 {
                        continue;
                    }
                    for x in 0..d {
                        let s = (o[i * d + x] - n[j * d + x]).signum();
                        // subgradient at the kink: see models::L1_SIGN_AT_ZERO
                        let s = if o[i * d + x] == n[j * d + x] {
                            super::L1_SIGN_AT_ZERO
                        } else {
                            s
                        };
                        d_o[i * d + x] += -g * s;
                        d_n[j * d + x] += g * s;
                    }
                }
            }
        }
    }
}

/// Diagonal variant: scores[i] = op(o_i, n_i) — used for positive triplets.
pub fn diag_forward(op: PairwiseOp, o: &[f32], n: &[f32], d: usize, scores: &mut [f32]) {
    let m = o.len() / d;
    let mut tmp = vec![0f32; 1];
    for i in 0..m {
        pairwise_forward(op, &o[i * d..(i + 1) * d], &n[i * d..(i + 1) * d], d, &mut tmp);
        scores[i] = tmp[0];
    }
}

/// VJP of `diag_forward`.
pub fn diag_backward(
    op: PairwiseOp,
    o: &[f32],
    n: &[f32],
    d: usize,
    scores: &[f32],
    d_scores: &[f32],
    d_o: &mut [f32],
    d_n: &mut [f32],
) {
    let m = o.len() / d;
    for i in 0..m {
        pairwise_backward(
            op,
            &o[i * d..(i + 1) * d],
            &n[i * d..(i + 1) * d],
            d,
            &scores[i..i + 1],
            &d_scores[i..i + 1],
            &mut d_o[i * d..(i + 1) * d],
            &mut d_n[i * d..(i + 1) * d],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn finite_diff_check(op: PairwiseOp) {
        let d = 6;
        let (m, k) = (3, 4);
        let mut rng = Rng::seed_from_u64(21);
        let o: Vec<f32> = (0..m * d).map(|_| rng.gen_normal()).collect();
        let n: Vec<f32> = (0..k * d).map(|_| rng.gen_normal()).collect();
        let mut scores = vec![0f32; m * k];
        pairwise_forward(op, &o, &n, d, &mut scores);

        // random upstream gradient
        let g: Vec<f32> = (0..m * k).map(|_| rng.gen_normal()).collect();
        let mut d_o = vec![0f32; m * d];
        let mut d_n = vec![0f32; k * d];
        pairwise_backward(op, &o, &n, d, &scores, &g, &mut d_o, &mut d_n);

        let loss = |o: &[f32], n: &[f32]| -> f64 {
            let mut s = vec![0f32; m * k];
            pairwise_forward(op, o, n, d, &mut s);
            s.iter().zip(&g).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let eps = 1e-3f32;
        for idx in 0..m * d {
            let mut op_ = o.clone();
            op_[idx] += eps;
            let mut om = o.clone();
            om[idx] -= eps;
            let fd = (loss(&op_, &n) - loss(&om, &n)) / (2.0 * eps as f64);
            assert!(
                (fd - d_o[idx] as f64).abs() < 2e-2,
                "{op:?} d_o[{idx}]: fd={fd} got={}",
                d_o[idx]
            );
        }
        for idx in 0..k * d {
            let mut np_ = n.to_vec();
            np_[idx] += eps;
            let mut nm = n.to_vec();
            nm[idx] -= eps;
            let fd = (loss(&o, &np_) - loss(&o, &nm)) / (2.0 * eps as f64);
            assert!(
                (fd - d_n[idx] as f64).abs() < 2e-2,
                "{op:?} d_n[{idx}]: fd={fd} got={}",
                d_n[idx]
            );
        }
    }

    #[test]
    fn grad_dot() {
        finite_diff_check(PairwiseOp::Dot);
    }

    #[test]
    fn grad_sqdiff() {
        finite_diff_check(PairwiseOp::SqDiff);
    }

    #[test]
    fn grad_l2() {
        finite_diff_check(PairwiseOp::L2);
    }

    #[test]
    fn grad_l1() {
        // L1 is piecewise linear; finite differences still valid away from
        // kinks, which random normals avoid w.p. 1.
        finite_diff_check(PairwiseOp::L1);
    }

    #[test]
    fn dot_matches_manual() {
        let o = [1.0, 2.0, 3.0, 4.0]; // 2x2
        let n = [1.0, 0.0, 0.0, 1.0]; // 2x2
        let mut s = vec![0f32; 4];
        pairwise_forward(PairwiseOp::Dot, &o, &n, 2, &mut s);
        assert_eq!(s, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn diag_matches_pairwise_diagonal() {
        let d = 4;
        let m = 3;
        let mut rng = Rng::seed_from_u64(5);
        let o: Vec<f32> = (0..m * d).map(|_| rng.gen_normal()).collect();
        let n: Vec<f32> = (0..m * d).map(|_| rng.gen_normal()).collect();
        for op in [PairwiseOp::Dot, PairwiseOp::SqDiff, PairwiseOp::L2, PairwiseOp::L1] {
            let mut full = vec![0f32; m * m];
            pairwise_forward(op, &o, &n, d, &mut full);
            let mut diag = vec![0f32; m];
            diag_forward(op, &o, &n, d, &mut diag);
            for i in 0..m {
                assert_eq!(diag[i], full[i * m + i]);
            }
        }
    }
}
