//! Full native train step (forward + backward) and eval scoring.
//!
//! This mirrors the AOT-compiled artifact contract exactly (DESIGN.md
//! §Artifact contract): inputs are the *gathered* embeddings of a
//! mini-batch under joint negative sampling; outputs are the loss and the
//! gradients w.r.t. those gathered embeddings. The coordinator owns
//! gather/scatter and the optimizer.

use super::builders::{build_o, build_o_backward, project_negs, project_negs_backward, Side};
use super::kernels::{zeroed, EvalScratch, KernelBackend, StepScratch};
use super::loss::{loss_and_grad, LossCfg};
use super::ModelKind;

/// Shapes of one training step: B = nc·cs positives, each chunk of cs
/// positives shares k tail-corruption negatives and k head-corruption
/// negatives (paper §3.3 joint sampling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepShape {
    pub batch: usize,
    pub chunks: usize,
    pub neg_k: usize,
    pub dim: usize,
}

impl StepShape {
    pub fn chunk_size(&self) -> usize {
        debug_assert_eq!(self.batch % self.chunks, 0);
        self.batch / self.chunks
    }
}

/// Borrowed gathered embeddings for one step.
pub struct StepInputs<'a> {
    /// positive head embeddings [B, D]
    pub h: &'a [f32],
    /// positive relation rows [B, RD]
    pub r: &'a [f32],
    /// positive tail embeddings [B, D]
    pub t: &'a [f32],
    /// head-corruption negatives [nc, K, D]
    pub neg_h: &'a [f32],
    /// tail-corruption negatives [nc, K, D]
    pub neg_t: &'a [f32],
}

/// Gradients w.r.t. the gathered embeddings (same shapes as inputs).
#[derive(Clone, Debug, Default)]
pub struct StepGrads {
    pub loss: f32,
    pub d_h: Vec<f32>,
    pub d_r: Vec<f32>,
    pub d_t: Vec<f32>,
    pub d_neg_h: Vec<f32>,
    pub d_neg_t: Vec<f32>,
}

/// Which side an eval scoring pass corrupts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalSide {
    Tail,
    Head,
}

/// Native (pure Rust) implementation of a KGE model step. Stateless apart
/// from configuration; safe to share across threads.
#[derive(Clone, Debug)]
pub struct NativeModel {
    pub kind: ModelKind,
    pub dim: usize,
    pub loss: LossCfg,
}

impl NativeModel {
    pub fn new(kind: ModelKind, dim: usize, loss: LossCfg) -> Self {
        assert!(kind.validate_dim(dim), "{kind:?} requires even dim, got {dim}");
        NativeModel { kind, dim, loss }
    }

    pub fn rel_dim(&self) -> usize {
        self.kind.rel_dim(self.dim)
    }

    /// Forward+backward of one mini-batch with the scalar reference
    /// kernels and a throwaway scratch arena. Convenience wrapper around
    /// [`NativeModel::train_step_with`] for tests and cold paths; the
    /// training workers hold a per-worker [`StepScratch`] and select the
    /// kernel backend from the spec.
    pub fn train_step(&self, shape: &StepShape, inp: &StepInputs<'_>) -> StepGrads {
        self.train_step_with(shape, inp, KernelBackend::Scalar, &mut StepScratch::default())
    }

    /// Forward+backward of one mini-batch. See module docs for layout.
    ///
    /// `kb` selects the pairwise kernels (scalar reference vs fused —
    /// results are bit-identical, see `docs/KERNELS.md`); `scratch` is the
    /// per-worker arena replacing every per-call `vec![0f32; ..]` on the
    /// hot path. Only the returned [`StepGrads`] buffers are allocated
    /// here.
    pub fn train_step_with(
        &self,
        shape: &StepShape,
        inp: &StepInputs<'_>,
        kb: KernelBackend,
        scratch: &mut StepScratch,
    ) -> StepGrads {
        let d = self.dim;
        let rd = self.rel_dim();
        let b = shape.batch;
        let nc = shape.chunks;
        let cs = shape.chunk_size();
        let k = shape.neg_k;
        let op = self.kind.pairwise_op();
        debug_assert_eq!(inp.h.len(), b * d);
        debug_assert_eq!(inp.r.len(), b * rd);
        debug_assert_eq!(inp.t.len(), b * d);
        debug_assert_eq!(inp.neg_h.len(), nc * k * d);
        debug_assert_eq!(inp.neg_t.len(), nc * k * d);

        // ---- forward ----
        let o_tail = zeroed(&mut scratch.o_tail, b * d);
        build_o(self.kind, Side::Tail, inp.h, inp.r, d, o_tail);
        let o_head = zeroed(&mut scratch.o_head, b * d);
        build_o(self.kind, Side::Head, inp.t, inp.r, d, o_head);

        // positives: pairwise(o_tail_i, proj_i(t_i))
        let projecting = self.kind.projects_negatives();
        let proj_t = zeroed(&mut scratch.proj_t, if projecting { b * d } else { 0 });
        if projecting {
            for i in 0..b {
                project_negs(
                    self.kind,
                    &inp.r[i * rd..(i + 1) * rd],
                    &inp.t[i * d..(i + 1) * d],
                    d,
                    &mut proj_t[i * d..(i + 1) * d],
                );
            }
        }
        let t_eff: &[f32] = if projecting { proj_t } else { inp.t };
        let pos = zeroed(&mut scratch.pos, b);
        kb.diag_forward(op, o_tail, t_eff, d, pos);

        // negatives: per chunk, pairwise(o rows, negs). TransR projects the
        // chunk negatives per positive row.
        // proj_neg_t[c] layout: [cs, k, d] when projecting, else unused.
        let neg_scores = zeroed(&mut scratch.neg_scores, b * 2 * k); // [B, 2K]: tail then head
        let proj_negs_t = zeroed(&mut scratch.proj_negs_t, if projecting { b * k * d } else { 0 });
        let proj_negs_h = zeroed(&mut scratch.proj_negs_h, if projecting { b * k * d } else { 0 });
        let s_row = zeroed(&mut scratch.row_k, k); // per-row scores (projecting path)
        let s_chunk = zeroed(&mut scratch.chunk_s, cs * k); // chunk scores (GEMM path)
        for c in 0..nc {
            let rows = c * cs..(c + 1) * cs;
            let nt = &inp.neg_t[c * k * d..(c + 1) * k * d];
            let nh = &inp.neg_h[c * k * d..(c + 1) * k * d];
            if projecting {
                for i in rows.clone() {
                    let r_row = &inp.r[i * rd..(i + 1) * rd];
                    let pt = &mut proj_negs_t[i * k * d..(i + 1) * k * d];
                    project_negs(self.kind, r_row, nt, d, pt);
                    kb.forward(op, &o_tail[i * d..(i + 1) * d], pt, d, s_row, &mut scratch.kernel);
                    neg_scores[i * 2 * k..i * 2 * k + k].copy_from_slice(s_row);
                    let ph = &mut proj_negs_h[i * k * d..(i + 1) * k * d];
                    project_negs(self.kind, r_row, nh, d, ph);
                    kb.forward(op, &o_head[i * d..(i + 1) * d], ph, d, s_row, &mut scratch.kernel);
                    neg_scores[i * 2 * k + k..(i + 1) * 2 * k].copy_from_slice(s_row);
                }
            } else {
                // chunk-level GEMM-shaped pairwise
                kb.forward(
                    op,
                    &o_tail[rows.start * d..rows.end * d],
                    nt,
                    d,
                    s_chunk,
                    &mut scratch.kernel,
                );
                for (li, i) in rows.clone().enumerate() {
                    neg_scores[i * 2 * k..i * 2 * k + k]
                        .copy_from_slice(&s_chunk[li * k..(li + 1) * k]);
                }
                kb.forward(
                    op,
                    &o_head[rows.start * d..rows.end * d],
                    nh,
                    d,
                    s_chunk,
                    &mut scratch.kernel,
                );
                for (li, i) in rows.clone().enumerate() {
                    neg_scores[i * 2 * k + k..(i + 1) * 2 * k]
                        .copy_from_slice(&s_chunk[li * k..(li + 1) * k]);
                }
            }
        }

        // ---- loss ----
        let d_pos = zeroed(&mut scratch.d_pos, b);
        let d_neg = zeroed(&mut scratch.d_neg, b * 2 * k);
        let loss = loss_and_grad(&self.loss, pos, neg_scores, 2 * k, d_pos, d_neg);

        // ---- backward ----
        let mut g = StepGrads {
            loss,
            d_h: vec![0f32; b * d],
            d_r: vec![0f32; b * rd],
            d_t: vec![0f32; b * d],
            d_neg_h: vec![0f32; nc * k * d],
            d_neg_t: vec![0f32; nc * k * d],
        };
        let d_o_tail = zeroed(&mut scratch.d_o_tail, b * d);
        let d_o_head = zeroed(&mut scratch.d_o_head, b * d);

        // positives → d_o_tail, d_t (through projection if TransR)
        {
            let d_t_eff = zeroed(&mut scratch.d_t_eff, b * d);
            kb.diag_backward(op, o_tail, t_eff, d, pos, d_pos, d_o_tail, d_t_eff);
            if projecting {
                for i in 0..b {
                    project_negs_backward(
                        self.kind,
                        &inp.r[i * rd..(i + 1) * rd],
                        &inp.t[i * d..(i + 1) * d],
                        d,
                        &d_t_eff[i * d..(i + 1) * d],
                        &mut g.d_t[i * d..(i + 1) * d],
                        &mut g.d_r[i * rd..(i + 1) * rd],
                    );
                }
            } else {
                g.d_t.copy_from_slice(&d_t_eff);
            }
        }

        // negatives
        for c in 0..nc {
            let rows = c * cs..(c + 1) * cs;
            let nt = &inp.neg_t[c * k * d..(c + 1) * k * d];
            let nh = &inp.neg_h[c * k * d..(c + 1) * k * d];
            if projecting {
                for i in rows.clone() {
                    let r_row = &inp.r[i * rd..(i + 1) * rd];
                    // tail side
                    let pt = &proj_negs_t[i * k * d..(i + 1) * k * d];
                    let st = &neg_scores[i * 2 * k..i * 2 * k + k];
                    let gt = &d_neg[i * 2 * k..i * 2 * k + k];
                    let d_pt = zeroed(&mut scratch.d_pt, k * d);
                    kb.backward(
                        op,
                        &o_tail[i * d..(i + 1) * d],
                        pt,
                        d,
                        st,
                        gt,
                        &mut d_o_tail[i * d..(i + 1) * d],
                        d_pt,
                    );
                    project_negs_backward(
                        self.kind,
                        r_row,
                        nt,
                        d,
                        d_pt,
                        &mut g.d_neg_t[c * k * d..(c + 1) * k * d],
                        &mut g.d_r[i * rd..(i + 1) * rd],
                    );
                    // head side
                    let ph = &proj_negs_h[i * k * d..(i + 1) * k * d];
                    let sh = &neg_scores[i * 2 * k + k..(i + 1) * 2 * k];
                    let gh = &d_neg[i * 2 * k + k..(i + 1) * 2 * k];
                    let d_ph = zeroed(&mut scratch.d_ph, k * d);
                    kb.backward(
                        op,
                        &o_head[i * d..(i + 1) * d],
                        ph,
                        d,
                        sh,
                        gh,
                        &mut d_o_head[i * d..(i + 1) * d],
                        d_ph,
                    );
                    project_negs_backward(
                        self.kind,
                        r_row,
                        nh,
                        d,
                        d_ph,
                        &mut g.d_neg_h[c * k * d..(c + 1) * k * d],
                        &mut g.d_r[i * rd..(i + 1) * rd],
                    );
                }
            } else {
                // reassemble chunk score/grad blocks [cs,k]
                let st = zeroed(&mut scratch.st, cs * k);
                let gt = zeroed(&mut scratch.gt, cs * k);
                let sh = zeroed(&mut scratch.sh, cs * k);
                let gh = zeroed(&mut scratch.gh, cs * k);
                for (li, i) in rows.clone().enumerate() {
                    st[li * k..(li + 1) * k]
                        .copy_from_slice(&neg_scores[i * 2 * k..i * 2 * k + k]);
                    gt[li * k..(li + 1) * k].copy_from_slice(&d_neg[i * 2 * k..i * 2 * k + k]);
                    sh[li * k..(li + 1) * k]
                        .copy_from_slice(&neg_scores[i * 2 * k + k..(i + 1) * 2 * k]);
                    gh[li * k..(li + 1) * k]
                        .copy_from_slice(&d_neg[i * 2 * k + k..(i + 1) * 2 * k]);
                }
                kb.backward(
                    op,
                    &o_tail[rows.start * d..rows.end * d],
                    nt,
                    d,
                    st,
                    gt,
                    &mut d_o_tail[rows.start * d..rows.end * d],
                    &mut g.d_neg_t[c * k * d..(c + 1) * k * d],
                );
                kb.backward(
                    op,
                    &o_head[rows.start * d..rows.end * d],
                    nh,
                    d,
                    sh,
                    gh,
                    &mut d_o_head[rows.start * d..rows.end * d],
                    &mut g.d_neg_h[c * k * d..(c + 1) * k * d],
                );
            }
        }

        // o builders
        build_o_backward(self.kind, Side::Tail, inp.h, inp.r, d, &d_o_tail, &mut g.d_h, &mut g.d_r);
        build_o_backward(self.kind, Side::Head, inp.t, inp.r, d, &d_o_head, &mut g.d_t, &mut g.d_r);
        g
    }

    /// Score `m` (entity, relation) pairs against `c` candidate entities.
    /// For `EvalSide::Tail`, `e` holds the positive heads and candidates
    /// are tails; for `EvalSide::Head`, `e` holds the positive tails and
    /// candidates are heads. Writes `scores[m, c]`.
    pub fn eval_scores(
        &self,
        side: EvalSide,
        e: &[f32],
        r: &[f32],
        cand: &[f32],
        scores: &mut [f32],
    ) {
        self.eval_scores_with(
            side,
            e,
            r,
            cand,
            scores,
            KernelBackend::Scalar,
            &mut EvalScratch::default(),
        );
    }

    /// [`NativeModel::eval_scores`] with an explicit kernel backend and a
    /// reusable per-thread scratch arena: the `o` query rows and the
    /// TransR projected-candidate buffer persist across calls instead of
    /// being reallocated per scoring block.
    pub fn eval_scores_with(
        &self,
        side: EvalSide,
        e: &[f32],
        r: &[f32],
        cand: &[f32],
        scores: &mut [f32],
        kb: KernelBackend,
        scratch: &mut EvalScratch,
    ) {
        let d = self.dim;
        let rd = self.rel_dim();
        let m = e.len() / d;
        let c = cand.len() / d;
        debug_assert_eq!(scores.len(), m * c);
        let op = self.kind.pairwise_op();
        let o = zeroed(&mut scratch.o, m * d);
        self.build_query(side, e, r, o);
        if self.kind.projects_negatives() {
            let pc = zeroed(&mut scratch.pc, c * d);
            for i in 0..m {
                project_negs(self.kind, &r[i * rd..(i + 1) * rd], cand, d, pc);
                kb.forward(
                    op,
                    &o[i * d..(i + 1) * d],
                    pc,
                    d,
                    &mut scores[i * c..(i + 1) * c],
                    &mut scratch.kernel,
                );
            }
        } else {
            kb.forward(op, o, cand, d, scores, &mut scratch.kernel);
        }
    }

    /// Build the `o = g(e, r)` query rows for eval scoring without scoring
    /// anything. The fused gather→score eval path builds the query once
    /// per (triplet, side) and streams candidate rows through
    /// `kernels::gather_scores` instead of staging a scoring block.
    pub fn build_query(&self, side: EvalSide, e: &[f32], r: &[f32], o: &mut [f32]) {
        let bside = match side {
            EvalSide::Tail => Side::Tail,
            EvalSide::Head => Side::Head,
        };
        build_o(self.kind, bside, e, r, self.dim, o);
    }

    /// Score a single triplet (used by tests and spot checks).
    pub fn score_one(&self, h: &[f32], r: &[f32], t: &[f32]) -> f32 {
        let mut s = vec![0f32; 1];
        self.eval_scores(EvalSide::Tail, h, r, t, &mut s);
        s[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LossKind;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gen_normal() * 0.5).collect()
    }

    fn shape() -> StepShape {
        StepShape { batch: 8, chunks: 2, neg_k: 3, dim: 6 }
    }

    fn make_inputs(rng: &mut Rng, kind: ModelKind, s: &StepShape) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let rd = kind.rel_dim(s.dim);
        (
            rand_vec(rng, s.batch * s.dim),
            rand_vec(rng, s.batch * rd),
            rand_vec(rng, s.batch * s.dim),
            rand_vec(rng, s.chunks * s.neg_k * s.dim),
            rand_vec(rng, s.chunks * s.neg_k * s.dim),
        )
    }

    /// Finite-difference check of the whole step for every model.
    #[test]
    fn train_step_gradients_all_models() {
        let s = shape();
        for kind in ModelKind::ALL {
            let model = NativeModel::new(kind, s.dim, LossCfg::default());
            let mut rng = Rng::seed_from_u64(kind as u64 + 100);
            let (h, r, t, nh, nt) = make_inputs(&mut rng, kind, &s);
            let inp = StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt };
            let g = model.train_step(&s, &inp);

            let eval = |h: &[f32], r: &[f32], t: &[f32], nh: &[f32], nt: &[f32]| -> f64 {
                model
                    .train_step(&s, &StepInputs { h, r, t, neg_h: nh, neg_t: nt })
                    .loss as f64
            };
            let eps = 1e-2f32;
            let tol = 5e-3;
            // spot-check a few coordinates of each gradient tensor
            for idx in [0usize, 7, s.batch * s.dim - 1] {
                let mut p = h.clone();
                p[idx] += eps;
                let mut m = h.clone();
                m[idx] -= eps;
                let fd = (eval(&p, &r, &t, &nh, &nt) - eval(&m, &r, &t, &nh, &nt)) / (2.0 * eps as f64);
                assert!((fd - g.d_h[idx] as f64).abs() < tol, "{kind:?} d_h[{idx}] fd={fd} got={}", g.d_h[idx]);
            }
            for idx in [0usize, r.len() / 2, r.len() - 1] {
                let mut p = r.clone();
                p[idx] += eps;
                let mut m = r.clone();
                m[idx] -= eps;
                let fd = (eval(&h, &p, &t, &nh, &nt) - eval(&h, &m, &t, &nh, &nt)) / (2.0 * eps as f64);
                assert!((fd - g.d_r[idx] as f64).abs() < tol, "{kind:?} d_r[{idx}] fd={fd} got={}", g.d_r[idx]);
            }
            for idx in [1usize, s.batch * s.dim - 2] {
                let mut p = t.clone();
                p[idx] += eps;
                let mut m = t.clone();
                m[idx] -= eps;
                let fd = (eval(&h, &r, &p, &nh, &nt) - eval(&h, &r, &m, &nh, &nt)) / (2.0 * eps as f64);
                assert!((fd - g.d_t[idx] as f64).abs() < tol, "{kind:?} d_t[{idx}] fd={fd} got={}", g.d_t[idx]);
            }
            for idx in [0usize, nh.len() - 1] {
                let mut p = nh.clone();
                p[idx] += eps;
                let mut m = nh.clone();
                m[idx] -= eps;
                let fd = (eval(&h, &r, &t, &p, &nt) - eval(&h, &r, &t, &m, &nt)) / (2.0 * eps as f64);
                assert!((fd - g.d_neg_h[idx] as f64).abs() < tol, "{kind:?} d_neg_h[{idx}]");
                let mut p = nt.clone();
                p[idx] += eps;
                let mut m = nt.clone();
                m[idx] -= eps;
                let fd = (eval(&h, &r, &t, &nh, &p) - eval(&h, &r, &t, &nh, &m)) / (2.0 * eps as f64);
                assert!((fd - g.d_neg_t[idx] as f64).abs() < tol, "{kind:?} d_neg_t[{idx}]");
            }
        }
    }

    /// Margin loss path also differentiates cleanly.
    #[test]
    fn train_step_margin_loss() {
        let s = shape();
        let model = NativeModel::new(
            ModelKind::TransEL2,
            s.dim,
            LossCfg { kind: LossKind::Margin(1.0), adv_temp: None },
        );
        let mut rng = Rng::seed_from_u64(7);
        let (h, r, t, nh, nt) = make_inputs(&mut rng, ModelKind::TransEL2, &s);
        let inp = StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt };
        let g = model.train_step(&s, &inp);
        assert!(g.loss > 0.0);
        let eval = |h: &[f32]| -> f64 {
            model.train_step(&s, &StepInputs { h, r: &r, t: &t, neg_h: &nh, neg_t: &nt }).loss as f64
        };
        let eps = 1e-2f32;
        let idx = 3;
        let mut p = h.clone();
        p[idx] += eps;
        let mut m = h.clone();
        m[idx] -= eps;
        let fd = (eval(&p) - eval(&m)) / (2.0 * eps as f64);
        assert!((fd - g.d_h[idx] as f64).abs() < 5e-3);
    }

    /// eval_scores tail-side must agree with the direct per-triplet score.
    #[test]
    fn eval_matches_train_decomposition() {
        let d = 8;
        for kind in ModelKind::ALL {
            let model = NativeModel::new(kind, d, LossCfg::default());
            let mut rng = Rng::seed_from_u64(kind as u64 + 11);
            let rd = kind.rel_dim(d);
            let h = rand_vec(&mut rng, d);
            let r = rand_vec(&mut rng, rd);
            let t = rand_vec(&mut rng, d);
            let tail = model.score_one(&h, &r, &t);
            // head-side scoring of the same triplet must agree
            let mut s = vec![0f32; 1];
            model.eval_scores(EvalSide::Head, &t, &r, &h, &mut s);
            assert!((tail - s[0]).abs() < 1e-4, "{kind:?} tail={tail} head={}", s[0]);
        }
    }

    /// The fused kernels must produce a bit-identical step (loss and every
    /// gradient tensor) for every model, with the scratch arena reused
    /// across models to stress checkout re-zeroing.
    #[test]
    fn train_step_fused_bit_matches_scalar() {
        use crate::models::kernels::{KernelBackend, StepScratch};
        use crate::util::ulp::max_ulp_distance;
        let s = shape();
        let mut scratch = StepScratch::default();
        for kind in ModelKind::ALL {
            let model = NativeModel::new(kind, s.dim, LossCfg::default());
            let mut rng = Rng::seed_from_u64(kind as u64 + 900);
            let (h, r, t, nh, nt) = make_inputs(&mut rng, kind, &s);
            let inp = StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt };
            let a = model.train_step(&s, &inp);
            let b = model.train_step_with(&s, &inp, KernelBackend::Fused, &mut scratch);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{kind:?} loss");
            for (name, x, y) in [
                ("d_h", &a.d_h, &b.d_h),
                ("d_r", &a.d_r, &b.d_r),
                ("d_t", &a.d_t, &b.d_t),
                ("d_neg_h", &a.d_neg_h, &b.d_neg_h),
                ("d_neg_t", &a.d_neg_t, &b.d_neg_t),
            ] {
                assert_eq!(max_ulp_distance(x, y), 0, "{kind:?} {name}");
            }
        }
    }

    /// Training on a toy problem must reduce the loss (end-to-end sanity
    /// of gradient direction).
    #[test]
    fn sgd_reduces_loss() {
        let s = StepShape { batch: 16, chunks: 4, neg_k: 8, dim: 8 };
        for kind in [ModelKind::TransEL2, ModelKind::DistMult, ModelKind::RotatE] {
            let model = NativeModel::new(kind, s.dim, LossCfg::default());
            let mut rng = Rng::seed_from_u64(5);
            let rd = kind.rel_dim(s.dim);
            let mut h = rand_vec(&mut rng, s.batch * s.dim);
            let mut r = rand_vec(&mut rng, s.batch * rd);
            let mut t = rand_vec(&mut rng, s.batch * s.dim);
            let mut nh = rand_vec(&mut rng, s.chunks * s.neg_k * s.dim);
            let mut nt = rand_vec(&mut rng, s.chunks * s.neg_k * s.dim);
            let first = model
                .train_step(&s, &StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt })
                .loss;
            let mut last = first;
            for _ in 0..200 {
                let g = model
                    .train_step(&s, &StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt });
                let lr = 0.5f32;
                for (x, dx) in h.iter_mut().zip(&g.d_h) {
                    *x -= lr * dx;
                }
                for (x, dx) in r.iter_mut().zip(&g.d_r) {
                    *x -= lr * dx;
                }
                for (x, dx) in t.iter_mut().zip(&g.d_t) {
                    *x -= lr * dx;
                }
                for (x, dx) in nh.iter_mut().zip(&g.d_neg_h) {
                    *x -= lr * dx;
                }
                for (x, dx) in nt.iter_mut().zip(&g.d_neg_t) {
                    *x -= lr * dx;
                }
                last = g.loss;
            }
            assert!(last < first * 0.7, "{kind:?}: loss {first} -> {last}");
        }
    }
}
