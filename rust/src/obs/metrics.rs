//! Process-wide metrics registry: named counters, gauges, and log-2
//! histograms behind cheap cloneable handles.
//!
//! Design:
//!
//! * A handle owns its own atomic cell(s); constructing one through
//!   [`Registry::counter`] / [`Registry::gauge`] /
//!   [`Registry::histogram`] registers the cell under a dotted name.
//!   Several instances may register the same name (one `CachedStore`
//!   per run, a `NetLedger` per client); [`Registry::snapshot`] sums
//!   same-named cells, while each owner keeps reading its private cell
//!   for per-instance reports — exactly the semantics the old ad-hoc
//!   struct counters had, so converting them is behavior-preserving.
//! * All cell traffic is `Ordering::Relaxed`: metrics are statistics,
//!   never data publication (relaxed-allowlist.toml; audit table in
//!   docs/CONCURRENCY.md). Nothing may branch on a metric to decide
//!   data visibility.
//! * Zero dependencies; snapshots serialize through `util::json` and
//!   round-trip losslessly ([`Snapshot::from_json`]), which is how they
//!   ride inside `api::Report` and `dglke … --metrics-out FILE`.
//!
//! Naming scheme is `<area>.<object>.<stat>` (`store.cache.hits`,
//! `kv.net.remote_bytes`, `serve.score_ns`); the catalog lives in
//! docs/OBSERVABILITY.md. Histograms bucket by bit width (bucket 0
//! holds exactly 0; bucket `b >= 1` holds `2^(b-1) ..= 2^b - 1`), so
//! one 65-slot array spans the full `u64` range with ~2x resolution —
//! coarse, but allocation-free and mergeable by addition.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Number of histogram buckets: one per possible bit width of a `u64`,
/// plus bucket 0 for the value zero.
pub const HISTO_BUCKETS: usize = 65;

/// Bucket index for a recorded value: its bit width (0 for 0).
pub fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Inclusive `(lo, hi)` value range covered by bucket `b`.
pub fn bucket_bounds(b: usize) -> (u64, u64) {
    match b {
        0 => (0, 0),
        64 => (1 << 63, u64::MAX),
        _ => (1 << (b - 1), (1 << b) - 1),
    }
}

/// Monotonically increasing count.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// An unregistered counter (for tests / default-constructed structs).
    pub fn detached() -> Counter {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A level that can move both ways (e.g. cache resident rows).
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn detached() -> Gauge {
        Gauge(Arc::new(AtomicU64::new(0)))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: u64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-2-bucketed histogram cells shared by a [`Histogram`] handle and
/// the registry.
#[derive(Debug)]
pub struct HistoCells {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl HistoCells {
    fn new() -> HistoCells {
        HistoCells {
            buckets: (0..HISTO_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = Vec::new();
        for (b, cell) in self.buckets.iter().enumerate() {
            let n = cell.load(Ordering::Relaxed);
            if n > 0 {
                buckets.push((b, n));
            }
        }
        HistogramSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Distribution of recorded values (typically durations in ns).
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistoCells>);

impl Histogram {
    pub fn detached() -> Histogram {
        Histogram(Arc::new(HistoCells::new()))
    }

    pub fn record(&self, v: u64) {
        self.record_n(v, 1);
    }

    /// Record `v` with multiplicity `n` (e.g. a per-query time applied
    /// to every query of a batch) at the cost of one bucket update.
    pub fn record_n(&self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.0.buckets[bucket_of(v)].fetch_add(n, Ordering::Relaxed);
        self.0.count.fetch_add(n, Ordering::Relaxed);
        self.0.sum.fetch_add(v.saturating_mul(n), Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        self.0.snapshot()
    }
}

enum Cell {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histogram(Arc<HistoCells>),
}

/// The registry proper: a name -> cell multimap. Registration is rare
/// (struct construction); the handles never touch the lock again.
pub struct Registry {
    inner: Mutex<Vec<(String, Cell)>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry { inner: Mutex::new(Vec::new()) }
    }

    fn entries(&self) -> MutexGuard<'_, Vec<(String, Cell)>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn counter(&self, name: &str) -> Counter {
        let c = Counter::detached();
        self.entries().push((name.to_string(), Cell::Counter(c.0.clone())));
        c
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let g = Gauge::detached();
        self.entries().push((name.to_string(), Cell::Gauge(g.0.clone())));
        g
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        let h = Histogram::detached();
        self.entries().push((name.to_string(), Cell::Histogram(h.0.clone())));
        h
    }

    /// Sum every registered cell by name. Cumulative over the process
    /// lifetime — per-run deltas belong to the owning structs, which
    /// keep their own handles.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = Snapshot::default();
        for (name, cell) in self.entries().iter() {
            match cell {
                Cell::Counter(c) => {
                    *snap.counters.entry(name.clone()).or_insert(0) +=
                        c.load(Ordering::Relaxed);
                }
                Cell::Gauge(g) => {
                    *snap.gauges.entry(name.clone()).or_insert(0) +=
                        g.load(Ordering::Relaxed);
                }
                Cell::Histogram(h) => {
                    snap.histograms
                        .entry(name.clone())
                        .or_insert_with(HistogramSnapshot::default)
                        .merge(&h.snapshot());
                }
            }
        }
        snap
    }
}

/// The process-wide registry instance every subsystem registers into.
pub fn global() -> &'static Registry {
    static GLOBAL: std::sync::OnceLock<Registry> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

// ------------------------------------------------------------- snapshot

#[derive(Debug, Default, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    /// Sparse `(bucket, count)` pairs, ascending by bucket.
    pub buckets: Vec<(usize, u64)>,
}

impl HistogramSnapshot {
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        let mut merged: BTreeMap<usize, u64> = self.buckets.iter().copied().collect();
        for &(b, n) in &other.buckets {
            *merged.entry(b).or_insert(0) += n;
        }
        self.buckets = merged.into_iter().collect();
    }

    /// The p-quantile (`0.0 ..= 1.0`) as the upper bound of the bucket
    /// holding the target rank — a conservative (never-understated)
    /// latency figure with log-2 resolution.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0);
        let mut cum = 0.0;
        for &(b, n) in &self.buckets {
            cum += n as f64;
            if cum >= target {
                return bucket_bounds(b).1 as f64;
            }
        }
        bucket_bounds(HISTO_BUCKETS - 1).1 as f64
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// A point-in-time reading of the whole registry, JSON round-trippable.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

impl Snapshot {
    pub fn to_json(&self) -> Json {
        let num_map = |m: &BTreeMap<String, u64>| {
            Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect())
        };
        let histos = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    let buckets = Json::Arr(
                        h.buckets
                            .iter()
                            .map(|&(b, n)| {
                                Json::Arr(vec![Json::Num(b as f64), Json::Num(n as f64)])
                            })
                            .collect(),
                    );
                    (
                        k.clone(),
                        obj(vec![
                            ("count", Json::Num(h.count as f64)),
                            ("sum", Json::Num(h.sum as f64)),
                            ("buckets", buckets),
                        ]),
                    )
                })
                .collect(),
        );
        obj(vec![
            ("counters", num_map(&self.counters)),
            ("gauges", num_map(&self.gauges)),
            ("histograms", histos),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Snapshot, String> {
        let num_map = |j: Option<&Json>, what: &str| -> Result<BTreeMap<String, u64>, String> {
            let mut out = BTreeMap::new();
            if let Some(Json::Obj(m)) = j {
                for (k, v) in m {
                    let n = v.as_f64().ok_or_else(|| format!("{what}.{k}: not a number"))?;
                    out.insert(k.clone(), n as u64);
                }
            }
            Ok(out)
        };
        let mut snap = Snapshot {
            counters: num_map(j.get("counters"), "counters")?,
            gauges: num_map(j.get("gauges"), "gauges")?,
            histograms: BTreeMap::new(),
        };
        if let Some(Json::Obj(m)) = j.get("histograms") {
            for (k, v) in m {
                let count = v.get("count").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                let sum = v.get("sum").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                let mut buckets = Vec::new();
                for pair in v.get("buckets").and_then(Json::as_arr).unwrap_or(&[]) {
                    let p = pair.as_arr().ok_or_else(|| format!("histograms.{k}: bad bucket"))?;
                    if p.len() != 2 {
                        return Err(format!("histograms.{k}: bucket pair has {} items", p.len()));
                    }
                    let b = p[0].as_usize().ok_or_else(|| format!("histograms.{k}: bad index"))?;
                    let n =
                        p[1].as_f64().ok_or_else(|| format!("histograms.{k}: bad count"))? as u64;
                    buckets.push((b, n));
                }
                snap.histograms.insert(k.clone(), HistogramSnapshot { count, sum, buckets });
            }
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_math_bounds_every_value() {
        // property: every value lands in exactly the bucket whose
        // inclusive bounds contain it
        let mut x: u64 = 0x2545_F491_4F6C_DD1D;
        let mut probe = |v: u64| {
            let b = bucket_of(v);
            let (lo, hi) = bucket_bounds(b);
            assert!(lo <= v && v <= hi, "v={v} b={b} lo={lo} hi={hi}");
            if b > 0 {
                let (plo, phi) = bucket_bounds(b - 1);
                assert!(phi < lo && plo <= phi, "buckets must tile without overlap");
            }
        };
        for v in [0u64, 1, 2, 3, 4, 7, 8, 255, 256, u64::MAX - 1, u64::MAX] {
            probe(v);
        }
        for _ in 0..10_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            probe(x);
            probe(x >> (x % 64));
        }
        // bucket bounds tile the full u64 range
        assert_eq!(bucket_bounds(0), (0, 0));
        assert_eq!(bucket_bounds(1), (1, 1));
        assert_eq!(bucket_bounds(64).1, u64::MAX);
        for b in 1..HISTO_BUCKETS {
            assert_eq!(bucket_bounds(b).0, bucket_bounds(b - 1).1 + 1);
        }
    }

    #[test]
    fn percentiles_are_monotonic_and_conservative() {
        let h = Histogram::detached();
        for v in [1u64, 1, 1, 1, 1, 1, 1, 1, 1, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10);
        let p50 = s.percentile(0.50);
        let p95 = s.percentile(0.95);
        let p99 = s.percentile(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of nine 1s is bucket(1)'s upper bound = 1
        assert_eq!(p50, 1.0);
        // the outlier dominates the tail; upper bound never understates
        assert!(p99 >= 1000.0);
        // empty histogram
        assert_eq!(HistogramSnapshot::default().percentile(0.99), 0.0);
    }

    #[test]
    fn record_n_matches_n_records() {
        let a = Histogram::detached();
        let b = Histogram::detached();
        for _ in 0..7 {
            a.record(300);
        }
        b.record_n(300, 7);
        assert_eq!(a.snapshot(), b.snapshot());
        b.record_n(300, 0); // no-op
        assert_eq!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn registry_sums_same_named_cells() {
        let r = Registry::new();
        let c1 = r.counter("t.hits");
        let c2 = r.counter("t.hits");
        let g = r.gauge("t.resident");
        let h1 = r.histogram("t.lat");
        let h2 = r.histogram("t.lat");
        c1.add(3);
        c2.add(4);
        g.add(10);
        g.sub(4);
        h1.record(5);
        h2.record(500);
        let s = r.snapshot();
        assert_eq!(s.counters.get("t.hits"), Some(&7));
        assert_eq!(s.gauges.get("t.resident"), Some(&6));
        let lat = s.histograms.get("t.lat").expect("histogram registered");
        assert_eq!(lat.count, 2);
        assert_eq!(lat.sum, 505);
        // the handles' private cells stay per-instance
        assert_eq!(c1.get(), 3);
        assert_eq!(c2.get(), 4);
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let r = Registry::new();
        r.counter("a.b").add(42);
        r.gauge("c.d").set(17);
        let h = r.histogram("e.f");
        h.record(0);
        h.record(9);
        h.record_n(1 << 40, 3);
        let snap = r.snapshot();
        let text = snap.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&text).expect("parses")).expect("decodes");
        assert_eq!(snap, back);
    }
}
