//! Unified observability layer: metrics registry + tracing spans.
//!
//! DGL-KE's claims are about *where time and bytes go* — overlap of
//! compute with memory access, reduced communication, high operation
//! efficiency (PAPER.md §3). This module makes that visible from one
//! run instead of end-of-run aggregates only:
//!
//! * [`metrics`] — a process-wide registry of named counters, gauges,
//!   and log-2 histograms behind cheap cloneable handles. It absorbs
//!   the formerly ad-hoc `AtomicU64` stats (`CachedStore` hit/miss,
//!   `NetLedger` traffic, `TransferLedger` PCIe bytes, serve counters)
//!   and snapshots into `api::Report` JSON and `--metrics-out`.
//! * [`trace`] — thread-scoped begin/end span events on a monotonic
//!   clock, pushed into per-thread lock-free buffers and drained to
//!   Chrome trace-event JSON (open in Perfetto / `chrome://tracing`).
//!   Enabled by `RunSpec.obs.trace` / `--trace`; a disabled span costs
//!   one relaxed load and a branch.
//!
//! Contract (docs/OBSERVABILITY.md): observability on vs off is
//! byte-identical for training outputs — spans and metrics observe,
//! they never steer. The equivalence matrix in
//! `rust/tests/obs_tests.rs` enforces this the same way the prefetch
//! and kernel matrices do.

pub mod metrics;
pub mod trace;
