//! Tracing spans: begin/end events on a monotonic clock, pushed into
//! per-thread lock-free buffers and exported as Chrome trace-event JSON
//! (load the file in Perfetto or `chrome://tracing` to *see* the
//! prefetch pipeline overlapping batch N+1 with batch N).
//!
//! ## Hot-path contract
//!
//! [`span`] with tracing disabled is one `Relaxed` load and a branch —
//! nothing else. Enabled, a span is two pushes into this thread's
//! [`SpanBuf`]: a slot write published by a `Release` store of the
//! length, which the drain side reads back with `Acquire`
//! (ordering-pairs.toml `trace-buf-len`; loom contract 11 in
//! `rust/tests/loom_tests.rs` proves a drain never reads a half-written
//! record and loses nothing once the writer has quiesced). Buffers are
//! append-only and fixed-capacity; overflow increments a drop counter
//! instead of blocking or reallocating, so tracing can never stall a
//! worker.
//!
//! ## Lifecycle
//!
//! One trace session at a time: [`start`] claims the global collector
//! (waiting out any concurrent session — test processes run sessions in
//! parallel), instrumented threads lazily register a buffer on their
//! first span, and [`TraceGuard::finish`] disables collection, drains
//! every buffer, and returns the [`TraceData`] to serialize. Threads
//! must quiesce (scoped-join, `ServeHandle::shutdown`) before `finish`
//! — events raced past the drain are dropped, never torn. Timestamps
//! are per-thread strictly monotonic by construction (ties bump by
//! 1 ns), which [`validate_chrome_trace`] checks along with the schema.
//!
//! Span identity is the closed [`SpanId`] catalog, not free strings —
//! an event is two `u64`s and the name table ships with the binary.
//! The catalog and instrumented seams are listed in
//! docs/OBSERVABILITY.md.

use crate::util::json::Json;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::{Arc, Mutex};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Instant;

/// Events a single thread can buffer before new ones are counted as
/// dropped instead (64 Ki events = 32 Ki spans ≈ a long traced run).
pub const BUF_CAPACITY: usize = 1 << 16;

/// The span catalog. Keep `SPAN_NAMES` index-aligned with the
/// discriminants; docs/OBSERVABILITY.md documents each seam.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanId {
    TrainEpoch = 0,
    TrainBatch = 1,
    Sample = 2,
    Gather = 3,
    Compute = 4,
    Update = 5,
    SyncBarrier = 6,
    PrefetchSample = 7,
    PrefetchGather = 8,
    PrefetchPatch = 9,
    KvPullWave = 10,
    KvPush = 11,
    KvDrain = 12,
    ServeRequest = 13,
    ServeScore = 14,
    ServeReassemble = 15,
    SwapPublish = 16,
}

pub const SPAN_NAMES: [&str; 17] = [
    "train.epoch",
    "train.batch",
    "train.sample",
    "train.gather",
    "train.compute",
    "train.update",
    "train.sync",
    "prefetch.sample",
    "prefetch.gather",
    "prefetch.patch",
    "kv.pull_wave",
    "kv.push",
    "kv.drain",
    "serve.request",
    "serve.score",
    "serve.reassemble",
    "swap.publish",
];

impl SpanId {
    pub fn name(self) -> &'static str {
        SPAN_NAMES[self as usize]
    }
}

fn name_of(id: u64) -> &'static str {
    usize::try_from(id).ok().and_then(|i| SPAN_NAMES.get(i)).copied().unwrap_or("unknown")
}

// ------------------------------------------------------------- SpanBuf

struct Slot {
    ts: AtomicU64,
    code: AtomicU64,
}

/// Fixed-capacity single-writer event buffer. The owning thread appends
/// with [`push`](SpanBuf::push); any thread may [`drain`](SpanBuf::drain)
/// a consistent prefix at any time. A record becomes visible only via
/// the `Release` store of `len` after both of its words are written, so
/// a drain can observe "not yet" but never "half".
pub struct SpanBuf {
    tid: u64,
    slots: Vec<Slot>,
    len: AtomicUsize,
    dropped: AtomicU64,
}

impl SpanBuf {
    pub fn with_capacity(tid: u64, cap: usize) -> SpanBuf {
        SpanBuf {
            tid,
            slots: (0..cap)
                .map(|_| Slot { ts: AtomicU64::new(0), code: AtomicU64::new(0) })
                .collect(),
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn tid(&self) -> u64 {
        self.tid
    }

    /// Append one event. Single-writer: only the owning thread calls
    /// this. Returns false (and counts a drop) when full.
    pub fn push(&self, ts: u64, code: u64) -> bool {
        let i = self.len.load(Ordering::Relaxed);
        if i >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        self.slots[i].ts.store(ts, Ordering::Relaxed);
        self.slots[i].code.store(code, Ordering::Relaxed);
        self.len.store(i + 1, Ordering::Release);
        true
    }

    /// Read the published prefix. The `Acquire` on `len` pairs with
    /// `push`'s `Release`, making every slot below it fully visible.
    pub fn drain(&self) -> Vec<(u64, u64)> {
        let n = self.len.load(Ordering::Acquire);
        self.slots[..n]
            .iter()
            .map(|s| (s.ts.load(Ordering::Relaxed), s.code.load(Ordering::Relaxed)))
            .collect()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

// -------------------------------------------------------- global state

static ENABLED: AtomicBool = AtomicBool::new(false);
static SESSION: AtomicU64 = AtomicU64::new(0);

struct TraceState {
    active: bool,
    start: Option<Instant>,
    bufs: Vec<Arc<SpanBuf>>,
}

static STATE: Mutex<TraceState> =
    Mutex::new(TraceState { active: false, start: None, bufs: Vec::new() });

struct ThreadTrace {
    session: u64,
    base: Instant,
    last_ts: u64,
    buf: Arc<SpanBuf>,
}

thread_local! {
    static TLS: RefCell<Option<ThreadTrace>> = const { RefCell::new(None) };
}

/// Owns the active trace session; dropping without [`finish`] discards
/// the collected events and frees the collector.
pub struct TraceGuard {
    done: bool,
}

/// Claim the collector and start recording. Blocks while another trace
/// session is active (sessions are process-global; parallel test
/// processes each get their own).
pub fn start() -> TraceGuard {
    loop {
        {
            let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
            if !st.active {
                st.active = true;
                st.start = Some(Instant::now());
                st.bufs = Vec::new();
                SESSION.fetch_add(1, Ordering::Relaxed);
                ENABLED.store(true, Ordering::Relaxed);
                return TraceGuard { done: false };
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}

/// True while a trace session is recording.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

impl TraceGuard {
    /// Stop recording and drain every thread's buffer. Call after the
    /// instrumented threads have quiesced (joined or barriered) so
    /// nothing is still appending.
    pub fn finish(mut self) -> TraceData {
        self.done = true;
        ENABLED.store(false, Ordering::Relaxed);
        let bufs = {
            let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
            st.active = false;
            st.start = None;
            std::mem::take(&mut st.bufs)
        };
        let mut threads = Vec::new();
        let mut dropped = 0;
        for b in bufs {
            dropped += b.dropped();
            threads.push(DrainedThread { tid: b.tid(), events: b.drain() });
        }
        TraceData { threads, dropped }
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        if !self.done {
            ENABLED.store(false, Ordering::Relaxed);
            let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
            st.active = false;
            st.start = None;
            st.bufs = Vec::new();
        }
    }
}

fn push_event(id: SpanId, end: bool) {
    TLS.with(|cell| {
        let mut tls = cell.borrow_mut();
        let cur = SESSION.load(Ordering::Relaxed);
        let stale = match tls.as_ref() {
            Some(t) => t.session != cur,
            None => true,
        };
        if stale {
            // first span of this thread in this session: register a buffer
            let bound = {
                let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
                match (st.active, st.start) {
                    (true, Some(base)) => {
                        let tid = st.bufs.len() as u64 + 1;
                        let buf = Arc::new(SpanBuf::with_capacity(tid, BUF_CAPACITY));
                        st.bufs.push(buf.clone());
                        Some(ThreadTrace { session: cur, base, last_ts: 0, buf })
                    }
                    _ => None, // session ended between the enabled check and here
                }
            };
            match bound {
                Some(t) => *tls = Some(t),
                None => return,
            }
        }
        if let Some(t) = tls.as_mut() {
            let raw = u64::try_from(t.base.elapsed().as_nanos()).unwrap_or(u64::MAX);
            // strictly monotonic per thread: coincident readings bump 1 ns
            let ts = raw.max(t.last_ts + 1);
            t.last_ts = ts;
            let code = ((id as u64) << 1) | u64::from(end);
            t.buf.push(ts, code);
        }
    });
}

/// RAII span: records a begin event now and the matching end event on
/// drop. With tracing off this is a single relaxed load and a branch.
pub struct Span {
    armed: bool,
    id: SpanId,
}

#[inline]
pub fn span(id: SpanId) -> Span {
    if !ENABLED.load(Ordering::Relaxed) {
        return Span { armed: false, id };
    }
    push_event(id, false);
    Span { armed: true, id }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.armed {
            push_event(self.id, true);
        }
    }
}

// -------------------------------------------------------------- export

struct DrainedThread {
    tid: u64,
    events: Vec<(u64, u64)>,
}

/// Everything a finished trace session collected.
pub struct TraceData {
    threads: Vec<DrainedThread>,
    /// Events lost to full buffers (0 in any healthy run).
    pub dropped: u64,
}

impl TraceData {
    pub fn event_count(&self) -> usize {
        self.threads.iter().map(|t| t.events.len()).sum()
    }

    /// Serialize as Chrome trace-event JSON (the `traceEvents` array of
    /// `B`/`E` duration events; `ts` is microseconds).
    pub fn to_chrome_json(&self) -> String {
        let mut events = Vec::with_capacity(self.event_count());
        for th in &self.threads {
            for &(ts, code) in &th.events {
                let mut e = BTreeMap::new();
                e.insert("name".to_string(), Json::Str(name_of(code >> 1).to_string()));
                e.insert("cat".to_string(), Json::Str("dglke".to_string()));
                e.insert(
                    "ph".to_string(),
                    Json::Str(if code & 1 == 1 { "E" } else { "B" }.to_string()),
                );
                e.insert("pid".to_string(), Json::Num(1.0));
                e.insert("tid".to_string(), Json::Num(th.tid as f64));
                e.insert("ts".to_string(), Json::Num(ts as f64 / 1000.0));
                events.push(Json::Obj(e));
            }
        }
        let mut top = BTreeMap::new();
        top.insert("traceEvents".to_string(), Json::Arr(events));
        top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
        Json::Obj(top).to_string()
    }
}

// ----------------------------------------------------------- validator

/// A completed (begin, end) pair recovered from a trace file.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanInterval {
    pub name: String,
    pub tid: u64,
    pub start_us: f64,
    pub end_us: f64,
}

/// Validation result: counts plus the recovered span intervals.
#[derive(Debug, Default)]
pub struct TraceCheck {
    pub events: usize,
    pub threads: usize,
    pub intervals: Vec<SpanInterval>,
}

impl TraceCheck {
    /// True if some completed span whose name starts with `a` overlaps
    /// in time with a span starting with `b` on a *different* thread —
    /// the pipeline-overlap evidence the trace exists to show.
    pub fn overlap_exists(&self, a: &str, b: &str) -> bool {
        self.intervals.iter().any(|x| {
            x.name.starts_with(a)
                && self.intervals.iter().any(|y| {
                    y.name.starts_with(b)
                        && y.tid != x.tid
                        && x.start_us < y.end_us
                        && y.start_us < x.end_us
                })
        })
    }
}

/// Check a Chrome trace-event JSON document: schema fields present,
/// every `B` matched by an `E` of the same name in stack order, and
/// per-thread timestamps strictly increasing. Used by the trace tests
/// and `dglke trace-check`.
pub fn validate_chrome_trace(text: &str) -> Result<TraceCheck, String> {
    let doc = Json::parse(text).map_err(|e| format!("not valid JSON: {e:?}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing top-level traceEvents array".to_string())?;
    let mut check = TraceCheck::default();
    let mut stacks: BTreeMap<u64, Vec<(String, f64)>> = BTreeMap::new();
    let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        let field = |k: &str| e.get(k).ok_or_else(|| format!("event {i}: missing `{k}`"));
        let name = field("name")?
            .as_str()
            .ok_or_else(|| format!("event {i}: `name` is not a string"))?
            .to_string();
        let ph = field("ph")?
            .as_str()
            .ok_or_else(|| format!("event {i}: `ph` is not a string"))?;
        field("pid")?.as_f64().ok_or_else(|| format!("event {i}: `pid` is not a number"))?;
        let tid = field("tid")?
            .as_f64()
            .ok_or_else(|| format!("event {i}: `tid` is not a number"))? as u64;
        let ts = field("ts")?
            .as_f64()
            .ok_or_else(|| format!("event {i}: `ts` is not a number"))?;
        if let Some(&prev) = last_ts.get(&tid) {
            if ts <= prev {
                return Err(format!(
                    "event {i}: tid {tid} timestamp {ts} not strictly after {prev}"
                ));
            }
        }
        last_ts.insert(tid, ts);
        match ph {
            "B" => stacks.entry(tid).or_default().push((name, ts)),
            "E" => {
                let (open, start) = stacks
                    .entry(tid)
                    .or_default()
                    .pop()
                    .ok_or_else(|| format!("event {i}: E `{name}` on tid {tid} with no open B"))?;
                if open != name {
                    return Err(format!(
                        "event {i}: E `{name}` closes B `{open}` on tid {tid} (bad nesting)"
                    ));
                }
                check.intervals.push(SpanInterval { name, tid, start_us: start, end_us: ts });
            }
            other => return Err(format!("event {i}: ph `{other}` (only B/E are emitted)")),
        }
        check.events += 1;
    }
    for (tid, stack) in &stacks {
        if let Some((name, _)) = stack.last() {
            return Err(format!("tid {tid}: B `{name}` never closed"));
        }
    }
    check.threads = last_ts.len();
    Ok(check)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_buf_push_drain_round_trip() {
        let b = SpanBuf::with_capacity(7, 8);
        assert!(b.push(1, 10));
        assert!(b.push(2, 11));
        assert_eq!(b.drain(), vec![(1, 10), (2, 11)]);
        assert_eq!(b.tid(), 7);
        assert_eq!(b.dropped(), 0);
    }

    #[test]
    fn span_buf_overflow_counts_drops() {
        let b = SpanBuf::with_capacity(1, 2);
        assert!(b.push(1, 0));
        assert!(b.push(2, 0));
        assert!(!b.push(3, 0));
        assert!(!b.push(4, 0));
        assert_eq!(b.drain().len(), 2);
        assert_eq!(b.dropped(), 2);
    }

    /// One test drives the whole global lifecycle: the collector is
    /// process-wide, so splitting these into parallel #[test]s would
    /// race each other through ENABLED.
    #[test]
    fn session_records_and_exports_valid_chrome_json() {
        // no session yet: spans must not register buffers or events
        let inert = span(SpanId::Compute);
        assert!(!inert.armed);
        drop(inert);

        let guard = start();
        {
            let _epoch = span(SpanId::TrainEpoch);
            for _ in 0..3 {
                let _b = span(SpanId::TrainBatch);
                let _g = span(SpanId::Gather);
            }
        }
        let helper = std::thread::spawn(|| {
            let _p = span(SpanId::PrefetchGather);
        });
        helper.join().expect("helper joins");
        let data = guard.finish();
        assert_eq!(data.dropped, 0);
        assert_eq!(data.event_count(), (1 + 3 * 2 + 1) * 2);
        let text = data.to_chrome_json();
        let check = validate_chrome_trace(&text).expect("well-formed");
        assert_eq!(check.events, data.event_count());
        assert_eq!(check.threads, 2);
        assert!(check.intervals.iter().any(|i| i.name == "prefetch.gather"));
        // collector is free again
        let g2 = start();
        drop(g2);
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        assert!(validate_chrome_trace("[1,2,3]").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\": 3}").is_err());
        // unmatched B
        let open = r#"{"traceEvents":[{"name":"a","cat":"c","ph":"B","pid":1,"tid":1,"ts":1.0}]}"#;
        assert!(validate_chrome_trace(open).unwrap_err().contains("never closed"));
        // E closing the wrong name
        let cross = r#"{"traceEvents":[
            {"name":"a","cat":"c","ph":"B","pid":1,"tid":1,"ts":1.0},
            {"name":"b","cat":"c","ph":"E","pid":1,"tid":1,"ts":2.0}]}"#;
        assert!(validate_chrome_trace(cross).unwrap_err().contains("bad nesting"));
        // non-monotonic per-thread timestamps
        let warp = r#"{"traceEvents":[
            {"name":"a","cat":"c","ph":"B","pid":1,"tid":1,"ts":2.0},
            {"name":"a","cat":"c","ph":"E","pid":1,"tid":1,"ts":2.0}]}"#;
        assert!(validate_chrome_trace(warp).unwrap_err().contains("strictly"));
    }

    #[test]
    fn overlap_detection_requires_distinct_threads() {
        let mk = |name: &str, tid, s, e| SpanInterval {
            name: name.to_string(),
            tid,
            start_us: s,
            end_us: e,
        };
        let mut c = TraceCheck::default();
        c.intervals = vec![mk("prefetch.gather", 2, 0.0, 5.0), mk("train.compute", 1, 3.0, 8.0)];
        assert!(c.overlap_exists("prefetch.", "train.compute"));
        // same thread: sequential by definition, not pipeline overlap
        c.intervals = vec![mk("prefetch.gather", 1, 0.0, 5.0), mk("train.compute", 1, 3.0, 8.0)];
        assert!(!c.overlap_exists("prefetch.", "train.compute"));
        // disjoint in time
        c.intervals = vec![mk("prefetch.gather", 2, 0.0, 2.0), mk("train.compute", 1, 3.0, 8.0)];
        assert!(!c.overlap_exists("prefetch.", "train.compute"));
    }
}
