//! Weighted undirected graph used by the min-cut partitioner.
//!
//! Built from a `TripletStore` by collapsing parallel edges (a (h,t) pair
//! connected by multiple relations becomes one edge of weight = multiplicity)
//! and dropping direction — edge-cut in the undirected multigraph is what
//! determines cross-machine embedding traffic (paper §3.2).

use crate::kg::TripletStore;

#[derive(Clone, Debug)]
pub struct WeightedGraph {
    /// CSR offsets, len = n+1
    pub offsets: Vec<u64>,
    /// neighbor vertex ids
    pub adj: Vec<u32>,
    /// edge weights, aligned with `adj`
    pub ewgt: Vec<u32>,
    /// vertex weights (number of collapsed original vertices)
    pub vwgt: Vec<u32>,
}

impl WeightedGraph {
    pub fn n_vertices(&self) -> usize {
        self.vwgt.len()
    }

    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    pub fn neighbors(&self, v: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        (lo..hi).map(move |i| (self.adj[i], self.ewgt[i]))
    }

    /// Total vertex weight.
    pub fn total_vwgt(&self) -> u64 {
        self.vwgt.iter().map(|&w| w as u64).sum()
    }

    /// Build from (u, v, w) edge triples with u != v. Parallel edges are
    /// collapsed by summing weights.
    pub fn from_edges(n: usize, edges: &[(u32, u32, u32)], vwgt: Option<Vec<u32>>) -> Self {
        // Dedup via sort on (min,max) keys.
        let mut keyed: Vec<(u32, u32, u32)> = edges
            .iter()
            .filter(|&&(u, v, _)| u != v)
            .map(|&(u, v, w)| if u < v { (u, v, w) } else { (v, u, w) })
            .collect();
        keyed.sort_unstable_by_key(|&(u, v, _)| ((u as u64) << 32) | v as u64);
        let mut dedup: Vec<(u32, u32, u32)> = Vec::with_capacity(keyed.len());
        for (u, v, w) in keyed {
            if let Some(last) = dedup.last_mut() {
                if last.0 == u && last.1 == v {
                    last.2 += w;
                    continue;
                }
            }
            dedup.push((u, v, w));
        }
        // CSR with both directions.
        let mut offsets = vec![0u64; n + 1];
        for &(u, v, _) in &dedup {
            offsets[u as usize + 1] += 1;
            offsets[v as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let m2 = dedup.len() * 2;
        let mut adj = vec![0u32; m2];
        let mut ewgt = vec![0u32; m2];
        let mut cursor = offsets.clone();
        for &(u, v, w) in &dedup {
            let pu = cursor[u as usize] as usize;
            adj[pu] = v;
            ewgt[pu] = w;
            cursor[u as usize] += 1;
            let pv = cursor[v as usize] as usize;
            adj[pv] = u;
            ewgt[pv] = w;
            cursor[v as usize] += 1;
        }
        WeightedGraph { offsets, adj, ewgt, vwgt: vwgt.unwrap_or_else(|| vec![1; n]) }
    }

    pub fn from_triplets(store: &TripletStore) -> Self {
        let edges: Vec<(u32, u32, u32)> =
            store.iter().map(|t| (t.head, t.tail, 1u32)).collect();
        Self::from_edges(store.n_entities(), &edges, None)
    }

    /// Edge-cut of a partition assignment (each cut edge counted once).
    pub fn edge_cut(&self, part: &[u32]) -> u64 {
        let mut cut = 0u64;
        for v in 0..self.n_vertices() {
            for (u, w) in self.neighbors(v as u32) {
                if (u as usize) > v && part[u as usize] != part[v] {
                    cut += w as u64;
                }
            }
        }
        cut
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collapse_parallel_edges() {
        let g = WeightedGraph::from_edges(3, &[(0, 1, 1), (1, 0, 1), (1, 2, 1)], None);
        assert_eq!(g.degree(0), 1);
        let (n, w) = g.neighbors(0).next().unwrap();
        assert_eq!((n, w), (1, 2));
    }

    #[test]
    fn self_loops_dropped() {
        let g = WeightedGraph::from_edges(2, &[(0, 0, 5), (0, 1, 1)], None);
        assert_eq!(g.degree(0), 1);
    }

    #[test]
    fn edge_cut_counts_once() {
        let g = WeightedGraph::from_edges(4, &[(0, 1, 2), (1, 2, 3), (2, 3, 1)], None);
        // split {0,1} | {2,3}: only edge (1,2) w=3 is cut
        assert_eq!(g.edge_cut(&[0, 0, 1, 1]), 3);
        assert_eq!(g.edge_cut(&[0, 0, 0, 0]), 0);
        assert_eq!(g.edge_cut(&[0, 1, 0, 1]), 2 + 3 + 1);
    }
}
