//! Multilevel k-way min-cut partitioner — our stand-in for METIS [6].
//!
//! Classic three-phase scheme (Karypis & Kumar):
//! 1. **Coarsening** — repeated heavy-edge matching collapses the graph
//!    until it is small;
//! 2. **Initial partitioning** — greedy region growing on the coarsest
//!    graph into k balanced parts;
//! 3. **Uncoarsening + refinement** — project the partition back up,
//!    applying boundary Kernighan–Lin-style gain moves at every level
//!    under a balance constraint.
//!
//! The paper only needs METIS's qualitative property: most triplets end up
//! inside diagonal blocks (Fig 2), so distributed trainers rarely touch
//! remote entity embeddings. `partition::stats` measures exactly that.

use super::graph::WeightedGraph;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct MetisConfig {
    /// Allowed imbalance: max part weight <= (1+epsilon) * ideal.
    pub epsilon: f64,
    /// Stop coarsening when the graph has at most this many vertices
    /// (scaled by k).
    pub coarsest_per_part: usize,
    /// Boundary refinement passes per level.
    pub refine_passes: usize,
    pub seed: u64,
}

impl Default for MetisConfig {
    fn default() -> Self {
        MetisConfig { epsilon: 0.05, coarsest_per_part: 30, refine_passes: 4, seed: 1 }
    }
}

/// Partition `g` into `k` parts. Returns the part id of every vertex.
pub fn partition(g: &WeightedGraph, k: usize, cfg: &MetisConfig) -> Vec<u32> {
    assert!(k >= 1);
    let n = g.n_vertices();
    if k == 1 || n <= k {
        return (0..n).map(|v| (v % k) as u32).collect();
    }
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x4d45_5449);

    // ---- coarsening ----
    let mut levels: Vec<(WeightedGraph, Vec<u32>)> = Vec::new(); // (coarser graph, map fine->coarse)
    let mut cur = g.clone();
    let target = (cfg.coarsest_per_part * k).max(64);
    while cur.n_vertices() > target && levels.len() < 40 {
        let (coarse, map) = coarsen_once(&cur, &mut rng);
        // stop if coarsening stalls (< 10% reduction)
        if coarse.n_vertices() as f64 > cur.n_vertices() as f64 * 0.95 {
            break;
        }
        levels.push((cur, map));
        cur = coarse;
    }

    // ---- initial partition on coarsest ----
    let total = cur.total_vwgt();
    let max_part = ((total as f64 / k as f64) * (1.0 + cfg.epsilon)).ceil() as u64;
    let mut part = region_grow(&cur, k, max_part, &mut rng);
    refine(&cur, &mut part, k, max_part, cfg.refine_passes);

    // ---- uncoarsen + refine ----
    while let Some((fine, map)) = levels.pop() {
        let mut fine_part = vec![0u32; fine.n_vertices()];
        for v in 0..fine.n_vertices() {
            fine_part[v] = part[map[v] as usize];
        }
        refine(&fine, &mut fine_part, k, max_part, cfg.refine_passes);
        part = fine_part;
    }
    part
}

/// One round of heavy-edge matching. Returns the coarse graph and the
/// fine→coarse vertex map.
fn coarsen_once(g: &WeightedGraph, rng: &mut Rng) -> (WeightedGraph, Vec<u32>) {
    let n = g.n_vertices();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut matched = vec![u32::MAX; n];
    let mut n_coarse = 0u32;
    for &v in &order {
        if matched[v as usize] != u32::MAX {
            continue;
        }
        // heaviest unmatched neighbor
        let mut best = u32::MAX;
        let mut best_w = 0u32;
        for (u, w) in g.neighbors(v) {
            if matched[u as usize] == u32::MAX && u != v && w >= best_w {
                best = u;
                best_w = w;
            }
        }
        matched[v as usize] = n_coarse;
        if best != u32::MAX {
            matched[best as usize] = n_coarse;
        }
        n_coarse += 1;
    }
    // coarse vertex weights + edges
    let mut vwgt = vec![0u32; n_coarse as usize];
    for v in 0..n {
        vwgt[matched[v] as usize] += g.vwgt[v];
    }
    let mut edges: Vec<(u32, u32, u32)> = Vec::with_capacity(g.adj.len() / 2);
    for v in 0..n {
        let cv = matched[v];
        for (u, w) in g.neighbors(v as u32) {
            let cu = matched[u as usize];
            if cv < cu {
                edges.push((cv, cu, w));
            }
        }
    }
    let coarse = WeightedGraph::from_edges(n_coarse as usize, &edges, Some(vwgt));
    (coarse, matched)
}

/// Greedy BFS region growing into k balanced parts.
fn region_grow(g: &WeightedGraph, k: usize, max_part: u64, rng: &mut Rng) -> Vec<u32> {
    let n = g.n_vertices();
    let mut part = vec![u32::MAX; n];
    let mut weights = vec![0u64; k];
    let mut frontier: Vec<Vec<u32>> = vec![Vec::new(); k];
    // distinct random seeds
    for (p, f) in frontier.iter_mut().enumerate() {
        for _ in 0..64 {
            let v = rng.gen_index(n) as u32;
            if part[v as usize] == u32::MAX {
                part[v as usize] = p as u32;
                weights[p] += g.vwgt[v as usize] as u64;
                f.push(v);
                break;
            }
        }
    }
    // round-robin BFS growth, lightest part first
    loop {
        // pick the lightest part that still has a frontier
        let mut grew = false;
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by_key(|&p| weights[p]);
        for p in order {
            if weights[p] as u64 >= max_part {
                continue;
            }
            while let Some(v) = frontier[p].pop() {
                let mut advanced = false;
                for (u, _) in g.neighbors(v) {
                    if part[u as usize] == u32::MAX {
                        part[u as usize] = p as u32;
                        weights[p] += g.vwgt[u as usize] as u64;
                        frontier[p].push(u);
                        advanced = true;
                        break;
                    }
                }
                if advanced {
                    frontier[p].push(v);
                    grew = true;
                    break;
                }
            }
            if grew {
                break;
            }
        }
        if !grew {
            break;
        }
    }
    // orphans (disconnected remainder) → lightest parts
    for v in 0..n {
        if part[v] == u32::MAX {
            let p = (0..k).min_by_key(|&p| weights[p]).unwrap();
            part[v] = p as u32;
            weights[p] += g.vwgt[v] as u64;
        }
    }
    part
}

/// Greedy boundary refinement: move boundary vertices to the neighboring
/// part with the highest cut gain, respecting the balance constraint.
fn refine(g: &WeightedGraph, part: &mut [u32], k: usize, max_part: u64, passes: usize) {
    let n = g.n_vertices();
    let mut weights = vec![0u64; k];
    for v in 0..n {
        weights[part[v] as usize] += g.vwgt[v] as u64;
    }
    let mut gains: Vec<i64> = vec![0; k];
    for _ in 0..passes {
        let mut moved = 0usize;
        for v in 0..n {
            let pv = part[v] as usize;
            // connectivity of v to each part
            let mut touched: Vec<usize> = Vec::with_capacity(8);
            for g_ in gains.iter_mut() {
                *g_ = 0;
            }
            for (u, w) in g.neighbors(v as u32) {
                let pu = part[u as usize] as usize;
                if gains[pu] == 0 {
                    touched.push(pu);
                }
                gains[pu] += w as i64;
            }
            let internal = gains[pv];
            let mut best_part = pv;
            let mut best_gain = 0i64;
            for &p in &touched {
                if p == pv {
                    continue;
                }
                let gain = gains[p] - internal;
                if gain > best_gain && weights[p] + g.vwgt[v] as u64 <= max_part {
                    best_gain = gain;
                    best_part = p;
                }
            }
            if best_part != pv {
                weights[pv] -= g.vwgt[v] as u64;
                weights[best_part] += g.vwgt[v] as u64;
                part[v] = best_part as u32;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::generator::{generate, GeneratorConfig};

    fn ring_of_cliques(n_cliques: usize, size: usize) -> WeightedGraph {
        // Cliques connected in a ring by single edges — a min-cut
        // partitioner must cut only the ring edges.
        let mut edges = Vec::new();
        for c in 0..n_cliques {
            let base = (c * size) as u32;
            for i in 0..size as u32 {
                for j in (i + 1)..size as u32 {
                    edges.push((base + i, base + j, 1u32));
                }
            }
            let next = (((c + 1) % n_cliques) * size) as u32;
            edges.push((base, next, 1u32));
        }
        WeightedGraph::from_edges(n_cliques * size, &edges, None)
    }

    #[test]
    fn cliques_stay_together() {
        let g = ring_of_cliques(8, 16);
        let part = partition(&g, 4, &MetisConfig::default());
        // cut should be close to the minimum of 4 ring edges; allow a bit
        // of slack for the greedy heuristics.
        let cut = g.edge_cut(&part);
        assert!(cut <= 12, "cut={cut}");
        // balance
        let mut w = [0u64; 4];
        for &p in &part {
            w[p as usize] += 1;
        }
        for &x in &w {
            assert!(x >= 16 && x <= 48, "weights={w:?}");
        }
    }

    #[test]
    fn balance_constraint_respected() {
        let g = ring_of_cliques(10, 10);
        let cfg = MetisConfig { epsilon: 0.10, ..Default::default() };
        let part = partition(&g, 5, &cfg);
        let mut w = vec![0u64; 5];
        for &p in &part {
            w[p as usize] += 1;
        }
        let max = *w.iter().max().unwrap();
        // region growing can overfill the last part with orphans, but
        // should stay near (1+eps)*ideal = 22
        assert!(max <= 30, "{w:?}");
    }

    #[test]
    fn beats_random_on_community_graph() {
        let kg = generate(&GeneratorConfig::tiny(3));
        let g = WeightedGraph::from_triplets(&kg.store);
        let part = partition(&g, 4, &MetisConfig::default());
        let metis_cut = g.edge_cut(&part);
        let mut rng = Rng::seed_from_u64(5);
        let rand_part: Vec<u32> = (0..g.n_vertices()).map(|_| rng.gen_index(4) as u32).collect();
        let rand_cut = g.edge_cut(&rand_part);
        assert!(
            (metis_cut as f64) < 0.8 * rand_cut as f64,
            "metis={metis_cut} random={rand_cut}"
        );
    }

    #[test]
    fn k1_trivial() {
        let g = ring_of_cliques(2, 4);
        let part = partition(&g, 1, &MetisConfig::default());
        assert!(part.iter().all(|&p| p == 0));
    }

    #[test]
    fn all_vertices_assigned() {
        let kg = generate(&GeneratorConfig::tiny(9));
        let g = WeightedGraph::from_triplets(&kg.store);
        for k in [2, 3, 4, 8] {
            let part = partition(&g, k, &MetisConfig::default());
            assert_eq!(part.len(), g.n_vertices());
            assert!(part.iter().all(|&p| (p as usize) < k));
            // every part non-empty
            let mut seen = vec![false; k];
            for &p in &part {
                seen[p as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "k={k}");
        }
    }
}
