//! Graph partitioning (paper §3.2) and relation partitioning (§3.4).
//!
//! * [`metis`] — multilevel min-cut partitioner (METIS stand-in) used to
//!   place entities and triplets on machines for distributed training;
//! * [`random_partition`] — the random baseline of §6.3;
//! * [`relation`] — the greedy relation partitioner that binds relations
//!   to computing units within a machine;
//! * [`stats`] — locality metrics (edge-cut, fraction of local triplets)
//!   used by tests and the Fig 7 bench.

pub mod graph;
pub mod metis;
pub mod relation;

use crate::kg::TripletStore;
use crate::util::rng::Rng;

pub use graph::WeightedGraph;
pub use metis::{partition as metis_partition, MetisConfig};
pub use relation::{partition_relations, RelationPartition, SPLIT};

/// A placement of entities and triplets onto `k` machines.
#[derive(Clone, Debug)]
pub struct GraphPartition {
    pub k: usize,
    /// entity → machine
    pub entity_part: Vec<u32>,
    /// triplet index → machine (machine of the triplet's head)
    pub triplet_part: Vec<u32>,
}

impl GraphPartition {
    /// Build from an entity assignment; triplets follow their head entity
    /// (the paper co-locates a METIS partition's entities and incident
    /// triplets).
    pub fn from_entity_assignment(store: &TripletStore, k: usize, entity_part: Vec<u32>) -> Self {
        assert_eq!(entity_part.len(), store.n_entities());
        let triplet_part = store.heads.iter().map(|&h| entity_part[h as usize]).collect();
        GraphPartition { k, entity_part, triplet_part }
    }

    /// METIS-style placement.
    pub fn metis(store: &TripletStore, k: usize, cfg: &MetisConfig) -> Self {
        let g = WeightedGraph::from_triplets(store);
        let part = metis_partition(&g, k, cfg);
        Self::from_entity_assignment(store, k, part)
    }

    /// Random placement (the §6.3 baseline).
    pub fn random(store: &TripletStore, k: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0x52_414e_44);
        let part = (0..store.n_entities()).map(|_| rng.gen_index(k) as u32).collect();
        Self::from_entity_assignment(store, k, part)
    }

    /// Triplet indices assigned to machine `p`.
    pub fn triplets_of(&self, p: u32) -> Vec<usize> {
        self.triplet_part
            .iter()
            .enumerate()
            .filter(|&(_, &tp)| tp == p)
            .map(|(i, _)| i)
            .collect()
    }

    /// Locality: fraction of triplet endpoints that live on the triplet's
    /// machine. 1.0 = no remote embedding traffic. This is the quantity
    /// the paper's Fig 2 visualizes as diagonal-block density.
    pub fn locality(&self, store: &TripletStore) -> f64 {
        let mut local = 0u64;
        for i in 0..store.len() {
            let p = self.triplet_part[i];
            if self.entity_part[store.heads[i] as usize] == p {
                local += 1;
            }
            if self.entity_part[store.tails[i] as usize] == p {
                local += 1;
            }
        }
        local as f64 / (2 * store.len()) as f64
    }

    /// Per-machine entity counts.
    pub fn entity_sizes(&self) -> Vec<u64> {
        let mut s = vec![0u64; self.k];
        for &p in &self.entity_part {
            s[p as usize] += 1;
        }
        s
    }

    /// Per-machine triplet counts.
    pub fn triplet_sizes(&self) -> Vec<u64> {
        let mut s = vec![0u64; self.k];
        for &p in &self.triplet_part {
            s[p as usize] += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::generator::{generate, GeneratorConfig};

    #[test]
    fn metis_beats_random_locality() {
        let kg = generate(&GeneratorConfig::tiny(8));
        let m = GraphPartition::metis(&kg.store, 4, &MetisConfig::default());
        let r = GraphPartition::random(&kg.store, 4, 8);
        let lm = m.locality(&kg.store);
        let lr = r.locality(&kg.store);
        // random gives ~0.25 + 0.5 (head always local) ≈ 0.625;
        // metis should clearly beat it on a community graph
        assert!(lm > lr + 0.1, "metis={lm} random={lr}");
    }

    #[test]
    fn heads_always_local() {
        let kg = generate(&GeneratorConfig::tiny(1));
        let p = GraphPartition::random(&kg.store, 4, 1);
        for i in 0..kg.store.len() {
            assert_eq!(p.triplet_part[i], p.entity_part[kg.store.heads[i] as usize]);
        }
    }

    #[test]
    fn triplets_of_partitions_cover_all() {
        let kg = generate(&GeneratorConfig::tiny(2));
        let p = GraphPartition::metis(&kg.store, 3, &MetisConfig::default());
        let total: usize = (0..3).map(|m| p.triplets_of(m).len()).sum();
        assert_eq!(total, kg.store.len());
    }
}
