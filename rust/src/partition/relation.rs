//! Relation partitioning (paper §3.4).
//!
//! Greedy algorithm from the paper: sort relations by frequency
//! (non-increasing), assign each to the partition with the fewest triplets
//! so far. Relations whose triplet count exceeds the ideal partition size
//! are *split* equally across all partitions ("most common relations").
//! Per-epoch randomization perturbs the assignment so SGD does not see the
//! same relation↔worker binding forever (paper's fix for reduced
//! stochasticity).

use crate::kg::TripletStore;
use crate::util::rng::Rng;

/// Assignment of triplets (and relations) to `k` computing units.
#[derive(Clone, Debug)]
pub struct RelationPartition {
    pub k: usize,
    /// triplet index → partition
    pub triplet_part: Vec<u32>,
    /// relation → owning partition, or `SPLIT` if split across all
    pub relation_part: Vec<u32>,
    /// number of triplets per partition
    pub sizes: Vec<u64>,
}

/// Marker for relations split across all partitions.
pub const SPLIT: u32 = u32::MAX;

impl RelationPartition {
    /// Distinct relations that partition `p` touches (split relations count
    /// for every partition) — the data-transfer metric of §3.4.
    pub fn relations_touched(&self, p: u32) -> usize {
        self.relation_part
            .iter()
            .filter(|&&rp| rp == p || rp == SPLIT)
            .count()
    }

    /// Triplet indices owned by partition `p`.
    pub fn triplets_of(&self, p: u32) -> Vec<usize> {
        self.triplet_part
            .iter()
            .enumerate()
            .filter(|&(_, &tp)| tp == p)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Build a relation partition for `store` into `k` parts.
///
/// `shuffle_seed` drives the per-epoch randomization: among partitions
/// whose load is within ~5% of the minimum, the tie is broken randomly, so
/// successive epochs produce different but equally balanced assignments.
pub fn partition_relations(store: &TripletStore, k: usize, shuffle_seed: u64) -> RelationPartition {
    assert!(k >= 1);
    let counts = store.relation_counts();
    let n_rel = counts.len();
    let total: u64 = counts.iter().sum();
    let ideal = total.div_ceil(k as u64);

    // sort relations by frequency, non-increasing; randomize ties so the
    // per-epoch assignment varies
    let mut rng = Rng::seed_from_u64(shuffle_seed ^ 0x52_454c);
    let mut order: Vec<u32> = (0..n_rel as u32).collect();
    rng.shuffle(&mut order);
    order.sort_by_key(|&r| std::cmp::Reverse(counts[r as usize]));

    let mut relation_part = vec![0u32; n_rel];
    let mut sizes = vec![0u64; k];
    for &r in &order {
        let c = counts[r as usize];
        if c == 0 {
            // unused relation: assign round-robin, irrelevant for load
            relation_part[r as usize] = (r as usize % k) as u32;
            continue;
        }
        if c > ideal {
            // very frequent relation: split across all partitions
            relation_part[r as usize] = SPLIT;
            for s in sizes.iter_mut() {
                *s += c / k as u64;
            }
            continue;
        }
        // partitions within 5% of the minimum load are tie-broken randomly
        let min = *sizes.iter().min().unwrap();
        let slack = (ideal / 20).max(1);
        let eligible: Vec<usize> =
            (0..k).filter(|&p| sizes[p] <= min.saturating_add(slack)).collect();
        let p = eligible[rng.gen_index(eligible.len())];
        relation_part[r as usize] = p as u32;
        sizes[p] += c;
    }

    // assign triplets: owned relation → its partition; split relation →
    // round-robin by a per-relation counter (equal split, deterministic)
    let mut triplet_part = vec![0u32; store.len()];
    let mut split_cursor = vec![0usize; n_rel];
    for i in 0..store.len() {
        let r = store.rels[i] as usize;
        let rp = relation_part[r];
        triplet_part[i] = if rp == SPLIT {
            let p = (split_cursor[r] % k) as u32;
            split_cursor[r] += 1;
            p
        } else {
            rp
        };
    }
    // recompute exact sizes from the triplet assignment
    let mut sizes = vec![0u64; k];
    for &p in &triplet_part {
        sizes[p as usize] += 1;
    }
    RelationPartition { k, triplet_part, relation_part, sizes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::generator::{generate, GeneratorConfig};
    use crate::kg::Triplet;

    fn store_with_counts(counts: &[u64]) -> TripletStore {
        let mut s = TripletStore::new(4, counts.len());
        for (r, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                s.push(Triplet { head: 0, rel: r as u32, tail: 1 });
            }
        }
        s
    }

    #[test]
    fn balanced_sizes() {
        // needs clearly more relations than partitions for greedy balance
        let cfg = GeneratorConfig { n_relations: 64, ..GeneratorConfig::tiny(2) };
        let kg = generate(&cfg);
        for k in [2, 4, 8] {
            let rp = partition_relations(&kg.store, k, 7);
            let min = *rp.sizes.iter().min().unwrap() as f64;
            let max = *rp.sizes.iter().max().unwrap() as f64;
            assert!(max <= 1.3 * min + 16.0, "k={k} sizes={:?}", rp.sizes);
            let total: u64 = rp.sizes.iter().sum();
            assert_eq!(total as usize, kg.store.len());
        }
    }

    #[test]
    fn each_owned_relation_in_one_partition() {
        let kg = generate(&GeneratorConfig::tiny(3));
        let rp = partition_relations(&kg.store, 4, 1);
        for i in 0..kg.store.len() {
            let r = kg.store.rels[i] as usize;
            if rp.relation_part[r] != SPLIT {
                assert_eq!(rp.triplet_part[i], rp.relation_part[r]);
            }
        }
    }

    #[test]
    fn heavy_relation_split() {
        // one relation with 90 of 100 triplets must be split across k=4
        let s = store_with_counts(&[90, 4, 3, 3]);
        let rp = partition_relations(&s, 4, 0);
        assert_eq!(rp.relation_part[0], SPLIT);
        // split relation spreads its triplets near-evenly
        let min = *rp.sizes.iter().min().unwrap();
        let max = *rp.sizes.iter().max().unwrap();
        assert!(max - min <= 6, "{:?}", rp.sizes);
    }

    #[test]
    fn per_epoch_reshuffle_changes_assignment() {
        let kg = generate(&GeneratorConfig::tiny(4));
        let a = partition_relations(&kg.store, 4, 1);
        let b = partition_relations(&kg.store, 4, 2);
        assert_ne!(a.relation_part, b.relation_part);
        // …but both stay balanced
        for rp in [&a, &b] {
            let min = *rp.sizes.iter().min().unwrap() as f64;
            let max = *rp.sizes.iter().max().unwrap() as f64;
            assert!(max <= 1.3 * min + 16.0);
        }
    }

    #[test]
    fn relations_touched_less_than_total() {
        // with many relations, each partition should touch ~1/k of them —
        // the whole point of §3.4 vs dense relation weights
        let kg = generate(&GeneratorConfig::tiny(5));
        let k = 4;
        let rp = partition_relations(&kg.store, k, 3);
        let n_rel = kg.store.n_relations();
        for p in 0..k as u32 {
            let touched = rp.relations_touched(p);
            assert!(touched < n_rel, "p={p} touched={touched} of {n_rel}");
        }
    }

    #[test]
    fn k1_owns_everything() {
        let kg = generate(&GeneratorConfig::tiny(6));
        let rp = partition_relations(&kg.store, 1, 0);
        assert!(rp.triplet_part.iter().all(|&p| p == 0));
        assert_eq!(rp.sizes[0] as usize, kg.store.len());
    }
}
