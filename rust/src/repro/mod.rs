//! Paper-experiment drivers: regenerate every accuracy table of the
//! evaluation section (`dglke repro --exp table4|table5|...|all`).
//!
//! Timing figures (Fig 3–10) live in `benches/` — see DESIGN.md's
//! experiment index. Each driver prints a paper-style table and writes
//! `results/<exp>.csv`. Absolute values differ from the paper (synthetic
//! datasets, simulated GPUs — see DESIGN.md substitutions); the *shape*
//! (who wins, roughly by how much) is the reproduction target.
//!
//! All drivers build declarative [`RunSpec`]s and run them through
//! [`Session`] — the same code path as the CLI and the benches.

use crate::api::{
    resolve_shape, EvalProtocolSpec, EvalSpec, ParallelMode, Report, RunSpec, Session,
};
use crate::baselines::{run_graphvite, GraphViteConfig};
use crate::dist::PartitionStrategy;
use crate::eval::{evaluate, Metrics};
use crate::kg::Dataset;
use crate::models::ModelKind;
use crate::runtime::{artifacts, BackendKind, Manifest};
use crate::train::worker::ModelState;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct ReproOpts {
    /// multiplies training epochs (1.0 = defaults tuned for this testbed)
    pub scale: f64,
    pub backend: BackendKind,
    pub out_dir: PathBuf,
    pub seed: u64,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts {
            scale: 1.0,
            backend: BackendKind::Xla,
            out_dir: PathBuf::from("results"),
            seed: 0,
        }
    }
}

pub fn run(exp: &str, opts: &ReproOpts) -> Result<()> {
    if !artifacts::available() && opts.backend == BackendKind::Xla {
        bail!("artifacts not built — run `make artifacts` first, or pass --backend native");
    }
    std::fs::create_dir_all(&opts.out_dir)?;
    match exp {
        "table4" => table4(opts),
        "table5" => table5(opts),
        "table6" => table6(opts),
        "table7" => table7(opts),
        "table8" => table89(opts, "fb15k-syn", "table8"),
        "table9" => table89(opts, "wn18-syn", "table9"),
        "all" => {
            for e in ["table4", "table5", "table6", "table7", "table8", "table9"] {
                println!("\n================ {e} ================");
                run(e, opts)?;
            }
            Ok(())
        }
        _ => bail!("unknown experiment {exp}; known: table4..table9, all"),
    }
}

/// One table row's training setup, in spec terms.
struct TableRun {
    model: ModelKind,
    workers: usize,
    epochs: f64,
    degree_frac: f64,
    eval: EvalSpec,
}

fn base_spec(opts: &ReproOpts, dataset: &Dataset, model: ModelKind) -> RunSpec {
    RunSpec {
        dataset: dataset.name.clone(),
        model,
        backend: opts.backend,
        lr: 0.3,
        sync_interval: 200,
        seed: opts.seed,
        ..Default::default()
    }
}

/// Batches needed to cover `epochs` passes over the training set at this
/// spec's resolved batch size.
fn epochs_to_batches(
    opts: &ReproOpts,
    dataset: &Dataset,
    manifest: Option<&Manifest>,
    spec: &RunSpec,
    epochs: f64,
) -> Result<usize> {
    let shape = resolve_shape(manifest, spec)?;
    let total =
        ((dataset.train.len() as f64 * epochs * opts.scale) / shape.step.batch as f64).ceil();
    Ok((total as usize).max(1))
}

/// Shared: train with the session API and evaluate. `manifest` is loaded
/// once per table and reused for every row.
fn train_eval(
    run: &TableRun,
    dataset: &Arc<Dataset>,
    manifest: Option<&Manifest>,
    opts: &ReproOpts,
) -> Result<(Metrics, Report)> {
    let mut spec = base_spec(opts, dataset, run.model);
    spec.mode = ParallelMode::Single { workers: run.workers, gpu: true };
    spec.neg_degree_frac = run.degree_frac;
    spec.eval = Some(run.eval.clone());
    let total = epochs_to_batches(opts, dataset, manifest, &spec, run.epochs)?;
    spec.batches = (total / run.workers).max(1);
    let mut session = Session::with_dataset(spec, dataset.clone())?;
    let report = session
        .train()
        .with_context(|| format!("training {} x{}", run.model.name(), run.workers))?;
    let metrics = report.metrics.expect("eval requested in spec");
    Ok((metrics, report))
}

fn freebase_eval(_seed: u64) -> EvalSpec {
    EvalSpec {
        protocol: EvalProtocolSpec::Sampled { uniform: 1000, degree: 1000 },
        max_triplets: 500,
        n_threads: 4,
    }
}

fn full_eval(_seed: u64, max: usize) -> EvalSpec {
    EvalSpec { protocol: EvalProtocolSpec::FullFiltered, max_triplets: max, n_threads: 4 }
}

fn write_csv(opts: &ReproOpts, name: &str, header: &str, rows: &[String]) -> Result<()> {
    let path = opts.out_dir.join(format!("{name}.csv"));
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    println!("[wrote {}]", path.display());
    Ok(())
}

fn print_metrics_block(label: &str, m: &Metrics) {
    println!("{label:24} {}", m.row());
}

fn metrics_csv(m: &Metrics) -> String {
    format!("{:.4},{:.4},{:.4},{:.2},{:.4}", m.hit10, m.hit3, m.hit1, m.mr, m.mrr)
}

/// Table 4: degree-based negative sampling, with vs without (Freebase).
fn table4(opts: &ReproOpts) -> Result<()> {
    println!("Table 4: degree-based negative sampling on freebase-syn (8 simulated GPUs)");
    let dataset = Arc::new(Dataset::load("freebase-syn:0.02", opts.seed)?);
    println!("  {}", dataset.summary());
    let manifest = crate::api::load_default_manifest()?;
    let mut rows = Vec::new();
    for model in [ModelKind::TransEL2, ModelKind::ComplEx, ModelKind::DistMult] {
        for (tag, frac) in [("with", 0.5), ("w/o", 0.0)] {
            let (m, _) = train_eval(
                &TableRun {
                    model,
                    workers: 8,
                    epochs: 4.0,
                    degree_frac: frac,
                    eval: freebase_eval(opts.seed),
                },
                &dataset,
                manifest.as_ref(),
                opts,
            )?;
            print_metrics_block(&format!("{} {}", model.name(), tag), &m);
            rows.push(format!("{},{},{}", model.name(), tag, metrics_csv(&m)));
        }
    }
    write_csv(opts, "table4", "model,degree_sampling,hit10,hit3,hit1,mr,mrr", &rows)
}

/// Table 5: FB15k accuracy, 1 GPU vs fastest (8 workers).
fn table5(opts: &ReproOpts) -> Result<()> {
    println!("Table 5: fb15k-syn accuracy, 1GPU vs Fastest (8 workers)");
    let dataset = Arc::new(Dataset::load("fb15k-syn", opts.seed)?);
    println!("  {}", dataset.summary());
    let manifest = crate::api::load_default_manifest()?;
    let models = [
        ModelKind::TransEL2,
        ModelKind::DistMult,
        ModelKind::ComplEx,
        ModelKind::RotatE,
        ModelKind::TransR,
    ];
    let mut rows = Vec::new();
    for model in models {
        let max = if model == ModelKind::TransR { 150 } else { 400 };
        for (tag, workers) in [("1GPU", 1usize), ("Fastest", 8)] {
            let (m, _) = train_eval(
                &TableRun {
                    model,
                    workers,
                    epochs: 2.0,
                    degree_frac: 0.0,
                    eval: full_eval(opts.seed, max),
                },
                &dataset,
                manifest.as_ref(),
                opts,
            )?;
            print_metrics_block(&format!("{} {}", model.name(), tag), &m);
            rows.push(format!("{},{},{}", model.name(), tag, metrics_csv(&m)));
        }
    }
    write_csv(opts, "table5", "model,config,hit10,hit3,hit1,mr,mrr", &rows)
}

/// Table 6: Freebase accuracy, 1 GPU vs fastest (8 GPUs / 16 procs).
fn table6(opts: &ReproOpts) -> Result<()> {
    println!("Table 6: freebase-syn accuracy, 1GPU vs Fastest (16 workers on 8 sim-GPUs)");
    let dataset = Arc::new(Dataset::load("freebase-syn:0.02", opts.seed)?);
    println!("  {}", dataset.summary());
    let manifest = crate::api::load_default_manifest()?;
    let models = [
        ModelKind::TransEL2,
        ModelKind::DistMult,
        ModelKind::ComplEx,
        ModelKind::RotatE,
        ModelKind::TransR,
    ];
    let mut rows = Vec::new();
    for model in models {
        let configs: &[(&str, usize)] = if model == ModelKind::TransR {
            &[("Fastest", 8)] // the paper also skips 1-GPU TransR (too slow)
        } else {
            &[("1GPU", 1), ("Fastest", 16)]
        };
        for &(tag, workers) in configs {
            let (m, _) = train_eval(
                &TableRun {
                    model,
                    workers,
                    epochs: 4.0,
                    degree_frac: 0.5,
                    eval: freebase_eval(opts.seed),
                },
                &dataset,
                manifest.as_ref(),
                opts,
            )?;
            print_metrics_block(&format!("{} {}", model.name(), tag), &m);
            rows.push(format!("{},{},{}", model.name(), tag, metrics_csv(&m)));
        }
    }
    write_csv(opts, "table6", "model,config,hit10,hit3,hit1,mr,mrr", &rows)
}

/// Table 7: distributed training accuracy — single vs random vs METIS.
fn table7(opts: &ReproOpts) -> Result<()> {
    println!("Table 7: distributed accuracy on freebase-syn: single / random / METIS");
    let dataset = Arc::new(Dataset::load("freebase-syn:0.02", opts.seed)?);
    println!("  {}", dataset.summary());
    let manifest = crate::api::load_default_manifest()?;
    let mut rows = Vec::new();
    for model in [ModelKind::TransEL2, ModelKind::DistMult] {
        // single machine baseline
        let (m_single, _) = train_eval(
            &TableRun {
                model,
                workers: 8,
                epochs: 4.0,
                degree_frac: 0.0,
                eval: freebase_eval(opts.seed),
            },
            &dataset,
            manifest.as_ref(),
            opts,
        )?;
        print_metrics_block(&format!("{} single", model.name()), &m_single);
        rows.push(format!("{},single,{}", model.name(), metrics_csv(&m_single)));

        for strategy in [PartitionStrategy::Random, PartitionStrategy::Metis] {
            let mut spec = base_spec(opts, &dataset, model);
            spec.mode = ParallelMode::Distributed {
                machines: 4,
                trainers: 2,
                servers: 2,
                partition: strategy,
                local_negatives: true,
            };
            spec.eval = Some(freebase_eval(opts.seed));
            let total = epochs_to_batches(opts, &dataset, manifest.as_ref(), &spec, 4.0)?;
            spec.batches = (total / 8).max(1);
            let mut session = Session::with_dataset(spec, dataset.clone())?;
            let report = session.train()?;
            let m = report.metrics.expect("eval requested in spec");
            print_metrics_block(&format!("{} {}", model.name(), strategy.name()), &m);
            println!(
                "    locality={:.3} remote={:.1}MB local={:.1}MB",
                report.locality,
                report.remote_bytes as f64 / 1e6,
                report.local_bytes as f64 / 1e6
            );
            rows.push(format!("{},{},{}", model.name(), strategy.name(), metrics_csv(&m)));
        }
    }
    write_csv(opts, "table7", "model,config,hit10,hit3,hit1,mr,mrr", &rows)
}

/// Tables 8/9: DGL-KE vs GraphVite-style accuracy at 1/4/8 workers.
fn table89(opts: &ReproOpts, dataset_name: &str, out: &str) -> Result<()> {
    println!("{out}: DGL-KE vs GraphVite-style on {dataset_name}, 1/4/8 simulated GPUs");
    let dataset = Arc::new(Dataset::load(dataset_name, opts.seed)?);
    println!("  {}", dataset.summary());
    let manifest = crate::api::load_default_manifest()?;
    let models = [ModelKind::TransEL2, ModelKind::DistMult, ModelKind::ComplEx, ModelKind::RotatE];
    let mut rows = Vec::new();
    for model in models {
        for workers in [1usize, 4, 8] {
            // DGL-KE through the session API
            let (m, report) = train_eval(
                &TableRun {
                    model,
                    workers,
                    epochs: 2.0,
                    degree_frac: 0.0,
                    eval: full_eval(opts.seed, 300),
                },
                &dataset,
                manifest.as_ref(),
                opts,
            )?;
            print_metrics_block(&format!("{} dglke x{}", model.name(), workers), &m);
            rows.push(format!(
                "{},dglke,{},{},{:.2}",
                model.name(),
                workers,
                metrics_csv(&m),
                report.sim_parallel_secs
            ));

            // GraphVite-style baseline (same total batch budget, same shape)
            let spec = base_spec(opts, &dataset, model);
            let shape = resolve_shape(manifest.as_ref(), &spec)?;
            let total = epochs_to_batches(opts, &dataset, manifest.as_ref(), &spec, 2.0)?;
            let gv_cfg = GraphViteConfig {
                model,
                backend: opts.backend,
                artifact_tag: "default".into(),
                shape: shape.native_override,
                n_workers: workers,
                episode_entities: 4096,
                episode_batches: 40,
                total_batches_per_worker: (total / workers).max(1),
                lr: 0.3,
                seed: opts.seed,
                ..Default::default()
            };
            let gv_state =
                ModelState::init_with(&dataset, model, shape.step.dim, 0.3, 0.37, opts.seed);
            let gv_stats = run_graphvite(&dataset, &gv_state, manifest.as_ref(), &gv_cfg)?;
            let gm = evaluate(
                model,
                &gv_state.entities,
                &gv_state.relations,
                &dataset,
                &dataset.test,
                &full_eval(opts.seed, 300).to_cfg(opts.seed),
            );
            print_metrics_block(&format!("{} graphvite x{}", model.name(), workers), &gm);
            rows.push(format!(
                "{},graphvite,{},{},{:.2}",
                model.name(),
                workers,
                metrics_csv(&gm),
                gv_stats.wall_secs
            ));
        }
    }
    write_csv(opts, out, "model,system,workers,hit10,hit3,hit1,mr,mrr,time_secs", &rows)
}
