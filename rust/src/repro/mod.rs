//! Paper-experiment drivers: regenerate every accuracy table of the
//! evaluation section (`dglke repro --exp table4|table5|...|all`).
//!
//! Timing figures (Fig 3–10) live in `benches/` — see DESIGN.md's
//! experiment index. Each driver prints a paper-style table and writes
//! `results/<exp>.csv`. Absolute values differ from the paper (synthetic
//! datasets, simulated GPUs — see DESIGN.md substitutions); the *shape*
//! (who wins, roughly by how much) is the reproduction target.

use crate::baselines::{run_graphvite, GraphViteConfig};
use crate::dist::{run_distributed, DistConfig, PartitionStrategy};
use crate::eval::{evaluate, EvalConfig, EvalProtocol, Metrics};
use crate::kg::Dataset;
use crate::models::{LossCfg, ModelKind};
use crate::runtime::{artifacts, BackendKind, Manifest};
use crate::train::worker::ModelState;
use crate::train::{run_training, Hardware, TrainConfig};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::PathBuf;

#[derive(Clone, Debug)]
pub struct ReproOpts {
    /// multiplies training epochs (1.0 = defaults tuned for this testbed)
    pub scale: f64,
    pub backend: BackendKind,
    pub out_dir: PathBuf,
    pub seed: u64,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts {
            scale: 1.0,
            backend: BackendKind::Xla,
            out_dir: PathBuf::from("results"),
            seed: 0,
        }
    }
}

pub fn run(exp: &str, opts: &ReproOpts) -> Result<()> {
    if !artifacts::available() && opts.backend == BackendKind::Xla {
        bail!("artifacts not built — run `make artifacts` first");
    }
    std::fs::create_dir_all(&opts.out_dir)?;
    let manifest = Manifest::load(&artifacts::default_dir())?;
    match exp {
        "table4" => table4(opts, &manifest),
        "table5" => table5(opts, &manifest),
        "table6" => table6(opts, &manifest),
        "table7" => table7(opts, &manifest),
        "table8" => table89(opts, &manifest, "fb15k-syn", "table8"),
        "table9" => table89(opts, &manifest, "wn18-syn", "table9"),
        "all" => {
            for e in ["table4", "table5", "table6", "table7", "table8", "table9"] {
                println!("\n================ {e} ================");
                run(e, opts)?;
            }
            Ok(())
        }
        _ => bail!("unknown experiment {exp}; known: table4..table9, all"),
    }
}

/// Shared: train with the main engine and evaluate.
struct RunSpec<'a> {
    dataset: &'a Dataset,
    model: ModelKind,
    workers: usize,
    epochs: f64,
    degree_frac: f64,
    eval: EvalConfig,
}

fn artifact_dim(manifest: &Manifest, model: ModelKind) -> Result<usize> {
    Ok(manifest.find_train(model.name(), "logistic", "default")?.dim)
}

fn train_eval(
    spec: &RunSpec<'_>,
    manifest: &Manifest,
    opts: &ReproOpts,
) -> Result<(Metrics, crate::train::TrainStats)> {
    let art = manifest.find_train(spec.model.name(), "logistic", "default")?;
    let total_batches = ((spec.dataset.train.len() as f64 * spec.epochs * opts.scale)
        / art.batch as f64)
        .ceil()
        .max(1.0) as usize;
    let cfg = TrainConfig {
        model: spec.model,
        loss: LossCfg::default(),
        backend: opts.backend,
        artifact_tag: "default".into(),
        shape: (opts.backend == BackendKind::Native).then_some(
            crate::models::step::StepShape {
                batch: art.batch,
                chunks: art.chunks,
                neg_k: art.neg_k,
                dim: art.dim,
            },
        ),
        n_workers: spec.workers,
        batches_per_worker: (total_batches / spec.workers).max(1),
        lr: 0.3,
        neg_degree_frac: spec.degree_frac,
        hardware: Hardware::Gpu { pcie_gbps: 12.0 },
        sync_interval: 200,
        seed: opts.seed,
        ..Default::default()
    };
    let state = ModelState::init(spec.dataset, spec.model, art.dim, &cfg);
    let stats = run_training(spec.dataset, &state, Some(manifest), &cfg)
        .with_context(|| format!("training {} x{}", spec.model.name(), spec.workers))?;
    let m = evaluate(
        spec.model,
        &state.entities,
        &state.relations,
        spec.dataset,
        &spec.dataset.test,
        &spec.eval,
    );
    Ok((m, stats))
}

fn freebase_eval(seed: u64) -> EvalConfig {
    EvalConfig {
        protocol: EvalProtocol::Sampled { uniform: 1000, degree: 1000 },
        max_triplets: 500,
        n_threads: 4,
        seed,
    }
}

fn full_eval(seed: u64, max: usize) -> EvalConfig {
    EvalConfig {
        protocol: EvalProtocol::FullFiltered,
        max_triplets: max,
        n_threads: 4,
        seed,
    }
}

fn write_csv(opts: &ReproOpts, name: &str, header: &str, rows: &[String]) -> Result<()> {
    let path = opts.out_dir.join(format!("{name}.csv"));
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    println!("[wrote {}]", path.display());
    Ok(())
}

fn print_metrics_block(label: &str, m: &Metrics) {
    println!("{label:24} {}", m.row());
}

/// Table 4: degree-based negative sampling, with vs without (Freebase).
fn table4(opts: &ReproOpts, manifest: &Manifest) -> Result<()> {
    println!("Table 4: degree-based negative sampling on freebase-syn (8 simulated GPUs)");
    let dataset = Dataset::load("freebase-syn:0.02", opts.seed)?;
    println!("  {}", dataset.summary());
    let mut rows = Vec::new();
    for model in [ModelKind::TransEL2, ModelKind::ComplEx, ModelKind::DistMult] {
        for (tag, frac) in [("with", 0.5), ("w/o", 0.0)] {
            let (m, _) = train_eval(
                &RunSpec {
                    dataset: &dataset,
                    model,
                    workers: 8,
                    epochs: 4.0,
                    degree_frac: frac,
                    eval: freebase_eval(opts.seed),
                },
                manifest,
                opts,
            )?;
            print_metrics_block(&format!("{} {}", model.name(), tag), &m);
            rows.push(format!(
                "{},{},{:.4},{:.4},{:.4},{:.2},{:.4}",
                model.name(),
                tag,
                m.hit10,
                m.hit3,
                m.hit1,
                m.mr,
                m.mrr
            ));
        }
    }
    write_csv(opts, "table4", "model,degree_sampling,hit10,hit3,hit1,mr,mrr", &rows)
}

/// Table 5: FB15k accuracy, 1 GPU vs fastest (8 workers).
fn table5(opts: &ReproOpts, manifest: &Manifest) -> Result<()> {
    println!("Table 5: fb15k-syn accuracy, 1GPU vs Fastest (8 workers)");
    let dataset = Dataset::load("fb15k-syn", opts.seed)?;
    println!("  {}", dataset.summary());
    let models = [
        ModelKind::TransEL2,
        ModelKind::DistMult,
        ModelKind::ComplEx,
        ModelKind::RotatE,
        ModelKind::TransR,
    ];
    let mut rows = Vec::new();
    for model in models {
        let max = if model == ModelKind::TransR { 150 } else { 400 };
        for (tag, workers) in [("1GPU", 1usize), ("Fastest", 8)] {
            let (m, _) = train_eval(
                &RunSpec {
                    dataset: &dataset,
                    model,
                    workers,
                    epochs: 2.0,
                    degree_frac: 0.0,
                    eval: full_eval(opts.seed, max),
                },
                manifest,
                opts,
            )?;
            print_metrics_block(&format!("{} {}", model.name(), tag), &m);
            rows.push(format!(
                "{},{},{:.4},{:.4},{:.4},{:.2},{:.4}",
                model.name(),
                tag,
                m.hit10,
                m.hit3,
                m.hit1,
                m.mr,
                m.mrr
            ));
        }
    }
    write_csv(opts, "table5", "model,config,hit10,hit3,hit1,mr,mrr", &rows)
}

/// Table 6: Freebase accuracy, 1 GPU vs fastest (8 GPUs / 16 procs).
fn table6(opts: &ReproOpts, manifest: &Manifest) -> Result<()> {
    println!("Table 6: freebase-syn accuracy, 1GPU vs Fastest (16 workers on 8 sim-GPUs)");
    let dataset = Dataset::load("freebase-syn:0.02", opts.seed)?;
    println!("  {}", dataset.summary());
    let models = [
        ModelKind::TransEL2,
        ModelKind::DistMult,
        ModelKind::ComplEx,
        ModelKind::RotatE,
        ModelKind::TransR,
    ];
    let mut rows = Vec::new();
    for model in models {
        let configs: &[(&str, usize)] = if model == ModelKind::TransR {
            &[("Fastest", 8)] // the paper also skips 1-GPU TransR (too slow)
        } else {
            &[("1GPU", 1), ("Fastest", 16)]
        };
        for &(tag, workers) in configs {
            let (m, _) = train_eval(
                &RunSpec {
                    dataset: &dataset,
                    model,
                    workers,
                    epochs: 4.0,
                    degree_frac: 0.5,
                    eval: freebase_eval(opts.seed),
                },
                manifest,
                opts,
            )?;
            print_metrics_block(&format!("{} {}", model.name(), tag), &m);
            rows.push(format!(
                "{},{},{:.4},{:.4},{:.4},{:.2},{:.4}",
                model.name(),
                tag,
                m.hit10,
                m.hit3,
                m.hit1,
                m.mr,
                m.mrr
            ));
        }
    }
    write_csv(opts, "table6", "model,config,hit10,hit3,hit1,mr,mrr", &rows)
}

/// Table 7: distributed training accuracy — single vs random vs METIS.
fn table7(opts: &ReproOpts, manifest: &Manifest) -> Result<()> {
    println!("Table 7: distributed accuracy on freebase-syn: single / random / METIS");
    let dataset = Dataset::load("freebase-syn:0.02", opts.seed)?;
    println!("  {}", dataset.summary());
    let mut rows = Vec::new();
    for model in [ModelKind::TransEL2, ModelKind::DistMult] {
        let art = manifest.find_train(model.name(), "logistic", "default")?;
        let epochs = 4.0 * opts.scale;
        let total_batches =
            ((dataset.train.len() as f64 * epochs) / art.batch as f64).ceil() as usize;

        // single machine baseline
        let (m_single, _) = train_eval(
            &RunSpec {
                dataset: &dataset,
                model,
                workers: 8,
                epochs: 4.0,
                degree_frac: 0.0,
                eval: freebase_eval(opts.seed),
            },
            manifest,
            opts,
        )?;
        print_metrics_block(&format!("{} single", model.name()), &m_single);

        let mut dist_metrics = Vec::new();
        for strategy in [PartitionStrategy::Random, PartitionStrategy::Metis] {
            let cfg = DistConfig {
                model,
                backend: opts.backend,
                artifact_tag: "default".into(),
                shape: (opts.backend == BackendKind::Native).then_some(
                    crate::models::step::StepShape {
                        batch: art.batch,
                        chunks: art.chunks,
                        neg_k: art.neg_k,
                        dim: art.dim,
                    },
                ),
                machines: 4,
                trainers_per_machine: 2,
                servers_per_machine: 2,
                partition: strategy,
                local_negatives: true,
                batches_per_trainer: (total_batches / 8).max(1),
                lr: 0.3,
                seed: opts.seed,
                ..Default::default()
            };
            let (stats, mut cluster) = run_distributed(&dataset, Some(manifest), &cfg)?;
            let ents = cluster.dump_entities(dataset.n_entities(), art.dim);
            let rels = cluster.dump_relations(dataset.n_relations(), art.rel_dim);
            cluster.shutdown();
            let m = evaluate(model, &ents, &rels, &dataset, &dataset.test, &freebase_eval(opts.seed));
            let name = match strategy {
                PartitionStrategy::Random => "random",
                PartitionStrategy::Metis => "metis",
            };
            print_metrics_block(&format!("{} {}", model.name(), name), &m);
            println!(
                "    locality={:.3} remote={:.1}MB local={:.1}MB",
                stats.locality,
                stats.remote_bytes as f64 / 1e6,
                stats.local_bytes as f64 / 1e6
            );
            dist_metrics.push((name, m));
        }
        rows.push(format!(
            "{},single,{:.4},{:.4},{:.4},{:.2},{:.4}",
            model.name(),
            m_single.hit10,
            m_single.hit3,
            m_single.hit1,
            m_single.mr,
            m_single.mrr
        ));
        for (name, m) in dist_metrics {
            rows.push(format!(
                "{},{},{:.4},{:.4},{:.4},{:.2},{:.4}",
                model.name(),
                name,
                m.hit10,
                m.hit3,
                m.hit1,
                m.mr,
                m.mrr
            ));
        }
    }
    write_csv(opts, "table7", "model,config,hit10,hit3,hit1,mr,mrr", &rows)
}

/// Tables 8/9: DGL-KE vs GraphVite-style accuracy at 1/4/8 workers.
fn table89(opts: &ReproOpts, manifest: &Manifest, dataset_name: &str, out: &str) -> Result<()> {
    println!("{out}: DGL-KE vs GraphVite-style on {dataset_name}, 1/4/8 simulated GPUs");
    let dataset = Dataset::load(dataset_name, opts.seed)?;
    println!("  {}", dataset.summary());
    let models = [ModelKind::TransEL2, ModelKind::DistMult, ModelKind::ComplEx, ModelKind::RotatE];
    let mut rows = Vec::new();
    for model in models {
        let art = manifest.find_train(model.name(), "logistic", "default")?;
        for workers in [1usize, 4, 8] {
            // DGL-KE
            let (m, stats) = train_eval(
                &RunSpec {
                    dataset: &dataset,
                    model,
                    workers,
                    epochs: 2.0,
                    degree_frac: 0.0,
                    eval: full_eval(opts.seed, 300),
                },
                manifest,
                opts,
            )?;
            print_metrics_block(&format!("{} dglke x{}", model.name(), workers), &m);
            rows.push(format!(
                "{},dglke,{},{:.4},{:.4},{:.4},{:.2},{:.4},{:.2}",
                model.name(),
                workers,
                m.hit10,
                m.hit3,
                m.hit1,
                m.mr,
                m.mrr,
                stats.sim_parallel_secs
            ));

            // GraphVite-style (same total batches)
            let total_batches = ((dataset.train.len() as f64 * 2.0 * opts.scale)
                / art.batch as f64)
                .ceil() as usize;
            let gv_cfg = GraphViteConfig {
                model,
                backend: opts.backend,
                artifact_tag: "default".into(),
                shape: (opts.backend == BackendKind::Native).then_some(
                    crate::models::step::StepShape {
                        batch: art.batch,
                        chunks: art.chunks,
                        neg_k: art.neg_k,
                        dim: art.dim,
                    },
                ),
                n_workers: workers,
                episode_entities: 4096,
                episode_batches: 40,
                total_batches_per_worker: (total_batches / workers).max(1),
                lr: 0.3,
                seed: opts.seed,
                ..Default::default()
            };
            let gv_state = ModelState::init(
                &dataset,
                model,
                art.dim,
                &TrainConfig { lr: 0.3, seed: opts.seed, ..Default::default() },
            );
            let gv_stats = run_graphvite(&dataset, &gv_state, Some(manifest), &gv_cfg)?;
            let gm = evaluate(
                model,
                &gv_state.entities,
                &gv_state.relations,
                &dataset,
                &dataset.test,
                &full_eval(opts.seed, 300),
            );
            print_metrics_block(&format!("{} graphvite x{}", model.name(), workers), &gm);
            rows.push(format!(
                "{},graphvite,{},{:.4},{:.4},{:.4},{:.2},{:.4},{:.2}",
                model.name(),
                workers,
                gm.hit10,
                gm.hit3,
                gm.hit1,
                gm.mr,
                gm.mrr,
                gv_stats.wall_secs
            ));
        }
    }
    write_csv(opts, out, "model,system,workers,hit10,hit3,hit1,mr,mrr,time_secs", &rows)
}
