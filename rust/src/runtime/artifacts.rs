//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. `make artifacts` writes `artifacts/manifest.json` plus one
//! HLO-text file per (model, phase, shape); this module indexes them.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct TrainArtifact {
    pub key: String,
    pub file: PathBuf,
    pub model: String,
    pub loss: String,
    pub tag: String,
    pub batch: usize,
    pub chunks: usize,
    pub neg_k: usize,
    pub dim: usize,
    pub rel_dim: usize,
}

#[derive(Clone, Debug)]
pub struct EvalArtifact {
    pub key: String,
    pub file: PathBuf,
    pub model: String,
    pub side: String, // "tail" | "head"
    pub tag: String,
    pub m: usize,
    pub cands: usize,
    pub dim: usize,
    pub rel_dim: usize,
}

#[derive(Debug, Default)]
pub struct Manifest {
    pub train: Vec<TrainArtifact>,
    pub eval: Vec<EvalArtifact>,
}

fn req_usize(j: &Json, k: &str) -> Result<usize> {
    j.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("manifest missing field {k}"))
}

fn req_str(j: &Json, k: &str) -> Result<String> {
    Ok(j.get(k)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest missing field {k}"))?
        .to_string())
}

impl Manifest {
    /// Load `dir/manifest.json`. Fails with a actionable message when the
    /// artifacts have not been built.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("cannot read {} — run `make artifacts` first", path.display())
        })?;
        let j = Json::parse(&text).context("manifest.json is not valid JSON")?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest has no artifacts array"))?;
        let mut m = Manifest::default();
        for a in arts {
            let kind = req_str(a, "kind")?;
            let file = dir.join(req_str(a, "file")?);
            if !file.exists() {
                bail!("artifact file {} listed in manifest but missing", file.display());
            }
            match kind.as_str() {
                "train" => m.train.push(TrainArtifact {
                    key: req_str(a, "key")?,
                    file,
                    model: req_str(a, "model")?,
                    loss: req_str(a, "loss")?,
                    tag: req_str(a, "tag")?,
                    batch: req_usize(a, "batch")?,
                    chunks: req_usize(a, "chunks")?,
                    neg_k: req_usize(a, "neg_k")?,
                    dim: req_usize(a, "dim")?,
                    rel_dim: req_usize(a, "rel_dim")?,
                }),
                "eval_tail" | "eval_head" => m.eval.push(EvalArtifact {
                    key: req_str(a, "key")?,
                    file,
                    model: req_str(a, "model")?,
                    side: kind.trim_start_matches("eval_").to_string(),
                    tag: req_str(a, "tag")?,
                    m: req_usize(a, "m")?,
                    cands: req_usize(a, "cands")?,
                    dim: req_usize(a, "dim")?,
                    rel_dim: req_usize(a, "rel_dim")?,
                }),
                other => bail!("unknown artifact kind {other}"),
            }
        }
        Ok(m)
    }

    /// Find the train artifact for (model, loss, tag).
    pub fn find_train(&self, model: &str, loss: &str, tag: &str) -> Result<&TrainArtifact> {
        self.train
            .iter()
            .find(|a| a.model == model && a.loss == loss && a.tag == tag)
            .ok_or_else(|| {
                anyhow!(
                    "no train artifact for model={model} loss={loss} tag={tag}; \
                     available: {:?}",
                    self.train.iter().map(|a| &a.key).collect::<Vec<_>>()
                )
            })
    }

    pub fn find_eval(&self, model: &str, side: &str, tag: &str) -> Result<&EvalArtifact> {
        self.eval
            .iter()
            .find(|a| a.model == model && a.side == side && a.tag == tag)
            .ok_or_else(|| anyhow!("no eval artifact for model={model} side={side} tag={tag}"))
    }
}

/// Default artifacts directory: $DGLKE_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var_os("DGLKE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if artifacts exist (used by tests to skip gracefully).
pub fn available() -> bool {
    default_dir().join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join(format!("dglke_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("x.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"artifacts":[
              {"kind":"train","key":"k1","file":"x.hlo.txt","model":"transe_l2","loss":"logistic",
               "tag":"tiny","batch":32,"chunks":4,"neg_k":16,"dim":16,"rel_dim":16},
              {"kind":"eval_tail","key":"k2","file":"x.hlo.txt","model":"transe_l2",
               "tag":"tiny","m":8,"cands":64,"dim":16,"rel_dim":16}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.train.len(), 1);
        assert_eq!(m.eval.len(), 1);
        let t = m.find_train("transe_l2", "logistic", "tiny").unwrap();
        assert_eq!(t.batch, 32);
        assert!(m.find_train("nope", "logistic", "tiny").is_err());
        let e = m.find_eval("transe_l2", "tail", "tiny").unwrap();
        assert_eq!(e.cands, 64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join(format!("dglke_manifest2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"kind":"train","key":"k","file":"gone.hlo.txt","model":"m",
              "loss":"l","tag":"t","batch":1,"chunks":1,"neg_k":1,"dim":1,"rel_dim":1}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
