//! Compute backend abstraction: XLA artifacts (production) or the native
//! Rust mirror (tests, fallback, coordinator-overhead isolation).
//!
//! Both implement the same step contract, and a dedicated integration test
//! (`rust/tests/xla_vs_native.rs`) asserts they agree numerically — the
//! cross-layer correctness signal of the whole stack.

use super::artifacts::Manifest;
use super::executor::{TrainExecutor, XlaRuntime};
use crate::models::step::{StepGrads, StepInputs, StepShape};
use crate::models::{KernelBackend, LossCfg, LossKind, ModelKind, NativeModel, StepScratch};
use anyhow::Result;
use std::cell::RefCell;

/// Which backend trainers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT-compiled XLA artifacts via PJRT (the production path).
    Xla,
    /// Pure-Rust mirror of the artifacts.
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "xla" => Some(BackendKind::Xla),
            "native" => Some(BackendKind::Native),
            _ => None,
        }
    }
}

/// A per-worker train-step backend. Construct *inside* the worker thread
/// (the XLA client must not cross threads).
pub enum TrainBackend {
    Xla(TrainExecutor),
    Native {
        model: NativeModel,
        shape: StepShape,
        /// score/grad kernel selection for the native step
        kernels: KernelBackend,
        /// per-worker scratch arena reused across steps. `RefCell` is
        /// sound here: the backend is constructed inside its worker
        /// thread and never shared (the XLA client is `!Send` anyway).
        scratch: RefCell<StepScratch>,
    },
}

impl TrainBackend {
    /// Build for a worker with the scalar reference kernels. `tag`
    /// selects the artifact shape family ("default" or "tiny").
    pub fn create(
        kind: BackendKind,
        model: ModelKind,
        loss: LossCfg,
        manifest: Option<&Manifest>,
        tag: &str,
        shape_override: Option<StepShape>,
    ) -> Result<TrainBackend> {
        Self::create_with_kernels(
            kind,
            model,
            loss,
            manifest,
            tag,
            shape_override,
            KernelBackend::Scalar,
        )
    }

    /// Build for a worker with an explicit kernel backend (native only;
    /// the XLA path compiles its own kernels).
    #[allow(clippy::too_many_arguments)]
    pub fn create_with_kernels(
        kind: BackendKind,
        model: ModelKind,
        loss: LossCfg,
        manifest: Option<&Manifest>,
        tag: &str,
        shape_override: Option<StepShape>,
        kernels: KernelBackend,
    ) -> Result<TrainBackend> {
        match kind {
            BackendKind::Xla => {
                let manifest =
                    manifest.ok_or_else(|| anyhow::anyhow!("XLA backend needs a manifest"))?;
                let loss_name = match loss.kind {
                    LossKind::Logistic => "logistic",
                    LossKind::Margin(_) => "margin",
                };
                let art = manifest.find_train(model.name(), loss_name, tag)?;
                let rt = XlaRuntime::cpu()?;
                Ok(TrainBackend::Xla(TrainExecutor::new(&rt, art)?))
            }
            BackendKind::Native => {
                let shape = shape_override
                    .ok_or_else(|| anyhow::anyhow!("native backend needs an explicit shape"))?;
                Ok(TrainBackend::Native {
                    model: NativeModel::new(model, shape.dim, loss),
                    shape,
                    kernels,
                    scratch: RefCell::new(StepScratch::default()),
                })
            }
        }
    }

    pub fn shape(&self) -> StepShape {
        match self {
            TrainBackend::Xla(e) => e.shape,
            TrainBackend::Native { shape, .. } => *shape,
        }
    }

    pub fn rel_dim(&self) -> usize {
        match self {
            TrainBackend::Xla(e) => e.rel_dim,
            TrainBackend::Native { model, .. } => model.rel_dim(),
        }
    }

    pub fn step(&self, inp: &StepInputs<'_>) -> Result<StepGrads> {
        match self {
            TrainBackend::Xla(e) => e.step(inp),
            TrainBackend::Native { model, shape, kernels, scratch } => {
                Ok(model.train_step_with(shape, inp, *kernels, &mut scratch.borrow_mut()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parse() {
        assert_eq!(BackendKind::parse("xla"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("Native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("gpu"), None);
    }

    #[test]
    fn native_backend_steps() {
        let shape = StepShape { batch: 8, chunks: 2, neg_k: 4, dim: 8 };
        let be = TrainBackend::create(
            BackendKind::Native,
            ModelKind::DistMult,
            LossCfg::default(),
            None,
            "tiny",
            Some(shape),
        )
        .unwrap();
        let mut rng = crate::util::rng::Rng::seed_from_u64(1);
        let mut mk = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.gen_normal()).collect() };
        let h = mk(8 * 8);
        let r = mk(8 * 8);
        let t = mk(8 * 8);
        let nh = mk(2 * 4 * 8);
        let nt = mk(2 * 4 * 8);
        let g = be
            .step(&StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt })
            .unwrap();
        assert!(g.loss.is_finite());
        assert_eq!(g.d_h.len(), 8 * 8);
    }

    #[test]
    fn fused_native_backend_matches_scalar() {
        let shape = StepShape { batch: 8, chunks: 2, neg_k: 4, dim: 8 };
        let mk_backend = |kernels| {
            TrainBackend::create_with_kernels(
                BackendKind::Native,
                ModelKind::TransEL2,
                LossCfg::default(),
                None,
                "tiny",
                Some(shape),
                kernels,
            )
            .unwrap()
        };
        let scalar = mk_backend(KernelBackend::Scalar);
        let fused = mk_backend(KernelBackend::Fused);
        let mut rng = crate::util::rng::Rng::seed_from_u64(9);
        let mut mk = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.gen_normal()).collect() };
        let (h, r, t) = (mk(8 * 8), mk(8 * 8), mk(8 * 8));
        let (nh, nt) = (mk(2 * 4 * 8), mk(2 * 4 * 8));
        let inp = StepInputs { h: &h, r: &r, t: &t, neg_h: &nh, neg_t: &nt };
        // two steps each, so the fused backend's scratch arena is reused
        for _ in 0..2 {
            let a = scalar.step(&inp).unwrap();
            let b = fused.step(&inp).unwrap();
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.d_h, b.d_h);
            assert_eq!(a.d_t, b.d_t);
            assert_eq!(a.d_r, b.d_r);
        }
    }

    #[test]
    fn xla_without_manifest_fails() {
        let shape = StepShape { batch: 8, chunks: 2, neg_k: 4, dim: 8 };
        assert!(TrainBackend::create(
            BackendKind::Xla,
            ModelKind::DistMult,
            LossCfg::default(),
            None,
            "tiny",
            Some(shape),
        )
        .is_err());
    }
}
