//! PJRT execution of the AOT artifacts.
//!
//! One `XlaRuntime` (PJRT CPU client) per trainer thread — the `xla`
//! crate's client is `Rc`-based and must not cross threads, which maps
//! naturally onto the paper's process-per-trainer design. Each trainer
//! compiles its own executable from the shared HLO text at startup
//! (compile once, execute per mini-batch).

use super::artifacts::{EvalArtifact, TrainArtifact};
use crate::models::step::{StepGrads, StepInputs, StepShape};
use anyhow::{Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// Thread-local XLA runtime: a PJRT CPU client.
pub struct XlaRuntime {
    client: PjRtClient,
}

impl XlaRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(XlaRuntime { client: PjRtClient::cpu()? })
    }

    /// Load + compile an HLO-text artifact.
    pub fn compile_file(&self, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let bytes = crate::util::bytes::f32_as_bytes(data);
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)?)
}

/// Compiled train-step executable (one per worker).
pub struct TrainExecutor {
    exe: PjRtLoadedExecutable,
    pub shape: StepShape,
    pub rel_dim: usize,
    pub key: String,
}

impl TrainExecutor {
    pub fn new(rt: &XlaRuntime, art: &TrainArtifact) -> Result<Self> {
        let exe = rt.compile_file(&art.file)?;
        Ok(TrainExecutor {
            exe,
            shape: StepShape {
                batch: art.batch,
                chunks: art.chunks,
                neg_k: art.neg_k,
                dim: art.dim,
            },
            rel_dim: art.rel_dim,
            key: art.key.clone(),
        })
    }

    /// Run one forward+backward step on gathered embeddings.
    pub fn step(&self, inp: &StepInputs<'_>) -> Result<StepGrads> {
        let s = &self.shape;
        let (b, nc, k, d) = (s.batch, s.chunks, s.neg_k, s.dim);
        let rd = self.rel_dim;
        let args = [
            literal_f32(inp.h, &[b, d])?,
            literal_f32(inp.r, &[b, rd])?,
            literal_f32(inp.t, &[b, d])?,
            literal_f32(inp.neg_h, &[nc, k, d])?,
            literal_f32(inp.neg_t, &[nc, k, d])?,
        ];
        let result = self.exe.execute::<Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 6, "train artifact returned {} outputs", outs.len());
        Ok(StepGrads {
            loss: outs[0].get_first_element::<f32>()?,
            d_h: outs[1].to_vec::<f32>()?,
            d_r: outs[2].to_vec::<f32>()?,
            d_t: outs[3].to_vec::<f32>()?,
            d_neg_h: outs[4].to_vec::<f32>()?,
            d_neg_t: outs[5].to_vec::<f32>()?,
        })
    }
}

/// Compiled eval-scoring executable.
pub struct EvalExecutor {
    exe: PjRtLoadedExecutable,
    pub m: usize,
    pub cands: usize,
    pub dim: usize,
    pub rel_dim: usize,
    pub side: String,
}

impl EvalExecutor {
    pub fn new(rt: &XlaRuntime, art: &EvalArtifact) -> Result<Self> {
        let exe = rt.compile_file(&art.file)?;
        Ok(EvalExecutor {
            exe,
            m: art.m,
            cands: art.cands,
            dim: art.dim,
            rel_dim: art.rel_dim,
            side: art.side.clone(),
        })
    }

    /// Score m (entity, relation) rows against the candidate block.
    /// Returns scores [m, cands].
    pub fn scores(&self, e: &[f32], r: &[f32], cand: &[f32]) -> Result<Vec<f32>> {
        let args = [
            literal_f32(e, &[self.m, self.dim])?,
            literal_f32(r, &[self.m, self.rel_dim])?,
            literal_f32(cand, &[self.cands, self.dim])?,
        ];
        let result = self.exe.execute::<Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}
