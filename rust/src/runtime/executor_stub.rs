//! Stub PJRT executor, compiled when the `xla` cargo feature is off (the
//! `xla` crate is not in the vendored dep set). Mirrors the API of
//! `executor.rs` exactly; every entry point fails at runtime with an
//! actionable message. The native backend is unaffected.

use super::artifacts::{EvalArtifact, TrainArtifact};
use crate::models::step::{StepGrads, StepInputs, StepShape};
use anyhow::{bail, Result};

const NO_XLA: &str =
    "built without the `xla` feature — use `--backend native`, or rebuild with \
     `cargo build --features xla` (requires the vendored xla crate)";

/// Thread-local XLA runtime (stub).
pub struct XlaRuntime {
    _private: (),
}

impl XlaRuntime {
    pub fn cpu() -> Result<Self> {
        bail!(NO_XLA)
    }

    pub fn compile_file(&self, _path: &std::path::Path) -> Result<()> {
        bail!(NO_XLA)
    }
}

/// Compiled train-step executable (stub).
pub struct TrainExecutor {
    pub shape: StepShape,
    pub rel_dim: usize,
    pub key: String,
}

impl TrainExecutor {
    pub fn new(_rt: &XlaRuntime, _art: &TrainArtifact) -> Result<Self> {
        bail!(NO_XLA)
    }

    pub fn step(&self, _inp: &StepInputs<'_>) -> Result<StepGrads> {
        bail!(NO_XLA)
    }
}

/// Compiled eval-scoring executable (stub).
pub struct EvalExecutor {
    pub m: usize,
    pub cands: usize,
    pub dim: usize,
    pub rel_dim: usize,
    pub side: String,
}

impl EvalExecutor {
    pub fn new(_rt: &XlaRuntime, _art: &EvalArtifact) -> Result<Self> {
        bail!(NO_XLA)
    }

    pub fn scores(&self, _e: &[f32], _r: &[f32], _cand: &[f32]) -> Result<Vec<f32>> {
        bail!(NO_XLA)
    }
}
