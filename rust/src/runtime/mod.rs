//! PJRT runtime: loads the AOT HLO-text artifacts built by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//! Python never runs at training time.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "xla")]
pub mod executor;
#[cfg(not(feature = "xla"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use artifacts::Manifest;
pub use backend::{BackendKind, TrainBackend};
pub use executor::{EvalExecutor, TrainExecutor, XlaRuntime};
