//! PJRT runtime: loads the AOT HLO-text artifacts built by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//! Python never runs at training time.

pub mod artifacts;
pub mod backend;
pub mod executor;

pub use artifacts::Manifest;
pub use backend::{BackendKind, TrainBackend};
pub use executor::{EvalExecutor, TrainExecutor, XlaRuntime};
