//! Mini-batch sampling: positive triplet batching and the paper's three
//! negative-sampling strategies (§3.3).
//!
//! * **Joint negative sampling** — each chunk of `cs` positives shares `k`
//!   uniformly-sampled negatives, cutting the entities touched per batch
//!   from O(b·k) to O(b + b·k/cs);
//! * **Naive sampling** — the baseline DGL-KE's Fig 3 compares against:
//!   every positive gets its own k negatives (equivalent to chunk size 1);
//! * **Degree-based (in-batch) sampling** — corrupt with entities already
//!   in the mini-batch (∝ in-batch degree), mixed with uniform negatives;
//! * **Local sampling** — restrict the uniform pool to a METIS partition's
//!   local entities so negatives add no network traffic (distributed mode).

pub mod negative;
pub mod positive;

pub use negative::{NegativeConfig, NegativeSampler};
pub use positive::{PositiveSampler, SamplerCursor};

/// One assembled mini-batch of triplet ids (embeddings not yet gathered).
#[derive(Clone, Debug)]
pub struct Batch {
    /// positive heads/relations/tails, len = b = chunks · chunk_size
    pub heads: Vec<u64>,
    pub rels: Vec<u64>,
    pub tails: Vec<u64>,
    /// shared negatives per chunk: [chunks · k] entity ids
    pub neg_heads: Vec<u64>,
    pub neg_tails: Vec<u64>,
    pub chunks: usize,
    pub neg_k: usize,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.heads.len()
    }

    /// Distinct entity ids touched by the batch — the paper's data-access
    /// metric for Fig 3.
    pub fn distinct_entities(&self) -> usize {
        let mut set = std::collections::HashSet::with_capacity(
            self.heads.len() * 2 + self.neg_heads.len() + self.neg_tails.len(),
        );
        set.extend(self.heads.iter().copied());
        set.extend(self.tails.iter().copied());
        set.extend(self.neg_heads.iter().copied());
        set.extend(self.neg_tails.iter().copied());
        set.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_entities_counts() {
        let b = Batch {
            heads: vec![1, 2],
            rels: vec![0, 0],
            tails: vec![2, 3],
            neg_heads: vec![4, 1],
            neg_tails: vec![5, 5],
            chunks: 1,
            neg_k: 2,
        };
        assert_eq!(b.distinct_entities(), 5); // {1,2,3,4,5}
    }
}
