//! Negative sampling strategies (paper §3.3).

use super::Batch;
use crate::kg::TripletStore;
use crate::util::rng::Rng;

/// Negative-sampling configuration.
#[derive(Clone, Debug)]
pub struct NegativeConfig {
    /// negatives per chunk (per corruption side)
    pub k: usize,
    /// positives per chunk (g in the paper); chunk count = b / chunk_size.
    /// chunk_size = 1 reproduces *naive* independent sampling.
    pub chunk_size: usize,
    /// fraction of negatives drawn from the mini-batch's own entities
    /// (∝ in-batch degree — the paper's "hard negative" strategy); the
    /// rest are uniform.
    pub degree_frac: f64,
    /// optional restricted uniform pool (partition-local entities for
    /// distributed training); `None` = all entities
    pub local_pool: Option<std::sync::Arc<Vec<u32>>>,
}

impl Default for NegativeConfig {
    fn default() -> Self {
        NegativeConfig { k: 64, chunk_size: 64, degree_frac: 0.0, local_pool: None }
    }
}

/// Stateful negative sampler (one per trainer thread). `Clone` forks the
/// full RNG state, so a clone replays the exact same negative stream —
/// used by the prefetch pipeline to move sampling onto a helper thread
/// without changing the drawn sequence.
#[derive(Clone)]
pub struct NegativeSampler {
    cfg: NegativeConfig,
    n_entities: u64,
    rng: Rng,
}

impl NegativeSampler {
    pub fn new(cfg: NegativeConfig, n_entities: usize, seed: u64) -> Self {
        assert!(cfg.k > 0 && cfg.chunk_size > 0);
        NegativeSampler { cfg, n_entities: n_entities as u64, rng: Rng::seed_from_u64(seed ^ 0x4e45_47) }
    }

    pub fn config(&self) -> &NegativeConfig {
        &self.cfg
    }

    /// Draw one uniform entity (from the local pool when configured).
    #[inline]
    fn uniform_entity(&mut self) -> u64 {
        match &self.cfg.local_pool {
            Some(pool) => pool[self.rng.gen_index(pool.len())] as u64,
            None => self.rng.gen_range(self.n_entities),
        }
    }

    /// Assemble a full batch from positive triplet indices.
    ///
    /// Degree-based negatives are drawn from the batch's own triplets:
    /// we uniformly sample a *triplet* of the batch and take its head
    /// (resp. tail) — per the paper this induces sampling ∝ in-batch
    /// entity degree.
    pub fn assemble(&mut self, store: &TripletStore, pos_idx: &[u32]) -> Batch {
        let b = pos_idx.len();
        let cs = self.cfg.chunk_size.min(b);
        assert!(b % cs == 0, "batch {b} not divisible by chunk size {cs}");
        let chunks = b / cs;
        let k = self.cfg.k;

        let mut heads = Vec::with_capacity(b);
        let mut rels = Vec::with_capacity(b);
        let mut tails = Vec::with_capacity(b);
        for &i in pos_idx {
            let t = store.get(i as usize);
            heads.push(t.head as u64);
            rels.push(t.rel as u64);
            tails.push(t.tail as u64);
        }

        let n_deg = ((k as f64) * self.cfg.degree_frac).round() as usize;
        let mut neg_heads = Vec::with_capacity(chunks * k);
        let mut neg_tails = Vec::with_capacity(chunks * k);
        for _c in 0..chunks {
            for j in 0..k {
                if j < n_deg {
                    // in-batch (degree-proportional) corruption
                    let pick = self.rng.gen_index(b);
                    neg_heads.push(heads[pick]);
                    let pick = self.rng.gen_index(b);
                    neg_tails.push(tails[pick]);
                } else {
                    neg_heads.push(self.uniform_entity());
                    neg_tails.push(self.uniform_entity());
                }
            }
        }
        Batch { heads, rels, tails, neg_heads, neg_tails, chunks, neg_k: k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::generator::{generate, GeneratorConfig};

    fn setup() -> (crate::kg::TripletStore, Vec<u32>) {
        let kg = generate(&GeneratorConfig::tiny(1));
        let idx: Vec<u32> = (0..128).collect();
        (kg.store, idx)
    }

    #[test]
    fn shapes() {
        let (store, idx) = setup();
        let cfg = NegativeConfig { k: 16, chunk_size: 32, ..Default::default() };
        let mut s = NegativeSampler::new(cfg, store.n_entities(), 1);
        let b = s.assemble(&store, &idx);
        assert_eq!(b.batch_size(), 128);
        assert_eq!(b.chunks, 4);
        assert_eq!(b.neg_heads.len(), 4 * 16);
        assert_eq!(b.neg_tails.len(), 4 * 16);
    }

    #[test]
    fn joint_touches_fewer_entities_than_naive() {
        // large entity space so distinct-entity counts don't saturate
        let mut store = crate::kg::TripletStore::new(1_000_000, 1);
        let mut rng = crate::util::rng::Rng::seed_from_u64(11);
        for _ in 0..128 {
            store.push(crate::kg::Triplet {
                head: rng.gen_index(1_000_000) as u32,
                rel: 0,
                tail: rng.gen_index(1_000_000) as u32,
            });
        }
        let idx: Vec<u32> = (0..128).collect();
        let joint = NegativeConfig { k: 32, chunk_size: 64, ..Default::default() };
        let naive = NegativeConfig { k: 32, chunk_size: 1, ..Default::default() };
        let bj = NegativeSampler::new(joint, store.n_entities(), 2).assemble(&store, &idx);
        let bn = NegativeSampler::new(naive, store.n_entities(), 2).assemble(&store, &idx);
        // the headline O(bd + bkd/g) vs O(bdk) effect
        assert!(
            bj.distinct_entities() * 4 < bn.distinct_entities(),
            "joint={} naive={}",
            bj.distinct_entities(),
            bn.distinct_entities()
        );
    }

    #[test]
    fn degree_based_negatives_come_from_batch() {
        let (store, idx) = setup();
        let cfg = NegativeConfig { k: 8, chunk_size: 128, degree_frac: 1.0, ..Default::default() };
        let mut s = NegativeSampler::new(cfg, store.n_entities(), 3);
        let b = s.assemble(&store, &idx);
        let batch_heads: std::collections::HashSet<u64> = b.heads.iter().copied().collect();
        let batch_tails: std::collections::HashSet<u64> = b.tails.iter().copied().collect();
        assert!(b.neg_heads.iter().all(|h| batch_heads.contains(h)));
        assert!(b.neg_tails.iter().all(|t| batch_tails.contains(t)));
    }

    #[test]
    fn local_pool_respected() {
        let (store, idx) = setup();
        let pool: Vec<u32> = (0..50).collect();
        let cfg = NegativeConfig {
            k: 16,
            chunk_size: 64,
            degree_frac: 0.0,
            local_pool: Some(std::sync::Arc::new(pool)),
        };
        let mut s = NegativeSampler::new(cfg, store.n_entities(), 4);
        let b = s.assemble(&store, &idx);
        assert!(b.neg_heads.iter().all(|&h| h < 50));
        assert!(b.neg_tails.iter().all(|&t| t < 50));
    }

    #[test]
    fn degree_proportionality() {
        // an entity appearing twice as often in the batch should be
        // sampled roughly twice as often as negatives
        let mut store = crate::kg::TripletStore::new(10, 1);
        // entity 0 in 4 triplet-tails, entity 1 in 2, entity 2 in 1
        for (h, t) in [(3, 0), (4, 0), (5, 0), (6, 0), (7, 1), (8, 1), (9, 2)] {
            store.push(crate::kg::Triplet { head: h, rel: 0, tail: t });
        }
        let idx: Vec<u32> = (0..7).collect();
        let cfg = NegativeConfig { k: 1000, chunk_size: 7, degree_frac: 1.0, ..Default::default() };
        // chunk_size=7 won't divide... use full batch = 7, cs=7
        let mut s = NegativeSampler::new(cfg, 10, 5);
        let b = s.assemble(&store, &idx);
        let c0 = b.neg_tails.iter().filter(|&&t| t == 0).count() as f64;
        let c1 = b.neg_tails.iter().filter(|&&t| t == 1).count() as f64;
        assert!((c0 / c1 - 2.0).abs() < 0.6, "c0={c0} c1={c1}");
    }
}
