//! Positive triplet sampling: epoch-shuffled traversal of a (local)
//! triplet set.
//!
//! Each trainer owns a disjoint set of triplet indices (its relation
//! partition within a machine, or its METIS partition's triplets in
//! distributed mode) and iterates them in a reshuffled order every epoch —
//! the paper's step (1).

use crate::kg::TripletStore;
use crate::util::rng::Rng;

/// A resumable snapshot of a [`PositiveSampler`]'s draw position: the
/// current epoch permutation, the cursor into it, and the RNG state that
/// will produce every future reshuffle. Seeking a sampler to a cursor
/// replays the exact batch id sequence from the snapshot point — across
/// epoch boundaries included. This replay-determinism contract is what
/// lets the prefetch pipeline hand a `Clone` of the cursors to a helper
/// thread and still draw the sequential loop's exact sequence (asserted
/// by the tests below); snapshot/seek is the explicit form of the same
/// contract for callers that need to rewind rather than fork.
#[derive(Clone, Debug)]
pub struct SamplerCursor {
    indices: Vec<u32>,
    cursor: usize,
    epoch: u64,
    rng: Rng,
}

#[derive(Clone)]
pub struct PositiveSampler {
    /// triplet indices this sampler may draw from
    indices: Vec<u32>,
    cursor: usize,
    epoch: u64,
    rng: Rng,
}

impl PositiveSampler {
    /// Sampler over all triplets of `store`.
    pub fn over_all(store: &TripletStore, seed: u64) -> Self {
        Self::over_indices((0..store.len() as u32).collect(), seed)
    }

    /// Sampler over an explicit index set (a partition).
    pub fn over_indices(indices: Vec<u32>, seed: u64) -> Self {
        let mut s = PositiveSampler {
            indices,
            cursor: 0,
            epoch: 0,
            rng: Rng::seed_from_u64(seed ^ 0x505f53),
        };
        s.rng.shuffle(&mut s.indices);
        s
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Replace the index set (used when the relation partition is
    /// recomputed at an epoch boundary, §3.4).
    pub fn reset_indices(&mut self, indices: Vec<u32>) {
        self.indices = indices;
        self.rng.shuffle(&mut self.indices);
        self.cursor = 0;
    }

    /// Snapshot the draw position (see [`SamplerCursor`]).
    pub fn cursor_state(&self) -> SamplerCursor {
        SamplerCursor {
            indices: self.indices.clone(),
            cursor: self.cursor,
            epoch: self.epoch,
            rng: self.rng.clone(),
        }
    }

    /// Restore a snapshot taken with [`PositiveSampler::cursor_state`]:
    /// the sampler replays the exact same id sequence the snapshotted
    /// sampler would produce, including future epoch reshuffles.
    pub fn seek(&mut self, state: &SamplerCursor) {
        self.indices = state.indices.clone();
        self.cursor = state.cursor;
        self.epoch = state.epoch;
        self.rng = state.rng.clone();
    }

    /// Draw the next `b` triplet indices, reshuffling at epoch boundaries.
    /// Returns the drawn indices and whether an epoch boundary was crossed.
    pub fn next_batch(&mut self, b: usize, out: &mut Vec<u32>) -> bool {
        out.clear();
        assert!(!self.indices.is_empty(), "empty positive sampler");
        let mut crossed = false;
        while out.len() < b {
            if self.cursor >= self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.cursor = 0;
                self.epoch += 1;
                crossed = true;
            }
            let take = (b - out.len()).min(self.indices.len() - self.cursor);
            out.extend_from_slice(&self.indices[self.cursor..self.cursor + take]);
            self.cursor += take;
        }
        crossed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::generator::{generate, GeneratorConfig};

    #[test]
    fn covers_all_indices_each_epoch() {
        let kg = generate(&GeneratorConfig::tiny(1));
        let n = kg.store.len();
        let mut s = PositiveSampler::over_all(&kg.store, 3);
        let mut seen = vec![0u32; n];
        let b = 64;
        let mut buf = Vec::new();
        let mut drawn = 0;
        while drawn < n {
            let take = b.min(n - drawn);
            s.next_batch(take, &mut buf);
            for &i in &buf {
                seen[i as usize] += 1;
            }
            drawn += take;
        }
        assert!(seen.iter().all(|&c| c == 1), "each triplet exactly once per epoch");
    }

    #[test]
    fn epoch_boundary_reported() {
        let mut s = PositiveSampler::over_indices((0..10).collect(), 1);
        let mut buf = Vec::new();
        assert!(!s.next_batch(8, &mut buf));
        assert!(s.next_batch(8, &mut buf)); // wraps
        assert_eq!(s.epoch(), 1);
        assert_eq!(buf.len(), 8);
    }

    #[test]
    fn epochs_reshuffled() {
        let mut s = PositiveSampler::over_indices((0..100).collect(), 2);
        let mut a = Vec::new();
        s.next_batch(100, &mut a);
        let mut b = Vec::new();
        s.next_batch(100, &mut b);
        assert_ne!(a, b);
        let mut bs = b.clone();
        bs.sort_unstable();
        assert_eq!(bs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn cloned_cursor_replays_sequence_across_epochs() {
        // clone mid-epoch, then both samplers must emit identical batches
        // through several epoch-boundary reshuffles
        let mut a = PositiveSampler::over_indices((0..37).collect(), 5);
        let mut warm = Vec::new();
        a.next_batch(10, &mut warm); // advance into the first epoch
        let mut b = a.clone();
        let (mut ba, mut bb) = (Vec::new(), Vec::new());
        for _ in 0..20 {
            let ca = a.next_batch(8, &mut ba);
            let cb = b.next_batch(8, &mut bb);
            assert_eq!(ba, bb, "cloned cursor diverged");
            assert_eq!(ca, cb);
            assert_eq!(a.epoch(), b.epoch());
        }
        assert!(a.epoch() >= 4, "test should cross several epochs");
    }

    #[test]
    fn seeked_cursor_replays_sequence() {
        let mut a = PositiveSampler::over_indices((0..50).collect(), 9);
        let mut buf = Vec::new();
        a.next_batch(13, &mut buf);
        let snap = a.cursor_state();
        // drain A past an epoch boundary, recording the sequence
        let mut expect = Vec::new();
        for _ in 0..12 {
            a.next_batch(13, &mut buf);
            expect.push(buf.clone());
        }
        // a fresh differently-seeded sampler seeked to the snapshot must
        // replay the exact same sequence
        let mut c = PositiveSampler::over_indices((0..50).collect(), 12345);
        c.seek(&snap);
        for want in &expect {
            c.next_batch(13, &mut buf);
            assert_eq!(&buf, want, "seeked cursor diverged");
        }
    }

    #[test]
    fn cloned_cursor_replays_after_reshuffle_reset() {
        // an epoch-boundary partition reshuffle (reset_indices) keeps a
        // cloned cursor in lockstep as long as both apply the same reset
        let mut a = PositiveSampler::over_indices((0..30).collect(), 7);
        let mut buf = Vec::new();
        a.next_batch(7, &mut buf);
        let mut b = a.clone();
        let new_part: Vec<u32> = (10..40).collect();
        a.reset_indices(new_part.clone());
        b.reset_indices(new_part);
        let (mut ba, mut bb) = (Vec::new(), Vec::new());
        for _ in 0..10 {
            a.next_batch(9, &mut ba);
            b.next_batch(9, &mut bb);
            assert_eq!(ba, bb, "diverged after reset_indices");
        }
    }

    #[test]
    fn partition_scoped() {
        let idx = vec![5u32, 9, 13];
        let mut s = PositiveSampler::over_indices(idx.clone(), 7);
        let mut buf = Vec::new();
        s.next_batch(9, &mut buf);
        assert!(buf.iter().all(|i| idx.contains(i)));
    }
}
