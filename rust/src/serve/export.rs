//! TSV export from a format-2 checkpoint (`dglke export --tsv`).
//!
//! Writes `entities.tsv` / `relations.tsv`, one row per embedding:
//! the row id, then `dim` tab-separated f32 values. Row ids are the
//! canonical dense ids the trainer uses (the vocab stores only content
//! hashes, not the original strings — `docs/SERVING.md`). Values are
//! printed with Rust's `f32` `Display`, which is shortest-round-trip:
//! parsing the text back with `str::parse::<f32>` reproduces the stored
//! bits exactly, so the TSV is a lossless interchange format.

use super::snapshot::Snapshot;
use crate::store::EmbeddingStore;
use anyhow::{Context, Result};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Export both tables of an opened snapshot as TSV into `out_dir`
/// (created if missing). Returns the two file paths
/// (`entities.tsv`, `relations.tsv`).
pub fn export_tsv(snap: &Snapshot, out_dir: &Path) -> Result<(PathBuf, PathBuf)> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let ents = out_dir.join("entities.tsv");
    let rels = out_dir.join("relations.tsv");
    write_table_tsv(snap.entities(), &ents)?;
    write_table_tsv(snap.relations(), &rels)?;
    Ok((ents, rels))
}

/// Stream one table: `id\tv0\tv1...\n` per row, buffered writes, one
/// scratch row — no table-sized allocation.
fn write_table_tsv(table: &Arc<dyn EmbeddingStore>, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    let dim = table.dim();
    let mut row = vec![0f32; dim];
    for i in 0..table.rows() {
        // lint:allow(ledger-billing) — offline export streams the table
        // once after training; the ledgers audit train/serve traffic
        table.read_row(i, &mut row);
        write!(w, "{i}")?;
        for v in &row {
            write!(w, "\t{v}")?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelKind;
    use crate::serve::manifest::{CheckpointManifest, ChunkInfo, TableInfo, FORMAT_VERSION};

    fn tmp_dir(tag: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("dglke-export-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_chunk(path: &Path, vals: &[f32]) {
        let mut bytes = (vals.len() as u64).to_le_bytes().to_vec();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, &bytes).unwrap();
    }

    /// 4 entities x dim 3 split across two chunks, 2 relations x dim 3;
    /// values chosen to stress Display round-trip: subnormals, repeating
    /// fractions, large magnitudes, negative zero.
    fn write_fixture(dir: &Path) -> CheckpointManifest {
        let e: Vec<f32> = vec![
            0.1,
            1.0 / 3.0,
            -2.5e10,
            f32::MIN_POSITIVE,
            1.0e-40, // subnormal
            -0.0,
            123456.78,
            core::f32::consts::PI,
            f32::MAX,
            -1.0e-7,
            2.0f32.powi(-24),
            9.999999,
        ];
        write_chunk(&dir.join("entities.00000.f32"), &e[..9]);
        write_chunk(&dir.join("entities.00001.f32"), &e[9..]);
        write_chunk(&dir.join("relations.f32"), &[7.25, -0.333333343, 1e-5, 42.0, 0.0, -3.5]);
        let m = CheckpointManifest {
            format_version: FORMAT_VERSION,
            model: ModelKind::TransEL2,
            dataset: "fixture".to_string(),
            dim: 3,
            rel_dim: 3,
            n_entities: 4,
            n_relations: 2,
            seed: 0,
            entity_vocab_hash: "fnv1a:0000000000000000".to_string(),
            relation_vocab_hash: "fnv1a:0000000000000000".to_string(),
            entities: TableInfo {
                rows: 4,
                dim: 3,
                chunks: vec![
                    ChunkInfo { file: "entities.00000.f32".to_string(), rows: 3 },
                    ChunkInfo { file: "entities.00001.f32".to_string(), rows: 1 },
                ],
            },
            relations: TableInfo::single("relations.f32", 2, 3),
        };
        m.save(dir).unwrap();
        m
    }

    fn parse_tsv(path: &Path) -> Vec<(usize, Vec<f32>)> {
        std::fs::read_to_string(path)
            .unwrap()
            .lines()
            .map(|line| {
                let mut cols = line.split('\t');
                let id: usize = cols.next().unwrap().parse().unwrap();
                (id, cols.map(|c| c.parse::<f32>().unwrap()).collect())
            })
            .collect()
    }

    #[test]
    fn tsv_round_trips_bit_exactly() {
        let dir = tmp_dir("roundtrip");
        write_fixture(&dir);
        let snap = Snapshot::open(&dir).unwrap();
        let out = dir.join("tsv");
        let (e_path, r_path) = export_tsv(&snap, &out).unwrap();

        for (path, table) in
            [(&e_path, snap.entities().clone()), (&r_path, snap.relations().clone())]
        {
            let rows = parse_tsv(path);
            assert_eq!(rows.len(), table.rows());
            for (i, (id, vals)) in rows.iter().enumerate() {
                assert_eq!(*id, i, "ids are dense row indices in order");
                let want = table.row_vec(i);
                assert_eq!(vals.len(), want.len());
                for (a, b) in vals.iter().zip(&want) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "row {i}: parsed {a:?} != stored {b:?} (Display must round-trip)"
                    );
                }
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn export_creates_out_dir_and_overwrites() {
        let dir = tmp_dir("overwrite");
        write_fixture(&dir);
        let snap = Snapshot::open(&dir).unwrap();
        let out = dir.join("deep").join("nested");
        export_tsv(&snap, &out).unwrap();
        // second export overwrites in place
        let (e_path, _) = export_tsv(&snap, &out).unwrap();
        assert_eq!(parse_tsv(&e_path).len(), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
