//! Versioned checkpoint manifests (`manifest.json`).
//!
//! A format-2 checkpoint directory contains:
//!
//! * `manifest.json` — this manifest: format version, model kind, dims,
//!   table counts, vocab hashes, and the chunked table file list;
//! * one or more table chunk files (`entities.f32`, `relations.f32`, or
//!   `entities.00000.f32` … when exported chunked), each framed as
//!   `[u64 LE value-count][LE f32 rows]` — the same framing format-1
//!   checkpoints used, so a single-chunk format-2 checkpoint's table
//!   files are byte-identical to the legacy layout;
//! * `checkpoint.json` — the legacy format-1 metadata, still written by
//!   single-file exports so pre-manifest readers keep working.
//!
//! Everything here validates *before* anyone touches table bytes: a
//! loader first checks the format version, then the manifest's internal
//! consistency ([`CheckpointManifest::validate`]), then every chunk
//! file's existence, size, and header
//! ([`CheckpointManifest::validate_files`]) — so a truncated or
//! mismatched checkpoint is rejected with context and without partially
//! mutating the destination tables.

use crate::kg::Vocab;
use crate::models::ModelKind;
use crate::store::{chunk_rows_for, EmbeddingStore};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Current checkpoint format version. Format 1 is the legacy
/// `checkpoint.json`-only layout (no manifest, no vocab hashes); format 2
/// adds `manifest.json` with chunked tables. Loaders reject anything
/// newer (can't know the layout) and manifests claiming anything older
/// (format 1 has no manifest by definition, so an old version number in
/// a manifest means the file is corrupt or hand-edited).
pub const FORMAT_VERSION: u64 = 2;

/// Every table chunk file starts with a `u64` little-endian value count.
pub const TABLE_HEADER_BYTES: u64 = 8;

/// One table chunk file: `rows` consecutive rows in `file`.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkInfo {
    /// file name relative to the checkpoint directory
    pub file: String,
    pub rows: usize,
}

/// One embedding table: total shape plus its ordered chunk list.
#[derive(Clone, Debug, PartialEq)]
pub struct TableInfo {
    pub rows: usize,
    pub dim: usize,
    pub chunks: Vec<ChunkInfo>,
}

impl TableInfo {
    /// A single-file table (the layout `export_embeddings` writes).
    pub fn single(file: &str, rows: usize, dim: usize) -> TableInfo {
        TableInfo { rows, dim, chunks: vec![ChunkInfo { file: file.to_string(), rows }] }
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("rows".to_string(), Json::Num(self.rows as f64));
        m.insert("dim".to_string(), Json::Num(self.dim as f64));
        m.insert(
            "chunks".to_string(),
            Json::Arr(
                self.chunks
                    .iter()
                    .map(|c| {
                        let mut cm = BTreeMap::new();
                        cm.insert("file".to_string(), Json::Str(c.file.clone()));
                        cm.insert("rows".to_string(), Json::Num(c.rows as f64));
                        Json::Obj(cm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    fn from_json(label: &str, j: &Json) -> Result<TableInfo> {
        let rows = req_usize(j, "rows").with_context(|| format!("manifest table {label}"))?;
        let dim = req_usize(j, "dim").with_context(|| format!("manifest table {label}"))?;
        let chunks_json = j
            .get("chunks")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest table {label} missing chunks array"))?;
        let mut chunks = Vec::with_capacity(chunks_json.len());
        for (i, c) in chunks_json.iter().enumerate() {
            let file = c
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest table {label} chunk {i} missing file"))?
                .to_string();
            let rows =
                req_usize(c, "rows").with_context(|| format!("manifest table {label} chunk {i}"))?;
            chunks.push(ChunkInfo { file, rows });
        }
        Ok(TableInfo { rows, dim, chunks })
    }
}

/// The `manifest.json` of a format-2 checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointManifest {
    pub format_version: u64,
    pub model: ModelKind,
    pub dataset: String,
    pub dim: usize,
    pub rel_dim: usize,
    pub n_entities: usize,
    pub n_relations: usize,
    pub seed: u64,
    /// [`vocab_hash`] of the entity vocabulary (names in id order)
    pub entity_vocab_hash: String,
    /// [`vocab_hash`] of the relation vocabulary
    pub relation_vocab_hash: String,
    pub entities: TableInfo,
    pub relations: TableInfo,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key).and_then(Json::as_usize).ok_or_else(|| anyhow!("missing or non-numeric {key:?}"))
}

impl CheckpointManifest {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("format_version".to_string(), Json::Num(self.format_version as f64));
        m.insert("model".to_string(), Json::Str(self.model.name().to_string()));
        m.insert("dataset".to_string(), Json::Str(self.dataset.clone()));
        m.insert("dim".to_string(), Json::Num(self.dim as f64));
        m.insert("rel_dim".to_string(), Json::Num(self.rel_dim as f64));
        m.insert("n_entities".to_string(), Json::Num(self.n_entities as f64));
        m.insert("n_relations".to_string(), Json::Num(self.n_relations as f64));
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        m.insert("entity_vocab_hash".to_string(), Json::Str(self.entity_vocab_hash.clone()));
        m.insert("relation_vocab_hash".to_string(), Json::Str(self.relation_vocab_hash.clone()));
        m.insert("entities".to_string(), self.entities.to_json());
        m.insert("relations".to_string(), self.relations.to_json());
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<CheckpointManifest> {
        let format_version = j
            .get("format_version")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("manifest missing format_version"))?
            as u64;
        if format_version != FORMAT_VERSION {
            bail!(
                "unsupported checkpoint format version {format_version} (this build reads \
                 version {FORMAT_VERSION}; re-export the checkpoint with a matching build)"
            );
        }
        let model_name = j
            .get("model")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing model"))?;
        let model = ModelKind::parse(model_name)
            .ok_or_else(|| anyhow!("manifest names unknown model {model_name:?}"))?;
        let dataset =
            j.get("dataset").and_then(Json::as_str).unwrap_or("unknown").to_string();
        let req_str = |key: &str| -> Result<String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("manifest missing {key}"))
        };
        Ok(CheckpointManifest {
            format_version,
            model,
            dataset,
            dim: req_usize(j, "dim")?,
            rel_dim: req_usize(j, "rel_dim")?,
            n_entities: req_usize(j, "n_entities")?,
            n_relations: req_usize(j, "n_relations")?,
            seed: j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            entity_vocab_hash: req_str("entity_vocab_hash")?,
            relation_vocab_hash: req_str("relation_vocab_hash")?,
            entities: TableInfo::from_json("entities", j.get("entities").unwrap_or(&Json::Null))?,
            relations: TableInfo::from_json(
                "relations",
                j.get("relations").unwrap_or(&Json::Null),
            )?,
        })
    }

    /// Read and parse `dir/manifest.json`, including the format-version
    /// gate (a stale or future version is rejected with context).
    pub fn load(dir: &Path) -> Result<CheckpointManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow!("bad manifest.json in {}: {e}", dir.display()))?;
        Self::from_json(&json).with_context(|| format!("validating {}", path.display()))
    }

    /// Write `dir/manifest.json`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let path = dir.join("manifest.json");
        std::fs::write(&path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Internal consistency: dims agree with the model, table shapes
    /// agree with the counts, chunk row sums cover each table exactly.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.model.validate_dim(self.dim),
            "manifest dim {} is invalid for model {}",
            self.dim,
            self.model.name()
        );
        anyhow::ensure!(
            self.rel_dim == self.model.rel_dim(self.dim),
            "manifest rel_dim {} does not match model {} at dim {} (expected {})",
            self.rel_dim,
            self.model.name(),
            self.dim,
            self.model.rel_dim(self.dim)
        );
        for (label, table, rows, dim) in [
            ("entities", &self.entities, self.n_entities, self.dim),
            ("relations", &self.relations, self.n_relations, self.rel_dim),
        ] {
            anyhow::ensure!(
                table.rows == rows,
                "manifest {label} table has {} rows but n_{label} is {rows}",
                table.rows
            );
            anyhow::ensure!(
                table.dim == dim,
                "manifest {label} table dim {} does not match declared dim {dim}",
                table.dim
            );
            anyhow::ensure!(!table.chunks.is_empty(), "manifest {label} table has no chunks");
            let sum: usize = table.chunks.iter().map(|c| c.rows).sum();
            anyhow::ensure!(
                sum == table.rows,
                "manifest {label} chunks sum to {sum} rows, table declares {}",
                table.rows
            );
        }
        Ok(())
    }

    /// Check every chunk file on disk — existence, exact size, and the
    /// `u64` value-count header — *before* any loader mutates a table.
    pub fn validate_files(&self, dir: &Path) -> Result<()> {
        for (label, table) in [("entities", &self.entities), ("relations", &self.relations)] {
            for chunk in &table.chunks {
                let path = dir.join(&chunk.file);
                let values = chunk.rows as u64 * table.dim as u64;
                let need = TABLE_HEADER_BYTES + values * 4;
                let len = std::fs::metadata(&path)
                    .with_context(|| {
                        format!("{label} chunk {} missing from {}", chunk.file, dir.display())
                    })?
                    .len();
                anyhow::ensure!(
                    len == need,
                    "{}: {label} chunk is {len} bytes, manifest expects {need} \
                     ({} rows x {} values; truncated or tampered checkpoint?)",
                    path.display(),
                    chunk.rows,
                    table.dim
                );
                let mut header = [0u8; 8];
                {
                    use std::io::Read;
                    let mut f = std::fs::File::open(&path)
                        .with_context(|| format!("opening {}", path.display()))?;
                    f.read_exact(&mut header)
                        .with_context(|| format!("reading header of {}", path.display()))?;
                }
                let declared = u64::from_le_bytes(header);
                anyhow::ensure!(
                    declared == values,
                    "{}: chunk header declares {declared} values, manifest expects {values}",
                    path.display()
                );
            }
        }
        Ok(())
    }
}

/// Order-sensitive FNV-1a 64 over a vocabulary's names in id order, with
/// a separator byte between names so `["ab","c"]` and `["a","bc"]` hash
/// differently. Rendered as a hex string because JSON numbers (f64)
/// cannot carry 64 bits.
pub fn vocab_hash(v: &Vocab) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for id in 0..v.len() {
        if let Some(name) = v.name(id as u32) {
            for &b in name.as_bytes() {
                mix(b);
            }
        }
        mix(0xFF);
    }
    format!("fnv1a:{h:016x}")
}

/// Stream one chunk file's rows into `table` starting at `first_row`,
/// through a bounded ~256 KiB buffer. The header and size must already
/// have been checked ([`CheckpointManifest::validate_files`]); this
/// re-verifies the header as a cheap belt-and-suspenders.
pub fn read_chunk_into(
    path: &Path,
    first_row: usize,
    rows: usize,
    dim: usize,
    table: &dyn EmbeddingStore,
) -> Result<()> {
    let f =
        std::fs::File::open(path).with_context(|| format!("reading {}", path.display()))?;
    let mut rd = std::io::BufReader::new(f);
    use std::io::Read;
    let mut len8 = [0u8; 8];
    rd.read_exact(&mut len8).with_context(|| format!("decoding {}", path.display()))?;
    let declared = u64::from_le_bytes(len8);
    anyhow::ensure!(
        declared == rows as u64 * dim as u64,
        "{}: header declares {declared} values, expected {} rows x {dim}",
        path.display(),
        rows
    );
    if rows == 0 || dim == 0 {
        return Ok(());
    }
    let chunk_rows = chunk_rows_for(dim, rows);
    let mut buf = vec![0f32; chunk_rows * dim];
    let mut row = 0;
    while row < rows {
        let take = chunk_rows.min(rows - row);
        let n_values = take * dim;
        let bytes = crate::util::bytes::f32_as_bytes_mut(&mut buf[..n_values]);
        rd.read_exact(bytes).with_context(|| format!("decoding {}", path.display()))?;
        // lint:allow(ledger-billing) — one-time checkpoint decode at
        // load; the ledgers audit training/serving traffic, not startup
        table.set_rows(first_row + row, &buf[..n_values]);
        row += take;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::DenseStore;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d =
            std::env::temp_dir().join(format!("dglke-manifest-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> CheckpointManifest {
        CheckpointManifest {
            format_version: FORMAT_VERSION,
            model: ModelKind::TransEL2,
            dataset: "tiny".to_string(),
            dim: 16,
            rel_dim: 16,
            n_entities: 200,
            n_relations: 8,
            seed: 7,
            entity_vocab_hash: "fnv1a:0000000000000001".to_string(),
            relation_vocab_hash: "fnv1a:0000000000000002".to_string(),
            entities: TableInfo::single("entities.f32", 200, 16),
            relations: TableInfo::single("relations.f32", 8, 16),
        }
    }

    #[test]
    fn json_round_trip() {
        let m = sample();
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(CheckpointManifest::from_json(&j).unwrap(), m);
    }

    #[test]
    fn chunked_json_round_trip() {
        let mut m = sample();
        m.entities.chunks = vec![
            ChunkInfo { file: "entities.00000.f32".to_string(), rows: 150 },
            ChunkInfo { file: "entities.00001.f32".to_string(), rows: 50 },
        ];
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        let back = CheckpointManifest::from_json(&j).unwrap();
        assert_eq!(back, m);
        back.validate().unwrap();
    }

    #[test]
    fn rejects_stale_and_future_versions() {
        for bad in [0.0, 1.0, 3.0, 99.0] {
            let mut j = match sample().to_json() {
                Json::Obj(m) => m,
                _ => unreachable!(),
            };
            j.insert("format_version".to_string(), Json::Num(bad));
            let err = CheckpointManifest::from_json(&Json::Obj(j)).unwrap_err();
            assert!(
                format!("{err:#}").contains("unsupported checkpoint format version"),
                "{err:#}"
            );
        }
    }

    #[test]
    fn validate_catches_shape_lies() {
        let mut m = sample();
        m.n_entities = 201;
        assert!(m.validate().is_err(), "row count mismatch");
        let mut m = sample();
        m.entities.chunks[0].rows = 199;
        assert!(m.validate().is_err(), "chunk sum mismatch");
        let mut m = sample();
        m.rel_dim = 17;
        assert!(m.validate().is_err(), "rel_dim mismatch");
        sample().validate().unwrap();
    }

    #[test]
    fn validate_files_checks_size_and_header() {
        let dir = tmp_dir("files");
        let m = CheckpointManifest {
            n_entities: 3,
            n_relations: 2,
            dim: 4,
            rel_dim: 4,
            entities: TableInfo::single("entities.f32", 3, 4),
            relations: TableInfo::single("relations.f32", 2, 4),
            ..sample()
        };
        for (file, values) in [("entities.f32", 12u64), ("relations.f32", 8u64)] {
            let mut bytes = values.to_le_bytes().to_vec();
            bytes.extend(std::iter::repeat(0u8).take(values as usize * 4));
            std::fs::write(dir.join(file), &bytes).unwrap();
        }
        m.validate_files(&dir).unwrap();
        // truncate one file → rejected
        let full = std::fs::read(dir.join("entities.f32")).unwrap();
        std::fs::write(dir.join("entities.f32"), &full[..full.len() - 4]).unwrap();
        assert!(m.validate_files(&dir).is_err());
        // right size, lying header → rejected
        let mut lying = full.clone();
        lying[..8].copy_from_slice(&99u64.to_le_bytes());
        std::fs::write(dir.join("entities.f32"), &lying).unwrap();
        let err = m.validate_files(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("header declares"), "{err:#}");
        // missing file → rejected
        std::fs::write(dir.join("entities.f32"), &full).unwrap();
        std::fs::remove_file(dir.join("relations.f32")).unwrap();
        assert!(m.validate_files(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn vocab_hash_is_order_and_boundary_sensitive() {
        let mut a = Vocab::new();
        a.intern("ab");
        a.intern("c");
        let mut b = Vocab::new();
        b.intern("a");
        b.intern("bc");
        let mut c = Vocab::new();
        c.intern("c");
        c.intern("ab");
        let ha = vocab_hash(&a);
        assert_ne!(ha, vocab_hash(&b), "boundary-sensitive");
        assert_ne!(ha, vocab_hash(&c), "order-sensitive");
        assert_eq!(ha, vocab_hash(&a.clone()), "deterministic");
        assert!(ha.starts_with("fnv1a:") && ha.len() == 6 + 16);
    }

    #[test]
    fn read_chunk_into_streams_rows() {
        let dir = tmp_dir("chunk");
        let rows = 5usize;
        let dim = 3usize;
        let mut bytes = ((rows * dim) as u64).to_le_bytes().to_vec();
        let mut expect = Vec::new();
        for i in 0..rows * dim {
            let v = i as f32 * 0.25;
            bytes.extend_from_slice(&v.to_le_bytes());
            expect.push(v);
        }
        let path = dir.join("t.f32");
        std::fs::write(&path, &bytes).unwrap();
        let table = DenseStore::zeros(rows + 2, dim);
        read_chunk_into(&path, 2, rows, dim, &table).unwrap();
        assert_eq!(table.snapshot()[2 * dim..], expect[..]);
        assert_eq!(table.row_vec(0), vec![0.0; dim], "rows before first_row untouched");
        // lying header is rejected
        bytes[..8].copy_from_slice(&7u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_chunk_into(&path, 0, rows, dim, &table).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
