//! Serving subsystem: versioned checkpoint snapshots + hot-swap top-k
//! inference.
//!
//! A training [`crate::api::Session`] exports a **versioned checkpoint**
//! (`manifest.json` + chunked table files, [`manifest`]); [`Snapshot`]
//! opens one read-only through the mmap store layer — zero-copy, instant
//! load regardless of table size — and answers batched link-prediction
//! queries `(h, r, ?)` / `(?, r, t)` with the same blocked scoring loop
//! as the offline evaluator, so served top-k results are bit-identical
//! to offline eval rankings (`rust/tests/serve_tests.rs` is the parity
//! gate).
//!
//! [`ServeHandle`] runs a pool of worker threads over one [`Swap`] latch:
//! [`ServeHandle::publish`] atomically hot-swaps the snapshot under live
//! traffic, with per-job atomicity (no query ever sees a torn mix of old
//! and new tables — loom contracts 9–10 in `docs/CONCURRENCY.md`).
//! [`protocol`] frames query batches and replies for the wire, total
//! over hostile input.
//!
//! [`export`] converts a checkpoint to TSV (`dglke export --tsv`) for
//! downstream tools; the text form round-trips the stored f32 bits.
//!
//! See `docs/SERVING.md` for the checkpoint format and operational
//! guide; `dglke serve --checkpoint DIR` is the CLI entry point.

pub mod export;
pub mod manifest;
pub mod protocol;
pub mod server;
pub mod snapshot;
pub mod swap;

pub use export::export_tsv;
pub use manifest::{vocab_hash, CheckpointManifest, ChunkInfo, TableInfo, FORMAT_VERSION};
pub use server::{ServeConfig, ServeHandle, ServeLatencies};
pub use snapshot::{Query, ServeScratch, Snapshot, SnapshotOptions, TopK};
pub use swap::Swap;
