//! Wire framing for serve requests/replies, reusing the KVStore frame
//! layer (`[u32 len][u8 opcode][payload]`, 1 GiB cap).
//!
//! Payload layout (little-endian throughout):
//!
//! * query batch (`OP_SQUERY`): `[u32 k][u64 n][n × (u8 side, u64 e,
//!   u64 r)]` with side 0 = tail-corruption `(e, r, ?)`, 1 =
//!   head-corruption `(?, r, e)`;
//! * reply (`OP_SREPLY`): `[u64 n][n × (u64-len-prefixed ids,
//!   u64-len-prefixed f32 scores)]`.
//!
//! Decoders are total over hostile input — length prefixes are checked
//! against the remaining payload *before* any allocation, unknown side
//! bytes and trailing garbage are rejected — and
//! `rust/tests/protocol_fuzz_tests.rs` fuzzes truncation at every cut.

use super::snapshot::{Query, TopK};
use crate::kvstore::protocol::{read_frame, write_frame};
use crate::models::EvalSide;
use crate::util::bytes::{Reader, Writer};
use anyhow::{bail, Result};
use std::io::{Read, Write};

/// Opcode for a serve query-batch frame (KVStore opcodes stay below
/// 0x10; replies mirror the 0x80 ack bit convention).
pub const OP_SQUERY: u8 = 0x10;
/// Opcode for a serve reply frame.
pub const OP_SREPLY: u8 = 0x90;

/// Hard cap on queries (or replies) per frame: a hostile length prefix
/// larger than this is rejected before any allocation.
pub const MAX_BATCH: usize = 1 << 20;

/// Bytes of one encoded query: side tag + two ids.
const QUERY_BYTES: usize = 1 + 8 + 8;

/// Encode a query batch with its requested top-k depth.
pub fn encode_query_batch(k: u32, queries: &[Query]) -> Vec<u8> {
    let mut w = Writer::with_capacity(4 + 8 + queries.len() * QUERY_BYTES);
    w.u32(k);
    w.u64(queries.len() as u64);
    for q in queries {
        w.u8(match q.side {
            EvalSide::Tail => 0,
            EvalSide::Head => 1,
        });
        w.u64(q.e);
        w.u64(q.r);
    }
    w.buf
}

/// Decode a query batch; total over arbitrary input.
pub fn decode_query_batch(payload: &[u8]) -> Result<(u32, Vec<Query>)> {
    let mut r = Reader::new(payload);
    let k = r.u32()?;
    let n = r.u64()?;
    if n > MAX_BATCH as u64 {
        bail!("query batch declares {n} queries, cap is {MAX_BATCH}");
    }
    // lint:allow(narrowing-cast) — guarded: n <= MAX_BATCH (1 << 20)
    let n = n as usize;
    if n > r.remaining() / QUERY_BYTES {
        bail!("query batch declares {n} queries but only {} payload bytes remain", r.remaining());
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let side = match r.u8()? {
            0 => EvalSide::Tail,
            1 => EvalSide::Head,
            b => bail!("bad query side tag {b}"),
        };
        let e = r.u64()?;
        let rel = r.u64()?;
        out.push(Query { side, e, r: rel });
    }
    if r.remaining() != 0 {
        bail!("{} trailing bytes after query batch", r.remaining());
    }
    Ok((k, out))
}

/// Encode a reply: one [`TopK`] per submitted query, in order.
pub fn encode_reply(results: &[TopK]) -> Vec<u8> {
    let mut w = Writer::new();
    w.u64(results.len() as u64);
    for t in results {
        w.u64_slice(&t.ids);
        w.f32_slice(&t.scores);
    }
    w.buf
}

/// Decode a reply; total over arbitrary input.
pub fn decode_reply(payload: &[u8]) -> Result<Vec<TopK>> {
    let mut r = Reader::new(payload);
    let n = r.u64()?;
    if n > MAX_BATCH as u64 {
        bail!("reply declares {n} results, cap is {MAX_BATCH}");
    }
    // lint:allow(narrowing-cast) — guarded: n <= MAX_BATCH (1 << 20)
    let n = n as usize;
    // each result carries at least its two u64 length prefixes
    if n > r.remaining() / 16 {
        bail!("reply declares {n} results but only {} payload bytes remain", r.remaining());
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let ids = r.u64_vec()?;
        let scores = r.f32_vec()?;
        if ids.len() != scores.len() {
            bail!("reply result has {} ids but {} scores", ids.len(), scores.len());
        }
        out.push(TopK { ids, scores });
    }
    if r.remaining() != 0 {
        bail!("{} trailing bytes after reply", r.remaining());
    }
    Ok(out)
}

/// Write one query-batch frame to a stream.
pub fn write_query_batch(stream: &mut impl Write, k: u32, queries: &[Query]) -> Result<()> {
    write_frame(stream, OP_SQUERY, &encode_query_batch(k, queries))
}

/// Read one query-batch frame from a stream.
pub fn read_query_batch(stream: &mut impl Read) -> Result<(u32, Vec<Query>)> {
    let (op, payload) = read_frame(stream)?;
    if op != OP_SQUERY {
        bail!("expected OP_SQUERY frame, got opcode {op:#04x}");
    }
    decode_query_batch(&payload)
}

/// Write one reply frame to a stream.
pub fn write_reply(stream: &mut impl Write, results: &[TopK]) -> Result<()> {
    write_frame(stream, OP_SREPLY, &encode_reply(results))
}

/// Read one reply frame from a stream.
pub fn read_reply(stream: &mut impl Read) -> Result<Vec<TopK>> {
    let (op, payload) = read_frame(stream)?;
    if op != OP_SREPLY {
        bail!("expected OP_SREPLY frame, got opcode {op:#04x}");
    }
    decode_reply(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_queries() -> Vec<Query> {
        vec![Query::tail(3, 1), Query::head(u64::MAX, 0), Query::tail(0, u64::MAX)]
    }

    #[test]
    fn query_batch_round_trip() {
        let qs = sample_queries();
        let (k, back) = decode_query_batch(&encode_query_batch(7, &qs)).unwrap();
        assert_eq!(k, 7);
        assert_eq!(back, qs);
        // empty batch and k = 0 are legal on the wire
        let (k, back) = decode_query_batch(&encode_query_batch(0, &[])).unwrap();
        assert_eq!((k, back.len()), (0, 0));
    }

    #[test]
    fn reply_round_trip() {
        let reply = vec![
            TopK { ids: vec![5, 1, 9], scores: vec![0.5, 0.25, -1.0] },
            TopK { ids: vec![], scores: vec![] },
        ];
        assert_eq!(decode_reply(&encode_reply(&reply)).unwrap(), reply);
        assert_eq!(decode_reply(&encode_reply(&[])).unwrap(), Vec::<TopK>::new());
    }

    #[test]
    fn hostile_lengths_rejected_before_alloc() {
        // query count far beyond the payload
        let mut w = crate::util::bytes::Writer::new();
        w.u32(1);
        w.u64(u64::MAX / 2);
        assert!(decode_query_batch(&w.buf).is_err());
        // above the cap but with a plausible-looking payload prefix
        let mut w = crate::util::bytes::Writer::new();
        w.u32(1);
        w.u64((MAX_BATCH + 1) as u64);
        assert!(decode_query_batch(&w.buf).is_err());
        // reply count lies too
        let mut w = crate::util::bytes::Writer::new();
        w.u64(u64::MAX - 1);
        assert!(decode_reply(&w.buf).is_err());
    }

    #[test]
    fn truncation_at_every_cut_errors() {
        let full = encode_query_batch(5, &sample_queries());
        for cut in 0..full.len() {
            assert!(decode_query_batch(&full[..cut]).is_err(), "cut {cut}");
        }
        let reply = encode_reply(&[TopK { ids: vec![1, 2], scores: vec![0.1, 0.2] }]);
        for cut in 0..reply.len() {
            assert!(decode_reply(&reply[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn bad_side_and_trailing_bytes_rejected() {
        let mut buf = encode_query_batch(1, &sample_queries());
        buf[12] = 9; // first query's side tag
        assert!(decode_query_batch(&buf).is_err());
        let mut buf = encode_query_batch(1, &sample_queries());
        buf.push(0);
        assert!(decode_query_batch(&buf).is_err());
        let mut buf = encode_reply(&[TopK { ids: vec![1], scores: vec![0.5] }]);
        buf.push(0);
        assert!(decode_reply(&buf).is_err());
    }

    #[test]
    fn mismatched_reply_lengths_rejected() {
        let mut w = crate::util::bytes::Writer::new();
        w.u64(1);
        w.u64_slice(&[1, 2]);
        w.f32_slice(&[0.5]);
        assert!(decode_reply(&w.buf).is_err());
    }

    #[test]
    fn stream_frames_round_trip() {
        let qs = sample_queries();
        let mut wire = Vec::new();
        write_query_batch(&mut wire, 3, &qs).unwrap();
        let reply = vec![TopK { ids: vec![2], scores: vec![1.5] }];
        write_reply(&mut wire, &reply).unwrap();
        let mut cursor = std::io::Cursor::new(wire);
        let (k, back) = read_query_batch(&mut cursor).unwrap();
        assert_eq!((k, back), (3, qs));
        assert_eq!(read_reply(&mut cursor).unwrap(), reply);
        // wrong opcode order is rejected
        let mut wire2 = Vec::new();
        write_reply(&mut wire2, &reply).unwrap();
        let mut cursor2 = std::io::Cursor::new(wire2);
        assert!(read_query_batch(&mut cursor2).is_err());
    }
}
