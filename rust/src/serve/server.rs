//! Threaded request loop: a pool of workers answering batched top-k
//! queries against the current [`Snapshot`], with hot-swap publishing.
//!
//! Each worker owns a long-lived [`ServeScratch`] (no steady-state
//! allocation) and pins the snapshot *once per job*: a job's queries are
//! all answered by one snapshot, so a publish that lands mid-storm flips
//! whole jobs from the old answer set to the new one and never mixes
//! epochs within a job. A multi-job [`ServeHandle::submit`] may span a
//! publish — per-job atomicity is the contract (`docs/SERVING.md`).
//!
//! Built on `util::sync` channels/atomics so `make loom` perturbs the
//! handoff; the swap latch itself is model-checked separately
//! (`serve::swap`, loom contracts 9–10).

use super::snapshot::{Query, ServeScratch, Snapshot, TopK};
use super::swap::Swap;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{mpsc, Arc, Mutex};
use anyhow::{anyhow, bail, Result};
use std::thread::JoinHandle;

/// Request-loop shape: worker threads, queries per dispatched job, and
/// the default top-k depth (`RunSpec.serve` carries the same knobs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeConfig {
    pub threads: usize,
    /// max queries handed to one worker as one job
    pub batch: usize,
    /// default k for entry points that don't pass one explicitly
    pub topk: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { threads: 2, batch: 64, topk: 10 }
    }
}

/// One unit of worker work: a slice of a submitted batch.
struct Job {
    queries: Vec<Query>,
    k: usize,
    /// position of this job's chunk within the submit call
    slot: usize,
    reply: mpsc::Sender<(usize, Result<Vec<TopK>, String>)>,
}

/// Handle to a running serve pool. Dropping it (or calling
/// [`ServeHandle::shutdown`]) closes the queue and joins the workers.
pub struct ServeHandle {
    swap: Arc<Swap<Snapshot>>,
    /// `None` once shut down — dropping the sender is what stops workers
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    batch: usize,
    served: Arc<AtomicU64>,
}

impl ServeHandle {
    /// Spawn `cfg.threads` workers serving `snapshot`.
    pub fn start(snapshot: Snapshot, cfg: &ServeConfig) -> ServeHandle {
        let swap = Arc::new(Swap::new(Arc::new(snapshot)));
        let served = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..cfg.threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let swap = Arc::clone(&swap);
                let served = Arc::clone(&served);
                std::thread::spawn(move || {
                    let mut scratch = ServeScratch::default();
                    loop {
                        // hold the receiver lock only for the dequeue, so
                        // idle workers don't serialize busy ones
                        let job = {
                            let guard = match rx.lock() {
                                Ok(g) => g,
                                Err(poisoned) => poisoned.into_inner(),
                            };
                            // lint:allow(blocking-under-lock) — the queue
                            // mutex exists only to share this Receiver;
                            // blocking in recv IS the idle state, and the
                            // guard is dropped before the job runs
                            guard.recv()
                        };
                        let job = match job {
                            Ok(j) => j,
                            Err(_) => break, // queue closed: shutdown
                        };
                        // pin one snapshot for the whole job — a publish
                        // mid-job cannot mix old and new answers
                        let snap = swap.load();
                        let res = snap.query_batch(&job.queries, job.k, &mut scratch);
                        served.fetch_add(job.queries.len() as u64, Ordering::Release);
                        // a submit() that already bailed dropped its
                        // receiver; that's fine, the job is abandoned
                        let _ =
                            job.reply.send((job.slot, res.map_err(|e| format!("{e:#}"))));
                    }
                })
            })
            .collect();
        ServeHandle { swap, tx: Some(tx), workers, batch: cfg.batch.max(1), served }
    }

    /// Answer `queries` (top `k` each), fanning chunks of `batch` across
    /// the worker pool and reassembling results in submission order.
    pub fn submit(&self, queries: &[Query], k: usize) -> Result<Vec<TopK>> {
        if queries.is_empty() {
            return Ok(Vec::new());
        }
        let tx = self.tx.as_ref().ok_or_else(|| anyhow!("serve handle is shut down"))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut n_jobs = 0usize;
        for (slot, chunk) in queries.chunks(self.batch).enumerate() {
            let job = Job { queries: chunk.to_vec(), k, slot, reply: reply_tx.clone() };
            if tx.send(job).is_err() {
                bail!("serve workers have shut down");
            }
            n_jobs += 1;
        }
        drop(reply_tx);
        let mut slots: Vec<Option<Vec<TopK>>> = vec![None; n_jobs];
        for _ in 0..n_jobs {
            let (slot, res) = reply_rx
                .recv()
                .map_err(|_| anyhow!("serve worker exited without replying"))?;
            match res {
                Ok(answers) => slots[slot] = Some(answers),
                Err(e) => bail!("serve query failed: {e}"),
            }
        }
        Ok(slots.into_iter().flatten().flatten().collect())
    }

    /// Hot-swap to a new snapshot; in-flight jobs finish on the old one.
    /// Returns the new epoch.
    pub fn publish(&self, snapshot: Snapshot) -> u64 {
        self.swap.publish(Arc::new(snapshot))
    }

    /// The snapshot new jobs will be served from.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        self.swap.load()
    }

    /// Publishes completed so far (0 = still the starting snapshot).
    pub fn epoch(&self) -> u64 {
        self.swap.epoch()
    }

    /// Total queries answered (across all workers and snapshots).
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Acquire)
    }

    /// Close the queue and join every worker.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.tx = None; // closes the channel; workers break out of recv
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}
