//! Threaded request loop: a pool of workers answering batched top-k
//! queries against the current [`Snapshot`], with hot-swap publishing.
//!
//! Each worker owns a long-lived [`ServeScratch`] (no steady-state
//! allocation) and pins the snapshot *once per job*: a job's queries are
//! all answered by one snapshot, so a publish that lands mid-storm flips
//! whole jobs from the old answer set to the new one and never mixes
//! epochs within a job. A multi-job [`ServeHandle::submit`] may span a
//! publish — per-job atomicity is the contract (`docs/SERVING.md`).
//!
//! Built on `util::sync` channels so `make loom` perturbs the handoff;
//! the swap latch itself is model-checked separately (`serve::swap`,
//! loom contracts 9–10). Served/error counts and queue/score/batch/query
//! latency histograms live in the `obs::metrics` registry (`serve.*`);
//! per-handle reads go through [`ServeHandle::served`] /
//! [`ServeHandle::latencies`].

use super::snapshot::{Query, ServeScratch, Snapshot, TopK};
use super::swap::Swap;
use crate::obs::metrics::{global, Counter, Histogram, HistogramSnapshot};
use crate::obs::trace::{span, SpanId};
use crate::util::sync::{mpsc, Arc, Mutex};
use anyhow::{anyhow, bail, Result};
use std::thread::JoinHandle;
use std::time::Instant;

/// Request-loop shape: worker threads, queries per dispatched job, and
/// the default top-k depth (`RunSpec.serve` carries the same knobs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeConfig {
    pub threads: usize,
    /// max queries handed to one worker as one job
    pub batch: usize,
    /// default k for entry points that don't pass one explicitly
    pub topk: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { threads: 2, batch: 64, topk: 10 }
    }
}

/// One unit of worker work: a slice of a submitted batch.
struct Job {
    queries: Vec<Query>,
    k: usize,
    /// position of this job's chunk within the submit call
    slot: usize,
    /// when `submit` put the job on the queue — the worker's dequeue
    /// timestamp minus this is the job's queue latency
    enqueued: Instant,
    reply: mpsc::Sender<(usize, Result<Vec<TopK>, String>)>,
}

/// Point-in-time latency distributions for one [`ServeHandle`], in
/// nanoseconds. Each field is a log-2 histogram snapshot; use
/// [`HistogramSnapshot::percentile`] for p50/p95/p99 (values are bucket
/// upper bounds, so ~2× resolution).
#[derive(Clone, Debug)]
pub struct ServeLatencies {
    /// enqueue → worker dequeue, per job
    pub queue_ns: HistogramSnapshot,
    /// snapshot scoring (`query_batch`), per job
    pub score_ns: HistogramSnapshot,
    /// enqueue → reply sent (queue + score), per job
    pub batch_ns: HistogramSnapshot,
    /// whole `submit` call including reassembly, per call
    pub query_ns: HistogramSnapshot,
}

/// Handle to a running serve pool. Dropping it (or calling
/// [`ServeHandle::shutdown`]) closes the queue and joins the workers.
pub struct ServeHandle {
    swap: Arc<Swap<Snapshot>>,
    /// `None` once shut down — dropping the sender is what stops workers
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    batch: usize,
    served: Counter,
    errors: Counter,
    queue_ns: Histogram,
    score_ns: Histogram,
    batch_ns: Histogram,
    query_ns: Histogram,
}

impl ServeHandle {
    /// Spawn `cfg.threads` workers serving `snapshot`.
    pub fn start(snapshot: Snapshot, cfg: &ServeConfig) -> ServeHandle {
        let swap = Arc::new(Swap::new(Arc::new(snapshot)));
        let served = global().counter("serve.served");
        let errors = global().counter("serve.errors");
        let queue_ns = global().histogram("serve.queue_ns");
        let score_ns = global().histogram("serve.score_ns");
        let batch_ns = global().histogram("serve.batch_ns");
        let query_ns = global().histogram("serve.query_ns");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..cfg.threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let swap = Arc::clone(&swap);
                let served = served.clone();
                let errors = errors.clone();
                let queue_ns = queue_ns.clone();
                let score_ns = score_ns.clone();
                let batch_ns = batch_ns.clone();
                std::thread::spawn(move || {
                    let mut scratch = ServeScratch::default();
                    loop {
                        // hold the receiver lock only for the dequeue, so
                        // idle workers don't serialize busy ones
                        let job = {
                            let guard = match rx.lock() {
                                Ok(g) => g,
                                Err(poisoned) => poisoned.into_inner(),
                            };
                            // lint:allow(blocking-under-lock) — the queue
                            // mutex exists only to share this Receiver;
                            // blocking in recv IS the idle state, and the
                            // guard is dropped before the job runs
                            guard.recv()
                        };
                        let job = match job {
                            Ok(j) => j,
                            Err(_) => break, // queue closed: shutdown
                        };
                        queue_ns.record(job.enqueued.elapsed().as_nanos() as u64);
                        // pin one snapshot for the whole job — a publish
                        // mid-job cannot mix old and new answers
                        let snap = swap.load();
                        let scored_at = Instant::now();
                        let res = {
                            let _s = span(SpanId::ServeScore);
                            snap.query_batch(&job.queries, job.k, &mut scratch)
                        };
                        score_ns.record(scored_at.elapsed().as_nanos() as u64);
                        if res.is_err() {
                            errors.inc();
                        } else {
                            served.add(job.queries.len() as u64);
                        }
                        batch_ns.record(job.enqueued.elapsed().as_nanos() as u64);
                        // a submit() that already bailed dropped its
                        // receiver; that's fine, the job is abandoned
                        let _ =
                            job.reply.send((job.slot, res.map_err(|e| format!("{e:#}"))));
                    }
                })
            })
            .collect();
        ServeHandle {
            swap,
            tx: Some(tx),
            workers,
            batch: cfg.batch.max(1),
            served,
            errors,
            queue_ns,
            score_ns,
            batch_ns,
            query_ns,
        }
    }

    /// Answer `queries` (top `k` each), fanning chunks of `batch` across
    /// the worker pool and reassembling results in submission order.
    pub fn submit(&self, queries: &[Query], k: usize) -> Result<Vec<TopK>> {
        let _request = span(SpanId::ServeRequest);
        if queries.is_empty() {
            return Ok(Vec::new());
        }
        let submitted_at = Instant::now();
        let tx = self.tx.as_ref().ok_or_else(|| anyhow!("serve handle is shut down"))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut n_jobs = 0usize;
        for (slot, chunk) in queries.chunks(self.batch).enumerate() {
            let job = Job {
                queries: chunk.to_vec(),
                k,
                slot,
                enqueued: Instant::now(),
                reply: reply_tx.clone(),
            };
            if tx.send(job).is_err() {
                bail!("serve workers have shut down");
            }
            n_jobs += 1;
        }
        drop(reply_tx);
        let mut slots: Vec<Option<Vec<TopK>>> = vec![None; n_jobs];
        let answers = {
            let _s = span(SpanId::ServeReassemble);
            for _ in 0..n_jobs {
                let (slot, res) = reply_rx
                    .recv()
                    .map_err(|_| anyhow!("serve worker exited without replying"))?;
                match res {
                    Ok(answers) => slots[slot] = Some(answers),
                    Err(e) => bail!("serve query failed: {e}"),
                }
            }
            slots.into_iter().flatten().flatten().collect()
        };
        self.query_ns.record(submitted_at.elapsed().as_nanos() as u64);
        Ok(answers)
    }

    /// Hot-swap to a new snapshot; in-flight jobs finish on the old one.
    /// Returns the new epoch.
    pub fn publish(&self, snapshot: Snapshot) -> u64 {
        self.swap.publish(Arc::new(snapshot))
    }

    /// The snapshot new jobs will be served from.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        self.swap.load()
    }

    /// Publishes completed so far (0 = still the starting snapshot).
    pub fn epoch(&self) -> u64 {
        self.swap.epoch()
    }

    /// Total queries answered (across all workers and snapshots).
    pub fn served(&self) -> u64 {
        self.served.get()
    }

    /// Jobs whose scoring failed (the submit call sees the error too).
    pub fn errors(&self) -> u64 {
        self.errors.get()
    }

    /// Snapshot of this handle's latency histograms (ns). The same
    /// distributions are visible — summed across handles — in
    /// `obs::metrics` snapshots under `serve.*_ns`.
    pub fn latencies(&self) -> ServeLatencies {
        ServeLatencies {
            queue_ns: self.queue_ns.snapshot(),
            score_ns: self.score_ns.snapshot(),
            batch_ns: self.batch_ns.snapshot(),
            query_ns: self.query_ns.snapshot(),
        }
    }

    /// Close the queue and join every worker.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.tx = None; // closes the channel; workers break out of recv
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}
