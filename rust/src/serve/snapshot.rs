//! Read-only [`Snapshot`] over a versioned checkpoint: zero-copy table
//! access and batched top-k link-prediction queries.
//!
//! Opening a snapshot never reads table bytes into memory up front — each
//! chunk file is viewed through [`MmapStore::open_at`] positioned I/O
//! behind its 8-byte header, so a larger-than-RAM checkpoint serves
//! instantly (optionally with a bounded hot-row cache in front, the PR 4
//! machinery reused read-side). Scoring mirrors the offline evaluator
//! (`eval::evaluate`) block-for-block — same `BLOCK`, same fused-vs-staged
//! dispatch, same kernels — so served scores are bit-identical to offline
//! eval scores; `rust/tests/serve_tests.rs` holds the two paths together.

use super::manifest::{CheckpointManifest, TableInfo, TABLE_HEADER_BYTES};
use crate::models::kernels::zeroed;
use crate::models::{EvalScratch, EvalSide, KernelBackend, LossCfg, NativeModel};
use crate::store::{split_cache_budget, CachedStore, EmbeddingStore, MmapStore};
use crate::train::batch::stream_gather_scores;
use crate::util::topk::top_k_indices;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Candidate block size — pinned to the offline evaluator's blocking.
/// Per-candidate scoring math is blocking-independent, but keeping the
/// constants identical makes "mirrors eval" checkable by inspection.
const BLOCK: usize = 4096;

/// How to open a [`Snapshot`].
#[derive(Clone, Debug)]
pub struct SnapshotOptions {
    /// Optional hot-row cache budget (MiB, fractional allowed), split
    /// proportionally across the entity/relation tables like a training
    /// run's `storage.cache_mb`. `None` = raw positioned I/O per row.
    pub cache_mb: Option<f64>,
    /// Score kernel backend. Results are bit-identical either way (the
    /// kernel parity contract); `Fused` streams candidate rows
    /// store→tile and is the serving default.
    pub kernels: KernelBackend,
}

impl Default for SnapshotOptions {
    fn default() -> Self {
        SnapshotOptions { cache_mb: None, kernels: KernelBackend::Fused }
    }
}

/// One link-prediction request: score every entity as the missing slot.
/// `Tail` asks `(e, r, ?)` (e is the head); `Head` asks `(?, r, e)`
/// (e is the tail).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Query {
    pub side: EvalSide,
    pub e: u64,
    pub r: u64,
}

impl Query {
    /// `(h, r, ?)`
    pub fn tail(h: u64, r: u64) -> Query {
        Query { side: EvalSide::Tail, e: h, r }
    }

    /// `(?, r, t)`
    pub fn head(t: u64, r: u64) -> Query {
        Query { side: EvalSide::Head, e: t, r }
    }
}

/// Top-k answer: entity ids in rank order (descending score, ascending
/// id on ties — exactly `eval::metrics::full_ranking`'s prefix) with
/// their scores.
#[derive(Clone, Debug, PartialEq)]
pub struct TopK {
    pub ids: Vec<u64>,
    pub scores: Vec<f32>,
}

/// Per-worker scratch arena for snapshot queries: query/candidate/score
/// buffers and the kernel tile scratch all persist across requests, so
/// the steady-state request path does not allocate.
#[derive(Default)]
pub struct ServeScratch {
    eval: EvalScratch,
    e_row: Vec<f32>,
    r_row: Vec<f32>,
    scores: Vec<f32>,
    ids: Vec<u64>,
    cand: Vec<f32>,
}

/// A read-only view of one checkpoint, shareable across worker threads.
pub struct Snapshot {
    manifest: CheckpointManifest,
    entities: Arc<dyn EmbeddingStore>,
    relations: Arc<dyn EmbeddingStore>,
    native: NativeModel,
    kernels: KernelBackend,
}

impl Snapshot {
    /// Open with defaults (fused kernels, no cache).
    pub fn open(dir: &Path) -> Result<Snapshot> {
        Self::open_with(dir, &SnapshotOptions::default())
    }

    /// Open a checkpoint directory: manifest load (format-version gate),
    /// internal validation, and full on-disk file validation all happen
    /// before the first query can run.
    pub fn open_with(dir: &Path, opts: &SnapshotOptions) -> Result<Snapshot> {
        let manifest = CheckpointManifest::load(dir)?;
        manifest
            .validate()
            .with_context(|| format!("inconsistent manifest in {}", dir.display()))?;
        manifest.validate_files(dir)?;
        let mut entities = open_table(dir, &manifest.entities)?;
        let mut relations = open_table(dir, &manifest.relations)?;
        if let Some(mb) = opts.cache_mb {
            let total = (mb * (1u64 << 20) as f64) as u64;
            let shares =
                split_cache_budget(total, &[entities.table_bytes(), relations.table_bytes()]);
            entities = maybe_cache(entities, shares.first().copied().unwrap_or(0));
            relations = maybe_cache(relations, shares.get(1).copied().unwrap_or(0));
        }
        let native = NativeModel::new(manifest.model, manifest.dim, LossCfg::default());
        Ok(Snapshot {
            manifest,
            entities: Arc::from(entities),
            relations: Arc::from(relations),
            native,
            kernels: opts.kernels,
        })
    }

    pub fn manifest(&self) -> &CheckpointManifest {
        &self.manifest
    }

    pub fn n_entities(&self) -> usize {
        self.entities.rows()
    }

    pub fn n_relations(&self) -> usize {
        self.relations.rows()
    }

    pub fn dim(&self) -> usize {
        self.native.dim
    }

    pub fn kernels(&self) -> KernelBackend {
        self.kernels
    }

    pub fn entities(&self) -> &Arc<dyn EmbeddingStore> {
        &self.entities
    }

    pub fn relations(&self) -> &Arc<dyn EmbeddingStore> {
        &self.relations
    }

    /// Score every entity as the missing slot of `q` and return the top
    /// `k` (clamped to the vocab size) in rank order.
    pub fn query(&self, q: &Query, k: usize, scratch: &mut ServeScratch) -> Result<TopK> {
        let n = self.entities.rows();
        anyhow::ensure!(
            (q.e as usize) < n,
            "entity id {} out of range (checkpoint has {n} entities)",
            q.e
        );
        anyhow::ensure!(
            (q.r as usize) < self.relations.rows(),
            "relation id {} out of range (checkpoint has {} relations)",
            q.r,
            self.relations.rows()
        );
        let dim = self.native.dim;
        scratch.e_row.clear();
        scratch.e_row.resize(dim, 0.0);
        // lint:allow(ledger-billing) — read-only serving path; the byte
        // ledgers audit training traffic, queries are not billed
        self.entities.read_row(q.e as usize, &mut scratch.e_row);
        scratch.r_row.clear();
        scratch.r_row.resize(self.relations.dim(), 0.0);
        self.relations.read_row(q.r as usize, &mut scratch.r_row);
        self.score_all(q.side, scratch);
        let top = top_k_indices(&scratch.scores, k.min(n));
        let mut ids = Vec::with_capacity(top.len());
        let mut scores = Vec::with_capacity(top.len());
        for &i in &top {
            ids.push(i as u64);
            scores.push(scratch.scores[i]);
        }
        Ok(TopK { ids, scores })
    }

    /// [`Snapshot::query`] over a batch, reusing one scratch arena.
    pub fn query_batch(
        &self,
        queries: &[Query],
        k: usize,
        scratch: &mut ServeScratch,
    ) -> Result<Vec<TopK>> {
        let mut out = Vec::with_capacity(queries.len());
        for q in queries {
            out.push(self.query(q, k, scratch)?);
        }
        Ok(out)
    }

    /// Fill `scratch.scores` with the score of every entity id as the
    /// corrupted slot. This is the offline evaluator's scoring loop with
    /// the candidate set fixed to `0..n_entities`: same block size, same
    /// fused-stream condition, same kernel entry points — so each
    /// candidate's score is bit-identical to what `eval::evaluate`
    /// computes for it.
    fn score_all(&self, side: EvalSide, scratch: &mut ServeScratch) {
        let n = self.entities.rows();
        let dim = self.native.dim;
        let op = self.native.kind.pairwise_op();
        let fused_stream =
            self.kernels == KernelBackend::Fused && !self.native.kind.projects_negatives();
        scratch.scores.clear();
        scratch.scores.resize(n, 0.0);
        if fused_stream {
            // build the o = g(e, r) query row once, then stream candidate
            // rows store→kernel-tile without staging [BLOCK, d] gathers
            let q = zeroed(&mut scratch.eval.query, dim);
            self.native.build_query(side, &scratch.e_row, &scratch.r_row, q);
            let mut start = 0usize;
            while start < n {
                let end = (start + BLOCK).min(n);
                scratch.ids.clear();
                scratch.ids.extend((start as u64)..(end as u64));
                stream_gather_scores(
                    op,
                    q,
                    self.entities.as_ref(),
                    &scratch.ids,
                    dim,
                    &mut scratch.scores[start..end],
                    &mut scratch.eval.kernel,
                );
                start = end;
            }
        } else {
            let mut start = 0usize;
            while start < n {
                let end = (start + BLOCK).min(n);
                scratch.ids.clear();
                scratch.ids.extend((start as u64)..(end as u64));
                scratch.cand.clear();
                scratch.cand.resize((end - start) * dim, 0.0);
                // lint:allow(ledger-billing) — read-only serving path;
                // candidate gathers are query work, not billed traffic
                self.entities.gather(&scratch.ids, &mut scratch.cand);
                self.native.eval_scores_with(
                    side,
                    &scratch.e_row,
                    &scratch.r_row,
                    &scratch.cand,
                    &mut scratch.scores[start..end],
                    self.kernels,
                    &mut scratch.eval,
                );
                start = end;
            }
        }
    }
}

fn maybe_cache(store: Box<dyn EmbeddingStore>, share: u64) -> Box<dyn EmbeddingStore> {
    let min_share = store.dim().max(1) as u64 * 4;
    if store.rows() > 0 && share >= min_share {
        Box::new(CachedStore::new(store, share))
    } else {
        store
    }
}

/// Open one table's chunk files as an [`EmbeddingStore`]: a single chunk
/// is an [`MmapStore`] directly; multiple chunks compose into a
/// [`ChunkedTable`].
fn open_table(dir: &Path, info: &TableInfo) -> Result<Box<dyn EmbeddingStore>> {
    let mut chunks = Vec::with_capacity(info.chunks.len());
    let mut starts = Vec::with_capacity(info.chunks.len());
    let mut first = 0usize;
    for c in &info.chunks {
        let path = dir.join(&c.file);
        starts.push(first);
        chunks.push(MmapStore::open_at(&path, TABLE_HEADER_BYTES, c.rows, info.dim)?);
        first += c.rows;
    }
    if chunks.len() == 1 {
        if let Some(only) = chunks.pop() {
            return Ok(Box::new(only));
        }
    }
    Ok(Box::new(ChunkedTable { chunks, starts, rows: info.rows, dim: info.dim }))
}

/// Several consecutive [`MmapStore`] chunks presented as one read-only
/// table. Row `i` lives in the chunk whose start is the greatest `<= i`.
struct ChunkedTable {
    chunks: Vec<MmapStore>,
    /// first global row of each chunk (starts[0] == 0, ascending)
    starts: Vec<usize>,
    rows: usize,
    dim: usize,
}

impl EmbeddingStore for ChunkedTable {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn backend_name(&self) -> &'static str {
        "snapshot"
    }

    fn read_row(&self, i: usize, out: &mut [f32]) {
        debug_assert!(i < self.rows);
        let c = self.starts.partition_point(|&s| s <= i) - 1;
        // lint:allow(ledger-billing) — chunk indirection inside the
        // read-only snapshot table; serving reads are not billed
        self.chunks[c].read_row(i - self.starts[c], out);
    }

    fn set_row(&self, _i: usize, _values: &[f32]) {
        panic!("snapshot tables are read-only");
    }

    fn update_row(&self, _i: usize, _f: &mut dyn FnMut(&mut [f32])) {
        panic!("snapshot tables are read-only");
    }

    fn resident_bytes(&self) -> u64 {
        self.chunks.iter().map(|c| c.resident_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelKind;
    use crate::serve::manifest::{ChunkInfo, FORMAT_VERSION};

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d =
            std::env::temp_dir().join(format!("dglke-snapshot-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Write a chunk file with the standard header; row r value j is
    /// `base + r + j/10`.
    fn write_chunk(path: &std::path::Path, first_row: usize, rows: usize, dim: usize) {
        let mut bytes = ((rows * dim) as u64).to_le_bytes().to_vec();
        for r in 0..rows {
            for j in 0..dim {
                bytes.extend_from_slice(
                    &((first_row + r) as f32 + j as f32 / 10.0).to_le_bytes(),
                );
            }
        }
        std::fs::write(path, &bytes).unwrap();
    }

    /// A minimal on-disk checkpoint: 6 entities in two chunks (4 + 2),
    /// 2 relations in one chunk, TransE-L2 dim 4.
    fn write_fixture(dir: &std::path::Path) -> CheckpointManifest {
        write_chunk(&dir.join("entities.00000.f32"), 0, 4, 4);
        write_chunk(&dir.join("entities.00001.f32"), 4, 2, 4);
        write_chunk(&dir.join("relations.f32"), 100, 2, 4);
        let m = CheckpointManifest {
            format_version: FORMAT_VERSION,
            model: ModelKind::TransEL2,
            dataset: "fixture".to_string(),
            dim: 4,
            rel_dim: 4,
            n_entities: 6,
            n_relations: 2,
            seed: 0,
            entity_vocab_hash: "fnv1a:0000000000000000".to_string(),
            relation_vocab_hash: "fnv1a:0000000000000000".to_string(),
            entities: TableInfo {
                rows: 6,
                dim: 4,
                chunks: vec![
                    ChunkInfo { file: "entities.00000.f32".to_string(), rows: 4 },
                    ChunkInfo { file: "entities.00001.f32".to_string(), rows: 2 },
                ],
            },
            relations: TableInfo::single("relations.f32", 2, 4),
        };
        m.save(dir).unwrap();
        m
    }

    #[test]
    fn chunked_table_maps_rows_across_chunks() {
        let dir = tmp_dir("chunks");
        let m = write_fixture(&dir);
        let table = open_table(&dir, &m.entities).unwrap();
        assert_eq!(table.backend_name(), "snapshot");
        assert_eq!(table.rows(), 6);
        for i in 0..6 {
            assert_eq!(
                table.row_vec(i),
                vec![i as f32, i as f32 + 0.1, i as f32 + 0.2, i as f32 + 0.3],
                "row {i}"
            );
        }
        // single-chunk tables come back as a bare mmap view
        let rels = open_table(&dir, &m.relations).unwrap();
        assert_eq!(rels.backend_name(), "mmap");
        assert_eq!(rels.row_vec(1)[0], 101.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "read-only")]
    fn chunked_table_rejects_writes() {
        let dir = tmp_dir("readonly");
        let m = write_fixture(&dir);
        let table = open_table(&dir, &m.entities).unwrap();
        let cleanup = scopeguard(dir);
        table.set_row(0, &[0.0; 4]);
        drop(cleanup);
    }

    fn scopeguard(dir: std::path::PathBuf) -> impl Drop {
        struct G(std::path::PathBuf);
        impl Drop for G {
            fn drop(&mut self) {
                std::fs::remove_dir_all(&self.0).ok();
            }
        }
        G(dir)
    }

    #[test]
    fn snapshot_queries_and_bounds() {
        let dir = tmp_dir("query");
        write_fixture(&dir);
        for kernels in [KernelBackend::Scalar, KernelBackend::Fused] {
            let snap = Snapshot::open_with(
                &dir,
                &SnapshotOptions { cache_mb: None, kernels },
            )
            .unwrap();
            assert_eq!(snap.n_entities(), 6);
            let mut scratch = ServeScratch::default();
            // k clamps to the vocab and ranks every entity
            let top = snap.query(&Query::tail(0, 0), 100, &mut scratch).unwrap();
            assert_eq!(top.ids.len(), 6);
            // scores are in rank order
            for w in top.scores.windows(2) {
                assert!(w[0] >= w[1]);
            }
            // out-of-range ids are rejected, not panicked on
            assert!(snap.query(&Query::tail(6, 0), 1, &mut scratch).is_err());
            assert!(snap.query(&Query::head(0, 2), 1, &mut scratch).is_err());
            // empty batch is fine
            assert_eq!(snap.query_batch(&[], 3, &mut scratch).unwrap().len(), 0);
        }
        // scalar and fused agree bit-for-bit
        let mut answers = Vec::new();
        for kernels in [KernelBackend::Scalar, KernelBackend::Fused] {
            let snap =
                Snapshot::open_with(&dir, &SnapshotOptions { cache_mb: None, kernels }).unwrap();
            let mut scratch = ServeScratch::default();
            let qs: Vec<Query> =
                (0..6).flat_map(|e| [Query::tail(e, 0), Query::head(e, 1)]).collect();
            answers.push(snap.query_batch(&qs, 6, &mut scratch).unwrap());
        }
        for (a, b) in answers[0].iter().zip(&answers[1]) {
            assert_eq!(a.ids, b.ids);
            let ab: Vec<u32> = a.scores.iter().map(|s| s.to_bits()).collect();
            let bb: Vec<u32> = b.scores.iter().map(|s| s.to_bits()).collect();
            assert_eq!(ab, bb);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cached_snapshot_answers_identically() {
        let dir = tmp_dir("cached");
        write_fixture(&dir);
        let plain = Snapshot::open(&dir).unwrap();
        let cached = Snapshot::open_with(
            &dir,
            &SnapshotOptions { cache_mb: Some(1.0), ..SnapshotOptions::default() },
        )
        .unwrap();
        assert_eq!(cached.entities().backend_name(), "cached");
        let mut s1 = ServeScratch::default();
        let mut s2 = ServeScratch::default();
        for q in [Query::tail(3, 1), Query::head(5, 0)] {
            let a = plain.query(&q, 6, &mut s1).unwrap();
            let b = cached.query(&q, 6, &mut s2).unwrap();
            assert_eq!(a, b);
            // twice more, to serve from a warm cache
            assert_eq!(cached.query(&q, 6, &mut s2).unwrap(), a);
        }
        assert!(cached.entities().cache_stats().map(|s| s.hits > 0).unwrap_or(false));
        std::fs::remove_dir_all(&dir).ok();
    }
}
