//! Atomic snapshot hot-swap: an arc-swap-style epoch latch on
//! `util::sync`, so `make loom` perturbs it (contracts 9–10 in
//! `docs/CONCURRENCY.md`).
//!
//! The representation is deliberately boring — `Mutex<Arc<T>>` plus an
//! `AtomicU64` epoch — because boring is what the loom harness can
//! actually explore. The lock is held only long enough to clone or
//! replace one `Arc` (no snapshot construction, no I/O), so publishers
//! never block readers for more than a pointer copy; once a reader holds
//! its `Arc`, it works wait-free on that snapshot for as long as it
//! likes while publishes proceed underneath.
//!
//! Ordering: the epoch uses `Release` on publish and `Acquire` on probe
//! — never `Relaxed` (xtask's relaxed-ordering lint allowlist does not
//! include this file, by design) — so a probed epoch value is never
//! newer than the snapshot contents a subsequent [`Swap::load_with_epoch`]
//! observes. The (arc, epoch) pair itself is made consistent by reading
//! and writing both under the one mutex.

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex, MutexGuard};

/// Shared slot holding the current snapshot and its publish epoch.
pub struct Swap<T> {
    current: Mutex<Arc<T>>,
    // lint:allow(metrics-registry) — epoch handshake cell (Release store /
    // Acquire load, `swap-epoch` pair), not a stat
    epoch: AtomicU64,
}

impl<T> Swap<T> {
    /// Wrap an initial snapshot at epoch 0.
    pub fn new(initial: Arc<T>) -> Swap<T> {
        // lint:allow(metrics-registry) — epoch handshake cell, see field doc
        Swap { current: Mutex::new(initial), epoch: AtomicU64::new(0) }
    }

    /// Poison-tolerant lock: a reader/publisher that panicked while
    /// holding the lock left a fully-replaced-or-untouched `Arc` (the
    /// critical sections are single pointer assignments), so the data is
    /// still coherent and later callers proceed.
    fn lock_current(&self) -> MutexGuard<'_, Arc<T>> {
        match self.current.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Clone the current snapshot handle. The returned `Arc` stays valid
    /// (and unchanged) for as long as the caller holds it, regardless of
    /// how many publishes happen afterwards — readers can never observe
    /// a torn or half-swapped snapshot.
    pub fn load(&self) -> Arc<T> {
        Arc::clone(&self.lock_current())
    }

    /// Snapshot handle plus the epoch it was published at. Both are read
    /// under one lock acquisition, so the pair is always consistent with
    /// some publish — never a new epoch with an old snapshot or vice
    /// versa.
    pub fn load_with_epoch(&self) -> (Arc<T>, u64) {
        let guard = self.lock_current();
        let snap = Arc::clone(&guard);
        let epoch = self.epoch.load(Ordering::Acquire);
        (snap, epoch)
    }

    /// Replace the current snapshot and bump the epoch. Returns the new
    /// epoch. In-flight readers keep their old `Arc`s; the old snapshot
    /// is dropped when the last of them finishes.
    pub fn publish(&self, next: Arc<T>) -> u64 {
        // span ends after the guard drops (locals drop in reverse order),
        // so the traced interval covers the full swap critical section
        let _span = crate::obs::trace::span(crate::obs::trace::SpanId::SwapPublish);
        let mut guard = self.lock_current();
        *guard = next;
        // Release pairs with the Acquire probes: anyone who observes the
        // new epoch value afterwards also observes the new arc on their
        // next load (the mutex orders the arc write before this bump).
        self.epoch.fetch_add(1, Ordering::Release) + 1
    }

    /// Wait-free staleness probe (no lock): how many publishes have
    /// completed. Never overtakes what [`Swap::load_with_epoch`] would
    /// return — a probe followed by a load sees an epoch >= the probe.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_bumps_epoch_and_replaces() {
        let swap = Swap::new(Arc::new(10u64));
        assert_eq!(swap.epoch(), 0);
        assert_eq!(*swap.load(), 10);
        assert_eq!(swap.publish(Arc::new(11)), 1);
        assert_eq!(swap.publish(Arc::new(12)), 2);
        let (snap, epoch) = swap.load_with_epoch();
        assert_eq!((*snap, epoch), (12, 2));
    }

    #[test]
    fn readers_keep_their_snapshot_across_publishes() {
        let swap = Swap::new(Arc::new(vec![1, 2, 3]));
        let held = swap.load();
        swap.publish(Arc::new(vec![4, 5, 6]));
        assert_eq!(*held, vec![1, 2, 3], "old arc unchanged");
        assert_eq!(*swap.load(), vec![4, 5, 6], "new loads see the publish");
    }

    #[test]
    fn concurrent_swaps_never_tear() {
        // Threaded smoke version of loom contract 9: every observed
        // snapshot is internally uniform, and (snap, epoch) pairs match.
        let swap = Arc::new(Swap::new(Arc::new(vec![0u64; 4])));
        std::thread::scope(|s| {
            let publisher = Arc::clone(&swap);
            s.spawn(move || {
                for e in 1..=200u64 {
                    publisher.publish(Arc::new(vec![e; 4]));
                }
            });
            for _ in 0..3 {
                let reader = Arc::clone(&swap);
                s.spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..400 {
                        let probed = reader.epoch();
                        let (snap, epoch) = reader.load_with_epoch();
                        assert!(snap.iter().all(|&v| v == snap[0]), "torn snapshot {snap:?}");
                        assert_eq!(snap[0], epoch, "epoch/content pairing");
                        assert!(epoch >= probed, "probe overtook contents");
                        assert!(epoch >= last, "epoch went backwards");
                        last = epoch;
                    }
                });
            }
        });
        assert_eq!(swap.epoch(), 200);
    }
}
