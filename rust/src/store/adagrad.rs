//! Sparse row-wise AdaGrad (the optimizer DGL-KE uses for embeddings).
//!
//! State is one scalar per embedding row: `G_i += mean(g²)`, update
//! `x_i -= lr · g / sqrt(G_i + eps)`. Row-wise (vs element-wise) state
//! halves memory traffic on the update path — the paper's §3.5 observes
//! that random-access embedding updates dominate on large graphs, so the
//! update must stay as lean as possible.
//!
//! The state lives behind the same [`EmbeddingStore`] boundary as the
//! table it optimizes (a dim-1 store), so a sharded/mmap table gets
//! sharded/mmap optimizer state — built together via
//! [`SparseAdagrad::with_storage`].
//!
//! Updates are Hogwild: concurrent updaters may interleave, which the
//! paper accepts by design. Duplicate ids within one `apply` call are
//! pre-accumulated (summed) so each row gets *one* exact AdaGrad step —
//! matching DGL-KE's `index_add_` semantics — instead of order-dependent
//! sequential steps.

use super::{EmbeddingStore, SparseGrads, StoreConfig};
use anyhow::Result;

pub struct SparseAdagrad {
    /// per-row accumulated squared-gradient mean, dim-1 store
    state: Box<dyn EmbeddingStore>,
    pub lr: f32,
    pub eps: f32,
}

thread_local! {
    /// Reused duplicate-id scratch: the check runs on every `apply` (hot
    /// path), so it must not allocate per call after warm-up.
    static SEEN: std::cell::RefCell<std::collections::HashSet<u64>> =
        std::cell::RefCell::new(std::collections::HashSet::new());
}

fn has_duplicates(ids: &[u64]) -> bool {
    if ids.len() < 2 {
        return false;
    }
    SEEN.with(|c| {
        let mut seen = c.borrow_mut();
        seen.clear();
        seen.reserve(ids.len());
        ids.iter().any(|id| !seen.insert(*id))
    })
}

impl SparseAdagrad {
    /// Dense (in-memory) optimizer state.
    pub fn new(rows: usize, lr: f32) -> Self {
        Self::with_storage(&StoreConfig::dense(), "adagrad", rows, lr)
            .expect("in-memory optimizer state cannot fail")
    }

    /// Optimizer state on the same backend as its table, so state
    /// shards/spills alongside the embeddings.
    pub fn with_storage(cfg: &StoreConfig, label: &str, rows: usize, lr: f32) -> Result<Self> {
        Self::with_storage_cached(cfg, label, rows, lr, None)
    }

    /// Like [`SparseAdagrad::with_storage`], with this state table's
    /// hot-row-cache byte share (mmap backend only; `None` = uncached).
    /// The state is touched on every update of its table's rows, so it
    /// deserves — and here gets — the same locality layer.
    pub fn with_storage_cached(
        cfg: &StoreConfig,
        label: &str,
        rows: usize,
        lr: f32,
        cache_bytes: Option<u64>,
    ) -> Result<Self> {
        Ok(SparseAdagrad { state: cfg.opt_state_cached(label, rows, cache_bytes)?, lr, eps: 1e-10 })
    }

    /// Hot-row-cache counters of the state store, when it has one.
    pub fn cache_stats(&self) -> Option<super::CacheStats> {
        self.state.cache_stats()
    }

    /// Apply one sparse update: for each (id, grad-row) pair, advance the
    /// AdaGrad state and update the embedding row in place.
    ///
    /// `grads` is [ids.len(), dim] row-major. Duplicate ids are legal:
    /// their rows are summed first (exact accumulation), then each unique
    /// row takes a single AdaGrad step.
    pub fn apply(&self, table: &dyn EmbeddingStore, ids: &[u64], grads: &[f32]) {
        let dim = table.dim();
        debug_assert_eq!(grads.len(), ids.len() * dim);
        if has_duplicates(ids) {
            let mut g = SparseGrads::with_capacity(dim, ids.len());
            g.extend_from(ids, grads);
            let acc = g.accumulate();
            self.apply_unique(table, &acc.ids, &acc.rows);
        } else {
            self.apply_unique(table, ids, grads);
        }
    }

    /// Like [`SparseAdagrad::apply`] but skips the duplicate check:
    /// callers that just ran [`SparseGrads::accumulate`] (the trainers'
    /// `split_grads` path) are contractually duplicate-free, so the
    /// per-batch id hashing would be pure waste on the hot path.
    pub fn apply_unique(&self, table: &dyn EmbeddingStore, ids: &[u64], grads: &[f32]) {
        debug_assert!(!has_duplicates(ids), "apply_unique requires pre-accumulated ids");
        let dim = table.dim();
        let table_rows = table.rows();
        let state_rows = self.state.rows();
        for (j, &id) in ids.iter().enumerate() {
            let g = &grads[j * dim..(j + 1) * dim];
            let mut sum_sq = 0f32;
            for &x in g {
                sum_sq += x * x;
            }
            let i = id as usize;
            // hard bound: backends use raw row access, so an oversized id
            // must fail loudly here, not corrupt the heap
            assert!(
                i < table_rows && i < state_rows,
                "adagrad id {i} out of range (table rows {table_rows}, state rows {state_rows})"
            );
            let mut scale = 0f32;
            self.state.update_row(i, &mut |s| {
                s[0] += sum_sq / dim as f32;
                scale = self.lr / (s[0] + self.eps).sqrt();
            });
            table.update_row(i, &mut |row| {
                for (x, &gx) in row.iter_mut().zip(g) {
                    *x -= scale * gx;
                }
            });
        }
    }

    /// Current state scalar for row `i` (tests/diagnostics).
    pub fn state_of(&self, i: usize) -> f32 {
        let mut v = [0f32];
        self.state.read_row(i, &mut v);
        v[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::DenseStore;

    #[test]
    fn single_update_math() {
        let t = DenseStore::zeros(2, 2);
        t.set_row(0, &[1.0, 1.0]);
        let opt = SparseAdagrad::new(2, 0.1);
        // g = [3, 4]: mean(g²) = 12.5, scale = 0.1/sqrt(12.5)
        opt.apply(&t, &[0], &[3.0, 4.0]);
        let scale = 0.1 / (12.5f32 + 1e-10).sqrt();
        let row = t.row(0);
        assert!((row[0] - (1.0 - scale * 3.0)).abs() < 1e-6);
        assert!((row[1] - (1.0 - scale * 4.0)).abs() < 1e-6);
        assert!((opt.state_of(0) - 12.5).abs() < 1e-6);
        // untouched row
        assert_eq!(t.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn effective_lr_decays() {
        let t = DenseStore::zeros(1, 2);
        let opt = SparseAdagrad::new(1, 0.1);
        let before = t.row(0)[0];
        opt.apply(&t, &[0], &[1.0, 1.0]);
        let step1 = (t.row(0)[0] - before).abs();
        let mid = t.row(0)[0];
        opt.apply(&t, &[0], &[1.0, 1.0]);
        let step2 = (t.row(0)[0] - mid).abs();
        assert!(step2 < step1);
    }

    #[test]
    fn duplicate_ids_take_one_exact_step() {
        // regression: duplicates must pre-accumulate into a single step,
        // not apply sequentially in batch order
        let t = DenseStore::zeros(1, 1);
        let opt = SparseAdagrad::new(1, 1.0);
        opt.apply(&t, &[0, 0], &[1.0, 1.0]);
        // accumulated g = 2: state = 4, x = -1·2/sqrt(4) = -1
        assert!((t.row(0)[0] - (-1.0)).abs() < 1e-5, "x={}", t.row(0)[0]);
        assert!((opt.state_of(0) - 4.0).abs() < 1e-5);

        // equivalently: duplicates == the pre-summed single entry
        let t2 = DenseStore::zeros(1, 1);
        let opt2 = SparseAdagrad::new(1, 1.0);
        opt2.apply(&t2, &[0], &[2.0]);
        assert_eq!(t.row(0), t2.row(0));
        assert_eq!(opt.state_of(0), opt2.state_of(0));
    }

    #[test]
    fn duplicate_order_is_irrelevant() {
        let mk = |ids: &[u64], grads: &[f32]| {
            let t = DenseStore::zeros(3, 2);
            let opt = SparseAdagrad::new(3, 0.5);
            opt.apply(&t, ids, grads);
            t.snapshot()
        };
        let a = mk(&[2, 0, 2], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = mk(&[2, 2, 0], &[1.0, 2.0, 5.0, 6.0, 3.0, 4.0]);
        assert_eq!(a, b);
    }

    #[test]
    fn converges_quadratic() {
        // minimize (x - 3)² via its gradient
        let t = DenseStore::zeros(1, 1);
        let opt = SparseAdagrad::new(1, 1.0);
        for _ in 0..500 {
            let x = t.row(0)[0];
            opt.apply(&t, &[0], &[2.0 * (x - 3.0)]);
        }
        assert!((t.row(0)[0] - 3.0).abs() < 0.05, "x={}", t.row(0)[0]);
    }

    #[test]
    fn state_follows_table_backend() {
        let dir = std::env::temp_dir()
            .join(format!("dglke-adagrad-mmap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = StoreConfig::mmap(dir.to_string_lossy().into_owned()).resolved().unwrap();
        let table = cfg.zeros("t", 4, 2).unwrap();
        let opt = SparseAdagrad::with_storage(&cfg, "t.opt", 4, 0.1).unwrap();
        opt.apply(&*table, &[1], &[3.0, 4.0]);
        assert!((opt.state_of(1) - 12.5).abs() < 1e-6);
        // mirror on dense: identical arithmetic
        let dt = DenseStore::zeros(4, 2);
        let dopt = SparseAdagrad::new(4, 0.1);
        dopt.apply(&dt, &[1], &[3.0, 4.0]);
        assert_eq!(table.row_vec(1), dt.row(1));
        std::fs::remove_dir_all(&dir).ok();
    }
}
