//! Sparse row-wise AdaGrad (the optimizer DGL-KE uses for embeddings).
//!
//! State is one scalar per embedding row: `G_i += mean(g²)`, update
//! `x_i -= lr · g / sqrt(G_i + eps)`. Row-wise (vs element-wise) state
//! halves memory traffic on the update path — the paper's §3.5 observes
//! that random-access embedding updates dominate on large graphs, so the
//! update must stay as lean as possible.
//!
//! Updates go through [`EmbeddingTable::row_mut`], i.e. they are Hogwild:
//! concurrent updaters may interleave, which the paper accepts by design.

use super::embedding::EmbeddingTable;
use std::cell::UnsafeCell;

pub struct SparseAdagrad {
    /// per-row accumulated squared-gradient mean
    state: UnsafeCell<Vec<f32>>,
    pub lr: f32,
    pub eps: f32,
}

unsafe impl Sync for SparseAdagrad {}
unsafe impl Send for SparseAdagrad {}

impl SparseAdagrad {
    pub fn new(rows: usize, lr: f32) -> Self {
        SparseAdagrad { state: UnsafeCell::new(vec![0f32; rows]), lr, eps: 1e-10 }
    }

    /// Apply one sparse update: for each (id, grad-row) pair, advance the
    /// AdaGrad state and update the embedding row in place.
    ///
    /// `grads` is [ids.len(), dim] row-major. Duplicate ids are legal; they
    /// are applied sequentially (caller may pre-accumulate for exactness).
    pub fn apply(&self, table: &EmbeddingTable, ids: &[u64], grads: &[f32]) {
        let dim = table.dim();
        debug_assert_eq!(grads.len(), ids.len() * dim);
        let state = unsafe { &mut *self.state.get() };
        for (j, &id) in ids.iter().enumerate() {
            let g = &grads[j * dim..(j + 1) * dim];
            let mut sum_sq = 0f32;
            for &x in g {
                sum_sq += x * x;
            }
            let i = id as usize;
            state[i] += sum_sq / dim as f32;
            let scale = self.lr / (state[i] + self.eps).sqrt();
            let row = unsafe { table.row_mut(i) };
            for (x, &gx) in row.iter_mut().zip(g) {
                *x -= scale * gx;
            }
        }
    }

    /// Current state scalar for row `i` (tests/diagnostics).
    pub fn state_of(&self, i: usize) -> f32 {
        unsafe { (&*self.state.get())[i] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_update_math() {
        let t = EmbeddingTable::zeros(2, 2);
        t.set_row(0, &[1.0, 1.0]);
        let opt = SparseAdagrad::new(2, 0.1);
        // g = [3, 4]: mean(g²) = 12.5, scale = 0.1/sqrt(12.5)
        opt.apply(&t, &[0], &[3.0, 4.0]);
        let scale = 0.1 / (12.5f32 + 1e-10).sqrt();
        let row = t.row(0);
        assert!((row[0] - (1.0 - scale * 3.0)).abs() < 1e-6);
        assert!((row[1] - (1.0 - scale * 4.0)).abs() < 1e-6);
        assert!((opt.state_of(0) - 12.5).abs() < 1e-6);
        // untouched row
        assert_eq!(t.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn effective_lr_decays() {
        let t = EmbeddingTable::zeros(1, 2);
        let opt = SparseAdagrad::new(1, 0.1);
        let before = t.row(0)[0];
        opt.apply(&t, &[0], &[1.0, 1.0]);
        let step1 = (t.row(0)[0] - before).abs();
        let mid = t.row(0)[0];
        opt.apply(&t, &[0], &[1.0, 1.0]);
        let step2 = (t.row(0)[0] - mid).abs();
        assert!(step2 < step1);
    }

    #[test]
    fn duplicate_ids_apply_sequentially() {
        let t = EmbeddingTable::zeros(1, 1);
        let opt = SparseAdagrad::new(1, 1.0);
        opt.apply(&t, &[0, 0], &[1.0, 1.0]);
        // after first: state=1, x = -1/sqrt(1) = -1
        // after second: state=2, x = -1 - 1/sqrt(2)
        let expect = -1.0 - 1.0 / 2f32.sqrt();
        assert!((t.row(0)[0] - expect).abs() < 1e-5);
    }

    #[test]
    fn converges_quadratic() {
        // minimize (x - 3)² via its gradient
        let t = EmbeddingTable::zeros(1, 1);
        let opt = SparseAdagrad::new(1, 1.0);
        for _ in 0..500 {
            let x = t.row(0)[0];
            opt.apply(&t, &[0], &[2.0 * (x - 3.0)]);
        }
        assert!((t.row(0)[0] - 3.0).abs() < 0.05, "x={}", t.row(0)[0]);
    }
}
