//! Bounded hot-row cache: [`CachedStore`] wraps any [`EmbeddingStore`]
//! with a user-space row cache so repeated touches of hot rows skip the
//! backing store entirely.
//!
//! The paper's §3.5 observation is that KGE training at the 86M-entity
//! scale is bound by random-access embedding reads/writes; every one of
//! its optimizations increases data locality. The mmap backend pays a
//! `pread`/`pwrite` syscall pair per touched row, so a skewed access
//! distribution (real KGs are heavily power-law) leaves most of that
//! syscall traffic re-reading the same hot rows. `CachedStore` keeps
//! those rows in memory under an explicit byte budget:
//!
//! * **Clock / second-chance eviction**, keyed by row id. A hit sets the
//!   slot's referenced bit; the clock hand clears bits until it finds an
//!   unreferenced victim — LRU-approximate with O(1) state per slot.
//! * **Write-back with per-row dirty bits.** `set_row`/`update_row` land
//!   in the cache and mark the slot dirty; the backing store is written
//!   only on eviction, [`EmbeddingStore::flush`], export, or drop. A
//!   training run that re-updates a hot row N times issues one `pwrite`,
//!   not N.
//! * **Sharded lock stripes** (row id → stripe), so concurrency stays
//!   Hogwild-correct at row granularity: two threads touching different
//!   rows rarely contend, and a racing read of a row being written sees
//!   either old or new bytes of *that row* — never another row's bytes
//!   (the same byte-provenance guarantee the mmap backend documents, and
//!   audited by the same test pattern below).
//! * **Bulk writes bypass the cache.** `set_rows` (parallel init,
//!   checkpoint load) goes straight to the backing store and invalidates
//!   overlapping cached rows — streaming a table through the cache would
//!   just evict the hot set.
//!
//! Sizing: the cache is built from `storage.budget_mb` (the run's
//! resident-set budget; `storage.cache_mb` overrides it), split across
//! the entity/relation/optimizer tables in proportion to their
//! [`EmbeddingStore::table_bytes`] — see [`split_cache_budget`] and the
//! wiring in `ModelState::init_with_storage`. `api::Session` enforces
//! the bound *statically* at spec time (`cache_mb` must fit under
//! `budget_mb`); `resident_bytes()` reports the filled slots at runtime
//! for observability, and may exceed the configured capacity by up to
//! `n_stripes - 1` rows of ceil-division slack.
//!
//! The prefetch pipeline (PR 3) composes with this for free: the helper
//! thread's gather of batch N+1 warms the cache while batch N computes,
//! so by the time the worker (or evaluator) touches those rows they are
//! memory-resident — cache hits are credited as overlapped/zero-cost in
//! the GPU transfer ledger (`train::worker::WorkerCtx::bill_gather`).

use super::{CacheStats, EmbeddingStore};
use crate::obs::metrics::{global, Counter, Gauge};
use crate::util::sync::Mutex;
use anyhow::Result;
use std::collections::HashMap;

/// Sentinel row id for an empty slot.
const EMPTY: usize = usize::MAX;

/// Split a total cache byte budget across tables in proportion to their
/// logical size, capping each share at the table itself (a cache larger
/// than its table is wasted budget). The shares sum to at most
/// `total_cache_bytes`.
pub fn split_cache_budget(total_cache_bytes: u64, table_bytes: &[u64]) -> Vec<u64> {
    let total: u128 = table_bytes.iter().map(|&b| b as u128).sum();
    if total == 0 {
        return vec![0; table_bytes.len()];
    }
    table_bytes
        .iter()
        .map(|&b| ((total_cache_bytes as u128 * b as u128 / total) as u64).min(b))
        .collect()
}

struct Slot {
    /// cached row id (`EMPTY` = slot storage exists but holds nothing)
    row: usize,
    /// second-chance bit: set on access, cleared by the clock hand
    referenced: bool,
    /// row differs from the backing store (write-back pending)
    dirty: bool,
}

/// One lock stripe: an independent clock over `cap` slots for the rows
/// that hash here. Slot `s` owns `data[s*dim..(s+1)*dim]`; slot storage
/// is grown on demand so an idle cache costs no memory.
struct Stripe {
    index: HashMap<usize, usize>,
    slots: Vec<Slot>,
    data: Vec<f32>,
    free: Vec<usize>,
    hand: usize,
    cap: usize,
}

impl Stripe {
    fn slot_data(&mut self, s: usize, dim: usize) -> &mut [f32] {
        &mut self.data[s * dim..(s + 1) * dim]
    }
}

/// A bounded write-back row cache over any [`EmbeddingStore`]. See the
/// module docs for the eviction policy and concurrency contract.
pub struct CachedStore {
    inner: Box<dyn EmbeddingStore>,
    rows: usize,
    dim: usize,
    stripes: Vec<Mutex<Stripe>>,
    capacity_rows: usize,
    // All five counters below are statistics only — nothing reads them to
    // decide data visibility, and every mutation happens while the owning
    // stripe lock is (or was just) held. They live in the `obs::metrics`
    // registry (Relaxed internally) under `store.cache.*`; the cache's
    // *data* consistency comes entirely from the stripe mutexes.
    hits: Counter,
    misses: Counter,
    evictions: Counter,
    write_backs: Counter,
    /// slots with allocated storage (monotone up to capacity): the
    /// cache's contribution to `resident_bytes` — advisory observability,
    /// not a gate (the budget is enforced statically at spec time)
    resident_rows: Gauge,
}

impl CachedStore {
    /// Wrap `inner` with a cache of at most `cache_bytes` of row payload
    /// (bookkeeping overhead is not counted). Capacity is clamped to
    /// `[1, inner.rows()]` rows; use [`CachedStore::with_capacity_rows`]
    /// for an explicit row count.
    pub fn new(inner: Box<dyn EmbeddingStore>, cache_bytes: u64) -> CachedStore {
        let row_bytes = (inner.dim().max(1) * 4) as u64;
        // lint:allow(narrowing-cast) — the quotient is clamped to
        // [1, rows] by with_capacity_rows immediately below
        let cap = (cache_bytes / row_bytes) as usize;
        Self::with_capacity_rows(inner, cap)
    }

    pub fn with_capacity_rows(inner: Box<dyn EmbeddingStore>, capacity_rows: usize) -> CachedStore {
        let rows = inner.rows();
        let dim = inner.dim();
        let capacity_rows = capacity_rows.clamp(1, rows.max(1));
        // enough stripes to keep Hogwild threads off each other's locks,
        // but at least ~8 slots per stripe so the per-stripe clock has
        // room for second chances
        let n_stripes = (capacity_rows / 8).clamp(1, 64);
        // ceil-divide so stripe caps sum to >= capacity (at most
        // n_stripes - 1 rows over; the budget is a target, not an ABI)
        let cap_per_stripe = capacity_rows.div_ceil(n_stripes);
        let stripes = (0..n_stripes)
            .map(|_| {
                Mutex::new(Stripe {
                    index: HashMap::new(),
                    slots: Vec::new(),
                    data: Vec::new(),
                    free: Vec::new(),
                    hand: 0,
                    cap: cap_per_stripe,
                })
            })
            .collect();
        CachedStore {
            inner,
            rows,
            dim,
            stripes,
            capacity_rows,
            hits: global().counter("store.cache.hits"),
            misses: global().counter("store.cache.misses"),
            evictions: global().counter("store.cache.evictions"),
            write_backs: global().counter("store.cache.write_backs"),
            resident_rows: global().gauge("store.cache.resident_rows"),
        }
    }

    /// Cache capacity in rows (after clamping).
    pub fn capacity_rows(&self) -> usize {
        self.capacity_rows
    }

    /// The wrapped store (tests/diagnostics — reads bypass the cache and
    /// may be stale for dirty rows).
    pub fn inner(&self) -> &dyn EmbeddingStore {
        self.inner.as_ref()
    }

    #[inline]
    fn stripe_of(&self, row: usize) -> &Mutex<Stripe> {
        &self.stripes[row % self.stripes.len()]
    }

    /// Find or create a slot for `row` inside a locked stripe, evicting
    /// (with write-back) if the stripe is full. The caller fills the
    /// slot's data and inserts the index entry.
    fn allocate(&self, st: &mut Stripe, row: usize) -> usize {
        if let Some(s) = st.free.pop() {
            st.slots[s].row = row;
            return s;
        }
        if st.slots.len() < st.cap {
            let s = st.slots.len();
            st.slots.push(Slot { row, referenced: false, dirty: false });
            st.data.resize((s + 1) * self.dim, 0.0);
            self.resident_rows.add(1);
            return s;
        }
        // clock sweep: clear referenced bits until an unreferenced victim
        loop {
            let s = st.hand;
            st.hand = (st.hand + 1) % st.slots.len();
            if st.slots[s].referenced {
                st.slots[s].referenced = false;
                continue;
            }
            let victim = st.slots[s].row;
            if st.slots[s].dirty {
                let data = &st.data[s * self.dim..(s + 1) * self.dim];
                self.inner.set_row(victim, data);
                self.write_backs.inc();
            }
            st.index.remove(&victim);
            self.evictions.inc();
            st.slots[s] = Slot { row, referenced: false, dirty: false };
            return s;
        }
    }

    /// `read_row` that reports whether it was served from the cache.
    fn read_row_tracked(&self, i: usize, out: &mut [f32]) -> bool {
        debug_assert!(i < self.rows);
        let mut st = self.stripe_of(i).lock().expect("cache stripe poisoned");
        if let Some(&s) = st.index.get(&i) {
            st.slots[s].referenced = true;
            out.copy_from_slice(st.slot_data(s, self.dim));
            self.hits.inc();
            true
        } else {
            self.misses.inc();
            let s = self.allocate(&mut st, i);
            self.inner.read_row(i, st.slot_data(s, self.dim));
            st.slots[s].referenced = true;
            st.index.insert(i, s);
            out.copy_from_slice(st.slot_data(s, self.dim));
            false
        }
    }

    /// Write every dirty row back to the backing store (without forcing
    /// the backing store's own flush).
    fn write_back_all(&self) {
        for stripe in &self.stripes {
            let mut st = stripe.lock().expect("cache stripe poisoned");
            for s in 0..st.slots.len() {
                if st.slots[s].row != EMPTY && st.slots[s].dirty {
                    let row = st.slots[s].row;
                    self.inner.set_row(row, &st.data[s * self.dim..(s + 1) * self.dim]);
                    st.slots[s].dirty = false;
                    self.write_backs.inc();
                }
            }
        }
    }
}

impl Drop for CachedStore {
    /// Dirty rows must reach the backing store even without an explicit
    /// flush — a persistent-dir mmap table is expected to hold the final
    /// values after the run.
    fn drop(&mut self) {
        self.write_back_all();
    }
}

impl EmbeddingStore for CachedStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn backend_name(&self) -> &'static str {
        "cached"
    }

    fn read_row(&self, i: usize, out: &mut [f32]) {
        self.read_row_tracked(i, out);
    }

    fn set_row(&self, i: usize, values: &[f32]) {
        debug_assert_eq!(values.len(), self.dim);
        debug_assert!(i < self.rows);
        let mut st = self.stripe_of(i).lock().expect("cache stripe poisoned");
        let s = match st.index.get(&i) {
            Some(&s) => {
                self.hits.inc();
                s
            }
            None => {
                // write-allocate: no need to read the old row, it is
                // overwritten whole
                self.misses.inc();
                let s = self.allocate(&mut st, i);
                st.index.insert(i, s);
                s
            }
        };
        st.slot_data(s, self.dim).copy_from_slice(values);
        st.slots[s].referenced = true;
        st.slots[s].dirty = true;
    }

    fn update_row(&self, i: usize, f: &mut dyn FnMut(&mut [f32])) {
        debug_assert!(i < self.rows);
        let mut st = self.stripe_of(i).lock().expect("cache stripe poisoned");
        let s = match st.index.get(&i) {
            Some(&s) => {
                self.hits.inc();
                s
            }
            None => {
                self.misses.inc();
                let s = self.allocate(&mut st, i);
                self.inner.read_row(i, st.slot_data(s, self.dim));
                st.index.insert(i, s);
                s
            }
        };
        f(st.slot_data(s, self.dim));
        st.slots[s].referenced = true;
        st.slots[s].dirty = true;
    }

    /// Bulk writes stream past the cache (caching them would evict the
    /// hot set); overlapping cached rows are invalidated, dirty or not —
    /// the incoming rows overwrite them whole. Unlike the row-granular
    /// ops, this is a quiescent-path API (parallel init writes disjoint
    /// ranges into an empty cache; checkpoint load is single-threaded):
    /// the backing write and the invalidation are not atomic, so a
    /// concurrent row op or eviction inside the written range could
    /// interleave between them.
    fn set_rows(&self, first_row: usize, values: &[f32]) {
        self.inner.set_rows(first_row, values);
        let n = values.len() / self.dim.max(1);
        let n_stripes = self.stripes.len();
        for (k, stripe) in self.stripes.iter().enumerate() {
            let mut st = stripe.lock().expect("cache stripe poisoned");
            if st.index.is_empty() {
                continue;
            }
            // walk only this stripe's rows of the range (row ≡ k mod
            // n_stripes) — O(chunk rows), not O(cached rows)
            let mut row = first_row + (k + n_stripes - first_row % n_stripes) % n_stripes;
            while row < first_row + n {
                if let Some(s) = st.index.remove(&row) {
                    st.slots[s] = Slot { row: EMPTY, referenced: false, dirty: false };
                    st.free.push(s);
                }
                row += n_stripes;
            }
        }
    }

    fn gather_hits(&self, ids: &[u64], out: &mut [f32]) -> (u64, u64) {
        debug_assert_eq!(out.len(), ids.len() * self.dim);
        let mut hit_values = 0u64;
        for (j, &id) in ids.iter().enumerate() {
            if self.read_row_tracked(id as usize, &mut out[j * self.dim..(j + 1) * self.dim]) {
                hit_values += self.dim as u64;
            }
        }
        ((ids.len() * self.dim) as u64, hit_values)
    }

    /// Backing residency plus the cache's filled slots — what the budget
    /// gate in `api::Session` compares against `storage.budget_mb`.
    fn resident_bytes(&self) -> u64 {
        self.inner.resident_bytes() + self.resident_rows.get() * (self.dim as u64) * 4
    }

    fn table_bytes(&self) -> u64 {
        self.inner.table_bytes()
    }

    /// Snapshot through the backing store after draining dirty rows — one
    /// bulk path instead of `rows` cache lookups.
    fn snapshot(&self) -> Vec<f32> {
        self.write_back_all();
        self.inner.snapshot()
    }

    fn flush(&self) -> Result<()> {
        self.write_back_all();
        self.inner.flush()
    }

    /// Checkpoint export streams from the backing store (keeping the
    /// mmap backend's no-table-sized-allocation property) after draining
    /// dirty rows.
    fn export_rows(&self, w: &mut dyn std::io::Write) -> Result<()> {
        self.write_back_all();
        self.inner.export_rows(w)
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        Some(CacheStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            evictions: self.evictions.get(),
            write_backs: self.write_backs.get(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{DenseStore, MmapStore};
    use crate::util::rng::Rng;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dglke-cache-test-{tag}-{}.f32", std::process::id()))
    }

    fn cached_mmap(tag: &str, rows: usize, dim: usize, capacity: usize) -> CachedStore {
        let inner = MmapStore::create_ephemeral(&tmp_path(tag), rows, dim).unwrap();
        CachedStore::with_capacity_rows(Box::new(inner), capacity)
    }

    #[test]
    fn split_cache_budget_is_proportional_and_capped() {
        // 4:2:1:1 tables, budget 40 → 20/10/5/5
        assert_eq!(split_cache_budget(40, &[400, 200, 100, 100]), vec![20, 10, 5, 5]);
        // budget above the tables: each share caps at its table
        assert_eq!(split_cache_budget(10_000, &[400, 200]), vec![400, 200]);
        // shares never exceed the budget in total
        let shares = split_cache_budget(100, &[7, 13, 977]);
        assert!(shares.iter().sum::<u64>() <= 100);
        // empty tables
        assert_eq!(split_cache_budget(100, &[0, 0]), vec![0, 0]);
    }

    #[test]
    fn random_ops_match_uncached_mirror() {
        // the cache must be observationally invisible: a random op stream
        // through a capacity-starved cache equals the same stream on a
        // dense store
        let cache = cached_mmap("mirror", 40, 3, 8);
        let mirror = DenseStore::zeros(40, 3);
        let mut rng = Rng::seed_from_u64(5);
        let mut out_c = vec![0f32; 4 * 3];
        let mut out_m = vec![0f32; 4 * 3];
        for _ in 0..500 {
            let i = rng.gen_index(40);
            match rng.gen_index(4) {
                0 => {
                    let vals: Vec<f32> = (0..3).map(|_| rng.gen_normal()).collect();
                    cache.set_row(i, &vals);
                    mirror.set_row(i, &vals);
                }
                1 => {
                    let delta = rng.gen_normal();
                    let mut f = |row: &mut [f32]| {
                        for x in row.iter_mut() {
                            *x += delta;
                        }
                    };
                    cache.update_row(i, &mut f);
                    mirror.update_row(i, &mut f);
                }
                2 => {
                    let ids: Vec<u64> = (0..4).map(|_| rng.gen_index(40) as u64).collect();
                    cache.gather(&ids, &mut out_c);
                    mirror.gather(&ids, &mut out_m);
                    assert_eq!(out_c, out_m);
                }
                _ => assert_eq!(cache.row_vec(i), mirror.row_vec(i)),
            }
        }
        assert_eq!(cache.snapshot(), mirror.snapshot());
        let stats = cache.cache_stats().unwrap();
        assert!(stats.hits > 0 && stats.misses > 0, "{stats:?}");
        assert!(stats.evictions > 0, "capacity 8 over 40 rows must evict: {stats:?}");
    }

    #[test]
    fn eviction_and_flush_persist_every_dirty_row() {
        // write (dirty) far more rows than the cache holds: evictions
        // write back their victims, and a final flush must persist the
        // rest — after which the *backing* store holds every row
        let path = tmp_path("writeback");
        let inner = MmapStore::create(&path, 64, 2).unwrap();
        let cache = CachedStore::with_capacity_rows(Box::new(inner), 7);
        for i in 0..64 {
            cache.set_row(i, &[i as f32, -(i as f32)]);
        }
        let stats = cache.cache_stats().unwrap();
        assert!(stats.evictions >= 64 - 7, "{stats:?}");
        assert!(stats.write_backs >= stats.evictions, "every dirty victim writes back");
        cache.flush().unwrap();
        // read the backing file directly: all 64 rows present
        let direct = crate::util::bytes::bytes_to_f32(&std::fs::read(&path).unwrap());
        for i in 0..64 {
            assert_eq!(direct[i * 2..(i + 1) * 2], [i as f32, -(i as f32)], "row {i} lost");
        }
        drop(cache);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn drop_writes_back_dirty_rows() {
        let path = tmp_path("drop");
        {
            let inner = MmapStore::create(&path, 8, 2).unwrap();
            let cache = CachedStore::with_capacity_rows(Box::new(inner), 8);
            cache.set_row(3, &[1.5, 2.5]);
            // no flush: drop alone must persist
        }
        let direct = crate::util::bytes::bytes_to_f32(&std::fs::read(&path).unwrap());
        assert_eq!(direct[3 * 2..4 * 2], [1.5, 2.5]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn set_rows_bypasses_and_invalidates() {
        let cache = cached_mmap("bulk", 16, 2, 8);
        cache.set_row(4, &[9.0, 9.0]); // dirty cached row
        cache.set_row(5, &[8.0, 8.0]);
        let bulk: Vec<f32> = (0..8).map(|v| v as f32).collect(); // rows 3..7
        cache.set_rows(3, &bulk);
        // the bulk write wins over the previously-dirty cached rows
        assert_eq!(cache.row_vec(4), vec![2.0, 3.0]);
        assert_eq!(cache.row_vec(5), vec![4.0, 5.0]);
        assert_eq!(cache.row_vec(3), vec![0.0, 1.0]);
        // untouched rows unaffected
        assert_eq!(cache.row_vec(0), vec![0.0, 0.0]);
    }

    #[test]
    fn export_rows_sees_dirty_cache_rows() {
        let cache = cached_mmap("export", 6, 2, 4);
        for i in 0..6 {
            cache.set_row(i, &[i as f32, 0.5]);
        }
        let mut bytes = Vec::new();
        cache.export_rows(&mut bytes).unwrap();
        assert_eq!(crate::util::bytes::bytes_to_f32(&bytes), cache.snapshot());
    }

    #[test]
    fn resident_bytes_reports_cache_residency() {
        let cache = cached_mmap("resident", 100, 4, 10);
        assert_eq!(cache.resident_bytes(), 0, "cold cache holds nothing");
        let mut out = vec![0f32; 4];
        for i in 0..5 {
            cache.read_row(i, &mut out);
        }
        assert_eq!(cache.resident_bytes(), 5 * 4 * 4);
        // residency saturates at capacity even when more rows stream by
        for i in 0..100 {
            cache.read_row(i, &mut out);
        }
        assert!(cache.resident_bytes() <= (cache.capacity_rows() as u64 + 64) * 4 * 4);
        assert!(cache.table_bytes() == 100 * 4 * 4);
    }

    #[test]
    fn second_chance_keeps_hot_rows() {
        // one stripe (capacity < stripes cap): rows 0..4 cached, row 0
        // kept hot via the referenced bit; streaming rows through must
        // evict around it
        let cache = cached_mmap("clock", 32, 1, 4);
        let mut out = [0f32];
        cache.set_row(0, &[7.0]);
        for i in 1..32 {
            cache.read_row(0, &mut out); // keep row 0 referenced
            cache.read_row(i, &mut out);
        }
        let before = cache.cache_stats().unwrap();
        cache.read_row(0, &mut out);
        let after = cache.cache_stats().unwrap();
        assert_eq!(out, [7.0]);
        assert_eq!(after.hits, before.hits + 1, "hot row 0 must still be cached");
    }

    #[test]
    fn concurrent_gather_races_stay_value_level_through_cache() {
        // the byte-provenance audit from store::mmap, through the cached
        // path, with a capacity-starved cache so the race crosses fills,
        // hits, evictions, and write-backs: a racing gather may see old
        // or new bytes of the row it reads — never another row's bytes,
        // a short read, or a fault. Every byte written to row r carries r
        // in its low 6 bits (generation in the high 2).
        let pattern = |row: usize, g: usize| -> f32 {
            let b = (row as u8) | (((g % 4) as u8) << 6);
            f32::from_bits(u32::from_le_bytes([b; 4]))
        };
        let cache = cached_mmap("race", 64, 8, 16);
        for row in 0..64 {
            cache.set_row(row, &[pattern(row, 0); 8]);
        }
        let ids: Vec<u64> = (0..64).collect();
        crate::util::threadpool::scoped_map(2, |w| {
            if w == 0 {
                for g in 1..=50 {
                    for row in 0..64usize {
                        cache.set_row(row, &[pattern(row, g); 8]);
                    }
                }
            } else {
                let mut out = vec![0f32; 64 * 8];
                for _ in 0..200 {
                    cache.gather(&ids, &mut out);
                    for (j, lanes) in out.chunks_exact(8).enumerate() {
                        for &v in lanes {
                            for byte in v.to_bits().to_le_bytes() {
                                assert_eq!(
                                    (byte & 0x3F) as usize,
                                    j,
                                    "row {j} holds a byte written to another row"
                                );
                            }
                        }
                    }
                }
            }
        });
        let stats = cache.cache_stats().unwrap();
        assert!(stats.evictions > 0, "the audit must cross evictions: {stats:?}");
    }

    #[test]
    fn gather_hits_counts_cached_values() {
        let cache = cached_mmap("hits", 20, 4, 20);
        let ids: Vec<u64> = (0..10).collect();
        let mut out = vec![0f32; 10 * 4];
        let (moved, hit) = cache.gather_hits(&ids, &mut out);
        assert_eq!(moved, 10 * 4);
        assert_eq!(hit, 0, "cold cache: all misses");
        let (moved, hit) = cache.gather_hits(&ids, &mut out);
        assert_eq!(moved, 10 * 4);
        assert_eq!(hit, 10 * 4, "warm cache: all hits");
    }
}
