//! Dense shared-memory backend: one flat Hogwild `Vec<f32>`.
//!
//! The paper (§2, citing Hogwild [14]) trains with asynchronous sparse
//! updates: multiple trainer processes read and write rows of the global
//! embedding tensors without locks, accepting benign races because
//! mini-batches rarely collide on rows when the entity count is large.
//! `DenseStore` reproduces that: it hands out raw row views from an
//! `UnsafeCell`-backed buffer shared across threads. It is the
//! zero-regression default backend of [`crate::store::StoreConfig`].
//!
//! Safety contract: races on individual f32 lanes may produce stale or
//! torn values — that is *by design* (same as the paper/PyTorch shared
//! tensors); it never produces out-of-bounds access, and `f32` loads and
//! stores on x86-64 are individually atomic at the hardware level. The
//! aliasing itself lives in [`crate::store::racy::RacyCell`] — the one
//! quarantined site the sanitizer lanes suppress (docs/CONCURRENCY.md,
//! "Intentional races").

use super::racy::RacyCell;
use super::EmbeddingStore;

pub struct DenseStore {
    data: RacyCell<Vec<f32>>,
    rows: usize,
    dim: usize,
}

impl DenseStore {
    pub fn zeros(rows: usize, dim: usize) -> Self {
        DenseStore { data: RacyCell::new(vec![0f32; rows * dim]), rows, dim }
    }

    /// DGL-KE-style init: uniform in [-init_scale, init_scale), per-row
    /// seeded (see [`crate::store::init_uniform_rows`]).
    pub fn uniform(rows: usize, dim: usize, init_scale: f32, seed: u64) -> Self {
        let t = Self::zeros(rows, dim);
        super::init_uniform_rows(&t, init_scale, seed);
        t
    }

    /// Immutable view of row `i`. May observe concurrent writes (Hogwild).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        // SAFETY: RacyCell Hogwild contract (store::racy module docs /
        // docs/CONCURRENCY.md): the view may race with writers at f32
        // granularity; `i < rows` keeps the slice in bounds; the Vec is
        // never reallocated after construction.
        unsafe {
            let v = self.data.get_ref();
            std::slice::from_raw_parts(v.as_ptr().add(i * self.dim), self.dim)
        }
    }

    /// Mutable view of row `i`.
    ///
    /// # Safety
    /// Caller must accept Hogwild races (the [`crate::store::racy`]
    /// contract): concurrent writers to the same row interleave at f32
    /// granularity.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn row_mut(&self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        // SAFETY: propagates the caller's acceptance of the RacyCell
        // contract; bounds and no-realloc as in `row`.
        let v = self.data.get_mut();
        std::slice::from_raw_parts_mut(v.as_mut_ptr().add(i * self.dim), self.dim)
    }
}

impl EmbeddingStore for DenseStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn backend_name(&self) -> &'static str {
        "dense"
    }

    #[inline]
    fn read_row(&self, i: usize, out: &mut [f32]) {
        out.copy_from_slice(self.row(i));
    }

    #[inline]
    fn set_row(&self, i: usize, values: &[f32]) {
        debug_assert_eq!(values.len(), self.dim);
        // SAFETY: Hogwild write under the RacyCell contract (row_mut docs).
        unsafe {
            self.row_mut(i).copy_from_slice(values);
        }
    }

    #[inline]
    fn update_row(&self, i: usize, f: &mut dyn FnMut(&mut [f32])) {
        // SAFETY: Hogwild read-modify-write under the RacyCell contract.
        f(unsafe { self.row_mut(i) });
    }

    fn gather(&self, ids: &[u64], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.dim);
        for (j, &id) in ids.iter().enumerate() {
            out[j * self.dim..(j + 1) * self.dim].copy_from_slice(self.row(id as usize));
        }
    }

    fn set_rows(&self, first_row: usize, values: &[f32]) {
        debug_assert!(first_row * self.dim + values.len() <= self.rows * self.dim);
        // SAFETY: bulk Hogwild write under the RacyCell contract; the
        // debug_assert bounds the copy inside the backing Vec.
        unsafe {
            let v = self.data.get_mut();
            let dst = v.as_mut_ptr().add(first_row * self.dim);
            std::ptr::copy_nonoverlapping(values.as_ptr(), dst, values.len());
        }
    }

    fn resident_bytes(&self) -> u64 {
        self.rows as u64 * self.dim as u64 * 4
    }

    fn snapshot(&self) -> Vec<f32> {
        // SAFETY: Hogwild read under the RacyCell contract — the clone may
        // observe in-flight writes, value-level stale as documented.
        unsafe { self.data.get_ref().clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_range_and_determinism() {
        let a = DenseStore::uniform(100, 16, 0.5, 3);
        let b = DenseStore::uniform(100, 16, 0.5, 3);
        assert_eq!(a.snapshot(), b.snapshot());
        for v in a.snapshot() {
            assert!(v >= -0.5 && v < 0.5);
        }
    }

    #[test]
    fn gather_matches_rows() {
        let t = DenseStore::uniform(10, 4, 1.0, 1);
        let ids = [3u64, 7, 3];
        let mut out = vec![0f32; 3 * 4];
        t.gather(&ids, &mut out);
        assert_eq!(&out[0..4], t.row(3));
        assert_eq!(&out[4..8], t.row(7));
        assert_eq!(&out[8..12], t.row(3));
    }

    #[test]
    fn concurrent_disjoint_writes() {
        let t = DenseStore::zeros(64, 8);
        crate::util::threadpool::scoped_map(8, |w| {
            for i in 0..8 {
                let row = w * 8 + i;
                unsafe {
                    t.row_mut(row).fill(row as f32);
                }
            }
        });
        for row in 0..64 {
            assert!(t.row(row).iter().all(|&v| v == row as f32));
        }
    }

    #[test]
    fn set_row_roundtrip() {
        let t = DenseStore::zeros(4, 3);
        t.set_row(2, &[1.0, 2.0, 3.0]);
        assert_eq!(t.row(2), &[1.0, 2.0, 3.0]);
        assert_eq!(t.row(1), &[0.0; 3]);
    }

    #[test]
    fn update_row_reads_current_values() {
        let t = DenseStore::zeros(2, 2);
        t.set_row(0, &[1.0, 2.0]);
        t.update_row(0, &mut |row| {
            for x in row.iter_mut() {
                *x *= 10.0;
            }
        });
        assert_eq!(t.row(0), &[10.0, 20.0]);
    }
}
