//! Shared-memory embedding table with Hogwild-style unsynchronized access.
//!
//! The paper (§2, citing Hogwild [14]) trains with asynchronous sparse
//! updates: multiple trainer processes read and write rows of the global
//! embedding tensors without locks, accepting benign races because
//! mini-batches rarely collide on rows when the entity count is large.
//! `EmbeddingTable` reproduces that: it hands out raw row views from an
//! `UnsafeCell`-backed buffer shared across threads.
//!
//! Safety contract: races on individual f32 lanes may produce stale or
//! torn values — that is *by design* (same as the paper/PyTorch shared
//! tensors); it never produces out-of-bounds access, and `f32` loads and
//! stores on x86-64 are individually atomic at the hardware level.

use crate::util::rng::Rng;
use std::cell::UnsafeCell;

pub struct EmbeddingTable {
    data: UnsafeCell<Vec<f32>>,
    rows: usize,
    dim: usize,
}

// Hogwild: see module docs.
unsafe impl Sync for EmbeddingTable {}
unsafe impl Send for EmbeddingTable {}

impl EmbeddingTable {
    pub fn zeros(rows: usize, dim: usize) -> Self {
        EmbeddingTable { data: UnsafeCell::new(vec![0f32; rows * dim]), rows, dim }
    }

    /// DGL-KE-style init: uniform in [-init_scale, init_scale]
    /// (DGL-KE uses gamma-adjusted uniform; the scale is a hyperparameter).
    pub fn uniform(rows: usize, dim: usize, init_scale: f32, seed: u64) -> Self {
        let t = Self::zeros(rows, dim);
        {
            let data = unsafe { &mut *t.data.get() };
            // parallel init for large tables
            let n_threads = if rows * dim > 1 << 22 { 8 } else { 1 };
            let ranges = crate::util::threadpool::split_ranges(data.len(), n_threads);
            let ptr = SyncPtr(data.as_mut_ptr());
            let ptr_ref = &ptr;
            crate::util::threadpool::scoped_map(n_threads, |i| {
                let mut rng = Rng::seed_from_u64(seed).fork(i as u64);
                let r = ranges[i].clone();
                for j in r {
                    unsafe {
                        *ptr_ref.0.add(j) = rng.gen_uniform(-init_scale, init_scale);
                    }
                }
            });
        }
        t
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn n_params(&self) -> usize {
        self.rows * self.dim
    }

    /// Immutable view of row `i`. May observe concurrent writes (Hogwild).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        unsafe {
            let v = &*self.data.get();
            std::slice::from_raw_parts(v.as_ptr().add(i * self.dim), self.dim)
        }
    }

    /// Mutable view of row `i`.
    ///
    /// # Safety
    /// Caller must accept Hogwild races: concurrent writers to the same row
    /// interleave at f32 granularity.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn row_mut(&self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        let v = &mut *self.data.get();
        std::slice::from_raw_parts_mut(v.as_mut_ptr().add(i * self.dim), self.dim)
    }

    /// Gather rows `ids` into `out` ([ids.len(), dim] row-major).
    pub fn gather(&self, ids: &[u64], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.dim);
        for (j, &id) in ids.iter().enumerate() {
            out[j * self.dim..(j + 1) * self.dim].copy_from_slice(self.row(id as usize));
        }
    }

    /// Number of bytes a gather of `n` rows moves (for the transfer ledger).
    pub fn gather_bytes(&self, n: usize) -> u64 {
        (n * self.dim * 4) as u64
    }

    /// Overwrite row `i` (used by KVStore pulls and checkpoint load).
    pub fn set_row(&self, i: usize, values: &[f32]) {
        debug_assert_eq!(values.len(), self.dim);
        unsafe {
            self.row_mut(i).copy_from_slice(values);
        }
    }

    /// Full snapshot (tests / checkpoints).
    pub fn snapshot(&self) -> Vec<f32> {
        unsafe { (*self.data.get()).clone() }
    }
}

/// Send+Sync raw pointer wrapper for scoped parallel init.
struct SyncPtr(*mut f32);
unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_range_and_determinism() {
        let a = EmbeddingTable::uniform(100, 16, 0.5, 3);
        let b = EmbeddingTable::uniform(100, 16, 0.5, 3);
        assert_eq!(a.snapshot(), b.snapshot());
        for v in a.snapshot() {
            assert!(v >= -0.5 && v < 0.5);
        }
    }

    #[test]
    fn gather_matches_rows() {
        let t = EmbeddingTable::uniform(10, 4, 1.0, 1);
        let ids = [3u64, 7, 3];
        let mut out = vec![0f32; 3 * 4];
        t.gather(&ids, &mut out);
        assert_eq!(&out[0..4], t.row(3));
        assert_eq!(&out[4..8], t.row(7));
        assert_eq!(&out[8..12], t.row(3));
    }

    #[test]
    fn concurrent_disjoint_writes() {
        let t = EmbeddingTable::zeros(64, 8);
        crate::util::threadpool::scoped_map(8, |w| {
            for i in 0..8 {
                let row = w * 8 + i;
                unsafe {
                    t.row_mut(row).fill(row as f32);
                }
            }
        });
        for row in 0..64 {
            assert!(t.row(row).iter().all(|&v| v == row as f32));
        }
    }

    #[test]
    fn set_row_roundtrip() {
        let t = EmbeddingTable::zeros(4, 3);
        t.set_row(2, &[1.0, 2.0, 3.0]);
        assert_eq!(t.row(2), &[1.0, 2.0, 3.0]);
        assert_eq!(t.row(1), &[0.0; 3]);
    }
}
