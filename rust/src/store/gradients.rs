//! Sparse gradient containers: (ids, rows) pairs with duplicate-id
//! accumulation.
//!
//! A mini-batch under joint negative sampling touches each embedding row
//! possibly many times (an entity can appear as head, tail, and negative).
//! Before the optimizer applies the update — and before gradients are
//! pushed over the KVStore — duplicates are folded together, which both
//! matches DGL-KE's `index_add_`-style accumulation and minimizes rows on
//! the wire.

use std::collections::HashMap;

/// A batch of sparse gradients over one embedding table.
#[derive(Clone, Debug, Default)]
pub struct SparseGrads {
    pub ids: Vec<u64>,
    /// [ids.len(), dim] row-major
    pub rows: Vec<f32>,
    pub dim: usize,
}

impl SparseGrads {
    pub fn new(dim: usize) -> Self {
        SparseGrads { ids: Vec::new(), rows: Vec::new(), dim }
    }

    pub fn with_capacity(dim: usize, n: usize) -> Self {
        SparseGrads { ids: Vec::with_capacity(n), rows: Vec::with_capacity(n * dim), dim }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Append gradient rows for `ids` from a contiguous buffer.
    pub fn extend_from(&mut self, ids: &[u64], rows: &[f32]) {
        debug_assert_eq!(rows.len(), ids.len() * self.dim);
        self.ids.extend_from_slice(ids);
        self.rows.extend_from_slice(rows);
    }

    /// Fold duplicate ids by summing their rows. Keeps first-seen order.
    pub fn accumulate(self) -> SparseGrads {
        let dim = self.dim;
        let mut index: HashMap<u64, usize> = HashMap::with_capacity(self.ids.len());
        let mut out = SparseGrads::with_capacity(dim, self.ids.len());
        for (j, &id) in self.ids.iter().enumerate() {
            let src = &self.rows[j * dim..(j + 1) * dim];
            match index.get(&id) {
                Some(&slot) => {
                    let dst = &mut out.rows[slot * dim..(slot + 1) * dim];
                    for (d, s) in dst.iter_mut().zip(src) {
                        *d += s;
                    }
                }
                None => {
                    index.insert(id, out.ids.len());
                    out.ids.push(id);
                    out.rows.extend_from_slice(src);
                }
            }
        }
        out
    }

    /// Split by a shard function (e.g. KVStore server of each id).
    pub fn split_by<F: Fn(u64) -> usize>(&self, n_shards: usize, shard_of: F) -> Vec<SparseGrads> {
        let mut out: Vec<SparseGrads> = (0..n_shards).map(|_| SparseGrads::new(self.dim)).collect();
        for (j, &id) in self.ids.iter().enumerate() {
            let s = shard_of(id);
            out[s].ids.push(id);
            out[s].rows.extend_from_slice(&self.rows[j * self.dim..(j + 1) * self.dim]);
        }
        out
    }

    /// Total bytes this gradient batch occupies on the wire.
    pub fn wire_bytes(&self) -> u64 {
        (self.ids.len() * 8 + self.rows.len() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_folds_duplicates() {
        let mut g = SparseGrads::new(2);
        g.extend_from(&[5, 3, 5], &[1.0, 2.0, 10.0, 20.0, 100.0, 200.0]);
        let a = g.accumulate();
        assert_eq!(a.ids, vec![5, 3]);
        assert_eq!(a.rows, vec![101.0, 202.0, 10.0, 20.0]);
    }

    #[test]
    fn accumulate_no_duplicates_is_identity() {
        let mut g = SparseGrads::new(1);
        g.extend_from(&[1, 2, 3], &[0.1, 0.2, 0.3]);
        let a = g.accumulate();
        assert_eq!(a.ids, vec![1, 2, 3]);
        assert_eq!(a.rows, vec![0.1, 0.2, 0.3]);
    }

    #[test]
    fn split_by_shard() {
        let mut g = SparseGrads::new(1);
        g.extend_from(&[0, 1, 2, 3], &[0.0, 1.0, 2.0, 3.0]);
        let parts = g.split_by(2, |id| (id % 2) as usize);
        assert_eq!(parts[0].ids, vec![0, 2]);
        assert_eq!(parts[1].ids, vec![1, 3]);
        assert_eq!(parts[1].rows, vec![1.0, 3.0]);
    }

    #[test]
    fn wire_bytes() {
        let mut g = SparseGrads::new(4);
        g.extend_from(&[9], &[0.0; 4]);
        assert_eq!(g.wire_bytes(), 8 + 16);
    }
}
