//! File-backed backend for larger-than-RAM embedding tables.
//!
//! Rows live in a flat backing file (`rows × dim` little-endian f32s,
//! row-major — the same layout checkpoints use) and are read/written with
//! positioned I/O (`pread`/`pwrite` via `std::os::unix::fs::FileExt`); the
//! OS page cache plays the role of the mapped working set, bounded by
//! available memory rather than table size. No `mmap(2)` call is issued —
//! the vendored dependency set has no `libc` — but the access model is the
//! same: only touched pages are resident, and `resident_bytes()` is 0 from
//! the process-heap perspective.
//!
//! Concurrent row updates race at row granularity (Hogwild, like every
//! backend); positioned I/O never moves a shared cursor, so races stay
//! value-level, never structural. This matters specifically for the
//! prefetch pipeline, where a helper thread gathers rows while the
//! worker (and async updater) write them: `pread` against a concurrent
//! `pwrite` of the same row returns some interleaving of old and new
//! bytes for that row only — never another row's data, a short read, or
//! a fault (audited by `concurrent_gather_races_stay_value_level` below).
//!
//! Checkpoint export streams straight from the backing file
//! ([`EmbeddingStore::export_rows`]) — no full-table `snapshot()` clone,
//! which is the difference between "checkpoint = table-sized allocation"
//! and "checkpoint = bounded buffer" at Freebase scale.

use super::EmbeddingStore;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

pub struct MmapStore {
    file: File,
    path: PathBuf,
    rows: usize,
    dim: usize,
    /// Byte offset of row 0 in the backing file. 0 for tables created by
    /// this store; non-zero for read-only views over checkpoint table
    /// files, whose rows sit behind a length header ([`MmapStore::open_at`]).
    base: u64,
}

thread_local! {
    /// Per-thread row scratch for read-modify-write (`update_row`).
    static SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

impl MmapStore {
    /// Create (or truncate) a backing file of `rows × dim` zeros. The file
    /// is extended sparsely, so an untouched table costs no disk. The file
    /// persists after the store is dropped (the caller owns the dir).
    pub fn create(path: &Path, rows: usize, dim: usize) -> Result<MmapStore> {
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("creating mmap store {}", path.display()))?;
        // size in u64: rows * dim * 4 overflows usize on 32-bit targets
        // for >4 GiB tables (Freebase at dim 400 is ~138 GiB)
        file.set_len(rows as u64 * dim as u64 * 4)
            .with_context(|| format!("sizing mmap store {}", path.display()))?;
        Ok(MmapStore { file, path: path.to_path_buf(), rows, dim, base: 0 })
    }

    /// Open an *existing* file as a read-only `rows × dim` table whose
    /// row 0 starts `base` bytes into the file — the zero-copy load path
    /// of the serving layer, which views checkpoint table files (rows
    /// behind an 8-byte length header) in place instead of streaming
    /// them into a fresh table. The file must be at least
    /// `base + rows * dim * 4` bytes; short files are rejected here, so
    /// a truncated checkpoint fails at open time, not mid-query.
    ///
    /// The store is opened without write permission: the row-write
    /// methods (`set_row` / `set_rows` / `update_row`) panic if called,
    /// which is the documented I/O-error contract of this backend —
    /// snapshot tables are immutable by construction.
    pub fn open_at(path: &Path, base: u64, rows: usize, dim: usize) -> Result<MmapStore> {
        let file = std::fs::OpenOptions::new()
            .read(true)
            .open(path)
            .with_context(|| format!("opening mmap table {}", path.display()))?;
        let need = base + rows as u64 * dim as u64 * 4;
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        anyhow::ensure!(
            len >= need,
            "{}: file is {len} bytes but a {rows}x{dim} table at offset {base} needs {need} \
             (truncated checkpoint?)",
            path.display()
        );
        Ok(MmapStore { file, path: path.to_path_buf(), rows, dim, base })
    }

    /// Like [`MmapStore::create`], but the backing file is unlinked
    /// immediately after opening: it stays fully usable through the open
    /// descriptor and the kernel reclaims the space when the store is
    /// dropped — even if the process crashes. Used for runs that did not
    /// pin a `storage.dir`, so scratch tables never accumulate in /tmp.
    pub fn create_ephemeral(path: &Path, rows: usize, dim: usize) -> Result<MmapStore> {
        let store = Self::create(path, rows, dim)?;
        std::fs::remove_file(path)
            .with_context(|| format!("unlinking ephemeral mmap store {}", path.display()))?;
        Ok(store)
    }

    /// The path the backing file was created at (already unlinked for
    /// ephemeral stores).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Byte offset of row `i`, computed in `u64` *before* any narrowing:
    /// `i * dim * 4` in `usize` wraps on 32-bit targets once the table
    /// crosses 4 GiB, silently aliasing distant rows.
    #[inline]
    fn offset(&self, i: usize) -> u64 {
        debug_assert!(i < self.rows);
        self.base + i as u64 * self.dim as u64 * 4
    }
}

impl EmbeddingStore for MmapStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn backend_name(&self) -> &'static str {
        "mmap"
    }

    fn read_row(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        self.file
            .read_exact_at(crate::util::bytes::f32_as_bytes_mut(out), self.offset(i))
            .expect("MmapStore: backing-file read failed");
    }

    fn set_row(&self, i: usize, values: &[f32]) {
        debug_assert_eq!(values.len(), self.dim);
        self.file
            .write_all_at(crate::util::bytes::f32_as_bytes(values), self.offset(i))
            .expect("MmapStore: backing-file write failed");
    }

    /// One positioned write per chunk instead of one syscall per row.
    fn set_rows(&self, first_row: usize, values: &[f32]) {
        debug_assert!(
            first_row as u64 * self.dim as u64 + values.len() as u64
                <= self.rows as u64 * self.dim as u64
        );
        self.file
            .write_all_at(crate::util::bytes::f32_as_bytes(values), self.offset(first_row))
            .expect("MmapStore: backing-file write failed");
    }

    fn update_row(&self, i: usize, f: &mut dyn FnMut(&mut [f32])) {
        SCRATCH.with(|c| {
            let mut buf = c.borrow_mut();
            buf.resize(self.dim, 0.0);
            self.read_row(i, &mut buf[..]);
            f(&mut buf[..]);
            self.set_row(i, &buf[..]);
        });
    }

    /// Rows live on disk / in the page cache, not on the process heap.
    fn resident_bytes(&self) -> u64 {
        0
    }

    fn flush(&self) -> Result<()> {
        self.file
            .sync_data()
            .with_context(|| format!("flushing mmap store {}", self.path.display()))
    }

    fn export_rows(&self, w: &mut dyn std::io::Write) -> Result<()> {
        let total = self.rows as u64 * self.dim as u64 * 4;
        // chunk math stays in u64 until after the min with the (<= 1 MiB)
        // buffer length — `total as usize` would wrap on 32-bit targets
        // for >4 GiB tables and stall the copy loop
        let mut buf = vec![0u8; total.clamp(1, 1 << 20) as usize];
        let mut off = 0u64;
        while off < total {
            let n = (total - off).min(buf.len() as u64) as usize;
            self.file
                .read_exact_at(&mut buf[..n], self.base + off)
                .with_context(|| format!("exporting mmap store {}", self.path.display()))?;
            w.write_all(&buf[..n])?;
            off += n as u64;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dglke-mmap-test-{tag}-{}.f32", std::process::id()))
    }

    #[test]
    fn rows_round_trip_through_file() {
        let path = tmp_path("roundtrip");
        let t = MmapStore::create(&path, 5, 3).unwrap();
        assert_eq!(t.row_vec(4), vec![0.0; 3]); // sparse zeros
        t.set_row(2, &[1.5, -2.5, 3.0]);
        assert_eq!(t.row_vec(2), vec![1.5, -2.5, 3.0]);
        t.update_row(2, &mut |row| row[1] = 9.0);
        assert_eq!(t.row_vec(2), vec![1.5, 9.0, 3.0]);
        t.flush().unwrap();
        assert_eq!(t.resident_bytes(), 0);
        assert!(t.table_bytes() > 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn export_streams_file_contents() {
        let path = tmp_path("export");
        let t = MmapStore::create(&path, 4, 2).unwrap();
        for i in 0..4 {
            t.set_row(i, &[i as f32, i as f32 + 0.5]);
        }
        let mut bytes = Vec::new();
        t.export_rows(&mut bytes).unwrap();
        assert_eq!(crate::util::bytes::bytes_to_f32(&bytes), t.snapshot());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ephemeral_file_is_unlinked_but_usable() {
        let path = tmp_path("ephemeral");
        let t = MmapStore::create_ephemeral(&path, 3, 2).unwrap();
        assert!(!path.exists(), "backing file should be unlinked");
        t.set_row(1, &[4.0, 5.0]);
        assert_eq!(t.row_vec(1), vec![4.0, 5.0]);
        let mut bytes = Vec::new();
        t.export_rows(&mut bytes).unwrap();
        assert_eq!(bytes.len(), 3 * 2 * 4);
    }

    #[test]
    fn concurrent_gather_races_stay_value_level() {
        // the prefetch-pipeline audit: one thread gathers the same id set
        // over and over while another rewrites those rows. The documented
        // guarantee is byte provenance, not atomicity: a racing read may
        // interleave old and new bytes of *that row* (Hogwild tearing),
        // but never bytes of another row, a short read, or a fault. Every
        // value ever written to row r has all four bytes carrying r in
        // the low 6 bits (generation in the high 2), so each gathered
        // byte proves which row it came from regardless of tearing.
        let pattern = |row: usize, g: usize| -> f32 {
            let b = (row as u8) | (((g % 4) as u8) << 6);
            f32::from_bits(u32::from_le_bytes([b; 4]))
        };
        let path = tmp_path("gather-race");
        let t = MmapStore::create(&path, 64, 8).unwrap();
        for row in 0..64 {
            t.set_row(row, &[pattern(row, 0); 8]);
        }
        let ids: Vec<u64> = (0..64).collect();
        crate::util::threadpool::scoped_map(2, |w| {
            if w == 0 {
                for g in 1..=50 {
                    for row in 0..64usize {
                        t.set_row(row, &[pattern(row, g); 8]);
                    }
                }
            } else {
                let mut out = vec![0f32; 64 * 8];
                for _ in 0..200 {
                    t.gather(&ids, &mut out);
                    for (j, lanes) in out.chunks_exact(8).enumerate() {
                        for &v in lanes {
                            for byte in v.to_bits().to_le_bytes() {
                                assert_eq!(
                                    (byte & 0x3F) as usize,
                                    j,
                                    "row {j} holds a byte written to another row"
                                );
                            }
                        }
                    }
                }
            }
        });
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn offsets_past_4gib_stay_exact() {
        // regression for the usize-before-u64 offset arithmetic: at
        // rows*dim*4 > u32::MAX the last row's byte offset exceeds 2^32,
        // which a 32-bit usize multiply would have wrapped into the
        // start of the file. The file is sparse, so the 4 GiB footprint
        // is logical, not physical — only the touched pages cost disk.
        let dim = 1024usize;
        let rows = (1usize << 20) + 1; // rows*dim*4 = 4 GiB + 4 KiB > u32::MAX
        let t = MmapStore::create_ephemeral(&tmp_path("4gib"), rows, dim).unwrap();
        assert_eq!(t.table_bytes(), 4 * rows as u64 * dim as u64);
        assert!(t.table_bytes() > u32::MAX as u64);
        let marker: Vec<f32> = (0..dim).map(|k| k as f32 + 0.5).collect();
        let head = vec![-1.0f32; dim];
        t.set_row(rows - 1, &marker); // offset 2^32 exactly
        t.set_row(0, &head);
        assert_eq!(t.row_vec(rows - 1), marker, "last row must not alias the file head");
        assert_eq!(t.row_vec(0), head);
        // a row past the 4 GiB line round-trips through update_row too
        t.update_row(rows - 1, &mut |row| row[0] = 7.0);
        assert_eq!(t.row_vec(rows - 1)[0], 7.0);
        assert_eq!(t.row_vec(rows - 2), vec![0.0; dim], "neighbor stays untouched");
    }

    #[test]
    fn open_at_views_rows_behind_a_header() {
        // checkpoint table layout: [u64 n_values][rows] — open_at(base=8)
        // must see exactly the rows, never the header bytes
        let path = tmp_path("openat");
        let rows = 6usize;
        let dim = 3usize;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&((rows * dim) as u64).to_le_bytes());
        for i in 0..rows {
            for k in 0..dim {
                bytes.extend_from_slice(&(i as f32 * 10.0 + k as f32).to_le_bytes());
            }
        }
        std::fs::write(&path, &bytes).unwrap();
        let t = MmapStore::open_at(&path, 8, rows, dim).unwrap();
        assert_eq!(t.rows(), rows);
        assert_eq!(t.dim(), dim);
        for i in 0..rows {
            assert_eq!(t.row_vec(i), vec![i as f32 * 10.0, i as f32 * 10.0 + 1.0, i as f32 * 10.0 + 2.0]);
        }
        // export streams the rows, not the header
        let mut exported = Vec::new();
        t.export_rows(&mut exported).unwrap();
        assert_eq!(exported, bytes[8..].to_vec());
        // a short file is rejected at open time
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        let err = MmapStore::open_at(&path, 8, rows, dim).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_disjoint_rows() {
        let path = tmp_path("hogwild");
        let t = MmapStore::create(&path, 32, 4).unwrap();
        crate::util::threadpool::scoped_map(4, |w| {
            for i in 0..8 {
                let row = w * 8 + i;
                t.set_row(row, &[row as f32; 4]);
            }
        });
        for row in 0..32 {
            assert_eq!(t.row_vec(row), vec![row as f32; 4]);
        }
        std::fs::remove_file(&path).ok();
    }
}
