//! Embedding storage and optimization: Hogwild shared tables, sparse
//! row-wise AdaGrad, and sparse-gradient containers.

pub mod adagrad;
pub mod embedding;
pub mod gradients;

pub use adagrad::SparseAdagrad;
pub use embedding::EmbeddingTable;
pub use gradients::SparseGrads;
