//! Embedding storage and optimization.
//!
//! The paper's core scaling observation (§3.5) is that KGE training at the
//! 86M-entity scale is dominated by random-access embedding reads/writes —
//! the storage layer, not the score kernel, is the bottleneck. This module
//! therefore puts storage behind one trait, [`EmbeddingStore`], with three
//! backends selected by [`StoreConfig`]:
//!
//! * [`DenseStore`] — one flat Hogwild `Vec<f32>` (the zero-regression
//!   default; what the old `EmbeddingTable` was);
//! * [`ShardedStore`] — N independently-allocated dense shards with
//!   per-shard parallel init/flush, making per-partition placement
//!   explicit for the KVStore/distributed layers;
//! * [`MmapStore`] — file-backed rows for larger-than-RAM tables, with
//!   streaming (no full-table clone) checkpoint export.
//!
//! Mmap tables (and their optimizer state) are wrapped in a
//! budget-bounded hot-row cache ([`CachedStore`]) when the config
//! carries a cache budget (`cache_mb`, defaulting to `budget_mb`) — see
//! the `cache` module docs.
//!
//! [`SparseAdagrad`] keeps its per-row state behind the same trait, so
//! optimizer state shards/spills alongside its table. [`SparseGrads`] is
//! the sparse-gradient container shared by the trainers and the KVStore
//! wire path.
//!
//! Row initialization is *per-row* seeded ([`init_uniform_rows`]): the
//! value of row `r` depends only on `(seed, r)`, never on the backend,
//! shard count, or init thread count — so every backend trains
//! byte-identically from the same spec (see `rust/tests/storage_tests.rs`).

pub mod adagrad;
pub mod cache;
pub mod dense;
pub mod gradients;
pub mod mmap;
pub mod racy;
pub mod sharded;

pub use adagrad::SparseAdagrad;
pub use cache::{split_cache_budget, CachedStore};
pub use dense::DenseStore;
pub use gradients::SparseGrads;
pub use mmap::MmapStore;
pub use sharded::ShardedStore;

use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Row-granular embedding storage with Hogwild semantics.
///
/// All methods take `&self`: concurrent readers and writers race at row
/// (and, within a row, f32-lane) granularity, which the paper accepts by
/// design for asynchronous sparse training. Implementations must never
/// produce out-of-bounds access; torn/stale lanes under contention are
/// permitted. I/O-backed implementations panic on I/O errors in the
/// row-granular methods (the hot path carries no `Result`), and report
/// failures from [`EmbeddingStore::flush`].
pub trait EmbeddingStore: Send + Sync {
    fn rows(&self) -> usize;

    fn dim(&self) -> usize;

    /// Backend tag ("dense" / "sharded" / "mmap") for logs and reports.
    fn backend_name(&self) -> &'static str;

    /// Copy row `i` into `out` (`out.len() == dim`).
    fn read_row(&self, i: usize, out: &mut [f32]);

    /// Overwrite row `i` (`values.len() == dim`).
    fn set_row(&self, i: usize, values: &[f32]);

    /// Read-modify-write row `i` in place. The closure sees the current
    /// row contents and mutates them; backends without resident rows load
    /// the row, apply the closure, and write it back.
    fn update_row(&self, i: usize, f: &mut dyn FnMut(&mut [f32]));

    /// Overwrite a contiguous run of rows starting at `first_row`
    /// (`values.len()` is a multiple of `dim`). Bulk writers (init,
    /// checkpoint load) should prefer this over per-row [`set_row`]:
    /// file-backed stores turn it into one positioned write instead of
    /// one syscall per row.
    ///
    /// [`set_row`]: EmbeddingStore::set_row
    fn set_rows(&self, first_row: usize, values: &[f32]) {
        let dim = self.dim();
        debug_assert_eq!(values.len() % dim.max(1), 0);
        for (k, row) in values.chunks_exact(dim).enumerate() {
            self.set_row(first_row + k, row);
        }
    }

    /// Bytes resident in RAM for this table (0 when rows live on disk;
    /// a [`CachedStore`] reports its filled cache slots).
    fn resident_bytes(&self) -> u64;

    /// Gather rows `ids` into `out` (`[ids.len(), dim]`, row-major).
    fn gather(&self, ids: &[u64], out: &mut [f32]) {
        let dim = self.dim();
        debug_assert_eq!(out.len(), ids.len() * dim);
        for (j, &id) in ids.iter().enumerate() {
            self.read_row(id as usize, &mut out[j * dim..(j + 1) * dim]);
        }
    }

    /// Like [`EmbeddingStore::gather`], but also reports how many of the
    /// gathered f32 values were served from a hot-row cache — `(values
    /// moved, values hit)`. The GPU transfer ledger credits hit values as
    /// zero-cost/overlapped rather than critical-path h2d traffic.
    /// Cacheless backends move everything and hit nothing.
    fn gather_hits(&self, ids: &[u64], out: &mut [f32]) -> (u64, u64) {
        self.gather(ids, out);
        ((ids.len() * self.dim()) as u64, 0)
    }

    /// Hit/miss/eviction/write-back counters, when this store has a
    /// hot-row cache in front of it (`None` otherwise). Counters are
    /// cumulative over the store's lifetime.
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }

    /// Element count of the table. `usize` because it sizes in-memory
    /// buffers; on 32-bit targets a table can exceed it — size *bytes*
    /// (checkpoint framing, budget math) from [`EmbeddingStore::table_bytes`],
    /// which computes in `u64`, never from this.
    fn n_params(&self) -> usize {
        self.rows() * self.dim()
    }

    /// Total logical table size in bytes (independent of residency).
    /// Computed in `u64` — `rows * dim * 4` can exceed `usize` on 32-bit
    /// targets at Freebase scale.
    fn table_bytes(&self) -> u64 {
        self.rows() as u64 * self.dim() as u64 * 4
    }

    /// Number of bytes a gather of `n` rows moves (for the transfer ledger).
    fn gather_bytes(&self, n: usize) -> u64 {
        n as u64 * self.dim() as u64 * 4
    }

    /// Owned copy of row `i` (tests, cold paths).
    fn row_vec(&self, i: usize) -> Vec<f32> {
        let mut out = vec![0f32; self.dim()];
        self.read_row(i, &mut out);
        out
    }

    /// Full copy of the table (tests, cold paths). Checkpoints should use
    /// [`EmbeddingStore::export_rows`] instead, which never materializes
    /// the whole table.
    fn snapshot(&self) -> Vec<f32> {
        let dim = self.dim();
        let mut out = vec![0f32; self.n_params()];
        for i in 0..self.rows() {
            self.read_row(i, &mut out[i * dim..(i + 1) * dim]);
        }
        out
    }

    /// Persist pending writes (no-op for memory backends).
    fn flush(&self) -> Result<()> {
        Ok(())
    }

    /// Stream every row, in order, as raw little-endian f32 bytes into `w`
    /// without materializing a full-table copy. File-backed stores copy
    /// straight from their backing file.
    fn export_rows(&self, w: &mut dyn std::io::Write) -> Result<()> {
        let dim = self.dim();
        let rows = self.rows();
        if dim == 0 || rows == 0 {
            return Ok(());
        }
        let chunk_rows = chunk_rows_for(dim, rows);
        let mut buf = vec![0f32; chunk_rows * dim];
        let mut r = 0;
        while r < rows {
            let n = chunk_rows.min(rows - r);
            for k in 0..n {
                self.read_row(r + k, &mut buf[k * dim..(k + 1) * dim]);
            }
            w.write_all(crate::util::bytes::f32_as_bytes(&buf[..n * dim]))?;
            r += n;
        }
        Ok(())
    }
}

/// Rows per bulk-I/O chunk (~256 KiB) for a `dim`-wide table — the one
/// formula shared by parallel init, checkpoint export, and checkpoint
/// load, so chunk-size tuning happens in exactly one place. Rounds
/// *down* to stay at or under the 256 KiB target (minimum one row, so
/// wide tables still make progress).
pub fn chunk_rows_for(dim: usize, rows: usize) -> usize {
    ((1usize << 16) / dim.max(1)).max(1).min(rows.max(1))
}

/// Hot-row-cache counters reported by [`EmbeddingStore::cache_stats`]
/// (cumulative over the store's lifetime) and surfaced per-run in
/// `api::Report`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// row accesses served from the cache
    pub hits: u64,
    /// row accesses that had to touch the backing store (or allocate)
    pub misses: u64,
    /// rows displaced by the clock sweep
    pub evictions: u64,
    /// dirty rows written back (on eviction, flush, export, or drop)
    pub write_backs: u64,
}

impl CacheStats {
    pub fn accumulate(&mut self, o: CacheStats) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.evictions += o.evictions;
        self.write_backs += o.write_backs;
    }

    /// Counter delta since an `earlier` snapshot (per-run accounting
    /// over cumulative counters).
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            evictions: self.evictions.saturating_sub(earlier.evictions),
            write_backs: self.write_backs.saturating_sub(earlier.write_backs),
        }
    }

    pub fn total_accesses(&self) -> u64 {
        self.hits + self.misses
    }
}

/// Which [`EmbeddingStore`] implementation a [`StoreConfig`] builds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreBackendKind {
    Dense,
    Sharded,
    Mmap,
}

impl StoreBackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            StoreBackendKind::Dense => "dense",
            StoreBackendKind::Sharded => "sharded",
            StoreBackendKind::Mmap => "mmap",
        }
    }

    pub fn parse(s: &str) -> Option<StoreBackendKind> {
        match s {
            "dense" => Some(StoreBackendKind::Dense),
            "sharded" => Some(StoreBackendKind::Sharded),
            "mmap" => Some(StoreBackendKind::Mmap),
            _ => None,
        }
    }
}

/// Declarative storage-backend selection; the `"storage"` field of a
/// `RunSpec` (see `api::spec` for the JSON form).
#[derive(Clone, Debug, PartialEq)]
pub struct StoreConfig {
    pub backend: StoreBackendKind,
    /// shard count (sharded backend only)
    pub shards: usize,
    /// backing directory (mmap backend). `None` = anonymous temp files,
    /// unlinked at creation so the kernel reclaims them when the run ends
    /// (crash-safe); `Some(dir)` = persistent files the caller owns.
    pub dir: Option<String>,
    /// optional in-memory budget in MiB (fractional allowed). Runs whose
    /// tables would exceed it must use the mmap backend; enforced by
    /// `api::Session`. For mmap runs this also sizes the hot-row cache
    /// (unless [`StoreConfig::cache_mb`] overrides it).
    pub budget_mb: Option<f64>,
    /// hot-row cache size in MiB for mmap tables (fractional allowed),
    /// overriding the `budget_mb`-derived default. Must not exceed
    /// `budget_mb` when both are set (the cache *is* the resident set of
    /// an mmap run). Ignored by the in-memory backends, which are their
    /// own cache.
    pub cache_mb: Option<f64>,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            backend: StoreBackendKind::Dense,
            shards: 8,
            dir: None,
            budget_mb: None,
            cache_mb: None,
        }
    }
}

// lint:allow(metrics-registry) — process-unique scratch-file name source, not a stat
static MMAP_FILE_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

impl StoreConfig {
    pub fn dense() -> StoreConfig {
        StoreConfig::default()
    }

    pub fn sharded(shards: usize) -> StoreConfig {
        StoreConfig { backend: StoreBackendKind::Sharded, shards, ..StoreConfig::default() }
    }

    pub fn mmap(dir: impl Into<String>) -> StoreConfig {
        StoreConfig {
            backend: StoreBackendKind::Mmap,
            dir: Some(dir.into()),
            ..StoreConfig::default()
        }
    }

    /// Structural validation (cheap; no filesystem access).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.shards >= 1, "storage.shards must be >= 1");
        if let Some(mb) = self.budget_mb {
            anyhow::ensure!(mb > 0.0, "storage.budget_mb must be positive");
        }
        if let Some(mb) = self.cache_mb {
            anyhow::ensure!(mb > 0.0, "storage.cache_mb must be positive");
        }
        Ok(())
    }

    /// Total hot-row-cache byte budget for this config: `cache_mb` when
    /// set, else `budget_mb` (an mmap run's budget is exactly its cache
    /// allowance — the rows themselves live on disk). `None` for the
    /// in-memory backends or when neither knob is set. Callers holding
    /// several tables split this with [`split_cache_budget`].
    pub fn cache_total_bytes(&self) -> Option<u64> {
        if self.backend != StoreBackendKind::Mmap {
            return None;
        }
        let mb = self.cache_mb.or(self.budget_mb)?;
        Some((mb * (1u64 << 20) as f64) as u64)
    }

    /// Fill in runtime defaults: clamp the shard count and create the
    /// explicit mmap backing dir when one is pinned. (With `dir: None`,
    /// mmap tables use anonymous unlinked temp files — nothing to create.)
    pub fn resolved(&self) -> Result<StoreConfig> {
        let mut cfg = self.clone();
        cfg.shards = cfg.shards.max(1);
        if cfg.backend == StoreBackendKind::Mmap {
            if let Some(dir) = &cfg.dir {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating storage dir {dir}"))?;
            }
        }
        Ok(cfg)
    }

    /// `cache_bytes` is this *table's* share of the cache budget (the
    /// proportional split across a model's tables happens in the caller,
    /// which is the only place that sees every table) — `None` or a
    /// sub-row share builds uncached. Only mmap tables are wrapped: the
    /// in-memory backends are their own cache.
    fn build(
        &self,
        label: &str,
        rows: usize,
        dim: usize,
        cache_bytes: Option<u64>,
    ) -> Result<Box<dyn EmbeddingStore>> {
        let store: Box<dyn EmbeddingStore> = match self.backend {
            StoreBackendKind::Dense => Box::new(DenseStore::zeros(rows, dim)),
            StoreBackendKind::Sharded => {
                Box::new(ShardedStore::zeros(rows, dim, self.shards.max(1)))
            }
            StoreBackendKind::Mmap => match &self.dir {
                Some(dir) => {
                    let path = std::path::Path::new(dir).join(format!("{label}.f32"));
                    Box::new(MmapStore::create(&path, rows, dim)?)
                }
                None => {
                    // anonymous scratch table: unique temp name, unlinked at
                    // creation so the space is reclaimed when the run ends
                    let n = MMAP_FILE_COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let path = std::env::temp_dir().join(format!(
                        "dglke-store-{}-{n}-{label}.f32",
                        std::process::id()
                    ));
                    Box::new(MmapStore::create_ephemeral(&path, rows, dim)?)
                }
            },
        };
        Ok(match cache_bytes {
            Some(bytes)
                if self.backend == StoreBackendKind::Mmap
                    && rows > 0
                    && bytes >= dim.max(1) as u64 * 4 =>
            {
                Box::new(CachedStore::new(store, bytes))
            }
            _ => store,
        })
    }

    /// Build a zero-initialized table.
    pub fn zeros(&self, label: &str, rows: usize, dim: usize) -> Result<Arc<dyn EmbeddingStore>> {
        Ok(Arc::from(self.build(label, rows, dim, None)?))
    }

    /// Build a table initialized uniform in `[-init_scale, init_scale]`
    /// with backend-independent per-row seeding.
    pub fn uniform(
        &self,
        label: &str,
        rows: usize,
        dim: usize,
        init_scale: f32,
        seed: u64,
    ) -> Result<Arc<dyn EmbeddingStore>> {
        self.uniform_cached(label, rows, dim, init_scale, seed, None)
    }

    /// Like [`StoreConfig::uniform`], with an explicit hot-row-cache byte
    /// share for this table (mmap backend only; `None` = uncached).
    pub fn uniform_cached(
        &self,
        label: &str,
        rows: usize,
        dim: usize,
        init_scale: f32,
        seed: u64,
        cache_bytes: Option<u64>,
    ) -> Result<Arc<dyn EmbeddingStore>> {
        let store = self.build(label, rows, dim, cache_bytes)?;
        init_uniform_rows(store.as_ref(), init_scale, seed);
        Ok(Arc::from(store))
    }

    /// Build optimizer state (one scalar per row) on the same backend, so
    /// state shards/spills alongside its table.
    pub fn opt_state(&self, label: &str, rows: usize) -> Result<Box<dyn EmbeddingStore>> {
        self.opt_state_cached(label, rows, None)
    }

    /// Like [`StoreConfig::opt_state`], with this state table's hot-row
    /// cache byte share (mmap backend only; `None` = uncached).
    pub fn opt_state_cached(
        &self,
        label: &str,
        rows: usize,
        cache_bytes: Option<u64>,
    ) -> Result<Box<dyn EmbeddingStore>> {
        self.build(label, rows, 1, cache_bytes)
    }
}

/// Initialize every row uniform in `[-scale, scale)`. Row `r` is drawn
/// from its own forked stream, so the result depends only on `(seed, r)`
/// — not on the backend, shard layout, write chunking, or how many init
/// threads run (threads come from `available_parallelism`, clamped).
/// Rows are written in ~256 KiB chunks via [`EmbeddingStore::set_rows`].
pub fn init_uniform_rows(store: &dyn EmbeddingStore, scale: f32, seed: u64) {
    let rows = store.rows();
    let dim = store.dim();
    if rows == 0 || dim == 0 {
        return;
    }
    let n_threads =
        if rows * dim > 1 << 22 { crate::util::threadpool::default_threads(16) } else { 1 };
    let base = Rng::seed_from_u64(seed);
    let ranges = crate::util::threadpool::split_ranges(rows, n_threads);
    crate::util::threadpool::scoped_map(n_threads, |w| {
        let range = ranges[w].clone();
        let chunk_rows = chunk_rows_for(dim, range.len());
        let mut buf = vec![0f32; chunk_rows * dim];
        let mut r = range.start;
        while r < range.end {
            let n = chunk_rows.min(range.end - r);
            for k in 0..n {
                let mut rng = base.fork((r + k) as u64);
                for v in buf[k * dim..(k + 1) * dim].iter_mut() {
                    *v = rng.gen_uniform(-scale, scale);
                }
            }
            store.set_rows(r, &buf[..n * dim]);
            r += n;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backends(tmp: &std::path::Path) -> Vec<(&'static str, StoreConfig)> {
        vec![
            ("dense", StoreConfig::dense()),
            ("sharded", StoreConfig::sharded(3)),
            ("mmap", StoreConfig::mmap(tmp.to_string_lossy().into_owned())),
        ]
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("dglke-store-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn uniform_init_identical_across_backends() {
        let tmp = tmp_dir("init");
        let mut snaps = Vec::new();
        for (name, cfg) in backends(&tmp) {
            let cfg = cfg.resolved().unwrap();
            let t = cfg.uniform(name, 33, 7, 0.5, 42).unwrap();
            assert_eq!(t.rows(), 33);
            assert_eq!(t.dim(), 7);
            let snap = t.snapshot();
            assert!(snap.iter().all(|v| *v >= -0.5 && *v < 0.5));
            snaps.push((name, snap));
        }
        for (name, s) in &snaps[1..] {
            assert_eq!(s, &snaps[0].1, "{name} init differs from dense");
        }
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn random_ops_identical_across_backends() {
        let tmp = tmp_dir("ops");
        let stores: Vec<Arc<dyn EmbeddingStore>> = backends(&tmp)
            .into_iter()
            .map(|(name, cfg)| cfg.resolved().unwrap().uniform(name, 50, 4, 0.3, 7).unwrap())
            .collect();
        let mut rng = Rng::seed_from_u64(99);
        let mut out = vec![0f32; 4 * 4];
        for _ in 0..300 {
            let op = rng.gen_index(3);
            let i = rng.gen_index(50);
            match op {
                0 => {
                    let vals: Vec<f32> = (0..4).map(|_| rng.gen_normal()).collect();
                    for s in &stores {
                        s.set_row(i, &vals);
                    }
                }
                1 => {
                    let delta = rng.gen_normal();
                    for s in &stores {
                        s.update_row(i, &mut |row| {
                            for x in row.iter_mut() {
                                *x += delta;
                            }
                        });
                    }
                }
                _ => {
                    let ids: Vec<u64> =
                        (0..4).map(|_| rng.gen_index(50) as u64).collect();
                    let mut first: Option<Vec<f32>> = None;
                    for s in &stores {
                        s.gather(&ids, &mut out);
                        match &first {
                            None => first = Some(out.clone()),
                            Some(f) => assert_eq!(f, &out),
                        }
                    }
                }
            }
        }
        let dense_snap = stores[0].snapshot();
        for s in &stores[1..] {
            assert_eq!(s.snapshot(), dense_snap, "{} diverged", s.backend_name());
        }
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn export_rows_matches_snapshot() {
        let tmp = tmp_dir("export");
        for (name, cfg) in backends(&tmp) {
            let cfg = cfg.resolved().unwrap();
            let t = cfg.uniform(name, 17, 5, 0.4, 3).unwrap();
            let mut bytes = Vec::new();
            t.export_rows(&mut bytes).unwrap();
            assert_eq!(crate::util::bytes::bytes_to_f32(&bytes), t.snapshot(), "{name}");
        }
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn default_mmap_dir_is_ephemeral_and_matches_dense() {
        let cfg = StoreConfig { backend: StoreBackendKind::Mmap, ..StoreConfig::default() };
        let t = cfg.resolved().unwrap().uniform("ephemeral", 8, 3, 0.2, 1).unwrap();
        assert_eq!(t.backend_name(), "mmap");
        let d = StoreConfig::dense().uniform("d", 8, 3, 0.2, 1).unwrap();
        assert_eq!(t.snapshot(), d.snapshot());
    }

    #[test]
    fn backend_kind_parse_round_trip() {
        for k in [StoreBackendKind::Dense, StoreBackendKind::Sharded, StoreBackendKind::Mmap] {
            assert_eq!(StoreBackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(StoreBackendKind::parse("ssd"), None);
    }

    #[test]
    fn config_validation() {
        assert!(StoreConfig { shards: 0, ..StoreConfig::default() }.validate().is_err());
        assert!(StoreConfig { budget_mb: Some(0.0), ..StoreConfig::default() }
            .validate()
            .is_err());
        assert!(StoreConfig { cache_mb: Some(-2.0), ..StoreConfig::default() }
            .validate()
            .is_err());
        assert!(StoreConfig::sharded(4).validate().is_ok());
    }

    #[test]
    fn chunk_rows_stay_at_or_under_256kib() {
        // regression: the old formula added +1, overshooting the target
        // by one row (and dim=1 tables chunked at 256 KiB + 4 B)
        let target = 1usize << 18; // 256 KiB
        for dim in [1, 3, 17, 64, 100, 65_536, 70_000] {
            let chunk = chunk_rows_for(dim, usize::MAX);
            assert!(chunk >= 1, "dim {dim}: must make progress");
            assert!(
                chunk == 1 || chunk * dim * 4 <= target,
                "dim {dim}: chunk {chunk} rows = {} bytes overshoots 256 KiB",
                chunk * dim * 4
            );
        }
        assert_eq!(chunk_rows_for(1, usize::MAX), 1 << 16, "dim=1 chunks at exactly 256 KiB");
        assert_eq!(chunk_rows_for(64, usize::MAX), 1024, "exact division must not round up");
        // still clamped to the table
        assert_eq!(chunk_rows_for(4, 10), 10);
        assert_eq!(chunk_rows_for(4, 0), 1);
    }

    #[test]
    fn cache_total_bytes_resolution() {
        let mmap = StoreConfig { backend: StoreBackendKind::Mmap, ..StoreConfig::default() };
        assert_eq!(mmap.cache_total_bytes(), None, "no budget, no cache");
        let budgeted = StoreConfig { budget_mb: Some(2.0), ..mmap.clone() };
        assert_eq!(budgeted.cache_total_bytes(), Some(2 << 20), "budget sizes the cache");
        let overridden = StoreConfig { cache_mb: Some(0.5), ..budgeted };
        assert_eq!(overridden.cache_total_bytes(), Some(1 << 19), "cache_mb wins");
        // in-memory backends never cache
        let dense = StoreConfig { budget_mb: Some(2.0), ..StoreConfig::default() };
        assert_eq!(dense.cache_total_bytes(), None);
    }

    #[test]
    fn cached_mmap_table_matches_uncached_init() {
        let cfg = StoreConfig { backend: StoreBackendKind::Mmap, ..StoreConfig::default() };
        let plain = cfg.uniform("plain", 33, 7, 0.5, 42).unwrap();
        let cached = cfg.uniform_cached("cached", 33, 7, 0.5, 42, Some(16 * 7 * 4)).unwrap();
        assert_eq!(cached.backend_name(), "cached");
        assert!(cached.cache_stats().is_some());
        assert_eq!(cached.snapshot(), plain.snapshot());
        // a sub-row share builds uncached instead of a degenerate cache
        let tiny = cfg.uniform_cached("tiny", 33, 7, 0.5, 42, Some(3)).unwrap();
        assert_eq!(tiny.backend_name(), "mmap");
        assert!(tiny.cache_stats().is_none());
    }
}
