//! The quarantined Hogwild cell: **every** intentional data race in this
//! repo flows through [`RacyCell`].
//!
//! The paper trains Hogwild (§2, citing [14]): multiple trainer threads
//! read and write rows of the shared embedding tensors without locks,
//! accepting benign races because large entity counts make row collisions
//! rare. That is undefined behavior by the letter of the Rust memory
//! model, so it is *contained* here rather than scattered: sanitizer
//! lanes (Miri, ThreadSanitizer) quarantine exactly this type — see
//! `tsan-suppressions.txt` and the `miri`/`tsan` CI jobs — which makes
//! any race *outside* `RacyCell` a hard CI failure instead of noise.
//! The full contract is cataloged in `docs/CONCURRENCY.md` ("Intentional
//! races").
//!
//! Contract accepted by every caller of the unsafe accessors:
//!
//! * Aliased `&mut` views may exist concurrently; racing writes to the
//!   same f32 lane interleave at 4-byte granularity (x86-64 aligned
//!   loads/stores are individually atomic at hardware level) — stale or
//!   mixed-lane values are possible, torn *bytes within one f32* are not
//!   on the supported targets.
//! * Accesses must stay in bounds of the wrapped value; the cell adds no
//!   bounds of its own.
//! * The wrapped value must never be structurally mutated through the
//!   cell (no `Vec` growth/realloc) while shared — callers only mutate
//!   element contents.

use std::cell::UnsafeCell;

/// A `Sync` cell handing out intentionally-racy views of its contents.
/// See the module docs for the Hogwild contract.
pub struct RacyCell<T>(UnsafeCell<T>);

// SAFETY: RacyCell exists to permit cross-thread aliased access as a
// deliberate Hogwild policy (module docs; docs/CONCURRENCY.md). `T: Send`
// bounds keep non-thread-safe payloads (Rc, etc.) out. This is the one
// sanctioned `unsafe impl` pair for shared mutation in the repo.
unsafe impl<T: Send> Sync for RacyCell<T> {}
// SAFETY: the cell owns its value; moving it between threads is as safe
// as moving `T` itself.
unsafe impl<T: Send> Send for RacyCell<T> {}

impl<T> RacyCell<T> {
    pub const fn new(value: T) -> Self {
        RacyCell(UnsafeCell::new(value))
    }

    /// Raw pointer to the contents (always safe to form; dereferencing is
    /// subject to the module contract).
    #[inline]
    pub fn get_ptr(&self) -> *mut T {
        self.0.get()
    }

    /// Shared view that may observe concurrent writes.
    ///
    /// # Safety
    /// Caller accepts the module-level Hogwild contract: the view races
    /// with concurrent `get_mut` writers at f32/word granularity.
    #[inline]
    pub unsafe fn get_ref(&self) -> &T {
        &*self.0.get()
    }

    /// Aliased mutable view.
    ///
    /// # Safety
    /// Caller accepts the module-level Hogwild contract: other `&mut`
    /// views of the same value may exist concurrently; no structural
    /// mutation (e.g. `Vec` realloc) is allowed, only element writes.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn get_mut(&self) -> &mut T {
        &mut *self.0.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_thread_read_write_roundtrip() {
        let c = RacyCell::new(vec![0f32; 4]);
        unsafe { c.get_mut()[2] = 7.5 };
        assert_eq!(unsafe { c.get_ref() }[2], 7.5);
        assert_eq!(unsafe { c.get_ref() }.len(), 4);
    }

    #[test]
    fn disjoint_concurrent_writes_all_land() {
        // Disjoint-index writes are race-free even under the quarantine
        // type (each lane has exactly one writer) — Miri-clean.
        let c = RacyCell::new(vec![0u32; 32]);
        crate::util::threadpool::scoped_map(4, |w| {
            for i in 0..8 {
                let idx = w * 8 + i;
                unsafe { c.get_mut()[idx] = idx as u32 };
            }
        });
        let v = unsafe { c.get_ref() };
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }
}
