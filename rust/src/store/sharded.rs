//! Sharded backend: N independently-allocated dense shards.
//!
//! Rows are split into contiguous blocks of `ceil(rows / shards)`; shard
//! `s` owns rows `[s·block, min((s+1)·block, rows))` in its own
//! [`DenseStore`] allocation. This (a) makes per-partition placement
//! explicit — a shard maps 1:1 to a KVStore server / machine partition —
//! and (b) keeps each shard's gather working set independently allocated,
//! so hot shards stay compact instead of striding one giant allocation.
//! Init and flush are per-shard parallel.
//!
//! Values are byte-identical to the dense backend for the same seed: row
//! init depends only on `(seed, row)` (see
//! [`crate::store::init_uniform_rows`]), and every row-granular operation
//! delegates to the owning shard.

use super::dense::DenseStore;
use super::EmbeddingStore;
use anyhow::Result;

pub struct ShardedStore {
    shards: Vec<DenseStore>,
    /// rows per shard (last shard may hold fewer)
    block: usize,
    rows: usize,
    dim: usize,
}

impl ShardedStore {
    pub fn zeros(rows: usize, dim: usize, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        let block = rows.div_ceil(n_shards).max(1);
        let shards = (0..n_shards)
            .map(|s| {
                let start = (s * block).min(rows);
                let end = ((s + 1) * block).min(rows);
                DenseStore::zeros(end - start, dim)
            })
            .collect();
        ShardedStore { shards, block, rows, dim }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns global row `i` (placement is explicit: shard
    /// index == partition index).
    pub fn shard_of(&self, i: usize) -> usize {
        i / self.block
    }

    #[inline]
    fn loc(&self, i: usize) -> (usize, usize) {
        debug_assert!(i < self.rows);
        (i / self.block, i % self.block)
    }
}

impl EmbeddingStore for ShardedStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn backend_name(&self) -> &'static str {
        "sharded"
    }

    #[inline]
    fn read_row(&self, i: usize, out: &mut [f32]) {
        let (s, l) = self.loc(i);
        out.copy_from_slice(self.shards[s].row(l));
    }

    #[inline]
    fn set_row(&self, i: usize, values: &[f32]) {
        let (s, l) = self.loc(i);
        self.shards[s].set_row(l, values);
    }

    #[inline]
    fn update_row(&self, i: usize, f: &mut dyn FnMut(&mut [f32])) {
        let (s, l) = self.loc(i);
        self.shards[s].update_row(l, f);
    }

    fn resident_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.resident_bytes()).sum()
    }

    fn flush(&self) -> Result<()> {
        // per-shard parallel flush (a no-op for in-memory shards, but the
        // fan-out is the contract disk/remote shards rely on)
        let results =
            crate::util::threadpool::scoped_map(self.shards.len(), |s| self.shards[s].flush());
        for r in results {
            r?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_rows_without_overlap() {
        for (rows, n_shards) in [(10usize, 3usize), (9, 3), (1, 4), (64, 8), (7, 1)] {
            let t = ShardedStore::zeros(rows, 2, n_shards);
            assert_eq!(t.rows(), rows);
            let total: usize = t.shards.iter().map(|s| s.rows()).sum();
            assert_eq!(total, rows, "rows={rows} shards={n_shards}");
            for i in 0..rows {
                t.set_row(i, &[i as f32, -(i as f32)]);
            }
            for i in 0..rows {
                assert_eq!(t.row_vec(i), vec![i as f32, -(i as f32)]);
            }
        }
    }

    #[test]
    fn matches_dense_for_same_seed() {
        let d = DenseStore::uniform(29, 6, 0.5, 11);
        let s = {
            let t = ShardedStore::zeros(29, 6, 4);
            super::super::init_uniform_rows(&t, 0.5, 11);
            t
        };
        assert_eq!(d.snapshot(), s.snapshot());
    }

    #[test]
    fn shard_placement_is_contiguous() {
        let t = ShardedStore::zeros(10, 1, 3);
        assert_eq!(t.n_shards(), 3);
        // block = ceil(10/3) = 4 → shards of 4, 4, 2
        assert_eq!(t.shard_of(0), 0);
        assert_eq!(t.shard_of(3), 0);
        assert_eq!(t.shard_of(4), 1);
        assert_eq!(t.shard_of(9), 2);
        assert!(t.flush().is_ok());
    }
}
