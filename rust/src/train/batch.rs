//! Batch assembly: gather embeddings for a sampled batch into the step
//! buffers (paper step 2), and map step gradients back to sparse
//! (id, row) updates (paper step 4).
//!
//! Buffers are reused across batches — no allocation on the hot loop.

use crate::models::step::{StepGrads, StepInputs, StepShape};
use crate::sampler::Batch;
use crate::store::{EmbeddingStore, SparseGrads};

/// Reusable gather buffers for one worker.
pub struct BatchBuffers {
    pub h: Vec<f32>,
    pub r: Vec<f32>,
    pub t: Vec<f32>,
    pub neg_h: Vec<f32>,
    pub neg_t: Vec<f32>,
}

impl BatchBuffers {
    pub fn new(shape: &StepShape, rel_dim: usize) -> Self {
        let (b, nc, k, d) = (shape.batch, shape.chunks, shape.neg_k, shape.dim);
        BatchBuffers {
            h: vec![0f32; b * d],
            r: vec![0f32; b * rel_dim],
            t: vec![0f32; b * d],
            neg_h: vec![0f32; nc * k * d],
            neg_t: vec![0f32; nc * k * d],
        }
    }

    /// Gather all embeddings of `batch` from the global tables (any
    /// storage backend). Returns the number of f32 values moved (for the
    /// transfer ledger).
    pub fn gather(
        &mut self,
        batch: &Batch,
        entities: &dyn EmbeddingStore,
        relations: &dyn EmbeddingStore,
    ) -> u64 {
        entities.gather(&batch.heads, &mut self.h);
        relations.gather(&batch.rels, &mut self.r);
        entities.gather(&batch.tails, &mut self.t);
        entities.gather(&batch.neg_heads, &mut self.neg_h);
        entities.gather(&batch.neg_tails, &mut self.neg_t);
        (self.h.len() + self.r.len() + self.t.len() + self.neg_h.len() + self.neg_t.len()) as u64
    }

    pub fn inputs(&self) -> StepInputs<'_> {
        StepInputs {
            h: &self.h,
            r: &self.r,
            t: &self.t,
            neg_h: &self.neg_h,
            neg_t: &self.neg_t,
        }
    }
}

/// Split step gradients into entity-sparse and relation-sparse updates,
/// folding duplicate ids (exact accumulation, like DGL-KE's index_add_).
pub fn split_grads(batch: &Batch, grads: &StepGrads, dim: usize, rel_dim: usize) -> (SparseGrads, SparseGrads) {
    let mut ent = SparseGrads::with_capacity(
        dim,
        batch.heads.len() * 2 + batch.neg_heads.len() + batch.neg_tails.len(),
    );
    ent.extend_from(&batch.heads, &grads.d_h);
    ent.extend_from(&batch.tails, &grads.d_t);
    ent.extend_from(&batch.neg_heads, &grads.d_neg_h);
    ent.extend_from(&batch.neg_tails, &grads.d_neg_t);

    let mut rel = SparseGrads::with_capacity(rel_dim, batch.rels.len());
    rel.extend_from(&batch.rels, &grads.d_r);

    (ent.accumulate(), rel.accumulate())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_and_split_roundtrip() {
        let shape = StepShape { batch: 4, chunks: 2, neg_k: 2, dim: 3 };
        let entities = crate::store::DenseStore::uniform(10, 3, 1.0, 1);
        let relations = crate::store::DenseStore::uniform(5, 3, 1.0, 2);
        let batch = Batch {
            heads: vec![1, 2, 3, 1],
            rels: vec![0, 1, 0, 2],
            tails: vec![4, 5, 6, 7],
            neg_heads: vec![8, 9, 8, 9],
            neg_tails: vec![0, 1, 2, 3],
            chunks: 2,
            neg_k: 2,
        };
        let mut buf = BatchBuffers::new(&shape, 3);
        let moved = buf.gather(&batch, &entities, &relations);
        assert_eq!(moved as usize, 4 * 3 * 3 + 2 * 2 * 3 * 2);
        assert_eq!(&buf.h[0..3], entities.row(1));
        assert_eq!(&buf.r[3..6], relations.row(1));
        assert_eq!(&buf.neg_t[0..3], entities.row(0));

        // fake grads: all ones
        let grads = StepGrads {
            loss: 0.0,
            d_h: vec![1.0; 4 * 3],
            d_r: vec![1.0; 4 * 3],
            d_t: vec![1.0; 4 * 3],
            d_neg_h: vec![1.0; 4 * 3],
            d_neg_t: vec![1.0; 4 * 3],
        };
        let (ent, rel) = split_grads(&batch, &grads, 3, 3);
        // entity 1: twice in heads + once in neg_tails → accumulated = 3.0
        let idx1 = ent.ids.iter().position(|&i| i == 1).unwrap();
        assert_eq!(&ent.rows[idx1 * 3..(idx1 + 1) * 3], &[3.0, 3.0, 3.0]);
        // no duplicate ids remain
        let set: std::collections::HashSet<_> = ent.ids.iter().collect();
        assert_eq!(set.len(), ent.ids.len());
        assert_eq!(rel.ids.len(), 3); // rels {0,1,2}, 0 twice
        let idx0 = rel.ids.iter().position(|&i| i == 0).unwrap();
        assert_eq!(&rel.rows[idx0 * 3..(idx0 + 1) * 3], &[2.0, 2.0, 2.0]);
    }
}
