//! Batch assembly: gather embeddings for a sampled batch into the step
//! buffers (paper step 2), and map step gradients back to sparse
//! (id, row) updates (paper step 4).
//!
//! Buffers are reused across batches — no allocation on the hot loop.

use crate::models::kernels::{self, KernelScratch};
use crate::models::step::{StepGrads, StepInputs, StepShape};
use crate::models::PairwiseOp;
use crate::sampler::Batch;
use crate::store::{EmbeddingStore, SparseGrads};
use std::collections::HashSet;

/// Bytes a transfer of `values` f32s moves. Gather/scatter paths count in
/// f32 values; the GPU ledger bills bytes — this is the one place that
/// conversion lives, so the ×4 can't silently drift between call sites.
pub fn bytes_moved(values: u64) -> u64 {
    values * std::mem::size_of::<f32>() as u64
}

/// Volume accounting of one [`BatchBuffers::gather`]: total f32 values
/// moved, and how many of them a hot-row cache served (entity vs
/// relation, because they bill differently under §3.4 relation
/// pinning). Hit values are credited as overlapped/zero-cost in the GPU
/// transfer ledger — a cached row never crosses the host/device link on
/// the critical path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GatherVolume {
    /// all f32 values gathered (the `bytes_moved` basis)
    pub values: u64,
    /// entity values served from a hot-row cache
    pub ent_hit_values: u64,
    /// relation values served from a hot-row cache
    pub rel_hit_values: u64,
}

/// Reusable gather buffers for one worker. Plain owned `Vec`s, so a
/// buffer set can be handed to a prefetch thread, filled there, and sent
/// back over a channel (the pipeline's double-buffer protocol) without
/// any shared-state aliasing.
pub struct BatchBuffers {
    pub h: Vec<f32>,
    pub r: Vec<f32>,
    pub t: Vec<f32>,
    pub neg_h: Vec<f32>,
    pub neg_t: Vec<f32>,
}

impl BatchBuffers {
    pub fn new(shape: &StepShape, rel_dim: usize) -> Self {
        let (b, nc, k, d) = (shape.batch, shape.chunks, shape.neg_k, shape.dim);
        BatchBuffers {
            h: vec![0f32; b * d],
            r: vec![0f32; b * rel_dim],
            t: vec![0f32; b * d],
            neg_h: vec![0f32; nc * k * d],
            neg_t: vec![0f32; nc * k * d],
        }
    }

    /// Gather all embeddings of `batch` from the global tables (any
    /// storage backend). Returns the f32 volume moved and the cache-hit
    /// share (for the transfer ledger).
    pub fn gather(
        &mut self,
        batch: &Batch,
        entities: &dyn EmbeddingStore,
        relations: &dyn EmbeddingStore,
    ) -> GatherVolume {
        let (hv, hh) = entities.gather_hits(&batch.heads, &mut self.h);
        let (rv, rh) = relations.gather_hits(&batch.rels, &mut self.r);
        let (tv, th) = entities.gather_hits(&batch.tails, &mut self.t);
        let (nhv, nhh) = entities.gather_hits(&batch.neg_heads, &mut self.neg_h);
        let (ntv, nth) = entities.gather_hits(&batch.neg_tails, &mut self.neg_t);
        GatherVolume {
            values: hv + rv + tv + nhv + ntv,
            ent_hit_values: hh + th + nhh + nth,
            rel_hit_values: rh,
        }
    }

    /// Re-gather the rows of `batch` whose ids appear in `ent_dirty` /
    /// `rel_dirty` — the ids written to the tables since this buffer was
    /// prefetched. Called by the worker after applying an update, so a
    /// pipelined gather that raced that update is repaired before compute
    /// and the prefetch pipeline stays byte-identical to the sequential
    /// loop under synchronous updates. Returns the `(entity, relation)`
    /// f32 values re-moved, separately — they bill differently: these
    /// re-gathers sit on the critical path, and relation rows only cross
    /// the link at all when relation partitioning is off (§3.4).
    pub fn patch_rows(
        &mut self,
        batch: &Batch,
        entities: &dyn EmbeddingStore,
        relations: &dyn EmbeddingStore,
        ent_dirty: &HashSet<u64>,
        rel_dirty: &HashSet<u64>,
    ) -> (u64, u64) {
        if ent_dirty.is_empty() && rel_dirty.is_empty() {
            return (0, 0);
        }
        let dim = entities.dim();
        let rel_dim = relations.dim();
        let mut ent_moved = 0u64;
        let mut rel_moved = 0u64;
        patch_section(&batch.heads, &mut self.h, entities, ent_dirty, dim, &mut ent_moved);
        patch_section(&batch.tails, &mut self.t, entities, ent_dirty, dim, &mut ent_moved);
        patch_section(&batch.neg_heads, &mut self.neg_h, entities, ent_dirty, dim, &mut ent_moved);
        patch_section(&batch.neg_tails, &mut self.neg_t, entities, ent_dirty, dim, &mut ent_moved);
        patch_section(&batch.rels, &mut self.r, relations, rel_dirty, rel_dim, &mut rel_moved);
        (ent_moved, rel_moved)
    }

    pub fn inputs(&self) -> StepInputs<'_> {
        StepInputs {
            h: &self.h,
            r: &self.r,
            t: &self.t,
            neg_h: &self.neg_h,
            neg_t: &self.neg_t,
        }
    }
}

/// One section of [`BatchBuffers::patch_rows`]: re-read the rows of `ids`
/// that appear in `dirty` into their slots of `buf`, counting f32s moved.
fn patch_section(
    ids: &[u64],
    buf: &mut [f32],
    store: &dyn EmbeddingStore,
    dirty: &HashSet<u64>,
    d: usize,
    moved: &mut u64,
) {
    for (j, id) in ids.iter().enumerate() {
        if dirty.contains(id) {
            store.read_row(*id as usize, &mut buf[j * d..(j + 1) * d]);
            *moved += d as u64;
        }
    }
}

/// Fused gather→score over entity candidates: stream `ids` rows from the
/// store through kernel tiles (`models::kernels::gather_scores`), scoring
/// each against the single query row `o`, with the same [`GatherVolume`]
/// accounting a staged [`EmbeddingStore::gather_hits`] + scalar scoring
/// pass would report — billing lives here, next to the staged path, so
/// the two can't drift. Scores are bit-identical to the staged path (the
/// kernel parity contract, `docs/KERNELS.md`).
pub fn stream_gather_scores(
    op: PairwiseOp,
    o: &[f32],
    entities: &dyn EmbeddingStore,
    ids: &[u64],
    d: usize,
    scores: &mut [f32],
    scratch: &mut KernelScratch,
) -> GatherVolume {
    let (values, ent_hit_values) = kernels::gather_scores(op, o, entities, ids, d, scores, scratch);
    GatherVolume { values, ent_hit_values, rel_hit_values: 0 }
}

/// Split step gradients into entity-sparse and relation-sparse updates,
/// folding duplicate ids (exact accumulation, like DGL-KE's index_add_).
pub fn split_grads(batch: &Batch, grads: &StepGrads, dim: usize, rel_dim: usize) -> (SparseGrads, SparseGrads) {
    let mut ent = SparseGrads::with_capacity(
        dim,
        batch.heads.len() * 2 + batch.neg_heads.len() + batch.neg_tails.len(),
    );
    ent.extend_from(&batch.heads, &grads.d_h);
    ent.extend_from(&batch.tails, &grads.d_t);
    ent.extend_from(&batch.neg_heads, &grads.d_neg_h);
    ent.extend_from(&batch.neg_tails, &grads.d_neg_t);

    let mut rel = SparseGrads::with_capacity(rel_dim, batch.rels.len());
    rel.extend_from(&batch.rels, &grads.d_r);

    (ent.accumulate(), rel.accumulate())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_and_split_roundtrip() {
        let shape = StepShape { batch: 4, chunks: 2, neg_k: 2, dim: 3 };
        let entities = crate::store::DenseStore::uniform(10, 3, 1.0, 1);
        let relations = crate::store::DenseStore::uniform(5, 3, 1.0, 2);
        let batch = Batch {
            heads: vec![1, 2, 3, 1],
            rels: vec![0, 1, 0, 2],
            tails: vec![4, 5, 6, 7],
            neg_heads: vec![8, 9, 8, 9],
            neg_tails: vec![0, 1, 2, 3],
            chunks: 2,
            neg_k: 2,
        };
        let mut buf = BatchBuffers::new(&shape, 3);
        let moved = buf.gather(&batch, &entities, &relations);
        assert_eq!(moved.values as usize, 4 * 3 * 3 + 2 * 2 * 3 * 2);
        assert_eq!(moved.ent_hit_values + moved.rel_hit_values, 0, "dense stores never hit");
        assert_eq!(&buf.h[0..3], entities.row(1));
        assert_eq!(&buf.r[3..6], relations.row(1));
        assert_eq!(&buf.neg_t[0..3], entities.row(0));

        // fake grads: all ones
        let grads = StepGrads {
            loss: 0.0,
            d_h: vec![1.0; 4 * 3],
            d_r: vec![1.0; 4 * 3],
            d_t: vec![1.0; 4 * 3],
            d_neg_h: vec![1.0; 4 * 3],
            d_neg_t: vec![1.0; 4 * 3],
        };
        let (ent, rel) = split_grads(&batch, &grads, 3, 3);
        // entity 1: twice in heads + once in neg_tails → accumulated = 3.0
        let idx1 = ent.ids.iter().position(|&i| i == 1).unwrap();
        assert_eq!(&ent.rows[idx1 * 3..(idx1 + 1) * 3], &[3.0, 3.0, 3.0]);
        // no duplicate ids remain
        let set: std::collections::HashSet<_> = ent.ids.iter().collect();
        assert_eq!(set.len(), ent.ids.len());
        assert_eq!(rel.ids.len(), 3); // rels {0,1,2}, 0 twice
        let idx0 = rel.ids.iter().position(|&i| i == 0).unwrap();
        assert_eq!(&rel.rows[idx0 * 3..(idx0 + 1) * 3], &[2.0, 2.0, 2.0]);
    }

    #[test]
    fn bytes_moved_is_four_bytes_per_value() {
        // regression for the GPU ledger math: gather() returns f32 counts,
        // every byte count billed to the ledger must go through bytes_moved
        assert_eq!(bytes_moved(0), 0);
        assert_eq!(bytes_moved(1), 4);
        assert_eq!(bytes_moved(1000), 4000);
        let shape = StepShape { batch: 4, chunks: 2, neg_k: 2, dim: 3 };
        let entities = crate::store::DenseStore::uniform(10, 3, 1.0, 1);
        let relations = crate::store::DenseStore::uniform(5, 3, 1.0, 2);
        let batch = Batch {
            heads: vec![1, 2, 3, 1],
            rels: vec![0, 1, 0, 2],
            tails: vec![4, 5, 6, 7],
            neg_heads: vec![8, 9, 8, 9],
            neg_tails: vec![0, 1, 2, 3],
            chunks: 2,
            neg_k: 2,
        };
        let mut buf = BatchBuffers::new(&shape, 3);
        let moved = buf.gather(&batch, &entities, &relations);
        let buffer_f32s =
            (buf.h.len() + buf.r.len() + buf.t.len() + buf.neg_h.len() + buf.neg_t.len()) as u64;
        assert_eq!(bytes_moved(moved.values), buffer_f32s * 4);
    }

    #[test]
    fn gather_volume_separates_ent_and_rel_hits() {
        // cached mmap tables: a second gather of the same batch is all
        // hits, split between the entity and relation sections
        let shape = StepShape { batch: 2, chunks: 1, neg_k: 2, dim: 3 };
        let cfg = crate::store::StoreConfig {
            backend: crate::store::StoreBackendKind::Mmap,
            ..Default::default()
        };
        let entities = cfg.uniform_cached("gv-ents", 10, 3, 1.0, 1, Some(10 * 3 * 4)).unwrap();
        let relations = cfg.uniform_cached("gv-rels", 5, 3, 1.0, 2, Some(5 * 3 * 4)).unwrap();
        let batch = Batch {
            heads: vec![1, 2],
            rels: vec![0, 1],
            tails: vec![3, 4],
            neg_heads: vec![5, 6],
            neg_tails: vec![7, 8],
            chunks: 1,
            neg_k: 2,
        };
        let mut buf = BatchBuffers::new(&shape, 3);
        let cold = buf.gather(&batch, &*entities, &*relations);
        assert_eq!(cold.ent_hit_values + cold.rel_hit_values, 0, "cold cache");
        let warm = buf.gather(&batch, &*entities, &*relations);
        assert_eq!(warm.values, cold.values);
        assert_eq!(warm.ent_hit_values, (8 * 3) as u64, "8 entity rows re-served");
        assert_eq!(warm.rel_hit_values, (2 * 3) as u64, "2 relation rows re-served");
    }

    #[test]
    fn stream_gather_scores_matches_staged_path() {
        let d = 3;
        let entities = crate::store::DenseStore::uniform(10, d, 1.0, 1);
        let ids: Vec<u64> = vec![1, 4, 9, 0, 2, 7, 3, 5, 8, 6]; // full tile + tail
        let o = vec![0.3f32, -1.2, 0.8];
        for op in [PairwiseOp::Dot, PairwiseOp::SqDiff, PairwiseOp::L2, PairwiseOp::L1] {
            let mut staged = vec![0f32; ids.len() * d];
            entities.gather(&ids, &mut staged);
            let mut want = vec![0f32; ids.len()];
            crate::models::ops::pairwise_forward(op, &o, &staged, d, &mut want);

            let mut got = vec![0f32; ids.len()];
            let mut scratch = KernelScratch::default();
            let vol = stream_gather_scores(op, &o, &entities, &ids, d, &mut got, &mut scratch);
            assert_eq!(want, got, "{op:?} streamed vs staged");
            assert_eq!(vol.values, (ids.len() * d) as u64);
            assert_eq!(vol.ent_hit_values, 0, "dense stores never hit");
            assert_eq!(vol.rel_hit_values, 0);
        }
    }

    #[test]
    fn stream_gather_scores_credits_cache_hits() {
        // cached mmap table: a second streaming pass over the same ids is
        // all hits, exactly like a staged warm gather
        let d = 3;
        let cfg = crate::store::StoreConfig {
            backend: crate::store::StoreBackendKind::Mmap,
            ..Default::default()
        };
        let entities = cfg.uniform_cached("sgs-ents", 10, d, 1.0, 1, Some(10 * 3 * 4)).unwrap();
        let ids: Vec<u64> = vec![0, 1, 2, 3, 4, 5, 6, 7, 8];
        let o = vec![1.0f32, 0.0, -1.0];
        let mut scores = vec![0f32; ids.len()];
        let mut scratch = KernelScratch::default();
        let cold = stream_gather_scores(
            PairwiseOp::Dot,
            &o,
            &*entities,
            &ids,
            d,
            &mut scores,
            &mut scratch,
        );
        assert_eq!(cold.ent_hit_values, 0, "cold cache");
        let warm = stream_gather_scores(
            PairwiseOp::Dot,
            &o,
            &*entities,
            &ids,
            d,
            &mut scores,
            &mut scratch,
        );
        assert_eq!(warm.values, cold.values);
        assert_eq!(warm.ent_hit_values, warm.values, "warm pass fully served from cache");
    }

    #[test]
    fn patch_rows_repairs_only_dirty_ids() {
        let shape = StepShape { batch: 2, chunks: 1, neg_k: 2, dim: 3 };
        let entities = crate::store::DenseStore::uniform(10, 3, 1.0, 3);
        let relations = crate::store::DenseStore::uniform(5, 3, 1.0, 4);
        let batch = Batch {
            heads: vec![1, 2],
            rels: vec![0, 1],
            tails: vec![3, 4],
            neg_heads: vec![5, 6],
            neg_tails: vec![7, 1],
            chunks: 1,
            neg_k: 2,
        };
        let mut buf = BatchBuffers::new(&shape, 3);
        buf.gather(&batch, &entities, &relations);

        // mutate rows 1 (head + neg_tail) and relation 1 behind the buffer
        entities.set_row(1, &[9.0, 9.0, 9.0]);
        relations.set_row(1, &[7.0, 7.0, 7.0]);
        let stale_tail = buf.t.clone();

        let ent_dirty: HashSet<u64> = [1].into_iter().collect();
        let rel_dirty: HashSet<u64> = [1].into_iter().collect();
        let (ent_moved, rel_moved) =
            buf.patch_rows(&batch, &entities, &relations, &ent_dirty, &rel_dirty);
        // entity 1 appears twice (heads[0], neg_tails[1]); relation 1 once
        assert_eq!(ent_moved, 2 * 3);
        assert_eq!(rel_moved, 3);
        assert_eq!(&buf.h[0..3], &[9.0, 9.0, 9.0]);
        assert_eq!(&buf.neg_t[3..6], &[9.0, 9.0, 9.0]);
        assert_eq!(&buf.r[3..6], &[7.0, 7.0, 7.0]);
        // untouched sections keep their gathered values
        assert_eq!(buf.t, stale_tail);
        // a patched buffer equals a fresh gather (the equivalence invariant)
        let mut fresh = BatchBuffers::new(&shape, 3);
        fresh.gather(&batch, &entities, &relations);
        assert_eq!(buf.h, fresh.h);
        assert_eq!(buf.r, fresh.r);
        assert_eq!(buf.t, fresh.t);
        assert_eq!(buf.neg_h, fresh.neg_h);
        assert_eq!(buf.neg_t, fresh.neg_t);

        // empty dirty sets are free
        assert_eq!(
            buf.patch_rows(&batch, &entities, &relations, &HashSet::new(), &HashSet::new()),
            (0, 0)
        );
    }
}
