//! Device simulation: host↔device transfer ledger (DESIGN.md
//! §Environment-constraints).
//!
//! The paper's multi-GPU results (Fig 3's 40×, Fig 4's rel_part bars) are
//! data-movement effects: how many embedding bytes cross PCIe per batch.
//! We count those bytes exactly and convert them to simulated transfer
//! time with a configurable link bandwidth (default 12 GB/s ≈ PCIe 3.0
//! x16, the paper's p3.16xlarge). Compute time is real (measured XLA
//! execution); transfer time is the counted-bytes model.

use crate::obs::metrics::{global, Counter};

/// Hardware mode of a training run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Hardware {
    /// Many-core CPU: shared memory, no transfer accounting (§6.2).
    Cpu,
    /// Simulated multi-GPU: per-batch embedding traffic is ledgered and
    /// billed at `pcie_gbps` (§6.1).
    Gpu { pcie_gbps: f64 },
}

impl Hardware {
    pub fn is_gpu(&self) -> bool {
        matches!(self, Hardware::Gpu { .. })
    }
}

/// Shared transfer ledger (one per run; workers add atomically). Each
/// counter is a private `obs::metrics` cell registered under
/// `train.transfer.*`, so the per-run totals read here also show up —
/// summed across runs — in metrics snapshots.
#[derive(Debug)]
pub struct TransferLedger {
    /// host→device bytes on the critical path
    pub h2d: Counter,
    /// device→host bytes on the critical path
    pub d2h: Counter,
    /// bytes whose transfer is overlapped with compute (async updates) —
    /// counted but not billed to the critical path
    pub overlapped: Counter,
}

impl Default for TransferLedger {
    fn default() -> Self {
        Self::new()
    }
}

impl TransferLedger {
    pub fn new() -> Self {
        TransferLedger {
            h2d: global().counter("train.transfer.h2d_bytes"),
            d2h: global().counter("train.transfer.d2h_bytes"),
            overlapped: global().counter("train.transfer.overlapped_bytes"),
        }
    }

    pub fn add_h2d(&self, bytes: u64) {
        self.h2d.add(bytes);
    }

    pub fn add_d2h(&self, bytes: u64) {
        self.d2h.add(bytes);
    }

    pub fn add_overlapped(&self, bytes: u64) {
        self.overlapped.add(bytes);
    }

    pub fn critical_bytes(&self) -> u64 {
        self.h2d.get() + self.d2h.get()
    }

    pub fn total_bytes(&self) -> u64 {
        self.critical_bytes() + self.overlapped.get()
    }

    /// Critical-path transfer seconds under `hw`'s bandwidth model,
    /// per worker (each simulated GPU has its own PCIe link, so the
    /// per-worker share is total / n_workers).
    pub fn critical_secs(&self, hw: Hardware, n_workers: usize) -> f64 {
        match hw {
            Hardware::Cpu => 0.0,
            Hardware::Gpu { pcie_gbps } => {
                self.critical_bytes() as f64 / (pcie_gbps * 1e9) / n_workers as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let l = TransferLedger::new();
        l.add_h2d(100);
        l.add_d2h(50);
        l.add_overlapped(25);
        assert_eq!(l.critical_bytes(), 150);
        assert_eq!(l.total_bytes(), 175);
    }

    #[test]
    fn cpu_mode_bills_nothing() {
        let l = TransferLedger::new();
        l.add_h2d(1 << 30);
        assert_eq!(l.critical_secs(Hardware::Cpu, 1), 0.0);
    }

    #[test]
    fn gpu_mode_bills_bandwidth() {
        let l = TransferLedger::new();
        l.add_h2d(12_000_000_000); // 12 GB at 12 GB/s = 1 s
        let s = l.critical_secs(Hardware::Gpu { pcie_gbps: 12.0 }, 1);
        assert!((s - 1.0).abs() < 1e-9);
        // split across 4 links
        let s4 = l.critical_secs(Hardware::Gpu { pcie_gbps: 12.0 }, 4);
        assert!((s4 - 0.25).abs() < 1e-9);
    }
}
