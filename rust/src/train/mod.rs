//! Training engine: the paper's §3 pipeline on one machine.
//!
//! * [`batch`] — gather/scatter between the global tables and step buffers;
//! * [`prefetch`] — the async prefetch pipeline: sample+gather one batch
//!   ahead on a helper thread, overlapped with compute (§3.5);
//! * [`updater`] — async entity-gradient updaters (§3.5);
//! * [`sync`] — periodic barriers + relation-partition reshuffles (§3.6);
//! * [`device`] — the multi-GPU transfer ledger (DESIGN.md substitution);
//! * [`worker`] + [`run_training`] — multi-worker orchestration covering
//!   the paper's many-core CPU (§6.2) and multi-GPU (§6.1) modes.
//!
//! Distributed (multi-machine) training lives in [`crate::dist`].

pub mod batch;
pub mod device;
pub mod prefetch;
pub mod sync;
pub mod updater;
pub mod worker;

pub use device::{Hardware, TransferLedger};
pub use worker::{run_training, TrainConfig, TrainStats};
