//! Async prefetch pipeline (paper §3.5: "overlap computations with
//! memory accesses").
//!
//! The sequential worker loop is `sample → gather → compute → update`;
//! with the mmap/sharded backends the gather phase is a visible chunk of
//! every step. [`Prefetcher`] turns the loop into a two-stage pipeline: a
//! helper thread owns the sampler cursors and a small pool of
//! [`BatchBuffers`], and runs sample(N+1) + gather(N+1) while the worker
//! computes step N. Hand-off is a bounded two-slot channel pair — filled
//! buffers flow worker-ward, consumed buffers flow back for reuse — so
//! the pipeline allocates nothing per step and its depth (and therefore
//! its staleness) is a hard bound, not a queue that can grow.
//!
//! Distributed trainers run the same pipeline with the gather replaced by
//! a KVStore pull wave — see [`crate::kvstore::comm::DistPrefetcher`],
//! which reuses this module's stamp + patch-on-update protocol against
//! the trainer's applied-*push* counter.
//!
//! # Determinism and staleness
//!
//! The helper thread samples from *cloned* cursors ([`PositiveSampler`] /
//! [`NegativeSampler`] are `Clone` with full RNG state), so the id
//! sequence is exactly the one the sequential loop would draw. Gathers,
//! however, run ahead of updates: buffer N+1 may be read before update N
//! lands. Every prefetched buffer is therefore stamped with the worker's
//! `applied`-update counter (read with `Acquire` *before* the gather
//! starts); the worker keeps the id sets of its last few updates and,
//! on receiving a buffer, re-gathers just the rows written since the
//! stamp ([`BatchBuffers::patch_rows`]). Under synchronous updates and a
//! single worker this repairs the race exactly — prefetch on/off is
//! byte-identical (see `rust/tests/prefetch_tests.rs`). Under async
//! updates or multiple workers, staleness is bounded by the pipeline
//! depth, which is the same Hogwild contract the async updater already
//! accepts.
//!
//! # Epoch-boundary resets
//!
//! When the relation partition is reshuffled at a sync barrier (§3.4),
//! the worker sends the new index set through a control channel and bumps
//! a generation counter. Batches sampled under the old generation are
//! discarded on receipt (their buffers recycled), so the pipeline
//! restarts cleanly without tearing down the thread.

use super::batch::{BatchBuffers, GatherVolume};
use crate::kg::TripletStore;
use crate::models::step::StepShape;
use crate::obs::trace::{span, SpanId};
use crate::sampler::{Batch, NegativeSampler, PositiveSampler};
use crate::store::EmbeddingStore;
use crate::util::timer::PhaseTimes;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use crate::util::sync::Arc;
use anyhow::{anyhow, Result};
use std::thread::{Scope, ScopedJoinHandle};

/// A sampled + gathered batch, ready for compute.
pub struct PrefetchedBatch {
    pub batch: Batch,
    pub buf: BatchBuffers,
    /// f32 volume moved by the prefetched gather, with its cache-hit
    /// share (ledger accounting)
    pub moved: GatherVolume,
    /// the worker's applied-update counter observed *before* the gather
    /// began: updates with index >= this stamp may not be reflected in
    /// the buffer and must be patched
    pub gathered_at: u64,
    /// sampler epoch after drawing this batch. Consumers must track
    /// epochs by value (`last.max(epoch)`), never by a crossing flag: a
    /// crossing carried by a batch discarded during a generation reset
    /// would be lost with a flag, silently skipping a reshuffle.
    pub epoch: u64,
    generation: u64,
}

enum Ctrl {
    /// Install a new positive index set (epoch-boundary reshuffle) and
    /// start a new generation.
    Reset(Vec<u32>),
}

/// Worker-side handle of the prefetch pipeline. Dropping it (or calling
/// [`Prefetcher::finish`]) closes the channels and stops the thread.
pub struct Prefetcher<'scope> {
    out_rx: Receiver<PrefetchedBatch>,
    free_tx: SyncSender<BatchBuffers>,
    ctrl_tx: Sender<Ctrl>,
    generation: u64,
    handle: Option<ScopedJoinHandle<'scope, PhaseTimes>>,
}

impl<'scope> Prefetcher<'scope> {
    /// Spawn the prefetch thread inside `scope`, taking ownership of the
    /// sampler cursors. `depth` buffers circulate (2 = classic double
    /// buffering); `applied` is the worker's completed-update counter used
    /// to stamp gathers for patching.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_scoped<'env>(
        scope: &'scope Scope<'scope, 'env>,
        mut pos: PositiveSampler,
        mut neg: NegativeSampler,
        triplets: &'env TripletStore,
        entities: Arc<dyn EmbeddingStore>,
        relations: Arc<dyn EmbeddingStore>,
        shape: StepShape,
        rel_dim: usize,
        depth: usize,
        // lint:allow(metrics-registry) — applied stamp (Release/Acquire), not a stat
        applied: Arc<AtomicU64>,
    ) -> Result<Prefetcher<'scope>> {
        let depth = depth.max(2);
        let (out_tx, out_rx) = sync_channel::<PrefetchedBatch>(depth);
        let (free_tx, free_rx) = sync_channel::<BatchBuffers>(depth);
        let (ctrl_tx, ctrl_rx) = crate::util::sync::mpsc::channel::<Ctrl>();
        for _ in 0..depth {
            // capacity == depth and free_rx is alive, so this only fails
            // if the runtime is already broken — surface it, don't panic
            free_tx
                .send(BatchBuffers::new(&shape, rel_dim))
                .map_err(|_| anyhow!("prefetch buffer pool channel closed during seeding"))?;
        }

        let handle = std::thread::Builder::new()
            .name("dglke-prefetch".into())
            .spawn_scoped(scope, move || {
                let mut pt = PhaseTimes::new();
                let mut generation = 0u64;
                let mut idx_buf: Vec<u32> = Vec::with_capacity(shape.batch);
                // hold the buffer across the control drain so a reset
                // arriving while we were blocked on the pool is applied
                // before we sample with it
                while let Ok(mut buf) = free_rx.recv() {
                    loop {
                        match ctrl_rx.try_recv() {
                            Ok(Ctrl::Reset(indices)) => {
                                pos.reset_indices(indices);
                                generation += 1;
                            }
                            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                        }
                    }
                    let gathered_at = applied.load(Ordering::Acquire);
                    let batch = {
                        let _s = span(SpanId::PrefetchSample);
                        pt.time("prefetch.sample", || pos.next_batch(shape.batch, &mut idx_buf));
                        pt.time("prefetch.sample", || neg.assemble(triplets, &idx_buf))
                    };
                    let moved = {
                        let _s = span(SpanId::PrefetchGather);
                        pt.time("prefetch.gather", || buf.gather(&batch, &*entities, &*relations))
                    };
                    let pb = PrefetchedBatch {
                        batch,
                        buf,
                        moved,
                        gathered_at,
                        epoch: pos.epoch(),
                        generation,
                    };
                    if out_tx.send(pb).is_err() {
                        break; // worker finished
                    }
                }
                pt
            })
            .map_err(|e| anyhow!("spawning prefetch thread: {e}"))?;

        Ok(Prefetcher { out_rx, free_tx, ctrl_tx, generation: 0, handle: Some(handle) })
    }

    /// Receive the next batch of the current generation, transparently
    /// discarding (and recycling) batches sampled before the last reset.
    /// Blocks while the pipeline is behind — that time is the pipeline
    /// stall the worker bills to its `prefetch` phase.
    pub fn recv(&mut self) -> Result<PrefetchedBatch> {
        loop {
            let pb = self
                .out_rx
                .recv()
                .map_err(|_| anyhow!("prefetch thread terminated unexpectedly"))?;
            if pb.generation == self.generation {
                return Ok(pb);
            }
            let _ = self.free_tx.send(pb.buf); // stale: recycle and retry
        }
    }

    /// Return a consumed batch's buffers to the pool so the prefetch
    /// thread can refill them.
    pub fn recycle(&self, pb: PrefetchedBatch) {
        let _ = self.free_tx.send(pb.buf);
    }

    /// Install a new positive index set (epoch-boundary relation
    /// reshuffle). In-flight batches of the old generation are discarded
    /// by [`Prefetcher::recv`].
    pub fn reset_indices(&mut self, indices: Vec<u32>) {
        self.generation += 1;
        let _ = self.ctrl_tx.send(Ctrl::Reset(indices));
    }

    /// Stop the thread and return its accumulated [`PhaseTimes`]
    /// (`prefetch.sample` / `prefetch.gather` — the overlapped, off-
    /// critical-path work).
    pub fn finish(mut self) -> Result<PhaseTimes> {
        let handle = self
            .handle
            .take()
            .ok_or_else(|| anyhow!("prefetcher already finished"))?;
        drop(self); // closes out_rx + free_tx: the thread's send/recv fails
        handle.join().map_err(|_| anyhow!("prefetch thread panicked"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kg::generator::{generate, GeneratorConfig};
    use crate::sampler::NegativeConfig;
    use crate::store::DenseStore;

    fn setup() -> (crate::kg::TripletStore, Arc<dyn EmbeddingStore>, Arc<dyn EmbeddingStore>) {
        let kg = generate(&GeneratorConfig::tiny(3));
        let n_ent = kg.store.n_entities();
        let n_rel = kg.store.n_relations();
        (
            kg.store,
            Arc::new(DenseStore::uniform(n_ent, 8, 0.4, 1)),
            Arc::new(DenseStore::uniform(n_rel, 8, 0.4, 2)),
        )
    }

    const SHAPE: StepShape = StepShape { batch: 16, chunks: 4, neg_k: 4, dim: 8 };

    fn samplers(store: &crate::kg::TripletStore) -> (PositiveSampler, NegativeSampler) {
        let pos = PositiveSampler::over_all(store, 5);
        let neg = NegativeSampler::new(
            NegativeConfig { k: SHAPE.neg_k, chunk_size: SHAPE.chunk_size(), ..Default::default() },
            store.n_entities(),
            6,
        );
        (pos, neg)
    }

    #[test]
    fn prefetched_stream_matches_sequential_stream() {
        let (store, entities, relations) = setup();
        let (pos, neg) = samplers(&store);
        let (mut seq_pos, mut seq_neg) = (pos.clone(), neg.clone());
        let applied = Arc::new(AtomicU64::new(0));

        std::thread::scope(|s| {
            let mut pf = Prefetcher::spawn_scoped(
                s,
                pos,
                neg,
                &store,
                entities.clone(),
                relations.clone(),
                SHAPE,
                8,
                2,
                applied.clone(),
            )
            .unwrap();
            let mut idx_buf = Vec::new();
            let mut seq_buf = BatchBuffers::new(&SHAPE, 8);
            for step in 0..40u64 {
                let pb = pf.recv().unwrap();
                seq_pos.next_batch(SHAPE.batch, &mut idx_buf);
                let seq_batch = seq_neg.assemble(&store, &idx_buf);
                assert_eq!(pb.batch.heads, seq_batch.heads, "step {step}");
                assert_eq!(pb.batch.rels, seq_batch.rels);
                assert_eq!(pb.batch.tails, seq_batch.tails);
                assert_eq!(pb.batch.neg_heads, seq_batch.neg_heads);
                assert_eq!(pb.batch.neg_tails, seq_batch.neg_tails);
                let moved = seq_buf.gather(&seq_batch, &*entities, &*relations);
                assert_eq!(pb.moved, moved);
                assert_eq!(pb.buf.h, seq_buf.h);
                assert_eq!(pb.buf.neg_t, seq_buf.neg_t);
                applied.store(step + 1, Ordering::Release);
                pf.recycle(pb);
            }
            let pt = pf.finish().unwrap();
            assert!(
                pt.entries().iter().any(|(p, _)| *p == "prefetch.sample"),
                "helper thread must report its sample phase"
            );
        });
    }

    #[test]
    fn reset_discards_stale_generations() {
        let (store, entities, relations) = setup();
        let (pos, neg) = samplers(&store);
        let applied = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            let mut pf = Prefetcher::spawn_scoped(
                s, pos, neg, &store, entities, relations, SHAPE, 8, 2, applied,
            )
            .unwrap();
            // take one batch, then reset to a narrow index window
            let pb = pf.recv().unwrap();
            pf.recycle(pb);
            let narrow: Vec<u32> = (0..20).collect();
            pf.reset_indices(narrow.clone());
            // everything received from now on must come from the new set
            for _ in 0..10 {
                let pb = pf.recv().unwrap();
                // ids in the batch were drawn from indices 0..20 of the store
                for &h in &pb.batch.heads {
                    let found = narrow
                        .iter()
                        .any(|&i| store.get(i as usize).head as u64 == h);
                    assert!(found, "head {h} not reachable from the reset index set");
                }
                pf.recycle(pb);
            }
            pf.finish().unwrap();
        });
    }

    #[test]
    fn stamps_are_monotone_and_bounded_by_depth() {
        let (store, entities, relations) = setup();
        let (pos, neg) = samplers(&store);
        let applied = Arc::new(AtomicU64::new(0));
        let depth = 3usize;
        std::thread::scope(|s| {
            let mut pf = Prefetcher::spawn_scoped(
                s, pos, neg, &store, entities, relations, SHAPE, 8, depth,
                applied.clone(),
            )
            .unwrap();
            let mut last_stamp = 0u64;
            for step in 0..30u64 {
                let pb = pf.recv().unwrap();
                assert!(pb.gathered_at >= last_stamp, "stamps must be monotone");
                assert!(pb.gathered_at <= step, "gather cannot observe future updates");
                // the pool bounds how far the gather can trail the consumer
                assert!(
                    step.saturating_sub(pb.gathered_at) <= depth as u64 + 1,
                    "stamp {} too stale for step {step}",
                    pb.gathered_at
                );
                last_stamp = pb.gathered_at;
                applied.store(step + 1, Ordering::Release);
                pf.recycle(pb);
            }
            pf.finish().unwrap();
        });
    }
}
