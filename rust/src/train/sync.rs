//! Periodic synchronization among trainer workers (paper §3.6).
//!
//! Fully-async multiprocessing lets workers drift apart, which the paper
//! observed to destabilize accuracy; a barrier every few thousand batches
//! keeps all trainers at roughly the same rate. The barrier is also the
//! coordination point for per-epoch relation-partition reshuffles (§3.4).

use crate::partition::RelationPartition;
use crate::util::sync::{Barrier, RwLock};

/// Shared sync state for one training run.
pub struct SyncState {
    barrier: Barrier,
    /// current relation partition (None when relation partitioning is off)
    rel_part: RwLock<Option<std::sync::Arc<RelationPartition>>>,
    /// epoch of the current partition
    rel_epoch: RwLock<u64>,
}

impl SyncState {
    pub fn new(n_workers: usize, initial: Option<RelationPartition>) -> Self {
        SyncState {
            barrier: Barrier::new(n_workers),
            rel_part: RwLock::new(initial.map(std::sync::Arc::new)),
            rel_epoch: RwLock::new(0),
        }
    }

    /// Wait for all workers. Returns true on the leader (exactly one
    /// worker per barrier crossing).
    pub fn wait(&self) -> bool {
        self.barrier.wait().is_leader()
    }

    /// Leader installs a freshly reshuffled relation partition for `epoch`.
    pub fn install_partition(&self, part: RelationPartition, epoch: u64) {
        *self.rel_part.write().unwrap() = Some(std::sync::Arc::new(part));
        *self.rel_epoch.write().unwrap() = epoch;
    }

    /// Current partition (if relation partitioning is enabled).
    pub fn partition(&self) -> Option<std::sync::Arc<RelationPartition>> {
        self.rel_part.read().unwrap().clone()
    }

    pub fn partition_epoch(&self) -> u64 {
        *self.rel_epoch.read().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn exactly_one_leader_per_crossing() {
        let sync = SyncState::new(4, None);
        let leaders = AtomicUsize::new(0);
        crate::util::threadpool::scoped_map(4, |_| {
            for _ in 0..10 {
                if sync.wait() {
                    leaders.fetch_add(1, Ordering::Relaxed);
                }
            }
        });
        assert_eq!(leaders.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn partition_install_visible_to_all() {
        use crate::kg::generator::{generate, GeneratorConfig};
        use crate::partition::partition_relations;
        let kg = generate(&GeneratorConfig::tiny(1));
        let sync = SyncState::new(2, Some(partition_relations(&kg.store, 2, 0)));
        let before = sync.partition().unwrap();
        crate::util::threadpool::scoped_map(2, |_| {
            if sync.wait() {
                sync.install_partition(partition_relations(&kg.store, 2, 99), 1);
            }
            sync.wait();
            assert_eq!(sync.partition_epoch(), 1);
        });
        let after = sync.partition().unwrap();
        assert_ne!(before.relation_part, after.relation_part);
    }
}
