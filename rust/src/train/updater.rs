//! Asynchronous entity-gradient updater (paper §3.5).
//!
//! Each trainer gets a *dedicated* updater thread. The trainer sends the
//! entity gradients of a finished batch over a channel and immediately
//! proceeds to the next batch; the updater applies sparse AdaGrad to the
//! shared table concurrently — overlapping the (random-memory-bound)
//! update with the next batch's compute, which the paper measures at
//! ~40% speedup on Freebase.
//!
//! A bounded channel caps staleness at `max_pending` batches.

use crate::store::{EmbeddingStore, SparseAdagrad, SparseGrads};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;

enum Msg {
    Apply(SparseGrads),
    Flush(SyncSender<()>),
    Stop,
}

/// Handle owned by the trainer thread.
pub struct AsyncUpdater {
    tx: SyncSender<Msg>,
    handle: Option<std::thread::JoinHandle<u64>>,
}

impl AsyncUpdater {
    /// Spawn the updater over the shared entity table/optimizer.
    pub fn spawn(
        table: Arc<dyn EmbeddingStore>,
        opt: Arc<SparseAdagrad>,
        max_pending: usize,
    ) -> AsyncUpdater {
        let (tx, rx): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(max_pending.max(1));
        let handle = std::thread::Builder::new()
            .name("dglke-updater".into())
            .spawn(move || {
                let mut applied = 0u64;
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Apply(g) => {
                            // submitted grads are pre-accumulated (split_grads)
                            opt.apply_unique(&*table, &g.ids, &g.rows);
                            applied += 1;
                        }
                        Msg::Flush(ack) => {
                            let _ = ack.send(());
                        }
                        Msg::Stop => break,
                    }
                }
                applied
            })
            .expect("spawn updater");
        AsyncUpdater { tx, handle: Some(handle) }
    }

    /// Queue one batch of entity gradients (blocks only when the updater
    /// is `max_pending` batches behind — the staleness bound). `grads`
    /// must be duplicate-free — `split_grads` pre-accumulates — since the
    /// updater takes the unique AdaGrad fast path.
    pub fn submit(&self, grads: SparseGrads) {
        self.tx.send(Msg::Apply(grads)).expect("updater thread died");
    }

    /// Wait until every queued update has been applied (used at sync
    /// barriers and before evaluation).
    pub fn flush(&self) {
        let (ack_tx, ack_rx) = sync_channel(1);
        self.tx.send(Msg::Flush(ack_tx)).expect("updater thread died");
        ack_rx.recv().expect("updater thread died");
    }

    /// Stop and join; returns the number of batches applied.
    pub fn join(mut self) -> u64 {
        let _ = self.tx.send(Msg::Stop);
        self.handle.take().unwrap().join().expect("updater panicked")
    }
}

impl Drop for AsyncUpdater {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = self.tx.send(Msg::Stop);
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::DenseStore;

    #[test]
    fn applies_all_updates() {
        let table: Arc<dyn EmbeddingStore> = Arc::new(DenseStore::zeros(4, 2));
        let opt = Arc::new(SparseAdagrad::new(4, 1.0));
        let up = AsyncUpdater::spawn(table.clone(), opt, 8);
        for _ in 0..10 {
            let mut g = SparseGrads::new(2);
            g.extend_from(&[1], &[1.0, 1.0]);
            up.submit(g);
        }
        let applied = up.join();
        assert_eq!(applied, 10);
        // row 1 moved, others untouched
        assert_ne!(table.row_vec(1), vec![0.0, 0.0]);
        assert_eq!(table.row_vec(0), vec![0.0, 0.0]);
    }

    #[test]
    fn flush_waits_for_pending() {
        let table: Arc<dyn EmbeddingStore> = Arc::new(DenseStore::zeros(2, 4));
        let opt = Arc::new(SparseAdagrad::new(2, 1.0));
        let up = AsyncUpdater::spawn(table.clone(), opt, 64);
        for _ in 0..50 {
            let mut g = SparseGrads::new(4);
            g.extend_from(&[0], &[0.1; 4]);
            up.submit(g);
        }
        up.flush();
        // after flush the row reflects all 50 updates (AdaGrad state 50·0.01)
        let moved = table.row_vec(0)[0];
        assert!(moved != 0.0);
        let snapshot = table.row_vec(0)[0];
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(table.row_vec(0)[0], snapshot, "no updates in flight after flush");
        up.join();
    }

    #[test]
    fn equivalent_to_sync_application() {
        // async updater applied N disjoint-row updates == applying inline
        let t_async: Arc<dyn EmbeddingStore> = Arc::new(DenseStore::zeros(8, 2));
        let t_sync = DenseStore::zeros(8, 2);
        let o_async = Arc::new(SparseAdagrad::new(8, 0.5));
        let o_sync = SparseAdagrad::new(8, 0.5);
        let up = AsyncUpdater::spawn(t_async.clone(), o_async, 4);
        for i in 0..8u64 {
            let mut g = SparseGrads::new(2);
            g.extend_from(&[i], &[i as f32, 1.0]);
            up.submit(g);
            o_sync.apply(&t_sync, &[i], &[i as f32, 1.0]);
        }
        up.flush();
        for i in 0..8 {
            assert_eq!(t_async.row_vec(i), t_sync.row(i));
        }
        up.join();
    }
}
