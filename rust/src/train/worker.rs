//! Multi-worker training orchestration (paper §3.1, §6.1, §6.2).
//!
//! Workers are OS threads standing in for the paper's trainer processes —
//! one per GPU (or two, §6.1.5) in GPU mode, one per core group in CPU
//! mode. Each worker:
//!
//! 1. samples positives from its triplet assignment + joint negatives,
//! 2. gathers embeddings from the shared tables (billing the transfer
//!    ledger in GPU mode),
//! 3. runs the fwd/bwd step on its own compiled PJRT executable,
//! 4. applies relation gradients inline and hands entity gradients to its
//!    dedicated async updater (§3.5) — or applies inline in sync mode,
//! 5. crosses a barrier every `sync_interval` batches (§3.6), where the
//!    leader reshuffles the relation partition at epoch boundaries (§3.4).

use super::batch::{split_grads, BatchBuffers};
use super::device::{Hardware, TransferLedger};
use super::sync::SyncState;
use super::updater::AsyncUpdater;
use crate::kg::Dataset;
use crate::models::step::StepShape;
use crate::models::{LossCfg, ModelKind};
use crate::partition::partition_relations;
use crate::runtime::{BackendKind, Manifest, TrainBackend};
use crate::sampler::{NegativeConfig, NegativeSampler, PositiveSampler};
use crate::store::{EmbeddingStore, SparseAdagrad, StoreConfig};
use crate::util::timer::{PhaseTimes, Timer};
use anyhow::Result;
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelKind,
    pub loss: LossCfg,
    pub backend: BackendKind,
    /// artifact shape family ("default" / "tiny"); ignored for native
    /// when `shape` is set
    pub artifact_tag: String,
    /// explicit shape (required for the native backend)
    pub shape: Option<StepShape>,
    pub n_workers: usize,
    pub batches_per_worker: usize,
    pub lr: f32,
    pub init_scale: f32,
    /// fraction of negatives drawn in-batch ∝ degree (§3.3 / Table 4)
    pub neg_degree_frac: f64,
    /// overlap entity updates with next-batch compute (§3.5)
    pub async_update: bool,
    /// bind relations to workers (§3.4); off = all workers sample all
    /// triplets and share all relations
    pub relation_partition: bool,
    /// barrier every this many batches (§3.6)
    pub sync_interval: usize,
    pub hardware: Hardware,
    pub seed: u64,
    /// record loss every this many batches (per worker 0)
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: ModelKind::TransEL2,
            loss: LossCfg::default(),
            backend: BackendKind::Native,
            artifact_tag: "default".into(),
            shape: None,
            n_workers: 1,
            batches_per_worker: 100,
            lr: 0.1,
            init_scale: 0.37,
            neg_degree_frac: 0.0,
            async_update: true,
            relation_partition: true,
            sync_interval: 1000,
            hardware: Hardware::Cpu,
            seed: 0,
            log_every: 50,
        }
    }
}

/// Shared mutable training state (the "model"). The tables sit behind
/// [`EmbeddingStore`], so the same trainers run over dense, sharded, or
/// file-backed (mmap) storage — pick with [`ModelState::init_with_storage`].
pub struct ModelState {
    pub entities: Arc<dyn EmbeddingStore>,
    pub relations: Arc<dyn EmbeddingStore>,
    pub ent_opt: Arc<SparseAdagrad>,
    pub rel_opt: Arc<SparseAdagrad>,
    pub dim: usize,
    pub rel_dim: usize,
}

impl ModelState {
    pub fn init(dataset: &Dataset, model: ModelKind, dim: usize, cfg: &TrainConfig) -> Self {
        Self::init_with(dataset, model, dim, cfg.lr, cfg.init_scale, cfg.seed)
    }

    /// Initialize from bare hyperparameters on the default dense backend
    /// (used by the baseline trainers and tests).
    pub fn init_with(
        dataset: &Dataset,
        model: ModelKind,
        dim: usize,
        lr: f32,
        init_scale: f32,
        seed: u64,
    ) -> Self {
        Self::init_with_storage(dataset, model, dim, lr, init_scale, seed, &StoreConfig::dense())
            .expect("dense storage init cannot fail")
    }

    /// Initialize on an explicit storage backend. Row init is per-row
    /// seeded, so every backend yields byte-identical starting tables for
    /// the same seed; optimizer state is built on the same backend so it
    /// shards/spills alongside its table.
    #[allow(clippy::too_many_arguments)]
    pub fn init_with_storage(
        dataset: &Dataset,
        model: ModelKind,
        dim: usize,
        lr: f32,
        init_scale: f32,
        seed: u64,
        storage: &StoreConfig,
    ) -> Result<Self> {
        let storage = storage.resolved()?;
        let rel_dim = model.rel_dim(dim);
        Ok(ModelState {
            entities: storage.uniform(
                "entities",
                dataset.n_entities(),
                dim,
                init_scale,
                seed ^ 0xE,
            )?,
            relations: storage.uniform(
                "relations",
                dataset.n_relations(),
                rel_dim,
                init_scale,
                seed ^ 0xF,
            )?,
            ent_opt: Arc::new(SparseAdagrad::with_storage(
                &storage,
                "entities.opt",
                dataset.n_entities(),
                lr,
            )?),
            rel_opt: Arc::new(SparseAdagrad::with_storage(
                &storage,
                "relations.opt",
                dataset.n_relations(),
                lr,
            )?),
            dim,
            rel_dim,
        })
    }

    /// Placeholder state (zero tables, unit optimizers) for runs whose
    /// real parameters live elsewhere — distributed KVStore shards
    /// initialize and train server-side, and are dumped into this state
    /// afterwards. Skips the (large) random init.
    pub fn placeholder(dataset: &Dataset, model: ModelKind, dim: usize, lr: f32) -> Self {
        let rel_dim = model.rel_dim(dim);
        ModelState {
            entities: Arc::new(crate::store::DenseStore::zeros(dataset.n_entities(), dim)),
            relations: Arc::new(crate::store::DenseStore::zeros(dataset.n_relations(), rel_dim)),
            ent_opt: Arc::new(SparseAdagrad::new(1, lr)),
            rel_opt: Arc::new(SparseAdagrad::new(1, lr)),
            dim,
            rel_dim,
        }
    }

    pub fn n_params(&self) -> usize {
        self.entities.n_params() + self.relations.n_params()
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    pub wall_secs: f64,
    /// wall + critical-path simulated transfer time (GPU mode)
    pub sim_secs: f64,
    /// simulated *parallel* wall-clock: max per-worker thread-CPU busy
    /// time + critical transfer. On this 1-core testbed concurrent
    /// threads time-share, so this — not `wall_secs` — is the multi-worker
    /// quantity comparable to the paper's multi-GPU/multi-core wall times
    /// (see DESIGN.md §Hardware-Adaptation).
    pub sim_parallel_secs: f64,
    /// per-worker thread-CPU busy seconds
    pub worker_busy_secs: Vec<f64>,
    pub total_batches: u64,
    /// throughput under the simulated-parallel clock
    pub triplets_per_sec: f64,
    pub mean_loss_tail: f32,
    pub loss_curve: Vec<(u64, f32)>,
    pub phases: Vec<(String, f64)>,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub overlapped_bytes: u64,
}

struct WorkerOut {
    phases: PhaseTimes,
    losses: Vec<(u64, f32)>,
    batches: u64,
    busy_secs: f64,
}

/// Triplet assignment for worker `w` under the current strategy/epoch.
fn assignment(
    dataset: &Dataset,
    cfg: &TrainConfig,
    sync: &SyncState,
    w: usize,
) -> Vec<u32> {
    if cfg.relation_partition && cfg.n_workers > 1 {
        let part = sync.partition().expect("relation partition missing");
        part.triplets_of(w as u32).into_iter().map(|i| i as u32).collect()
    } else {
        // strided split — balanced and disjoint
        (0..dataset.train.len() as u32)
            .filter(|i| (*i as usize) % cfg.n_workers == w)
            .collect()
    }
}

/// Run a full training job; returns aggregate stats. The embeddings are
/// left trained inside `state`.
pub fn run_training(
    dataset: &Dataset,
    state: &ModelState,
    manifest: Option<&Manifest>,
    cfg: &TrainConfig,
) -> Result<TrainStats> {
    assert!(cfg.n_workers >= 1);
    let initial_part = (cfg.relation_partition && cfg.n_workers > 1)
        .then(|| partition_relations(&dataset.train, cfg.n_workers, cfg.seed));
    let sync = SyncState::new(cfg.n_workers, initial_part);
    let ledger = TransferLedger::new();

    let timer = Timer::new();
    let outs: Vec<Result<WorkerOut>> = crate::util::threadpool::scoped_map(cfg.n_workers, |w| {
        worker_loop(dataset, state, manifest, cfg, &sync, &ledger, w)
    });
    let wall = timer.elapsed_secs();

    let mut phases = PhaseTimes::new();
    let mut losses = Vec::new();
    let mut batches = 0u64;
    let mut worker_busy = Vec::with_capacity(cfg.n_workers);
    for out in outs {
        let out = out?;
        phases.merge(&out.phases);
        batches += out.batches;
        worker_busy.push(out.busy_secs);
        if out.losses.len() > losses.len() {
            losses = out.losses;
        }
    }
    let b = cfg
        .shape
        .map(|s| s.batch)
        .or_else(|| {
            manifest.and_then(|m| {
                m.find_train(cfg.model.name(), loss_name(&cfg.loss), &cfg.artifact_tag)
                    .ok()
                    .map(|a| a.batch)
            })
        })
        .unwrap_or(0);
    let transfer = ledger.critical_secs(cfg.hardware, cfg.n_workers);
    let sim = wall + transfer;
    let max_busy = worker_busy.iter().cloned().fold(0f64, f64::max);
    let sim_parallel = max_busy + transfer;
    let tail = losses.iter().rev().take(10).map(|&(_, l)| l).collect::<Vec<_>>();
    Ok(TrainStats {
        wall_secs: wall,
        sim_secs: sim,
        sim_parallel_secs: sim_parallel,
        worker_busy_secs: worker_busy,
        total_batches: batches,
        triplets_per_sec: (batches * b as u64) as f64 / sim_parallel.max(1e-9),
        mean_loss_tail: if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().sum::<f32>() / tail.len() as f32
        },
        loss_curve: losses,
        phases: phases
            .entries()
            .iter()
            .map(|&(p, d)| (p.to_string(), d.as_secs_f64()))
            .collect(),
        h2d_bytes: ledger.h2d.load(std::sync::atomic::Ordering::Relaxed),
        d2h_bytes: ledger.d2h.load(std::sync::atomic::Ordering::Relaxed),
        overlapped_bytes: ledger.overlapped.load(std::sync::atomic::Ordering::Relaxed),
    })
}

fn loss_name(l: &LossCfg) -> &'static str {
    match l.kind {
        crate::models::LossKind::Logistic => "logistic",
        crate::models::LossKind::Margin(_) => "margin",
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    dataset: &Dataset,
    state: &ModelState,
    manifest: Option<&Manifest>,
    cfg: &TrainConfig,
    sync: &SyncState,
    ledger: &TransferLedger,
    w: usize,
) -> Result<WorkerOut> {
    // backend is created inside the worker thread (PJRT client is !Send)
    let backend = TrainBackend::create(
        cfg.backend,
        cfg.model,
        cfg.loss,
        manifest,
        &cfg.artifact_tag,
        cfg.shape,
    )?;
    let shape = backend.shape();
    let rel_dim = backend.rel_dim();
    anyhow::ensure!(
        shape.dim == state.dim && rel_dim == state.rel_dim,
        "artifact dims ({}, {}) do not match model state ({}, {})",
        shape.dim,
        rel_dim,
        state.dim,
        state.rel_dim
    );

    let mut pos = PositiveSampler::over_indices(
        assignment(dataset, cfg, sync, w),
        cfg.seed ^ (w as u64 + 1),
    );
    let mut neg = NegativeSampler::new(
        NegativeConfig {
            k: shape.neg_k,
            chunk_size: shape.chunk_size(),
            degree_frac: cfg.neg_degree_frac,
            local_pool: None,
        },
        dataset.n_entities(),
        cfg.seed ^ (0x9e00 + w as u64),
    );
    let mut buf = BatchBuffers::new(&shape, rel_dim);
    let updater = cfg
        .async_update
        .then(|| AsyncUpdater::spawn(state.entities.clone(), state.ent_opt.clone(), 4));

    let gpu = cfg.hardware.is_gpu();
    let cpu_timer = crate::util::cputime::CpuTimer::new();
    let mut phases = PhaseTimes::new();
    let mut losses = Vec::new();
    let mut idx_buf: Vec<u32> = Vec::with_capacity(shape.batch);
    let mut last_epoch = 0u64;

    for step in 0..cfg.batches_per_worker as u64 {
        // (1) sample
        let crossed = phases.time("sample", || pos.next_batch(shape.batch, &mut idx_buf));
        let batch = phases.time("sample", || neg.assemble(&dataset.train, &idx_buf));
        if crossed {
            last_epoch = pos.epoch();
        }

        // (2) gather
        let moved = phases.time("gather", || {
            buf.gather(&batch, &state.entities, &state.relations)
        });
        if gpu {
            // entity rows move host→device every batch; relation rows only
            // when relation partitioning is off (§3.4 pins them on-GPU)
            let rel_bytes = (batch.rels.len() * rel_dim * 4) as u64;
            let ent_bytes = moved * 4 - rel_bytes;
            ledger.add_h2d(ent_bytes);
            if !cfg.relation_partition {
                ledger.add_h2d(rel_bytes);
            }
        }

        // (3) compute fwd/bwd
        let grads = phases.time("compute", || backend.step(&buf.inputs()))?;
        if step % cfg.log_every as u64 == 0 {
            losses.push((step, grads.loss));
        }

        // (4) update
        phases.time("update", || {
            let (ent_g, rel_g) = split_grads(&batch, &grads, shape.dim, rel_dim);
            if gpu && !cfg.relation_partition {
                ledger.add_d2h((rel_g.rows.len() * 4) as u64);
            }
            // split_grads pre-accumulated duplicates → unique fast path
            state.rel_opt.apply_unique(&state.relations, &rel_g.ids, &rel_g.rows);
            let ent_bytes = (ent_g.rows.len() * 4) as u64;
            match &updater {
                Some(up) => {
                    if gpu {
                        ledger.add_overlapped(ent_bytes);
                    }
                    up.submit(ent_g);
                }
                None => {
                    if gpu {
                        ledger.add_d2h(ent_bytes);
                    }
                    state.ent_opt.apply_unique(&state.entities, &ent_g.ids, &ent_g.rows);
                }
            }
        });

        // (5) periodic synchronization
        if cfg.n_workers > 1 && (step + 1) % cfg.sync_interval as u64 == 0 {
            phases.time("sync", || {
                if let Some(up) = &updater {
                    up.flush();
                }
                let leader = sync.wait();
                // epoch-boundary relation reshuffle (§3.4)
                if cfg.relation_partition {
                    if leader && last_epoch > sync.partition_epoch() {
                        sync.install_partition(
                            partition_relations(
                                &dataset.train,
                                cfg.n_workers,
                                cfg.seed ^ last_epoch,
                            ),
                            last_epoch,
                        );
                    }
                    sync.wait();
                    if sync.partition_epoch() == last_epoch && last_epoch > 0 {
                        pos.reset_indices(assignment(dataset, cfg, sync, w));
                    }
                }
            });
        }
    }

    let busy_secs = cpu_timer.elapsed().as_secs_f64();
    if let Some(up) = updater {
        up.flush();
        up.join();
    }
    Ok(WorkerOut { phases, losses, batches: cfg.batches_per_worker as u64, busy_secs })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(n_workers: usize) -> TrainConfig {
        TrainConfig {
            backend: BackendKind::Native,
            shape: Some(StepShape { batch: 32, chunks: 4, neg_k: 16, dim: 16 }),
            n_workers,
            batches_per_worker: 30,
            sync_interval: 10,
            log_every: 5,
            ..Default::default()
        }
    }

    #[test]
    fn single_worker_loss_decreases() {
        let dataset = Dataset::load("tiny", 1).unwrap();
        let cfg = tiny_cfg(1);
        let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
        let stats = run_training(&dataset, &state, None, &cfg).unwrap();
        assert_eq!(stats.total_batches, 30);
        let first = stats.loss_curve.first().unwrap().1;
        let last = stats.loss_curve.last().unwrap().1;
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn multi_worker_runs_and_trains() {
        let dataset = Dataset::load("tiny", 2).unwrap();
        let mut cfg = tiny_cfg(4);
        cfg.batches_per_worker = 40;
        let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
        let stats = run_training(&dataset, &state, None, &cfg).unwrap();
        assert_eq!(stats.total_batches, 160);
        assert!(stats.mean_loss_tail < stats.loss_curve.first().unwrap().1);
    }

    #[test]
    fn gpu_mode_ledgers_transfers() {
        let dataset = Dataset::load("tiny", 3).unwrap();
        let mut cfg = tiny_cfg(2);
        cfg.hardware = Hardware::Gpu { pcie_gbps: 12.0 };
        cfg.relation_partition = false;
        cfg.async_update = false;
        let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
        let stats = run_training(&dataset, &state, None, &cfg).unwrap();
        assert!(stats.h2d_bytes > 0);
        assert!(stats.d2h_bytes > 0);
        assert!(stats.sim_secs > stats.wall_secs);
    }

    #[test]
    fn relation_partition_reduces_rel_traffic() {
        let dataset = Dataset::load("tiny", 4).unwrap();
        let mk = |rel_part: bool| {
            let mut cfg = tiny_cfg(2);
            cfg.hardware = Hardware::Gpu { pcie_gbps: 12.0 };
            cfg.relation_partition = rel_part;
            cfg.async_update = false;
            let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
            run_training(&dataset, &state, None, &cfg).unwrap()
        };
        let with = mk(true);
        let without = mk(false);
        assert!(
            with.h2d_bytes < without.h2d_bytes,
            "rel_part should cut h2d: {} vs {}",
            with.h2d_bytes,
            without.h2d_bytes
        );
    }

    #[test]
    fn async_overlap_moves_bytes_off_critical_path() {
        let dataset = Dataset::load("tiny", 5).unwrap();
        let mk = |async_update: bool| {
            let mut cfg = tiny_cfg(1);
            cfg.hardware = Hardware::Gpu { pcie_gbps: 12.0 };
            cfg.async_update = async_update;
            let state = ModelState::init(&dataset, cfg.model, 16, &cfg);
            run_training(&dataset, &state, None, &cfg).unwrap()
        };
        let a = mk(true);
        let s = mk(false);
        assert!(a.overlapped_bytes > 0);
        assert_eq!(s.overlapped_bytes, 0);
        assert!(a.d2h_bytes < s.d2h_bytes);
    }
}
